#!/usr/bin/env bash
# CI gate: formatting, lints, the tier-1 verify
# (cargo build --release && cargo test -q), then artifact-free end-to-end
# smoke runs: the weaved-store example (truncating + double-sampled host
# paths) and the fused-dot bench in --quick mode, whose assertions pin the
# double-sampling byte accounting to exactly 2x the truncating path.
# Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check =="
cargo fmt --all --check

echo "== cargo clippy -D warnings =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== tier-1 verify =="
cargo build --release
cargo test -q

echo "== example smoke: store_weaving (fused + DS host paths, no artifacts) =="
cargo run --release --example store_weaving > /dev/null

echo "== bench smoke: fused_dot --quick (asserts DS bytes == 2x truncation) =="
cargo bench --bench fused_dot -- --quick > /dev/null

echo "CI OK"
