#!/usr/bin/env bash
# CI gate: formatting, lints, then the tier-1 verify
# (cargo build --release && cargo test -q). Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check =="
cargo fmt --all --check

echo "== cargo clippy -D warnings =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== tier-1 verify =="
cargo build --release
cargo test -q

echo "CI OK"
