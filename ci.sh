#!/usr/bin/env bash
# CI gate: formatting, lints, the tier-1 verify
# (cargo build --release && cargo test -q), then an artifact-free
# end-to-end smoke run of the weaved-store example. Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check =="
cargo fmt --all --check

echo "== cargo clippy -D warnings =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== tier-1 verify =="
cargo build --release
cargo test -q

echo "== example smoke: store_weaving (fused host path, no artifacts) =="
cargo run --release --example store_weaving > /dev/null

echo "CI OK"
