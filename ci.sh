#!/usr/bin/env bash
# CI gate: formatting, lints, rustdoc (-D warnings, so the public
# HostSession/GlmLoss API stays documented), the tier-1 verify
# (cargo build --release && cargo test -q), then artifact-free end-to-end
# smoke runs: the weaved-store example (truncating + double-sampled host
# paths), a `zipml train --host --model logistic --store weaved-ds` CLI
# run (a non-linear GLM through the session, end to end, with a
# `--trace` that is then schema-validated by `zipml trace validate` and
# summarized — TRACE_smoke.jsonl is uploaded as a CI artifact)
# and the fused-dot bench in --quick mode, whose assertions pin the
# blocked/per-row byte accounting equality and DS bytes == 2x truncation
# (the perf-ratio acceptance asserts — blocked >= 2x per-row, popcount
# beating f32 at q <= 4 — enforce only at full budgets, i.e. under
# `ci.sh --bench`; quick smoke runs warn instead of failing on noisy
# shared runners) — and which writes the machine-readable perf trajectory
# BENCH_kernels.json at the repo root (uploaded as a CI artifact).
#
# Usage: ci.sh [--quick|--bench|--analyze|--simd]
#   (default) full gate; the bench smoke runs with --quick budgets
#   --quick   alias for the default gate (kept for muscle memory)
#   --bench   build + run the fused-dot bench at FULL measurement budgets,
#             refreshing BENCH_kernels.json with trajectory-quality numbers
#   --analyze concurrency & invariant verification (DESIGN.md §11, §13):
#             zipml-lint v2 (all twelve rules, cross-file flow analysis)
#             over rust/src in baseline-diff mode — findings land in
#             LINT_findings.json (CI artifact) and the run fails only on
#             findings not in LINT_baseline.json — plus its fixture
#             suites, the cfg-matrix typecheck (default cfg, nightly
#             `--features simd`, `--cfg loom`), then the loom models
#             (RUSTFLAGS="--cfg loom"); Miri/TSan run as separate
#             nightly CI jobs (see .github/workflows/ci.yml)
#   --simd    the std::simd twin tier (DESIGN.md §12) on the pinned
#             nightly: full test suite with `--features simd` (includes
#             the forced-tier A/B suite in tests/simd_twins.rs), then the
#             fused-dot bench smoke with the feature on, writing
#             BENCH_kernels_simd.json so scalar and simd trajectories can
#             be diffed side by side
# Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")"

MODE="${1:-gate}"
case "$MODE" in
  gate|--quick|--bench|--analyze|--simd) ;;
  *) echo "usage: ci.sh [--quick|--bench|--analyze|--simd]  (got: $MODE)" >&2; exit 2 ;;
esac

if [[ "$MODE" == "--analyze" ]]; then
  NIGHTLY="${SANITIZER_NIGHTLY:-nightly-2025-07-01}"
  echo "== zipml-lint v2: twelve invariant rules over rust/src, baseline diff (DESIGN.md §11, §13) =="
  # writes the full findings stream (JSONL, one object per finding) to
  # LINT_findings.json — uploaded as a CI artifact — and fails only on
  # findings absent from the committed LINT_baseline.json
  cargo run --release -p zipml-lint -- --json=LINT_findings.json --baseline=LINT_baseline.json
  echo "== zipml-lint: rule unit + fixture tests (each rule fires at its seeded lines) =="
  cargo test --release -p zipml-lint -q
  echo "== cfg-matrix: every cfg surface typechecks (default / simd nightly / --cfg loom) =="
  cargo check --workspace --all-targets
  if command -v rustup > /dev/null && rustup toolchain list | grep -q "$NIGHTLY"; then
    cargo +"$NIGHTLY" check -p zipml --features simd
  else
    echo "   (skipping --features simd leg: pinned nightly $NIGHTLY not installed)"
  fi
  RUSTFLAGS="--cfg loom" cargo check --release -p zipml --test loom_models
  echo "== loom models: ShardedU64 / store byte accounting / RacyF32Cell =="
  RUSTFLAGS="--cfg loom" cargo test --release -p zipml --test loom_models -- --nocapture
  echo "ANALYZE OK"
  exit 0
fi

if [[ "$MODE" == "--simd" ]]; then
  NIGHTLY="${SANITIZER_NIGHTLY:-nightly-2025-07-01}"
  echo "== simd feature tests on pinned nightly ($NIGHTLY) =="
  cargo +"$NIGHTLY" test -p zipml --features simd -q
  echo "== simd bench smoke: fused_dot --features simd --quick (writes BENCH_kernels_simd.json) =="
  ZIPML_BENCH_JSON=BENCH_kernels_simd.json \
    cargo +"$NIGHTLY" bench -p zipml --features simd --bench fused_dot -- --quick > /dev/null
  echo "SIMD OK — trajectory in BENCH_kernels_simd.json"
  exit 0
fi

if [[ "$MODE" == "--bench" ]]; then
  echo "== cargo build --release =="
  cargo build --release
  echo "== bench: fused_dot (full budgets, writes BENCH_kernels.json) =="
  cargo bench --bench fused_dot
  echo "BENCH OK — trajectory in BENCH_kernels.json"
  exit 0
fi

echo "== cargo fmt --check =="
cargo fmt --all --check

echo "== cargo clippy -D warnings =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== cargo doc --no-deps (rustdoc -D warnings: the public API stays documented) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace

echo "== tier-1 verify =="
cargo build --release
cargo test -q

echo "== example smoke: store_weaving (HostSession fused + DS paths, no artifacts) =="
cargo run --release --example store_weaving > /dev/null

echo "== CLI smoke: logistic GLM over the double-sampled weaved store, traced (HostSession) =="
cargo run --release --bin zipml -- \
  train --host --model logistic --store weaved-ds --bits 3 --epochs 2 \
  --trace TRACE_smoke.jsonl --trace-level full > /dev/null

echo "== trace smoke: schema-validate + summarize the emitted TRACE_smoke.jsonl =="
cargo run --release --bin zipml -- trace validate TRACE_smoke.jsonl
cargo run --release --bin zipml -- trace summarize TRACE_smoke.jsonl > /dev/null

echo "== bench smoke: fused_dot --quick (blocked/popcount/accounting asserts; writes BENCH_kernels.json) =="
cargo bench --bench fused_dot -- --quick > /dev/null

echo "CI OK"
