//! `zipml` — leader entrypoint / CLI for the ZipML reproduction.
//!
//! Commands:
//!   zipml list                         list figures/tables and artifacts
//!   zipml figure <id>|all [--quick]    regenerate a paper figure (CSV + stdout)
//!   zipml train [opts]                 train one model/mode combination
//!   zipml trace summarize|validate F   inspect a --trace JSONL file
//!   zipml fpga-sim [--k K --n N]       print the pipeline cycle model
//!   zipml quantize-demo                optimal-vs-uniform levels demo
//!
//! (clap is not in the offline crate set — parsing is hand-rolled.)

#![forbid(unsafe_code)]

use std::sync::Arc;

use anyhow::{bail, Result};

use zipml::coordinator::{self, Ctx};
use zipml::data;
use zipml::quant::ColumnScale;
use zipml::sgd::{
    self, modes::RefetchStrategy, HostSession, Mode, ModelKind, ReadStrategy, StoreBackend,
    TrainConfig,
};
use zipml::store::{PrecisionSchedule, ShardedStore};
use zipml::telemetry::{self, Metrics, TraceLevel, TraceSink};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        None | Some("help") | Some("--help") => {
            println!("{}", HELP);
            Ok(())
        }
        Some("list") => cmd_list(),
        Some("figure") => cmd_figure(&args[1..]),
        Some("train") => cmd_train(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("fpga-sim") => cmd_fpga(&args[1..]),
        Some("quantize-demo") => cmd_quantize_demo(),
        Some(other) => bail!("unknown command {other:?}\n{HELP}"),
    }
}

const HELP: &str = "zipml — end-to-end low-precision training (ZipML reproduction)

USAGE:
  zipml list
  zipml figure <id>|all [--quick] [--seed N]
  zipml train --model linreg|lssvm|logistic|svm --mode MODE [--dataset D]
              [--bits B] [--epochs E] [--lr F] [--batch N] [--seed N]
              [--store legacy|weaved|weaved-ds] [--shards N] [--schedule S]
              [--store-bits W] [--bits-m M] [--bits-g G]
              [--host] [--step-bits Q] [--plane-index]
              [--trace FILE [--trace-level counters|spans|full]]
       MODE: fp32 | naive | ds | dsu8 | e2e | mq | gq | optimal | round
             | cheby | poly | refetch-l1 | refetch-jl
       S (weaved stores, reads p planes/epoch): fixed | step | refetch
       weaved    reads truncate to the top p = --bits planes (--mode naive)
       weaved-ds reads draw two unbiased stochastic p = --bits plane
                 samples per row — §2.2 double sampling from one copy
                 (--mode ds); the store is ingested at --store-bits W
                 (default min(2·bits, 16)), and W > p keeps the carry
                 planes live
       --bits-m M / --bits-g G  (--mode e2e only) model / gradient
                 quantization widths, 1..=16, default 8 each — the §E
                 end-to-end point (samples stay at --bits)
       --host    artifact-free GLM training on the fused host kernels —
                 any --model (linreg|lssvm|logistic|svm): the session
                 computes a^T x in the weaved domain and applies the
                 loss's step multiplier on the host (no PJRT runtime
                 needed; --store weaved or weaved-ds; needs --epochs >= 1)
       --step-bits Q  (with --host --store weaved) popcount fast path:
                 round g = m*x to Q sign/magnitude bit planes per step and
                 dot by AND+POPCNT; unbiased, off by default
       --plane-index  (--host only) build the per-plane occupancy index
                 after ingestion: truncating reads skip all-zero 8-word
                 plane runs in O(1), bit-identical results (DESIGN.md
                 §12); index bytes are derived metadata, not wire traffic
       --trace FILE   (--host only) write a JSONL telemetry trace: run
                 header, per-epoch loss/precision/exact-byte rollups,
                 phase spans, counter totals, and a cross-checked summary
                 (schema: DESIGN.md §10). --trace-level picks the
                 detail: counters (epoch rollups + counters), spans
                 (default; + phase spans), full (+ per-shard bytes)
  zipml trace summarize <file.jsonl>   per-epoch table from a --trace file
  zipml trace validate <file.jsonl>    schema + consistency check a trace
  zipml fpga-sim [--k K] [--n N]
  zipml quantize-demo";

fn cmd_list() -> Result<()> {
    println!("figures / tables:");
    for (id, desc, _) in coordinator::FIGURES {
        println!("  {id:10} {desc}");
    }
    if let Ok(rt) = zipml::runtime::Runtime::open_default() {
        println!("\nartifacts ({}):", rt.manifest.artifacts.len());
        for name in rt.manifest.artifacts.keys() {
            println!("  {name}");
        }
    } else {
        println!("\n(artifacts not built — run `make artifacts`)");
    }
    println!("\ndatasets:");
    for (name, ktr, kte, n, task) in data::TABLE1 {
        println!("  {name:16} train={ktr:7} test={kte:7} n={n:5} {task:?}");
    }
    Ok(())
}

fn cmd_figure(args: &[String]) -> Result<()> {
    let id = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .map(String::as_str)
        .unwrap_or("all");
    let mut ctx = Ctx::new(flag(args, "--quick"))?;
    if let Some(s) = opt(args, "--seed") {
        ctx.seed = s.parse()?;
    }
    if id == "all" {
        for (fid, _, _) in coordinator::FIGURES {
            println!("\n##### running {fid} #####");
            coordinator::run_figure(&ctx, fid)?;
        }
    } else {
        coordinator::run_figure(&ctx, id)?;
    }
    Ok(())
}

fn parse_mode(args: &[String], mode: &str, bits: u32) -> Result<Mode> {
    if mode != "e2e" && (opt(args, "--bits-m").is_some() || opt(args, "--bits-g").is_some()) {
        bail!("--bits-m/--bits-g quantize the model/gradient of --mode e2e (got --mode {mode})");
    }
    Ok(match mode {
        "fp32" | "full" => Mode::Full,
        "naive" => Mode::Naive { bits },
        "ds" => Mode::DoubleSample { bits },
        "dsu8" => Mode::DoubleSampleU8 { bits },
        "e2e" => {
            let bits_m: u32 = opt(args, "--bits-m").map(|v| v.parse()).transpose()?.unwrap_or(8);
            let bits_g: u32 = opt(args, "--bits-g").map(|v| v.parse()).transpose()?.unwrap_or(8);
            for (name, b) in [("--bits-m", bits_m), ("--bits-g", bits_g)] {
                if !(1..=16).contains(&b) {
                    bail!("{name} must be 1..=16, got {b}");
                }
            }
            Mode::EndToEnd { bits_s: bits, bits_m, bits_g }
        }
        "mq" => Mode::ModelQuant { bits },
        "gq" => Mode::GradQuant { bits },
        "optimal" => Mode::OptimalDs { levels: 1 << bits },
        "round" => Mode::NearestRound { bits },
        "cheby" => Mode::Cheby { bits },
        "poly" => Mode::PolyDs { bits },
        "refetch-l1" => Mode::Refetch { bits, strategy: RefetchStrategy::L1 },
        "refetch-jl" => Mode::Refetch {
            bits,
            strategy: RefetchStrategy::L2Jl { sketch_dim: 64, delta: 0.05 },
        },
        other => bail!("unknown mode {other}"),
    })
}

/// `--model` (+ `--c` for LS-SVM), shared by the artifact and host paths.
fn parse_model(args: &[String]) -> Result<ModelKind> {
    Ok(match opt(args, "--model").unwrap_or("linreg") {
        "linreg" => ModelKind::Linreg,
        "lssvm" => ModelKind::Lssvm {
            c: opt(args, "--c").map(|v| v.parse()).transpose()?.unwrap_or(1e-4),
        },
        "logistic" => ModelKind::Logistic,
        "svm" => ModelKind::Svm,
        other => bail!("unknown model {other}"),
    })
}

/// Per-epoch read-precision schedule for the weaved store backends.
fn parse_schedule(args: &[String], bits: u32) -> Result<PrecisionSchedule> {
    Ok(match opt(args, "--schedule").unwrap_or("fixed") {
        "fixed" => PrecisionSchedule::Fixed(bits),
        "step" => PrecisionSchedule::StepUp { start: 1.max(bits / 4), every: 3, max: bits },
        "refetch" => PrecisionSchedule::RefetchTriggered {
            start: 1.max(bits / 4),
            max: bits,
            min_rel_improve: 0.01,
        },
        other => bail!("unknown schedule {other}"),
    })
}

/// Artifact-free host training over the weaved store: one
/// [`HostSession`] composes any `--model` (linreg, LS-SVM, logistic,
/// SVM/hinge) with any read strategy — truncating (`--store weaved`),
/// double-sampled (`--store weaved-ds`), or popcount (`--step-bits Q`,
/// DESIGN.md §8) — on the fused weaved-domain kernels directly. No PJRT
/// runtime, no artifacts: runs in every checkout.
fn cmd_train_host(args: &[String]) -> Result<()> {
    let model = parse_model(args)?;
    if let Some(mode) = opt(args, "--mode") {
        // the host path's algorithm is picked by --model, --store
        // (truncating / double-sampled), and --step-bits, never by
        // --mode — reject it rather than silently training something
        // else than requested
        bail!("--host ignores --mode (got {mode}): use --model, --store weaved|weaved-ds");
    }
    if opt(args, "--bits-m").is_some() || opt(args, "--bits-g").is_some() {
        // same reject-don't-ignore rule as --mode: these flags belong to
        // the artifact e2e mode, the host session has no model/gradient
        // quantization axis
        bail!("--bits-m/--bits-g quantize the artifact e2e step (--mode e2e), not --host runs");
    }
    let bits: u32 = opt(args, "--bits").map(|v| v.parse()).transpose()?.unwrap_or(5);
    let seed: u64 = opt(args, "--seed").map(|v| v.parse()).transpose()?.unwrap_or(42);
    let epochs: usize = opt(args, "--epochs").map(|v| v.parse()).transpose()?.unwrap_or(15);
    if epochs == 0 {
        // regression guard: a 0-epoch run would "report" only the
        // untrained model's loss as the final result
        bail!("--epochs 0 trains nothing (the curve would only hold the untrained model); \
               pass --epochs >= 1");
    }
    let batch: usize = opt(args, "--batch").map(|v| v.parse()).transpose()?.unwrap_or(64);
    let lr0: f32 = opt(args, "--lr").map(|v| v.parse()).transpose()?.unwrap_or(0.05);
    let shards: usize = opt(args, "--shards").map(|v| v.parse()).transpose()?.unwrap_or(16);
    let step_bits: Option<u32> = opt(args, "--step-bits").map(|v| v.parse()).transpose()?;
    if let Some(q) = step_bits {
        if !(1..=16).contains(&q) {
            bail!("--step-bits must be 1..=16, got {q}");
        }
    }
    let trace_path = opt(args, "--trace");
    let trace_level = match opt(args, "--trace-level") {
        Some(s) => {
            if trace_path.is_none() {
                bail!("--trace-level picks the detail of --trace: add --trace FILE");
            }
            TraceLevel::parse(s).map_err(anyhow::Error::msg)?
        }
        None => TraceLevel::Spans,
    };
    let dataset_name = opt(args, "--dataset").unwrap_or(if model.is_classification() {
        "cod-rna"
    } else {
        "synthetic100"
    });
    let ds = data::by_name(dataset_name, seed)?;
    let scale = ColumnScale::from_data(&ds.train_a);
    let schedule = parse_schedule(args, bits)?;
    let ingest_seed = seed ^ 0x5745_4156_4544; // "WEAVED"
    let store_kind = opt(args, "--store").unwrap_or("weaved");
    let ingest_start = zipml::telemetry::Stopwatch::start();
    let (mut store, read) = match store_kind {
        "weaved" => (
            ShardedStore::ingest(&ds.train_a, &scale, bits, ingest_seed, shards, 0),
            match step_bits {
                Some(q) => ReadStrategy::Popcount { q },
                None => ReadStrategy::Truncate,
            },
        ),
        "weaved-ds" => {
            if step_bits.is_some() {
                bail!("--step-bits is the truncating popcount path: use --store weaved");
            }
            let store_bits: u32 = opt(args, "--store-bits")
                .map(|v| v.parse())
                .transpose()?
                .unwrap_or_else(|| (2 * bits).min(16));
            if store_bits <= bits {
                eprintln!(
                    "warning: --store-bits {store_bits} <= read precision {bits}: \
                     double-sampled reads degenerate to exact truncation"
                );
            }
            (
                ShardedStore::ingest(&ds.train_a, &scale, store_bits, ingest_seed, shards, 0),
                ReadStrategy::DoubleSample,
            )
        }
        other => bail!("--host needs --store weaved|weaved-ds, got {other}"),
    };
    if flag(args, "--plane-index") {
        store.build_plane_index();
        eprintln!(
            "plane index: {} occupancy bytes (derived metadata, not wire traffic)",
            store.index_bytes()
        );
    }
    let ingest_secs = ingest_start.elapsed_secs();
    // One registry serves both views: the store tallies its exact-byte
    // accounting into it on every read, the session reads it back for the
    // trace's `counters` events — so the two agree bit for bit.
    let metrics = Arc::new(Metrics::enabled());
    let sink = match trace_path {
        Some(p) => {
            store.attach_metrics(Arc::clone(&metrics));
            let sink = TraceSink::to_path(std::path::Path::new(p), trace_level)?;
            sink.emit_at(
                TraceLevel::Spans,
                "span",
                &[("name", "ingest".into()), ("secs", ingest_secs.into())],
            );
            Some(sink)
        }
        None => None,
    };
    let mut sess = HostSession::over(&ds, &store)
        .loss(&model)
        .read(read)
        .schedule(schedule)
        .epochs(epochs)
        .batch(batch)
        .lr0(lr0)
        .seed(seed);
    if let Some(t) = &sink {
        sess = sess.metrics(&metrics).trace(t);
    }
    let r = sess.run()?;
    println!(
        "training [{}] on {dataset_name} (n={}, K={}, p={bits})",
        r.label,
        ds.n(),
        ds.k_train()
    );
    for (e, l) in r.loss_curve.iter().enumerate() {
        println!("  epoch {e:3}  loss {l:.6}");
    }
    println!(
        "final={:.6} bytes/epoch={:.3e} precisions={:?}",
        r.loss_curve.last().unwrap(),
        r.sample_bytes_per_epoch,
        r.precisions
    );
    if let (Some(t), Some(p)) = (&sink, trace_path) {
        let events = t.finish()?;
        println!("trace: {events} events ({}) -> {p}", trace_level.as_str());
    }
    Ok(())
}

/// Inspect a `--trace` JSONL file: `validate` runs the DESIGN.md §10
/// schema and consistency checks; `summarize` prints the per-epoch table
/// (after validating).
fn cmd_trace(args: &[String]) -> Result<()> {
    let usage = "usage: zipml trace summarize|validate <file.jsonl>";
    let (sub, path) = match (args.first().map(String::as_str), args.get(1)) {
        (Some(sub @ ("summarize" | "validate")), Some(path)) => (sub, path),
        _ => bail!("{usage}"),
    };
    let text = std::fs::read_to_string(path)?;
    match sub {
        "summarize" => {
            print!("{}", telemetry::summarize(&text).map_err(anyhow::Error::msg)?);
        }
        _ => {
            let st = telemetry::validate(&text).map_err(anyhow::Error::msg)?;
            let loss = st.final_loss.map_or("-".to_string(), |l| format!("{l:.6}"));
            println!(
                "ok: {} events, {} epochs, {} bytes read, final loss {loss}",
                st.events, st.epochs, st.total_bytes
            );
        }
    }
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    if flag(args, "--host") {
        return cmd_train_host(args);
    }
    if opt(args, "--step-bits").is_some() {
        bail!("--step-bits is a host-kernel feature: add --host (see zipml help)");
    }
    if opt(args, "--trace").is_some() || opt(args, "--trace-level").is_some() {
        bail!("--trace is a host-session feature: add --host (see zipml help)");
    }
    if flag(args, "--plane-index") {
        bail!("--plane-index accelerates the host kernels: add --host (see zipml help)");
    }
    let model = parse_model(args)?;
    let bits: u32 = opt(args, "--bits").map(|v| v.parse()).transpose()?.unwrap_or(5);
    let mode = parse_mode(args, opt(args, "--mode").unwrap_or("ds"), bits)?;
    let dataset_name = opt(args, "--dataset").unwrap_or(if model.is_classification() {
        "cod-rna"
    } else {
        "synthetic100"
    });
    let seed: u64 = opt(args, "--seed").map(|v| v.parse()).transpose()?.unwrap_or(42);

    let ds = data::by_name(dataset_name, seed)?;
    let rt = zipml::runtime::Runtime::open_default()?;
    let mut cfg = TrainConfig::new(model, mode);
    cfg.epochs = opt(args, "--epochs").map(|v| v.parse()).transpose()?.unwrap_or(15);
    cfg.lr0 = opt(args, "--lr").map(|v| v.parse()).transpose()?.unwrap_or(0.05);
    cfg.batch = opt(args, "--batch").map(|v| v.parse()).transpose()?.unwrap_or(64);
    cfg.seed = seed;
    let store_kind = opt(args, "--store").unwrap_or("legacy");
    if !matches!(store_kind, "legacy" | "weaved" | "weaved-ds") {
        bail!("unknown store backend {store_kind} (legacy|weaved|weaved-ds)");
    }
    if store_kind != "legacy" {
        let shards: usize = opt(args, "--shards").map(|v| v.parse()).transpose()?.unwrap_or(16);
        let schedule = parse_schedule(args, bits)?;
        cfg.store = if store_kind == "weaved-ds" {
            if !matches!(cfg.mode, Mode::DoubleSample { .. }) {
                bail!("--store weaved-ds runs the double-sampling step: use --mode ds");
            }
            // the store must be wider than the read precision, or the
            // carry planes are empty and the "stochastic" draw degenerates
            // to the deterministic truncation
            let store_bits: u32 = opt(args, "--store-bits")
                .map(|v| v.parse())
                .transpose()?
                .unwrap_or_else(|| (2 * bits).min(16));
            if store_bits <= bits {
                eprintln!(
                    "warning: --store-bits {store_bits} <= read precision {bits}: \
                     double-sampled reads degenerate to exact truncation"
                );
            }
            StoreBackend::WeavedDs { shards, schedule, store_bits }
        } else {
            StoreBackend::Weaved { shards, schedule }
        };
    }

    println!("training {model:?} mode={} on {dataset_name} (n={}, K={})",
        cfg.mode.label(), ds.n(), ds.k_train());
    let r = sgd::train(&rt, &ds, &cfg)?;
    for (e, l) in r.loss_curve.iter().enumerate() {
        println!("  epoch {e:3}  loss {l:.6}");
    }
    println!(
        "final={:.6} wall={:.2}s bytes/epoch={:.3e} refetch={:.2}%{}",
        r.final_loss,
        r.wall_secs,
        r.sample_bytes_per_epoch,
        r.refetch_fraction * 100.0,
        if r.diverged { " DIVERGED" } else { "" }
    );
    let st = rt.stats();
    println!("runtime: {} executions, {} compiles, {:.3}s in PJRT",
        st.executions, st.compile_count, st.exec_nanos as f64 * 1e-9);
    Ok(())
}

fn cmd_fpga(args: &[String]) -> Result<()> {
    let k: usize = opt(args, "--k").map(|v| v.parse()).transpose()?.unwrap_or(50_000);
    let n: usize = opt(args, "--n").map(|v| v.parse()).transpose()?.unwrap_or(90);
    println!("FPGA pipeline model, K={k} samples, n={n} features:");
    let base = zipml::fpga::epoch_seconds(zipml::fpga::Precision::Float, k, n);
    for p in [
        zipml::fpga::Precision::Float,
        zipml::fpga::Precision::Q(8),
        zipml::fpga::Precision::Q(4),
        zipml::fpga::Precision::Q(2),
        zipml::fpga::Precision::Q(1),
    ] {
        let t = zipml::fpga::epoch_seconds(p, k, n);
        println!("  {:6}  epoch {:.4e}s   speedup {:.2}x", p.label(), t, base / t);
    }
    println!("  hogwild(10 cores) epoch {:.4e}s",
        zipml::fpga::hogwild::hogwild_epoch_seconds(k, n, 10));
    Ok(())
}

fn cmd_quantize_demo() -> Result<()> {
    let mut rng = zipml::rng::Rng::new(7);
    let mut pts: Vec<f32> = (0..3000).map(|_| (rng.normal() * 0.1 + 0.3).clamp(0.0, 1.0)).collect();
    pts.extend((0..500).map(|_| (rng.normal() * 0.03 + 0.85).clamp(0.0, 1.0)));
    for nlevels in [4usize, 8, 16] {
        let uniform: Vec<f32> = (0..nlevels).map(|i| i as f32 / (nlevels - 1) as f32).collect();
        let opt_lv = zipml::quant::optimal_levels(&pts, nlevels);
        let mv_u = zipml::quant::quantization_variance(&pts, &uniform);
        let mv_o = zipml::quant::quantization_variance(&pts, &opt_lv);
        println!("levels={nlevels:2}  uniform MV={mv_u:.3e}  optimal MV={mv_o:.3e}  gain={:.2}x",
            mv_u / mv_o);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    /// CLI regression: `--host --epochs 0` must bail with a clear message
    /// instead of reporting the untrained model (or panicking downstream).
    #[test]
    fn train_host_epochs_zero_bails() {
        let err = cmd_train_host(&a(&["--epochs", "0"])).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("--epochs"), "unhelpful error: {msg}");
    }

    /// `--bits-m`/`--bits-g` reach the e2e mode (no more hardcoded 8/8)
    /// and default to 8 when absent.
    #[test]
    fn parse_mode_e2e_bits_flags() {
        let args = a(&["--bits-m", "4", "--bits-g", "6"]);
        assert_eq!(
            parse_mode(&args, "e2e", 5).unwrap(),
            Mode::EndToEnd { bits_s: 5, bits_m: 4, bits_g: 6 }
        );
        assert_eq!(
            parse_mode(&a(&[]), "e2e", 5).unwrap(),
            Mode::EndToEnd { bits_s: 5, bits_m: 8, bits_g: 8 }
        );
        assert!(parse_mode(&a(&["--bits-m", "0"]), "e2e", 5).is_err());
        assert!(parse_mode(&a(&["--bits-g", "17"]), "e2e", 5).is_err());
    }

    /// The flags are e2e-only: other modes reject them instead of
    /// silently ignoring them.
    #[test]
    fn bits_flags_rejected_outside_e2e() {
        let err = parse_mode(&a(&["--bits-m", "4"]), "ds", 5).unwrap_err();
        assert!(format!("{err:#}").contains("e2e"));
        assert!(parse_mode(&a(&[]), "ds", 5).is_ok());
    }

    /// `--host` accepts every GLM; unknown models still error.
    #[test]
    fn parse_model_accepts_all_glms() {
        assert_eq!(parse_model(&a(&["--model", "linreg"])).unwrap(), ModelKind::Linreg);
        assert_eq!(parse_model(&a(&["--model", "logistic"])).unwrap(), ModelKind::Logistic);
        assert_eq!(parse_model(&a(&["--model", "svm"])).unwrap(), ModelKind::Svm);
        assert_eq!(
            parse_model(&a(&["--model", "lssvm", "--c", "0.5"])).unwrap(),
            ModelKind::Lssvm { c: 0.5 }
        );
        assert!(parse_model(&a(&["--model", "resnet"])).is_err());
    }

    /// End-to-end host smoke: a logistic model trains over the
    /// double-sampled weaved store straight from the CLI path (the ci.sh
    /// gate runs the same invocation through the built binary).
    #[test]
    fn train_host_logistic_weaved_ds_smoke() {
        cmd_train_host(&a(&[
            "--model",
            "logistic",
            "--store",
            "weaved-ds",
            "--dataset",
            "cod-rna",
            "--bits",
            "3",
            "--epochs",
            "2",
            "--plane-index",
        ]))
        .unwrap();
    }

    /// `--host` still rejects `--mode`, artifact-only flags, and bad
    /// store kinds instead of silently ignoring them.
    #[test]
    fn train_host_rejects_mode_and_bad_store() {
        assert!(cmd_train_host(&a(&["--mode", "ds"])).is_err());
        assert!(cmd_train_host(&a(&["--bits-m", "4"])).is_err());
        assert!(cmd_train_host(&a(&["--bits-g", "4"])).is_err());
        assert!(cmd_train_host(&a(&["--store", "legacy"])).is_err());
        assert!(cmd_train_host(&a(&["--store", "weaved-ds", "--step-bits", "4"])).is_err());
        assert!(cmd_train_host(&a(&["--step-bits", "0"])).is_err());
    }

    /// `--trace-level` modifies `--trace`, and both are host-session
    /// flags: lone or artifact-path uses bail with a pointer to the fix.
    #[test]
    fn trace_flags_validated() {
        let err = cmd_train_host(&a(&["--trace-level", "full"])).unwrap_err();
        assert!(format!("{err:#}").contains("--trace"), "unhelpful: {err:#}");
        let err = cmd_train(&a(&["--trace", "t.jsonl"])).unwrap_err();
        assert!(format!("{err:#}").contains("--host"), "unhelpful: {err:#}");
        let err = cmd_train(&a(&["--plane-index"])).unwrap_err();
        assert!(format!("{err:#}").contains("--host"), "unhelpful: {err:#}");
        // bad level names are rejected before any training happens
        assert!(cmd_train_host(&a(&["--trace", "t.jsonl", "--trace-level", "verbose"])).is_err());
        // the trace subcommand needs a known verb and a file
        assert!(cmd_trace(&a(&["dump", "t.jsonl"])).is_err());
        assert!(cmd_trace(&a(&["validate"])).is_err());
    }

    /// End-to-end CLI trace: a host run with `--trace` emits a JSONL
    /// file that `zipml trace validate` and `summarize` both accept.
    #[test]
    fn train_host_trace_round_trips_through_validate() {
        let name = format!("zipml_cli_trace_{}.jsonl", std::process::id());
        let path = std::env::temp_dir().join(name);
        let p = path.to_str().unwrap();
        cmd_train_host(&a(&[
            "--store",
            "weaved-ds",
            "--bits",
            "3",
            "--epochs",
            "2",
            "--trace",
            p,
            "--trace-level",
            "full",
        ]))
        .unwrap();
        cmd_trace(&a(&["validate", p])).unwrap();
        cmd_trace(&a(&["summarize", p])).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
