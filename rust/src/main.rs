//! `zipml` — leader entrypoint / CLI for the ZipML reproduction.
//!
//! Commands:
//!   zipml list                         list figures/tables and artifacts
//!   zipml figure <id>|all [--quick]    regenerate a paper figure (CSV + stdout)
//!   zipml train [opts]                 train one model/mode combination
//!   zipml fpga-sim [--k K --n N]       print the pipeline cycle model
//!   zipml quantize-demo                optimal-vs-uniform levels demo
//!
//! (clap is not in the offline crate set — parsing is hand-rolled.)

use anyhow::{bail, Result};

use zipml::coordinator::{self, Ctx};
use zipml::data;
use zipml::quant::ColumnScale;
use zipml::sgd::{self, modes::RefetchStrategy, Mode, ModelKind, StoreBackend, TrainConfig};
use zipml::store::{PrecisionSchedule, ShardedStore};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        None | Some("help") | Some("--help") => {
            println!("{}", HELP);
            Ok(())
        }
        Some("list") => cmd_list(),
        Some("figure") => cmd_figure(&args[1..]),
        Some("train") => cmd_train(&args[1..]),
        Some("fpga-sim") => cmd_fpga(&args[1..]),
        Some("quantize-demo") => cmd_quantize_demo(),
        Some(other) => bail!("unknown command {other:?}\n{HELP}"),
    }
}

const HELP: &str = "zipml — end-to-end low-precision training (ZipML reproduction)

USAGE:
  zipml list
  zipml figure <id>|all [--quick] [--seed N]
  zipml train --model linreg|lssvm|logistic|svm --mode MODE [--dataset D]
              [--bits B] [--epochs E] [--lr F] [--batch N] [--seed N]
              [--store legacy|weaved|weaved-ds] [--shards N] [--schedule S]
              [--store-bits W] [--host] [--step-bits Q]
       MODE: fp32 | naive | ds | dsu8 | e2e | mq | gq | optimal | round
             | cheby | poly | refetch-l1 | refetch-jl
       S (weaved stores, reads p planes/epoch): fixed | step | refetch
       weaved    reads truncate to the top p = --bits planes (--mode naive)
       weaved-ds reads draw two unbiased stochastic p = --bits plane
                 samples per row — §2.2 double sampling from one copy
                 (--mode ds); the store is ingested at --store-bits W
                 (default min(2·bits, 16)), and W > p keeps the carry
                 planes live
       --host    artifact-free linreg training on the fused host kernels
                 (no PJRT runtime needed; --store weaved or weaved-ds)
       --step-bits Q  (with --host --store weaved) popcount fast path:
                 round g = m*x to Q sign/magnitude bit planes per step and
                 dot by AND+POPCNT; unbiased, off by default
  zipml fpga-sim [--k K] [--n N]
  zipml quantize-demo";

fn cmd_list() -> Result<()> {
    println!("figures / tables:");
    for (id, desc, _) in coordinator::FIGURES {
        println!("  {id:10} {desc}");
    }
    if let Ok(rt) = zipml::runtime::Runtime::open_default() {
        println!("\nartifacts ({}):", rt.manifest.artifacts.len());
        for name in rt.manifest.artifacts.keys() {
            println!("  {name}");
        }
    } else {
        println!("\n(artifacts not built — run `make artifacts`)");
    }
    println!("\ndatasets:");
    for (name, ktr, kte, n, task) in data::TABLE1 {
        println!("  {name:16} train={ktr:7} test={kte:7} n={n:5} {task:?}");
    }
    Ok(())
}

fn cmd_figure(args: &[String]) -> Result<()> {
    let id = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .map(String::as_str)
        .unwrap_or("all");
    let mut ctx = Ctx::new(flag(args, "--quick"))?;
    if let Some(s) = opt(args, "--seed") {
        ctx.seed = s.parse()?;
    }
    if id == "all" {
        for (fid, _, _) in coordinator::FIGURES {
            println!("\n##### running {fid} #####");
            coordinator::run_figure(&ctx, fid)?;
        }
    } else {
        coordinator::run_figure(&ctx, id)?;
    }
    Ok(())
}

fn parse_mode(mode: &str, bits: u32) -> Result<Mode> {
    Ok(match mode {
        "fp32" | "full" => Mode::Full,
        "naive" => Mode::Naive { bits },
        "ds" => Mode::DoubleSample { bits },
        "dsu8" => Mode::DoubleSampleU8 { bits },
        "e2e" => Mode::EndToEnd { bits_s: bits, bits_m: 8, bits_g: 8 },
        "mq" => Mode::ModelQuant { bits },
        "gq" => Mode::GradQuant { bits },
        "optimal" => Mode::OptimalDs { levels: 1 << bits },
        "round" => Mode::NearestRound { bits },
        "cheby" => Mode::Cheby { bits },
        "poly" => Mode::PolyDs { bits },
        "refetch-l1" => Mode::Refetch { bits, strategy: RefetchStrategy::L1 },
        "refetch-jl" => Mode::Refetch {
            bits,
            strategy: RefetchStrategy::L2Jl { sketch_dim: 64, delta: 0.05 },
        },
        other => bail!("unknown mode {other}"),
    })
}

/// Per-epoch read-precision schedule for the weaved store backends.
fn parse_schedule(args: &[String], bits: u32) -> Result<PrecisionSchedule> {
    Ok(match opt(args, "--schedule").unwrap_or("fixed") {
        "fixed" => PrecisionSchedule::Fixed(bits),
        "step" => PrecisionSchedule::StepUp { start: 1.max(bits / 4), every: 3, max: bits },
        "refetch" => PrecisionSchedule::RefetchTriggered {
            start: 1.max(bits / 4),
            max: bits,
            min_rel_improve: 0.01,
        },
        other => bail!("unknown schedule {other}"),
    })
}

/// Artifact-free host training over the weaved store (linreg): runs the
/// fused weaved-domain kernels directly — no PJRT runtime, no artifacts —
/// so the truncating, double-sampled, and popcount hot paths are
/// exercisable from the CLI in every checkout. `--step-bits Q` switches
/// the truncating path onto the integer popcount fast path (DESIGN.md §8).
fn cmd_train_host(args: &[String]) -> Result<()> {
    let model = opt(args, "--model").unwrap_or("linreg");
    if model != "linreg" {
        bail!("--host runs the artifact-free linreg kernels; got --model {model}");
    }
    if let Some(mode) = opt(args, "--mode") {
        // the host path's algorithm is picked by --store (truncating /
        // double-sampled) and --step-bits, never by --mode — reject it
        // rather than silently training something else than requested
        bail!("--host ignores --mode (got {mode}): use --store weaved|weaved-ds, --step-bits");
    }
    let bits: u32 = opt(args, "--bits").map(|v| v.parse()).transpose()?.unwrap_or(5);
    let seed: u64 = opt(args, "--seed").map(|v| v.parse()).transpose()?.unwrap_or(42);
    let epochs: usize = opt(args, "--epochs").map(|v| v.parse()).transpose()?.unwrap_or(15);
    let batch: usize = opt(args, "--batch").map(|v| v.parse()).transpose()?.unwrap_or(64);
    let lr0: f32 = opt(args, "--lr").map(|v| v.parse()).transpose()?.unwrap_or(0.05);
    let shards: usize = opt(args, "--shards").map(|v| v.parse()).transpose()?.unwrap_or(16);
    let step_bits: Option<u32> = opt(args, "--step-bits").map(|v| v.parse()).transpose()?;
    if let Some(q) = step_bits {
        if !(1..=16).contains(&q) {
            bail!("--step-bits must be 1..=16, got {q}");
        }
    }
    let dataset_name = opt(args, "--dataset").unwrap_or("synthetic100");
    let ds = data::by_name(dataset_name, seed)?;
    let scale = ColumnScale::from_data(&ds.train_a);
    let schedule = parse_schedule(args, bits)?;
    let ingest_seed = seed ^ 0x5745_4156_4544; // "WEAVED"
    let store_kind = opt(args, "--store").unwrap_or("weaved");
    let (label, r) = match store_kind {
        "weaved" => {
            let store = ShardedStore::ingest(&ds.train_a, &scale, bits, ingest_seed, shards, 0);
            match step_bits {
                Some(q) => (
                    format!("host fused popcount (q={q})"),
                    sgd::train_store_host_q(&ds, &store, schedule, q, epochs, batch, lr0, seed),
                ),
                None => (
                    "host fused truncating".to_string(),
                    sgd::train_store_host(&ds, &store, schedule, epochs, batch, lr0, seed),
                ),
            }
        }
        "weaved-ds" => {
            if step_bits.is_some() {
                bail!("--step-bits is the truncating popcount path: use --store weaved");
            }
            let store_bits: u32 = opt(args, "--store-bits")
                .map(|v| v.parse())
                .transpose()?
                .unwrap_or_else(|| (2 * bits).min(16));
            if store_bits <= bits {
                eprintln!(
                    "warning: --store-bits {store_bits} <= read precision {bits}: \
                     double-sampled reads degenerate to exact truncation"
                );
            }
            let store =
                ShardedStore::ingest(&ds.train_a, &scale, store_bits, ingest_seed, shards, 0);
            (
                "host fused double-sampling".to_string(),
                sgd::train_store_host_ds(&ds, &store, schedule, epochs, batch, lr0, seed),
            )
        }
        other => bail!("--host needs --store weaved|weaved-ds, got {other}"),
    };
    println!(
        "training linreg [{label}] on {dataset_name} (n={}, K={}, p={bits})",
        ds.n(),
        ds.k_train()
    );
    for (e, l) in r.loss_curve.iter().enumerate() {
        println!("  epoch {e:3}  loss {l:.6}");
    }
    println!(
        "final={:.6} bytes/epoch={:.3e} precisions={:?}",
        r.loss_curve.last().unwrap(),
        r.sample_bytes_per_epoch,
        r.precisions
    );
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    if flag(args, "--host") {
        return cmd_train_host(args);
    }
    if opt(args, "--step-bits").is_some() {
        bail!("--step-bits is a host-kernel feature: add --host (see zipml help)");
    }
    let model = match opt(args, "--model").unwrap_or("linreg") {
        "linreg" => ModelKind::Linreg,
        "lssvm" => ModelKind::Lssvm {
            c: opt(args, "--c").map(|v| v.parse()).transpose()?.unwrap_or(1e-4),
        },
        "logistic" => ModelKind::Logistic,
        "svm" => ModelKind::Svm,
        other => bail!("unknown model {other}"),
    };
    let bits: u32 = opt(args, "--bits").map(|v| v.parse()).transpose()?.unwrap_or(5);
    let mode = parse_mode(opt(args, "--mode").unwrap_or("ds"), bits)?;
    let dataset_name = opt(args, "--dataset").unwrap_or(if model.is_classification() {
        "cod-rna"
    } else {
        "synthetic100"
    });
    let seed: u64 = opt(args, "--seed").map(|v| v.parse()).transpose()?.unwrap_or(42);

    let ds = data::by_name(dataset_name, seed)?;
    let rt = zipml::runtime::Runtime::open_default()?;
    let mut cfg = TrainConfig::new(model, mode);
    cfg.epochs = opt(args, "--epochs").map(|v| v.parse()).transpose()?.unwrap_or(15);
    cfg.lr0 = opt(args, "--lr").map(|v| v.parse()).transpose()?.unwrap_or(0.05);
    cfg.batch = opt(args, "--batch").map(|v| v.parse()).transpose()?.unwrap_or(64);
    cfg.seed = seed;
    let store_kind = opt(args, "--store").unwrap_or("legacy");
    if !matches!(store_kind, "legacy" | "weaved" | "weaved-ds") {
        bail!("unknown store backend {store_kind} (legacy|weaved|weaved-ds)");
    }
    if store_kind != "legacy" {
        let shards: usize = opt(args, "--shards").map(|v| v.parse()).transpose()?.unwrap_or(16);
        let schedule = parse_schedule(args, bits)?;
        cfg.store = if store_kind == "weaved-ds" {
            if !matches!(cfg.mode, Mode::DoubleSample { .. }) {
                bail!("--store weaved-ds runs the double-sampling step: use --mode ds");
            }
            // the store must be wider than the read precision, or the
            // carry planes are empty and the "stochastic" draw degenerates
            // to the deterministic truncation
            let store_bits: u32 = opt(args, "--store-bits")
                .map(|v| v.parse())
                .transpose()?
                .unwrap_or_else(|| (2 * bits).min(16));
            if store_bits <= bits {
                eprintln!(
                    "warning: --store-bits {store_bits} <= read precision {bits}: \
                     double-sampled reads degenerate to exact truncation"
                );
            }
            StoreBackend::WeavedDs { shards, schedule, store_bits }
        } else {
            StoreBackend::Weaved { shards, schedule }
        };
    }

    println!("training {model:?} mode={} on {dataset_name} (n={}, K={})",
        cfg.mode.label(), ds.n(), ds.k_train());
    let r = sgd::train(&rt, &ds, &cfg)?;
    for (e, l) in r.loss_curve.iter().enumerate() {
        println!("  epoch {e:3}  loss {l:.6}");
    }
    println!(
        "final={:.6} wall={:.2}s bytes/epoch={:.3e} refetch={:.2}%{}",
        r.final_loss,
        r.wall_secs,
        r.sample_bytes_per_epoch,
        r.refetch_fraction * 100.0,
        if r.diverged { " DIVERGED" } else { "" }
    );
    let st = rt.stats();
    println!("runtime: {} executions, {} compiles, {:.3}s in PJRT",
        st.executions, st.compile_count, st.exec_nanos as f64 * 1e-9);
    Ok(())
}

fn cmd_fpga(args: &[String]) -> Result<()> {
    let k: usize = opt(args, "--k").map(|v| v.parse()).transpose()?.unwrap_or(50_000);
    let n: usize = opt(args, "--n").map(|v| v.parse()).transpose()?.unwrap_or(90);
    println!("FPGA pipeline model, K={k} samples, n={n} features:");
    let base = zipml::fpga::epoch_seconds(zipml::fpga::Precision::Float, k, n);
    for p in [
        zipml::fpga::Precision::Float,
        zipml::fpga::Precision::Q(8),
        zipml::fpga::Precision::Q(4),
        zipml::fpga::Precision::Q(2),
        zipml::fpga::Precision::Q(1),
    ] {
        let t = zipml::fpga::epoch_seconds(p, k, n);
        println!("  {:6}  epoch {:.4e}s   speedup {:.2}x", p.label(), t, base / t);
    }
    println!("  hogwild(10 cores) epoch {:.4e}s",
        zipml::fpga::hogwild::hogwild_epoch_seconds(k, n, 10));
    Ok(())
}

fn cmd_quantize_demo() -> Result<()> {
    let mut rng = zipml::rng::Rng::new(7);
    let mut pts: Vec<f32> = (0..3000).map(|_| (rng.normal() * 0.1 + 0.3).clamp(0.0, 1.0)).collect();
    pts.extend((0..500).map(|_| (rng.normal() * 0.03 + 0.85).clamp(0.0, 1.0)));
    for nlevels in [4usize, 8, 16] {
        let uniform: Vec<f32> = (0..nlevels).map(|i| i as f32 / (nlevels - 1) as f32).collect();
        let opt_lv = zipml::quant::optimal_levels(&pts, nlevels);
        let mv_u = zipml::quant::quantization_variance(&pts, &uniform);
        let mv_o = zipml::quant::quantization_variance(&pts, &opt_lv);
        println!("levels={nlevels:2}  uniform MV={mv_u:.3e}  optimal MV={mv_o:.3e}  gain={:.2}x",
            mv_u / mv_o);
    }
    Ok(())
}
