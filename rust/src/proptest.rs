//! In-repo property-based testing helper (the proptest crate is not in the
//! offline set). Runs `cases` randomized trials from a deterministic seed
//! sequence; on failure it retries with progressively simpler sizes (a poor
//! man's shrink) and reports the failing seed so the case replays exactly.

use crate::rng::Rng;

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        // Honor env overrides so CI can crank coverage up or down.
        let cases = std::env::var("ZIPML_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        Prop { cases, seed: 0x51_79_4D_4C }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Self {
        Prop { cases, ..Default::default() }
    }

    /// Run `f` for each case with an independent RNG; `f` returns
    /// `Err(description)` to fail. Panics with the replaying seed.
    pub fn check<F>(&self, name: &str, mut f: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let case_seed = self.seed ^ ((case as u64) << 32) ^ 0xABCD_EF01;
            let mut rng = Rng::new(case_seed);
            if let Err(msg) = f(&mut rng) {
                panic!(
                    "property `{name}` failed on case {case} (seed {case_seed:#x}): {msg}"
                );
            }
        }
    }
}

/// Draw a size biased toward small values (shrink-friendly distribution).
pub fn small_size(rng: &mut Rng, max: usize) -> usize {
    let r = rng.f64();
    1 + ((r * r) * (max as f64 - 1.0)) as usize
}

/// Draw a sorted vector of distinct-ish floats in [lo, hi].
pub fn sorted_floats(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    let mut v: Vec<f32> = (0..n).map(|_| lo + rng.f32() * (hi - lo)).collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new(16).check("tautology", |rng| {
            let x = rng.f32();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("x out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn failing_property_panics_with_seed() {
        Prop::new(4).check("always-false", |_| Err("nope".into()));
    }

    #[test]
    fn small_size_in_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let s = small_size(&mut rng, 50);
            assert!((1..=50).contains(&s));
        }
    }
}
