//! The crate's single wall-clock portal.
//!
//! zipml-lint's `wall-clock` rule (and the clippy `disallowed-methods`
//! backstop in `clippy.toml`) forbid `Instant::now` / `SystemTime`
//! outside `telemetry/` and `bench.rs`: wall-clock reads anywhere else
//! would leak nondeterminism into traced fields and silently break the
//! fixed-seed determinism contract
//! ([`crate::telemetry::UNSTABLE_FIELDS`], [`crate::telemetry::stable_view`]).
//! Code that legitimately times work — the SGD drivers' `wall_secs`,
//! the runtime's `exec_nanos`, example printouts — goes through
//! [`Stopwatch`] instead, which keeps every wall-clock read inside the
//! telemetry boundary and makes new nondeterministic fields a
//! deliberate, greppable act.

use std::time::Instant;

/// A started wall-clock timer. The only sanctioned way to measure
/// elapsed time outside `telemetry/` and `bench.rs`.
///
/// Anything derived from a `Stopwatch` is wall-clock-dependent and must
/// only ever feed fields listed in [`crate::telemetry::UNSTABLE_FIELDS`]
/// (or human-facing printouts) — never fields the fixed-seed
/// determinism contract covers.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    /// Start timing now.
    #[allow(clippy::disallowed_methods)] // the one sanctioned Instant::now
    pub fn start() -> Self {
        Stopwatch { t0: Instant::now() }
    }

    /// Seconds elapsed since [`Stopwatch::start`].
    pub fn elapsed_secs(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Whole nanoseconds elapsed since [`Stopwatch::start`], saturating
    /// at `u64::MAX` (~584 years).
    pub fn elapsed_nanos(&self) -> u64 {
        u64::try_from(self.t0.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_is_monotone_nonnegative() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_nanos();
        let b = sw.elapsed_nanos();
        assert!(b >= a);
        assert!(sw.elapsed_secs() >= 0.0);
    }

    #[test]
    fn copies_share_the_start_instant() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_nanos();
        let copy = sw;
        assert!(copy.elapsed_nanos() >= a, "a copy measures from the same start");
    }
}
