//! The JSONL trace sink, its flat-JSON reader, and the schema tools
//! (`zipml trace summarize|validate`) built on it.
//!
//! One trace is a sequence of flat JSON objects, one per line, each with
//! a `"kind"` discriminator (DESIGN.md §10 specifies the schema). The
//! emitter is [`crate::bench`]'s serde-free writer ([`JsonVal`] values
//! rendered through `bench::JsonObj` — the repo's ONLY JSON emitters,
//! per zipml-lint's `json-emitter` rule), so pathological labels are
//! exactly as safe here as in `BENCH_kernels.json`; the reader below is
//! the matching serde-free parser for flat objects — it powers the CLI
//! subcommands and the determinism tests.
//!
//! Determinism contract: under a fixed seed and sequential execution,
//! every emitted field is bit-reproducible EXCEPT the wall-clock timing
//! fields and the racy hogwild publish tallies, enumerated in
//! [`UNSTABLE_FIELDS`]. [`stable_view`] strips exactly those, so two
//! same-seed traces compare byte-identical line by line.

use std::io::Write;
use std::sync::Mutex;

use crate::bench::{JsonObj, JsonVal};

/// How much a [`TraceSink`] records. Ordered: each level is a superset
/// of the previous one.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    /// Run metadata, per-epoch rollups, final counter totals, summary.
    Counters,
    /// `Counters` plus phase spans (`ingest`, `epoch`, `grad_batch`,
    /// `eval`, per-worker `hogwild_epoch`).
    Spans,
    /// `Spans` plus per-shard byte attribution.
    Full,
}

impl TraceLevel {
    /// Parse the CLI spelling (`counters|spans|full`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "counters" => Ok(TraceLevel::Counters),
            "spans" => Ok(TraceLevel::Spans),
            "full" => Ok(TraceLevel::Full),
            other => Err(format!("unknown trace level {other:?} (counters|spans|full)")),
        }
    }

    /// The CLI spelling, also recorded in the trace's `run` event.
    pub fn as_str(&self) -> &'static str {
        match self {
            TraceLevel::Counters => "counters",
            TraceLevel::Spans => "spans",
            TraceLevel::Full => "full",
        }
    }
}

enum SinkOut {
    File(std::io::BufWriter<std::fs::File>),
    Mem(Vec<u8>),
}

struct Inner {
    out: SinkOut,
    err: Option<std::io::Error>,
    events: u64,
}

/// A JSONL trace writer: one flat object per [`TraceSink::emit`], in
/// emission order. Write errors are latched and reported once by
/// [`TraceSink::finish`] so the training hot path never branches on IO.
pub struct TraceSink {
    level: TraceLevel,
    inner: Mutex<Inner>,
}

impl TraceSink {
    /// A sink writing (buffered) to `path`, truncating any existing file.
    pub fn to_path(path: &std::path::Path, level: TraceLevel) -> std::io::Result<Self> {
        let f = std::fs::File::create(path)?;
        Ok(TraceSink {
            level,
            inner: Mutex::new(Inner {
                out: SinkOut::File(std::io::BufWriter::new(f)),
                err: None,
                events: 0,
            }),
        })
    }

    /// An in-memory sink (tests, validators): read back with
    /// [`TraceSink::lines`].
    pub fn in_memory(level: TraceLevel) -> Self {
        TraceSink {
            level,
            inner: Mutex::new(Inner { out: SinkOut::Mem(Vec::new()), err: None, events: 0 }),
        }
    }

    pub fn level(&self) -> TraceLevel {
        self.level
    }

    /// Emit one event unconditionally (the caller gates on level; most
    /// call sites use [`TraceSink::emit_at`]). `kind` becomes the leading
    /// `"kind"` field.
    pub fn emit(&self, kind: &str, fields: &[(&str, JsonVal)]) {
        let mut obj = JsonObj::with_capacity(96);
        obj.field_str("kind", kind);
        for (k, v) in fields {
            obj.field(k, v);
        }
        let mut line = obj.finish();
        line.push('\n');
        let mut inner = self.inner.lock().expect("trace sink poisoned");
        inner.events += 1;
        if inner.err.is_some() {
            return;
        }
        let r = match &mut inner.out {
            SinkOut::File(w) => w.write_all(line.as_bytes()),
            SinkOut::Mem(buf) => {
                buf.extend_from_slice(line.as_bytes());
                Ok(())
            }
        };
        if let Err(e) = r {
            inner.err = Some(e);
        }
    }

    /// Emit only when this sink records at least `min` detail.
    pub fn emit_at(&self, min: TraceLevel, kind: &str, fields: &[(&str, JsonVal)]) {
        if self.level >= min {
            self.emit(kind, fields);
        }
    }

    /// Events emitted so far (including any dropped after an IO error).
    pub fn events(&self) -> u64 {
        self.inner.lock().expect("trace sink poisoned").events
    }

    /// The emitted lines (in-memory sinks; empty for file sinks).
    pub fn lines(&self) -> Vec<String> {
        let inner = self.inner.lock().expect("trace sink poisoned");
        match &inner.out {
            SinkOut::Mem(buf) => String::from_utf8_lossy(buf)
                .lines()
                .map(|l| l.to_string())
                .collect(),
            SinkOut::File(_) => Vec::new(),
        }
    }

    /// Flush and surface any latched write error; returns the event count.
    pub fn finish(&self) -> std::io::Result<u64> {
        let mut inner = self.inner.lock().expect("trace sink poisoned");
        if let Some(e) = inner.err.take() {
            return Err(e);
        }
        if let SinkOut::File(w) = &mut inner.out {
            w.flush()?;
        }
        Ok(inner.events)
    }
}

// ---------------------------------------------------------------------------
// Reading traces back: a serde-free parser for the flat objects we emit
// ---------------------------------------------------------------------------

/// One parsed JSON scalar (the trace schema is flat: no arrays/objects
/// nest inside an event).
#[derive(Clone, Debug, PartialEq)]
pub enum JsonScalar {
    Num(f64),
    Str(String),
    Bool(bool),
    Null,
}

impl JsonScalar {
    /// Numeric value, if this scalar is a number.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            JsonScalar::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// String value, if this scalar is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonScalar::Str(s) => Some(s),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn next(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\r' | b'\n')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        match self.next() {
            Some(got) if got == c => Ok(()),
            got => Err(format!("expected {:?} at byte {}, got {:?}", c as char, self.i, got)),
        }
    }

    fn hex4(&mut self) -> Result<u16, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| "non-ascii \\u escape".to_string())?;
        self.i += 4;
        u16::from_str_radix(s, 16).map_err(|_| format!("bad \\u escape {s:?}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut buf: Vec<u8> = Vec::new();
        loop {
            let c = self.next().ok_or("unterminated string")?;
            match c {
                b'"' => break,
                b'\\' => {
                    let e = self.next().ok_or("unterminated escape")?;
                    match e {
                        b'"' => buf.push(b'"'),
                        b'\\' => buf.push(b'\\'),
                        b'/' => buf.push(b'/'),
                        b'n' => buf.push(b'\n'),
                        b't' => buf.push(b'\t'),
                        b'r' => buf.push(b'\r'),
                        b'b' => buf.push(0x08),
                        b'f' => buf.push(0x0c),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..=0xDBFF).contains(&hi) {
                                // surrogate pair: the low half must follow
                                if self.next() != Some(b'\\') || self.next() != Some(b'u') {
                                    return Err("lone high surrogate".into());
                                }
                                let lo = self.hex4()?;
                                if !(0xDC00..=0xDFFF).contains(&lo) {
                                    return Err("invalid low surrogate".into());
                                }
                                0x10000 + (((hi as u32 - 0xD800) << 10) | (lo as u32 - 0xDC00))
                            } else if (0xDC00..=0xDFFF).contains(&hi) {
                                return Err("lone low surrogate".into());
                            } else {
                                hi as u32
                            };
                            let ch =
                                char::from_u32(cp).ok_or_else(|| "invalid codepoint".to_string())?;
                            let mut tmp = [0u8; 4];
                            buf.extend_from_slice(ch.encode_utf8(&mut tmp).as_bytes());
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                c => buf.push(c),
            }
        }
        String::from_utf8(buf).map_err(|_| "invalid utf-8 in string".to_string())
    }

    fn value(&mut self) -> Result<JsonScalar, String> {
        match self.peek().ok_or("missing value")? {
            b'"' => Ok(JsonScalar::Str(self.string()?)),
            b't' => self.literal(b"true", JsonScalar::Bool(true)),
            b'f' => self.literal(b"false", JsonScalar::Bool(false)),
            b'n' => self.literal(b"null", JsonScalar::Null),
            _ => {
                let start = self.i;
                while matches!(
                    self.peek(),
                    Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
                ) {
                    self.i += 1;
                }
                let s = std::str::from_utf8(&self.b[start..self.i])
                    .expect("number bytes are ascii");
                s.parse::<f64>().map(JsonScalar::Num).map_err(|_| format!("bad number {s:?}"))
            }
        }
    }

    fn literal(&mut self, lit: &[u8], v: JsonScalar) -> Result<JsonScalar, String> {
        if self.b[self.i..].starts_with(lit) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
}

/// Parse one flat JSON object line into its (key, scalar) pairs, in
/// source order. Rejects nesting, trailing bytes, and malformed escapes.
pub fn parse_line(line: &str) -> Result<Vec<(String, JsonScalar)>, String> {
    let mut p = Parser { b: line.as_bytes(), i: 0 };
    p.ws();
    p.expect(b'{')?;
    let mut out = Vec::new();
    p.ws();
    if p.peek() == Some(b'}') {
        p.i += 1;
    } else {
        loop {
            p.ws();
            let k = p.string()?;
            p.ws();
            p.expect(b':')?;
            p.ws();
            let v = p.value()?;
            out.push((k, v));
            p.ws();
            match p.next() {
                Some(b',') => continue,
                Some(b'}') => break,
                got => return Err(format!("expected ',' or '}}', got {got:?}")),
            }
        }
    }
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing bytes after object at {}", p.i));
    }
    Ok(out)
}

/// Look up `key` in a parsed line.
pub fn field<'a>(obj: &'a [(String, JsonScalar)], key: &str) -> Option<&'a JsonScalar> {
    obj.iter().find(|(k, _)| k == key).map(|(_, v)| v)
}

// ---------------------------------------------------------------------------
// Determinism contract
// ---------------------------------------------------------------------------

/// Fields excluded from the fixed-seed determinism contract: wall-clock
/// timings and the racy hogwild publish tallies (the count of per-column
/// adds depends on racy snapshots at `threads > 1`). Everything else in
/// a sequential trace is bit-reproducible under a fixed seed.
pub const UNSTABLE_FIELDS: &[&str] = &["secs", "grad_secs", "eval_secs", "wall_secs", "publishes"];

/// Canonical re-render of one trace line with [`UNSTABLE_FIELDS`]
/// removed — the form two same-seed traces are compared in.
pub fn stable_view(line: &str) -> Result<String, String> {
    let obj = parse_line(line)?;
    let mut out = JsonObj::with_capacity(line.len());
    for (k, v) in &obj {
        if UNSTABLE_FIELDS.contains(&k.as_str()) {
            continue;
        }
        match v {
            JsonScalar::Num(n) => out.field(k, &JsonVal::Num(*n)),
            JsonScalar::Str(s) => out.field_str(k, s),
            JsonScalar::Bool(b) => out.field(k, &JsonVal::Bool(*b)),
            // non-finite Num renders as null
            JsonScalar::Null => out.field(k, &JsonVal::Num(f64::NAN)),
        };
    }
    Ok(out.finish())
}

// ---------------------------------------------------------------------------
// Schema validation + summarization (the `zipml trace` subcommands)
// ---------------------------------------------------------------------------

/// What [`validate`] measured while checking a trace.
#[derive(Debug, Default)]
pub struct TraceStats {
    /// Non-empty lines (= events) in the trace.
    pub events: usize,
    /// `epoch` events seen.
    pub epochs: usize,
    /// Sum of the `epoch` events' `bytes` fields.
    pub total_bytes: u64,
    /// `loss` of the last `epoch` event, if any.
    pub final_loss: Option<f64>,
}

fn req_num(obj: &[(String, JsonScalar)], kind: &str, key: &str) -> Result<f64, String> {
    field(obj, key)
        .and_then(JsonScalar::as_num)
        .ok_or_else(|| format!("{kind} event missing numeric {key:?}"))
}

fn req_str<'a>(
    obj: &'a [(String, JsonScalar)],
    kind: &str,
    key: &str,
) -> Result<&'a str, String> {
    field(obj, key)
        .and_then(JsonScalar::as_str)
        .ok_or_else(|| format!("{kind} event missing string {key:?}"))
}

/// Validate a JSONL trace: every non-empty line parses as a flat object
/// with a `"kind"`, required fields per kind are present and typed, and
/// the byte totals are mutually consistent (epoch deltas vs `summary`
/// vs `counters` vs per-shard attribution). Unknown kinds are allowed
/// (they must still parse) so the schema can grow.
pub fn validate(text: &str) -> Result<TraceStats, String> {
    let mut stats = TraceStats::default();
    let mut run_epochs: Option<f64> = None;
    let mut summary_bytes: Option<f64> = None;
    let mut counters_bytes: Option<f64> = None;
    let mut shard_bytes_sum: f64 = 0.0;
    let mut saw_shard_bytes = false;
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let obj = parse_line(line).map_err(|e| format!("line {}: {e}", ln + 1))?;
        let kind = req_str(&obj, "every", "kind").map_err(|e| format!("line {}: {e}", ln + 1))?;
        let check = |r: Result<f64, String>| {
            r.map(|_| ()).map_err(|e| format!("line {}: {e}", ln + 1))
        };
        match kind {
            "run" => {
                req_str(&obj, "run", "label").map_err(|e| format!("line {}: {e}", ln + 1))?;
                req_str(&obj, "run", "level").map_err(|e| format!("line {}: {e}", ln + 1))?;
                for k in ["rows", "cols", "epochs", "seed"] {
                    check(req_num(&obj, "run", k))?;
                }
                run_epochs = Some(req_num(&obj, "run", "epochs").expect("checked"));
            }
            "epoch" => {
                for k in ["epoch", "p", "loss", "rows", "bytes", "updates"] {
                    check(req_num(&obj, "epoch", k))?;
                }
                let loss = req_num(&obj, "epoch", "loss").expect("checked");
                if !loss.is_finite() {
                    return Err(format!("line {}: non-finite epoch loss", ln + 1));
                }
                stats.epochs += 1;
                stats.total_bytes += req_num(&obj, "epoch", "bytes").expect("checked") as u64;
                stats.final_loss = Some(loss);
            }
            "span" => {
                req_str(&obj, "span", "name").map_err(|e| format!("line {}: {e}", ln + 1))?;
                check(req_num(&obj, "span", "secs"))?;
            }
            "hogwild_epoch" => {
                for k in ["epoch", "worker", "updates"] {
                    check(req_num(&obj, "hogwild_epoch", k))?;
                }
            }
            "shard_bytes" => {
                check(req_num(&obj, "shard_bytes", "shard"))?;
                let b = req_num(&obj, "shard_bytes", "bytes")
                    .map_err(|e| format!("line {}: {e}", ln + 1))?;
                shard_bytes_sum += b;
                saw_shard_bytes = true;
            }
            "counters" => {
                let name = req_str(&obj, "counters", "counter")
                    .map_err(|e| format!("line {}: {e}", ln + 1))?;
                let v = req_num(&obj, "counters", "value")
                    .map_err(|e| format!("line {}: {e}", ln + 1))?;
                if name == "bytes_read" {
                    counters_bytes = Some(v);
                }
            }
            "summary" => {
                for k in ["total_bytes", "final_loss", "epochs", "updates"] {
                    check(req_num(&obj, "summary", k))?;
                }
                summary_bytes = Some(req_num(&obj, "summary", "total_bytes").expect("checked"));
            }
            _ => {} // forward-compatible: unknown kinds only need to parse
        }
        stats.events += 1;
    }
    if stats.events == 0 {
        return Err("empty trace".into());
    }
    if let Some(e) = run_epochs {
        if stats.epochs > 0 && stats.epochs as f64 != e {
            return Err(format!(
                "run declares {e} epochs but trace has {} epoch events",
                stats.epochs
            ));
        }
    }
    if let Some(s) = summary_bytes {
        if stats.epochs > 0 && stats.total_bytes as f64 != s {
            return Err(format!(
                "byte totals disagree: epoch events sum to {} but summary says {s}",
                stats.total_bytes
            ));
        }
        if let Some(c) = counters_bytes {
            if c != s {
                return Err(format!(
                    "byte totals disagree: counters bytes_read {c} vs summary {s}"
                ));
            }
        }
        if saw_shard_bytes && shard_bytes_sum != s {
            return Err(format!(
                "byte totals disagree: shard attribution sums to {shard_bytes_sum} vs summary {s}"
            ));
        }
    }
    Ok(stats)
}

/// Render the per-epoch table `zipml trace summarize` prints: loss,
/// precision, bytes/row, rows/sec, and (when present) per-worker hogwild
/// update counts.
pub fn summarize(text: &str) -> Result<String, String> {
    use std::fmt::Write as _;
    let stats = validate(text)?;
    let mut out = String::new();
    let mut workers: Vec<(u64, u64)> = Vec::new(); // (worker, updates) summed
    let mut label = String::from("?");
    let mut level = String::from("?");
    let mut rows_meta = None;
    let mut cols_meta = None;
    let mut wrote_header = false;
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let obj = parse_line(line)?;
        match field(&obj, "kind").and_then(JsonScalar::as_str).unwrap_or("") {
            "run" => {
                label = req_str(&obj, "run", "label")?.to_string();
                level = req_str(&obj, "run", "level")?.to_string();
                rows_meta = field(&obj, "rows").and_then(JsonScalar::as_num);
                cols_meta = field(&obj, "cols").and_then(JsonScalar::as_num);
            }
            "epoch" => {
                if !wrote_header {
                    let _ = writeln!(
                        out,
                        "{:>5} {:>4} {:>14} {:>14} {:>10} {:>12} {:>9}",
                        "epoch", "p", "loss", "bytes", "bytes/row", "rows/s", "updates"
                    );
                    wrote_header = true;
                }
                let e = req_num(&obj, "epoch", "epoch")?;
                let p = req_num(&obj, "epoch", "p")?;
                let loss = req_num(&obj, "epoch", "loss")?;
                let bytes = req_num(&obj, "epoch", "bytes")?;
                let rows = req_num(&obj, "epoch", "rows")?;
                let updates = req_num(&obj, "epoch", "updates")?;
                let secs = field(&obj, "secs").and_then(JsonScalar::as_num).unwrap_or(0.0);
                let rows_per_sec = if secs > 0.0 {
                    format!("{:.3e}", rows / secs)
                } else {
                    "-".to_string()
                };
                let _ = writeln!(
                    out,
                    "{:>5} {:>4} {:>14.6} {:>14} {:>10.1} {:>12} {:>9}",
                    e,
                    p,
                    loss,
                    bytes,
                    if rows > 0.0 { bytes / rows } else { 0.0 },
                    rows_per_sec,
                    updates
                );
            }
            "hogwild_epoch" => {
                let w = req_num(&obj, "hogwild_epoch", "worker")? as u64;
                let u = req_num(&obj, "hogwild_epoch", "updates")? as u64;
                match workers.iter_mut().find(|(id, _)| *id == w) {
                    Some((_, total)) => *total += u,
                    None => workers.push((w, u)),
                }
            }
            _ => {}
        }
    }
    let shape = match (rows_meta, cols_meta) {
        (Some(r), Some(c)) => format!("  rows={r} cols={c}"),
        _ => String::new(),
    };
    let mut head = format!("trace: {label}  level={level}{shape}\n");
    head.push_str(&out);
    let _ = writeln!(
        head,
        "total: {} events, {} epochs, {} bytes{}",
        stats.events,
        stats.epochs,
        stats.total_bytes,
        match stats.final_loss {
            Some(l) => format!(", final loss {l:.6}"),
            None => String::new(),
        }
    );
    if !workers.is_empty() {
        workers.sort_by_key(|&(w, _)| w);
        head.push_str("worker updates:");
        for (w, u) in &workers {
            let _ = write!(head, " w{w}={u}");
        }
        head.push('\n');
    }
    Ok(head)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink_lines(level: TraceLevel) -> Vec<String> {
        let t = TraceSink::in_memory(level);
        t.emit("run", &[("label", "x".into()), ("seed", 7u64.into())]);
        t.emit_at(TraceLevel::Spans, "span", &[("name", "epoch".into()), ("secs", 0.5.into())]);
        let shard = [("shard", 0u64.into()), ("bytes", 64u64.into())];
        t.emit_at(TraceLevel::Full, "shard_bytes", &shard);
        t.lines()
    }

    #[test]
    fn levels_gate_events() {
        assert_eq!(sink_lines(TraceLevel::Counters).len(), 1);
        assert_eq!(sink_lines(TraceLevel::Spans).len(), 2);
        assert_eq!(sink_lines(TraceLevel::Full).len(), 3);
        assert!(TraceLevel::Counters < TraceLevel::Spans);
        assert!(TraceLevel::Spans < TraceLevel::Full);
        assert_eq!(TraceLevel::parse("full"), Ok(TraceLevel::Full));
        assert!(TraceLevel::parse("verbose").is_err());
    }

    #[test]
    fn emitted_lines_parse_back() {
        let t = TraceSink::in_memory(TraceLevel::Full);
        t.emit(
            "epoch",
            &[
                ("epoch", 1u64.into()),
                ("p", 8u32.into()),
                ("loss", 0.125.into()),
                ("bytes", u64::MAX.into()),
            ],
        );
        let lines = t.lines();
        assert_eq!(lines.len(), 1);
        let obj = parse_line(&lines[0]).unwrap();
        assert_eq!(field(&obj, "kind").unwrap().as_str(), Some("epoch"));
        assert_eq!(field(&obj, "loss").unwrap().as_num(), Some(0.125));
        // u64::MAX survives textually (emitted via the UInt variant)
        assert!(lines[0].contains(&u64::MAX.to_string()), "{}", lines[0]);
    }

    /// Satellite contract: pathological labels round-trip through the
    /// escaping emitter and the parser unchanged.
    #[test]
    fn pathological_strings_round_trip() {
        let cases = [
            "plain",
            "quote\" backslash\\ done",
            "newline\n tab\t cr\r",
            "nul\u{0}bell\u{7}esc\u{1b}",
            "unicode é ❤ 𝄞 — emoji 🚀",
            "{\"looks\":\"like json\"}",
            "trailing backslash \\",
        ];
        for case in cases {
            let t = TraceSink::in_memory(TraceLevel::Counters);
            t.emit("run", &[("label", case.into())]);
            let line = &t.lines()[0];
            let obj = parse_line(line).unwrap_or_else(|e| panic!("{case:?}: {e}\n{line}"));
            assert_eq!(field(&obj, "label").unwrap().as_str(), Some(case), "case {case:?}");
        }
    }

    #[test]
    fn parser_handles_escapes_and_rejects_junk() {
        let obj = parse_line(r#"{"a":"xAé𝄞","b":-1.5e3,"c":true,"d":null}"#)
            .unwrap();
        assert_eq!(field(&obj, "a").unwrap().as_str(), Some("xAé𝄞"));
        assert_eq!(field(&obj, "b").unwrap().as_num(), Some(-1500.0));
        assert_eq!(field(&obj, "c"), Some(&JsonScalar::Bool(true)));
        assert_eq!(field(&obj, "d"), Some(&JsonScalar::Null));
        assert!(parse_line("{").is_err());
        assert!(parse_line(r#"{"a":}"#).is_err());
        assert!(parse_line(r#"{"a":1} extra"#).is_err());
        assert!(parse_line(r#"{"a":"\ud834"}"#).is_err(), "lone surrogate");
        assert!(parse_line(r#"{"a":{"nested":1}}"#).is_err(), "schema is flat");
        assert!(parse_line("{}").unwrap().is_empty());
    }

    #[test]
    fn stable_view_strips_exactly_the_unstable_fields() {
        let line = r#"{"kind":"epoch","epoch":1,"loss":0.5,"secs":0.123,"publishes":99}"#;
        assert_eq!(stable_view(line).unwrap(), r#"{"kind":"epoch","epoch":1,"loss":0.5}"#);
        // stable fields survive byte-for-byte across two renders
        assert_eq!(stable_view(line).unwrap(), stable_view(line).unwrap());
    }

    fn valid_trace() -> String {
        [
            r#"{"kind":"run","label":"l × t × s","level":"full","rows":100,"cols":8,"epochs":2,"seed":7}"#,
            r#"{"kind":"span","name":"ingest","secs":0.01}"#,
            r#"{"kind":"epoch","epoch":1,"p":4,"loss":0.5,"rows":100,"bytes":800,"updates":4,"secs":0.02}"#,
            r#"{"kind":"epoch","epoch":2,"p":8,"loss":0.25,"rows":100,"bytes":1600,"updates":4,"secs":0.02}"#,
            r#"{"kind":"shard_bytes","shard":0,"bytes":1400}"#,
            r#"{"kind":"shard_bytes","shard":1,"bytes":1000}"#,
            r#"{"kind":"counters","counter":"bytes_read","value":2400}"#,
            r#"{"kind":"summary","total_bytes":2400,"final_loss":0.25,"epochs":2,"updates":8}"#,
        ]
        .join("\n")
    }

    #[test]
    fn validate_accepts_consistent_traces() {
        let stats = validate(&valid_trace()).unwrap();
        assert_eq!(stats.events, 8);
        assert_eq!(stats.epochs, 2);
        assert_eq!(stats.total_bytes, 2400);
        assert_eq!(stats.final_loss, Some(0.25));
    }

    #[test]
    fn validate_rejects_inconsistent_and_malformed_traces() {
        assert!(validate("").is_err(), "empty");
        assert!(validate("not json").is_err());
        assert!(validate(r#"{"no_kind":1}"#).is_err());
        // epoch bytes vs summary mismatch
        let bad = valid_trace().replace("\"total_bytes\":2400", "\"total_bytes\":2401");
        assert!(validate(&bad).unwrap_err().contains("disagree"), "{bad}");
        // counters vs summary mismatch
        let bad = valid_trace().replace("\"value\":2400", "\"value\":9");
        assert!(validate(&bad).unwrap_err().contains("counters"));
        // shard attribution mismatch
        let bad = valid_trace().replace("\"bytes\":1000", "\"bytes\":999");
        assert!(validate(&bad).unwrap_err().contains("shard"));
        // epoch count vs run declaration
        let bad = valid_trace().replace("\"epochs\":2,\"seed\":7", "\"epochs\":3,\"seed\":7");
        assert!(validate(&bad).unwrap_err().contains("epoch events"));
        // missing required field
        let bad = valid_trace().replace("\"p\":4,", "");
        assert!(validate(&bad).unwrap_err().contains("\"p\""));
    }

    #[test]
    fn summarize_renders_table_and_workers() {
        let mut text = valid_trace();
        text.push_str("\n{\"kind\":\"hogwild_epoch\",\"epoch\":1,\"worker\":0,\"updates\":50}");
        text.push_str("\n{\"kind\":\"hogwild_epoch\",\"epoch\":1,\"worker\":1,\"updates\":50}");
        let s = summarize(&text).unwrap();
        assert!(s.contains("l × t × s"), "{s}");
        assert!(s.contains("bytes/row"), "{s}");
        assert!(s.contains("0.250000"), "{s}");
        assert!(s.contains("w0=50 w1=50"), "{s}");
    }
}
