//! Zero-dependency observability for the ZipML training paths
//! (DESIGN.md §10).
//!
//! The paper's claims are accounting claims — double sampling costs
//! exactly 2× the truncating bytes per visit, the popcount path trades
//! RNG draws for integer ops — so the telemetry layer's job is to make
//! that accounting observable without perturbing it:
//!
//! * [`metrics`] — [`Metrics`]: a registry of sharded relaxed counters
//!   (bytes read per precision, row visits, plane words, RNG draws,
//!   stochastic-round refreshes, hogwild updates/publishes per worker).
//!   Disabled registries are branch-free no-ops: every recorder applies
//!   a constant mask (`0` when disabled, `!0` when enabled) to the
//!   addend, so the instruction stream is identical either way.
//! * [`trace`] — [`TraceSink`]: a JSONL writer over the serde-free
//!   value model in [`crate::bench`], plus the flat-JSON reader,
//!   schema [`validate`]r, [`summarize`]r, and the fixed-seed
//!   determinism contract ([`UNSTABLE_FIELDS`], [`stable_view`]).
//! * [`clock`] — [`Stopwatch`]: the crate's single wall-clock portal.
//!   zipml-lint's `wall-clock` rule forbids `Instant`/`SystemTime`
//!   outside `telemetry/` and `bench.rs`, so every timing read funnels
//!   through here and nondeterministic fields stay a deliberate act.
//!
//! Two hard contracts bind this module to the store: telemetry byte
//! counters equal [`crate::store::ShardedStore`]'s exact-byte
//! accounting bit-for-bit, and trace content (timing fields aside) is
//! deterministic under a fixed seed.

pub mod clock;
pub mod metrics;
pub mod trace;

pub use clock::Stopwatch;
pub use metrics::{Metrics, ShardedU64, COUNTER_LANES, MAX_PRECISION};
pub use trace::{
    field, parse_line, stable_view, summarize, validate, JsonScalar, TraceLevel, TraceSink,
    TraceStats, UNSTABLE_FIELDS,
};
