//! The counter registry: sharded relaxed counters behind a mask gate.
//!
//! Every counter is a [`ShardedU64`] — one logical u64 striped across
//! [`COUNTER_LANES`] cache-line-padded atomic cells, summed on read — so
//! concurrent writers (hogwild workers, parallel ingest shards) never
//! ping-pong one line. Recording is **mask-gated, not branch-gated**: a
//! disabled registry adds `v & 0` through the identical instruction
//! stream, so enabling telemetry changes no control flow, only the value
//! added (the `telemetry_overhead` bench section pins the cost of that
//! difference at ≥ 0.95× disabled throughput).
//!
//! Ordering contract: all cells are `Relaxed`. Totals are exact once the
//! writers have quiesced (joined threads / returned calls); a `sum()`
//! taken while writers race is a valid but non-linearizable snapshot —
//! the same contract as [`crate::store::ShardedStore::bytes_read`].

use crate::sync::{AtomicU64, Ordering};
use std::fmt;
use std::sync::Arc;
#[cfg(not(loom))]
use std::sync::OnceLock;

/// Stripe width of every counter. A power of two; lane hints are masked
/// with `COUNTER_LANES - 1`, so any shard id / worker id works as a hint.
pub const COUNTER_LANES: usize = 16;

/// Highest per-precision byte bucket: 32 is the dense-f32 "precision"
/// bucket, 1..=16 are weaved read widths.
pub const MAX_PRECISION: u32 = 32;

// No derive(Default): loom's AtomicU64 has no Default impl, and the
// explicit zero keeps the std and loom builds identical.
#[repr(align(64))]
struct Lane(AtomicU64);

impl Default for Lane {
    fn default() -> Self {
        Lane(AtomicU64::new(0))
    }
}

/// One relaxed u64 counter striped across [`COUNTER_LANES`] padded cells.
pub struct ShardedU64 {
    lanes: Box<[Lane; COUNTER_LANES]>,
}

impl Default for ShardedU64 {
    fn default() -> Self {
        ShardedU64 { lanes: Box::new(std::array::from_fn(|_| Lane::default())) }
    }
}

impl ShardedU64 {
    /// Add `v` to the cell picked by `lane` (any usize: masked to the
    /// stripe width). Relaxed; see the module ordering contract.
    #[inline]
    pub fn add(&self, lane: usize, v: u64) {
        // ordering: relaxed — counter adds need atomicity only; totals
        // are read after writers quiesce (module ordering contract)
        self.lanes[lane & (COUNTER_LANES - 1)].0.fetch_add(v, Ordering::Relaxed);
    }

    /// Relaxed sum over all lanes — exact once writers have quiesced.
    pub fn sum(&self) -> u64 {
        // ordering: relaxed — non-linearizable snapshot while writers
        // race, exact after quiescence (loom model pins both)
        self.lanes.iter().map(|l| l.0.load(Ordering::Relaxed)).sum()
    }

    /// Per-lane relaxed snapshot (worker-keyed counters read this).
    pub fn lane_values(&self) -> [u64; COUNTER_LANES] {
        // ordering: relaxed — same snapshot contract as `sum`
        std::array::from_fn(|i| self.lanes[i].0.load(Ordering::Relaxed))
    }

    /// Zero every lane (relaxed stores).
    pub fn reset(&self) {
        for l in self.lanes.iter() {
            // ordering: relaxed — reset is only called from quiescence
            // (between epochs / in tests), never racing recorders
            l.0.store(0, Ordering::Relaxed);
        }
    }
}

/// The telemetry counter registry (DESIGN.md §10).
///
/// Instrumentation points add through [`Metrics::add_read`] and friends;
/// a disabled registry (the default every [`crate::store::ShardedStore`]
/// starts with, see `Metrics::shared_disabled`) masks every addend to 0
/// without branching. Counter totals are read back with the accessors;
/// byte totals are bit-for-bit equal to the store's own exact-byte
/// accounting because both are fed the same `bytes` value at the same
/// call sites.
pub struct Metrics {
    /// `!0` when enabled, `0` when disabled: every addend is `v & mask`.
    mask: u64,
    /// Exact sample bytes read, bucketed by read precision (index = p;
    /// 32 is the dense-f32 bucket). `bytes_read_total()` sums buckets.
    bytes_read: Vec<ShardedU64>,
    /// Row visits (each DS visit counts once; its 2 draws are bytes/RNG).
    row_visits: ShardedU64,
    /// 8-byte plane words touched — always `bytes_read / 8`, since every
    /// weaved read moves whole u64 plane spans (and the dense bucket's
    /// rows are whole f32 pairs); pinned by `kernel::plane_words_per_row`.
    plane_words: ShardedU64,
    /// Stochastic p-plane row draws (2 per DS row visit, 1 per
    /// `dequantize_row_ds`).
    rng_draws: ShardedU64,
    /// Stochastic-round refreshes of the popcount step kernel
    /// (`QuantStepKernel::refresh` calls issued by the session).
    sround_refreshes: ShardedU64,
    /// Hogwild per-sample updates, lane-keyed by worker id.
    hogwild_updates: ShardedU64,
    /// Hogwild racy per-column model publishes actually applied
    /// (zero-delta columns are skipped), lane-keyed by worker id.
    hogwild_publishes: ShardedU64,
}

impl Metrics {
    fn with_mask(mask: u64) -> Self {
        Metrics {
            mask,
            bytes_read: (0..=MAX_PRECISION as usize).map(|_| ShardedU64::default()).collect(),
            row_visits: ShardedU64::default(),
            plane_words: ShardedU64::default(),
            rng_draws: ShardedU64::default(),
            sround_refreshes: ShardedU64::default(),
            hogwild_updates: ShardedU64::default(),
            hogwild_publishes: ShardedU64::default(),
        }
    }

    /// A recording registry.
    pub fn enabled() -> Self {
        Self::with_mask(u64::MAX)
    }

    /// A registry whose every add is a masked no-op (same instructions,
    /// addend forced to 0).
    pub fn disabled() -> Self {
        Self::with_mask(0)
    }

    /// The process-wide disabled registry every store points at until a
    /// caller attaches its own — one allocation, shared by `Arc`.
    #[cfg(not(loom))]
    pub fn shared_disabled() -> Arc<Metrics> {
        static DISABLED: OnceLock<Arc<Metrics>> = OnceLock::new();
        DISABLED.get_or_init(|| Arc::new(Metrics::disabled())).clone()
    }

    /// Loom build: loom atomics must not outlive one model iteration, so
    /// the singleton is replaced by a fresh disabled registry per call.
    #[cfg(loom)]
    pub fn shared_disabled() -> Arc<Metrics> {
        Arc::new(Metrics::disabled())
    }

    /// Whether adds record (false: addends are masked to 0).
    pub fn is_enabled(&self) -> bool {
        self.mask != 0
    }

    /// Record `rows` row visits moving `bytes` at read precision `p`.
    /// `lane` spreads concurrent writers (shard id or worker id).
    #[inline]
    pub fn add_read(&self, lane: usize, p: u32, rows: u64, bytes: u64) {
        let m = self.mask;
        self.row_visits.add(lane, rows & m);
        self.plane_words.add(lane, (bytes / 8) & m);
        self.bytes_read[p.min(MAX_PRECISION) as usize].add(lane, bytes & m);
    }

    /// Record `n` stochastic p-plane row draws.
    #[inline]
    pub fn add_rng_draws(&self, lane: usize, n: u64) {
        self.rng_draws.add(lane, n & self.mask);
    }

    /// Record `n` stochastic-round refreshes of a popcount step kernel.
    #[inline]
    pub fn add_sround_refreshes(&self, lane: usize, n: u64) {
        self.sround_refreshes.add(lane, n & self.mask);
    }

    /// Record one hogwild worker's epoch tallies (flushed once per
    /// (epoch, worker) after the join — not per visit).
    #[inline]
    pub fn add_hogwild(&self, worker: usize, updates: u64, publishes: u64) {
        let m = self.mask;
        self.hogwild_updates.add(worker, updates & m);
        self.hogwild_publishes.add(worker, publishes & m);
    }

    /// Total exact bytes read across all precision buckets.
    pub fn bytes_read_total(&self) -> u64 {
        self.bytes_read.iter().map(|c| c.sum()).sum()
    }

    /// Exact bytes read at precision `p` (32 = dense-f32 bucket).
    pub fn bytes_read_at(&self, p: u32) -> u64 {
        self.bytes_read[p.min(MAX_PRECISION) as usize].sum()
    }

    pub fn row_visits(&self) -> u64 {
        self.row_visits.sum()
    }

    pub fn plane_words(&self) -> u64 {
        self.plane_words.sum()
    }

    pub fn rng_draws(&self) -> u64 {
        self.rng_draws.sum()
    }

    pub fn sround_refreshes(&self) -> u64 {
        self.sround_refreshes.sum()
    }

    pub fn hogwild_updates(&self) -> u64 {
        self.hogwild_updates.sum()
    }

    pub fn hogwild_publishes(&self) -> u64 {
        self.hogwild_publishes.sum()
    }

    /// Per-worker-lane hogwild update counts (lane = worker id masked to
    /// the stripe width; workers ≥ [`COUNTER_LANES`] fold onto lanes).
    pub fn hogwild_updates_per_lane(&self) -> [u64; COUNTER_LANES] {
        self.hogwild_updates.lane_values()
    }

    /// Zero every counter (the mask is untouched).
    pub fn reset(&self) {
        for c in &self.bytes_read {
            c.reset();
        }
        self.row_visits.reset();
        self.plane_words.reset();
        self.rng_draws.reset();
        self.sround_refreshes.reset();
        self.hogwild_updates.reset();
        self.hogwild_publishes.reset();
    }
}

impl fmt::Debug for Metrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Metrics")
            .field("enabled", &self.is_enabled())
            .field("bytes_read", &self.bytes_read_total())
            .field("row_visits", &self.row_visits())
            .field("plane_words", &self.plane_words())
            .field("rng_draws", &self.rng_draws())
            .field("sround_refreshes", &self.sround_refreshes())
            .field("hogwild_updates", &self.hogwild_updates())
            .field("hogwild_publishes", &self.hogwild_publishes())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_records_nothing() {
        let m = Metrics::disabled();
        m.add_read(3, 8, 10, 640);
        m.add_rng_draws(0, 20);
        m.add_sround_refreshes(1, 5);
        m.add_hogwild(2, 100, 90);
        assert!(!m.is_enabled());
        assert_eq!(m.bytes_read_total(), 0);
        assert_eq!(m.row_visits(), 0);
        assert_eq!(m.plane_words(), 0);
        assert_eq!(m.rng_draws(), 0);
        assert_eq!(m.sround_refreshes(), 0);
        assert_eq!(m.hogwild_updates(), 0);
        assert_eq!(m.hogwild_publishes(), 0);
    }

    #[test]
    fn enabled_registry_sums_across_lanes_and_buckets() {
        let m = Metrics::enabled();
        // spread the same precision over many lanes: sum is lane-blind
        for lane in 0..40 {
            m.add_read(lane, 4, 1, 64);
        }
        m.add_read(0, 8, 2, 256);
        m.add_read(1, 32, 3, 1200); // dense bucket
        assert_eq!(m.bytes_read_at(4), 40 * 64);
        assert_eq!(m.bytes_read_at(8), 256);
        assert_eq!(m.bytes_read_at(32), 1200);
        assert_eq!(m.bytes_read_total(), 40 * 64 + 256 + 1200);
        assert_eq!(m.row_visits(), 40 + 2 + 3);
        assert_eq!(m.plane_words(), m.bytes_read_total() / 8);
        m.reset();
        assert_eq!(m.bytes_read_total(), 0);
        assert_eq!(m.row_visits(), 0);
        assert!(m.is_enabled(), "reset must not flip the mask");
    }

    #[test]
    fn hogwild_lanes_key_by_worker() {
        let m = Metrics::enabled();
        m.add_hogwild(0, 10, 8);
        m.add_hogwild(1, 20, 15);
        m.add_hogwild(0, 5, 4);
        assert_eq!(m.hogwild_updates(), 35);
        assert_eq!(m.hogwild_publishes(), 27);
        let lanes = m.hogwild_updates_per_lane();
        assert_eq!(lanes[0], 15);
        assert_eq!(lanes[1], 20);
    }

    #[test]
    fn shared_disabled_is_one_allocation() {
        let a = Metrics::shared_disabled();
        let b = Metrics::shared_disabled();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(!a.is_enabled());
    }

    #[test]
    fn concurrent_adds_sum_exactly() {
        let m = std::sync::Arc::new(Metrics::enabled());
        std::thread::scope(|scope| {
            for t in 0..4 {
                let m = &m;
                scope.spawn(move || {
                    for _ in 0..1000 {
                        m.add_read(t, 8, 1, 16);
                    }
                });
            }
        });
        assert_eq!(m.row_visits(), 4000);
        assert_eq!(m.bytes_read_at(8), 4000 * 16);
    }
}
