//! Minimal dense row-major matrix used by the coordinator.
//!
//! The *heavy* math runs in the AOT-compiled XLA artifacts; this type covers
//! host-side bookkeeping (dataset storage, reference gradients for tests,
//! Hogwild baseline, refetch bounds).

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// y = A x (x.len() == cols).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            y[r] = dot(self.row(r), x);
        }
        y
    }

    /// y = Aᵀ v (v.len() == rows).
    pub fn tmatvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.rows);
        let mut y = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            let vr = v[r];
            if vr == 0.0 {
                continue;
            }
            for (yc, &a) in y.iter_mut().zip(self.row(r)) {
                *yc += vr * a;
            }
        }
        y
    }

    /// Gather rows into a contiguous (idx.len() × cols) buffer.
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Per-column min/max — inputs to the paper's column scaling (§A.3).
    pub fn col_min_max(&self) -> (Vec<f32>, Vec<f32>) {
        let mut lo = vec![f32::INFINITY; self.cols];
        let mut hi = vec![f32::NEG_INFINITY; self.cols];
        for r in 0..self.rows {
            for (c, &v) in self.row(r).iter().enumerate() {
                if v < lo[c] {
                    lo[c] = v;
                }
                if v > hi[c] {
                    hi[c] = v;
                }
            }
        }
        (lo, hi)
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than naive zip-sum and
    // deterministic (fixed association order).
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut tail = 0.0f32;
    for j in chunks * 4..a.len() {
        tail += a[j] * b[j];
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

pub fn norm1(x: &[f32]) -> f32 {
    x.iter().map(|v| v.abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_known() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.matvec(&[1., 1., 1.]), vec![6., 15.]);
        assert_eq!(a.tmatvec(&[1., 1.]), vec![5., 7., 9.]);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..37).map(|i| (37 - i) as f32 * 0.25).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2);
    }

    #[test]
    fn gather_rows_copies() {
        let a = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let g = a.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
    }

    #[test]
    fn col_min_max_correct() {
        let a = Matrix::from_vec(2, 2, vec![1., -5., 3., 2.]);
        let (lo, hi) = a.col_min_max();
        assert_eq!(lo, vec![1., -5.]);
        assert_eq!(hi, vec![3., 2.]);
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3., 4.]) - 5.0).abs() < 1e-6);
        assert!((norm1(&[3., -4.]) - 7.0).abs() < 1e-6);
    }
}
