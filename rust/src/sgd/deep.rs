//! Deep-learning extension (§3.3, Fig 7b): training an MLP with quantized
//! weights, comparing uniform level grids ("XNOR5", the multi-bit strategy
//! of XNOR-Net/QNN) against the paper's variance-optimal grids ("Optimal5").
//!
//! The coordinator owns the level placement: before every epoch it
//! recomputes per-layer grids from the current weight distribution (uniform
//! span vs the §3.2 discretized DP) and passes them to the `mlp_q_step`
//! artifact, whose forward pass snaps weights to the grid under an STE
//! backward. CIFAR-10 is replaced by a synthetic 10-class image-like
//! dataset (DESIGN.md §3).

use anyhow::Result;

use crate::quant::discretized_optimal_levels;
use crate::rng::Rng;
use crate::runtime::{lit_f32, lit_i32, lit_scalar11, to_f32_scalar, to_f32_vec, Runtime};

pub const DIMS: (usize, usize, usize, usize) = (784, 256, 128, 10);
pub const BATCH: usize = 64;
/// Level-array length baked into the mlp artifacts (aot.py MLP_LEVELS).
pub const LEVELS_PAD: usize = 33;

/// Weight-quantization strategy for the quantized-model runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WeightQuant {
    FullPrecision,
    /// `levels` uniform points over the symmetric weight range (XNOR-style).
    Uniform { levels: usize },
    /// `levels` variance-optimal points from the discretized DP (§3.2).
    Optimal { levels: usize },
}

impl WeightQuant {
    pub fn label(&self) -> String {
        match self {
            WeightQuant::FullPrecision => "fp32".into(),
            WeightQuant::Uniform { levels } => format!("xnor{levels}"),
            WeightQuant::Optimal { levels } => format!("optimal{levels}"),
        }
    }
}

/// Synthetic 10-class image-like dataset: class prototypes + structured
/// noise, 784 dims (28×28 layout for plausibility).
pub struct DeepDataset {
    pub x_train: Vec<f32>,
    pub y_train: Vec<i32>,
    pub x_test: Vec<f32>,
    pub y_test: Vec<i32>,
    pub k_train: usize,
    pub k_test: usize,
}

pub fn make_deep_dataset(k_train: usize, k_test: usize, seed: u64) -> DeepDataset {
    let d = DIMS.0;
    let mut rng = Rng::new(seed);
    // prototypes with block structure (local correlations, like images).
    // Classes share a common background and differ only in a weak class
    // signal + per-class pairwise feature interactions, so the task needs
    // the hidden layers (not linearly separable) and lands in the 70-90%
    // accuracy band where weight-quantization differences are visible.
    let mut protos = vec![0.0f32; 10 * d];
    let mut background = vec![0.0f32; d];
    let mut prev_bg = 0.0f32;
    for (j, b) in background.iter_mut().enumerate() {
        if j % 16 == 0 {
            *b = rng.normal();
        } else {
            *b = prev_bg * 0.9 + 0.3 * rng.normal();
        }
        prev_bg = *b;
    }
    for cls in 0..10 {
        let mut v = 0.0f32;
        for j in 0..d {
            if j % 16 == 0 {
                v = rng.normal();
            }
            protos[cls * d + j] = background[j] + 0.35 * (v * 0.8 + 0.2 * rng.normal());
        }
    }
    let gen = |k: usize, rng: &mut Rng| {
        let mut xs = vec![0.0f32; k * d];
        let mut ys = vec![0i32; k];
        for i in 0..k {
            let cls = rng.below(10);
            ys[i] = cls as i32;
            let row = &mut xs[i * d..(i + 1) * d];
            // class-dependent sign pattern: xor-like interaction the MLP
            // must learn; plus heavy additive noise
            let flip = if rng.f32() < 0.5 { 1.0 } else { -1.0 };
            for (j, v) in row.iter_mut().enumerate() {
                let inter = if (j / 8) % 10 == cls { flip * 0.8 } else { 0.0 };
                *v = protos[cls * d + j] + inter + 1.6 * rng.normal();
            }
        }
        (xs, ys)
    };
    let (x_train, y_train) = gen(k_train, &mut rng);
    let (x_test, y_test) = gen(k_test, &mut rng);
    DeepDataset { x_train, y_train, x_test, y_test, k_train, k_test }
}

/// MLP parameters (He-initialized), flattened per tensor.
#[derive(Clone)]
pub struct MlpParams {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub w3: Vec<f32>,
    pub b3: Vec<f32>,
}

impl MlpParams {
    pub fn init(seed: u64) -> Self {
        let (d0, d1, d2, d3) = DIMS;
        let mut rng = Rng::new(seed);
        let mut init = |fan_in: usize, len: usize| -> Vec<f32> {
            let s = (2.0 / fan_in as f32).sqrt();
            (0..len).map(|_| rng.normal() * s).collect()
        };
        MlpParams {
            w1: init(d0, d0 * d1),
            b1: vec![0.0; d1],
            w2: init(d1, d1 * d2),
            b2: vec![0.0; d2],
            w3: init(d2, d2 * d3),
            b3: vec![0.0; d3],
        }
    }

    pub fn num_params(&self) -> usize {
        self.w1.len() + self.b1.len() + self.w2.len() + self.b2.len() + self.w3.len() + self.b3.len()
    }
}

/// Compute the per-layer level grids for this strategy, padded to the
/// artifact's fixed length (padding repeats the max level — harmless for
/// nearest-level assignment).
pub fn layer_levels(params: &MlpParams, wq: WeightQuant) -> Option<[Vec<f32>; 3]> {
    let build = |w: &[f32]| -> Vec<f32> {
        let grid = match wq {
            WeightQuant::FullPrecision => return vec![0.0; LEVELS_PAD],
            WeightQuant::Uniform { levels } => {
                let wmax = w.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8);
                (0..levels)
                    .map(|i| -wmax + 2.0 * wmax * i as f32 / (levels - 1) as f32)
                    .collect::<Vec<f32>>()
            }
            WeightQuant::Optimal { levels } => {
                // subsample weights for the DP (single pass, §3.2)
                let stride = (w.len() / 4096).max(1);
                let sample: Vec<f32> = w.iter().step_by(stride).copied().collect();
                discretized_optimal_levels(&sample, levels, 128)
            }
        };
        let mut padded = grid;
        padded.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let last = *padded.last().unwrap();
        while padded.len() < LEVELS_PAD {
            padded.push(last);
        }
        padded
    };
    match wq {
        WeightQuant::FullPrecision => None,
        _ => Some([build(&params.w1), build(&params.w2), build(&params.w3)]),
    }
}

pub struct DeepResult {
    pub label: String,
    pub train_loss_curve: Vec<f64>,
    pub test_acc_curve: Vec<f64>,
    pub final_test_acc: f64,
    pub wall_secs: f64,
}

/// Train for `epochs` over the dataset, recomputing level grids per epoch.
pub fn train_mlp(
    rt: &Runtime,
    data: &DeepDataset,
    wq: WeightQuant,
    epochs: usize,
    lr0: f32,
    seed: u64,
) -> Result<DeepResult> {
    let t0 = crate::telemetry::Stopwatch::start();
    let (d0, d1, d2, d3) = DIMS;
    let mut p = MlpParams::init(seed);
    let mut rng = Rng::new(seed ^ 0xDEE9);
    let nb = data.k_train / BATCH;
    let step_art = if wq == WeightQuant::FullPrecision { "mlp_fp_step" } else { "mlp_q_step" };
    let eval_art = if wq == WeightQuant::FullPrecision { "mlp_eval_fp" } else { "mlp_eval_q" };

    let mut train_loss_curve = Vec::new();
    let mut test_acc_curve = Vec::new();
    let mut order: Vec<usize> = (0..nb * BATCH).collect();

    for epoch in 0..epochs {
        let levels = layer_levels(&p, wq);
        let lv_lits = match &levels {
            Some([l1, l2, l3]) => Some((
                lit_f32(&[LEVELS_PAD], l1)?,
                lit_f32(&[LEVELS_PAD], l2)?,
                lit_f32(&[LEVELS_PAD], l3)?,
            )),
            None => None,
        };
        let lr = super::lr_at_epoch(lr0, epoch);
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0f64;
        for bi in 0..nb {
            let rows = &order[bi * BATCH..(bi + 1) * BATCH];
            let mut xb = vec![0.0f32; BATCH * d0];
            let mut yb = vec![0i32; BATCH];
            for (i, &r) in rows.iter().enumerate() {
                xb[i * d0..(i + 1) * d0].copy_from_slice(&data.x_train[r * d0..(r + 1) * d0]);
                yb[i] = data.y_train[r];
            }
            let mut args = vec![
                lit_f32(&[d0, d1], &p.w1)?,
                lit_f32(&[1, d1], &p.b1)?,
                lit_f32(&[d1, d2], &p.w2)?,
                lit_f32(&[1, d2], &p.b2)?,
                lit_f32(&[d2, d3], &p.w3)?,
                lit_f32(&[1, d3], &p.b3)?,
                lit_f32(&[BATCH, d0], &xb)?,
                lit_i32(&[BATCH], &yb)?,
                lit_scalar11(lr)?,
            ];
            if let Some((l1, l2, l3)) = &lv_lits {
                args.push(l1.clone());
                args.push(l2.clone());
                args.push(l3.clone());
            }
            let out = rt.exec(step_art, &args)?;
            p.w1 = to_f32_vec(&out[0])?;
            p.b1 = to_f32_vec(&out[1])?;
            p.w2 = to_f32_vec(&out[2])?;
            p.b2 = to_f32_vec(&out[3])?;
            p.w3 = to_f32_vec(&out[4])?;
            p.b3 = to_f32_vec(&out[5])?;
            epoch_loss += to_f32_scalar(&out[6])? as f64;
        }
        train_loss_curve.push(epoch_loss / nb as f64);
        test_acc_curve.push(evaluate(rt, data, &p, eval_art, &levels)?.1);
        let _ = epoch;
    }

    Ok(DeepResult {
        label: wq.label(),
        final_test_acc: *test_acc_curve.last().unwrap_or(&0.0),
        train_loss_curve,
        test_acc_curve,
        wall_secs: t0.elapsed_secs(),
    })
}

/// (loss, accuracy) over the test split.
fn evaluate(
    rt: &Runtime,
    data: &DeepDataset,
    p: &MlpParams,
    eval_art: &str,
    levels: &Option<[Vec<f32>; 3]>,
) -> Result<(f64, f64)> {
    let (d0, d1, d2, d3) = DIMS;
    let nb = (data.k_test / BATCH).min(16); // bounded eval cost
    let mut loss = 0.0f64;
    let mut acc = 0.0f64;
    for bi in 0..nb {
        let xb = &data.x_test[bi * BATCH * d0..(bi + 1) * BATCH * d0];
        let yb = &data.y_test[bi * BATCH..(bi + 1) * BATCH];
        let mut args = vec![
            lit_f32(&[d0, d1], &p.w1)?,
            lit_f32(&[1, d1], &p.b1)?,
            lit_f32(&[d1, d2], &p.w2)?,
            lit_f32(&[1, d2], &p.b2)?,
            lit_f32(&[d2, d3], &p.w3)?,
            lit_f32(&[1, d3], &p.b3)?,
            lit_f32(&[BATCH, d0], xb)?,
            lit_i32(&[BATCH], yb)?,
        ];
        if let Some([l1, l2, l3]) = levels {
            args.push(lit_f32(&[LEVELS_PAD], l1)?);
            args.push(lit_f32(&[LEVELS_PAD], l2)?);
            args.push(lit_f32(&[LEVELS_PAD], l3)?);
        }
        let out = rt.exec(eval_art, &args)?;
        loss += to_f32_scalar(&out[0])? as f64;
        acc += to_f32_scalar(&out[1])? as f64;
    }
    Ok((loss / nb as f64, acc / nb as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_shapes_and_labels() {
        let d = make_deep_dataset(256, 128, 1);
        assert_eq!(d.x_train.len(), 256 * 784);
        assert!(d.y_train.iter().all(|&y| (0..10).contains(&y)));
        // classes are balanced-ish
        let c0 = d.y_train.iter().filter(|&&y| y == 0).count();
        assert!(c0 > 5 && c0 < 80, "class 0 count {c0}");
    }

    #[test]
    fn params_sized_right() {
        let p = MlpParams::init(2);
        assert_eq!(p.num_params(), 784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10);
    }

    #[test]
    fn uniform_levels_span_weights() {
        let p = MlpParams::init(3);
        let lv = layer_levels(&p, WeightQuant::Uniform { levels: 5 }).unwrap();
        for (li, w) in lv.iter().zip([&p.w1, &p.w2, &p.w3]) {
            assert_eq!(li.len(), LEVELS_PAD);
            let wmax = w.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            assert!((li[0] + wmax).abs() < 1e-5);
            assert!(li.windows(2).all(|p| p[0] <= p[1]));
        }
    }

    #[test]
    fn optimal_levels_tighter_variance_than_uniform() {
        let p = MlpParams::init(4);
        let lu = layer_levels(&p, WeightQuant::Uniform { levels: 5 }).unwrap();
        let lo = layer_levels(&p, WeightQuant::Optimal { levels: 5 }).unwrap();
        let mv_u = crate::quant::quantization_variance(&p.w1, &lu[0][..5]);
        let mv_o = crate::quant::quantization_variance(&p.w1, &lo[0][..5]);
        // gaussian-ish weights: optimal grid concentrates near 0 and wins
        assert!(mv_o < mv_u, "optimal {mv_o} vs uniform {mv_u}");
    }

    #[test]
    fn fp_has_no_levels() {
        let p = MlpParams::init(5);
        assert!(layer_levels(&p, WeightQuant::FullPrecision).is_none());
    }
}
