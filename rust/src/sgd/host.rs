//! The composable host-training session: **any GLM × any read strategy ×
//! any execution × any precision schedule**, from one engine.
//!
//! Before this module, every artifact-free host trainer was its own free
//! function — `train_store_host{,_ds,_q,_dequant}`, `train_packed_host`,
//! `hogwild_train{,_store,_store_ds,_store_q}` — nine near-duplicates,
//! all linreg-only, multiplying instead of composing whenever a new axis
//! (double sampling, popcount, Hogwild!) landed. [`HostSession`] replaces
//! them with a builder over four orthogonal axes:
//!
//! * **loss** — a [`GlmLoss`] (implemented for every
//!   [`ModelKind`]: linreg, LS-SVM, logistic, SVM/hinge). The fused
//!   weaved-domain kernels already produce the dot product aᵀx; the
//!   engine maps it through the loss's step multiplier m = ℓ′(aᵀx; b) on
//!   the host and applies m via the existing axpy kernels — so the
//!   truncating, double-sampled, *and* popcount plane-domain paths extend
//!   to all four GLMs with zero new kernel code (DESIGN.md §9).
//! * **read strategy** — [`ReadStrategy`]: `Truncate` (top-p planes),
//!   `DoubleSample` (two independent unbiased stochastic draws per visit,
//!   §2.2), `Popcount { q }` (integer AND+POPCNT dots against a q-bit
//!   rounded step kernel, DESIGN.md §8), or `Dense` (full-precision f32
//!   rows straight from the dataset — the fp32 baseline, no store).
//! * **execution** — [`Execution`]: `Sequential` minibatch SGD (short
//!   ragged tail batch, deterministic bit for bit in the seed) or
//!   `Hogwild { threads }` (lock-free racy updates over a strided row
//!   partition; each worker owns its kernel state and RNG stream).
//! * **schedule** — a [`PrecisionSchedule`] picking the read precision
//!   per epoch (store-backed reads; defaults to the stored width).
//!
//! **Observability** (DESIGN.md §10): [`HostSession::trace`] attaches a
//! JSONL [`TraceSink`] — the session emits a `run` header, per-epoch
//! rollups (loss, precision, exact bytes, updates), phase spans, and a
//! consistency-checked `summary`/`counters` tail; [`HostSession::metrics`]
//! attaches the counter registry the trace reads back. Both default to
//! off, and the disabled path is branch-free in the kernels (mask-gated
//! counters on the store).
//!
//! The nine legacy entry points survive as `#[deprecated]` shims over the
//! session, bit-for-bit identical for linreg (the sequential engine
//! issues exactly the same f32 operations in the same order; the hogwild
//! engine is op-identical per visit and deterministic at one thread).
//! Invalid axis combinations — a store-backed read without a store, the
//! dequantize oracle under hogwild or a stochastic read, popcount outside
//! q ∈ 1..=16 — error at [`HostSession::run`] instead of silently
//! falling back.
//!
//! ```no_run
//! # use zipml::data::synthetic::make_classification;
//! # use zipml::quant::ColumnScale;
//! # use zipml::sgd::{Execution, HostSession, ModelKind, ReadStrategy};
//! # use zipml::store::ShardedStore;
//! let ds = make_classification("demo", 512, 64, 32, 7);
//! let scale = ColumnScale::from_data(&ds.train_a);
//! let store = ShardedStore::ingest(&ds.train_a, &scale, 8, 42, 8, 0);
//! let r = HostSession::over(&ds, &store)
//!     .loss(&ModelKind::Logistic)
//!     .read(ReadStrategy::DoubleSample)
//!     .execution(Execution::Hogwild { threads: 4 })
//!     .epochs(10)
//!     .run()
//!     .expect("valid combination");
//! println!("{}: final loss {:?}", r.label, r.loss_curve.last());
//! ```

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::fpga::hogwild::HogwildResult;
use crate::rng::Rng;
use crate::store::{
    kernel, MinibatchIter, PrecisionSchedule, QuantStepKernel, ScheduleState, ShardedStore,
    StepKernel,
};
use crate::sync::RacyF32Cell;
use crate::telemetry::{Metrics, Stopwatch, TraceLevel, TraceSink, MAX_PRECISION};
use crate::tensor::{axpy, dot};

use super::driver::HostTrainResult;
use super::modes::ModelKind;

// ---------------------------------------------------------------------------
// The loss axis
// ---------------------------------------------------------------------------

/// A generalized linear model's loss, reduced to the two scalars the
/// fused plane-domain engine needs: the pointwise loss ℓ(aᵀx; b) for the
/// per-epoch metric and the **step multiplier** m = ℓ′(aᵀx; b) — the
/// derivative of the loss in its linear argument. Every host path
/// computes the dot product aᵀx in the weaved domain, maps it through
/// [`GlmLoss::multiplier`] on the host, and applies the resulting scalar
/// through the existing axpy kernels, so one implementation serves the
/// truncating, double-sampled, and popcount reads alike.
///
/// Bias contract (DESIGN.md §9): for losses whose multiplier is *linear*
/// in the sample (least squares, LS-SVM), the double-sampled estimator is
/// exactly unbiased at any read precision — the §2.2/§5 identity. For
/// non-linear multipliers (logistic, hinge) the two independent draws
/// still factorize E\[m(â₁ᵀx)·â₂\] = E\[m(â₁ᵀx)\]·a, leaving a residual
/// bias only inside the multiplier term, bounded by the §4 smoothness
/// argument.
///
/// Implementors must be [`Sync`]: hogwild execution shares the loss
/// across racy worker threads.
pub trait GlmLoss: Sync {
    /// Short id used in labels and reports (e.g. `"logistic"`).
    fn label(&self) -> &'static str;

    /// The step multiplier m = ℓ′(aᵀx; b): the scalar the sample is
    /// multiplied by in the gradient ∇ℓ = m·a.
    fn multiplier(&self, dot: f32, target: f32) -> f32;

    /// Pointwise loss ℓ(aᵀx; b), accumulated in f64 for the epoch metric.
    fn loss(&self, dot: f32, target: f32) -> f64;

    /// ℓ2 regularization strength (LS-SVM's `c`; 0 for the others). The
    /// engine applies it as the model-side shrink x ← (1 − lr·c)·x per
    /// step — never as sample traffic.
    fn l2_reg(&self) -> f32 {
        0.0
    }

    /// Model-level penalty added to the epoch metric: (c/2)·‖x‖². Exactly
    /// 0.0 when [`GlmLoss::l2_reg`] is zero, so unregularized losses keep
    /// their metric bit-for-bit.
    fn l2_penalty(&self, x: &[f32]) -> f64 {
        let c = self.l2_reg();
        if c == 0.0 {
            0.0
        } else {
            0.5 * c as f64 * x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
        }
    }
}

impl GlmLoss for ModelKind {
    fn label(&self) -> &'static str {
        match self {
            ModelKind::Linreg => "linreg",
            ModelKind::Lssvm { .. } => "lssvm",
            ModelKind::Logistic => "logistic",
            ModelKind::Svm => "svm",
        }
    }

    fn multiplier(&self, dot: f32, target: f32) -> f32 {
        match self {
            // least squares (and LS-SVM: for ±1 labels (z−y)² ≡ (1−yz)²,
            // so the residual IS the LS-SVM multiplier)
            ModelKind::Linreg | ModelKind::Lssvm { .. } => dot - target,
            // ℓ(z) = ln(1+e^{−yz}) ⇒ ℓ′(z) = −y/(1+e^{yz}); saturates to
            // −y (margin ≪ 0) and −0 (margin ≫ 0) without overflow
            ModelKind::Logistic => {
                let yz = target * dot;
                -target / (1.0 + yz.exp())
            }
            // hinge subgradient: −y on margin violations, 0 otherwise
            ModelKind::Svm => {
                if target * dot < 1.0 {
                    -target
                } else {
                    0.0
                }
            }
        }
    }

    fn loss(&self, dot: f32, target: f32) -> f64 {
        match self {
            // squared residual, matching `Dataset::train_mse` bit for bit
            // (the f32 subtraction happens before the f64 square)
            ModelKind::Linreg | ModelKind::Lssvm { .. } => ((dot - target) as f64).powi(2),
            // stable ln(1+e^{−yz}): ln_1p on the side that cannot overflow
            ModelKind::Logistic => {
                let yz = target as f64 * dot as f64;
                if yz >= 0.0 {
                    (-yz).exp().ln_1p()
                } else {
                    -yz + yz.exp().ln_1p()
                }
            }
            ModelKind::Svm => (1.0 - target as f64 * dot as f64).max(0.0),
        }
    }

    fn l2_reg(&self) -> f32 {
        match self {
            ModelKind::Lssvm { c } => *c,
            ModelKind::Linreg | ModelKind::Logistic | ModelKind::Svm => 0.0,
        }
    }
}

/// Mean [`GlmLoss`] over the training split plus the model-level ℓ2
/// penalty — the session's per-epoch metric. For [`ModelKind::Linreg`]
/// this reproduces [`Dataset::train_mse`] bit for bit (same matvec, same
/// f64 accumulation order, +0.0 penalty).
pub fn eval_glm_loss(ds: &Dataset, loss: &dyn GlmLoss, x: &[f32]) -> f64 {
    let pred = ds.train_a.matvec(x);
    let mut acc = 0.0f64;
    for (&p, &y) in pred.iter().zip(&ds.train_b) {
        acc += loss.loss(p, y);
    }
    acc / ds.train_b.len().max(1) as f64 + loss.l2_penalty(x)
}

// ---------------------------------------------------------------------------
// The read and execution axes
// ---------------------------------------------------------------------------

/// How sample values reach the step: which representation is read and
/// which estimator it feeds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReadStrategy {
    /// Full-precision f32 rows straight from the dataset — the fp32
    /// baseline. Needs no store ([`HostSession::dense`]); the precision
    /// axis is inert (schedules are ignored, `precisions` reports 32).
    Dense,
    /// Deterministic truncating read of the top p bit planes (biased
    /// below the stored width), on the fused plane-domain kernels.
    Truncate,
    /// Two independent unbiased stochastic p-plane draws per row visit —
    /// §2.2 double sampling from the single stored copy (DESIGN.md §5).
    /// Byte accounting is exactly 2× the truncating read.
    DoubleSample,
    /// Truncating read whose dots run the integer AND+POPCNT fast path
    /// against a q-bit stochastically rounded step kernel (DESIGN.md §8).
    /// The axpy side stays exact; byte accounting equals `Truncate`.
    Popcount {
        /// Sign/magnitude bit planes of the rounded g = m⊙x, 1..=16.
        q: u32,
    },
}

impl ReadStrategy {
    /// Short id used in labels and reports.
    pub fn label(&self) -> String {
        match self {
            ReadStrategy::Dense => "dense-f32".into(),
            ReadStrategy::Truncate => "truncate".into(),
            ReadStrategy::DoubleSample => "double-sample".into(),
            ReadStrategy::Popcount { q } => format!("popcount(q={q})"),
        }
    }
}

/// How updates are applied to the model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Execution {
    /// Minibatch SGD: shuffled epoch, genuinely short ragged tail batch,
    /// update scaled by the batch's own row count. Deterministic bit for
    /// bit in (seed, store contents).
    Sequential,
    /// Hogwild! (De Sa et al., 2015): `threads` workers race one-sample
    /// updates on a shared atomic model without synchronization. Each
    /// epoch's rows are partitioned across workers by
    /// [`MinibatchIter::strided`], and each worker owns its kernel state
    /// and a per-(epoch, worker) RNG stream, so the *set* of visits and
    /// draws is reproducible even though interleaving is racy
    /// (deterministic bit for bit at `threads == 1`). The `batch` knob is
    /// inert here — updates are per-sample by construction.
    Hogwild {
        /// Racing worker threads, >= 1.
        threads: usize,
    },
}

// ---------------------------------------------------------------------------
// The session
// ---------------------------------------------------------------------------

/// Result of a [`HostSession`] run — the union of what the legacy host
/// and hogwild result types reported.
#[derive(Clone, Debug)]
pub struct SessionResult {
    /// `"<loss> × <read> × <execution>"`, for reports.
    pub label: String,
    /// `loss_curve[e]` = [`eval_glm_loss`] after e epochs (0 = initial).
    pub loss_curve: Vec<f64>,
    pub final_model: Vec<f32>,
    /// Store-accounted sample bytes per epoch (exact for store-backed
    /// reads; `rows × cols × 4` for [`ReadStrategy::Dense`]).
    pub sample_bytes_per_epoch: f64,
    /// Read precision at each epoch (32 for [`ReadStrategy::Dense`]).
    pub precisions: Vec<u32>,
    pub wall_secs: f64,
    /// Model updates applied: batch steps sequentially, per-sample racy
    /// updates under hogwild.
    pub updates: usize,
}

impl SessionResult {
    /// Project onto the legacy [`HostTrainResult`] (sequential shims).
    pub fn into_host(self) -> HostTrainResult {
        HostTrainResult {
            loss_curve: self.loss_curve,
            final_model: self.final_model,
            sample_bytes_per_epoch: self.sample_bytes_per_epoch,
            precisions: self.precisions,
        }
    }

    /// Project onto the legacy [`HogwildResult`] (hogwild shims).
    pub fn into_hogwild(self) -> HogwildResult {
        HogwildResult {
            loss_curve: self.loss_curve,
            wall_secs: self.wall_secs,
            final_model: self.final_model,
            updates: self.updates,
        }
    }
}

/// Builder for one artifact-free host training run: pick a data source
/// ([`HostSession::over`] a weaved store, or [`HostSession::dense`]),
/// then compose the four axes and [`HostSession::run`]. Every knob has
/// the legacy default, so the nine deprecated entry points are thin shims
/// over this type.
#[derive(Clone, Copy)]
pub struct HostSession<'a> {
    ds: &'a Dataset,
    store: Option<&'a ShardedStore>,
    loss: &'a dyn GlmLoss,
    read: ReadStrategy,
    exec: Execution,
    schedule: Option<PrecisionSchedule>,
    epochs: usize,
    batch: usize,
    lr0: f32,
    seed: u64,
    oracle: bool,
    metrics: Option<&'a Metrics>,
    trace: Option<&'a TraceSink>,
}

impl<'a> HostSession<'a> {
    /// A session over the bit-weaved store (read strategy defaults to
    /// [`ReadStrategy::Truncate`], schedule to the stored width).
    pub fn over(ds: &'a Dataset, store: &'a ShardedStore) -> Self {
        HostSession {
            ds,
            store: Some(store),
            loss: &ModelKind::Linreg,
            read: ReadStrategy::Truncate,
            exec: Execution::Sequential,
            schedule: None,
            epochs: 10,
            batch: 64,
            lr0: 0.05,
            seed: 42,
            oracle: false,
            metrics: None,
            trace: None,
        }
    }

    /// A storeless session reading full-precision dataset rows
    /// ([`ReadStrategy::Dense`]) — the fp32 baseline and the home of the
    /// classic dense Hogwild! run.
    pub fn dense(ds: &'a Dataset) -> Self {
        HostSession {
            ds,
            store: None,
            loss: &ModelKind::Linreg,
            read: ReadStrategy::Dense,
            exec: Execution::Sequential,
            schedule: None,
            epochs: 10,
            batch: 64,
            lr0: 0.05,
            seed: 42,
            oracle: false,
            metrics: None,
            trace: None,
        }
    }

    /// Set the loss (default [`ModelKind::Linreg`]); any [`GlmLoss`]
    /// works, the four paper GLMs come from [`ModelKind`].
    pub fn loss(mut self, loss: &'a dyn GlmLoss) -> Self {
        self.loss = loss;
        self
    }

    /// Set the read strategy (default [`ReadStrategy::Truncate`] over a
    /// store, [`ReadStrategy::Dense`] for storeless sessions).
    pub fn read(mut self, read: ReadStrategy) -> Self {
        self.read = read;
        self
    }

    /// Set the execution (default [`Execution::Sequential`]).
    pub fn execution(mut self, exec: Execution) -> Self {
        self.exec = exec;
        self
    }

    /// Set the per-epoch read-precision schedule (default: fixed at the
    /// stored width). Inert for [`ReadStrategy::Dense`].
    pub fn schedule(mut self, schedule: PrecisionSchedule) -> Self {
        self.schedule = Some(schedule);
        self
    }

    /// Set the epoch count (default 10). 0 is allowed and returns the
    /// initial loss only — callers that need training should validate.
    pub fn epochs(mut self, epochs: usize) -> Self {
        self.epochs = epochs;
        self
    }

    /// Set the sequential minibatch size (default 64; inert under
    /// hogwild, whose updates are per-sample).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Set the initial learning rate (default 0.05; decays as lr0/(e+1)).
    pub fn lr0(mut self, lr0: f32) -> Self {
        self.lr0 = lr0;
        self
    }

    /// Set the seed (default 42) driving shuffling, stochastic draws, and
    /// rounding streams.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run the truncating read through the materializing dequantize-row
    /// oracle instead of the fused kernels — the validation baseline the
    /// fused path is property-tested against. Sequential + `Truncate`
    /// only; other combinations error at [`HostSession::run`].
    pub fn dequant_oracle(mut self) -> Self {
        self.oracle = true;
        self
    }

    /// Attach a telemetry counter registry for this run. The session
    /// resets it at run start, flushes hogwild worker tallies into it,
    /// and reads it back for the trace's `counters` events. Store-backed
    /// reads tally into the registry the *store* carries
    /// ([`ShardedStore::attach_metrics`]) — attach the same `Arc` there
    /// and pass it here so the two views agree bit for bit (the CLI
    /// does). If unset, the session falls back to the store's own
    /// registry; a disabled registry is treated as absent.
    pub fn metrics(mut self, m: &'a Metrics) -> Self {
        self.metrics = Some(m);
        self
    }

    /// Attach a JSONL trace sink: the run emits its `run` header,
    /// per-epoch rollups, phase spans (at [`TraceLevel::Spans`]+),
    /// per-shard byte attribution (at [`TraceLevel::Full`]), and the
    /// `counters`/`summary` tail, per the DESIGN.md §10 schema. Trace
    /// content is deterministic under a fixed seed except the
    /// wall-clock/publish fields in
    /// [`crate::telemetry::UNSTABLE_FIELDS`].
    pub fn trace(mut self, t: &'a TraceSink) -> Self {
        self.trace = Some(t);
        self
    }

    /// The registry the run records/reports against: the builder's, else
    /// the store's, provided it is enabled.
    fn effective_metrics(&self) -> Option<&Metrics> {
        self.metrics
            .or_else(|| self.store.map(|s| s.metrics()))
            .filter(|m| m.is_enabled())
    }

    fn label_string(&self) -> String {
        let exec = match self.exec {
            Execution::Sequential => "sequential".to_string(),
            Execution::Hogwild { threads } => format!("hogwild({threads})"),
        };
        let oracle = if self.oracle { " (dequant oracle)" } else { "" };
        format!("{} × {}{} × {}", self.loss.label(), self.read.label(), oracle, exec)
    }

    fn schedule_for(&self, store: &ShardedStore) -> PrecisionSchedule {
        self.schedule.unwrap_or(PrecisionSchedule::Fixed(store.bits()))
    }

    fn validate(&self) -> Result<()> {
        if self.batch == 0 {
            bail!("batch size must be >= 1");
        }
        match self.read {
            ReadStrategy::Dense => {
                if self.store.is_some() {
                    bail!(
                        "ReadStrategy::Dense reads full-precision dataset rows and would \
                         silently ignore the store; build the session with HostSession::dense \
                         or pick a store-backed read strategy"
                    );
                }
                if self.oracle {
                    bail!("the dequantize oracle applies to store-backed truncating reads only");
                }
            }
            ReadStrategy::Truncate => {
                if self.store.is_none() {
                    bail!(
                        "ReadStrategy::Truncate reads bit planes: build the session with \
                         HostSession::over(ds, &store)"
                    );
                }
            }
            ReadStrategy::DoubleSample => {
                if self.store.is_none() {
                    bail!(
                        "ReadStrategy::DoubleSample draws from stored bit planes: build the \
                         session with HostSession::over(ds, &store)"
                    );
                }
                if self.oracle {
                    bail!(
                        "no dequantize oracle for double-sampled reads: the blocked DS kernels \
                         consume carry randomness in a different specified order than a per-row \
                         materializing oracle would (DESIGN.md §8)"
                    );
                }
            }
            ReadStrategy::Popcount { q } => {
                if self.store.is_none() {
                    bail!(
                        "ReadStrategy::Popcount reads stored bit planes: build the session \
                         with HostSession::over(ds, &store)"
                    );
                }
                if !(1..=16).contains(&q) {
                    bail!("popcount step rounding needs q in 1..=16, got {q}");
                }
                if self.oracle {
                    bail!(
                        "no dequantize oracle for the popcount path: its dot is integer \
                         AND+POPCNT by construction"
                    );
                }
            }
        }
        if let Some(s) = self.store {
            if s.rows() != self.ds.k_train() {
                bail!("store/dataset row mismatch: {} vs {}", s.rows(), self.ds.k_train());
            }
            if s.cols() != self.ds.n() {
                bail!("store/dataset col mismatch: {} vs {}", s.cols(), self.ds.n());
            }
        }
        if self.ds.k_train() == 0 {
            bail!("empty training split");
        }
        if let Execution::Hogwild { threads } = self.exec {
            if threads == 0 {
                bail!("hogwild execution needs >= 1 thread");
            }
            if self.oracle {
                bail!("the dequantize oracle is a sequential validation path, not a hogwild one");
            }
        }
        Ok(())
    }

    /// Validate the axis combination and train. Errors on invalid
    /// combinations (see the module docs); never silently substitutes a
    /// different configuration.
    ///
    /// Accounting is reset at run start (store byte cells and the
    /// effective metrics registry), so after the run the store counter,
    /// the registry, and the trace's per-epoch byte deltas all describe
    /// exactly this run — the §10 consistency contract.
    pub fn run(self) -> Result<SessionResult> {
        self.validate()?;
        if let Some(s) = self.store {
            s.reset_bytes_read();
        }
        if let Some(m) = self.effective_metrics() {
            m.reset();
        }
        if let Some(t) = self.trace {
            let threads = match self.exec {
                Execution::Sequential => 1usize,
                Execution::Hogwild { threads } => threads,
            };
            t.emit(
                "run",
                &[
                    ("label", self.label_string().as_str().into()),
                    ("loss", self.loss.label().into()),
                    ("read", self.read.label().as_str().into()),
                    ("level", t.level().as_str().into()),
                    ("rows", self.ds.k_train().into()),
                    ("cols", self.ds.n().into()),
                    ("epochs", self.epochs.into()),
                    ("batch", self.batch.into()),
                    ("threads", threads.into()),
                    ("seed", self.seed.into()),
                    ("lr0", (self.lr0 as f64).into()),
                    // which kernel tier served this run (DESIGN.md §12) and
                    // whether the sparse-plane occupancy index was resident
                    ("kernel_tier", crate::store::kernel::dispatch::tier_label().into()),
                    (
                        "plane_index",
                        if self.store.is_some_and(|s| s.has_plane_index()) { "on" } else { "off" }
                            .into(),
                    ),
                ],
            );
        }
        let t0 = Stopwatch::start();
        let mut r = match self.exec {
            Execution::Sequential => self.run_sequential()?,
            Execution::Hogwild { threads } => self.run_hogwild(threads)?,
        };
        r.wall_secs = t0.elapsed_secs();
        if let Some(t) = self.trace {
            self.emit_tail(t, &r);
        }
        Ok(r)
    }

    /// The trace's trailing events: per-shard byte attribution (Full),
    /// counter totals (when an enabled registry is in play), and the
    /// `summary` whose `total_bytes` the validator cross-checks against
    /// the per-epoch deltas, the counters, and the shard attribution.
    fn emit_tail(&self, t: &TraceSink, r: &SessionResult) {
        if let Some(s) = self.store {
            for si in 0..s.num_shards() {
                t.emit_at(
                    TraceLevel::Full,
                    "shard_bytes",
                    &[("shard", si.into()), ("bytes", s.shard_bytes_read(si).into())],
                );
            }
        }
        if let Some(m) = self.effective_metrics() {
            let mut counters: Vec<(String, u64)> = vec![
                ("bytes_read".into(), m.bytes_read_total()),
                ("row_visits".into(), m.row_visits()),
                ("plane_words".into(), m.plane_words()),
                ("rng_draws".into(), m.rng_draws()),
                ("sround_refreshes".into(), m.sround_refreshes()),
                ("hogwild_updates".into(), m.hogwild_updates()),
                ("hogwild_publishes".into(), m.hogwild_publishes()),
            ];
            for p in 1..=MAX_PRECISION {
                let b = m.bytes_read_at(p);
                if b != 0 {
                    counters.push((format!("bytes_read_p{p}"), b));
                }
            }
            for (name, v) in &counters {
                t.emit("counters", &[("counter", name.as_str().into()), ("value", (*v).into())]);
            }
        }
        let total_bytes: u64 = match self.store {
            Some(s) => s.bytes_read(),
            None => self.epochs as u64 * (self.ds.k_train() * self.ds.n() * 4) as u64,
        };
        t.emit(
            "summary",
            &[
                ("total_bytes", total_bytes.into()),
                ("final_loss", (*r.loss_curve.last().expect("curve holds initial loss")).into()),
                ("epochs", self.epochs.into()),
                ("updates", r.updates.into()),
                ("wall_secs", r.wall_secs.into()),
            ],
        );
        t.emit_at(
            TraceLevel::Spans,
            "span",
            &[("name", "session".into()), ("secs", r.wall_secs.into())],
        );
    }

    // -- sequential ---------------------------------------------------------

    fn run_sequential(&self) -> Result<SessionResult> {
        let ds = self.ds;
        let loss = self.loss;
        let k_rows = ds.k_train();
        let n = ds.n();
        // Per-epoch trace emitter shared by every arm: byte deltas come
        // off the store's exact counter (reset in `run`), never a second
        // formula, so trace bytes ARE store accounting. Dense sessions
        // have no store; their analytic rows×cols×4 is also fed to the
        // registry's dense bucket so the counters stay consistent.
        let trace = self.trace;
        let metrics = self.effective_metrics();
        let store_opt = self.store;
        let dense_epoch_bytes = (k_rows * n * 4) as u64;
        let mut prev_bytes = 0u64;
        let mut on_epoch = move |obs: EpochObs| {
            let bytes = match store_opt {
                Some(s) => {
                    let total = s.bytes_read();
                    let delta = total - prev_bytes;
                    prev_bytes = total;
                    delta
                }
                None => {
                    if let Some(m) = metrics {
                        m.add_read(0, 32, k_rows as u64, dense_epoch_bytes);
                    }
                    dense_epoch_bytes
                }
            };
            let Some(t) = trace else { return };
            let secs = obs.grad_secs + obs.eval_secs;
            t.emit(
                "epoch",
                &[
                    ("epoch", obs.epoch.into()),
                    ("p", obs.p.into()),
                    ("loss", obs.loss.into()),
                    ("rows", k_rows.into()),
                    ("bytes", bytes.into()),
                    ("updates", obs.updates.into()),
                    ("secs", secs.into()),
                    ("grad_secs", obs.grad_secs.into()),
                    ("eval_secs", obs.eval_secs.into()),
                ],
            );
            t.emit_at(
                TraceLevel::Spans,
                "span",
                &[("name", "epoch".into()), ("secs", secs.into())],
            );
            t.emit_at(
                TraceLevel::Spans,
                "span",
                &[("name", "grad_batch".into()), ("secs", obs.grad_secs.into())],
            );
            t.emit_at(
                TraceLevel::Spans,
                "span",
                &[("name", "eval".into()), ("secs", obs.eval_secs.into())],
            );
        };
        let (loss_curve, final_model, precisions, updates) = match self.read {
            ReadStrategy::Dense => epoch_skeleton(
                ds,
                loss,
                self.epochs,
                self.batch,
                self.lr0,
                self.seed,
                |_, _| 32,
                |_, rows, x, grad| {
                    for &r in rows {
                        let row = ds.train_a.row(r);
                        let coef = loss.multiplier(dot(row, x), ds.train_b[r]);
                        axpy(coef, row, grad);
                    }
                },
                &mut on_epoch,
            ),
            ReadStrategy::Truncate if self.oracle => {
                let store = self.store.expect("validated");
                let mut sched = ScheduleState::new(self.schedule_for(store), store.bits());
                let mut row = vec![0.0f32; store.cols()];
                epoch_skeleton(
                    ds,
                    loss,
                    self.epochs,
                    self.batch,
                    self.lr0,
                    self.seed,
                    |epoch, hist| sched.precision_for_epoch(epoch, hist),
                    |p, rows, x, grad| {
                        for &r in rows {
                            store.dequantize_row(r, p, &mut row);
                            let coef = loss.multiplier(dot(&row, x), ds.train_b[r]);
                            axpy(coef, &row, grad);
                        }
                    },
                    &mut on_epoch,
                )
            }
            ReadStrategy::Truncate => {
                let store = self.store.expect("validated");
                let mut sched = ScheduleState::new(self.schedule_for(store), store.bits());
                let m = store.scale().m.clone();
                let mut kern = StepKernel::new(store.cols());
                let mut targets = vec![0.0f32; self.batch];
                epoch_skeleton(
                    ds,
                    loss,
                    self.epochs,
                    self.batch,
                    self.lr0,
                    self.seed,
                    |epoch, hist| sched.precision_for_epoch(epoch, hist),
                    |p, rows, x, grad| {
                        kern.refresh(&m, x);
                        let t = &mut targets[..rows.len()];
                        for (t, &r) in t.iter_mut().zip(rows) {
                            *t = ds.train_b[r];
                        }
                        store.fused_grad_batch_glm(
                            rows,
                            p,
                            &kern,
                            t,
                            |d, b| loss.multiplier(d, b),
                            grad,
                        );
                    },
                    &mut on_epoch,
                )
            }
            ReadStrategy::DoubleSample => {
                let store = self.store.expect("validated");
                let mut sched = ScheduleState::new(self.schedule_for(store), store.bits());
                let m = store.scale().m.clone();
                let mut kern = StepKernel::new(store.cols());
                let mut targets = vec![0.0f32; self.batch];
                // carry-randomness stream, independent of the shuffle
                // stream so DS and truncating runs share visit orders
                let mut ds_rng = Rng::new_stream(self.seed, 0x4453); // "DS"
                epoch_skeleton(
                    ds,
                    loss,
                    self.epochs,
                    self.batch,
                    self.lr0,
                    self.seed,
                    |epoch, hist| sched.precision_for_epoch(epoch, hist),
                    |p, rows, x, grad| {
                        kern.refresh(&m, x);
                        let t = &mut targets[..rows.len()];
                        for (t, &r) in t.iter_mut().zip(rows) {
                            *t = ds.train_b[r];
                        }
                        store.ds_grad_batch_glm(
                            rows,
                            p,
                            &kern,
                            t,
                            |d, b| loss.multiplier(d, b),
                            &mut ds_rng,
                            grad,
                        );
                    },
                    &mut on_epoch,
                )
            }
            ReadStrategy::Popcount { q } => {
                let store = self.store.expect("validated");
                let mut sched = ScheduleState::new(self.schedule_for(store), store.bits());
                let m = store.scale().m.clone();
                let mut qk = QuantStepKernel::new(store.cols(), q);
                let mut targets = vec![0.0f32; self.batch];
                let mut q_rng = Rng::new_stream(self.seed, 0x5153); // "QS"
                let srounds = self.effective_metrics();
                epoch_skeleton(
                    ds,
                    loss,
                    self.epochs,
                    self.batch,
                    self.lr0,
                    self.seed,
                    |epoch, hist| sched.precision_for_epoch(epoch, hist),
                    |p, rows, x, grad| {
                        qk.refresh(&m, x, &mut q_rng);
                        if let Some(mm) = srounds {
                            mm.add_sround_refreshes(0, 1);
                        }
                        let t = &mut targets[..rows.len()];
                        for (t, &r) in t.iter_mut().zip(rows) {
                            *t = ds.train_b[r];
                        }
                        store.fused_grad_batch_q_glm(
                            rows,
                            p,
                            &qk,
                            t,
                            |d, b| loss.multiplier(d, b),
                            grad,
                        );
                    },
                    &mut on_epoch,
                )
            }
        };
        let bytes = match store_opt {
            Some(s) => s.bytes_read() as f64 / self.epochs.max(1) as f64,
            None => dense_epoch_bytes as f64,
        };
        Ok(SessionResult {
            label: self.label_string(),
            loss_curve,
            final_model,
            sample_bytes_per_epoch: bytes,
            precisions,
            wall_secs: 0.0,
            updates,
        })
    }

    // -- hogwild ------------------------------------------------------------

    fn run_hogwild(&self, threads: usize) -> Result<SessionResult> {
        let ds = self.ds;
        let loss = self.loss;
        let n = ds.n();
        let k = ds.k_train();
        let x: Vec<RacyF32Cell> = (0..n).map(|_| RacyF32Cell::new(0.0)).collect();
        let snapshot =
            |x: &[RacyF32Cell]| -> Vec<f32> { x.iter().map(RacyF32Cell::load).collect() };
        let mut loss_curve = Vec::with_capacity(self.epochs + 1);
        loss_curve.push(eval_glm_loss(ds, loss, &snapshot(&x)));
        let mut precisions = Vec::with_capacity(self.epochs);
        let mut sched = self
            .store
            .map(|s| ScheduleState::new(self.schedule_for(s), s.bits()));
        let c_reg = loss.l2_reg();
        let trace = self.trace;
        let metrics = self.effective_metrics();
        let dense_epoch_bytes = (k * n * 4) as u64;
        let mut updates_total = 0usize;
        let mut prev_bytes = 0u64;

        for epoch in 0..self.epochs {
            let p = match sched.as_mut() {
                Some(s) => s.precision_for_epoch(epoch, &loss_curve),
                None => 32,
            };
            precisions.push(p);
            let lr = super::lr_at_epoch(self.lr0, epoch);
            let lrc = lr * c_reg;
            let epoch_seed = self.seed ^ ((epoch as u64) << 32);
            // fused readers account one plane fetch per row visit (both
            // fetches for the two DS draws), like the row-read path
            let reads_per_visit: u32 = match self.read {
                ReadStrategy::DoubleSample => 2,
                ReadStrategy::Dense | ReadStrategy::Truncate | ReadStrategy::Popcount { .. } => 1,
            };
            let grad_start = Stopwatch::start();
            // Each worker tallies locally (updates, publishes, rng draws,
            // stochastic-round refreshes, secs) and the epoch flushes the
            // tallies once post-join — the hot loop never touches the
            // registry except through the store's per-visit accounting.
            let worker_stats: Vec<(usize, usize, u64, u64, f64)> =
                std::thread::scope(|scope| {
                    let xr = &x;
                    let handles: Vec<_> = (0..threads)
                        .map(|t| {
                            scope.spawn(move || {
                                let w_start = Stopwatch::start();
                                let mut w_updates = 0usize;
                                let mut w_pubs = 0usize;
                                let mut w_draws = 0u64;
                                let mut w_srounds = 0u64;
                                // per-worker visitor state: each worker owns
                                // its kernel scratch and a per-(epoch,
                                // worker) stream, so stochastic variants
                                // never share randomness across racy threads
                                let mut it =
                                    MinibatchIter::strided(k, 1, epoch_seed, t, threads);
                                let mut rng = Rng::new_stream(
                                    self.seed,
                                    (epoch as u64) * threads as u64 + t as u64,
                                );
                                let mut local = vec![0.0f32; n];
                                // per-read-strategy state only: Dense needs
                                // no plane scratch, Popcount no f32 kernel
                                let mut delta = match self.read {
                                    ReadStrategy::Dense => Vec::new(),
                                    ReadStrategy::Truncate
                                    | ReadStrategy::DoubleSample
                                    | ReadStrategy::Popcount { .. } => vec![0.0f32; n],
                                };
                                let mut kern = match self.read {
                                    ReadStrategy::Truncate | ReadStrategy::DoubleSample => {
                                        Some(StepKernel::new(n))
                                    }
                                    ReadStrategy::Dense | ReadStrategy::Popcount { .. } => None,
                                };
                                let mut qk = match self.read {
                                    ReadStrategy::Popcount { q } => {
                                        Some(QuantStepKernel::new(n, q))
                                    }
                                    ReadStrategy::Dense
                                    | ReadStrategy::Truncate
                                    | ReadStrategy::DoubleSample => None,
                                };
                                let store_m = self.store.map(|s| &s.scale().m);
                                while let Some(batch) = it.next_batch() {
                                    for &r in batch {
                                        let r = r as usize;
                                        // racy model snapshot → update state
                                        for (l, xa) in local.iter_mut().zip(xr.iter()) {
                                            *l = xa.load();
                                        }
                                        let target = ds.train_b[r];
                                        if self.read == ReadStrategy::Dense {
                                            let row = ds.train_a.row(r);
                                            let coef =
                                                -lr * loss.multiplier(dot(row, &local), target);
                                            for (xa, &a) in xr.iter().zip(row) {
                                                if a != 0.0 {
                                                    xa.add(coef * a);
                                                    w_pubs += 1;
                                                }
                                            }
                                        } else {
                                            let store = self.store.expect("validated");
                                            let (shard, sr) = store.locate_row(r);
                                            store.note_row_visit(r, p, reads_per_visit, t);
                                            let m = store_m.expect("validated");
                                            let coef = match self.read {
                                                ReadStrategy::Truncate => {
                                                    let kern =
                                                        kern.as_mut().expect("step kernel");
                                                    kern.refresh(m, &local);
                                                    let d =
                                                        kernel::dot_row(shard, sr, p, kern);
                                                    let coef =
                                                        -lr * loss.multiplier(d, target);
                                                    kernel::axpy_row_planes(
                                                        shard, sr, p, coef, &mut delta,
                                                    );
                                                    coef
                                                }
                                                ReadStrategy::DoubleSample => {
                                                    let kern =
                                                        kern.as_mut().expect("step kernel");
                                                    kern.refresh(m, &local);
                                                    // draw one feeds the dot,
                                                    // draw two the racy
                                                    // accumulation
                                                    let d = kernel::dot_row_ds(
                                                        shard, sr, p, kern, &mut rng,
                                                    );
                                                    let coef =
                                                        -lr * loss.multiplier(d, target);
                                                    kernel::axpy_row_planes_ds(
                                                        shard, sr, p, coef, &mut rng,
                                                        &mut delta,
                                                    );
                                                    w_draws += 2;
                                                    coef
                                                }
                                                ReadStrategy::Popcount { .. } => {
                                                    let qk =
                                                        qk.as_mut().expect("popcount kernel");
                                                    qk.refresh(m, &local, &mut rng);
                                                    w_srounds += 1;
                                                    let d =
                                                        kernel::dot_row_q(shard, sr, p, qk);
                                                    let coef =
                                                        -lr * loss.multiplier(d, target);
                                                    kernel::axpy_row_planes(
                                                        shard, sr, p, coef, &mut delta,
                                                    );
                                                    coef
                                                }
                                                ReadStrategy::Dense => unreachable!(),
                                            };
                                            // publish: fold the affine plane
                                            // term into ONE racy add per live
                                            // column, re-zeroing the scratch
                                            for ((xa, d), &mc) in
                                                xr.iter().zip(delta.iter_mut()).zip(m.iter())
                                            {
                                                let upd = *d - coef * mc;
                                                *d = 0.0;
                                                if upd != 0.0 {
                                                    xa.add(upd);
                                                    w_pubs += 1;
                                                }
                                            }
                                        }
                                        if lrc != 0.0 {
                                            // ℓ2 shrink against the snapshot
                                            for (xa, &lv) in xr.iter().zip(local.iter()) {
                                                if lv != 0.0 {
                                                    xa.add(-lrc * lv);
                                                    w_pubs += 1;
                                                }
                                            }
                                        }
                                        w_updates += 1;
                                    }
                                }
                                let secs = w_start.elapsed_secs();
                                (w_updates, w_pubs, w_draws, w_srounds, secs)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("hogwild worker panicked"))
                        .collect()
                });
            let grad_secs = grad_start.elapsed_secs();
            let eval_start = Stopwatch::start();
            loss_curve.push(eval_glm_loss(ds, loss, &snapshot(&x)));
            let eval_secs = eval_start.elapsed_secs();

            let mut epoch_updates = 0usize;
            for (w, &(u, pb, dr, sr, secs)) in worker_stats.iter().enumerate() {
                epoch_updates += u;
                if let Some(m) = metrics {
                    m.add_hogwild(w, u as u64, pb as u64);
                    m.add_rng_draws(w, dr);
                    m.add_sround_refreshes(w, sr);
                }
                if let Some(t) = trace {
                    t.emit_at(
                        TraceLevel::Spans,
                        "hogwild_epoch",
                        &[
                            ("epoch", (epoch + 1).into()),
                            ("worker", w.into()),
                            ("updates", u.into()),
                            ("publishes", pb.into()),
                            ("secs", secs.into()),
                        ],
                    );
                }
            }
            updates_total += epoch_updates;

            let bytes = match self.store {
                Some(s) => {
                    let total = s.bytes_read();
                    let delta = total - prev_bytes;
                    prev_bytes = total;
                    delta
                }
                None => {
                    if let Some(m) = metrics {
                        m.add_read(0, 32, k as u64, dense_epoch_bytes);
                    }
                    dense_epoch_bytes
                }
            };
            if let Some(t) = trace {
                let secs = grad_secs + eval_secs;
                t.emit(
                    "epoch",
                    &[
                        ("epoch", (epoch + 1).into()),
                        ("p", p.into()),
                        ("loss", (*loss_curve.last().expect("just pushed")).into()),
                        ("rows", k.into()),
                        ("bytes", bytes.into()),
                        ("updates", epoch_updates.into()),
                        ("secs", secs.into()),
                        ("grad_secs", grad_secs.into()),
                        ("eval_secs", eval_secs.into()),
                    ],
                );
                t.emit_at(
                    TraceLevel::Spans,
                    "span",
                    &[("name", "epoch".into()), ("secs", secs.into())],
                );
                t.emit_at(
                    TraceLevel::Spans,
                    "span",
                    &[("name", "grad_batch".into()), ("secs", grad_secs.into())],
                );
                t.emit_at(
                    TraceLevel::Spans,
                    "span",
                    &[("name", "eval".into()), ("secs", eval_secs.into())],
                );
            }
        }

        let bytes = match self.store {
            Some(s) => s.bytes_read() as f64 / self.epochs.max(1) as f64,
            None => (k * n * 4) as f64,
        };
        Ok(SessionResult {
            label: self.label_string(),
            loss_curve,
            final_model: snapshot(&x),
            sample_bytes_per_epoch: bytes,
            precisions,
            wall_secs: 0.0,
            updates: updates_total,
        })
    }
}

// ---------------------------------------------------------------------------
// Shared machinery
// ---------------------------------------------------------------------------

/// Per-epoch observation handed to the session's `on_epoch` hook right
/// after the epoch's evaluation. `epoch` is 1-based so it indexes the
/// matching `loss_curve` entry directly (`loss_curve[0]` is the initial
/// loss, before any update). Timing fields are wall-clock and therefore
/// excluded from the trace determinism contract (DESIGN.md §10).
struct EpochObs {
    /// 1-based epoch index; equals the `loss_curve` index for this loss.
    epoch: usize,
    /// Precision used for this epoch's gradient reads.
    p: u32,
    /// Loss evaluated after this epoch's updates.
    loss: f64,
    /// Model updates applied this epoch (= number of minibatches).
    updates: usize,
    /// Wall-clock seconds spent in shuffle + gradient batches.
    grad_secs: f64,
    /// Wall-clock seconds spent evaluating the epoch loss.
    eval_secs: f64,
}

/// Minibatch SGD epoch skeleton shared by every sequential read strategy.
/// `step_batch(p, rows, x, grad)` accumulates the un-scaled minibatch
/// gradient Σ mᵢ·aᵢ into `grad`; the skeleton owns shuffling, the lr
/// schedule, the model update (and ℓ2 shrink), and the per-epoch loss, so
/// every path shares them exactly. Every training row is visited each
/// epoch: when `k % batch != 0` the final batch is genuinely short and
/// its update is scaled by its own row count. For a zero-`l2_reg` loss
/// this is op-for-op the legacy linreg skeleton. `on_epoch` fires once
/// per epoch after evaluation; pass `|_| {}` when not tracing.
#[allow(clippy::too_many_arguments)] // private engine core: 6 knobs + 3 hooks
fn epoch_skeleton(
    ds: &Dataset,
    loss: &dyn GlmLoss,
    epochs: usize,
    batch: usize,
    lr0: f32,
    seed: u64,
    mut precision: impl FnMut(usize, &[f64]) -> u32,
    mut step_batch: impl FnMut(u32, &[usize], &[f32], &mut [f32]),
    mut on_epoch: impl FnMut(EpochObs),
) -> (Vec<f64>, Vec<f32>, Vec<u32>, usize) {
    let n = ds.n();
    let k = ds.k_train();
    assert!(k > 0, "empty training split");
    let nb = k.div_ceil(batch);
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; n];
    let mut loss_curve = vec![eval_glm_loss(ds, loss, &x)];
    let mut precisions = Vec::with_capacity(epochs);
    let mut order: Vec<usize> = (0..k).collect();
    let mut grad = vec![0.0f32; n];
    let mut updates = 0usize;
    let c = loss.l2_reg();
    for epoch in 0..epochs {
        let p = precision(epoch, &loss_curve);
        precisions.push(p);
        let lr = super::lr_at_epoch(lr0, epoch);
        let grad_start = Stopwatch::start();
        rng.shuffle(&mut order);
        for bi in 0..nb {
            let rows = &order[bi * batch..((bi + 1) * batch).min(k)];
            grad.fill(0.0);
            step_batch(p, rows, &x, &mut grad);
            axpy(-lr / rows.len() as f32, &grad, &mut x);
            if c != 0.0 {
                // ℓ2: x ← (1 − lr·c)·x, skipped entirely at c == 0 so
                // unregularized losses stay bit-for-bit the legacy path
                let shrink = 1.0 - lr * c;
                for v in x.iter_mut() {
                    *v *= shrink;
                }
            }
            updates += 1;
        }
        let grad_secs = grad_start.elapsed_secs();
        let eval_start = Stopwatch::start();
        loss_curve.push(eval_glm_loss(ds, loss, &x));
        let eval_secs = eval_start.elapsed_secs();
        on_epoch(EpochObs {
            epoch: epoch + 1,
            p,
            loss: *loss_curve.last().expect("just pushed"),
            updates: nb,
            grad_secs,
            eval_secs,
        });
    }
    (loss_curve, x, precisions, updates)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::make_regression;
    use crate::quant::packing::PackedMatrix;
    use crate::quant::ColumnScale;

    fn packed_and_store(
        ds: &Dataset,
        bits: u32,
        shards: usize,
        seed: u64,
    ) -> (PackedMatrix, ShardedStore) {
        let scale = ColumnScale::from_data(&ds.train_a);
        let mut rng = Rng::new(seed);
        let packed = PackedMatrix::quantize(&ds.train_a, &scale, bits, &mut rng);
        let store = ShardedStore::from_packed(&packed, shards);
        (packed, store)
    }

    fn final_loss(r: &SessionResult) -> f64 {
        *r.loss_curve.last().unwrap()
    }

    /// At p = stored width over identical indices, the session's weaved
    /// dequantize oracle is bit-identical to the legacy packed host path
    /// (the pre-fusion guarantee, preserved through the shim).
    #[test]
    #[allow(deprecated)]
    fn session_oracle_matches_packed_host_exactly_at_full_width() {
        let ds = make_regression("host_eq", 512, 64, 24, 11);
        let (packed, store) = packed_and_store(&ds, 8, 5, 13);
        let a = super::super::driver::train_packed_host(&ds, &packed, 6, 32, 0.05, 7);
        let b = HostSession::over(&ds, &store)
            .schedule(PrecisionSchedule::Fixed(8))
            .dequant_oracle()
            .epochs(6)
            .batch(32)
            .lr0(0.05)
            .seed(7)
            .run()
            .unwrap();
        assert_eq!(a.loss_curve, b.loss_curve);
        assert_eq!(a.final_model, b.final_model);
        assert_eq!(b.precisions, vec![8; 6]);
    }

    /// Loss-curve equivalence of the fused path: the fused session (no
    /// f32 rows) tracks the dequantize oracle at every epoch, reads the
    /// same precisions, accounts identical bytes — and is itself
    /// deterministic bit for bit. (Exact f32 equality with the oracle is
    /// impossible: the fused path sums in plane order.)
    #[test]
    fn fused_session_tracks_dequant_oracle_curve() {
        let ds = make_regression("host_fused", 512, 64, 24, 11);
        let (_, store) = packed_and_store(&ds, 8, 5, 13);
        for sched in [
            PrecisionSchedule::Fixed(8),
            PrecisionSchedule::Fixed(3),
            PrecisionSchedule::StepUp { start: 2, every: 2, max: 8 },
        ] {
            let base = HostSession::over(&ds, &store)
                .schedule(sched)
                .epochs(6)
                .batch(32)
                .lr0(0.05)
                .seed(7);
            let oracle = base.dequant_oracle().run().unwrap();
            let fused = base.run().unwrap();
            assert_eq!(oracle.precisions, fused.precisions, "{sched:?}");
            assert_eq!(
                oracle.sample_bytes_per_epoch, fused.sample_bytes_per_epoch,
                "{sched:?}: byte accounting must be identical to the row-read path"
            );
            for (e, (a, b)) in oracle.loss_curve.iter().zip(&fused.loss_curve).enumerate() {
                assert!(
                    (a - b).abs() <= 2e-2 * (1.0 + a.abs()),
                    "{sched:?} epoch {e}: oracle {a} vs fused {b}"
                );
            }
            let again = base.run().unwrap();
            assert_eq!(fused.loss_curve, again.loss_curve, "{sched:?} not deterministic");
            assert_eq!(fused.final_model, again.final_model);
        }
    }

    /// Independently ingested store (fresh stochastic draws) converges to
    /// the same loss regime as the packed path at p=8 — tolerance form of
    /// the acceptance criterion.
    #[test]
    #[allow(deprecated)]
    fn ingested_store_matches_packed_loss_within_tolerance() {
        let ds = make_regression("host_tol", 1024, 64, 32, 17);
        let scale = ColumnScale::from_data(&ds.train_a);
        let mut rng = Rng::new(19);
        let packed = PackedMatrix::quantize(&ds.train_a, &scale, 8, &mut rng);
        let store = ShardedStore::ingest(&ds.train_a, &scale, 8, 23, 8, 0);
        let a = super::super::driver::train_packed_host(&ds, &packed, 8, 32, 0.05, 7);
        let b = HostSession::over(&ds, &store).epochs(8).batch(32).lr0(0.05).seed(7).run().unwrap();
        let af = *a.loss_curve.last().unwrap();
        assert!(af < 0.5 * a.loss_curve[0], "packed did not converge");
        let ratio = final_loss(&b) / af.max(1e-12);
        assert!((0.5..2.0).contains(&ratio), "loss ratio {ratio}");
    }

    /// Step-up schedule reads coarse planes early, fine planes late, and
    /// pays fewer bytes than a fixed full-width run.
    #[test]
    fn step_up_schedule_reads_fewer_bytes() {
        let ds = make_regression("host_sched", 512, 64, 16, 29);
        let (_, store) = packed_and_store(&ds, 8, 4, 31);
        let base = HostSession::over(&ds, &store).epochs(6).batch(32).lr0(0.05).seed(3);
        let full = base.schedule(PrecisionSchedule::Fixed(8)).run().unwrap();
        let step = base
            .schedule(PrecisionSchedule::StepUp { start: 2, every: 2, max: 8 })
            .run()
            .unwrap();
        assert_eq!(step.precisions, vec![2, 2, 4, 4, 8, 8]);
        assert!(step.sample_bytes_per_epoch < full.sample_bytes_per_epoch);
        assert!(final_loss(&step).is_finite());
    }

    /// Regression for the ragged-tail drop: with k % batch != 0 the
    /// skeleton must visit every training row exactly once per epoch, in
    /// one genuinely short final batch.
    #[test]
    fn epoch_skeleton_visits_ragged_tail() {
        let ds = make_regression("host_tail", 70, 8, 6, 41);
        let mut seen = vec![0u32; 70];
        let mut batch_sizes = Vec::new();
        epoch_skeleton(
            &ds,
            &ModelKind::Linreg,
            1,
            32,
            0.0,
            5,
            |_, _| 1,
            |_, rows, _, _| {
                batch_sizes.push(rows.len());
                for &r in rows {
                    seen[r] += 1;
                }
            },
            |_| {},
        );
        assert_eq!(batch_sizes, vec![32, 32, 6]);
        assert!(seen.iter().all(|&c| c == 1), "rows missed or repeated: {seen:?}");
    }

    /// Ragged-tail byte accounting over the store paths: with k % batch
    /// != 0 every row is fetched once per epoch (truncation) and twice
    /// per epoch (double sampling) — the DS path's bytes are *exactly*
    /// 2×.
    #[test]
    fn ragged_store_paths_account_every_row() {
        let ds = make_regression("host_tail_store", 100, 16, 12, 43);
        let (_, store) = packed_and_store(&ds, 8, 3, 19);
        let base = HostSession::over(&ds, &store)
            .schedule(PrecisionSchedule::Fixed(4))
            .epochs(3)
            .batch(32)
            .lr0(0.05)
            .seed(7);
        let tr = base.run().unwrap();
        assert_eq!(tr.sample_bytes_per_epoch, (100 * store.bytes_per_row(4)) as f64);
        let dsr = base.read(ReadStrategy::DoubleSample).run().unwrap();
        assert_eq!(dsr.sample_bytes_per_epoch, 2.0 * tr.sample_bytes_per_epoch);
    }

    /// The popcount session converges like the exact fused path at a
    /// generous q, replays bit for bit from its seed, and accounts
    /// exactly the truncating path's bytes.
    #[test]
    fn popcount_session_converges_deterministic_same_bytes() {
        let ds = make_regression("host_q", 512, 64, 24, 51);
        let (_, store) = packed_and_store(&ds, 8, 5, 13);
        let base = HostSession::over(&ds, &store).epochs(8).batch(32).lr0(0.05).seed(7);
        let exact = base.run().unwrap();
        let q = base.read(ReadStrategy::Popcount { q: 12 }).run().unwrap();
        assert_eq!(q.precisions, exact.precisions);
        assert_eq!(
            q.sample_bytes_per_epoch, exact.sample_bytes_per_epoch,
            "popcount path must not change sample-byte accounting"
        );
        let (le, lq) = (final_loss(&exact), final_loss(&q));
        assert!(le < 0.5 * exact.loss_curve[0], "exact path did not converge");
        assert!(
            lq < 2.0 * le.max(1e-9) + 0.05 * exact.loss_curve[0],
            "q path stalled: {lq} vs {le}"
        );
        let again = base.read(ReadStrategy::Popcount { q: 12 }).run().unwrap();
        assert_eq!(q.loss_curve, again.loss_curve, "not deterministic");
        assert_eq!(q.final_model, again.final_model);
        // a different seed draws different roundings below exactness
        let other = base.read(ReadStrategy::Popcount { q: 4 }).seed(8).run().unwrap();
        assert_ne!(q.final_model, other.final_model);
    }

    /// The DS session is deterministic bit for bit and degenerates to the
    /// truncating fused path at p = stored width (carry-free draws).
    #[test]
    fn ds_session_deterministic_and_exact_at_full_width() {
        let ds = make_regression("host_ds", 256, 32, 16, 47);
        let (_, store) = packed_and_store(&ds, 8, 4, 23);
        let base = HostSession::over(&ds, &store).epochs(5).batch(32).lr0(0.05).seed(7);
        let a = base.read(ReadStrategy::DoubleSample).run().unwrap();
        let b = base.read(ReadStrategy::DoubleSample).run().unwrap();
        assert_eq!(a.loss_curve, b.loss_curve);
        assert_eq!(a.final_model, b.final_model);
        // at p = bits both draws are the exact stored row, so the loss
        // curve tracks the truncating fused path epoch for epoch
        let t = base.run().unwrap();
        for (e, (u, v)) in a.loss_curve.iter().zip(&t.loss_curve).enumerate() {
            assert!((u - v).abs() <= 2e-2 * (1.0 + u.abs()), "epoch {e}: ds {u} vs trunc {v}");
        }
        // distinct seeds draw distinct carries below full width
        let c = base.read(ReadStrategy::DoubleSample).schedule(PrecisionSchedule::Fixed(3)).run();
        let d = base
            .read(ReadStrategy::DoubleSample)
            .schedule(PrecisionSchedule::Fixed(3))
            .seed(8)
            .run();
        assert_ne!(c.unwrap().final_model, d.unwrap().final_model);
    }

    /// GlmLoss sanity: multipliers and losses at hand-checked points.
    #[test]
    fn glm_loss_pointwise_values() {
        let lin = ModelKind::Linreg;
        assert_eq!(lin.multiplier(3.0, 1.0), 2.0);
        assert_eq!(lin.loss(3.0, 1.0), 4.0);
        assert_eq!(lin.l2_reg(), 0.0);
        assert_eq!(lin.l2_penalty(&[5.0, 5.0]), 0.0);

        let ls = ModelKind::Lssvm { c: 0.5 };
        assert_eq!(ls.multiplier(3.0, 1.0), 2.0);
        assert_eq!(ls.l2_reg(), 0.5);
        assert!((ls.l2_penalty(&[2.0, 0.0]) - 1.0).abs() < 1e-12);

        let lo = ModelKind::Logistic;
        // at the decision boundary: ℓ = ln 2, ℓ′ = −y/2
        assert!((lo.loss(0.0, 1.0) - std::f64::consts::LN_2).abs() < 1e-12);
        assert!((lo.multiplier(0.0, 1.0) + 0.5).abs() < 1e-6);
        assert!((lo.multiplier(0.0, -1.0) - 0.5).abs() < 1e-6);
        // saturation is overflow-free on both sides
        assert!(lo.multiplier(1e4, 1.0).abs() < 1e-6);
        assert!((lo.multiplier(-1e4, 1.0) + 1.0).abs() < 1e-6);
        assert!(lo.loss(1e4, 1.0).abs() < 1e-12);
        assert!((lo.loss(-300.0, 1.0) - 300.0).abs() < 1e-9);

        let sv = ModelKind::Svm;
        assert_eq!(sv.multiplier(0.5, 1.0), -1.0); // inside the margin
        assert_eq!(sv.multiplier(2.0, 1.0), 0.0); // satisfied
        assert_eq!(sv.multiplier(-0.5, -1.0), 1.0); // violation at y = −1: −y
        assert_eq!(sv.loss(0.5, 1.0), 0.5);
        assert_eq!(sv.loss(2.0, 1.0), 0.0);
    }

    /// eval_glm_loss reproduces train_mse bit for bit for linreg.
    #[test]
    fn eval_glm_loss_matches_train_mse_for_linreg() {
        let ds = make_regression("glm_mse", 128, 16, 12, 3);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..12).map(|_| rng.normal()).collect();
        assert_eq!(eval_glm_loss(&ds, &ModelKind::Linreg, &x), ds.train_mse(&x));
    }
}
