//! Quantization mode lattice and its bandwidth accounting.
//!
//! Each mode states (a) which artifact kind executes the step, and (b) how
//! many bits per sample value cross the memory boundary — the quantity the
//! FPGA experiment (Fig 5) and the bandwidth figure trade on.

use crate::quant::packing::extra_bits_symmetric;

/// Which generalized linear model is being trained.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ModelKind {
    Linreg,
    /// least-squares SVM with ℓ2 regularization strength c (§F.1)
    Lssvm { c: f32 },
    Logistic,
    /// hinge loss (±1 labels), subgradient steps
    Svm,
}

impl ModelKind {
    pub fn step_kind_fp(&self) -> &'static str {
        match self {
            ModelKind::Linreg => "linreg_fp_step",
            ModelKind::Lssvm { .. } => "lssvm_fp_step",
            ModelKind::Logistic => "logistic_fp_step",
            ModelKind::Svm => "svm_fp_step",
        }
    }

    pub fn step_kind_ds(&self) -> Option<&'static str> {
        match self {
            ModelKind::Linreg => Some("linreg_ds_step"),
            ModelKind::Lssvm { .. } => Some("lssvm_ds_step"),
            // non-linear models use cheby/poly/refetch paths
            ModelKind::Logistic | ModelKind::Svm => None,
        }
    }

    pub fn loss_kind(&self) -> &'static str {
        match self {
            ModelKind::Linreg => "linreg_loss",
            ModelKind::Lssvm { .. } => "lssvm_loss",
            ModelKind::Logistic => "logistic_loss",
            ModelKind::Svm => "hinge_loss",
        }
    }

    pub fn is_classification(&self) -> bool {
        matches!(self, ModelKind::Logistic | ModelKind::Svm)
    }
}

/// Refetch strategy for non-smooth losses (§G.3/§G.4).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RefetchStrategy {
    /// deterministic ℓ1 bound: refetch iff the quantization interval could
    /// flip sign of (1 − b·aᵀx)
    L1,
    /// JL-sketch margin estimate with gap δ (probabilistic, sublinear comm)
    L2Jl { sketch_dim: usize, delta: f32 },
}

/// End-to-end quantization mode (Fig 1 / §A.1's compression points).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mode {
    /// 32-bit baseline.
    Full,
    /// Naive single-sample quantization — *biased*, §B.1's strawman.
    Naive { bits: u32 },
    /// Double sampling (§2.2), f32 dequantized operands.
    DoubleSample { bits: u32 },
    /// Double sampling with u8 level indices dequantized inside the L1
    /// kernel (bandwidth-faithful device path).
    DoubleSampleU8 { bits: u32 },
    /// Samples + model + gradient quantization (§E).
    EndToEnd { bits_s: u32, bits_m: u32, bits_g: u32 },
    /// Model quantized only (§C): full-precision samples/gradient.
    ModelQuant { bits: u32 },
    /// Gradient quantized only (§D / QSGD): full-precision samples/model.
    GradQuant { bits: u32 },
    /// Double sampling on per-feature variance-optimal grids (§3).
    OptimalDs { levels: usize },
    /// Deterministic nearest rounding of the data once (the §5.4 strawman).
    NearestRound { bits: u32 },
    /// Chebyshev-approximate gradient for non-linear losses (§4.2).
    Cheby { bits: u32 },
    /// Unbiased polynomial estimator with d+1 independent samples (§4.1).
    PolyDs { bits: u32 },
    /// Quantized SVM with refetching (§G).
    Refetch { bits: u32, strategy: RefetchStrategy },
}

impl Mode {
    /// Bits per sample value crossing the memory boundary (wire format).
    pub fn wire_bits_per_value(&self, cheby_degree: usize) -> f64 {
        match *self {
            Mode::Full => 32.0,
            Mode::Naive { bits } | Mode::NearestRound { bits } => bits as f64,
            Mode::DoubleSample { bits } | Mode::DoubleSampleU8 { bits } => {
                (bits + extra_bits_symmetric(2)) as f64
            }
            Mode::EndToEnd { bits_s, .. } => (bits_s + extra_bits_symmetric(2)) as f64,
            // samples move at full precision in these two modes
            Mode::ModelQuant { .. } | Mode::GradQuant { .. } => 32.0,
            Mode::OptimalDs { levels } => {
                let bits = (usize::BITS - (levels - 1).leading_zeros()) as u32;
                (bits + extra_bits_symmetric(2)) as f64
            }
            Mode::Cheby { bits } => (bits + extra_bits_symmetric(2)) as f64,
            // d+1 samples at `bits` each with the symmetric-count encoding
            Mode::PolyDs { bits } => (bits + extra_bits_symmetric(cheby_degree + 1)) as f64,
            // refetching adds the refetched rows separately (driver counts)
            Mode::Refetch { bits, .. } => bits as f64,
        }
    }

    /// Short id used in reports/CSV.
    pub fn label(&self) -> String {
        match *self {
            Mode::Full => "fp32".into(),
            Mode::Naive { bits } => format!("naive{bits}"),
            Mode::DoubleSample { bits } => format!("ds{bits}"),
            Mode::DoubleSampleU8 { bits } => format!("dsu8_{bits}"),
            Mode::EndToEnd { bits_s, bits_m, bits_g } => format!("e2e{bits_s}m{bits_m}g{bits_g}"),
            Mode::ModelQuant { bits } => format!("mq{bits}"),
            Mode::GradQuant { bits } => format!("gq{bits}"),
            Mode::OptimalDs { levels } => format!("opt{levels}"),
            Mode::NearestRound { bits } => format!("round{bits}"),
            Mode::Cheby { bits } => format!("cheby{bits}"),
            Mode::PolyDs { bits } => format!("poly{bits}"),
            Mode::Refetch { bits, strategy: RefetchStrategy::L1 } => format!("refetch_l1_{bits}"),
            Mode::Refetch { bits, .. } => format!("refetch_jl_{bits}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bits_ordering() {
        // fp32 ≫ double-sampled 4-bit > naive 4-bit
        let fp = Mode::Full.wire_bits_per_value(15);
        let ds = Mode::DoubleSample { bits: 4 }.wire_bits_per_value(15);
        let nv = Mode::Naive { bits: 4 }.wire_bits_per_value(15);
        assert_eq!(fp, 32.0);
        assert_eq!(ds, 6.0); // 4 + ⌈log2 3⌉
        assert_eq!(nv, 4.0);
        assert!(fp / ds > 5.0);
    }

    #[test]
    fn poly_accounting_matches_paper() {
        // §5.4: degree 15 → 16 samples → 4 extra bits; 4-bit base = 8 bits
        let m = Mode::PolyDs { bits: 4 };
        assert_eq!(m.wire_bits_per_value(15), 9.0); // 4 + ⌈log2 17⌉ = 9
        // (the paper's "8 bits total" counts log2(16); we account the
        //  k+1 = 17 count exactly — one bit of honesty overhead)
    }

    #[test]
    fn labels_unique_enough() {
        let ms = [
            Mode::Full,
            Mode::Naive { bits: 4 },
            Mode::DoubleSample { bits: 4 },
            Mode::OptimalDs { levels: 8 },
        ];
        let labels: Vec<String> = ms.iter().map(|m| m.label()).collect();
        let mut dedup = labels.clone();
        dedup.dedup();
        assert_eq!(labels.len(), dedup.len());
    }

    #[test]
    fn model_kind_artifacts() {
        assert_eq!(ModelKind::Linreg.step_kind_fp(), "linreg_fp_step");
        assert_eq!(ModelKind::Lssvm { c: 0.1 }.loss_kind(), "lssvm_loss");
        assert!(ModelKind::Svm.step_kind_ds().is_none());
        assert!(ModelKind::Logistic.is_classification());
    }
}
