//! The ZipML training system: quantized sample store + SGD driver running
//! AOT-compiled step artifacts on the PJRT runtime.
//!
//! * [`modes`]   — the quantization mode lattice (Fig 1's design space)
//! * [`driver`]  — the epoch loop: store → batches → artifact execution
//! * [`host`]    — artifact-free [`HostSession`]: any GLM × read strategy
//!   × execution × schedule over the weaved store (the legacy free host
//!   trainers are deprecated shims over it)
//! * [`refetch`] — ℓ1 / ℓ2(JL) refetching for hinge loss (§G)
//! * [`deep`]    — quantized-model MLP training (§3.3, Fig 7b)

pub mod deep;
pub mod driver;
pub mod host;
pub mod modes;
pub mod refetch;

pub use driver::{train, HostTrainResult, StoreBackend, TrainConfig, TrainResult};
#[allow(deprecated)] // legacy entry points stay importable during migration
pub use driver::{
    train_packed_host, train_store_host, train_store_host_dequant, train_store_host_ds,
    train_store_host_q,
};
pub use host::{eval_glm_loss, Execution, GlmLoss, HostSession, ReadStrategy, SessionResult};
pub use modes::{Mode, ModelKind};

/// Diminishing step size α/k per epoch k (the paper's §5 schedule).
pub fn lr_at_epoch(lr0: f32, epoch: usize) -> f32 {
    lr0 / (epoch as f32 + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_diminishes() {
        assert_eq!(lr_at_epoch(0.1, 0), 0.1);
        assert_eq!(lr_at_epoch(0.1, 1), 0.05);
        assert!(lr_at_epoch(0.1, 9) < lr_at_epoch(0.1, 8));
    }
}
