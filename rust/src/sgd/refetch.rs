//! Refetching for non-smooth losses (§G.3–G.4).
//!
//! Hinge-loss SGD on quantized samples can *flip* the subgradient: the sign
//! of (1 − b·aᵀx) may differ between Q(a) and a. Two guards:
//!
//! * **ℓ1** (deterministic, §G.4): per-coordinate quantization error is at
//!   most one grid interval, so |Q(a)ᵀx − aᵀx| ≤ Σ_c |x_c| · 2 m_c / s.
//!   If [margin ± bound] brackets 1, refetch the full-precision row.
//!   *Never* admits a flip — a property test pins this.
//! * **ℓ2 / JL** (probabilistic, §G.3.1): transmitter and receiver share a
//!   seed; the margin is estimated from r-dimensional ±1 sketches and rows
//!   inside the 2δ gap are refetched. Communication per decision is r
//!   floats instead of n.

use anyhow::Result;

use crate::data::Dataset;
use crate::quant::jl::JlSketch;
use crate::quant::packing::PackedMatrix;
use crate::quant::ColumnScale;
use crate::runtime::{lit_f32, Runtime};
use crate::tensor::Matrix;

use super::modes::RefetchStrategy;

pub struct RefetchState {
    strategy: RefetchStrategy,
    s: u32,
    scale_m: Vec<f32>,
    /// cached sketches of the *full-precision* rows (computed once — the
    /// transmitter-side half of the §G.3.1 protocol)
    row_sketches: Vec<Vec<f32>>,
    jl: Option<JlSketch>,
    /// counters
    refetched: u64,
    total: u64,
}

impl RefetchState {
    pub fn new(
        ds: &Dataset,
        scale: &ColumnScale,
        bits: u32,
        strategy: RefetchStrategy,
        seed: u64,
    ) -> Result<Self> {
        let s = crate::quant::intervals_for_bits(bits);
        let (jl, row_sketches) = match strategy {
            RefetchStrategy::L1 => (None, Vec::new()),
            RefetchStrategy::L2Jl { sketch_dim, .. } => {
                let jl = JlSketch::new(sketch_dim, ds.n(), seed);
                let sketches = (0..ds.k_train())
                    .map(|r| jl.sketch(ds.train_a.row(r)))
                    .collect();
                (Some(jl), sketches)
            }
        };
        Ok(RefetchState {
            strategy,
            s,
            scale_m: scale.m.clone(),
            row_sketches,
            jl,
            refetched: 0,
            total: 0,
        })
    }

    /// Fill `batch` with dequantized rows, replacing flagged rows by their
    /// full-precision originals. `rows` are dataset indices.
    pub fn prepare_batch(
        &mut self,
        rt: &Runtime,
        packed: &PackedMatrix,
        ds: &Dataset,
        rows: &[usize],
        x: &[f32],
        batch: &mut Matrix,
    ) -> Result<()> {
        let n = ds.n();
        let b = rows.len();
        for (i, &r) in rows.iter().enumerate() {
            packed.dequantize_row(r, batch.row_mut(i));
        }
        self.total += b as u64;
        match self.strategy {
            RefetchStrategy::L1 => {
                // margins on the quantized batch via the margins artifact
                let bv: Vec<f32> = rows.iter().map(|&r| ds.train_b[r]).collect();
                let margins = rt.exec1_f32(
                    &rt.manifest.find_kind_n("margins", n)?.name.clone(),
                    &[
                        lit_f32(&[n, 1], x)?,
                        lit_f32(&[b, n], &batch.data)?,
                        lit_f32(&[b, 1], &bv)?,
                    ],
                )?;
                // worst-case |Q(a)ᵀx − aᵀx| under one-interval error/coord
                let bound: f32 = x
                    .iter()
                    .zip(&self.scale_m)
                    .map(|(&xc, &mc)| xc.abs() * 2.0 * mc / self.s as f32)
                    .sum();
                for (i, &r) in rows.iter().enumerate() {
                    let gap = 1.0 - margins[i];
                    if gap.abs() <= bound {
                        batch.row_mut(i).copy_from_slice(ds.train_a.row(r));
                        self.refetched += 1;
                    }
                }
            }
            RefetchStrategy::L2Jl { delta, .. } => {
                let jl = self.jl.as_ref().unwrap();
                let sx = jl.sketch(x);
                for (i, &r) in rows.iter().enumerate() {
                    let est = JlSketch::est_dot(&self.row_sketches[r], &sx);
                    let c = 1.0 - ds.train_b[r] * est;
                    if c.abs() <= 2.0 * delta {
                        batch.row_mut(i).copy_from_slice(ds.train_a.row(r));
                        self.refetched += 1;
                    }
                }
            }
        }
        Ok(())
    }

    pub fn fraction(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.refetched as f64 / self.total as f64
        }
    }

    /// Additional full-precision bytes fetched per epoch, amortized.
    pub fn extra_bytes_per_epoch(&self, samples_per_epoch: usize, n: usize) -> f64 {
        let per_sample = self.fraction() * (n * 4) as f64;
        let jl_overhead = match self.strategy {
            RefetchStrategy::L1 => 0.0,
            // receiver ships its sketch of x once per *step*; amortized per
            // sample it is r·4/B bytes — counted conservatively per sample
            RefetchStrategy::L2Jl { sketch_dim, .. } => (sketch_dim * 4) as f64 / 64.0,
        };
        (per_sample + jl_overhead) * samples_per_epoch as f64
    }
}

/// Pure helper used by tests: does the ℓ1 bound provably preclude a flip?
pub fn l1_flip_impossible(margin_q: f32, x: &[f32], scale_m: &[f32], s: u32) -> bool {
    let bound: f32 = x
        .iter()
        .zip(scale_m)
        .map(|(&xc, &mc)| xc.abs() * 2.0 * mc / s as f32)
        .sum();
    (1.0 - margin_q).abs() > bound
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::Prop;
    use crate::rng::Rng;
    use crate::tensor::dot;

    /// The ℓ1 guarantee: if the bound says "no flip possible", then for the
    /// *true* full-precision margin the sign of (1 − z) must match.
    #[test]
    fn l1_bound_never_admits_flip() {
        Prop::new(200).check("l1-no-flip", |rng: &mut Rng| {
            let n = 1 + (rng.below(30));
            let s = 1 + rng.below(15) as u32;
            let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let x: Vec<f32> = (0..n).map(|_| rng.normal() * 0.5).collect();
            let m: Vec<f32> = a.iter().map(|v| v.abs() + rng.f32() * 0.5 + 1e-3).collect();
            let b = if rng.f32() < 0.5 { 1.0 } else { -1.0 };
            // quantize a stochastically
            let mut q = vec![0.0f32; n];
            crate::quant::stochastic::quantize_values(&a, n, &m, s, rng, &mut q);
            let zq = b * dot(&q, &x);
            let z = b * dot(&a, &x);
            if l1_flip_impossible(zq, &x, &m, s) {
                let sq = (1.0 - zq) > 0.0;
                let st = (1.0 - z) > 0.0;
                if sq != st {
                    return Err(format!("flip admitted: zq={zq} z={z}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn l1_bound_scales_with_bits() {
        let x = [0.5f32, -0.5];
        let m = [1.0f32, 1.0];
        // higher s (more bits) → tighter bound → fewer refetches
        let loose = !l1_flip_impossible(1.05, &x, &m, 1);
        let tight = l1_flip_impossible(1.05, &x, &m, 255);
        assert!(loose && tight);
    }
}
