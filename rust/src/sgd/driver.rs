//! The SGD driver: epoch loop over the quantized sample store, executing
//! AOT-compiled step artifacts on the PJRT runtime.
//!
//! Data is quantized ONCE into a bit-packed store (the paper quantizes
//! "during the first epoch"); each step dequantizes a batch and dispatches
//! one artifact execution. Loss is evaluated per epoch on full-precision
//! data against the true objective.
//!
//! Two sample-store backends ([`StoreBackend`], selected in
//! [`TrainConfig`]): the legacy per-mode stores, and the bit-weaved
//! [`ShardedStore`] whose single stored copy serves any precision and
//! whose per-epoch precision follows a [`PrecisionSchedule`]. The weaved
//! path also has an artifact-free host twin ([`super::host::HostSession`],
//! any GLM × read strategy × execution) used by tests, benches, the CLI's
//! `--host` path, and the `store_weaving` example.

use anyhow::{bail, Context, Result};

use crate::cheby;
use crate::data::Dataset;
use crate::quant::packing::{DoubleSampleBlock, PackedMatrix};
use crate::quant::{discretized_optimal_levels, ColumnScale};
use crate::rng::Rng;
use crate::runtime::{lit_f32, lit_scalar11, lit_u8, Runtime};
use crate::store::{PrecisionSchedule, ScheduleState, ShardedStore};
use crate::tensor::Matrix;

use super::host::{HostSession, ReadStrategy};
use super::modes::{Mode, ModelKind};
use super::refetch::RefetchState;

/// Chebyshev settings shared with the artifacts (aot.py constants).
pub const CHEBY_DEG: usize = 15;
pub const RADIUS: f64 = 8.0;

/// Which sample-store implementation backs the epoch loop.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StoreBackend {
    /// Per-mode stores: dense / `PackedMatrix` / `DoubleSampleBlock`.
    Legacy,
    /// Bit-weaved `ShardedStore`: one stored copy read at the precision the
    /// schedule picks each epoch. Drives the packed-sample (`Mode::Naive`)
    /// step; bandwidth is reported from the store's exact byte accounting.
    Weaved { shards: usize, schedule: PrecisionSchedule },
    /// Bit-weaved store read with *stochastic* (unbiased) p-plane draws:
    /// two independent draws per row visit feed the double-sampling step
    /// (`Mode::DoubleSample`), implementing §2.2 from the single stored
    /// copy. Both fetches enter the exact byte accounting (DESIGN.md §5).
    /// `store_bits` is the *ingested* width (1..=16) and must exceed the
    /// schedule's read precision for the carry to be live — at p ==
    /// store_bits the draw degenerates to the exact (deterministic) read,
    /// which defeats double sampling.
    WeavedDs { shards: usize, schedule: PrecisionSchedule, store_bits: u32 },
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: ModelKind,
    pub mode: Mode,
    pub epochs: usize,
    pub batch: usize,
    pub lr0: f32,
    pub seed: u64,
    /// Number of 64-row batches used for the per-epoch loss evaluation.
    pub eval_batches: usize,
    pub store: StoreBackend,
}

impl TrainConfig {
    pub fn new(model: ModelKind, mode: Mode) -> Self {
        TrainConfig {
            model,
            mode,
            epochs: 20,
            batch: 64,
            lr0: 0.05,
            seed: 42,
            eval_batches: 16,
            store: StoreBackend::Legacy,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainResult {
    pub mode_label: String,
    /// loss_curve[e] = training loss after e epochs (index 0 = initial).
    pub loss_curve: Vec<f64>,
    pub final_loss: f64,
    pub wall_secs: f64,
    /// Sample bytes crossing the memory boundary per epoch (wire format).
    pub sample_bytes_per_epoch: f64,
    /// Fraction of samples refetched at full precision (refetch modes).
    pub refetch_fraction: f64,
    pub diverged: bool,
    pub final_model: Vec<f32>,
}

/// Per-mode quantized representation of the training samples.
enum Store {
    Dense(Matrix),
    Packed(PackedMatrix),
    Double(DoubleSampleBlock),
    /// per-feature variance-optimal grids + two pre-quantized index planes
    /// (OptimalDs; "quantized during the first epoch", §Perf L3-4)
    Levels {
        grids: Vec<Vec<f32>>,
        idx: [Vec<u8>; 2],
    },
    /// bit-weaved sharded store: any precision from one copy
    Weaved(ShardedStore),
}

pub fn train(rt: &Runtime, ds: &Dataset, cfg: &TrainConfig) -> Result<TrainResult> {
    let t0 = crate::telemetry::Stopwatch::start();
    let n = ds.n();
    let b = cfg.batch;
    let k = ds.k_train();
    if k < b {
        bail!("dataset smaller than one batch");
    }
    // batches per epoch: every row is visited, so the ragged tail adds one
    // wrap-padded batch (see fill_wrapped_batch); the bandwidth accounting
    // below counts the padded rows too — they are genuinely fetched
    let nb = k.div_ceil(b);
    let mut rng = Rng::new(cfg.seed);
    let scale = ColumnScale::from_data(&ds.train_a);

    // --- resolve artifacts -------------------------------------------------
    let man = &rt.manifest;
    let loss_art = man.find_kind_n(cfg.model.loss_kind(), n)?.name.clone();
    let loss_batch = man.get(&loss_art)?.meta_usize("batch").unwrap_or(64);
    let step_art: String = match (&cfg.mode, &cfg.model) {
        (Mode::Full | Mode::Naive { .. } | Mode::NearestRound { .. }, m) => {
            man.find_kind_n_batch(m.step_kind_fp(), n, b)?.name.clone()
        }
        (Mode::DoubleSample { .. } | Mode::OptimalDs { .. }, m) => {
            let kind = m
                .step_kind_ds()
                .with_context(|| format!("mode {:?} unsupported for {:?}", cfg.mode, m))?;
            man.find_kind_n_batch(kind, n, b)?.name.clone()
        }
        (Mode::DoubleSampleU8 { .. }, ModelKind::Linreg) => {
            man.find_kind_n_batch("linreg_ds_u8_step", n, b)?.name.clone()
        }
        (
            Mode::EndToEnd { .. } | Mode::ModelQuant { .. } | Mode::GradQuant { .. },
            ModelKind::Linreg,
        ) => man.find_kind_n_batch("e2e_step", n, b)?.name.clone(),
        (Mode::Cheby { .. }, m) if m.is_classification() => {
            man.find_kind_n_batch("cheby_step", n, b)?.name.clone()
        }
        (Mode::PolyDs { .. }, m) if m.is_classification() => {
            man.find_kind_n_batch("poly_ds_step", n, b)?.name.clone()
        }
        (Mode::Refetch { .. }, ModelKind::Svm) => {
            man.find_kind_n_batch("svm_fp_step", n, b)?.name.clone()
        }
        (mode, m) => bail!("mode {mode:?} not supported for model {m:?}"),
    };

    // --- build the quantized store (the "first epoch" quantization) -------
    let store = match cfg.store {
        StoreBackend::Weaved { shards, .. } => {
            let Mode::Naive { bits } = cfg.mode else {
                bail!(
                    "the weaved store backend drives the packed-sample step \
                     (Mode::Naive); got mode {:?}",
                    cfg.mode
                );
            };
            Store::Weaved(ShardedStore::ingest(
                &ds.train_a,
                &scale,
                bits,
                cfg.seed ^ 0x5745_4156_4544, // "WEAVED"
                shards,
                0,
            ))
        }
        StoreBackend::WeavedDs { shards, store_bits, .. } => {
            if !matches!(cfg.mode, Mode::DoubleSample { .. }) {
                bail!(
                    "the weaved-ds store backend drives the double-sampling \
                     step (Mode::DoubleSample); got mode {:?}",
                    cfg.mode
                );
            }
            if !(1..=16).contains(&store_bits) {
                bail!("weaved-ds store_bits must be 1..=16, got {store_bits}");
            }
            Store::Weaved(ShardedStore::ingest(
                &ds.train_a,
                &scale,
                store_bits,
                cfg.seed ^ 0x5745_4156_4544, // "WEAVED"
                shards,
                0,
            ))
        }
        StoreBackend::Legacy => build_legacy_store(ds, cfg, &scale, k, n, &mut rng)?,
    };
    // per-epoch precision schedule (weaved backends only)
    let mut sched = match (&cfg.store, &store) {
        (
            StoreBackend::Weaved { schedule, .. } | StoreBackend::WeavedDs { schedule, .. },
            Store::Weaved(ws),
        ) => Some(ScheduleState::new(*schedule, ws.bits())),
        _ => None,
    };
    // carry-randomness stream for stochastic store reads (independent of
    // the shuffle stream, so Naive and DS runs share visit orders)
    let mut ds_rng = Rng::new_stream(cfg.seed, 0x4453); // "DS"
    let weaved_ds = matches!(cfg.store, StoreBackend::WeavedDs { .. });

    // --- Chebyshev coefficients (classification approximations) -----------
    let (coefs_lit, mono_lit) = if matches!(cfg.mode, Mode::Cheby { .. } | Mode::PolyDs { .. }) {
        let f: Box<dyn Fn(f64) -> f64> = match cfg.model {
            ModelKind::Logistic => Box::new(cheby::logistic_lprime),
            ModelKind::Svm => Box::new(|z| cheby::hinge_lprime_smoothed(z, 0.25)),
            ModelKind::Linreg | ModelKind::Lssvm { .. } => {
                bail!("cheby modes need a classification model")
            }
        };
        let coefs = cheby::cheb_fit(&*f, RADIUS, CHEBY_DEG);
        let mono = cheby::cheb_to_monomial(&coefs, RADIUS);
        let cf: Vec<f32> = coefs.iter().map(|&c| c as f32).collect();
        let mf: Vec<f32> = mono.iter().map(|&c| c as f32).collect();
        (
            Some(lit_f32(&[CHEBY_DEG + 1, 1], &cf)?),
            Some(lit_f32(&[CHEBY_DEG + 1, 1], &mf)?),
        )
    } else {
        (None, None)
    };

    // --- loss evaluation batches (full precision, fixed) -------------------
    let eval_nb = eval_batch_count(cfg.eval_batches, loss_batch, k)?;
    let mut eval_lits = Vec::with_capacity(eval_nb);
    for e in 0..eval_nb {
        let rows: Vec<usize> = (e * loss_batch..(e + 1) * loss_batch).collect();
        let a = ds.train_a.gather_rows(&rows);
        let bv: Vec<f32> = rows.iter().map(|&r| ds.train_b[r]).collect();
        eval_lits.push((lit_f32(&[loss_batch, n], &a.data)?, lit_f32(&[loss_batch, 1], &bv)?));
    }
    let c_reg = if let ModelKind::Lssvm { c } = cfg.model { c } else { 0.0 };
    let eval_loss = |x: &[f32], rt: &Runtime| -> Result<f64> {
        let xl = lit_f32(&[n, 1], x)?;
        let mut acc = 0.0f64;
        for (al, bl) in &eval_lits {
            let args: Vec<xla::Literal> = match cfg.model {
                ModelKind::Lssvm { .. } => vec![
                    xl.clone(),
                    al.clone(),
                    bl.clone(),
                    lit_scalar11(c_reg)?,
                ],
                ModelKind::Linreg | ModelKind::Logistic | ModelKind::Svm => {
                    vec![xl.clone(), al.clone(), bl.clone()]
                }
            };
            acc += rt.exec1_scalar(&loss_art, &args)? as f64;
        }
        Ok(acc / eval_nb as f64)
    };

    // --- refetch state ------------------------------------------------------
    let mut refetch = if let Mode::Refetch { bits, strategy } = cfg.mode {
        Some(RefetchState::new(ds, &scale, bits, strategy, cfg.seed ^ 0x5245_4645_5443_4821)?)
    } else {
        None
    };

    // --- epoch loop ---------------------------------------------------------
    let mut x = vec![0.0f32; n];
    let mut loss_curve = Vec::with_capacity(cfg.epochs + 1);
    loss_curve.push(eval_loss(&x, rt)?);
    // every training row is visited: the final ragged batch (artifacts are
    // fixed-shape, so it cannot simply be short) wraps around to rows from
    // the front of this epoch's permutation
    let mut order: Vec<usize> = (0..k).collect();
    let mut batch_rows = vec![0usize; b];
    let mut diverged = false;

    // reusable batch buffers
    let mut a1 = Matrix::zeros(b, n);
    let mut a2 = Matrix::zeros(b, n);
    let mut bv = vec![0.0f32; b];
    let mut idx1 = vec![0u8; b * n];
    let mut idx2 = vec![0u8; b * n];
    let mut aq_poly = vec![0.0f32; (CHEBY_DEG + 1) * b * n];
    let mut rand_buf = vec![0.0f32; n];
    let mut rand_buf2 = vec![0.0f32; n];

    'outer: for epoch in 0..cfg.epochs {
        let lr = super::lr_at_epoch(cfg.lr0, epoch);
        let lr_lit = lit_scalar11(lr)?;
        // weaved backend: pick this epoch's read precision from the schedule
        let p_epoch = match sched.as_mut() {
            Some(s) => s.precision_for_epoch(epoch, &loss_curve),
            None => 0,
        };
        rng.shuffle(&mut order);
        for bi in 0..nb {
            fill_wrapped_batch(&order, bi, b, &mut batch_rows);
            let rows: &[usize] = &batch_rows;
            for (i, &r) in rows.iter().enumerate() {
                bv[i] = ds.train_b[r];
            }
            let xl = lit_f32(&[n, 1], &x)?;
            let bl = lit_f32(&[b, 1], &bv)?;

            let out = match (&store, &cfg.mode) {
                // §C (model-only) / §D (gradient-only) quantization reuse
                // the e2e artifact with full-precision samples (a1 == a2 ==
                // A makes the DS estimator exact) and the *other* quantizer
                // at f32-resolution interval count.
                (Store::Dense(a), Mode::ModelQuant { bits })
                | (Store::Dense(a), Mode::GradQuant { bits }) => {
                    gather_into(a, rows, &mut a1);
                    rng.fill_uniform(&mut rand_buf);
                    rng.fill_uniform(&mut rand_buf2);
                    const FP_INTERVALS: f32 = ((1u32 << 23) - 1) as f32;
                    let q = crate::quant::intervals_for_bits(*bits) as f32;
                    let (s_m, s_g) = if matches!(cfg.mode, Mode::ModelQuant { .. }) {
                        (q, FP_INTERVALS)
                    } else {
                        (FP_INTERVALS, q)
                    };
                    let al = lit_f32(&[b, n], &a1.data)?;
                    let args = vec![
                        xl,
                        al.clone(),
                        al,
                        bl,
                        lr_lit.clone(),
                        lit_f32(&[1, n], &rand_buf)?,
                        lit_f32(&[1, n], &rand_buf2)?,
                        lit_scalar11(s_m)?,
                        lit_scalar11(s_g)?,
                    ];
                    rt.exec(&step_art, &args)?
                }
                (Store::Dense(a), _) => {
                    gather_into(a, rows, &mut a1);
                    let al = lit_f32(&[b, n], &a1.data)?;
                    let mut args = vec![xl, al, bl, lr_lit.clone()];
                    if let ModelKind::Lssvm { c } = cfg.model {
                        args.push(lit_scalar11(c)?);
                    }
                    rt.exec(&step_art, &args)?
                }
                (Store::Packed(p), Mode::Naive { .. }) => {
                    for (i, &r) in rows.iter().enumerate() {
                        p.dequantize_row(r, a1.row_mut(i));
                    }
                    let al = lit_f32(&[b, n], &a1.data)?;
                    let mut args = vec![xl, al, bl, lr_lit.clone()];
                    if let ModelKind::Lssvm { c } = cfg.model {
                        args.push(lit_scalar11(c)?);
                    }
                    rt.exec(&step_art, &args)?
                }
                (Store::Packed(p), Mode::Refetch { .. }) => {
                    let rf = refetch.as_mut().unwrap();
                    rf.prepare_batch(rt, p, ds, rows, &x, &mut a1)?;
                    let al = lit_f32(&[b, n], &a1.data)?;
                    rt.exec(&step_art, &[xl, al, bl, lr_lit.clone()])?
                }
                (Store::Weaved(ws), Mode::DoubleSample { .. }) if weaved_ds => {
                    // §2.2 from one stored copy: two independent unbiased
                    // p_epoch-plane draws per row; both fetches counted
                    for (i, &r) in rows.iter().enumerate() {
                        ws.dequantize_row_ds(r, p_epoch, &mut ds_rng, a1.row_mut(i));
                        ws.dequantize_row_ds(r, p_epoch, &mut ds_rng, a2.row_mut(i));
                    }
                    let mut args = vec![
                        xl,
                        lit_f32(&[b, n], &a1.data)?,
                        lit_f32(&[b, n], &a2.data)?,
                        bl,
                        lr_lit.clone(),
                    ];
                    if let ModelKind::Lssvm { c } = cfg.model {
                        args.push(lit_scalar11(c)?);
                    }
                    rt.exec(&step_art, &args)?
                }
                (Store::Weaved(ws), _) => {
                    // any-precision read: only p_epoch bit planes are
                    // touched; the store counts the exact bytes
                    for (i, &r) in rows.iter().enumerate() {
                        ws.dequantize_row(r, p_epoch, a1.row_mut(i));
                    }
                    let al = lit_f32(&[b, n], &a1.data)?;
                    let mut args = vec![xl, al, bl, lr_lit.clone()];
                    if let ModelKind::Lssvm { c } = cfg.model {
                        args.push(lit_scalar11(c)?);
                    }
                    rt.exec(&step_art, &args)?
                }
                (Store::Double(dsb), Mode::DoubleSampleU8 { bits }) => {
                    for (i, &r) in rows.iter().enumerate() {
                        dsb.indices_row_u8(r, 0, &mut idx1[i * n..(i + 1) * n]);
                        dsb.indices_row_u8(r, 1, &mut idx2[i * n..(i + 1) * n]);
                    }
                    let s = crate::quant::intervals_for_bits(*bits) as f32;
                    let args = vec![
                        xl,
                        lit_u8(&[b, n], &idx1)?,
                        lit_u8(&[b, n], &idx2)?,
                        lit_f32(&[1, n], &scale.m)?,
                        lit_scalar11(s)?,
                        bl,
                        lr_lit.clone(),
                    ];
                    rt.exec(&step_art, &args)?
                }
                (Store::Double(dsb), Mode::EndToEnd { bits_m, bits_g, .. }) => {
                    for (i, &r) in rows.iter().enumerate() {
                        dsb.dequantize_row(r, 0, a1.row_mut(i));
                        dsb.dequantize_row(r, 1, a2.row_mut(i));
                    }
                    rng.fill_uniform(&mut rand_buf);
                    rng.fill_uniform(&mut rand_buf2);
                    let s_m = crate::quant::intervals_for_bits(*bits_m) as f32;
                    let s_g = crate::quant::intervals_for_bits(*bits_g) as f32;
                    let args = vec![
                        xl,
                        lit_f32(&[b, n], &a1.data)?,
                        lit_f32(&[b, n], &a2.data)?,
                        bl,
                        lr_lit.clone(),
                        lit_f32(&[1, n], &rand_buf)?,
                        lit_f32(&[1, n], &rand_buf2)?,
                        lit_scalar11(s_m)?,
                        lit_scalar11(s_g)?,
                    ];
                    rt.exec(&step_art, &args)?
                }
                (Store::Double(dsb), Mode::Cheby { .. }) => {
                    for (i, &r) in rows.iter().enumerate() {
                        dsb.dequantize_row(r, 0, a1.row_mut(i));
                        dsb.dequantize_row(r, 1, a2.row_mut(i));
                    }
                    let args = vec![
                        xl,
                        lit_f32(&[b, n], &a1.data)?,
                        lit_f32(&[b, n], &a2.data)?,
                        bl,
                        lr_lit.clone(),
                        coefs_lit.as_ref().unwrap().clone(),
                    ];
                    rt.exec(&step_art, &args)?
                }
                (Store::Double(dsb), Mode::PolyDs { .. }) => {
                    for j in 0..=CHEBY_DEG {
                        for (i, &r) in rows.iter().enumerate() {
                            let off = j * b * n + i * n;
                            dsb.dequantize_row(r, j, &mut aq_poly[off..off + n]);
                        }
                    }
                    let args = vec![
                        xl,
                        lit_f32(&[CHEBY_DEG + 1, b, n], &aq_poly)?,
                        bl,
                        lr_lit.clone(),
                        mono_lit.as_ref().unwrap().clone(),
                    ];
                    rt.exec(&step_art, &args)?
                }
                (Store::Double(dsb), _) => {
                    // plain double sampling
                    for (i, &r) in rows.iter().enumerate() {
                        dsb.dequantize_row(r, 0, a1.row_mut(i));
                        dsb.dequantize_row(r, 1, a2.row_mut(i));
                    }
                    let mut args = vec![
                        xl,
                        lit_f32(&[b, n], &a1.data)?,
                        lit_f32(&[b, n], &a2.data)?,
                        bl,
                        lr_lit.clone(),
                    ];
                    if let ModelKind::Lssvm { c } = cfg.model {
                        args.push(lit_scalar11(c)?);
                    }
                    rt.exec(&step_art, &args)?
                }
                (Store::Packed(_), mode) => {
                    bail!("packed store with incompatible mode {mode:?}")
                }
                (Store::Levels { grids, idx }, _) => {
                    // variance-optimal grids: gather pre-quantized indices
                    // and dequantize via grid lookup (§Perf L3-4)
                    for (i, &r) in rows.iter().enumerate() {
                        let (p0, p1) = (&idx[0][r * n..(r + 1) * n], &idx[1][r * n..(r + 1) * n]);
                        for c in 0..n {
                            a1.set(i, c, grids[c][p0[c] as usize]);
                            a2.set(i, c, grids[c][p1[c] as usize]);
                        }
                    }
                    let mut args = vec![
                        xl,
                        lit_f32(&[b, n], &a1.data)?,
                        lit_f32(&[b, n], &a2.data)?,
                        bl,
                        lr_lit.clone(),
                    ];
                    if let ModelKind::Lssvm { c } = cfg.model {
                        args.push(lit_scalar11(c)?);
                    }
                    rt.exec(&step_art, &args)?
                }
            };
            let newx = crate::runtime::to_f32_vec(&out[0])?;
            x.copy_from_slice(&newx);
            // radius projection for polynomial-approximation modes
            if matches!(cfg.mode, Mode::Cheby { .. } | Mode::PolyDs { .. }) {
                let norm = crate::tensor::norm2(&x);
                if norm > RADIUS as f32 {
                    let f = RADIUS as f32 / norm;
                    for v in x.iter_mut() {
                        *v *= f;
                    }
                }
            }
        }
        let loss = eval_loss(&x, rt)?;
        loss_curve.push(loss);
        if !loss.is_finite() || loss > 1e12 {
            diverged = true;
            break 'outer;
        }
    }

    // --- bandwidth accounting ------------------------------------------------
    let epochs_run = loss_curve.len().saturating_sub(1).max(1);
    let mut sample_bytes = match &store {
        // exact bytes touched, measured by the store itself
        Store::Weaved(ws) => ws.bytes_read() as f64 / epochs_run as f64,
        _ => {
            let wire_bits = cfg.mode.wire_bits_per_value(CHEBY_DEG);
            (nb * b * n) as f64 * wire_bits / 8.0
        }
    };
    let refetch_fraction = refetch
        .as_ref()
        .map(|r| r.fraction())
        .unwrap_or(0.0);
    if let Some(rf) = &refetch {
        sample_bytes += rf.extra_bytes_per_epoch(nb * b, n);
    }

    Ok(TrainResult {
        mode_label: cfg.mode.label(),
        final_loss: *loss_curve.last().unwrap(),
        loss_curve,
        wall_secs: t0.elapsed_secs(),
        sample_bytes_per_epoch: sample_bytes,
        refetch_fraction,
        diverged,
        final_model: x,
    })
}

/// Legacy per-mode store construction (the pre-weaving quantization).
fn build_legacy_store(
    ds: &Dataset,
    cfg: &TrainConfig,
    scale: &ColumnScale,
    k: usize,
    n: usize,
    rng: &mut Rng,
) -> Result<Store> {
    Ok(match cfg.mode {
        // §C / §D: samples stay full precision
        Mode::Full | Mode::ModelQuant { .. } | Mode::GradQuant { .. } => {
            Store::Dense(ds.train_a.clone())
        }
        Mode::NearestRound { bits } => {
            // deterministic nearest rounding of the data, once (§5.4 strawman)
            let s = crate::quant::intervals_for_bits(bits);
            let mut a = ds.train_a.clone();
            for r in 0..a.rows {
                for (c, v) in a.row_mut(r).iter_mut().enumerate() {
                    let m = scale.m[c];
                    if m <= 0.0 {
                        *v = 0.0;
                        continue;
                    }
                    let u = (*v / m).clamp(-1.0, 1.0);
                    let idx = ((u + 1.0) * 0.5 * s as f32).round().min(s as f32);
                    *v = (idx / s as f32 * 2.0 - 1.0) * m;
                }
            }
            Store::Dense(a)
        }
        Mode::Naive { bits } | Mode::Refetch { bits, .. } => {
            Store::Packed(PackedMatrix::quantize(&ds.train_a, scale, bits, rng))
        }
        Mode::DoubleSample { bits }
        | Mode::DoubleSampleU8 { bits }
        | Mode::EndToEnd { bits_s: bits, .. } => {
            Store::Double(DoubleSampleBlock::quantize(&ds.train_a, scale, bits, 2, rng))
        }
        Mode::Cheby { bits } => {
            Store::Double(DoubleSampleBlock::quantize(&ds.train_a, scale, bits, 2, rng))
        }
        Mode::PolyDs { bits } => Store::Double(DoubleSampleBlock::quantize(
            &ds.train_a,
            scale,
            bits,
            CHEBY_DEG + 1,
            rng,
        )),
        Mode::OptimalDs { levels } => {
            // per-feature grids from a column subsample (single data pass)
            let sample_rows = k.min(2000);
            let mut grids = Vec::with_capacity(n);
            let mut col = vec![0.0f32; sample_rows];
            for c in 0..n {
                for (i, v) in col.iter_mut().enumerate() {
                    *v = ds.train_a.get(i * (k / sample_rows).max(1) % k, c);
                }
                grids.push(discretized_optimal_levels(&col, levels, 64));
            }
            // pre-quantize both independent sample planes once
            let mut idx = [vec![0u8; k * n], vec![0u8; k * n]];
            for plane in idx.iter_mut() {
                for (row, orow) in ds.train_a.data.chunks(n).zip(plane.chunks_mut(n)) {
                    for ((&v, o), grid) in row.iter().zip(orow.iter_mut()).zip(&grids) {
                        *o = crate::quant::stochastic::quantize_one_to_level_index(v, grid, rng)
                            as u8;
                    }
                }
            }
            Store::Levels { grids, idx }
        }
    })
}

fn gather_into(a: &Matrix, rows: &[usize], out: &mut Matrix) {
    for (i, &r) in rows.iter().enumerate() {
        out.row_mut(i).copy_from_slice(a.row(r));
    }
}

/// Fill fixed-size batch `bi` from a shuffled visit order, wrapping the
/// final ragged batch around to the front of the permutation: every row of
/// `order` is visited at least once per epoch (the wrapped rows twice).
/// Fixed-shape artifact steps cannot take a short batch, so this is the
/// artifact path's tail policy; the host paths run a genuinely short final
/// batch instead. Requires `order.len() >= out.len()`.
fn fill_wrapped_batch(order: &[usize], bi: usize, b: usize, out: &mut [usize]) {
    debug_assert_eq!(out.len(), b);
    debug_assert!(order.len() >= b);
    let start = bi * b;
    let end = (start + b).min(order.len());
    let live = end - start;
    out[..live].copy_from_slice(&order[start..end]);
    out[live..].copy_from_slice(&order[..b - live]);
}

/// Number of per-epoch loss-evaluation batches: the requested count clamped
/// to what the training split can fill. Errors instead of silently building
/// zero batches — with `eval_nb == 0` the per-epoch loss would divide by
/// zero and report NaN as "diverged".
fn eval_batch_count(requested: usize, loss_batch: usize, k: usize) -> Result<usize> {
    if loss_batch == 0 {
        bail!("loss artifact declares batch=0");
    }
    let nb = requested.min(k / loss_batch);
    if nb == 0 {
        bail!(
            "cannot evaluate loss: {k} training rows fill no {loss_batch}-row eval batch \
             (need k >= {loss_batch} and eval_batches >= 1)"
        );
    }
    Ok(nb)
}

// ---------------------------------------------------------------------------
// Artifact-free host training (legacy entry points).
//
// The host engine lives in [`super::host`]: a [`HostSession`] composes
// any GLM loss × read strategy × execution × precision schedule over the
// weaved store. The five historical free functions below survive as
// deprecated ≤5-line shims — each is one fixed point of the session's
// axis lattice, bit-for-bit identical to its pre-session implementation
// for linreg (regression-tested in tests/host_session.rs).
// ---------------------------------------------------------------------------

/// Result of a legacy host-path run ([`train_store_host`] /
/// [`train_packed_host`]); new code reads the richer
/// [`super::host::SessionResult`] instead.
#[derive(Clone, Debug)]
pub struct HostTrainResult {
    /// loss_curve[e] = full-precision training loss after e epochs.
    pub loss_curve: Vec<f64>,
    pub final_model: Vec<f32>,
    /// Store-accounted sample bytes per epoch (exact for the weaved path).
    pub sample_bytes_per_epoch: f64,
    /// Precision actually read at each epoch.
    pub precisions: Vec<u32>,
}

/// Truncating fused host training (linreg). Shim over [`HostSession`].
#[deprecated(note = "compose a sgd::host::HostSession (ReadStrategy::Truncate) instead")]
pub fn train_store_host(
    ds: &Dataset,
    store: &ShardedStore,
    schedule: PrecisionSchedule,
    epochs: usize,
    batch: usize,
    lr0: f32,
    seed: u64,
) -> HostTrainResult {
    let s = HostSession::over(ds, store).schedule(schedule);
    let s = s.epochs(epochs).batch(batch).lr0(lr0).seed(seed);
    s.run().expect("legacy train_store_host combination").into_host()
}

/// Double-sampled fused host training (linreg, §2.2). Shim over
/// [`HostSession`].
#[deprecated(note = "compose a sgd::host::HostSession (ReadStrategy::DoubleSample) instead")]
pub fn train_store_host_ds(
    ds: &Dataset,
    store: &ShardedStore,
    schedule: PrecisionSchedule,
    epochs: usize,
    batch: usize,
    lr0: f32,
    seed: u64,
) -> HostTrainResult {
    let s = HostSession::over(ds, store).schedule(schedule).read(ReadStrategy::DoubleSample);
    let s = s.epochs(epochs).batch(batch).lr0(lr0).seed(seed);
    s.run().expect("legacy train_store_host_ds combination").into_host()
}

/// Popcount fast-path host training (linreg, DESIGN.md §8). Shim over
/// [`HostSession`].
#[deprecated(note = "compose a sgd::host::HostSession (ReadStrategy::Popcount) instead")]
#[allow(clippy::too_many_arguments)] // the legacy signature: 7 + step_bits
pub fn train_store_host_q(
    ds: &Dataset,
    store: &ShardedStore,
    schedule: PrecisionSchedule,
    step_bits: u32,
    epochs: usize,
    batch: usize,
    lr0: f32,
    seed: u64,
) -> HostTrainResult {
    let s = HostSession::over(ds, store).schedule(schedule);
    let s = s.read(ReadStrategy::Popcount { q: step_bits });
    let s = s.epochs(epochs).batch(batch).lr0(lr0).seed(seed);
    s.run().expect("legacy train_store_host_q combination").into_host()
}

/// Dequantize-row oracle over the weaved store — the pre-fusion
/// validation baseline. Shim over [`HostSession::dequant_oracle`].
#[deprecated(note = "compose a sgd::host::HostSession (dequant_oracle) instead")]
pub fn train_store_host_dequant(
    ds: &Dataset,
    store: &ShardedStore,
    schedule: PrecisionSchedule,
    epochs: usize,
    batch: usize,
    lr0: f32,
    seed: u64,
) -> HostTrainResult {
    let s = HostSession::over(ds, store).schedule(schedule).dequant_oracle();
    let s = s.epochs(epochs).batch(batch).lr0(lr0).seed(seed);
    s.run().expect("legacy train_store_host_dequant combination").into_host()
}

/// Host-path twin over the legacy [`PackedMatrix`] (full stored width) —
/// the baseline the weaved paths are validated against. Shim over
/// [`HostSession`]: re-shards losslessly via `ShardedStore::from_packed`
/// (bit-identical reads) and keeps the legacy packed wire-bytes figure.
#[deprecated(note = "compose a sgd::host::HostSession over ShardedStore::from_packed instead")]
pub fn train_packed_host(
    ds: &Dataset,
    packed: &PackedMatrix,
    epochs: usize,
    batch: usize,
    lr0: f32,
    seed: u64,
) -> HostTrainResult {
    let store = ShardedStore::from_packed(packed, 1);
    let s = HostSession::over(ds, &store).schedule(PrecisionSchedule::Fixed(packed.bits));
    let s = s.dequant_oracle().epochs(epochs).batch(batch).lr0(lr0).seed(seed);
    let mut r = s.run().expect("legacy train_packed_host combination").into_host();
    r.sample_bytes_per_epoch = packed.rows as f64 * (packed.bytes() as f64 / packed.rows as f64);
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression for the eval_nb == 0 divide-by-zero: too few rows for
    /// one loss batch must error out instead of reporting NaN loss.
    #[test]
    fn eval_batch_count_rejects_empty_eval() {
        assert!(eval_batch_count(16, 64, 40).is_err());
        assert!(eval_batch_count(0, 64, 1000).is_err());
        assert!(eval_batch_count(16, 0, 1000).is_err());
        assert_eq!(eval_batch_count(16, 64, 64).unwrap(), 1);
        assert_eq!(eval_batch_count(16, 64, 10_000).unwrap(), 16);
        assert_eq!(eval_batch_count(4, 64, 200).unwrap(), 3);
        let msg = format!("{:#}", eval_batch_count(16, 64, 40).unwrap_err());
        assert!(msg.contains("64-row"), "unhelpful error: {msg}");
    }

    /// The artifact path's fixed-shape batches wrap the ragged tail around
    /// to the front of the permutation: all rows covered, shapes constant.
    #[test]
    fn fill_wrapped_batch_covers_all_rows() {
        let order: Vec<usize> = (0..70).rev().collect();
        let b = 32;
        let mut out = vec![0usize; b];
        let mut seen = vec![0u32; 70];
        for bi in 0..70usize.div_ceil(b) {
            fill_wrapped_batch(&order, bi, b, &mut out);
            assert_eq!(out.len(), b);
            for &r in &out {
                seen[r] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c >= 1), "a row was never visited");
        // the wrapped rows are revisited: 3 batches × 32 = 96 slots, 70 rows
        assert_eq!(seen.iter().sum::<u32>(), 96);
        // exact-fit epochs have no duplicates
        let order2: Vec<usize> = (0..64).collect();
        let mut seen2 = vec![0u32; 64];
        for bi in 0..2 {
            fill_wrapped_batch(&order2, bi, b, &mut out);
            for &r in &out {
                seen2[r] += 1;
            }
        }
        assert!(seen2.iter().all(|&c| c == 1));
    }
}

