//! The SGD driver: epoch loop over the quantized sample store, executing
//! AOT-compiled step artifacts on the PJRT runtime.
//!
//! Data is quantized ONCE into a bit-packed store (the paper quantizes
//! "during the first epoch"); each step dequantizes a batch and dispatches
//! one artifact execution. Loss is evaluated per epoch on full-precision
//! data against the true objective.

use anyhow::{bail, Context, Result};

use crate::cheby;
use crate::data::Dataset;
use crate::quant::packing::{DoubleSampleBlock, PackedMatrix};
use crate::quant::{discretized_optimal_levels, ColumnScale};
use crate::rng::Rng;
use crate::runtime::{lit_f32, lit_scalar11, lit_u8, Runtime};
use crate::tensor::Matrix;

use super::modes::{Mode, ModelKind};
use super::refetch::RefetchState;

/// Chebyshev settings shared with the artifacts (aot.py constants).
pub const CHEBY_DEG: usize = 15;
pub const RADIUS: f64 = 8.0;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: ModelKind,
    pub mode: Mode,
    pub epochs: usize,
    pub batch: usize,
    pub lr0: f32,
    pub seed: u64,
    /// Number of 64-row batches used for the per-epoch loss evaluation.
    pub eval_batches: usize,
}

impl TrainConfig {
    pub fn new(model: ModelKind, mode: Mode) -> Self {
        TrainConfig { model, mode, epochs: 20, batch: 64, lr0: 0.05, seed: 42, eval_batches: 16 }
    }
}

#[derive(Clone, Debug)]
pub struct TrainResult {
    pub mode_label: String,
    /// loss_curve[e] = training loss after e epochs (index 0 = initial).
    pub loss_curve: Vec<f64>,
    pub final_loss: f64,
    pub wall_secs: f64,
    /// Sample bytes crossing the memory boundary per epoch (wire format).
    pub sample_bytes_per_epoch: f64,
    /// Fraction of samples refetched at full precision (refetch modes).
    pub refetch_fraction: f64,
    pub diverged: bool,
    pub final_model: Vec<f32>,
}

/// Per-mode quantized representation of the training samples.
enum Store {
    Dense(Matrix),
    Packed(PackedMatrix),
    Double(DoubleSampleBlock),
    /// per-feature variance-optimal grids + two pre-quantized index planes
    /// (OptimalDs; "quantized during the first epoch", §Perf L3-4)
    Levels {
        grids: Vec<Vec<f32>>,
        idx: [Vec<u8>; 2],
    },
}

pub fn train(rt: &Runtime, ds: &Dataset, cfg: &TrainConfig) -> Result<TrainResult> {
    let t0 = std::time::Instant::now();
    let n = ds.n();
    let b = cfg.batch;
    let k = ds.k_train();
    let nb = k / b;
    if nb == 0 {
        bail!("dataset smaller than one batch");
    }
    let mut rng = Rng::new(cfg.seed);
    let scale = ColumnScale::from_data(&ds.train_a);

    // --- resolve artifacts -------------------------------------------------
    let man = &rt.manifest;
    let loss_art = man.find_kind_n(cfg.model.loss_kind(), n)?.name.clone();
    let loss_batch = man.get(&loss_art)?.meta_usize("batch").unwrap_or(64);
    let step_art: String = match (&cfg.mode, &cfg.model) {
        (Mode::Full | Mode::Naive { .. } | Mode::NearestRound { .. }, m) => {
            man.find_kind_n_batch(m.step_kind_fp(), n, b)?.name.clone()
        }
        (Mode::DoubleSample { .. } | Mode::OptimalDs { .. }, m) => {
            let kind = m
                .step_kind_ds()
                .with_context(|| format!("mode {:?} unsupported for {:?}", cfg.mode, m))?;
            man.find_kind_n_batch(kind, n, b)?.name.clone()
        }
        (Mode::DoubleSampleU8 { .. }, ModelKind::Linreg) => {
            man.find_kind_n_batch("linreg_ds_u8_step", n, b)?.name.clone()
        }
        (Mode::EndToEnd { .. } | Mode::ModelQuant { .. } | Mode::GradQuant { .. }, ModelKind::Linreg) => {
            man.find_kind_n_batch("e2e_step", n, b)?.name.clone()
        }
        (Mode::Cheby { .. }, m) if m.is_classification() => {
            man.find_kind_n_batch("cheby_step", n, b)?.name.clone()
        }
        (Mode::PolyDs { .. }, m) if m.is_classification() => {
            man.find_kind_n_batch("poly_ds_step", n, b)?.name.clone()
        }
        (Mode::Refetch { .. }, ModelKind::Svm) => {
            man.find_kind_n_batch("svm_fp_step", n, b)?.name.clone()
        }
        (mode, m) => bail!("mode {mode:?} not supported for model {m:?}"),
    };

    // --- build the quantized store (the "first epoch" quantization) -------
    let store = match cfg.mode {
        // §C / §D: samples stay full precision
        Mode::Full | Mode::ModelQuant { .. } | Mode::GradQuant { .. } => {
            Store::Dense(ds.train_a.clone())
        }
        Mode::NearestRound { bits } => {
            // deterministic nearest rounding of the data, once (§5.4 strawman)
            let s = crate::quant::intervals_for_bits(bits);
            let mut a = ds.train_a.clone();
            for r in 0..a.rows {
                for (c, v) in a.row_mut(r).iter_mut().enumerate() {
                    let m = scale.m[c];
                    if m <= 0.0 {
                        *v = 0.0;
                        continue;
                    }
                    let u = (*v / m).clamp(-1.0, 1.0);
                    let idx = ((u + 1.0) * 0.5 * s as f32).round().min(s as f32);
                    *v = (idx / s as f32 * 2.0 - 1.0) * m;
                }
            }
            Store::Dense(a)
        }
        Mode::Naive { bits } | Mode::Refetch { bits, .. } => {
            Store::Packed(PackedMatrix::quantize(&ds.train_a, &scale, bits, &mut rng))
        }
        Mode::DoubleSample { bits } | Mode::DoubleSampleU8 { bits } | Mode::EndToEnd { bits_s: bits, .. } => {
            Store::Double(DoubleSampleBlock::quantize(&ds.train_a, &scale, bits, 2, &mut rng))
        }
        Mode::Cheby { bits } => {
            Store::Double(DoubleSampleBlock::quantize(&ds.train_a, &scale, bits, 2, &mut rng))
        }
        Mode::PolyDs { bits } => Store::Double(DoubleSampleBlock::quantize(
            &ds.train_a,
            &scale,
            bits,
            CHEBY_DEG + 1,
            &mut rng,
        )),
        Mode::OptimalDs { levels } => {
            // per-feature grids from a column subsample (single data pass)
            let sample_rows = k.min(2000);
            let mut grids = Vec::with_capacity(n);
            let mut col = vec![0.0f32; sample_rows];
            for c in 0..n {
                for (i, v) in col.iter_mut().enumerate() {
                    *v = ds.train_a.get(i * (k / sample_rows).max(1) % k, c);
                }
                grids.push(discretized_optimal_levels(&col, levels, 64));
            }
            // pre-quantize both independent sample planes once
            let mut idx = [vec![0u8; k * n], vec![0u8; k * n]];
            for plane in idx.iter_mut() {
                for (row, orow) in ds.train_a.data.chunks(n).zip(plane.chunks_mut(n)) {
                    for ((&v, o), grid) in row.iter().zip(orow.iter_mut()).zip(&grids) {
                        *o = crate::quant::stochastic::quantize_one_to_level_index(v, grid, &mut rng)
                            as u8;
                    }
                }
            }
            Store::Levels { grids, idx }
        }
    };

    // --- Chebyshev coefficients (classification approximations) -----------
    let (coefs_lit, mono_lit) = if matches!(cfg.mode, Mode::Cheby { .. } | Mode::PolyDs { .. }) {
        let f: Box<dyn Fn(f64) -> f64> = match cfg.model {
            ModelKind::Logistic => Box::new(cheby::logistic_lprime),
            ModelKind::Svm => Box::new(|z| cheby::hinge_lprime_smoothed(z, 0.25)),
            _ => bail!("cheby modes need a classification model"),
        };
        let coefs = cheby::cheb_fit(&*f, RADIUS, CHEBY_DEG);
        let mono = cheby::cheb_to_monomial(&coefs, RADIUS);
        let cf: Vec<f32> = coefs.iter().map(|&c| c as f32).collect();
        let mf: Vec<f32> = mono.iter().map(|&c| c as f32).collect();
        (
            Some(lit_f32(&[CHEBY_DEG + 1, 1], &cf)?),
            Some(lit_f32(&[CHEBY_DEG + 1, 1], &mf)?),
        )
    } else {
        (None, None)
    };

    // --- loss evaluation batches (full precision, fixed) -------------------
    let eval_rows = (cfg.eval_batches * loss_batch).min(k / loss_batch * loss_batch);
    let eval_nb = eval_rows / loss_batch;
    let mut eval_lits = Vec::with_capacity(eval_nb);
    for e in 0..eval_nb {
        let rows: Vec<usize> = (e * loss_batch..(e + 1) * loss_batch).collect();
        let a = ds.train_a.gather_rows(&rows);
        let bv: Vec<f32> = rows.iter().map(|&r| ds.train_b[r]).collect();
        eval_lits.push((lit_f32(&[loss_batch, n], &a.data)?, lit_f32(&[loss_batch, 1], &bv)?));
    }
    let c_reg = if let ModelKind::Lssvm { c } = cfg.model { c } else { 0.0 };
    let eval_loss = |x: &[f32], rt: &Runtime| -> Result<f64> {
        let xl = lit_f32(&[n, 1], x)?;
        let mut acc = 0.0f64;
        for (al, bl) in &eval_lits {
            let args: Vec<xla::Literal> = match cfg.model {
                ModelKind::Lssvm { .. } => vec![
                    xl.clone(),
                    al.clone(),
                    bl.clone(),
                    lit_scalar11(c_reg)?,
                ],
                _ => vec![xl.clone(), al.clone(), bl.clone()],
            };
            acc += rt.exec1_scalar(&loss_art, &args)? as f64;
        }
        Ok(acc / eval_nb as f64)
    };

    // --- refetch state ------------------------------------------------------
    let mut refetch = if let Mode::Refetch { bits, strategy } = cfg.mode {
        Some(RefetchState::new(ds, &scale, bits, strategy, cfg.seed ^ 0x5245_4645_5443_4821)?)
    } else {
        None
    };

    // --- epoch loop ---------------------------------------------------------
    let mut x = vec![0.0f32; n];
    let mut loss_curve = Vec::with_capacity(cfg.epochs + 1);
    loss_curve.push(eval_loss(&x, rt)?);
    let mut order: Vec<usize> = (0..nb * b).collect();
    let mut diverged = false;

    // reusable batch buffers
    let mut a1 = Matrix::zeros(b, n);
    let mut a2 = Matrix::zeros(b, n);
    let mut bv = vec![0.0f32; b];
    let mut idx1 = vec![0u8; b * n];
    let mut idx2 = vec![0u8; b * n];
    let mut aq_poly = vec![0.0f32; (CHEBY_DEG + 1) * b * n];
    let mut rand_buf = vec![0.0f32; n];
    let mut rand_buf2 = vec![0.0f32; n];

    'outer: for epoch in 0..cfg.epochs {
        let lr = super::lr_at_epoch(cfg.lr0, epoch);
        let lr_lit = lit_scalar11(lr)?;
        rng.shuffle(&mut order);
        for bi in 0..nb {
            let rows = &order[bi * b..(bi + 1) * b];
            for (i, &r) in rows.iter().enumerate() {
                bv[i] = ds.train_b[r];
            }
            let xl = lit_f32(&[n, 1], &x)?;
            let bl = lit_f32(&[b, 1], &bv)?;

            let out = match (&store, &cfg.mode) {
                // §C (model-only) / §D (gradient-only) quantization reuse
                // the e2e artifact with full-precision samples (a1 == a2 ==
                // A makes the DS estimator exact) and the *other* quantizer
                // at f32-resolution interval count.
                (Store::Dense(a), Mode::ModelQuant { bits }) | (Store::Dense(a), Mode::GradQuant { bits }) => {
                    gather_into(a, rows, &mut a1);
                    rng.fill_uniform(&mut rand_buf);
                    rng.fill_uniform(&mut rand_buf2);
                    const FP_INTERVALS: f32 = ((1u32 << 23) - 1) as f32;
                    let q = crate::quant::intervals_for_bits(*bits) as f32;
                    let (s_m, s_g) = if matches!(cfg.mode, Mode::ModelQuant { .. }) {
                        (q, FP_INTERVALS)
                    } else {
                        (FP_INTERVALS, q)
                    };
                    let al = lit_f32(&[b, n], &a1.data)?;
                    let args = vec![
                        xl,
                        al.clone(),
                        al,
                        bl,
                        lr_lit.clone(),
                        lit_f32(&[1, n], &rand_buf)?,
                        lit_f32(&[1, n], &rand_buf2)?,
                        lit_scalar11(s_m)?,
                        lit_scalar11(s_g)?,
                    ];
                    rt.exec(&step_art, &args)?
                }
                (Store::Dense(a), _) => {
                    gather_into(a, rows, &mut a1);
                    let al = lit_f32(&[b, n], &a1.data)?;
                    let mut args = vec![xl, al, bl, lr_lit.clone()];
                    if let ModelKind::Lssvm { c } = cfg.model {
                        args.push(lit_scalar11(c)?);
                    }
                    rt.exec(&step_art, &args)?
                }
                (Store::Packed(p), Mode::Naive { .. }) => {
                    for (i, &r) in rows.iter().enumerate() {
                        p.dequantize_row(r, a1.row_mut(i));
                    }
                    let al = lit_f32(&[b, n], &a1.data)?;
                    let mut args = vec![xl, al, bl, lr_lit.clone()];
                    if let ModelKind::Lssvm { c } = cfg.model {
                        args.push(lit_scalar11(c)?);
                    }
                    rt.exec(&step_art, &args)?
                }
                (Store::Packed(p), Mode::Refetch { .. }) => {
                    let rf = refetch.as_mut().unwrap();
                    rf.prepare_batch(rt, p, ds, rows, &x, &mut a1)?;
                    let al = lit_f32(&[b, n], &a1.data)?;
                    rt.exec(&step_art, &[xl, al, bl, lr_lit.clone()])?
                }
                (Store::Double(dsb), Mode::DoubleSampleU8 { bits }) => {
                    for (i, &r) in rows.iter().enumerate() {
                        dsb.indices_row_u8(r, 0, &mut idx1[i * n..(i + 1) * n]);
                        dsb.indices_row_u8(r, 1, &mut idx2[i * n..(i + 1) * n]);
                    }
                    let s = crate::quant::intervals_for_bits(*bits) as f32;
                    let args = vec![
                        xl,
                        lit_u8(&[b, n], &idx1)?,
                        lit_u8(&[b, n], &idx2)?,
                        lit_f32(&[1, n], &scale.m)?,
                        lit_scalar11(s)?,
                        bl,
                        lr_lit.clone(),
                    ];
                    rt.exec(&step_art, &args)?
                }
                (Store::Double(dsb), Mode::EndToEnd { bits_m, bits_g, .. }) => {
                    for (i, &r) in rows.iter().enumerate() {
                        dsb.dequantize_row(r, 0, a1.row_mut(i));
                        dsb.dequantize_row(r, 1, a2.row_mut(i));
                    }
                    rng.fill_uniform(&mut rand_buf);
                    rng.fill_uniform(&mut rand_buf2);
                    let s_m = crate::quant::intervals_for_bits(*bits_m) as f32;
                    let s_g = crate::quant::intervals_for_bits(*bits_g) as f32;
                    let args = vec![
                        xl,
                        lit_f32(&[b, n], &a1.data)?,
                        lit_f32(&[b, n], &a2.data)?,
                        bl,
                        lr_lit.clone(),
                        lit_f32(&[1, n], &rand_buf)?,
                        lit_f32(&[1, n], &rand_buf2)?,
                        lit_scalar11(s_m)?,
                        lit_scalar11(s_g)?,
                    ];
                    rt.exec(&step_art, &args)?
                }
                (Store::Double(dsb), Mode::Cheby { .. }) => {
                    for (i, &r) in rows.iter().enumerate() {
                        dsb.dequantize_row(r, 0, a1.row_mut(i));
                        dsb.dequantize_row(r, 1, a2.row_mut(i));
                    }
                    let args = vec![
                        xl,
                        lit_f32(&[b, n], &a1.data)?,
                        lit_f32(&[b, n], &a2.data)?,
                        bl,
                        lr_lit.clone(),
                        coefs_lit.as_ref().unwrap().clone(),
                    ];
                    rt.exec(&step_art, &args)?
                }
                (Store::Double(dsb), Mode::PolyDs { .. }) => {
                    for j in 0..=CHEBY_DEG {
                        for (i, &r) in rows.iter().enumerate() {
                            let off = j * b * n + i * n;
                            dsb.dequantize_row(r, j, &mut aq_poly[off..off + n]);
                        }
                    }
                    let args = vec![
                        xl,
                        lit_f32(&[CHEBY_DEG + 1, b, n], &aq_poly)?,
                        bl,
                        lr_lit.clone(),
                        mono_lit.as_ref().unwrap().clone(),
                    ];
                    rt.exec(&step_art, &args)?
                }
                (Store::Double(dsb), _) => {
                    // plain double sampling
                    for (i, &r) in rows.iter().enumerate() {
                        dsb.dequantize_row(r, 0, a1.row_mut(i));
                        dsb.dequantize_row(r, 1, a2.row_mut(i));
                    }
                    let mut args = vec![
                        xl,
                        lit_f32(&[b, n], &a1.data)?,
                        lit_f32(&[b, n], &a2.data)?,
                        bl,
                        lr_lit.clone(),
                    ];
                    if let ModelKind::Lssvm { c } = cfg.model {
                        args.push(lit_scalar11(c)?);
                    }
                    rt.exec(&step_art, &args)?
                }
                (Store::Packed(_), mode) => {
                    bail!("packed store with incompatible mode {mode:?}")
                }
                (Store::Levels { grids, idx }, _) => {
                    // variance-optimal grids: gather pre-quantized indices
                    // and dequantize via grid lookup (§Perf L3-4)
                    for (i, &r) in rows.iter().enumerate() {
                        let (p0, p1) = (&idx[0][r * n..(r + 1) * n], &idx[1][r * n..(r + 1) * n]);
                        for c in 0..n {
                            a1.set(i, c, grids[c][p0[c] as usize]);
                            a2.set(i, c, grids[c][p1[c] as usize]);
                        }
                    }
                    let mut args = vec![
                        xl,
                        lit_f32(&[b, n], &a1.data)?,
                        lit_f32(&[b, n], &a2.data)?,
                        bl,
                        lr_lit.clone(),
                    ];
                    if let ModelKind::Lssvm { c } = cfg.model {
                        args.push(lit_scalar11(c)?);
                    }
                    rt.exec(&step_art, &args)?
                }
            };
            let newx = crate::runtime::to_f32_vec(&out[0])?;
            x.copy_from_slice(&newx);
            // radius projection for polynomial-approximation modes
            if matches!(cfg.mode, Mode::Cheby { .. } | Mode::PolyDs { .. }) {
                let norm = crate::tensor::norm2(&x);
                if norm > RADIUS as f32 {
                    let f = RADIUS as f32 / norm;
                    for v in x.iter_mut() {
                        *v *= f;
                    }
                }
            }
        }
        let loss = eval_loss(&x, rt)?;
        loss_curve.push(loss);
        if !loss.is_finite() || loss > 1e12 {
            diverged = true;
            break 'outer;
        }
    }

    // --- bandwidth accounting ------------------------------------------------
    let wire_bits = cfg.mode.wire_bits_per_value(CHEBY_DEG);
    let mut sample_bytes = (nb * b * n) as f64 * wire_bits / 8.0;
    let refetch_fraction = refetch
        .as_ref()
        .map(|r| r.fraction())
        .unwrap_or(0.0);
    if let Some(rf) = &refetch {
        sample_bytes += rf.extra_bytes_per_epoch(nb * b, n);
    }

    Ok(TrainResult {
        mode_label: cfg.mode.label(),
        final_loss: *loss_curve.last().unwrap(),
        loss_curve,
        wall_secs: t0.elapsed().as_secs_f64(),
        sample_bytes_per_epoch: sample_bytes,
        refetch_fraction,
        diverged,
        final_model: x,
    })
}

fn gather_into(a: &Matrix, rows: &[usize], out: &mut Matrix) {
    for (i, &r) in rows.iter().enumerate() {
        out.row_mut(i).copy_from_slice(a.row(r));
    }
}
