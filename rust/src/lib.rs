//! # ZipML — end-to-end low-precision training with provable guarantees
//!
//! Rust + JAX + Pallas reproduction of Zhang et al. (2016), "The ZipML
//! Framework for Training Models with End-to-End Low Precision".
//!
//! Three layers (see DESIGN.md):
//! * **L3 (this crate)** — the coordinator: quantized sample store
//!   ([`quant::packing`] and the bit-weaved, sharded, any-precision
//!   [`store`]), variance-optimal level placement, SGD driver, refetch
//!   heuristics, FPGA bandwidth simulator, experiment harness.
//! * **L2 (python/compile/model.py)** — JAX step functions, AOT-lowered to
//!   HLO text once at build time (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas kernels (stochastic
//!   quantization, double-sampling gradient, Clenshaw) inside the L2 HLO.
//!
//! Python never runs at training time: [`runtime::Runtime`] executes the
//! artifacts on the PJRT CPU client from the Rust hot loop.

// Public docs deliberately link private kernels (`masked_sum`,
// `select_add_word`, …) to explain the fused hot path; rustdoc renders
// those as plain code. Broken links still fail the ci.sh doc gate.
#![allow(rustdoc::private_intra_doc_links)]
// The explicit-SIMD kernel twins (store/kernel/simd.rs) use std::simd,
// still nightly-only; the attribute is inert on the stable default
// build, where the scalar tier is the only one compiled (DESIGN.md §12).
#![cfg_attr(feature = "simd", feature(portable_simd))]
// The crate carries no unsafe at all (the former raw-parts casts in
// runtime/literal.rs are now safe to_le_bytes copies). zipml-lint's
// `unsafe-code` rule enforces the same at the token level, with an
// allowlist that starts empty (rust/lint/allowlist_unsafe.txt).
#![forbid(unsafe_code)]

pub mod bench;
pub mod cheby;
pub mod coordinator;
pub mod data;
pub mod fpga;
pub mod proptest;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod sgd;
pub mod store;
pub mod sync;
pub mod telemetry;
pub mod tensor;
