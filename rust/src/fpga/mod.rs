//! FPGA substrate (Fig 5, 13, 14; Kara et al. FCCM'17) — simulated.
//!
//! The paper's FPGA result is a *memory-bandwidth* argument: the SGD
//! pipeline processes one full cache line per cycle, so epoch time is
//! bounded by `bytes(precision) / bandwidth` until the pipeline becomes
//! compute-bound (which happens only for Q1, whose pipeline is half-width).
//! We reproduce that mechanism with a cycle-accurate analytic model of the
//! published pipelines, and pair it with a real multi-threaded Hogwild!
//! baseline (`hogwild`) to regenerate Fig 5's loss-vs-time curves.

pub mod hogwild;
pub mod pipeline;

#[allow(deprecated)] // legacy entry point stays importable during migration
pub use hogwild::hogwild_train;
pub use hogwild::{HogwildConfig, HogwildResult};
pub use pipeline::{epoch_seconds, PipelineSpec, Precision, FPGA_CLOCK_HZ, MEM_BANDWIDTH_BYTES};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantized_epochs_faster() {
        let k = 10_000;
        let n = 100;
        let t32 = epoch_seconds(Precision::Float, k, n);
        let tq4 = epoch_seconds(Precision::Q(4), k, n);
        let speedup = t32 / tq4;
        // Fig 5: 6-7x; our model gives 32-bit/4-bit ≈ 8x at pure
        // bandwidth-bound operation, minus latency overheads
        assert!(speedup > 4.0 && speedup < 9.0, "speedup {speedup}");
    }
}
