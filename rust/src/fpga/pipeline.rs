//! Cycle model of the FCCM'17 SGD pipelines (paper Fig 13/14).
//!
//! Published parameters:
//! * float  — latency 36 cycles, data width 64 B, rate 64 B/cycle
//! * Q2/4/8 — latency log₂(K)+5 cycles, width 64 B, rate 64 B/cycle
//! * Q1     — latency 12 cycles, width 32 B, rate 32 B/cycle (the pipeline
//!   does not scale out at 1 bit: Q1 is *compute-bound*, Fig 14b)
//!
//! Epoch time = max(memory time, compute time) + drain latency, where
//! memory time = bytes / DRAM bandwidth and compute time = beats / clock.

use crate::store::ShardedStore;

/// Memory bandwidth of the simulated platform (bytes/s). The FCCM target
/// (Intel HARP-like) sustains ~15 GB/s to the accelerator.
pub const MEM_BANDWIDTH_BYTES: f64 = 15.0e9;
/// Accelerator clock (Hz).
pub const FPGA_CLOCK_HZ: f64 = 200.0e6;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Float,
    /// Qb with b ∈ {1, 2, 4, 8}
    Q(u32),
}

impl Precision {
    pub fn bits(&self) -> u32 {
        match self {
            Precision::Float => 32,
            Precision::Q(b) => *b,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Precision::Float => "float".into(),
            Precision::Q(b) => format!("Q{b}"),
        }
    }
}

/// The pipeline spec from Fig 13/14.
#[derive(Clone, Copy, Debug)]
pub struct PipelineSpec {
    pub latency_cycles: f64,
    pub width_bytes_per_cycle: f64,
}

impl PipelineSpec {
    pub fn for_precision(p: Precision) -> Self {
        // K in Fig 14a is the dot-product reduction fan-in ≈ values/line
        let k = (512.0 / p.bits() as f64).max(2.0);
        match p {
            Precision::Float => {
                PipelineSpec { latency_cycles: 36.0, width_bytes_per_cycle: 64.0 }
            }
            Precision::Q(1) => PipelineSpec { latency_cycles: 12.0, width_bytes_per_cycle: 32.0 },
            Precision::Q(_) => {
                PipelineSpec { latency_cycles: k.log2() + 5.0, width_bytes_per_cycle: 64.0 }
            }
        }
    }
}

/// Bytes per epoch for K samples × n features at this precision
/// (+1 full-precision label per sample). Idealized value-packed layout;
/// prefer the store-derived accounting ([`store_epoch_bytes`]) when a
/// [`ShardedStore`] exists — it reflects the bytes actually touched.
pub fn epoch_bytes(p: Precision, k_samples: usize, n_features: usize) -> f64 {
    let sample_bits = (n_features as u64 * p.bits() as u64) as f64;
    k_samples as f64 * (sample_bits / 8.0 + 4.0)
}

/// Simulated wall-clock seconds for one epoch moving `bytes` of sample
/// data through the precision-`p` pipeline. The single source of truth for
/// the cycle model; byte counts come from either the idealized layout
/// ([`epoch_seconds`]) or the store's exact accounting
/// ([`store_epoch_seconds`]).
pub fn epoch_seconds_from_bytes(p: Precision, bytes: f64, k_samples: usize) -> f64 {
    let spec = PipelineSpec::for_precision(p);
    let mem_time = bytes / MEM_BANDWIDTH_BYTES;
    // the pipeline consumes width_bytes_per_cycle of *quantized* data/beat
    let compute_time = bytes / spec.width_bytes_per_cycle / FPGA_CLOCK_HZ;
    // per-sample drain latency (dependent updates serialize the drain)
    let drain = spec.latency_cycles / FPGA_CLOCK_HZ * k_samples as f64 * 0.05;
    mem_time.max(compute_time) + drain
}

/// Simulated wall-clock seconds for one SGD epoch (idealized layout).
pub fn epoch_seconds(p: Precision, k_samples: usize, n_features: usize) -> f64 {
    epoch_seconds_from_bytes(p, epoch_bytes(p, k_samples, n_features), k_samples)
}

/// Bytes per epoch derived from a weaved store's layout: the p bit planes
/// a precision-`p` reader touches per row, plus one f32 label per sample —
/// no recomputation from `Precision`, the store *is* the accounting.
pub fn store_epoch_bytes(store: &ShardedStore, p: u32) -> f64 {
    store.epoch_bytes(p) + 4.0 * store.rows() as f64
}

/// Epoch seconds for a precision-`p` pass over a weaved store.
pub fn store_epoch_seconds(store: &ShardedStore, p: u32) -> f64 {
    epoch_seconds_from_bytes(Precision::Q(p), store_epoch_bytes(store, p), store.rows())
}

/// Loss-vs-time series: pair per-epoch losses with the cumulative simulated
/// epoch times — Fig 5's axes.
pub fn loss_vs_time(p: Precision, k: usize, n: usize, losses: &[f64]) -> Vec<(f64, f64)> {
    let dt = epoch_seconds(p, k, n);
    losses
        .iter()
        .enumerate()
        .map(|(e, &l)| (e as f64 * dt, l))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_float_params() {
        let s = PipelineSpec::for_precision(Precision::Float);
        assert_eq!(s.latency_cycles, 36.0);
        assert_eq!(s.width_bytes_per_cycle, 64.0);
    }

    #[test]
    fn fig14_q_latency() {
        // Q8: K = 512/8 = 64 values/line → latency log2(64)+5 = 11
        let s = PipelineSpec::for_precision(Precision::Q(8));
        assert!((s.latency_cycles - 11.0).abs() < 1e-9);
        // Q1 is half-width
        let q1 = PipelineSpec::for_precision(Precision::Q(1));
        assert_eq!(q1.width_bytes_per_cycle, 32.0);
    }

    #[test]
    fn bytes_scale_with_bits() {
        let b32 = epoch_bytes(Precision::Float, 1000, 100);
        let b4 = epoch_bytes(Precision::Q(4), 1000, 100);
        assert!((b32 / b4 - 32.0 / 4.0).abs() < 0.7); // ≈8x minus label overhead
    }

    #[test]
    fn monotone_in_precision() {
        let mut prev = f64::INFINITY;
        for p in [Precision::Float, Precision::Q(8), Precision::Q(4), Precision::Q(2)] {
            let t = epoch_seconds(p, 50_000, 90);
            assert!(t < prev, "{:?} not faster", p);
            prev = t;
        }
    }

    #[test]
    fn q1_compute_bound() {
        // At 1 bit the half-width pipeline, not memory, limits throughput:
        // check compute time exceeds memory time.
        let bytes = epoch_bytes(Precision::Q(1), 100_000, 1000);
        let spec = PipelineSpec::for_precision(Precision::Q(1));
        let mem = bytes / MEM_BANDWIDTH_BYTES;
        let compute = bytes / spec.width_bytes_per_cycle / FPGA_CLOCK_HZ;
        assert!(compute > mem, "Q1 should be compute-bound: {compute} vs {mem}");
    }

    #[test]
    fn loss_time_series_monotone_time() {
        let ts = loss_vs_time(Precision::Q(4), 1000, 100, &[1.0, 0.5, 0.25]);
        assert_eq!(ts.len(), 3);
        assert!(ts.windows(2).all(|w| w[1].0 > w[0].0));
    }

    #[test]
    fn from_bytes_agrees_with_idealized_path() {
        for p in [Precision::Float, Precision::Q(8), Precision::Q(2)] {
            let direct = epoch_seconds(p, 10_000, 100);
            let via = epoch_seconds_from_bytes(p, epoch_bytes(p, 10_000, 100), 10_000);
            assert!((direct - via).abs() < 1e-15, "{p:?}");
        }
    }

    /// Fig 5 acceptance: the store's own accounting reproduces the
    /// bytes-per-epoch ordering Q1 < Q2 < Q4 < Q8 < f32, hence the
    /// epoch-time/speedup ordering of the pipeline model.
    #[test]
    fn store_accounting_reproduces_fig5_ordering() {
        use crate::quant::ColumnScale;
        use crate::rng::Rng;
        use crate::tensor::Matrix;
        let (k, n) = (512usize, 100usize);
        let mut rng = Rng::new(3);
        let a = Matrix::from_vec(k, n, (0..k * n).map(|_| rng.normal()).collect());
        let scale = ColumnScale::from_data(&a);
        let store = ShardedStore::ingest(&a, &scale, 8, 7, 4, 1);
        let f32_bytes = epoch_bytes(Precision::Float, k, n);
        let mut prev_bytes = 0.0;
        for p in [1u32, 2, 4, 8] {
            let bytes = store_epoch_bytes(&store, p);
            assert!(bytes > prev_bytes, "Q{p} bytes not increasing");
            assert!(bytes < f32_bytes, "Q{p}: {bytes} !< f32 {f32_bytes}");
            prev_bytes = bytes;
        }
        // epoch-time ordering holds on the full-width pipelines (Q1 is
        // compute-bound on the half-width pipeline — Fig 14b — so it is
        // excluded, as in `monotone_in_precision`)
        let mut prev_secs = 0.0;
        for p in [2u32, 4, 8] {
            let secs = store_epoch_seconds(&store, p);
            assert!(secs > prev_secs, "Q{p} secs not increasing");
            prev_secs = secs;
        }
        // quantized epochs beat the float epoch in the cycle model too
        let t32 = epoch_seconds(Precision::Float, k, n);
        assert!(store_epoch_seconds(&store, 8) < t32);
    }
}
