//! Hogwild! CPU baseline (Fig 5's third contender): genuinely lock-free
//! multi-threaded SGD over a shared model stored as `AtomicU32`-encoded
//! f32s, racing updates without synchronization (De Sa et al., 2015).
//!
//! Used both as a wall-clock baseline and as a substrate correctness test
//! (convergence under benign races on well-conditioned problems).

use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::data::Dataset;
use crate::rng::Rng;
use crate::store::{kernel, MinibatchIter, ShardedStore, StepKernel, WeavedMatrix};

#[derive(Clone, Debug)]
pub struct HogwildConfig {
    pub threads: usize,
    pub epochs: usize,
    pub lr0: f32,
    pub seed: u64,
}

impl Default for HogwildConfig {
    fn default() -> Self {
        HogwildConfig { threads: 8, epochs: 10, lr0: 0.05, seed: 42 }
    }
}

#[derive(Clone, Debug)]
pub struct HogwildResult {
    pub loss_curve: Vec<f64>,
    pub wall_secs: f64,
    pub final_model: Vec<f32>,
    pub updates: usize,
}

#[inline]
fn load_f32(a: &AtomicU32) -> f32 {
    f32::from_bits(a.load(Ordering::Relaxed))
}

#[inline]
fn add_f32(a: &AtomicU32, delta: f32) {
    // racy read-modify-write — deliberately NOT a CAS loop: Hogwild!'s
    // whole point is that unsynchronized updates still converge.
    let cur = f32::from_bits(a.load(Ordering::Relaxed));
    a.store((cur + delta).to_bits(), Ordering::Relaxed);
}

/// Least-squares Hogwild! SGD (one sample per update, per thread).
pub fn hogwild_train(ds: &Dataset, cfg: &HogwildConfig) -> HogwildResult {
    let t0 = std::time::Instant::now();
    let n = ds.n();
    let k = ds.k_train();
    let x: Arc<Vec<AtomicU32>> = Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
    let updates = Arc::new(AtomicUsize::new(0));
    let mut loss_curve = Vec::with_capacity(cfg.epochs + 1);
    let snapshot = |x: &[AtomicU32]| -> Vec<f32> { x.iter().map(load_f32).collect() };
    loss_curve.push(ds.train_mse(&snapshot(&x)));

    for epoch in 0..cfg.epochs {
        let lr = cfg.lr0 / (epoch as f32 + 1.0);
        std::thread::scope(|scope| {
            for t in 0..cfg.threads {
                let x = Arc::clone(&x);
                let updates = Arc::clone(&updates);
                let seed = cfg.seed ^ ((epoch as u64) << 32) ^ t as u64;
                scope.spawn(move || {
                    let mut rng = crate::rng::Rng::new(seed);
                    let per_thread = k / cfg.threads;
                    let mut local = vec![0.0f32; n];
                    for _ in 0..per_thread {
                        let r = rng.below(k);
                        let row = ds.train_a.row(r);
                        for (l, xa) in local.iter_mut().zip(x.iter()) {
                            *l = load_f32(xa);
                        }
                        let err = crate::tensor::dot(row, &local) - ds.train_b[r];
                        let g = lr * err;
                        for (xa, &a) in x.iter().zip(row) {
                            if a != 0.0 {
                                add_f32(xa, -g * a);
                            }
                        }
                        updates.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        loss_curve.push(ds.train_mse(&snapshot(&x)));
    }

    HogwildResult {
        final_model: snapshot(&x),
        loss_curve,
        wall_secs: t0.elapsed().as_secs_f64(),
        updates: updates.load(Ordering::Relaxed),
    }
}

/// Shared skeleton of the weaved-store Hogwild! paths: per epoch, every
/// worker walks its strided row partition ([`MinibatchIter::strided`] at
/// batch 1, so the (row, worker) assignment is reproducible), takes a racy
/// model snapshot, asks its visitor for the row's update coefficient and
/// plane-part delta, then publishes `delta − coef·m[c]` as ONE racy add
/// per live column (re-zeroing the scratch) — the pre-fusion contention
/// profile. `make_visitor` is called once per worker thread, so each
/// visitor owns its per-step kernel state ([`StepKernel`],
/// [`kernel::QuantStepKernel`], …) without sharing across racy threads;
/// the visitor receives (shard, local row, model snapshot, target, lr,
/// rng, delta scratch) and refreshes its kernel from the snapshot.
/// `bytes_per_visit` is counted once per row visit; the RNG is a
/// per-(epoch, worker) stream derived via [`crate::rng::Rng::new_stream`],
/// so stochastic variants never share randomness across racy threads
/// (deterministic variants ignore it).
fn hogwild_store_run<V>(
    ds: &Dataset,
    store: &ShardedStore,
    cfg: &HogwildConfig,
    bytes_per_visit: usize,
    make_visitor: impl Fn() -> V + Sync,
) -> HogwildResult
where
    V: FnMut(&WeavedMatrix, usize, &[f32], f32, f32, &mut Rng, &mut [f32]) -> f32,
{
    assert_eq!(store.rows(), ds.k_train(), "store/dataset row mismatch");
    let t0 = std::time::Instant::now();
    let n = store.cols();
    let k = store.rows();
    let x: Arc<Vec<AtomicU32>> = Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
    let updates = Arc::new(AtomicUsize::new(0));
    let mut loss_curve = Vec::with_capacity(cfg.epochs + 1);
    let snapshot = |x: &[AtomicU32]| -> Vec<f32> { x.iter().map(load_f32).collect() };
    loss_curve.push(ds.train_mse(&snapshot(&x)));

    // per-sample updates: batch 1 through the strided iterator
    const BATCH: usize = 1;
    for epoch in 0..cfg.epochs {
        let lr = cfg.lr0 / (epoch as f32 + 1.0);
        let epoch_seed = cfg.seed ^ ((epoch as u64) << 32);
        std::thread::scope(|scope| {
            let make_visitor = &make_visitor;
            for t in 0..cfg.threads {
                let x = Arc::clone(&x);
                let updates = Arc::clone(&updates);
                scope.spawn(move || {
                    let mut visit = make_visitor();
                    let mut it = MinibatchIter::strided(k, BATCH, epoch_seed, t, cfg.threads);
                    let mut rng =
                        Rng::new_stream(cfg.seed, (epoch as u64) * cfg.threads as u64 + t as u64);
                    let mut local = vec![0.0f32; n];
                    let mut delta = vec![0.0f32; n];
                    let m = &store.scale().m;
                    while let Some(batch) = it.next_batch() {
                        for &r in batch {
                            let r = r as usize;
                            let (shard, sr) = store.locate_row(r);
                            // racy model snapshot → per-update kernel state
                            for (l, xa) in local.iter_mut().zip(x.iter()) {
                                *l = load_f32(xa);
                            }
                            store.note_bytes_read(bytes_per_visit);
                            let coef =
                                visit(shard, sr, &local, ds.train_b[r], lr, &mut rng, &mut delta);
                            for ((xa, d), &mc) in x.iter().zip(delta.iter_mut()).zip(m.iter()) {
                                let upd = *d - coef * mc;
                                *d = 0.0;
                                if upd != 0.0 {
                                    add_f32(xa, upd);
                                }
                            }
                            updates.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        loss_curve.push(ds.train_mse(&snapshot(&x)));
    }

    HogwildResult {
        final_model: snapshot(&x),
        loss_curve,
        wall_secs: t0.elapsed().as_secs_f64(),
        updates: updates.load(Ordering::Relaxed),
    }
}

/// Hogwild! over the weaved sample store: every worker computes its dot
/// products and model updates **in the weaved domain** — the fused kernels
/// ([`crate::store::kernel`]) touch only the p requested planes (the dot
/// side on the lane-parallel masked sum), so no worker ever materializes
/// an f32 row. Shard reads stay lock-free (the store only touches a
/// relaxed byte counter) and updates race on the shared model exactly like
/// [`hogwild_train`]. Bytes are counted once per row visit (the update
/// pass reuses the planes the dot just fetched), identical to the
/// row-read accounting.
pub fn hogwild_train_store(
    ds: &Dataset,
    store: &ShardedStore,
    p: u32,
    cfg: &HogwildConfig,
) -> HogwildResult {
    let n = store.cols();
    let m = &store.scale().m;
    hogwild_store_run(ds, store, cfg, store.bytes_per_row(p), || {
        let mut kern = StepKernel::new(n);
        move |shard: &WeavedMatrix,
              sr: usize,
              local: &[f32],
              target: f32,
              lr: f32,
              _rng: &mut Rng,
              delta: &mut [f32]| {
            kern.refresh(m, local);
            let err = kernel::dot_row(shard, sr, p, &kern) - target;
            let coef = -lr * err;
            kernel::axpy_row_planes(shard, sr, p, coef, delta);
            coef
        }
    })
}

/// Hogwild! over the weaved store with **double-sampled** reads: every
/// worker takes two independent unbiased stochastic p-plane draws per row
/// visit — draw one for the fused dot, draw two for the racy model update
/// — implementing the §2.2 estimator concurrently from the single stored
/// copy (DESIGN.md §5). Each worker owns a carry-randomness stream derived
/// from (seed, epoch, worker) via [`crate::rng::Rng::new_stream`], so the
/// *set* of draws is reproducible even though update interleaving is racy.
/// Both fetches are counted: 2·p plane spans per row visit, exactly 2× the
/// truncating [`hogwild_train_store`].
pub fn hogwild_train_store_ds(
    ds: &Dataset,
    store: &ShardedStore,
    p: u32,
    cfg: &HogwildConfig,
) -> HogwildResult {
    let n = store.cols();
    let m = &store.scale().m;
    // two independent draws: both fetches counted
    hogwild_store_run(ds, store, cfg, 2 * store.bytes_per_row(p), || {
        let mut kern = StepKernel::new(n);
        move |shard: &WeavedMatrix,
              sr: usize,
              local: &[f32],
              target: f32,
              lr: f32,
              rng: &mut Rng,
              delta: &mut [f32]| {
            kern.refresh(m, local);
            let err = kernel::dot_row_ds(shard, sr, p, &kern, rng) - target;
            let coef = -lr * err;
            // draw two accumulates the plane part; the skeleton's publish
            // pass folds the affine term and issues the racy adds
            kernel::axpy_row_planes_ds(shard, sr, p, coef, rng, delta);
            coef
        }
    })
}

/// Hogwild! on the **popcount fast path** (DESIGN.md §8): every worker
/// re-rounds its snapshot's `g = m⊙x` onto a q-bit sign/magnitude grid
/// per visit (one [`kernel::QuantStepKernel::refresh`] draw from the
/// worker's own stream) and computes the fused dot by integer AND+POPCNT
/// ([`kernel::dot_row_q`]); the racy update side stays the exact bit-walk
/// axpy. The rounding is unbiased, so every visit's expected update is the
/// truncating visit's. Byte accounting matches [`hogwild_train_store`]
/// exactly — the ĝ planes never cross the memory boundary as sample
/// traffic.
pub fn hogwild_train_store_q(
    ds: &Dataset,
    store: &ShardedStore,
    p: u32,
    step_bits: u32,
    cfg: &HogwildConfig,
) -> HogwildResult {
    let n = store.cols();
    let m = &store.scale().m;
    hogwild_store_run(ds, store, cfg, store.bytes_per_row(p), || {
        let mut qk = kernel::QuantStepKernel::new(n, step_bits);
        move |shard: &WeavedMatrix,
              sr: usize,
              local: &[f32],
              target: f32,
              lr: f32,
              rng: &mut Rng,
              delta: &mut [f32]| {
            qk.refresh(m, local, rng);
            let err = kernel::dot_row_q(shard, sr, p, &qk) - target;
            let coef = -lr * err;
            kernel::axpy_row_planes(shard, sr, p, coef, delta);
            coef
        }
    })
}

/// Simulated epoch time for the 10-core Hogwild baseline of Fig 5: CPU
/// reads full-precision samples from DRAM; per-core effective bandwidth is
/// shared. Model mirrors `fpga::pipeline::epoch_seconds` assumptions.
pub fn hogwild_epoch_seconds(k_samples: usize, n_features: usize, threads: usize) -> f64 {
    let bytes = k_samples as f64 * (n_features as f64 * 4.0 + 4.0);
    let dram = bytes / crate::fpga::MEM_BANDWIDTH_BYTES;
    // compute: ~1 FMA/cycle/core at 2.5 GHz with imperfect scaling
    let flops = 2.0 * k_samples as f64 * n_features as f64;
    let compute = flops / (2.5e9 * threads as f64 * 0.7);
    dram.max(compute)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::make_regression;

    #[test]
    fn hogwild_converges_multithreaded() {
        let ds = make_regression("hw", 4000, 100, 20, 3);
        let r = hogwild_train(&ds, &HogwildConfig { threads: 4, epochs: 8, lr0: 0.02, seed: 1 });
        let first = r.loss_curve[0];
        let last = *r.loss_curve.last().unwrap();
        assert!(last < 0.2 * first, "no convergence: {first} -> {last}");
        assert!(r.updates >= 4000 * 8 / 4 * 3);
    }

    #[test]
    fn single_thread_matches_sequential_sgd_quality() {
        let ds = make_regression("hw1", 2000, 100, 10, 5);
        // per-sample SGD stability needs lr < 2/max‖a‖² (~0.02 here)
        let r = hogwild_train(&ds, &HogwildConfig { threads: 1, epochs: 10, lr0: 0.02, seed: 2 });
        assert!(*r.loss_curve.last().unwrap() < 0.1 * r.loss_curve[0]);
    }

    #[test]
    fn epoch_seconds_scale_with_threads() {
        let t1 = hogwild_epoch_seconds(100_000, 1000, 1);
        let t10 = hogwild_epoch_seconds(100_000, 1000, 10);
        assert!(t10 <= t1);
    }

    /// Multi-threaded shard readers converge on quantized data and the
    /// store counts every concurrent read exactly.
    #[test]
    fn hogwild_over_weaved_store_converges() {
        use crate::quant::ColumnScale;
        let ds = make_regression("hw_store", 4000, 100, 20, 3);
        let scale = ColumnScale::from_data(&ds.train_a);
        let store = crate::store::ShardedStore::ingest(&ds.train_a, &scale, 8, 11, 8, 0);
        let cfg = HogwildConfig { threads: 4, epochs: 8, lr0: 0.02, seed: 1 };
        let r = hogwild_train_store(&ds, &store, 8, &cfg);
        let first = r.loss_curve[0];
        let last = *r.loss_curve.last().unwrap();
        assert!(last < 0.3 * first, "no convergence: {first} -> {last}");
        // every (epoch × row) read was counted, no more, no less
        assert_eq!(
            store.bytes_read(),
            (8 * 4000 * store.bytes_per_row(8)) as u64
        );
        // coarse reads move fewer bytes for the same update count
        store.reset_bytes_read();
        let r2 = hogwild_train_store(&ds, &store, 2, &cfg);
        assert_eq!(r2.updates, r.updates);
        assert_eq!(
            store.bytes_read(),
            (8 * 4000 * store.bytes_per_row(2)) as u64
        );
    }

    /// Double-sampled Hogwild!: racy workers draw two unbiased stochastic
    /// samples per visit, converge at a low read precision, and the store
    /// counts exactly 2× the truncating path's bytes.
    #[test]
    fn hogwild_ds_over_weaved_store_converges_and_counts_double() {
        use crate::quant::ColumnScale;
        let ds = make_regression("hw_ds", 4000, 100, 20, 3);
        let scale = ColumnScale::from_data(&ds.train_a);
        let store = crate::store::ShardedStore::ingest(&ds.train_a, &scale, 8, 11, 8, 0);
        let cfg = HogwildConfig { threads: 4, epochs: 8, lr0: 0.02, seed: 1 };
        let r = hogwild_train_store_ds(&ds, &store, 4, &cfg);
        let first = r.loss_curve[0];
        let last = *r.loss_curve.last().unwrap();
        assert!(last < 0.3 * first, "no convergence: {first} -> {last}");
        assert_eq!(r.updates, 8 * 4000);
        // both draws of every (epoch × row) visit were counted
        assert_eq!(
            store.bytes_read(),
            (8 * 4000 * 2 * store.bytes_per_row(4)) as u64
        );
    }

    /// Popcount-path Hogwild!: racy workers re-round g per visit from
    /// their own streams, converge at a generous q, and the store counts
    /// exactly the truncating path's bytes (ĝ planes are not traffic).
    #[test]
    fn hogwild_popcount_over_weaved_store_converges_same_bytes() {
        use crate::quant::ColumnScale;
        let ds = make_regression("hw_q", 4000, 100, 20, 3);
        let scale = ColumnScale::from_data(&ds.train_a);
        let store = crate::store::ShardedStore::ingest(&ds.train_a, &scale, 8, 11, 8, 0);
        let cfg = HogwildConfig { threads: 4, epochs: 8, lr0: 0.02, seed: 1 };
        let r = hogwild_train_store_q(&ds, &store, 8, 8, &cfg);
        let first = r.loss_curve[0];
        let last = *r.loss_curve.last().unwrap();
        assert!(last < 0.3 * first, "no convergence: {first} -> {last}");
        assert_eq!(r.updates, 8 * 4000);
        assert_eq!(store.bytes_read(), (8 * 4000 * store.bytes_per_row(8)) as u64);
    }
}
