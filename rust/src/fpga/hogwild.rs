//! Hogwild! CPU baseline (Fig 5's third contender): genuinely lock-free
//! multi-threaded SGD over a shared model of [`crate::sync::RacyF32Cell`]
//! columns, racing updates without synchronization (De Sa et al., 2015).
//!
//! The engine itself lives in [`crate::sgd::host`] as the session's
//! `Execution::Hogwild` axis — any [`crate::sgd::GlmLoss`] × any read
//! strategy (dense f32, truncating, double-sampled, popcount) runs
//! through one racy-update skeleton with per-worker kernel state and
//! per-(epoch, worker) RNG streams. The four historical free functions
//! below survive as deprecated ≤5-line shims over
//! [`HostSession`], plus the analytic
//! [`hogwild_epoch_seconds`] wall-clock model Fig 5 trades against.

use crate::data::Dataset;
use crate::sgd::host::{Execution, HostSession, ReadStrategy};
use crate::store::{PrecisionSchedule, ShardedStore};

#[derive(Clone, Debug)]
pub struct HogwildConfig {
    pub threads: usize,
    pub epochs: usize,
    pub lr0: f32,
    pub seed: u64,
}

impl Default for HogwildConfig {
    fn default() -> Self {
        HogwildConfig { threads: 8, epochs: 10, lr0: 0.05, seed: 42 }
    }
}

#[derive(Clone, Debug)]
pub struct HogwildResult {
    pub loss_curve: Vec<f64>,
    pub wall_secs: f64,
    pub final_model: Vec<f32>,
    pub updates: usize,
}

/// Least-squares Hogwild! SGD over full-precision f32 rows (one sample
/// per update, per thread). Shim over [`HostSession::dense`] with
/// hogwild execution: each epoch's rows are partitioned across workers
/// by the strided minibatch iterator. The historical implementation
/// sampled rows with replacement, `threads·⌊k/threads⌋` draws per
/// epoch; the partition visits every row exactly once — exactly `k`
/// updates per epoch (up to `threads − 1` more than before when
/// `threads ∤ k`), with reproducible visit sets.
#[deprecated(note = "compose a sgd::host::HostSession (dense + Execution::Hogwild) instead")]
pub fn hogwild_train(ds: &Dataset, cfg: &HogwildConfig) -> HogwildResult {
    let s = HostSession::dense(ds).execution(Execution::Hogwild { threads: cfg.threads });
    let s = s.epochs(cfg.epochs).lr0(cfg.lr0).seed(cfg.seed);
    s.run().expect("legacy hogwild_train combination").into_hogwild()
}

/// Hogwild! over the weaved sample store on the fused truncating kernels
/// (no worker ever materializes an f32 row). Shim over [`HostSession`].
#[deprecated(note = "compose a sgd::host::HostSession (Truncate + Execution::Hogwild) instead")]
pub fn hogwild_train_store(
    ds: &Dataset,
    store: &ShardedStore,
    p: u32,
    cfg: &HogwildConfig,
) -> HogwildResult {
    let s = HostSession::over(ds, store).schedule(PrecisionSchedule::Fixed(p));
    let s = s.execution(Execution::Hogwild { threads: cfg.threads });
    let s = s.epochs(cfg.epochs).lr0(cfg.lr0).seed(cfg.seed);
    s.run().expect("legacy hogwild_train_store combination").into_hogwild()
}

/// Hogwild! with **double-sampled** reads: two independent unbiased
/// stochastic p-plane draws per row visit, concurrently, from the single
/// stored copy (§2.2, DESIGN.md §5); bytes count exactly 2× the
/// truncating path. Shim over [`HostSession`].
#[deprecated(
    note = "compose a sgd::host::HostSession (DoubleSample + Execution::Hogwild) instead"
)]
pub fn hogwild_train_store_ds(
    ds: &Dataset,
    store: &ShardedStore,
    p: u32,
    cfg: &HogwildConfig,
) -> HogwildResult {
    let s = HostSession::over(ds, store).schedule(PrecisionSchedule::Fixed(p));
    let s = s.read(ReadStrategy::DoubleSample);
    let s = s.execution(Execution::Hogwild { threads: cfg.threads });
    s.epochs(cfg.epochs).lr0(cfg.lr0).seed(cfg.seed).run().expect("legacy combo").into_hogwild()
}

/// Hogwild! on the **popcount fast path** (DESIGN.md §8): every worker
/// re-rounds its snapshot's g = m⊙x per visit and dots by integer
/// AND+POPCNT; byte accounting matches the truncating path. Shim over
/// [`HostSession`].
#[deprecated(note = "compose a sgd::host::HostSession (Popcount + Execution::Hogwild) instead")]
pub fn hogwild_train_store_q(
    ds: &Dataset,
    store: &ShardedStore,
    p: u32,
    step_bits: u32,
    cfg: &HogwildConfig,
) -> HogwildResult {
    let s = HostSession::over(ds, store).schedule(PrecisionSchedule::Fixed(p));
    let s = s.read(ReadStrategy::Popcount { q: step_bits });
    let s = s.execution(Execution::Hogwild { threads: cfg.threads });
    s.epochs(cfg.epochs).lr0(cfg.lr0).seed(cfg.seed).run().expect("legacy combo").into_hogwild()
}

/// Simulated epoch time for the 10-core Hogwild baseline of Fig 5: CPU
/// reads full-precision samples from DRAM; per-core effective bandwidth is
/// shared. Model mirrors `fpga::pipeline::epoch_seconds` assumptions.
pub fn hogwild_epoch_seconds(k_samples: usize, n_features: usize, threads: usize) -> f64 {
    let bytes = k_samples as f64 * (n_features as f64 * 4.0 + 4.0);
    let dram = bytes / crate::fpga::MEM_BANDWIDTH_BYTES;
    // compute: ~1 FMA/cycle/core at 2.5 GHz with imperfect scaling
    let flops = 2.0 * k_samples as f64 * n_features as f64;
    let compute = flops / (2.5e9 * threads as f64 * 0.7);
    dram.max(compute)
}

#[cfg(test)]
#[allow(deprecated)] // the shims ARE the subject under test here
mod tests {
    use super::*;
    use crate::data::synthetic::make_regression;

    #[test]
    fn hogwild_converges_multithreaded() {
        let ds = make_regression("hw", 4000, 100, 20, 3);
        let r = hogwild_train(&ds, &HogwildConfig { threads: 4, epochs: 8, lr0: 0.02, seed: 1 });
        let first = r.loss_curve[0];
        let last = *r.loss_curve.last().unwrap();
        assert!(last < 0.2 * first, "no convergence: {first} -> {last}");
        assert!(r.updates >= 4000 * 8 / 4 * 3);
    }

    #[test]
    fn single_thread_matches_sequential_sgd_quality() {
        let ds = make_regression("hw1", 2000, 100, 10, 5);
        // per-sample SGD stability needs lr < 2/max‖a‖² (~0.02 here)
        let r = hogwild_train(&ds, &HogwildConfig { threads: 1, epochs: 10, lr0: 0.02, seed: 2 });
        assert!(*r.loss_curve.last().unwrap() < 0.1 * r.loss_curve[0]);
    }

    #[test]
    fn epoch_seconds_scale_with_threads() {
        let t1 = hogwild_epoch_seconds(100_000, 1000, 1);
        let t10 = hogwild_epoch_seconds(100_000, 1000, 10);
        assert!(t10 <= t1);
    }

    /// Multi-threaded shard readers converge on quantized data and the
    /// store counts every concurrent read exactly.
    #[test]
    fn hogwild_over_weaved_store_converges() {
        use crate::quant::ColumnScale;
        let ds = make_regression("hw_store", 4000, 100, 20, 3);
        let scale = ColumnScale::from_data(&ds.train_a);
        let store = crate::store::ShardedStore::ingest(&ds.train_a, &scale, 8, 11, 8, 0);
        let cfg = HogwildConfig { threads: 4, epochs: 8, lr0: 0.02, seed: 1 };
        let r = hogwild_train_store(&ds, &store, 8, &cfg);
        let first = r.loss_curve[0];
        let last = *r.loss_curve.last().unwrap();
        assert!(last < 0.3 * first, "no convergence: {first} -> {last}");
        // every (epoch × row) read was counted, no more, no less
        assert_eq!(
            store.bytes_read(),
            (8 * 4000 * store.bytes_per_row(8)) as u64
        );
        // coarse reads move fewer bytes for the same update count
        store.reset_bytes_read();
        let r2 = hogwild_train_store(&ds, &store, 2, &cfg);
        assert_eq!(r2.updates, r.updates);
        assert_eq!(
            store.bytes_read(),
            (8 * 4000 * store.bytes_per_row(2)) as u64
        );
    }

    /// Double-sampled Hogwild!: racy workers draw two unbiased stochastic
    /// samples per visit, converge at a low read precision, and the store
    /// counts exactly 2× the truncating path's bytes.
    #[test]
    fn hogwild_ds_over_weaved_store_converges_and_counts_double() {
        use crate::quant::ColumnScale;
        let ds = make_regression("hw_ds", 4000, 100, 20, 3);
        let scale = ColumnScale::from_data(&ds.train_a);
        let store = crate::store::ShardedStore::ingest(&ds.train_a, &scale, 8, 11, 8, 0);
        let cfg = HogwildConfig { threads: 4, epochs: 8, lr0: 0.02, seed: 1 };
        let r = hogwild_train_store_ds(&ds, &store, 4, &cfg);
        let first = r.loss_curve[0];
        let last = *r.loss_curve.last().unwrap();
        assert!(last < 0.3 * first, "no convergence: {first} -> {last}");
        assert_eq!(r.updates, 8 * 4000);
        // both draws of every (epoch × row) visit were counted
        assert_eq!(
            store.bytes_read(),
            (8 * 4000 * 2 * store.bytes_per_row(4)) as u64
        );
    }

    /// Popcount-path Hogwild!: racy workers re-round g per visit from
    /// their own streams, converge at a generous q, and the store counts
    /// exactly the truncating path's bytes (ĝ planes are not traffic).
    #[test]
    fn hogwild_popcount_over_weaved_store_converges_same_bytes() {
        use crate::quant::ColumnScale;
        let ds = make_regression("hw_q", 4000, 100, 20, 3);
        let scale = ColumnScale::from_data(&ds.train_a);
        let store = crate::store::ShardedStore::ingest(&ds.train_a, &scale, 8, 11, 8, 0);
        let cfg = HogwildConfig { threads: 4, epochs: 8, lr0: 0.02, seed: 1 };
        let r = hogwild_train_store_q(&ds, &store, 8, 8, &cfg);
        let first = r.loss_curve[0];
        let last = *r.loss_curve.last().unwrap();
        assert!(last < 0.3 * first, "no convergence: {first} -> {last}");
        assert_eq!(r.updates, 8 * 4000);
        assert_eq!(store.bytes_read(), (8 * 4000 * store.bytes_per_row(8)) as u64);
    }
}
