//! Tomographic-reconstruction workload (paper §1, Table 1 bottom row).
//!
//! A 2-D Shepp-Logan-style phantom is observed through a parallel-beam
//! projector; reconstruction is least squares over the ray equations
//! R·f = p, i.e. exactly the linear model of §2 with n = pixels. The
//! paper's data-movement argument (quantize projection rows) applies
//! unchanged; the 128³ volume becomes a 64² slice for laptop scale
//! (DESIGN.md §3).

use super::{Dataset, Task};
use crate::tensor::Matrix;

/// Ellipse in normalized [-1, 1]² coordinates.
struct Ellipse {
    x0: f32,
    y0: f32,
    a: f32,
    b: f32,
    angle_deg: f32,
    value: f32,
}

/// The classic Shepp-Logan parameter set (standard contrast variant).
const SHEPP_LOGAN: &[Ellipse] = &[
    Ellipse { x0: 0.0, y0: 0.0, a: 0.69, b: 0.92, angle_deg: 0.0, value: 1.0 },
    Ellipse { x0: 0.0, y0: -0.0184, a: 0.6624, b: 0.874, angle_deg: 0.0, value: -0.8 },
    Ellipse { x0: 0.22, y0: 0.0, a: 0.11, b: 0.31, angle_deg: -18.0, value: -0.2 },
    Ellipse { x0: -0.22, y0: 0.0, a: 0.16, b: 0.41, angle_deg: 18.0, value: -0.2 },
    Ellipse { x0: 0.0, y0: 0.35, a: 0.21, b: 0.25, angle_deg: 0.0, value: 0.1 },
    Ellipse { x0: 0.0, y0: 0.1, a: 0.046, b: 0.046, angle_deg: 0.0, value: 0.1 },
    Ellipse { x0: 0.0, y0: -0.1, a: 0.046, b: 0.046, angle_deg: 0.0, value: 0.1 },
    Ellipse { x0: -0.08, y0: -0.605, a: 0.046, b: 0.023, angle_deg: 0.0, value: 0.1 },
    Ellipse { x0: 0.0, y0: -0.605, a: 0.023, b: 0.023, angle_deg: 0.0, value: 0.1 },
    Ellipse { x0: 0.06, y0: -0.605, a: 0.023, b: 0.046, angle_deg: 0.0, value: 0.1 },
];

/// Rasterize the phantom at `size`×`size`.
pub fn phantom(size: usize) -> Vec<f32> {
    let mut img = vec![0.0f32; size * size];
    for (i, px) in img.iter_mut().enumerate() {
        let r = i / size;
        let c = i % size;
        let y = 1.0 - 2.0 * (r as f32 + 0.5) / size as f32;
        let x = 2.0 * (c as f32 + 0.5) / size as f32 - 1.0;
        for e in SHEPP_LOGAN {
            let th = e.angle_deg.to_radians();
            let (s, cth) = (th.sin(), th.cos());
            let dx = x - e.x0;
            let dy = y - e.y0;
            let xr = dx * cth + dy * s;
            let yr = -dx * s + dy * cth;
            if (xr / e.a).powi(2) + (yr / e.b).powi(2) <= 1.0 {
                *px += e.value;
            }
        }
    }
    img
}

/// Parallel-beam projector: `n_angles` uniformly spaced directions,
/// `size` detector bins each; each ray is a length-weighted line integral
/// sampled at sub-pixel steps. Returns the system matrix (rows = rays) —
/// dense, because the quantized sample store is dense.
pub fn projector(size: usize, n_angles: usize) -> Matrix {
    let n = size * size;
    let mut a = Matrix::zeros(n_angles * size, n);
    let steps = size * 2;
    let step_len = 2.0 * std::f32::consts::SQRT_2 / steps as f32;
    for ai in 0..n_angles {
        let theta = std::f32::consts::PI * ai as f32 / n_angles as f32;
        let (dirx, diry) = (theta.cos(), theta.sin());
        // detector axis ⊥ ray direction
        let (px, py) = (-diry, dirx);
        for det in 0..size {
            let t = 2.0 * (det as f32 + 0.5) / size as f32 - 1.0;
            let row = a.row_mut(ai * size + det);
            // march along the ray through [-√2, √2]
            for s in 0..steps {
                let u = -std::f32::consts::SQRT_2 + (s as f32 + 0.5) * step_len;
                let x = t * px + u * dirx;
                let y = t * py + u * diry;
                if !(-1.0..1.0).contains(&x) || !(-1.0..1.0).contains(&y) {
                    continue;
                }
                let c = ((x + 1.0) * 0.5 * size as f32) as usize;
                let r = ((1.0 - y) * 0.5 * size as f32) as usize;
                let (c, r) = (c.min(size - 1), r.min(size - 1));
                row[r * size + c] += step_len;
            }
        }
    }
    a
}

/// Full tomography dataset: rays as samples, sinogram as labels.
/// Train = all rays; test = a held-out random 10% of rays re-used for
/// generalization MSE (reconstruction error is reported separately).
pub fn make_tomography(size: usize, n_angles: usize, seed: u64) -> (Dataset, Vec<f32>) {
    let img = phantom(size);
    let proj = projector(size, n_angles);
    let sino = proj.matvec(&img);
    let mut rng = crate::rng::Rng::new(seed);
    let k = proj.rows;
    let mut idx: Vec<usize> = (0..k).collect();
    rng.shuffle(&mut idx);
    let n_test = k / 10;
    let test_idx = &idx[..n_test];
    let train_idx = &idx[n_test..];
    let ds = Dataset {
        name: format!("tomo{size}x{size}_{n_angles}ang"),
        task: Task::Regression,
        train_a: proj.gather_rows(train_idx),
        train_b: train_idx.iter().map(|&i| sino[i]).collect(),
        test_a: proj.gather_rows(test_idx),
        test_b: test_idx.iter().map(|&i| sino[i]).collect(),
    };
    (ds, img)
}

/// Pixel-space reconstruction RMSE against the phantom.
pub fn reconstruction_rmse(recon: &[f32], truth: &[f32]) -> f64 {
    let acc: f64 = recon
        .iter()
        .zip(truth)
        .map(|(&r, &t)| ((r - t) as f64).powi(2))
        .sum();
    (acc / truth.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phantom_structure() {
        let img = phantom(32);
        assert_eq!(img.len(), 1024);
        // center is inside the big ellipse + the darker inner one
        let center = img[16 * 32 + 16];
        assert!(center > 0.0 && center < 1.0, "center {center}");
        // corners are empty
        assert_eq!(img[0], 0.0);
        assert_eq!(img[1023], 0.0);
    }

    #[test]
    fn projector_row_mass_reasonable() {
        let p = projector(16, 8);
        assert_eq!(p.rows, 128);
        assert_eq!(p.cols, 256);
        // a central ray must traverse ~2 units of path length
        let central = p.row(8); // angle 0, center detector
        let mass: f32 = central.iter().sum();
        assert!(mass > 1.0 && mass < 3.0, "mass {mass}");
    }

    #[test]
    fn sinogram_consistent() {
        let (ds, img) = make_tomography(16, 8, 1);
        // labels equal projector × phantom by construction: verify on train
        let pred = ds.train_a.matvec(&img);
        for (p, b) in pred.iter().zip(&ds.train_b) {
            assert!((p - b).abs() < 1e-4);
        }
        assert!(ds.train_mse(&img) < 1e-8);
    }

    #[test]
    fn rmse_zero_for_perfect() {
        let img = phantom(16);
        assert_eq!(reconstruction_rmse(&img, &img), 0.0);
    }
}
