//! Dataset substrate (Table 1 equivalents).
//!
//! The paper's public datasets are replaced by controlled synthetic
//! generators matched on (K, n, task) — see DESIGN.md §3 for why this
//! preserves the quantization behaviour under study. Every generator is
//! deterministic in its seed.

pub mod synthetic;
pub mod tomo;

use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Regression,
    /// ±1 labels.
    Classification,
}

/// An in-memory labeled dataset with a train/test split.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub task: Task,
    /// K_train × n
    pub train_a: Matrix,
    pub train_b: Vec<f32>,
    /// K_test × n
    pub test_a: Matrix,
    pub test_b: Vec<f32>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.train_a.cols
    }

    pub fn k_train(&self) -> usize {
        self.train_a.rows
    }

    pub fn k_test(&self) -> usize {
        self.test_a.rows
    }

    /// Mean squared residual on the training split (Eq. 3 objective).
    pub fn train_mse(&self, x: &[f32]) -> f64 {
        mse(&self.train_a, &self.train_b, x)
    }

    pub fn test_mse(&self, x: &[f32]) -> f64 {
        mse(&self.test_a, &self.test_b, x)
    }

    /// Classification accuracy of sign(aᵀx) on the test split.
    pub fn test_accuracy(&self, x: &[f32]) -> f64 {
        debug_assert_eq!(self.task, Task::Classification);
        let pred = self.test_a.matvec(x);
        let correct = pred
            .iter()
            .zip(&self.test_b)
            .filter(|(&p, &y)| (p >= 0.0) == (y >= 0.0))
            .count();
        correct as f64 / self.test_b.len().max(1) as f64
    }
}

fn mse(a: &Matrix, b: &[f32], x: &[f32]) -> f64 {
    let pred = a.matvec(x);
    let mut acc = 0.0f64;
    for (&p, &y) in pred.iter().zip(b) {
        acc += ((p - y) as f64).powi(2);
    }
    acc / b.len().max(1) as f64
}

/// Table 1 rows: (name, K_train, K_test, n, task). Sizes are the paper's
/// where laptop-feasible, scaled otherwise (documented in DESIGN.md §3).
pub const TABLE1: &[(&str, usize, usize, usize, Task)] = &[
    ("synthetic10", 10_000, 10_000, 10, Task::Regression),
    ("synthetic100", 10_000, 10_000, 100, Task::Regression),
    ("synthetic1000", 10_000, 10_000, 1_000, Task::Regression),
    ("yearprediction", 46_371, 5_163, 90, Task::Regression), // 1/10 of paper's K
    ("cadata", 10_000, 10_640, 8, Task::Regression),
    ("cpusmall", 6_000, 2_192, 12, Task::Regression),
    ("cod-rna", 20_000, 27_161, 8, Task::Classification), // 1/3 K_train, 1/10 K_test
    ("gisette", 6_000, 1_000, 500, Task::Classification), // n 5000 → 500
];

/// Build a Table 1 dataset by name.
pub fn by_name(name: &str, seed: u64) -> anyhow::Result<Dataset> {
    let row = TABLE1
        .iter()
        .find(|r| r.0 == name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {name}"))?;
    let (_, ktr, kte, n, task) = *row;
    Ok(match task {
        Task::Regression => synthetic::make_regression(name, ktr, kte, n, seed),
        Task::Classification => synthetic::make_classification(name, ktr, kte, n, seed),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_by_name_shapes() {
        let d = by_name("cadata", 1).unwrap();
        assert_eq!(d.n(), 8);
        assert_eq!(d.k_train(), 10_000);
        assert_eq!(d.k_test(), 10_640);
        assert_eq!(d.task, Task::Regression);
    }

    #[test]
    fn unknown_name_errors() {
        assert!(by_name("nope", 1).is_err());
    }

    #[test]
    fn mse_zero_model_is_label_power() {
        let d = by_name("cpusmall", 2).unwrap();
        let zero = vec![0.0f32; d.n()];
        let mse = d.train_mse(&zero);
        let mean_b2: f64 =
            d.train_b.iter().map(|&b| (b as f64).powi(2)).sum::<f64>() / d.k_train() as f64;
        assert!((mse - mean_b2).abs() < 1e-6 * mean_b2.max(1.0));
    }
}
