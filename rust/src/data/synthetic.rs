//! Synthetic dataset generators matched to Table 1 (see DESIGN.md §3).
//!
//! Design goals that matter for quantization studies:
//! * heterogeneous per-feature scales (column scaling must matter),
//! * controllable conditioning (convergence-rate differences show up),
//! * a planted ground-truth model (losses have a known floor),
//! * heavy-tailed feature options (optimal ≠ uniform levels, Fig 7).

use super::{Dataset, Task};
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Features: z ~ N(0, I) mixed by a decaying spectrum, then per-feature
/// scaled by log-uniform factors in [0.2, 5] — realistic ill-scaled data.
fn gen_features(k: usize, n: usize, rng: &mut Rng, heavy_tails: bool) -> Matrix {
    let scales: Vec<f32> = (0..n)
        .map(|_| (0.2f32.ln() + rng.f32() * (5.0f32 / 0.2).ln()).exp())
        .collect();
    // low-rank-ish correlation: x_j = z_j + 0.5 * z_{(j+1) mod n}
    let mut a = Matrix::zeros(k, n);
    for r in 0..k {
        let row = a.row_mut(r);
        let mut prev = rng.normal();
        let first = prev;
        for c in 0..n {
            let z = if c + 1 < n { rng.normal() } else { first };
            let mut v = prev + 0.5 * z;
            if heavy_tails {
                // occasional large outliers → skewed distribution where
                // variance-optimal levels beat uniform (Fig 3/7 regime)
                if rng.f32() < 0.02 {
                    v *= 4.0;
                }
                v = v.signum() * v.abs().powf(1.3);
            }
            row[c] = v * scales[c];
            prev = z;
        }
    }
    // Normalize the global magnitude (mean ‖a‖² = 25) so one step-size
    // regime is stable across all datasets; the *relative* per-column
    // scales — what column-scaled quantization cares about — are kept.
    let mean_sq: f64 = (0..k)
        .map(|r| a.row(r).iter().map(|&v| (v as f64).powi(2)).sum::<f64>())
        .sum::<f64>()
        / k as f64;
    let norm = (25.0 / mean_sq.max(1e-12)).sqrt() as f32;
    for v in a.data.iter_mut() {
        *v *= norm;
    }
    a
}

fn planted_model(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n).map(|_| rng.normal() / (n as f32).sqrt()).collect()
}

/// Regression: b = a·x* + noise. Noise scale fixed at 5% of label std.
pub fn make_regression(name: &str, k_train: usize, k_test: usize, n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ hash_name(name));
    let heavy = name.contains("yearprediction") || name.contains("cadata");
    let xstar = planted_model(n, &mut rng);
    let gen = |k: usize, rng: &mut Rng| {
        let a = gen_features(k, n, rng, heavy);
        let mut b = a.matvec(&xstar);
        let std = (b.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / k as f64)
            .sqrt()
            .max(1e-9) as f32;
        for v in b.iter_mut() {
            *v += 0.05 * std * rng.normal();
        }
        (a, b)
    };
    let (train_a, train_b) = gen(k_train, &mut rng);
    let (test_a, test_b) = gen(k_test, &mut rng);
    Dataset { name: name.to_string(), task: Task::Regression, train_a, train_b, test_a, test_b }
}

/// Classification: b = sign(a·x* + logistic noise) ∈ {−1, +1}; ~10% label
/// flips near the boundary (realistic non-separable data).
pub fn make_classification(
    name: &str,
    k_train: usize,
    k_test: usize,
    n: usize,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed ^ hash_name(name));
    let heavy = name.contains("gisette");
    let xstar = planted_model(n, &mut rng);
    let gen = |k: usize, rng: &mut Rng| {
        let mut a = gen_features(k, n, rng, heavy);
        // normalize rows to ≤ 1 (the §4 assumption ‖a‖₂ ≤ 1)
        for r in 0..k {
            let norm = crate::tensor::norm2(a.row(r)).max(1e-9);
            for v in a.row_mut(r) {
                *v /= norm;
            }
        }
        let margin = a.matvec(&xstar);
        let scale = (margin.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / k as f64)
            .sqrt()
            .max(1e-12) as f32;
        let b: Vec<f32> = margin
            .iter()
            .map(|&m| {
                let z = (m / scale) as f64 * 3.0;
                let p = 1.0 / (1.0 + (-z).exp());
                if (rng.f64()) < p {
                    1.0
                } else {
                    -1.0
                }
            })
            .collect();
        (a, b)
    };
    let (train_a, train_b) = gen(k_train, &mut rng);
    let (test_a, test_b) = gen(k_test, &mut rng);
    Dataset { name: name.to_string(), task: Task::Classification, train_a, train_b, test_a, test_b }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a — stable across runs, decouples datasets sharing a seed
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regression_deterministic_per_seed() {
        let a = make_regression("t", 50, 10, 5, 7);
        let b = make_regression("t", 50, 10, 5, 7);
        assert_eq!(a.train_a.data, b.train_a.data);
        let c = make_regression("t", 50, 10, 5, 8);
        assert_ne!(a.train_a.data, c.train_a.data);
    }

    #[test]
    fn regression_has_low_noise_floor() {
        // the planted model must achieve far lower MSE than the zero model
        let d = make_regression("floor", 2000, 100, 20, 3);
        // recover x* by a few hundred full-gradient steps
        let mut x = vec![0.0f32; 20];
        for _ in 0..4000 {
            let r = d.train_a.matvec(&x);
            let mut g = vec![0.0f32; 20];
            for (i, (&ri, &bi)) in r.iter().zip(&d.train_b).enumerate() {
                let e = ri - bi;
                for (gc, &ac) in g.iter_mut().zip(d.train_a.row(i)) {
                    *gc += e * ac / d.k_train() as f32;
                }
            }
            for (xc, gc) in x.iter_mut().zip(&g) {
                *xc -= 0.02 * gc;
            }
        }
        assert!(d.train_mse(&x) < 0.15 * d.train_mse(&vec![0.0; 20]));
    }

    #[test]
    fn classification_labels_pm1_and_learnable() {
        let d = make_classification("cls", 3000, 500, 10, 5);
        assert!(d.train_b.iter().all(|&b| b == 1.0 || b == -1.0));
        let pos = d.train_b.iter().filter(|&&b| b > 0.0).count();
        assert!(pos > 500 && pos < 2500, "degenerate class balance: {pos}");
        assert!(d.train_a.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn classification_rows_normalized() {
        let d = make_classification("norm", 100, 10, 16, 2);
        for r in 0..100 {
            assert!(crate::tensor::norm2(d.train_a.row(r)) <= 1.0 + 1e-4);
        }
    }

    #[test]
    fn feature_scales_heterogeneous() {
        let d = make_regression("het", 2000, 10, 30, 9);
        let (lo, hi) = d.train_a.col_min_max();
        let spans: Vec<f32> = lo.iter().zip(&hi).map(|(&l, &h)| h - l).collect();
        let max = spans.iter().cloned().fold(0.0f32, f32::max);
        let min = spans.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(max / min > 3.0, "column scales too uniform: {min}..{max}");
    }
}
