//! Chebyshev approximation machinery (§4.2–4.3).
//!
//! The coordinator fits P ≈ ℓ' once per model (degree-15 by default, the
//! paper's setting) and ships the coefficients to the `cheby_step` /
//! `poly_ds_step` artifacts. The step function (hinge gradient) is fitted
//! through an erf-smoothed surrogate with gap δ — polynomials cannot
//! approximate the discontinuity on [-δ, δ] (§4.3), which is exactly the
//! regime the refetch heuristics guard.

/// Fit Chebyshev coefficients c_0..c_deg of f on [-radius, radius] by
/// interpolation at Chebyshev nodes (discrete orthogonality — exact for
/// polynomials of degree ≤ deg, near-minimax for smooth f).
pub fn cheb_fit<F: Fn(f64) -> f64>(f: F, radius: f64, deg: usize) -> Vec<f64> {
    let n = deg + 1;
    let fv: Vec<f64> = (0..n)
        .map(|j| {
            let theta = (2 * j + 1) as f64 / (2 * n) as f64 * std::f64::consts::PI;
            f(theta.cos() * radius)
        })
        .collect();
    (0..n)
        .map(|k| {
            let mut acc = 0.0;
            for (j, &v) in fv.iter().enumerate() {
                let theta = (2 * j + 1) as f64 / (2 * n) as f64 * std::f64::consts::PI;
                acc += v * (k as f64 * theta).cos();
            }
            acc * if k == 0 { 1.0 } else { 2.0 } / n as f64
        })
        .collect()
}

/// Clenshaw evaluation of Σ c_k T_k(z/radius); clamps |z| to the radius
/// (mirrors the L1 kernel).
pub fn cheb_eval(coefs: &[f64], radius: f64, z: f64) -> f64 {
    let t = (z / radius).clamp(-1.0, 1.0);
    let (mut b1, mut b2) = (0.0f64, 0.0f64);
    for &c in coefs.iter().skip(1).rev() {
        let b = c + 2.0 * t * b1 - b2;
        b2 = b1;
        b1 = b;
    }
    coefs[0] + t * b1 - b2
}

/// Convert Chebyshev coefficients (on [-radius, radius]) to monomial
/// coefficients m_0..m_deg of P(z) = Σ m_i z^i — the `poly_ds_step`
/// artifacts need monomials because the unbiased multi-sample estimator
/// multiplies independent quantizations per monomial term (§4.1).
pub fn cheb_to_monomial(coefs: &[f64], radius: f64) -> Vec<f64> {
    let deg = coefs.len() - 1;
    // T_k recurrence in monomial space (in t = z/radius).
    let mut tk_prev = vec![0.0f64; deg + 1]; // T_0 = 1
    tk_prev[0] = 1.0;
    let mut tk = vec![0.0f64; deg + 1]; // T_1 = t
    if deg >= 1 {
        tk[1] = 1.0;
    }
    let mut mono_t = vec![0.0f64; deg + 1];
    mono_t[0] += coefs[0];
    if deg >= 1 {
        for (m, &t1) in mono_t.iter_mut().zip(tk.iter()) {
            *m += coefs[1] * t1;
        }
    }
    for k in 2..=deg {
        // T_k = 2 t T_{k-1} − T_{k-2}
        let mut next = vec![0.0f64; deg + 1];
        for i in 0..deg {
            next[i + 1] += 2.0 * tk[i];
        }
        for i in 0..=deg {
            next[i] -= tk_prev[i];
        }
        for (m, &t1) in mono_t.iter_mut().zip(next.iter()) {
            *m += coefs[k] * t1;
        }
        tk_prev = tk;
        tk = next;
    }
    // substitute t = z / radius
    mono_t
        .iter()
        .enumerate()
        .map(|(i, &c)| c / radius.powi(i as i32))
        .collect()
}

/// ℓ'(z) for logistic loss ℓ(z) = log(1 + e^{-z}): ℓ'(z) = -σ(-z).
pub fn logistic_lprime(z: f64) -> f64 {
    -1.0 / (1.0 + z.exp())
}

/// Smoothed hinge-gradient surrogate: ℓ'(z) = -H(1-z) smoothed with an erf
/// transition of width `delta` (the [-δ, δ] gap of §4.3).
pub fn hinge_lprime_smoothed(z: f64, delta: f64) -> f64 {
    -0.5 * (1.0 - erf((z - 1.0) / delta))
}

/// Abramowitz–Stegun 7.1.26 erf approximation (|err| ≤ 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Degree needed so the Chebyshev fit of logistic ℓ' on [-R, R] has sup-norm
/// error ≤ eps (scanned empirically; Lemma 5's D(ε, ℓ)).
pub fn degree_for_eps_logistic(radius: f64, eps: f64, max_deg: usize) -> Option<usize> {
    for deg in 1..=max_deg {
        let coefs = cheb_fit(logistic_lprime, radius, deg);
        let worst = (0..400)
            .map(|i| {
                let z = -radius + 2.0 * radius * i as f64 / 399.0;
                (cheb_eval(&coefs, radius, z) - logistic_lprime(z)).abs()
            })
            .fold(0.0f64, f64::max);
        if worst <= eps {
            return Some(deg);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_recovers_polynomial_exactly() {
        // f(z) = 1 − 2z + 0.5 z³ is degree 3: a deg-5 fit must be exact.
        let f = |z: f64| 1.0 - 2.0 * z + 0.5 * z * z * z;
        let coefs = cheb_fit(f, 4.0, 5);
        for i in 0..50 {
            let z = -4.0 + 8.0 * i as f64 / 49.0;
            assert!((cheb_eval(&coefs, 4.0, z) - f(z)).abs() < 1e-9);
        }
    }

    #[test]
    fn logistic_fit_deg15_accurate() {
        // the paper's setting: degree 15 on a moderate radius
        let coefs = cheb_fit(logistic_lprime, 8.0, 15);
        let mut worst = 0.0f64;
        for i in 0..200 {
            let z = -8.0 + 16.0 * i as f64 / 199.0;
            worst = worst.max((cheb_eval(&coefs, 8.0, z) - logistic_lprime(z)).abs());
        }
        assert!(worst < 5e-3, "sup err {worst}");
    }

    #[test]
    fn monomial_conversion_matches_clenshaw() {
        let coefs = cheb_fit(logistic_lprime, 8.0, 15);
        let mono = cheb_to_monomial(&coefs, 8.0);
        for i in 0..100 {
            let z = -7.5 + 15.0 * i as f64 / 99.0;
            let horner = mono.iter().rev().fold(0.0f64, |acc, &m| acc * z + m);
            let clen = cheb_eval(&coefs, 8.0, z);
            assert!((horner - clen).abs() < 1e-6, "z={z} {horner} vs {clen}");
        }
    }

    #[test]
    fn erf_reference_values() {
        assert!((erf(0.0)).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427007).abs() < 1e-5);
        assert!((erf(-1.0) + 0.8427007).abs() < 1e-5);
        assert!((erf(3.0) - 0.9999779).abs() < 1e-5);
    }

    #[test]
    fn hinge_surrogate_limits() {
        assert!((hinge_lprime_smoothed(-3.0, 0.2) + 1.0).abs() < 1e-6); // deep in margin
        assert!(hinge_lprime_smoothed(5.0, 0.2).abs() < 1e-6); // well classified
        assert!((hinge_lprime_smoothed(1.0, 0.2) + 0.5).abs() < 1e-9); // midpoint
    }

    #[test]
    fn degree_grows_as_eps_shrinks() {
        let d1 = degree_for_eps_logistic(8.0, 1e-1, 40).unwrap();
        let d2 = degree_for_eps_logistic(8.0, 1e-3, 40).unwrap();
        assert!(d2 > d1, "{d2} !> {d1}");
    }
}
