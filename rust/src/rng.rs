//! Deterministic RNG for the coordinator: xoshiro256++ (Blackman/Vigna).
//!
//! Every stochastic-quantization decision in ZipML consumes explicit
//! randomness; the artifacts are pure functions, so all entropy originates
//! here and experiments replay exactly from a seed.

/// xoshiro256++ PRNG. Not cryptographic; fast, 2^256-1 period, splittable
/// via `jump`-free reseeding from `split`.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 expansion (the reference seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (e.g. one per epoch or worker).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Stateless stream derivation for `(seed, stream)` pairs: the stream
    /// id is mixed through splitmix-style avalanching before seeding, so
    /// adjacent ids (worker 0, 1, 2, … or epoch·W + worker) give
    /// decorrelated streams. Used by the double-sampling readers, where
    /// every racy Hogwild! worker must own its carry-randomness stream.
    pub fn new_stream(seed: u64, stream: u64) -> Rng {
        let mut z = stream.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        Rng::new(seed ^ (z ^ (z >> 31)))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1) with 24 bits of mantissa (f32-exact).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53 bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fill a slice with U[0,1) floats (the random operands of artifacts).
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        // Two f32s per u64 draw — halves RNG cost in the hot loop.
        let mut chunks = out.chunks_exact_mut(2);
        for pair in &mut chunks {
            let r = self.next_u64();
            pair[0] = (r >> 40) as f32 * (1.0 / (1u64 << 24) as f32);
            pair[1] = ((r << 24) >> 40) as f32 * (1.0 / (1u64 << 24) as f32);
        }
        for v in chunks.into_remainder() {
            *v = self.f32();
        }
    }

    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Fisher-Yates shuffle (used for per-epoch sample permutation).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Random sign (±1.0) — JL sketch entries.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(3);
        let mut buf = vec![0.0f32; 100_001]; // odd length exercises remainder
        r.fill_uniform(&mut buf);
        let mean: f64 = buf.iter().map(|&v| v as f64).sum::<f64>() / buf.len() as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
        assert!(buf.iter().all(|&v| (0.0..1.0).contains(&v)));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            m1 += v;
            m2 += v * v;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.01, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var {m2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn streams_deterministic_and_decorrelated() {
        let mut a = Rng::new_stream(42, 0);
        let mut b = Rng::new_stream(42, 0);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // adjacent stream ids and adjacent seeds must diverge immediately
        let mut c = Rng::new_stream(42, 1);
        assert_ne!(a.next_u64(), c.next_u64());
        let mut d = Rng::new_stream(43, 0);
        assert_ne!(b.next_u64(), d.next_u64());
        // stream 0 is not the plain seeding (ids are avalanche-mixed)
        let mut plain = Rng::new(42);
        let mut s0 = Rng::new_stream(42, 0);
        assert_ne!(plain.next_u64(), s0.next_u64());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
