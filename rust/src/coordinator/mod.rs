//! Experiment coordinator: a registry mapping every paper table/figure to
//! the code that regenerates it (DESIGN.md §6's index, executable).

pub mod figures;
pub mod report;

use anyhow::Result;
use std::path::PathBuf;

pub use report::Report;

/// Shared experiment context.
pub struct Ctx {
    pub rt: crate::runtime::Runtime,
    /// Shrinks dataset sizes / epochs ~10x for CI and smoke runs.
    pub quick: bool,
    pub out_dir: PathBuf,
    pub seed: u64,
}

impl Ctx {
    pub fn new(quick: bool) -> Result<Self> {
        Ok(Ctx {
            rt: crate::runtime::Runtime::open_default()?,
            quick,
            out_dir: PathBuf::from("results"),
            seed: 42,
        })
    }

    pub fn epochs(&self, full: usize) -> usize {
        if self.quick {
            (full / 5).max(2)
        } else {
            full
        }
    }

    pub fn k_scale(&self, k: usize) -> usize {
        if self.quick {
            (k / 10).max(256)
        } else {
            k
        }
    }
}

type FigureFn = fn(&Ctx) -> Result<Vec<Report>>;

/// (id, description, regenerator) — one entry per paper table/figure plus
/// the claim-level extras (bias, bandwidth, tomo).
pub const FIGURES: &[(&str, &str, FigureFn)] = &[
    ("table1", "Dataset statistics", figures::table1),
    ("fig3", "Optimal quantization points vs data distribution", figures::fig3),
    ("fig4", "Linear models, end-to-end low precision (linreg + LS-SVM)", figures::fig4),
    ("fig5", "FPGA speedup: float vs quantized vs Hogwild!", figures::fig5),
    ("fig6", "Impact of mini-batch size (16 vs 256)", figures::fig6),
    ("fig7a", "Uniform vs optimal quantization (3/5-bit)", figures::fig7a),
    ("fig7b", "Deep learning: FP32 vs XNOR5 vs Optimal5", figures::fig7b),
    ("fig8", "Linreg with quantized data across dimensionalities", figures::fig8),
    ("fig9", "Non-linear models: Chebyshev vs naive rounding (negative result)", figures::fig9),
    ("fig10", "Supplement: linreg end-to-end across datasets", figures::fig10),
    ("fig11", "Supplement: LS-SVM end-to-end across datasets", figures::fig11),
    ("fig12", "SVM refetching on cod-rna", figures::fig12),
    ("fig13", "FPGA pipeline cycle model (Fig 13/14 parameters)", figures::fig13),
    ("bias", "Naive quantization is biased and diverges (§B.1)", figures::bias),
    ("bandwidth", "Wire bytes per epoch per mode (§5.1 savings)", figures::bandwidth),
    ("tomo", "Tomographic reconstruction under quantized data", figures::tomo),
];

pub fn run_figure(ctx: &Ctx, id: &str) -> Result<Vec<Report>> {
    let (_, _, f) = FIGURES
        .iter()
        .find(|(fid, _, _)| *fid == id)
        .ok_or_else(|| anyhow::anyhow!("unknown figure {id}; see `zipml list`"))?;
    let reports = f(ctx)?;
    for r in &reports {
        r.print();
        let p = r.write_csv(&ctx.out_dir)?;
        println!("  → {}", p.display());
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique() {
        let mut ids: Vec<&str> = FIGURES.iter().map(|f| f.0).collect();
        let before = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), before);
        assert!(before >= 16);
    }
}
