//! One regenerator per paper table/figure (DESIGN.md §6).
//!
//! Absolute numbers differ from the paper (synthetic data, simulated FPGA,
//! CPU PJRT backend) but each function reproduces the *shape* of the
//! corresponding result: who wins, by what factor, where crossovers fall.

use anyhow::Result;

use super::{Ctx, Report};
use crate::data::{self, synthetic, Dataset, Task};
use crate::fpga::{self, Precision};
use crate::quant::{self, discretized_optimal_levels, optimal_levels, quantization_variance};
use crate::rng::Rng;
use crate::sgd::modes::RefetchStrategy;
use crate::sgd::{self, deep, Execution, HostSession, Mode, ModelKind, TrainConfig};

/// Dataset by Table-1 name, scaled down in quick mode.
fn dataset(ctx: &Ctx, name: &str) -> Result<Dataset> {
    let row = data::TABLE1.iter().find(|r| r.0 == name).unwrap();
    let (_, ktr, kte, n, task) = *row;
    let (ktr, kte) = (ctx.k_scale(ktr), ctx.k_scale(kte).min(2048));
    Ok(match task {
        Task::Regression => synthetic::make_regression(name, ktr, kte, n, ctx.seed),
        Task::Classification => synthetic::make_classification(name, ktr, kte, n, ctx.seed),
    })
}

/// Train and return (label, per-epoch losses, result extras).
fn run(
    ctx: &Ctx,
    ds: &Dataset,
    model: ModelKind,
    mode: Mode,
    epochs: usize,
    lr0: f32,
) -> Result<sgd::TrainResult> {
    let mut cfg = TrainConfig::new(model, mode);
    cfg.epochs = epochs;
    cfg.lr0 = lr0;
    cfg.seed = ctx.seed;
    cfg.eval_batches = if ctx.quick { 4 } else { 16 };
    sgd::train(&ctx.rt, ds, &cfg)
}

/// Loss-curve report: one column per mode, one row per epoch.
fn curve_report(id: &str, title: &str, runs: &[&sgd::TrainResult]) -> Report {
    let mut cols = vec!["epoch".to_string()];
    cols.extend(runs.iter().map(|r| r.mode_label.clone()));
    let cols_ref: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut rep = Report::new(id, title, &cols_ref);
    let max_len = runs.iter().map(|r| r.loss_curve.len()).max().unwrap_or(0);
    for e in 0..max_len {
        let mut cells = vec![e.to_string()];
        for r in runs {
            cells.push(
                r.loss_curve
                    .get(e)
                    .map(|&v| super::report::fmt_g(v))
                    .unwrap_or_else(|| "".into()),
            );
        }
        rep.row(cells);
    }
    for r in runs {
        rep.note(format!(
            "{}: final={} bytes/epoch={:.2e}{}{}",
            r.mode_label,
            super::report::fmt_g(r.final_loss),
            r.sample_bytes_per_epoch,
            if r.refetch_fraction > 0.0 {
                format!(" refetch={:.1}%", r.refetch_fraction * 100.0)
            } else {
                String::new()
            },
            if r.diverged { " DIVERGED" } else { "" },
        ));
    }
    rep
}

// ---------------------------------------------------------------------------

pub fn table1(_ctx: &Ctx) -> Result<Vec<Report>> {
    let mut rep = Report::new("table1", "Dataset statistics (Table 1 equivalents)",
        &["dataset", "train", "test", "features", "task"]);
    for (name, ktr, kte, n, task) in data::TABLE1 {
        rep.row(vec![
            name.to_string(),
            ktr.to_string(),
            kte.to_string(),
            n.to_string(),
            format!("{task:?}"),
        ]);
    }
    rep.row(vec!["tomography".into(), "96 proj × 64 bins".into(), "10%".into(),
        "4096 (64²)".into(), "Regression".into()]);
    rep.note("paper sizes scaled where laptop-infeasible; see DESIGN.md §3");
    Ok(vec![rep])
}

pub fn fig3(_ctx: &Ctx) -> Result<Vec<Report>> {
    // bimodal mixture like the paper's illustration
    let mut rng = Rng::new(3);
    let mut pts: Vec<f32> = (0..4000).map(|_| (rng.normal() * 0.08 + 0.25).clamp(0.0, 1.0)).collect();
    pts.extend((0..1000).map(|_| (rng.normal() * 0.05 + 0.75).clamp(0.0, 1.0)));
    let nlevels = 8;
    let uniform: Vec<f32> = (0..nlevels).map(|i| i as f32 / (nlevels - 1) as f32).collect();
    let exact = optimal_levels(&pts, nlevels);
    let disc = discretized_optimal_levels(&pts, nlevels, 128);
    let greedy = quant::greedy::adaquant_levels(&pts, nlevels);
    let mut rep = Report::new("fig3", "Quantization points on a bimodal distribution",
        &["method", "levels", "mean_variance"]);
    for (name, lv) in [("uniform", &uniform), ("optimal_dp", &exact),
                       ("discretized_dp_M128", &disc), ("adaquant_2approx", &greedy)] {
        rep.row(vec![
            name.into(),
            lv.iter().map(|v| format!("{v:.3}")).collect::<Vec<_>>().join(" "),
            super::report::fmt_g(quantization_variance(&pts, lv)),
        ]);
    }
    rep.note("optimal levels concentrate where the density is (paper Fig 3)");
    Ok(vec![rep])
}

pub fn fig4(ctx: &Ctx) -> Result<Vec<Report>> {
    let epochs = ctx.epochs(20);
    // (a) linear regression on Synthetic 100
    let ds = dataset(ctx, "synthetic100")?;
    let fp = run(ctx, &ds, ModelKind::Linreg, Mode::Full, epochs, 0.05)?;
    let ds3 = run(ctx, &ds, ModelKind::Linreg, Mode::DoubleSample { bits: 3 }, epochs, 0.05)?;
    let ds5 = run(ctx, &ds, ModelKind::Linreg, Mode::DoubleSample { bits: 5 }, epochs, 0.05)?;
    let a = curve_report("fig4a", "Linreg on synthetic100: FP32 vs double-sampled 3/5-bit",
        &[&fp, &ds3, &ds5]);
    // (b) LS-SVM on gisette-like
    let dsg = dataset(ctx, "gisette")?;
    let model = ModelKind::Lssvm { c: 1e-4 };
    let fp_g = run(ctx, &dsg, model, Mode::Full, epochs, 0.5)?;
    let q5 = run(ctx, &dsg, model, Mode::DoubleSample { bits: 5 }, epochs, 0.5)?;
    let q6 = run(ctx, &dsg, model, Mode::DoubleSample { bits: 6 }, epochs, 0.5)?;
    let b = curve_report("fig4b", "LS-SVM on gisette-like: FP32 vs 5/6-bit", &[&fp_g, &q5, &q6]);
    Ok(vec![a, b])
}

pub fn fig5(ctx: &Ctx) -> Result<Vec<Report>> {
    let epochs = ctx.epochs(20);
    let ds = dataset(ctx, "synthetic100")?;
    let (k, n) = (ds.k_train(), ds.n());
    let fp = run(ctx, &ds, ModelKind::Linreg, Mode::Full, epochs, 0.05)?;
    let q4 = run(ctx, &ds, ModelKind::Linreg, Mode::DoubleSample { bits: 4 }, epochs, 0.05)?;
    let hw = HostSession::dense(&ds)
        .execution(Execution::Hogwild {
            threads: 10.min(std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)),
        })
        .epochs(epochs)
        .lr0(0.05)
        .seed(ctx.seed)
        .run()?;
    let t_f32 = fpga::epoch_seconds(Precision::Float, k, n);
    let t_q4 = fpga::epoch_seconds(Precision::Q(4), k, n);
    let t_hw = fpga::hogwild::hogwild_epoch_seconds(k, n, 10);
    let mut rep = Report::new("fig5", "Loss vs (simulated) time: FPGA float / FPGA Q4 / Hogwild",
        &["epoch", "t_fpga32_s", "loss_fpga32", "t_fpgaQ4_s", "loss_fpgaQ4", "t_hogwild_s", "loss_hogwild"]);
    for e in 0..fp.loss_curve.len() {
        rep.row(vec![
            e.to_string(),
            format!("{:.4e}", e as f64 * t_f32),
            super::report::fmt_g(fp.loss_curve[e]),
            format!("{:.4e}", e as f64 * t_q4),
            super::report::fmt_g(q4.loss_curve[e]),
            format!("{:.4e}", e as f64 * t_hw),
            hw.loss_curve.get(e).map(|&v| super::report::fmt_g(v)).unwrap_or_default(),
        ]);
    }
    rep.note(format!("FPGA speedup (epoch time float/Q4) = {:.2}x (paper: 6-7x)", t_f32 / t_q4));
    rep.note(format!("Hogwild wallclock (real, {} upd): {:.2}s", hw.updates, hw.wall_secs));
    Ok(vec![rep])
}

pub fn fig6(ctx: &Ctx) -> Result<Vec<Report>> {
    let epochs = ctx.epochs(24);
    let ds = dataset(ctx, "synthetic100")?;
    let mut reports = Vec::new();
    for batch in [16usize, 256] {
        let mk = |mode: Mode| -> Result<sgd::TrainResult> {
            let mut cfg = TrainConfig::new(ModelKind::Linreg, mode);
            cfg.batch = batch;
            cfg.epochs = epochs;
            cfg.lr0 = 0.1;
            cfg.seed = ctx.seed;
            cfg.eval_batches = if ctx.quick { 4 } else { 16 };
            sgd::train(&ctx.rt, &ds, &cfg)
        };
        let fp = mk(Mode::Full)?;
        let q5 = mk(Mode::DoubleSample { bits: 5 })?;
        reports.push(curve_report(
            &format!("fig6_bs{batch}"),
            &format!("Mini-batch size {batch}: FP32 vs 5-bit double sampling"),
            &[&fp, &q5],
        ));
    }
    Ok(reports)
}

pub fn fig7a(ctx: &Ctx) -> Result<Vec<Report>> {
    let epochs = ctx.epochs(20);
    let ds = dataset(ctx, "yearprediction")?;
    let fp = run(ctx, &ds, ModelKind::Linreg, Mode::Full, epochs, 0.05)?;
    let u3 = run(ctx, &ds, ModelKind::Linreg, Mode::DoubleSample { bits: 3 }, epochs, 0.05)?;
    let u5 = run(ctx, &ds, ModelKind::Linreg, Mode::DoubleSample { bits: 5 }, epochs, 0.05)?;
    let o3 = run(ctx, &ds, ModelKind::Linreg, Mode::OptimalDs { levels: 8 }, epochs, 0.05)?;
    let o5 = run(ctx, &ds, ModelKind::Linreg, Mode::OptimalDs { levels: 32 }, epochs, 0.05)?;
    let mut rep = curve_report("fig7a",
        "YearPrediction-like: uniform vs variance-optimal quantization",
        &[&fp, &u3, &u5, &o3, &o5]);
    rep.note("paper: optimal 3-bit ≈ uniform 5-bit (1.7x bit saving)");
    Ok(vec![rep])
}

pub fn fig7b(ctx: &Ctx) -> Result<Vec<Report>> {
    // Data-limited regime (k ≪ capacity): this is where the weight-grid
    // choice separates, mirroring CIFAR-10's difficulty relative to the
    // paper's network (DESIGN.md §3). With k ≫ 8k the synthetic task
    // saturates and all grids reach the same accuracy.
    let (ktr, kte) = if ctx.quick { (1024, 512) } else { (2048, 2048) };
    let epochs = ctx.epochs(10);
    let data = deep::make_deep_dataset(ktr, kte, ctx.seed);
    let fp = deep::train_mlp(&ctx.rt, &data, deep::WeightQuant::FullPrecision, epochs, 0.1, ctx.seed)?;
    let xnor = deep::train_mlp(&ctx.rt, &data, deep::WeightQuant::Uniform { levels: 5 }, epochs, 0.1, ctx.seed)?;
    let opt = deep::train_mlp(&ctx.rt, &data, deep::WeightQuant::Optimal { levels: 5 }, epochs, 0.1, ctx.seed)?;
    let mut rep = Report::new("fig7b", "Quantized-model MLP: FP32 vs XNOR5 vs Optimal5",
        &["epoch", "loss_fp32", "loss_xnor5", "loss_optimal5", "acc_fp32", "acc_xnor5", "acc_optimal5"]);
    for e in 0..epochs {
        rep.row(vec![
            e.to_string(),
            super::report::fmt_g(fp.train_loss_curve[e]),
            super::report::fmt_g(xnor.train_loss_curve[e]),
            super::report::fmt_g(opt.train_loss_curve[e]),
            format!("{:.4}", fp.test_acc_curve[e]),
            format!("{:.4}", xnor.test_acc_curve[e]),
            format!("{:.4}", opt.test_acc_curve[e]),
        ]);
    }
    rep.note(format!("final acc: fp32={:.3} xnor5={:.3} optimal5={:.3} (paper: optimal5 > xnor5 by >5 pts)",
        fp.final_test_acc, xnor.final_test_acc, opt.final_test_acc));
    Ok(vec![rep])
}

pub fn fig8(ctx: &Ctx) -> Result<Vec<Report>> {
    let epochs = ctx.epochs(20);
    let mut reports = Vec::new();
    for (name, bits_lo, bits_hi) in [("synthetic10", 2, 4), ("synthetic100", 3, 5), ("synthetic1000", 4, 6)] {
        let ds = dataset(ctx, name)?;
        let fp = run(ctx, &ds, ModelKind::Linreg, Mode::Full, epochs, 0.05)?;
        let lo = run(ctx, &ds, ModelKind::Linreg, Mode::DoubleSample { bits: bits_lo }, epochs, 0.05)?;
        let hi = run(ctx, &ds, ModelKind::Linreg, Mode::DoubleSample { bits: bits_hi }, epochs, 0.05)?;
        let olo = run(ctx, &ds, ModelKind::Linreg, Mode::OptimalDs { levels: 1 << bits_lo }, epochs, 0.05)?;
        let mut rep = curve_report(&format!("fig8_{name}"),
            &format!("{name}: uniform {bits_lo}/{bits_hi}-bit vs optimal {bits_lo}-bit"),
            &[&fp, &lo, &hi, &olo]);
        rep.note("higher n needs more bits (quantization variance grows with n)");
        reports.push(rep);
    }
    Ok(reports)
}

pub fn fig9(ctx: &Ctx) -> Result<Vec<Report>> {
    let epochs = ctx.epochs(16);
    let mut reports = Vec::new();
    for (name, model, lr) in [
        ("gisette", ModelKind::Logistic, 0.5f32),
        ("cod-rna", ModelKind::Logistic, 0.5),
        ("cod-rna", ModelKind::Svm, 0.2),
    ] {
        let ds = dataset(ctx, name)?;
        let fp = run(ctx, &ds, model, Mode::Full, epochs, lr)?;
        let cheby = run(ctx, &ds, model, Mode::Cheby { bits: 4 }, epochs, lr)?;
        let poly = run(ctx, &ds, model, Mode::PolyDs { bits: 4 }, epochs, lr)?;
        let round = run(ctx, &ds, model, Mode::NearestRound { bits: 8 }, epochs, lr)?;
        let naive = run(ctx, &ds, model, Mode::Naive { bits: 8 }, epochs, lr)?;
        let model_tag = match model {
            ModelKind::Svm => "svm",
            ModelKind::Logistic | ModelKind::Linreg | ModelKind::Lssvm { .. } => "logistic",
        };
        let id = format!("fig9_{model_tag}_{name}");
        let mut rep = curve_report(&id,
            &format!("{name} / {:?}: Chebyshev vs 8-bit rounding strawmen", model),
            &[&fp, &cheby, &poly, &round, &naive]);
        rep.note("the paper's NEGATIVE result: naive 8-bit rounding matches Chebyshev");
        reports.push(rep);
    }
    Ok(reports)
}

pub fn fig10(ctx: &Ctx) -> Result<Vec<Report>> {
    linear_sweep(ctx, ModelKind::Linreg, "fig10",
        &["synthetic10", "synthetic100", "synthetic1000", "yearprediction", "cadata", "cpusmall"])
}

pub fn fig11(ctx: &Ctx) -> Result<Vec<Report>> {
    linear_sweep(ctx, ModelKind::Lssvm { c: 1e-4 }, "fig11", &["cod-rna", "gisette"])
}

fn linear_sweep(ctx: &Ctx, model: ModelKind, id: &str, names: &[&str]) -> Result<Vec<Report>> {
    let epochs = ctx.epochs(15);
    let mut rep = Report::new(id, "End-to-end quantization across datasets",
        &["dataset", "fp32_final", "e2e5_final", "e2e6_final", "ratio_e2e6/fp32", "bytes_saved_x"]);
    for name in names {
        let ds = dataset(ctx, name)?;
        let lr = if model.is_classification() { 0.5 } else { 0.05 };
        let fp = run(ctx, &ds, model, Mode::Full, epochs, lr)?;
        let (m5, m6);
        if matches!(model, ModelKind::Linreg) {
            m5 = run(ctx, &ds, model, Mode::EndToEnd { bits_s: 5, bits_m: 8, bits_g: 8 }, epochs, lr)?;
            m6 = run(ctx, &ds, model, Mode::EndToEnd { bits_s: 6, bits_m: 8, bits_g: 8 }, epochs, lr)?;
        } else {
            m5 = run(ctx, &ds, model, Mode::DoubleSample { bits: 5 }, epochs, lr)?;
            m6 = run(ctx, &ds, model, Mode::DoubleSample { bits: 6 }, epochs, lr)?;
        }
        rep.row(vec![
            name.to_string(),
            super::report::fmt_g(fp.final_loss),
            super::report::fmt_g(m5.final_loss),
            super::report::fmt_g(m6.final_loss),
            format!("{:.3}", m6.final_loss / fp.final_loss.max(1e-12)),
            format!("{:.2}", fp.sample_bytes_per_epoch / m6.sample_bytes_per_epoch),
        ]);
    }
    rep.note("5-6 bits suffices to match FP32 final loss (paper §J.1)");
    Ok(vec![rep])
}

pub fn fig12(ctx: &Ctx) -> Result<Vec<Report>> {
    let epochs = ctx.epochs(12);
    let ds = dataset(ctx, "cod-rna")?;
    let fp = run(ctx, &ds, ModelKind::Svm, Mode::Full, epochs, 0.2)?;
    let mut runs = vec![fp];
    for bits in [4u32, 6, 8] {
        runs.push(run(ctx, &ds, ModelKind::Svm,
            Mode::Refetch { bits, strategy: RefetchStrategy::L1 }, epochs, 0.2)?);
    }
    runs.push(run(ctx, &ds, ModelKind::Svm,
        Mode::Refetch { bits: 8, strategy: RefetchStrategy::L2Jl { sketch_dim: 64, delta: 0.05 } },
        epochs, 0.2)?);
    let refs: Vec<&sgd::TrainResult> = runs.iter().collect();
    let mut rep = curve_report("fig12", "SVM with refetching on cod-rna-like", &refs);
    rep.note("paper: 8-bit refetches <5-6% of samples");
    Ok(vec![rep])
}

pub fn fig13(_ctx: &Ctx) -> Result<Vec<Report>> {
    let mut rep = Report::new("fig13", "Pipeline cycle model (paper Fig 13/14)",
        &["precision", "latency_cycles", "width_B_per_cycle", "epoch_s_50k_x90", "speedup_vs_float"]);
    let base = fpga::epoch_seconds(Precision::Float, 50_000, 90);
    for p in [Precision::Float, Precision::Q(8), Precision::Q(4), Precision::Q(2), Precision::Q(1)] {
        let spec = fpga::PipelineSpec::for_precision(p);
        let t = fpga::epoch_seconds(p, 50_000, 90);
        rep.row(vec![
            p.label(),
            format!("{:.1}", spec.latency_cycles),
            format!("{}", spec.width_bytes_per_cycle),
            format!("{t:.4e}"),
            format!("{:.2}", base / t),
        ]);
    }
    Ok(vec![rep])
}

pub fn bias(ctx: &Ctx) -> Result<Vec<Report>> {
    // §B.1's instance: a minimizer far from 0 makes D_a·x dominate.
    let epochs = ctx.epochs(60);
    let n = 10;
    let mut rng = Rng::new(ctx.seed);
    let k = ctx.k_scale(8000);
    let mut a = crate::tensor::Matrix::zeros(k, n);
    for r in 0..k {
        for c in 0..n {
            a.set(r, c, rng.normal());
        }
    }
    let xstar: Vec<f32> = (0..n).map(|_| 3.0 + rng.f32()).collect(); // large minimizer
    let b = a.matvec(&xstar);
    let half = k / 2;
    let ds = Dataset {
        name: "bias_demo".into(),
        task: Task::Regression,
        train_a: a.gather_rows(&(0..half).collect::<Vec<_>>()),
        train_b: b[..half].to_vec(),
        test_a: a.gather_rows(&(half..k).collect::<Vec<_>>()),
        test_b: b[half..].to_vec(),
    };
    let fp = run(ctx, &ds, ModelKind::Linreg, Mode::Full, epochs, 0.15)?;
    let naive = run(ctx, &ds, ModelKind::Linreg, Mode::Naive { bits: 3 }, epochs, 0.15)?;
    let dsq = run(ctx, &ds, ModelKind::Linreg, Mode::DoubleSample { bits: 3 }, epochs, 0.15)?;
    let mut rep = curve_report("bias", "Naive 3-bit vs double-sampled 3-bit (large x*)",
        &[&fp, &naive, &dsq]);
    rep.note(format!(
        "naive converges to a biased solution: final {} vs ds {} (fp {})",
        super::report::fmt_g(naive.final_loss),
        super::report::fmt_g(dsq.final_loss),
        super::report::fmt_g(fp.final_loss)
    ));
    Ok(vec![rep])
}

pub fn bandwidth(ctx: &Ctx) -> Result<Vec<Report>> {
    let mut rep = Report::new("bandwidth", "Wire bits/value and bytes per epoch (synthetic100)",
        &["mode", "bits_per_value", "bytes_per_epoch", "saving_vs_fp32"]);
    let ds = dataset(ctx, "synthetic100")?;
    let (k, n) = (ds.k_train() / 64 * 64, ds.n());
    for mode in [
        Mode::Full,
        Mode::Naive { bits: 8 },
        Mode::DoubleSample { bits: 4 },
        Mode::DoubleSample { bits: 6 },
        Mode::DoubleSampleU8 { bits: 4 },
        Mode::EndToEnd { bits_s: 5, bits_m: 8, bits_g: 8 },
        Mode::PolyDs { bits: 4 },
        Mode::OptimalDs { levels: 8 },
    ] {
        let bits = mode.wire_bits_per_value(sgd::driver::CHEBY_DEG);
        let bytes = k as f64 * n as f64 * bits / 8.0;
        let fp_bytes = k as f64 * n as f64 * 4.0;
        rep.row(vec![
            mode.label(),
            format!("{bits}"),
            format!("{bytes:.3e}"),
            format!("{:.2}x", fp_bytes / bytes),
        ]);
    }
    rep.note("paper §5.1: 6-8x bandwidth saving at 5-6 bits; tomography 2.7x at 8-bit+overhead");
    Ok(vec![rep])
}

pub fn tomo(ctx: &Ctx) -> Result<Vec<Report>> {
    // n = size² is baked into the artifacts, so quick mode shrinks the
    // number of angles (rows), not the volume.
    let size = 64;
    let n_angles = if ctx.quick { 8 } else { 96 };
    let epochs = ctx.epochs(30);
    let (ds, truth) = crate::data::tomo::make_tomography(size, n_angles, ctx.seed);
    let fp = run(ctx, &ds, ModelKind::Linreg, Mode::Full, epochs, 0.2)?;
    let q8 = run(ctx, &ds, ModelKind::Linreg, Mode::DoubleSample { bits: 8 }, epochs, 0.2)?;
    let q6 = run(ctx, &ds, ModelKind::Linreg, Mode::DoubleSample { bits: 6 }, epochs, 0.2)?;
    let mut rep = Report::new("tomo",
        &format!("Tomographic reconstruction {size}x{size}, {n_angles} angles"),
        &["mode", "final_sino_mse", "recon_rmse", "bytes_per_epoch", "saving"]);
    for r in [&fp, &q8, &q6] {
        rep.row(vec![
            r.mode_label.clone(),
            super::report::fmt_g(r.final_loss),
            super::report::fmt_g(crate::data::tomo::reconstruction_rmse(&r.final_model, &truth)),
            format!("{:.3e}", r.sample_bytes_per_epoch),
            format!("{:.2}x", fp.sample_bytes_per_epoch / r.sample_bytes_per_epoch),
        ]);
    }
    rep.note("paper: 2.7x data-movement saving at negligible quality loss");
    Ok(vec![rep])
}
