//! Tabular experiment reports: printed as aligned text and written as CSV
//! under `results/` so every paper figure has a machine-readable twin.

use anyhow::Result;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Self {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.columns.len());
        self.rows.push(cells);
    }

    pub fn row_f(&mut self, label: &str, values: &[f64]) {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|v| fmt_g(*v)));
        self.row(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn print(&self) {
        println!("\n## {} — {}", self.id, self.title);
        let widths: Vec<usize> = self
            .columns
            .iter()
            .enumerate()
            .map(|(c, h)| {
                self.rows
                    .iter()
                    .map(|r| r.get(c).map_or(0, |s| s.len()))
                    .chain(std::iter::once(h.len()))
                    .max()
                    .unwrap_or(0)
            })
            .collect();
        let line = |cells: &[String]| {
            let joined: Vec<String> = cells
                .iter()
                .zip(&widths)
                .map(|(s, w)| format!("{s:>w$}", w = w))
                .collect();
            println!("  {}", joined.join("  "));
        };
        line(&self.columns);
        for r in &self.rows {
            line(r);
        }
        for n in &self.notes {
            println!("  · {n}");
        }
    }

    pub fn write_csv(&self, dir: &Path) -> Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.id));
        let mut out = String::new();
        out.push_str(&self.columns.join(","));
        out.push('\n');
        for r in &self.rows {
            let esc: Vec<String> = r
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            out.push_str(&esc.join(","));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("# {n}\n"));
        }
        std::fs::write(&path, out)?;
        Ok(path)
    }
}

/// Compact general-purpose float formatting for report cells.
pub fn fmt_g(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    let a = v.abs();
    if a == 0.0 {
        "0".into()
    } else if a >= 1e5 || a < 1e-3 {
        format!("{v:.3e}")
    } else if a >= 100.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_roundtrip_csv() {
        let mut r = Report::new("t1", "test", &["mode", "loss"]);
        r.row_f("fp32", &[0.123456]);
        r.row(vec!["weird, cell".into(), "1".into()]);
        r.note("a note");
        let dir = std::env::temp_dir().join("zipml_report_test");
        let p = r.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.starts_with("mode,loss\n"));
        assert!(text.contains("\"weird, cell\""));
        assert!(text.contains("# a note"));
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_g(0.0), "0");
        assert_eq!(fmt_g(0.5), "0.5000");
        assert_eq!(fmt_g(123.45), "123.5");
        assert!(fmt_g(1.0e-9).contains('e'));
        assert!(fmt_g(f64::NAN).contains("NaN"));
    }
}
