//! Parse `artifacts/manifest.tsv` — the compile-path contract with aot.py.
//!
//! Line format (tab-separated):
//! ```text
//! artifact  <name>  <file>  <num_outputs>
//! input     <name>  <arg>   <dtype>  <d0,d1,...>
//! meta      <name>  <key>   <value>
//! ```

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u8" => DType::U8,
            _ => bail!("unknown dtype {s}"),
        })
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl InputSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub num_outputs: usize,
    pub inputs: Vec<InputSpec>,
    pub meta: BTreeMap<String, String>,
}

impl ArtifactSpec {
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.parse().ok())
    }

    pub fn input(&self, name: &str) -> Option<&InputSpec> {
        self.inputs.iter().find(|i| i.name == name)
    }
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut m = Manifest { artifacts: BTreeMap::new(), dir: dir.to_path_buf() };
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim_end();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            let err = || format!("manifest line {}: {line:?}", lineno + 1);
            match fields[0] {
                "artifact" => {
                    if fields.len() != 4 {
                        bail!("{}", err());
                    }
                    let name = fields[1].to_string();
                    m.artifacts.insert(
                        name.clone(),
                        ArtifactSpec {
                            name,
                            file: dir.join(fields[2]),
                            num_outputs: fields[3].parse().with_context(err)?,
                            inputs: Vec::new(),
                            meta: BTreeMap::new(),
                        },
                    );
                }
                "input" => {
                    if fields.len() != 5 {
                        bail!("{}", err());
                    }
                    let art = m
                        .artifacts
                        .get_mut(fields[1])
                        .with_context(|| format!("input before artifact: {line}"))?;
                    let shape = fields[4]
                        .split(',')
                        .filter(|s| !s.is_empty())
                        .map(|s| s.parse::<usize>().map_err(anyhow::Error::from))
                        .collect::<Result<Vec<_>>>()
                        .with_context(err)?;
                    art.inputs.push(InputSpec {
                        name: fields[2].to_string(),
                        dtype: DType::parse(fields[3])?,
                        shape,
                    });
                }
                "meta" => {
                    if fields.len() != 4 {
                        bail!("{}", err());
                    }
                    let art = m
                        .artifacts
                        .get_mut(fields[1])
                        .with_context(|| format!("meta before artifact: {line}"))?;
                    art.meta.insert(fields[2].to_string(), fields[3].to_string());
                }
                other => bail!("unknown record type {other:?} at line {}", lineno + 1),
            }
        }
        Ok(m)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest ({} known)", self.artifacts.len()))
    }

    /// Find e.g. `linreg_ds_step_n{n}` by kind + n metadata.
    pub fn find_kind_n(&self, kind: &str, n: usize) -> Result<&ArtifactSpec> {
        self.artifacts
            .values()
            .find(|a| {
                a.meta.get("kind").map(String::as_str) == Some(kind)
                    && a.meta_usize("n") == Some(n)
                    && !a.meta.contains_key("num_batches")
                    && a.meta_usize("batch") == self.default_batch_for(kind, n)
            })
            .with_context(|| format!("no artifact kind={kind} n={n}"))
    }

    fn default_batch_for(&self, kind: &str, n: usize) -> Option<usize> {
        // prefer batch=64 (the default shape class) when several exist
        let batches: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| {
                a.meta.get("kind").map(String::as_str) == Some(kind) && a.meta_usize("n") == Some(n)
            })
            .filter_map(|a| a.meta_usize("batch"))
            .collect();
        if batches.contains(&64) {
            Some(64)
        } else {
            batches.first().copied()
        }
    }

    /// Variant with an explicit batch (Fig 6 uses batch 16 / 256).
    pub fn find_kind_n_batch(&self, kind: &str, n: usize, batch: usize) -> Result<&ArtifactSpec> {
        self.artifacts
            .values()
            .find(|a| {
                a.meta.get("kind").map(String::as_str) == Some(kind)
                    && a.meta_usize("n") == Some(n)
                    && a.meta_usize("batch") == Some(batch)
                    && !a.meta.contains_key("num_batches")
            })
            .with_context(|| format!("no artifact kind={kind} n={n} batch={batch}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "artifact\tfoo_n10\tfoo_n10.hlo.txt\t2\n\
input\tfoo_n10\tx\tf32\t10,1\n\
input\tfoo_n10\tidx\tu8\t64,10\n\
meta\tfoo_n10\tkind\tfoo\n\
meta\tfoo_n10\tn\t10\n\
meta\tfoo_n10\tbatch\t64\n";

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let a = m.get("foo_n10").unwrap();
        assert_eq!(a.num_outputs, 2);
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![10, 1]);
        assert_eq!(a.inputs[1].dtype, DType::U8);
        assert_eq!(a.meta_usize("n"), Some(10));
        assert_eq!(a.input("idx").unwrap().elements(), 640);
        assert!(m.find_kind_n("foo", 10).is_ok());
        assert!(m.find_kind_n("foo", 11).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus\tx", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("input\tmissing\tx\tf32\t1", Path::new("/tmp")).is_err());
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() > 50);
            let ds = m.find_kind_n("linreg_ds_step", 100).unwrap();
            assert_eq!(ds.meta_usize("batch"), Some(64));
            // Fig 6 variants resolvable by explicit batch
            assert!(m.find_kind_n_batch("linreg_ds_step", 100, 16).is_ok());
            assert!(m.find_kind_n_batch("linreg_ds_step", 100, 256).is_ok());
        }
    }
}
