//! Literal construction/extraction helpers over the xla crate.

use anyhow::{bail, Result};
use xla::{ElementType, Literal};

use super::manifest::DType;

/// Safe widening of a scalar slice to its little-endian byte image —
/// replaces the crate's former (and only) `unsafe` raw-parts casts.
/// PJRT untyped-data buffers are little-endian on every supported
/// target, so this is byte-for-byte what the pointer cast produced.
fn le_bytes<T: Copy, const N: usize>(data: &[T], to_le: impl Fn(T) -> [u8; N]) -> Vec<u8> {
    let mut out = vec![0u8; data.len() * N];
    for (chunk, &v) in out.chunks_exact_mut(N).zip(data) {
        chunk.copy_from_slice(&to_le(v));
    }
    out
}

/// Build a literal of the given dtype/shape from raw host data.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let expected: usize = shape.iter().product();
    if data.len() != expected {
        bail!("lit_f32 shape {shape:?} wants {expected} elems, got {}", data.len());
    }
    let bytes = le_bytes(data, f32::to_le_bytes);
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, &bytes)?)
}

pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    let expected: usize = shape.iter().product();
    if data.len() != expected {
        bail!("lit_i32 shape {shape:?} wants {expected} elems, got {}", data.len());
    }
    let bytes = le_bytes(data, i32::to_le_bytes);
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, &bytes)?)
}

pub fn lit_u8(shape: &[usize], data: &[u8]) -> Result<Literal> {
    let expected: usize = shape.iter().product();
    if data.len() != expected {
        bail!("lit_u8 shape {shape:?} wants {expected} elems, got {}", data.len());
    }
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::U8, shape, data)?)
}

/// (1, 1) f32 scalar operand (lr, c, s, …).
pub fn lit_scalar11(v: f32) -> Result<Literal> {
    lit_f32(&[1, 1], &[v])
}

/// Validate raw byte length against an input spec and wrap.
pub fn lit_for_spec(spec: &super::manifest::InputSpec, f32s: Option<&[f32]>, i32s: Option<&[i32]>, u8s: Option<&[u8]>) -> Result<Literal> {
    match spec.dtype {
        DType::F32 => lit_f32(&spec.shape, f32s.expect("f32 data")),
        DType::I32 => lit_i32(&spec.shape, i32s.expect("i32 data")),
        DType::U8 => lit_u8(&spec.shape, u8s.expect("u8 data")),
    }
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract the single f32 from a (1,1) literal (loss outputs).
pub fn to_f32_scalar(lit: &Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    if v.len() != 1 {
        bail!("expected scalar literal, got {} elems", v.len());
    }
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&[2, 3], &data).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), data.to_vec());
    }

    #[test]
    fn u8_roundtrip() {
        let data = [0u8, 1, 2, 255];
        let lit = lit_u8(&[4], &data).unwrap();
        assert_eq!(lit.to_vec::<u8>().unwrap(), data.to_vec());
    }

    #[test]
    fn i32_roundtrip() {
        let data = [3i32, -7, 0];
        let lit = lit_i32(&[3], &data).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data.to_vec());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[2, 2], &[1.0]).is_err());
        assert!(lit_u8(&[3], &[1, 2]).is_err());
    }

    #[test]
    fn scalar_helpers() {
        let lit = lit_scalar11(0.25).unwrap();
        assert_eq!(to_f32_scalar(&lit).unwrap(), 0.25);
    }
}
