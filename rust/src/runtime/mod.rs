//! The PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot loop. Python is never involved at this point.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`, with the
//! root tuple decomposed into per-output literals.

pub mod literal;
pub mod manifest;

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

pub use literal::{lit_f32, lit_i32, lit_scalar11, lit_u8, to_f32_scalar, to_f32_vec};
pub use manifest::{ArtifactSpec, DType, Manifest};

/// Cumulative execution counters (perf accounting; see EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub compile_count: u64,
    pub exec_nanos: u64,
}

/// Owns the PJRT CPU client and a compiled-executable cache keyed by
/// artifact name. One `Runtime` per process; cheap to share via `&`.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Open the artifact directory (default: `<repo>/artifacts`).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Default artifact dir relative to the crate root.
    pub fn default_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn open_default() -> Result<Self> {
        Self::open(&Self::default_dir())
    }

    pub fn stats(&self) -> RuntimeStats {
        *self.stats.borrow()
    }

    /// Compile (or fetch cached) executable for `name`.
    pub fn load(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?,
        );
        self.stats.borrow_mut().compile_count += 1;
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name`, returning the decomposed output tuple.
    pub fn exec(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.get(name)?;
        anyhow::ensure!(
            args.len() == spec.inputs.len(),
            "artifact {name} wants {} inputs, got {}",
            spec.inputs.len(),
            args.len()
        );
        let exe = self.load(name)?;
        let t0 = crate::telemetry::Stopwatch::start();
        let result = exe.execute::<xla::Literal>(args)?;
        let root = result[0][0].to_literal_sync()?;
        let outs = root.to_tuple()?;
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.exec_nanos += t0.elapsed_nanos();
        anyhow::ensure!(
            outs.len() == spec.num_outputs,
            "artifact {name} declared {} outputs, produced {}",
            spec.num_outputs,
            outs.len()
        );
        Ok(outs)
    }

    /// Execute expecting a single output, extracted to f32.
    pub fn exec1_f32(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<f32>> {
        let outs = self.exec(name, args)?;
        to_f32_vec(&outs[0])
    }

    /// Execute a loss artifact → scalar.
    pub fn exec1_scalar(&self, name: &str, args: &[xla::Literal]) -> Result<f32> {
        let outs = self.exec(name, args)?;
        to_f32_scalar(&outs[0])
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }
}
