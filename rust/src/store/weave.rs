//! Bit-plane-interleaved ("weaved") sample storage — MLWeaving's layout
//! applied to the ZipML sample store.
//!
//! [`crate::quant::packing::PackedMatrix`] stores the b-bit level index of
//! every value contiguously, so a reader pays for all b bits regardless of
//! the precision it actually wants. [`WeavedMatrix`] transposes each row at
//! word granularity: plane t holds bit (b−1−t) — MSB first — of every
//! value's index, packed 64 columns per `u64`. A reader at precision
//! `p ≤ b` touches only the first `p` planes of a row and reconstructs the
//! top-p truncation `index >> (b − p)` — any precision, one stored copy,
//! and the bytes crossing the memory boundary scale with `p` exactly
//! (the paper's Fig 5 bandwidth argument, now per-read instead of
//! per-stored-copy).
//!
//! Truncation semantics: the p-bit index addresses the uniform grid with
//! s_p = 2^p − 1 intervals, so a full-width read (p = b) reproduces the
//! `PackedMatrix` dequantization bit for bit. Lower p behaves like
//! deterministic nearest-down rounding of the stored draw — unbiasedness
//! degrades gracefully (one stochastic draw is still inside) and the
//! precision schedules (see [`super::precision_schedule`]) step p up when
//! the induced noise floor is reached.

use crate::quant::packing::PackedMatrix;
use crate::quant::scaling::ColumnScale;
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Optional rank-style occupancy summary of the weaved planes: one byte
/// per 8-word run, bit k set ⇔ word `8·run + k` of that plane is
/// nonzero. Eight words are one 64-byte cache line, so a zero occupancy
/// byte lets the truncating kernels skip a whole line of plane loads
/// with a single byte test (DESIGN.md §12). The index is *derived*
/// metadata: it never crosses the simulated memory wire, and its bytes
/// are accounted separately ([`WeavedMatrix::index_bytes`]) from the
/// §5/§8 wire-byte contract, which is unchanged.
#[derive(Clone, Debug)]
pub struct PlaneIndex {
    /// `rows × bits × runs_per_plane` occupancy bytes, row-major then
    /// plane-major — the same nesting order as the plane data itself.
    occ: Vec<u8>,
    /// Occupancy bytes per plane: ceil(words_per_plane / 8).
    runs_per_plane: usize,
}

/// A (rows × cols) matrix of b-bit level indices stored as bit planes.
///
/// Planes are packed at `u64` word granularity, so each plane of a row
/// costs `8·⌈cols/64⌉` bytes. The layout targets wide sample matrices;
/// for very narrow ones (cols ≤ 16) the per-plane word rounding can erase
/// the bandwidth advantage over f32 rows.
#[derive(Clone, Debug)]
pub struct WeavedMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Stored (maximum readable) bit width, 1..=16.
    pub bits: u32,
    /// Interval count of the full-width grid: s = 2^bits − 1.
    pub s: u32,
    pub scale: ColumnScale,
    /// `u64` words per bit plane: ceil(cols / 64).
    words_per_plane: usize,
    /// rows × bits planes, row-major then plane-major (MSB plane first).
    data: Vec<u64>,
    /// Optional occupancy index for the truncating sparse fast path;
    /// built on demand by [`WeavedMatrix::build_plane_index`].
    index: Option<PlaneIndex>,
}

impl WeavedMatrix {
    /// Quantize a dense matrix (one stochastic draw) and weave it.
    pub fn quantize(a: &Matrix, scale: &ColumnScale, bits: u32, rng: &mut Rng) -> Self {
        Self::quantize_rows(&a.data, a.rows, a.cols, scale, bits, rng)
    }

    /// Quantize a row-major slice (`data.len() == rows * cols`) — the
    /// per-shard ingestion entry point (no intermediate Matrix copy).
    pub fn quantize_rows(
        data: &[f32],
        rows: usize,
        cols: usize,
        scale: &ColumnScale,
        bits: u32,
        rng: &mut Rng,
    ) -> Self {
        let s = crate::quant::intervals_for_bits(bits);
        let mut idx = vec![0u16; rows * cols];
        crate::quant::stochastic::quantize_indices(data, cols, &scale.m, s, rng, &mut idx);
        Self::from_indices(rows, cols, bits, s, scale.clone(), &idx)
    }

    /// Weave pre-quantized level indices (each < 2^bits).
    pub fn from_indices(
        rows: usize,
        cols: usize,
        bits: u32,
        s: u32,
        scale: ColumnScale,
        idx: &[u16],
    ) -> Self {
        assert!((1..=16).contains(&bits), "weaved width must be 1..=16, got {bits}");
        assert_eq!(idx.len(), rows * cols);
        let wpp = cols.div_ceil(64);
        let stride = bits as usize * wpp;
        let mut data = vec![0u64; rows * stride];
        for r in 0..rows {
            let row = &mut data[r * stride..(r + 1) * stride];
            for (c, &v) in idx[r * cols..(r + 1) * cols].iter().enumerate() {
                debug_assert!((v as u32) <= s, "index {v} exceeds grid {s}");
                let (w, j) = (c / 64, c % 64);
                for t in 0..bits as usize {
                    let bit = (v >> (bits as usize - 1 - t)) & 1;
                    if bit != 0 {
                        row[t * wpp + w] |= 1u64 << j;
                    }
                }
            }
        }
        WeavedMatrix { rows, cols, bits, s, scale, words_per_plane: wpp, data, index: None }
    }

    /// Re-weave an existing packed store (identical indices, new layout).
    pub fn from_packed(p: &PackedMatrix) -> Self {
        let mut idx = vec![0u16; p.rows * p.cols];
        for r in 0..p.rows {
            for (c, o) in idx[r * p.cols..(r + 1) * p.cols].iter_mut().enumerate() {
                *o = p.index(r, c);
            }
        }
        Self::from_indices(p.rows, p.cols, p.bits, p.s, p.scale.clone(), &idx)
    }

    /// The core gather kernel: reconstruct the top-p truncated indices of
    /// word-column `w` of the row at plane offset `base`, into `out`
    /// (sliced to the live columns of this word). Shared by every reader.
    /// Word-parallel via [`super::kernel::spread_word`] — sparse planes
    /// walk set bits, dense planes spread a byte at a time; no per-bit
    /// 64-iteration loop.
    #[inline]
    fn gather_word(&self, base: usize, w: usize, p: u32, out: &mut [u16]) {
        out.fill(0);
        let wpp = self.words_per_plane;
        for t in 0..p as usize {
            let word = self.data[base + t * wpp + w];
            super::kernel::spread_word(word, p - 1 - t as u32, out);
        }
    }

    /// All bit planes of row `r` (plane-major, `bits × words_per_plane`
    /// words) — the raw operand of the fused weaved-domain kernels.
    #[inline]
    pub(crate) fn row_planes(&self, r: usize) -> &[u64] {
        let stride = self.bits as usize * self.words_per_plane;
        &self.data[r * stride..(r + 1) * stride]
    }

    /// Read row `r` at precision `p` (1..=bits): `out[c]` gets the top-p
    /// truncation `index(r, c) >> (bits − p)`. Returns the bytes touched —
    /// exactly the p plane spans of this row.
    pub fn read_row(&self, r: usize, p: u32, out: &mut [u16]) -> usize {
        assert!(p >= 1 && p <= self.bits, "precision {p} outside 1..={}", self.bits);
        let base = r * self.bits as usize * self.words_per_plane;
        for (w, chunk) in out[..self.cols].chunks_mut(64).enumerate() {
            self.gather_word(base, w, p, chunk);
        }
        self.bytes_per_row(p)
    }

    /// Dequantize row `r` read at precision `p` onto the 2^p−1-interval
    /// grid. At p = bits this is bit-identical to
    /// `PackedMatrix::dequantize_row` over the same indices. Returns bytes
    /// touched.
    pub fn dequantize_row_at(&self, r: usize, p: u32, out: &mut [f32]) -> usize {
        assert!(p >= 1 && p <= self.bits, "precision {p} outside 1..={}", self.bits);
        let sp = (1u32 << p) - 1;
        let inv_s2 = 2.0 / sp as f32;
        let m = &self.scale.m;
        let wpp = self.words_per_plane;
        let base = r * self.bits as usize * wpp;
        let mut idx = [0u16; 64];
        for w in 0..wpp {
            let c0 = w * 64;
            let lim = (self.cols - c0).min(64);
            self.gather_word(base, w, p, &mut idx[..lim]);
            for (j, &v) in idx[..lim].iter().enumerate() {
                out[c0 + j] = (v as f32 * inv_s2 - 1.0) * m[c0 + j];
            }
        }
        self.bytes_per_row(p)
    }

    /// Stochastic (double-sampling) read of row `r` at precision `p`:
    /// `out[c]` gets the *augmented coarse* sample `h + C ∈ 0..=2^p`, where
    /// `h` is the top-p truncation and `C` is a Bernoulli carry with
    /// probability `residual / 2^(bits−p)` drawn from the discarded low
    /// planes ([`super::kernel::carry_mask_word`]). The sample dequantizes
    /// on the *fine* grid as `(h+C)·2^(bits−p)`, whose expectation is
    /// exactly the stored index — an unbiased any-precision read from the
    /// single stored copy (DESIGN.md §5). At p = bits the carry is zero
    /// and the read degenerates to the exact full-width read. Returns the
    /// wire bytes of the draw: the p plane spans a truncating read of this
    /// row would move (see DESIGN.md §5 on the accounting boundary).
    pub fn read_row_ds(&self, r: usize, p: u32, rng: &mut Rng, out: &mut [u16]) -> usize {
        assert!(p >= 1 && p <= self.bits, "precision {p} outside 1..={}", self.bits);
        let wpp = self.words_per_plane;
        let stride = self.bits as usize * wpp;
        let base = r * stride;
        let planes = &self.data[base..base + stride];
        let mut thresholds = super::kernel::BufferedThresholds::new(rng);
        for (w, chunk) in out[..self.cols].chunks_mut(64).enumerate() {
            self.gather_word(base, w, p, chunk);
            let mut carry =
                super::kernel::carry_mask_word(planes, wpp, self.bits, p, w, &mut thresholds);
            while carry != 0 {
                let j = carry.trailing_zeros() as usize;
                // tail carry bits can't exist: residual planes store 0 there
                chunk[j] += 1;
                carry &= carry - 1;
            }
        }
        self.bytes_per_row(p)
    }

    /// Dequantize one stochastic p-plane draw of row `r` onto the stored
    /// (full-width) grid: `out[c] = ((h+C)·2^(bits−p) · 2/s − 1) · m[c]`.
    /// Unbiased for [`WeavedMatrix::dequantize_row_at`] at p = bits — the
    /// materializing oracle of the fused DS kernels, consuming carry
    /// randomness in the same order. Returns the wire bytes of the draw.
    pub fn dequantize_row_ds(&self, r: usize, p: u32, rng: &mut Rng, out: &mut [f32]) -> usize {
        assert!(p >= 1 && p <= self.bits, "precision {p} outside 1..={}", self.bits);
        let wpp = self.words_per_plane;
        let stride = self.bits as usize * wpp;
        let base = r * stride;
        let planes = &self.data[base..base + stride];
        let q = (1u32 << (self.bits - p)) as f32;
        let inv_s2 = 2.0 / self.s as f32;
        let m = &self.scale.m;
        let mut idx = [0u16; 64];
        let mut thresholds = super::kernel::BufferedThresholds::new(rng);
        for w in 0..wpp {
            let c0 = w * 64;
            let lim = (self.cols - c0).min(64);
            self.gather_word(base, w, p, &mut idx[..lim]);
            let carry =
                super::kernel::carry_mask_word(planes, wpp, self.bits, p, w, &mut thresholds);
            for (j, &h) in idx[..lim].iter().enumerate() {
                let fine = (h as f32 + ((carry >> j) & 1) as f32) * q;
                out[c0 + j] = (fine * inv_s2 - 1.0) * m[c0 + j];
            }
        }
        self.bytes_per_row(p)
    }

    /// Single-element read at precision `p` (diagnostics/tests).
    pub fn index_at(&self, r: usize, c: usize, p: u32) -> u16 {
        assert!(p >= 1 && p <= self.bits);
        let wpp = self.words_per_plane;
        let base = r * self.bits as usize * wpp;
        let (w, j) = (c / 64, c % 64);
        let mut v = 0u16;
        for t in 0..p as usize {
            let bit = ((self.data[base + t * wpp + w] >> j) & 1) as u16;
            v |= bit << (p as usize - 1 - t);
        }
        v
    }

    /// Bytes a precision-`p` row read touches: p plane spans of this row.
    pub fn bytes_per_row(&self, p: u32) -> usize {
        p as usize * self.words_per_plane * 8
    }

    /// Total stored payload (all planes; one copy serves every precision).
    pub fn bytes(&self) -> usize {
        self.data.len() * 8
    }

    pub fn words_per_plane(&self) -> usize {
        self.words_per_plane
    }

    /// Build (or rebuild) the per-plane occupancy index. Idempotent over
    /// the immutable plane data; kernels pick it up on the next call.
    pub fn build_plane_index(&mut self) {
        let rpp = self.runs_per_plane();
        let mut occ = vec![0u8; self.rows * self.bits as usize * rpp];
        for (pi, plane) in self.data.chunks(self.words_per_plane.max(1)).enumerate() {
            for (wi, &word) in plane.iter().enumerate() {
                if word != 0 {
                    occ[pi * rpp + wi / 8] |= 1 << (wi % 8);
                }
            }
        }
        self.index = Some(PlaneIndex { occ, runs_per_plane: rpp });
    }

    /// Whether the occupancy index is resident (host trace metadata).
    pub fn has_plane_index(&self) -> bool {
        self.index.is_some()
    }

    /// Bytes held by the occupancy index — derived metadata, reported
    /// separately from [`WeavedMatrix::bytes`] and never part of any
    /// per-read wire-byte figure (DESIGN.md §12).
    pub fn index_bytes(&self) -> usize {
        self.index.as_ref().map_or(0, |ix| ix.occ.len())
    }

    /// Occupancy bytes per plane: ceil(words_per_plane / 8). Valid even
    /// before the index is built (kernels hoist it outside row loops).
    #[inline]
    pub(crate) fn runs_per_plane(&self) -> usize {
        self.words_per_plane.div_ceil(8)
    }

    /// Occupancy bytes of row `r` (`bits × runs_per_plane`, plane-major —
    /// mirroring [`WeavedMatrix::row_planes`]), if the index is built.
    #[inline]
    pub(crate) fn row_plane_occ(&self, r: usize) -> Option<&[u8]> {
        self.index.as_ref().map(|ix| {
            let stride = self.bits as usize * ix.runs_per_plane;
            &ix.occ[r * stride..(r + 1) * stride]
        })
    }

    /// Deliberately violate the tail contract (set a bit at or beyond the
    /// live columns in the MSB plane of row `r`) — used by the kernel
    /// guard regression tests only.
    #[cfg(test)]
    pub(crate) fn poison_tail_bit_for_test(&mut self, r: usize) {
        assert!(self.cols % 64 != 0, "poisoning needs a ragged tail word");
        let wpp = self.words_per_plane;
        let base = r * self.bits as usize * wpp;
        self.data[base + wpp - 1] |= 1u64 << (self.cols % 64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(rows: usize, cols: usize, seed: u64) -> (Matrix, ColumnScale) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let a = Matrix::from_vec(rows, cols, data);
        let s = ColumnScale::from_data(&a);
        (a, s)
    }

    #[test]
    fn full_width_read_matches_packed_indices() {
        let (a, sc) = mk(9, 70, 1);
        for bits in [1u32, 3, 8, 12, 16] {
            let mut rng = Rng::new(2);
            let p = PackedMatrix::quantize(&a, &sc, bits, &mut rng);
            let w = WeavedMatrix::from_packed(&p);
            let mut idx = vec![0u16; 70];
            for r in 0..9 {
                w.read_row(r, bits, &mut idx);
                for c in 0..70 {
                    assert_eq!(idx[c], p.index(r, c), "bits={bits} r={r} c={c}");
                }
            }
        }
    }

    #[test]
    fn truncated_read_is_top_planes() {
        let (a, sc) = mk(6, 130, 3);
        let mut rng = Rng::new(4);
        let packed = PackedMatrix::quantize(&a, &sc, 8, &mut rng);
        let w = WeavedMatrix::from_packed(&packed);
        let mut idx = vec![0u16; 130];
        for p in 1..=8u32 {
            for r in 0..6 {
                w.read_row(r, p, &mut idx);
                for c in 0..130 {
                    assert_eq!(idx[c], packed.index(r, c) >> (8 - p), "p={p} r={r} c={c}");
                    assert_eq!(idx[c], w.index_at(r, c, p));
                }
            }
        }
    }

    #[test]
    fn full_width_dequantize_bit_identical_to_packed() {
        let (a, sc) = mk(12, 33, 5);
        let mut rng = Rng::new(6);
        let packed = PackedMatrix::quantize(&a, &sc, 7, &mut rng);
        let w = WeavedMatrix::from_packed(&packed);
        let (mut dp, mut dw) = (vec![0.0f32; 33], vec![0.0f32; 33]);
        for r in 0..12 {
            packed.dequantize_row(r, &mut dp);
            w.dequantize_row_at(r, 7, &mut dw);
            assert_eq!(dp, dw, "row {r}");
        }
    }

    #[test]
    fn bytes_scale_linearly_with_precision() {
        let (a, sc) = mk(4, 100, 7);
        let mut rng = Rng::new(8);
        let w = WeavedMatrix::quantize(&a, &sc, 8, &mut rng);
        // 100 cols → 2 words/plane → 16 B per plane per row
        assert_eq!(w.bytes_per_row(1), 16);
        assert_eq!(w.bytes_per_row(4), 64);
        assert_eq!(w.bytes_per_row(8), 128);
        let mut out = vec![0.0f32; 100];
        assert_eq!(w.dequantize_row_at(0, 2, &mut out), 32);
        // one stored copy = the full-width payload
        assert_eq!(w.bytes(), 4 * 8 * 2 * 8);
    }

    #[test]
    fn low_precision_read_stays_near_value() {
        // top-p truncation is at worst one coarse-grid interval away
        let (a, sc) = mk(16, 24, 9);
        let mut rng = Rng::new(10);
        let w = WeavedMatrix::quantize(&a, &sc, 8, &mut rng);
        let mut out = vec![0.0f32; 24];
        for p in [2u32, 4] {
            let sp = (1u32 << p) - 1;
            for r in 0..16 {
                w.dequantize_row_at(r, p, &mut out);
                for (c, &q) in out.iter().enumerate() {
                    let m = w.scale.m[c];
                    if m == 0.0 {
                        assert_eq!(q, 0.0);
                        continue;
                    }
                    // coarse interval + one fine interval of slack
                    let width = 2.0 * m / sp as f32 + 2.0 * m / w.s as f32;
                    let v = a.get(r, c);
                    assert!((q - v).abs() <= width + 1e-4, "p={p} q={q} v={v} width={width}");
                }
            }
        }
    }

    /// Stochastic reads: every draw is the truncation or one coarse ulp
    /// above it, the dequantized draw brackets the stored value within one
    /// coarse interval, and p = bits degenerates to the exact read without
    /// consuming randomness.
    #[test]
    fn ds_read_brackets_stored_value() {
        let (a, sc) = mk(10, 70, 13);
        let mut rng = Rng::new(14);
        let packed = PackedMatrix::quantize(&a, &sc, 8, &mut rng);
        let w = WeavedMatrix::from_packed(&packed);
        let mut idx = vec![0u16; 70];
        let mut val = vec![0.0f32; 70];
        let mut stored = vec![0.0f32; 70];
        for p in 1..=8u32 {
            let q = 1u32 << (8 - p);
            for r in 0..10 {
                let bytes = w.read_row_ds(r, p, &mut rng, &mut idx);
                assert_eq!(bytes, w.bytes_per_row(p), "wire bytes = p plane spans");
                w.dequantize_row_ds(r, p, &mut rng, &mut val);
                w.dequantize_row_at(r, 8, &mut stored);
                for c in 0..70 {
                    let h = packed.index(r, c) >> (8 - p);
                    assert!(
                        idx[c] == h || idx[c] == h + 1,
                        "p={p} r={r} c={c}: draw {} vs truncation {h}",
                        idx[c]
                    );
                    // residual 0 never carries
                    if packed.index(r, c) % q as u16 == 0 {
                        assert_eq!(idx[c], h, "carry on zero residual");
                    }
                    // one coarse interval brackets the stored value
                    let coarse = q as f32 * 2.0 * sc.m[c] / w.s as f32;
                    assert!(
                        (val[c] - stored[c]).abs() <= coarse + 1e-5,
                        "p={p} r={r} c={c}: {} vs stored {}",
                        val[c],
                        stored[c]
                    );
                }
            }
        }
        // p = bits: exact, bit-identical to the deterministic read
        let mut exact = vec![0.0f32; 70];
        for r in 0..10 {
            w.dequantize_row_ds(r, 8, &mut rng, &mut val);
            w.dequantize_row_at(r, 8, &mut exact);
            assert_eq!(val, exact, "row {r}");
        }
    }

    /// The mean stochastic draw converges to the stored value (the §2.2
    /// unbiasedness this layer must provide; the full CLT-budgeted harness
    /// lives in tests/ds_statistics.rs).
    #[test]
    fn ds_read_unbiased_smoke() {
        let (a, sc) = mk(2, 20, 15);
        let mut rng = Rng::new(16);
        let w = WeavedMatrix::quantize(&a, &sc, 8, &mut rng);
        let p = 3u32;
        let n = 4000;
        let mut val = vec![0.0f32; 20];
        let mut acc = vec![0.0f64; 20];
        let mut stored = vec![0.0f32; 20];
        for _ in 0..n {
            w.dequantize_row_ds(0, p, &mut rng, &mut val);
            for (a, &v) in acc.iter_mut().zip(&val) {
                *a += v as f64;
            }
        }
        w.dequantize_row_at(0, 8, &mut stored);
        let q = (1u32 << (8 - p)) as f64;
        for c in 0..20 {
            let mean = acc[c] / n as f64;
            let coarse = q * 2.0 * sc.m[c] as f64 / w.s as f64;
            let tol = 5.0 * (coarse / 2.0) / (n as f64).sqrt() + 1e-6;
            assert!(
                (mean - stored[c] as f64).abs() <= tol,
                "c={c}: mean {mean} vs stored {} (tol {tol})",
                stored[c]
            );
        }
    }

    /// The occupancy index marks exactly the nonzero plane words, its
    /// bytes are accounted apart from the payload, and building it leaves
    /// every wire-byte figure unchanged.
    #[test]
    fn plane_index_marks_nonzero_words_and_separate_bytes() {
        let (a, sc) = mk(7, 200, 21);
        let mut rng = Rng::new(22);
        let mut w = WeavedMatrix::quantize(&a, &sc, 6, &mut rng);
        let (bytes, per_row) = (w.bytes(), w.bytes_per_row(3));
        assert!(!w.has_plane_index());
        assert_eq!(w.index_bytes(), 0);
        assert_eq!(w.row_plane_occ(0), None);
        w.build_plane_index();
        assert!(w.has_plane_index());
        // 200 cols → 4 words/plane → 1 occupancy byte per plane
        let rpp = w.runs_per_plane();
        assert_eq!(rpp, 1);
        assert_eq!(w.index_bytes(), 7 * 6 * rpp);
        // wire/payload accounting is untouched by the derived index
        assert_eq!(w.bytes(), bytes);
        assert_eq!(w.bytes_per_row(3), per_row);
        let wpp = w.words_per_plane();
        for r in 0..7 {
            let occ = w.row_plane_occ(r).unwrap();
            assert_eq!(occ.len(), 6 * rpp);
            let planes = w.row_planes(r);
            for t in 0..6usize {
                for (wi, &word) in planes[t * wpp..(t + 1) * wpp].iter().enumerate() {
                    let bit = (occ[t * rpp + wi / 8] >> (wi % 8)) & 1;
                    assert_eq!(bit == 1, word != 0, "r={r} t={t} wi={wi}");
                }
            }
        }
    }

    #[test]
    fn zero_scale_columns_read_zero() {
        let a = Matrix::from_vec(2, 3, vec![0.0, 1.0, -1.0, 0.0, 0.5, 0.25]);
        let sc = ColumnScale::from_data(&a);
        assert_eq!(sc.m[0], 0.0);
        let mut rng = Rng::new(11);
        let w = WeavedMatrix::quantize(&a, &sc, 6, &mut rng);
        let mut out = vec![0.0f32; 3];
        for p in 1..=6u32 {
            for r in 0..2 {
                w.dequantize_row_at(r, p, &mut out);
                assert_eq!(out[0], 0.0);
            }
        }
    }
}
