//! Portable-SIMD (`std::simd`) twins of the dense kernel primitives —
//! compiled only under the `simd` cargo feature on the pinned nightly,
//! still `#![forbid(unsafe_code)]`: `std::simd` is a safe API that
//! compiles for the baseline target, which is exactly why runtime
//! dispatch can be a cached probe ([`super::dispatch`]) instead of
//! unsafe fn-pointer multiversioning.
//!
//! **Bit-equality contract** (tests/simd_twins.rs, DESIGN.md §12): every
//! function here returns bit-for-bit what its scalar twin returns. That
//! contract pins the implementation shape:
//!
//! * [`masked_sum_dense`] keeps the scalar twin's exact 8-lane schedule:
//!   the single `f32x8` accumulator *is* the scalar `[f32; 8]`
//!   accumulator array (lane j only ever adds `g[8c+j]`, in the same
//!   chunk order), the ragged tail runs the scalar remainder loop on the
//!   extracted lane array, and the final reduction is the same fixed
//!   tree — NOT `reduce_sum`, whose association order is unspecified.
//!   Wider vectors (16/32 lanes) would change the association order of
//!   the per-lane partial sums and are therefore not candidates at this
//!   API: the lane count is part of the kernel's numeric contract.
//! * Select masks AND at full f32 bit width, so unset lanes add the same
//!   `+0.0` the scalar path adds (never `-0.0`): `v + (+0.0)` is
//!   bit-preserving for every value the kernels accumulate onto.
//! * The DS carry compare has **no** twin here — deliberately. One of
//!   this codebase's "cannots": [`super::carry_mask_word`] is already
//!   SIMD-within-a-register (64 column lanes per u64 bit-op), its early
//!   stop makes the threshold count data-dependent, and batching words
//!   or planes would reorder the pinned RNG draw stream that every DS
//!   reader is property-tested against. Both tiers share the scalar
//!   SWAR compare.

use std::simd::num::SimdFloat;
use std::simd::{f32x8, u32x8};

/// Per-lane bit positions of one 8-column group within its plane byte.
const LANE_SHIFTS: u32x8 = u32x8::from_array([0, 1, 2, 3, 4, 5, 6, 7]);

/// Expand the low byte of `w` into the scalar twin's keep masks: lane j
/// is all-ones iff bit j is set — the vector form of the scalar path's
/// `0u32.wrapping_sub(bit)` (`0 - x` wraps lanewise on integer vectors).
#[inline]
fn keep_mask(w: u64) -> u32x8 {
    let bits = (u32x8::splat((w & 0xFF) as u32) >> LANE_SHIFTS) & u32x8::splat(1);
    u32x8::splat(0) - bits
}

/// SIMD twin of [`super::masked_sum_dense`], bit-identical by
/// construction (same lane schedule, same remainder handling, same
/// reduction tree — see the module docs).
#[inline]
pub fn masked_sum_dense(word: u64, g: &[f32]) -> f32 {
    let g = &g[..g.len().min(64)];
    let mut vacc = f32x8::splat(0.0);
    let mut w = word;
    let mut chunks = g.chunks_exact(8);
    for c8 in &mut chunks {
        let gv = f32x8::from_slice(c8);
        vacc += f32x8::from_bits(gv.to_bits() & keep_mask(w));
        w >>= 8;
    }
    let mut acc = vacc.to_array();
    for (j, &gv) in chunks.remainder().iter().enumerate() {
        let keep = 0u32.wrapping_sub(((w >> j) & 1) as u32);
        acc[j] += f32::from_bits(gv.to_bits() & keep);
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// SIMD twin of [`super::select_add_word_scalar`]: identical per-column
/// additions in identical order; unset lanes add a masked `+0.0`. Keeps
/// the scalar twin's tail-contract guard so the poisoned-tail regression
/// twins trip in this tier too.
#[inline]
pub fn select_add_word(word: u64, wgt: f32, m: &[f32], out: &mut [f32]) {
    let lanes = m.len().min(out.len()).min(64);
    debug_assert!(
        lanes >= 64 || word >> lanes == 0,
        "plane word has set bits at or beyond lane {lanes}: the weaved tail contract \
         (bits beyond the live columns are zero) is violated"
    );
    let m = &m[..lanes];
    let out = &mut out[..lanes];
    let wv = f32x8::splat(wgt);
    let mut w = word;
    let mut oc = out.chunks_exact_mut(8);
    let mut mc = m.chunks_exact(8);
    for (o8, m8) in (&mut oc).zip(&mut mc) {
        let add = f32x8::from_bits((wv * f32x8::from_slice(m8)).to_bits() & keep_mask(w));
        o8.copy_from_slice(&(f32x8::from_slice(o8) + add).to_array());
        w >>= 8;
    }
    for (j, (o, &mv)) in oc.into_remainder().iter_mut().zip(mc.remainder()).enumerate() {
        let keep = 0u32.wrapping_sub(((w >> j) & 1) as u32);
        *o += f32::from_bits((wgt * mv).to_bits() & keep);
    }
}

#[cfg(test)]
mod tests {
    use crate::rng::Rng;

    /// In-module smoke of the bit-equality contract (the exhaustive
    /// shapes × bits × multipliers suite is tests/simd_twins.rs): random
    /// words and ±0.0-seeded inputs, full and ragged lane counts.
    #[test]
    fn simd_twins_bit_identical_smoke() {
        let mut rng = Rng::new(71);
        for lanes in [64usize, 63, 17, 9, 8, 7, 1] {
            let mut g: Vec<f32> = (0..lanes).map(|_| rng.normal()).collect();
            if lanes > 2 {
                g[1] = -0.0; // signed-zero operand must survive masking
                g[2] = 0.0;
            }
            for trial in 0..50 {
                let dense = rng.next_u64();
                let sparse = dense & rng.next_u64() & rng.next_u64();
                for word in [dense, sparse, 0, u64::MAX] {
                    let masked = if lanes == 64 { word } else { word & ((1u64 << lanes) - 1) };
                    assert_eq!(
                        super::masked_sum_dense(masked, &g).to_bits(),
                        crate::store::kernel::masked_sum_dense(masked, &g).to_bits(),
                        "masked_sum lanes={lanes} trial={trial} word={masked:#x}"
                    );
                    let wgt = rng.normal();
                    let mut a: Vec<f32> = (0..lanes).map(|_| rng.normal()).collect();
                    let mut b = a.clone();
                    super::select_add_word(masked, wgt, &g, &mut a);
                    crate::store::kernel::select_add_word_scalar(masked, wgt, &g, &mut b);
                    for (j, (x, y)) in a.iter().zip(&b).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "select_add lanes={lanes} trial={trial} j={j}"
                        );
                    }
                }
            }
        }
    }
}
