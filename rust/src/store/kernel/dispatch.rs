//! One-time-probed runtime dispatch for the kernel primitives.
//!
//! The crate is `#![forbid(unsafe_code)]`, which rules out the classic
//! `#[target_feature]` fn-pointer multiversioning (calling a
//! target-feature function is `unsafe`). Portable SIMD gives us the safe
//! alternative: `std::simd` code compiles for the *baseline* target and
//! is always sound to call, so "dispatch" reduces to picking which safe
//! twin to run — a pure decision, probed once and cached.
//!
//! * Without the `simd` cargo feature, [`tier`] is an `#[inline(always)]`
//!   constant `Tier::Scalar`: the stable default build const-folds every
//!   dispatch site away and is bit-for-bit (and codegen-wise) the
//!   pre-dispatch scalar crate.
//! * With the feature (pinned nightly), the first [`tier`] call probes
//!   the target and the `ZIPML_SIMD` kill switch, then caches the result
//!   in a relaxed atomic — subsequent calls are one relaxed load, cheap
//!   enough to sit inside `masked_sum` itself.
//!
//! Every *call site* that branches on [`tier`] must carry a
//! `// twin: <scalar_fn> (<bit_equality_test>)` comment naming the
//! scalar twin it dispatches against and the test pinning their
//! bit-equality — enforced by zipml-lint's `twin-contract-v2` rule,
//! which also checks the named test exists (DESIGN.md §12, §13).

/// Kernel implementation tier. Discriminants double as the probe-cache
/// encoding (0 is reserved for "unprobed").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Portable scalar lane loops — the stable-toolchain default and the
    /// bit-exactness oracle every other tier is property-tested against.
    Scalar = 1,
    /// `std::simd` 8-lane twins (`simd` feature, nightly): same 8-lane
    /// accumulator schedule as the scalar path, one `f32x8` per chunk.
    Lanes8 = 2,
}

impl Tier {
    /// Stable label for trace `run` events and `BENCH_kernels.json`.
    pub fn label(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Lanes8 => "simd8",
        }
    }
}

/// Label of the active tier (host traces, bench JSON).
pub fn tier_label() -> &'static str {
    tier().label()
}

/// The active kernel tier. Without the `simd` feature there is exactly
/// one tier, and the call const-folds to `Tier::Scalar` — zero
/// behavioral change for the stable default build.
#[cfg(not(feature = "simd"))]
#[inline(always)]
pub fn tier() -> Tier {
    Tier::Scalar
}

/// The active kernel tier: probed once (target arch + the `ZIPML_SIMD`
/// env kill switch), then served from a relaxed atomic cache.
#[cfg(feature = "simd")]
#[inline]
pub fn tier() -> Tier {
    probe::get()
}

/// Pin the dispatch tier — the A/B lever for the twin property suite
/// (tests/simd_twins.rs) and the bench's scalar-vs-simd section.
/// Overwrites the probe cache; subsequent [`tier`] calls return `t`
/// until forced again. Process-global: tests that force tiers must not
/// run concurrently with other tier-forcing tests.
#[cfg(feature = "simd")]
pub fn force_tier(t: Tier) {
    probe::force(t);
}

#[cfg(feature = "simd")]
mod probe {
    use super::Tier;

    /// Probe cache: 0 = unprobed, otherwise a `Tier` discriminant.
    /// (Under `--cfg loom` the shimmed atomics cannot live in a static;
    /// the probe is pure, so the loom build just re-probes per call.)
    #[cfg(not(loom))]
    static TIER: crate::sync::AtomicU32 = crate::sync::AtomicU32::new(0);

    fn run() -> u32 {
        // ZIPML_SIMD=scalar is the kill switch / out-of-process A-B
        // lever: force the scalar twins even where SIMD is available.
        if std::env::var_os("ZIPML_SIMD").is_some_and(|v| v == "scalar") {
            return Tier::Scalar as u32;
        }
        // std::simd compiles everywhere; 8 f32 lanes map onto one AVX2
        // half-register (x86-64) or two NEON registers (aarch64). On
        // targets without native wide lanes the scalar schedule is at
        // least as good, so the probe stays conservative.
        if cfg!(any(target_arch = "x86_64", target_arch = "aarch64")) {
            Tier::Lanes8 as u32
        } else {
            Tier::Scalar as u32
        }
    }

    fn decode(t: u32) -> Tier {
        if t == Tier::Lanes8 as u32 {
            Tier::Lanes8
        } else {
            Tier::Scalar
        }
    }

    #[cfg(not(loom))]
    pub(super) fn get() -> Tier {
        // ordering: relaxed — idempotent one-time probe cache: every
        // racing prober computes and publishes the same value, and no
        // other memory depends on observing the publication
        let mut t = TIER.load(crate::sync::Ordering::Relaxed);
        if t == 0 {
            t = run();
            // ordering: relaxed — same idempotent-cache contract
            TIER.store(t, crate::sync::Ordering::Relaxed);
        }
        decode(t)
    }

    #[cfg(loom)]
    pub(super) fn get() -> Tier {
        decode(run())
    }

    #[cfg(not(loom))]
    pub(super) fn force(t: Tier) {
        // ordering: relaxed — test/bench override of the idempotent cache
        TIER.store(t as u32, crate::sync::Ordering::Relaxed);
    }

    #[cfg(loom)]
    pub(super) fn force(_t: Tier) {}
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    /// The probe is sticky and labeled; the feature-off build is pinned
    /// to the scalar tier (the zero-behavioral-change contract).
    #[test]
    fn tier_is_stable_and_labeled() {
        let t = tier();
        assert_eq!(t, tier(), "probe must be sticky");
        assert!(matches!(t.label(), "scalar" | "simd8"));
        #[cfg(not(feature = "simd"))]
        assert_eq!(t, Tier::Scalar, "stable default build must stay scalar");
    }
}
