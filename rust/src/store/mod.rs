//! The any-precision SampleStore subsystem (DESIGN.md §4).
//!
//! The paper's end-to-end speedup is a memory-bandwidth argument: epoch
//! time scales with the bytes of quantized sample data read per epoch.
//! The original [`crate::quant::packing::PackedMatrix`] bakes one bit
//! width into the stored copy; retraining at another precision means
//! re-quantizing and re-storing. This module stores the quantized data
//! **once**, bit-plane interleaved, and lets every reader pick its own
//! precision per read:
//!
//! * [`weave`] — [`WeavedMatrix`]: word-level bit-plane transpose with
//!   `read_row(p)` at any `p ∈ 1..=bits` and exact bytes-touched
//!   accounting (MLWeaving's layout).
//! * [`shard`] — [`ShardedStore`]: cache-line-aligned row shards,
//!   parallel deterministic ingestion ("quantize during the first
//!   epoch"), concurrent readers, and the deterministic
//!   [`MinibatchIter`] that partitions an epoch across workers.
//! * [`precision_schedule`] — per-epoch precision policies (fixed,
//!   step-up, refetch-triggered) consumed by the SGD driver.
//! * [`kernel`] — word-parallel fused kernels computing dot products and
//!   gradient accumulations *in the weaved domain* (no f32 row
//!   materialization); [`StepKernel`] holds the per-step `g = m ⊙ x`
//!   precompute. The dot side runs a lane-parallel select-add masked sum,
//!   and multi-row **blocked** kernels process a whole shard visit against
//!   one resident kernel, bit-for-bit equal to the per-row kernels
//!   (DESIGN.md §8). Reads come in two flavors: deterministic top-p
//!   *truncation* (biased below the stored width) and *stochastic* draws
//!   whose Bernoulli carry is sourced from the residual planes — exactly
//!   unbiased for the stored value at any p, serving both independent
//!   draws of the paper's §2.2 double-sampled gradient from the single
//!   stored copy (DESIGN.md §5). An opt-in popcount fast path
//!   ([`QuantStepKernel`]) stochastically rounds `g` itself onto q bit
//!   planes so the dot's inner loop is pure integer AND+POPCNT.
//!
//! Consumers: `sgd::driver` (store-backed training path, selectable via
//! `TrainConfig::store`; the host twins run the fused truncating and
//! double-sampling paths), `fpga::pipeline` (epoch seconds from
//! store-derived bytes), `fpga::hogwild` (lock-free multi-threaded fused
//! shard reads, truncating and double-sampled).

pub mod kernel;
pub mod precision_schedule;
pub mod shard;
pub mod weave;

pub use kernel::{QuantStepKernel, StepKernel};
pub use precision_schedule::{PrecisionSchedule, ScheduleState};
pub use shard::{MinibatchIter, ShardedStore};
pub use weave::WeavedMatrix;
