//! Word-parallel, plane-major fused kernels over the weaved layout —
//! computing *in the weaved domain* (MLWeaving, arXiv 1903.03404) so the
//! training hot loop never materializes an f32 row.
//!
//! Two layers:
//!
//! * **Gather** — [`spread_word`] scatters one plane word into the `u16`
//!   index outputs without a 64-iteration dependent loop: sparse words walk
//!   their set bits via `trailing_zeros`, dense words spread a byte at a
//!   time through a 256-entry lookup table. `WeavedMatrix::read_row` is
//!   built on this.
//! * **Fused compute** — [`dot_row`] and [`axpy_row`] evaluate dot products
//!   and gradient accumulations straight from the bit planes using the
//!   identity (DESIGN.md §4, "weaved-domain kernels"):
//!
//!   ```text
//!   dequant_p(row)[c] = (idx_p[c] · 2/s_p − 1) · m[c]
//!   idx_p[c]          = Σ_t 2^(p−1−t) · bit_t[c]
//!   dot(dequant_p(row), x)
//!       = (2/s_p) · Σ_t 2^(p−1−t) · maskedsum(plane_t, g) − Σ_c g[c]
//!   ```
//!
//!   with `g[c] = m[c]·x[c]` precomputed once per SGD step ([`StepKernel`]).
//!   Only the set bits of the p requested planes are touched; zero-scale
//!   columns contribute exactly 0 through `g`. FLOPs per row ≈ popcount of
//!   the touched planes plus one fused multiply-add per plane — versus
//!   gather + per-column dequant + dot for the materializing path.
//!
//! Accumulation order is fixed (plane-major, then word, then ascending bit)
//! and plane sums are carried in f64, so results are deterministic and
//! within ~1e-7 relative of the dequantize-then-`tensor::dot` oracle (the
//! property suite pins ≤ 1e-4). Exact bit-equality with the oracle is not
//! possible — the two paths round in different summation orders — which is
//! why `WeavedMatrix::dequantize_row_at` stays as the validation oracle.
//!
//! * **Stochastic (double-sampling) reads** — [`carry_mask_word`] turns the
//!   *residual* planes (the b−p low planes a truncating reader discards)
//!   into an exact per-column Bernoulli carry: column c gains one coarse
//!   ulp with probability r_c / 2^(b−p), where r_c is its residual. The
//!   augmented sample `(h_c + C_c)·2^(b−p)` is a fine-grid index with
//!   expectation exactly the stored index (DESIGN.md §5), so a p-plane
//!   stochastic read is *unbiased* for the stored value — the host-native
//!   form of the paper's §2.2 sampling, serving both independent draws of
//!   a double-sampled gradient from the single stored copy.
//!   [`dot_row_ds`] and [`axpy_row_planes_ds`] fuse it: the carry mask
//!   acts as one extra plane with weight 2^(b−p) under the *full-width*
//!   dequant scale 2/s:
//!
//!   ```text
//!   dot(dequant_ds(row), x)
//!       = (2/s)·[Σ_{t<p} 2^(b−1−t)·maskedsum(plane_t, g)
//!                + 2^(b−p)·maskedsum(carry, g)] − Σ_c g[c]
//!   ```
//!
//!   RNG contract: every DS reader consumes carry randomness in the same
//!   order — word 0..wpp, and per word the residual planes MSB→LSB with an
//!   early stop once all 64 comparisons are decided — so fused and
//!   materializing DS readers given equal RNG states draw identical
//!   samples (property-tested), and any DS path is deterministic in
//!   (seed, store contents, visit order).

use crate::rng::Rng;

use super::weave::WeavedMatrix;

/// Per-plane-word spread LUT: `SPREAD8[b][j] = (b >> j) & 1`.
static SPREAD8: [[u16; 8]; 256] = build_spread8();

const fn build_spread8() -> [[u16; 8]; 256] {
    let mut t = [[0u16; 8]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut j = 0usize;
        while j < 8 {
            t[b][j] = ((b >> j) & 1) as u16;
            j += 1;
        }
        b += 1;
    }
    t
}

/// Below this popcount a word is "sparse": walking set bits beats spreading
/// every byte.
const SPARSE_BITS: u32 = 8;

/// OR bit `j` of `word` into `out[j] << shift` for every set bit, without a
/// per-bit dependent loop. Bits at or beyond `out.len()` are ignored (tail
/// words of a ragged row store them as 0 anyway).
#[inline]
pub fn spread_word(word: u64, shift: u32, out: &mut [u16]) {
    if word == 0 {
        return;
    }
    if word.count_ones() <= SPARSE_BITS {
        let mut m = word;
        while m != 0 {
            let j = m.trailing_zeros() as usize;
            if j >= out.len() {
                break;
            }
            out[j] |= 1 << shift;
            m &= m - 1;
        }
    } else {
        for (chunk, byte) in out.chunks_mut(8).zip(word.to_le_bytes()) {
            if byte == 0 {
                continue;
            }
            for (o, &b) in chunk.iter_mut().zip(&SPREAD8[byte as usize]) {
                *o |= b << shift;
            }
        }
    }
}

/// Σ g[j] over the set bits of `word`. Bits beyond `g.len()` must be zero
/// (guaranteed for weaved tail words). Two alternating accumulators break
/// the f32 add-latency chain on dense planes (~32 set bits/word); the
/// summation order stays fixed, so results are deterministic.
#[inline]
fn masked_sum(mut word: u64, g: &[f32]) -> f32 {
    let (mut acc0, mut acc1) = (0.0f32, 0.0f32);
    while word != 0 {
        let j = word.trailing_zeros() as usize;
        acc0 += g[j];
        word &= word - 1;
        if word == 0 {
            break;
        }
        let j = word.trailing_zeros() as usize;
        acc1 += g[j];
        word &= word - 1;
    }
    acc0 + acc1
}

/// Per-SGD-step context for the fused kernels: `g = m ⊙ x` and its sum,
/// valid until the model `x` changes (refresh once per step — the same
/// amortization the ISSUE's identity assumes).
#[derive(Clone, Debug)]
pub struct StepKernel {
    g: Vec<f32>,
    sum_g: f32,
}

impl StepKernel {
    pub fn new(cols: usize) -> Self {
        StepKernel { g: vec![0.0; cols], sum_g: 0.0 }
    }

    /// Recompute `g[c] = m[c]·x[c]` and `Σ g` for the current model.
    pub fn refresh(&mut self, m: &[f32], x: &[f32]) {
        debug_assert_eq!(m.len(), self.g.len());
        debug_assert_eq!(x.len(), self.g.len());
        let mut acc = 0.0f64;
        for ((g, &mc), &xc) in self.g.iter_mut().zip(m).zip(x) {
            *g = mc * xc;
            acc += *g as f64;
        }
        self.sum_g = acc as f32;
    }

    pub fn g(&self) -> &[f32] {
        &self.g
    }

    pub fn sum_g(&self) -> f32 {
        self.sum_g
    }
}

/// Fused weaved-domain dot product: `dot(dequant_p(row r), x)` where `k`
/// was refreshed with (`scale.m`, `x`). Touches only the p requested bit
/// planes; never materializes indices or an f32 row.
pub fn dot_row(w: &WeavedMatrix, r: usize, p: u32, k: &StepKernel) -> f32 {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    assert_eq!(k.g.len(), w.cols, "StepKernel built for {} cols, store has {}", k.g.len(), w.cols);
    let planes = w.row_planes(r);
    let wpp = w.words_per_plane();
    let inv_s2 = 2.0 / ((1u32 << p) - 1) as f32;
    let mut acc = 0.0f64;
    for t in 0..p as usize {
        let weight = (1u64 << (p as usize - 1 - t)) as f64;
        let mut psum = 0.0f64;
        for (wi, &word) in planes[t * wpp..(t + 1) * wpp].iter().enumerate() {
            if word != 0 {
                psum += masked_sum(word, &k.g[wi * 64..]) as f64;
            }
        }
        acc += weight * psum;
    }
    (inv_s2 as f64 * acc - k.sum_g as f64) as f32
}

/// Draw the stochastic-carry mask for word-column `wi` of a row's planes:
/// bit j of the result is 1 with probability r_j / 2^(bits−p), where r_j
/// is the residual of column wi·64+j — the integer spelled by its low
/// bits−p planes. Exact Bernoulli via a bit-sliced comparison of the
/// residual against fresh uniform threshold bits, MSB first: 64 columns
/// decide in ≤ bits−p bitwise steps, one `next_u64` each, stopping early
/// once every lane's comparison is settled. At p == bits the mask is zero
/// and no randomness is consumed. Tail bits beyond the live columns stay
/// 0 (their residual planes store 0).
#[inline]
pub fn carry_mask_word(
    planes: &[u64],
    wpp: usize,
    bits: u32,
    p: u32,
    wi: usize,
    rng: &mut Rng,
) -> u64 {
    debug_assert!(p >= 1 && p <= bits);
    let mut gt = 0u64;
    let mut eq = !0u64;
    for t in p as usize..bits as usize {
        let r = planes[t * wpp + wi];
        let thresh = rng.next_u64();
        gt |= eq & r & !thresh;
        eq &= !(r ^ thresh);
        if eq == 0 {
            break;
        }
    }
    gt
}

/// Fused stochastic (double-sampling) dot product: one unbiased p-plane
/// draw of row `r`, dotted with `x` straight from the bit planes. The
/// draw's fine-grid index is `Σ_{t<p} 2^(b−1−t)·bit_t + 2^(b−p)·C`, so
/// plane weights are the *fine-grid* ones and the carry mask enters as one
/// extra plane; the affine term reuses `k.sum_g`. Each call consumes fresh
/// carry randomness — two successive calls are the two independent draws
/// of a §2.2 double-sampled gradient.
pub fn dot_row_ds(w: &WeavedMatrix, r: usize, p: u32, k: &StepKernel, rng: &mut Rng) -> f32 {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    assert_eq!(k.g.len(), w.cols, "StepKernel built for {} cols, store has {}", k.g.len(), w.cols);
    let planes = w.row_planes(r);
    let wpp = w.words_per_plane();
    let bits = w.bits as usize;
    let inv_s2 = 2.0 / w.s as f32;
    let carry_w = (1u64 << (bits - p as usize)) as f64;
    let mut acc = 0.0f64;
    for wi in 0..wpp {
        let g = &k.g[wi * 64..];
        for t in 0..p as usize {
            let word = planes[t * wpp + wi];
            if word != 0 {
                acc += (1u64 << (bits - 1 - t)) as f64 * masked_sum(word, g) as f64;
            }
        }
        let carry = carry_mask_word(planes, wpp, w.bits, p, wi, rng);
        if carry != 0 {
            acc += carry_w * masked_sum(carry, g) as f64;
        }
    }
    (inv_s2 as f64 * acc - k.sum_g as f64) as f32
}

/// Plane + carry part of the stochastic axpy: draw one unbiased p-plane
/// sample of row `r` and add `coef · dequant_ds(row)[c]` into `out`,
/// *without* the shared affine term — callers batching rows defer
/// `−(Σ coef)·m` to one [`axpy_affine`] pass, exactly like
/// [`axpy_row_planes`]. Consumes carry randomness in the shared DS order.
pub fn axpy_row_planes_ds(
    w: &WeavedMatrix,
    r: usize,
    p: u32,
    coef: f32,
    rng: &mut Rng,
    out: &mut [f32],
) {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    debug_assert_eq!(out.len(), w.cols);
    let planes = w.row_planes(r);
    let wpp = w.words_per_plane();
    let bits = w.bits as usize;
    let m = &w.scale.m;
    let inv_s2 = 2.0 / w.s as f32;
    let carry_wgt = coef * inv_s2 * (1u64 << (bits - p as usize)) as f32;
    for wi in 0..wpp {
        let c0 = wi * 64;
        for t in 0..p as usize {
            let wgt = coef * inv_s2 * (1u64 << (bits - 1 - t)) as f32;
            let mut word = planes[t * wpp + wi];
            while word != 0 {
                let j = c0 + word.trailing_zeros() as usize;
                out[j] += wgt * m[j];
                word &= word - 1;
            }
        }
        let mut carry = carry_mask_word(planes, wpp, w.bits, p, wi, rng);
        while carry != 0 {
            let j = c0 + carry.trailing_zeros() as usize;
            out[j] += carry_wgt * m[j];
            carry &= carry - 1;
        }
    }
}

/// Plane part of the fused axpy: for every set bit of the p planes of row
/// `r`, add `coef · 2^(p−1−t) · (2/s_p) · m[c]` into `sink(c, delta)`.
#[inline]
fn plane_walk(w: &WeavedMatrix, r: usize, p: u32, coef: f32, mut sink: impl FnMut(usize, f32)) {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    let planes = w.row_planes(r);
    let wpp = w.words_per_plane();
    let m = &w.scale.m;
    let inv_s2 = 2.0 / ((1u32 << p) - 1) as f32;
    for t in 0..p as usize {
        let wgt = coef * inv_s2 * (1u64 << (p as usize - 1 - t)) as f32;
        for (wi, &word) in planes[t * wpp..(t + 1) * wpp].iter().enumerate() {
            let c0 = wi * 64;
            let mut bits = word;
            while bits != 0 {
                let j = c0 + bits.trailing_zeros() as usize;
                sink(j, wgt * m[j]);
                bits &= bits - 1;
            }
        }
    }
}

/// Plane part of `out[c] += coef · dequant_p(row)[c]`; callers batching
/// many rows defer the shared affine term to one [`axpy_affine`] call.
pub fn axpy_row_planes(w: &WeavedMatrix, r: usize, p: u32, coef: f32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), w.cols);
    plane_walk(w, r, p, coef, |c, d| out[c] += d);
}

/// The affine term of the dequant identity: `out[c] -= coef_sum · m[c]`.
/// For a batch, `coef_sum` is the sum of the per-row axpy coefficients.
pub fn axpy_affine(coef_sum: f32, m: &[f32], out: &mut [f32]) {
    for (o, &mc) in out.iter_mut().zip(m) {
        *o -= coef_sum * mc;
    }
}

/// Full fused axpy for one row: `out[c] += coef · dequant_p(row)[c]`,
/// computed from bit planes (plane part + affine part), no f32 row.
pub fn axpy_row(w: &WeavedMatrix, r: usize, p: u32, coef: f32, out: &mut [f32]) {
    axpy_row_planes(w, r, p, coef, out);
    axpy_affine(coef, &w.scale.m, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scaling::ColumnScale;
    use crate::rng::Rng;
    use crate::tensor::{dot, Matrix};

    fn mk(rows: usize, cols: usize, bits: u32, seed: u64) -> (Matrix, WeavedMatrix) {
        let mut rng = Rng::new(seed);
        let mut data: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        if cols > 2 {
            // plant a zero-scale column
            for r in 0..rows {
                data[r * cols + 1] = 0.0;
            }
        }
        let a = Matrix::from_vec(rows, cols, data);
        let sc = ColumnScale::from_data(&a);
        let w = WeavedMatrix::quantize(&a, &sc, bits, &mut rng);
        (a, w)
    }

    fn rel_err(got: f64, want: f64, scale: f64) -> f64 {
        (got - want).abs() / (1.0 + want.abs() + scale)
    }

    /// Fused dot == dequantize-then-dot (≤1e-4 relative) for bits 1..=16,
    /// the ragged column counts the ISSUE names, and zero-scale columns.
    #[test]
    fn fused_dot_matches_dequant_oracle() {
        for &cols in &[63usize, 64, 65, 130] {
            for bits in [1u32, 2, 5, 8, 12, 16] {
                let (_, w) = mk(6, cols, bits, 11 + bits as u64);
                let mut rng = Rng::new(99 + cols as u64);
                let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
                let mut k = StepKernel::new(cols);
                k.refresh(&w.scale.m, &x);
                let mut row = vec![0.0f32; cols];
                for p in 1..=bits {
                    for r in 0..6 {
                        w.dequantize_row_at(r, p, &mut row);
                        let want = dot(&row, &x) as f64;
                        let got = dot_row(&w, r, p, &k) as f64;
                        let scale: f64 =
                            row.iter().zip(&x).map(|(&a, &b)| (a as f64 * b as f64).abs()).sum();
                        assert!(
                            rel_err(got, want, scale) < 1e-4,
                            "cols={cols} bits={bits} p={p} r={r}: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    /// Fused axpy (plane + affine) == dequantize-then-`tensor::axpy`.
    #[test]
    fn fused_axpy_matches_dequant_oracle() {
        for &cols in &[63usize, 64, 65, 130] {
            for bits in [1u32, 4, 9, 16] {
                let (_, w) = mk(5, cols, bits, 7 + bits as u64);
                let mut rng = Rng::new(3);
                let mut row = vec![0.0f32; cols];
                for p in [1, bits] {
                    let mut gf = vec![0.0f32; cols];
                    let mut gr = vec![0.0f64; cols];
                    let mut mag = vec![0.0f64; cols];
                    for r in 0..5 {
                        let coef = rng.normal();
                        axpy_row(&w, r, p, coef, &mut gf);
                        w.dequantize_row_at(r, p, &mut row);
                        for ((o, g), &v) in gr.iter_mut().zip(mag.iter_mut()).zip(&row) {
                            *o += coef as f64 * v as f64;
                            *g += (coef as f64 * v as f64).abs();
                        }
                    }
                    for c in 0..cols {
                        assert!(
                            rel_err(gf[c] as f64, gr[c], mag[c]) < 1e-4,
                            "cols={cols} bits={bits} p={p} c={c}: {} vs {}",
                            gf[c],
                            gr[c]
                        );
                    }
                }
            }
        }
    }

    /// Zero-scale columns: dot ignores them, axpy leaves them untouched.
    #[test]
    fn zero_scale_columns_are_inert() {
        let (_, w) = mk(4, 10, 8, 21);
        assert_eq!(w.scale.m[1], 0.0);
        let x = vec![1.0f32; 10];
        let mut k = StepKernel::new(10);
        k.refresh(&w.scale.m, &x);
        assert_eq!(k.g()[1], 0.0);
        let mut grad = vec![0.0f32; 10];
        for r in 0..4 {
            let _ = dot_row(&w, r, 8, &k);
            axpy_row(&w, r, 8, 1.5, &mut grad);
        }
        assert_eq!(grad[1], 0.0);
    }

    /// spread_word: LUT (dense) and trailing_zeros (sparse) paths agree
    /// with the reference bit extraction, including short tail outputs.
    #[test]
    fn spread_word_paths_match_reference() {
        let mut rng = Rng::new(17);
        for lim in [64usize, 63, 17, 8, 3, 1] {
            for _ in 0..50 {
                let dense = rng.next_u64();
                let sparse = dense & rng.next_u64() & rng.next_u64() & rng.next_u64();
                for word in [dense, sparse, 0, u64::MAX] {
                    let masked = if lim == 64 { word } else { word & ((1u64 << lim) - 1) };
                    let mut out = vec![0u16; lim];
                    spread_word(masked, 3, &mut out);
                    for (j, &o) in out.iter().enumerate() {
                        assert_eq!(o, (((masked >> j) & 1) as u16) << 3, "lim={lim} j={j}");
                    }
                }
            }
        }
    }

    /// The carry mask is exactly Bernoulli(residual / 2^(b−p)): degenerate
    /// residuals are deterministic, generic ones match their probability
    /// statistically, and p == bits consumes no randomness.
    #[test]
    fn carry_mask_distribution() {
        let (bits, cols) = (8u32, 64usize);
        // residual of column j is j itself at p = 2 (residual width 6)
        let idx: Vec<u16> = (0..cols as u16).collect();
        let w = WeavedMatrix::from_indices(
            1,
            cols,
            bits,
            255,
            ColumnScale { m: vec![1.0; cols] },
            &idx,
        );
        let planes = w.row_planes(0);
        let p = 2u32;
        let q = 1u64 << (bits - p); // 64
        let trials = 40_000;
        let mut counts = [0u32; 64];
        let mut rng = Rng::new(5);
        for _ in 0..trials {
            let mask = carry_mask_word(planes, w.words_per_plane(), bits, p, 0, &mut rng);
            for (j, c) in counts.iter_mut().enumerate() {
                *c += ((mask >> j) & 1) as u32;
            }
        }
        // residual 0 never carries; residual j carries w.p. j/64
        assert_eq!(counts[0], 0);
        for (j, &c) in counts.iter().enumerate() {
            let want = j as f64 / q as f64;
            let got = c as f64 / trials as f64;
            let tol = 5.0 * (want * (1.0 - want) / trials as f64).sqrt() + 1e-9;
            assert!((got - want).abs() <= tol, "col {j}: p̂ {got} vs {want} (tol {tol})");
        }
        // p == bits: no residual planes, mask identically zero, rng intact
        let mut a = Rng::new(9);
        let before = a.clone().next_u64();
        assert_eq!(carry_mask_word(planes, w.words_per_plane(), bits, bits, 0, &mut a), 0);
        assert_eq!(a.next_u64(), before, "full-width mask consumed randomness");
    }

    /// Fused DS kernels and the materializing DS oracle consume carry
    /// randomness in the same order: equal RNG states draw the same
    /// sample, so fused dot/axpy match dequantize_row_ds within tolerance.
    #[test]
    fn fused_ds_matches_dequant_ds_oracle_same_seed() {
        for &cols in &[63usize, 64, 65, 130] {
            for bits in [2u32, 5, 8, 12, 16] {
                let (_, w) = mk(5, cols, bits, 77 + bits as u64);
                let mut rng = Rng::new(3 + cols as u64);
                let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
                let mut k = StepKernel::new(cols);
                k.refresh(&w.scale.m, &x);
                let mut row = vec![0.0f32; cols];
                for p in [1u32, bits / 2 + 1, bits] {
                    for r in 0..5 {
                        let seed = 1000 + (p as u64) * 31 + r as u64;
                        let got = dot_row_ds(&w, r, p, &k, &mut Rng::new(seed)) as f64;
                        w.dequantize_row_ds(r, p, &mut Rng::new(seed), &mut row);
                        let want = dot(&row, &x) as f64;
                        let scale: f64 =
                            row.iter().zip(&x).map(|(&a, &b)| (a as f64 * b as f64).abs()).sum();
                        assert!(
                            rel_err(got, want, scale) < 1e-4,
                            "dot cols={cols} bits={bits} p={p} r={r}: {got} vs {want}"
                        );
                        // axpy against the same draw
                        let mut gf = vec![0.0f32; cols];
                        axpy_row_planes_ds(&w, r, p, 0.7, &mut Rng::new(seed), &mut gf);
                        axpy_affine(0.7, &w.scale.m, &mut gf);
                        for c in 0..cols {
                            let want = 0.7 * row[c];
                            assert!(
                                rel_err(gf[c] as f64, want as f64, want.abs() as f64) < 1e-4,
                                "axpy cols={cols} bits={bits} p={p} r={r} c={c}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// At p = stored width the DS draw is carry-free: dot_row_ds equals
    /// the truncating dot_row (same sample, different summation order).
    #[test]
    fn ds_dot_degenerates_to_truncation_at_full_width() {
        let (_, w) = mk(6, 100, 9, 13);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..100).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(100);
        k.refresh(&w.scale.m, &x);
        for r in 0..6 {
            let ds = dot_row_ds(&w, r, 9, &k, &mut rng) as f64;
            let tr = dot_row(&w, r, 9, &k) as f64;
            assert!(rel_err(ds, tr, tr.abs()) < 1e-4, "r={r}: {ds} vs {tr}");
        }
    }

    /// Zero-scale columns stay inert through the stochastic kernels too.
    #[test]
    fn ds_kernels_zero_scale_inert() {
        let (_, w) = mk(4, 10, 8, 21);
        assert_eq!(w.scale.m[1], 0.0);
        let x = vec![1.0f32; 10];
        let mut k = StepKernel::new(10);
        k.refresh(&w.scale.m, &x);
        let mut rng = Rng::new(6);
        let mut grad = vec![0.0f32; 10];
        for r in 0..4 {
            let _ = dot_row_ds(&w, r, 3, &k, &mut rng);
            axpy_row_planes_ds(&w, r, 3, 1.5, &mut rng, &mut grad);
            axpy_affine(1.5, &w.scale.m, &mut grad);
        }
        assert_eq!(grad[1], 0.0);
    }

    /// Deterministic: identical inputs give bit-identical fused results.
    #[test]
    fn fused_kernels_deterministic() {
        let (_, w) = mk(8, 130, 8, 31);
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..130).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(130);
        k.refresh(&w.scale.m, &x);
        for r in 0..8 {
            assert_eq!(dot_row(&w, r, 5, &k).to_bits(), dot_row(&w, r, 5, &k).to_bits());
        }
    }
}
