//! Word-parallel, plane-major fused kernels over the weaved layout —
//! computing *in the weaved domain* (MLWeaving, arXiv 1903.03404) so the
//! training hot loop never materializes an f32 row.
//!
//! Three layers:
//!
//! * **Gather** — [`spread_word`] scatters one plane word into the `u16`
//!   index outputs without a 64-iteration dependent loop: sparse words walk
//!   their set bits via `trailing_zeros`, dense words spread a byte at a
//!   time through a 256-entry lookup table. `WeavedMatrix::read_row` is
//!   built on this.
//! * **Fused compute** — [`dot_row`] and [`axpy_row`] evaluate dot products
//!   and gradient accumulations straight from the bit planes using the
//!   identity (DESIGN.md §4, "weaved-domain kernels"):
//!
//!   ```text
//!   dequant_p(row)[c] = (idx_p[c] · 2/s_p − 1) · m[c]
//!   idx_p[c]          = Σ_t 2^(p−1−t) · bit_t[c]
//!   dot(dequant_p(row), x)
//!       = (2/s_p) · Σ_t 2^(p−1−t) · maskedsum(plane_t, g) − Σ_c g[c]
//!   ```
//!
//!   with `g[c] = m[c]·x[c]` precomputed once per SGD step ([`StepKernel`]).
//!   `maskedsum` is **lane-parallel** (DESIGN.md §8): each plane word is
//!   expanded into per-8-lane select masks and `g` is accumulated with
//!   branch-free select-adds — a fixed, autovectorizable 64-lane schedule —
//!   with a `trailing_zeros` walk below [`MASKED_SUM_SPARSE_BITS`] set
//!   bits. The summation order is fixed either way, plane carries stay in
//!   f64, so results remain deterministic and within the ≤ 1e-4 oracle
//!   bound of the dequantize-then-`tensor::dot` path (exact bit-equality
//!   with the oracle is impossible — different rounding schedules — which
//!   is why `WeavedMatrix::dequantize_row_at` stays as the validation
//!   oracle).
//! * **Blocked batch kernels** — [`dot_rows_block`] / [`axpy_rows_block`]
//!   (and the `_ds` twins) process a whole block of rows of ONE shard
//!   against a single resident [`StepKernel`], amortizing `g` loads and
//!   plane-pointer setup across the block, and running the axpy side
//!   lane-parallel ([`select_add_word`]-style select-adds instead of the
//!   per-set-bit walk). They are **bit-for-bit equal** to calling the
//!   per-row kernels row by row in the same order (property-tested): the
//!   dot side shares `masked_sum` verbatim, and the lane-parallel axpy
//!   issues the identical `out[c] += wgt·m[c]` additions in the identical
//!   per-column order — unset lanes contribute a masked `+0.0`, which is
//!   f32-bit-preserving for the `+0.0`-initialized accumulators every
//!   caller uses (DESIGN.md §8 spells out the −0.0 caveat).
//!
//! * **Stochastic (double-sampling) reads** — [`carry_mask_word`] turns the
//!   *residual* planes (the b−p low planes a truncating reader discards)
//!   into an exact per-column Bernoulli carry: column c gains one coarse
//!   ulp with probability r_c / 2^(b−p), where r_c is its residual. The
//!   augmented sample `(h_c + C_c)·2^(b−p)` is a fine-grid index with
//!   expectation exactly the stored index (DESIGN.md §5), so a p-plane
//!   stochastic read is *unbiased* for the stored value — the host-native
//!   form of the paper's §2.2 sampling, serving both independent draws of
//!   a double-sampled gradient from the single stored copy.
//!   [`dot_row_ds`] and [`axpy_row_planes_ds`] fuse it: the carry mask
//!   acts as one extra plane with weight 2^(b−p) under the *full-width*
//!   dequant scale 2/s:
//!
//!   ```text
//!   dot(dequant_ds(row), x)
//!       = (2/s)·[Σ_{t<p} 2^(b−1−t)·maskedsum(plane_t, g)
//!                + 2^(b−p)·maskedsum(carry, g)] − Σ_c g[c]
//!   ```
//!
//!   RNG contract: every DS reader consumes carry randomness in the same
//!   order — word 0..wpp, and per word the residual planes MSB→LSB with an
//!   early stop once all 64 comparisons are decided — so fused and
//!   materializing DS readers given equal RNG states draw identical
//!   samples (property-tested), and any DS path is deterministic in
//!   (seed, store contents, visit order). The blocked DS kernels consume
//!   carries row-major in block order, exactly as the per-row kernels
//!   called sequentially would.
//!
//! * **Quantized-step popcount fast path** — [`QuantStepKernel`]
//!   stochastically rounds `g = m⊙x` into q sign/magnitude bit planes
//!   once per step, collapsing `maskedsum(plane, ĝ)` to
//!
//!   ```text
//!   step · Σ_u 2^(q−1−u) · [popcount(plane ∧ mag_u)
//!                           − 2·popcount(plane ∧ mag_u ∧ sign)]
//!   ```
//!
//!   — a pure AND+POPCNT integer inner loop with no f32 until the final
//!   rescale ([`dot_row_q`]). The rounding is unbiased (E[ĝ] = g,
//!   property-tested under a CLT budget), so E[dot_q] is the exact fused
//!   dot; the trade is integer throughput for one stochastic-rounding
//!   noise term per step. Opt-in (`--step-bits q` on the host CLI path;
//!   off by default). Derivation and variance notes: DESIGN.md §8.
//!
//! * **Explicit SIMD twins + runtime dispatch** — under the `simd` cargo
//!   feature (pinned nightly, `std::simd`, still zero `unsafe`) the
//!   dense primitives [`masked_sum_dense`] and [`select_add_word_scalar`]
//!   gain portable-SIMD twins ([`simd`]) selected by a one-time-probed
//!   [`dispatch`] tier. Every twin is **bit-for-bit** equal to its
//!   scalar original — same 8-lane accumulator schedule, same fixed
//!   reduction tree, same masked-`+0.0` select semantics — so switching
//!   tiers can never change a result (tests/simd_twins.rs pins it, and
//!   zipml-lint's `twin-contract-v2` rule forces every dispatch site
//!   to name its twin and a test that exists). The DS carry compare deliberately has
//!   no SIMD twin: it is already SIMD-within-a-register and batching it
//!   would reorder the pinned RNG stream (DESIGN.md §12, a "cannot").
//!
//! * **Rank-indexed sparse planes** — an opt-in per-plane occupancy
//!   summary ([`WeavedMatrix::build_plane_index`]: one byte per 8-word
//!   run, bit k set iff word 8·run+k is nonzero) lets the *truncating*
//!   dot/axpy kernels skip all-zero word spans in O(1) — one byte test
//!   skips a whole cache line of plane words. The indexed paths visit
//!   the surviving words in the same ascending order the dense paths do
//!   (which already skip zero words), so results stay bit-for-bit
//!   identical. DS kernels never use the index: a zero residual word
//!   still consumes threshold draws, so skipping it would change the
//!   stream. Index bytes are accounted *separately* from wire bytes —
//!   the exact byte-accounting contract (DESIGN.md §5/§8) is untouched.
//!
//! * **Buffered carry thresholds** — [`carry_mask_word`] is generic over
//!   [`ThresholdSource`]; the DS row kernels wrap their stream in a
//!   [`BufferedThresholds`] (one per row call) that refills eight draws
//!   at a time. Served value k is raw draw k, so every sampled carry is
//!   bit-identical to drawing straight from the stream, and the refill
//!   is lazy, so p = bits still consumes no randomness.

use crate::rng::Rng;

use super::weave::WeavedMatrix;

pub mod dispatch;
#[cfg(feature = "simd")]
pub mod simd;

/// Per-plane-word spread LUT: `SPREAD8[b][j] = (b >> j) & 1`.
static SPREAD8: [[u16; 8]; 256] = build_spread8();

const fn build_spread8() -> [[u16; 8]; 256] {
    let mut t = [[0u16; 8]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut j = 0usize;
        while j < 8 {
            t[b][j] = ((b >> j) & 1) as u16;
            j += 1;
        }
        b += 1;
    }
    t
}

/// Below this popcount [`spread_word`] walks set bits via `trailing_zeros`
/// instead of spreading every byte through the LUT. The crossover is
/// re-measured per popcount by the `sparse_crossover` section of
/// `benches/fused_dot.rs`, which records both paths' timings *and* the
/// measured crossover popcount (`spread_crossover_pc` — the smallest
/// swept popcount where the LUT spread beats the walk) in
/// `BENCH_kernels.json` — the constant is pinned to data, not folklore.
/// CI-measured crossovers land between 6 and 12 set bits depending on
/// runner; 8 sits inside that band. Re-derive from the artifact when the
/// kernels or targets change.
pub const SPARSE_BITS: u32 = 8;

/// Below this popcount [`masked_sum`] walks set bits instead of running
/// the 8-lane select-add over the whole word: the dense path always issues
/// 64 lane-adds (vectorizable, no dependent chain), so very sparse words
/// are cheaper on the walk. Re-measured by the same `sparse_crossover`
/// bench section, which records `masked_sum_crossover_pc` (the smallest
/// swept popcount where the lane path beats the walk) in
/// `BENCH_kernels.json`; measured crossovers sit between 2 and 6 set
/// bits (lower than the spread crossover — the lane path has no LUT
/// loads), bracketing this constant. With the `simd` feature the lane
/// path gets faster and the true crossover drops toward 2; the constant
/// stays at the scalar-safe value so both tiers share one dispatch
/// boundary (a word's path choice is part of the determinism contract).
pub const MASKED_SUM_SPARSE_BITS: u32 = 4;

/// OR bit `j` of `word` into `out[j] << shift` for every set bit, without a
/// per-bit dependent loop. Bits at or beyond `out.len()` are ignored (tail
/// words of a ragged row store them as 0 anyway). Dispatches on popcount
/// ([`SPARSE_BITS`]).
#[inline]
pub fn spread_word(word: u64, shift: u32, out: &mut [u16]) {
    if word == 0 {
        return;
    }
    if word.count_ones() <= SPARSE_BITS {
        spread_word_sparse(word, shift, out);
    } else {
        spread_word_dense(word, shift, out);
    }
}

/// Sparse [`spread_word`] path: walk set bits via `trailing_zeros`.
/// Exposed (with [`spread_word_dense`]) for the crossover bench.
#[inline]
pub fn spread_word_sparse(word: u64, shift: u32, out: &mut [u16]) {
    let mut m = word;
    while m != 0 {
        let j = m.trailing_zeros() as usize;
        if j >= out.len() {
            break;
        }
        out[j] |= 1 << shift;
        m &= m - 1;
    }
}

/// Dense [`spread_word`] path: spread one byte at a time through the
/// 256-entry LUT.
#[inline]
pub fn spread_word_dense(word: u64, shift: u32, out: &mut [u16]) {
    for (chunk, byte) in out.chunks_mut(8).zip(word.to_le_bytes()) {
        if byte == 0 {
            continue;
        }
        for (o, &b) in chunk.iter_mut().zip(&SPREAD8[byte as usize]) {
            *o |= b << shift;
        }
    }
}

/// Σ g[j] over the set bits of `word`. Bits beyond `g.len()` must be zero
/// (guaranteed for weaved tail words; `debug_assert`ed here). Dispatches on
/// popcount: sparse words walk their set bits, dense words run the
/// lane-parallel select-add ([`masked_sum_dense`]). Each path has a fixed
/// summation order, and a given word always takes the same path, so
/// results are deterministic.
#[inline]
fn masked_sum(word: u64, g: &[f32]) -> f32 {
    debug_assert!(
        g.len() >= 64 || word >> g.len() == 0,
        "plane word has set bits at or beyond lane {}: the weaved tail contract \
         (bits beyond g.len() are zero) is violated",
        g.len()
    );
    if word.count_ones() <= MASKED_SUM_SPARSE_BITS {
        return masked_sum_sparse(word, g);
    }
    // twin: masked_sum_dense (simd_masked_sum_bit_identical_to_scalar)
    #[cfg(feature = "simd")]
    if dispatch::tier() == dispatch::Tier::Lanes8 {
        return simd::masked_sum_dense(word, g);
    }
    masked_sum_dense(word, g)
}

/// Sparse [`masked_sum`] path: walk set bits (dependent `trailing_zeros`
/// chain, one add per set bit). Exposed for the crossover bench.
#[inline]
pub fn masked_sum_sparse(mut word: u64, g: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    while word != 0 {
        acc += g[word.trailing_zeros() as usize];
        word &= word - 1;
    }
    acc
}

/// Dense [`masked_sum`] path: expand the word into per-8-lane select masks
/// and accumulate `g` with branch-free select-adds — eight independent
/// lane accumulators (lane j sums `g[8c+j]`), no data-dependent branches
/// or index chains, so the loop autovectorizes. The final reduction order
/// is fixed. Exposed for the crossover bench.
#[inline]
pub fn masked_sum_dense(word: u64, g: &[f32]) -> f32 {
    let g = &g[..g.len().min(64)];
    let mut acc = [0.0f32; 8];
    let mut w = word;
    let mut chunks = g.chunks_exact(8);
    for c8 in &mut chunks {
        for (j, (a, &gv)) in acc.iter_mut().zip(c8).enumerate() {
            let keep = 0u32.wrapping_sub(((w >> j) & 1) as u32);
            *a += f32::from_bits(gv.to_bits() & keep);
        }
        w >>= 8;
    }
    for (j, &gv) in chunks.remainder().iter().enumerate() {
        let keep = 0u32.wrapping_sub(((w >> j) & 1) as u32);
        acc[j] += f32::from_bits(gv.to_bits() & keep);
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// `out[j] += select(bit j of word, wgt·m[j], +0.0)` over the ≤ 64 live
/// lanes — the lane-parallel write side of the blocked axpy kernels. For
/// every SET bit this is the exact `out[j] += wgt·m[j]` the per-row
/// bit-walk issues; unset lanes add a masked `+0.0`, which never changes
/// an f32 accumulation that started from `+0.0` (adding ±0.0 cannot
/// produce −0.0, and v + 0.0 == v bit-for-bit for every other v).
/// Dispatches between the scalar twin and the `std::simd` twin; both are
/// bit-identical (tests/simd_twins.rs).
#[inline]
fn select_add_word(word: u64, wgt: f32, m: &[f32], out: &mut [f32]) {
    // twin: select_add_word_scalar (simd_select_add_bit_identical_to_scalar)
    #[cfg(feature = "simd")]
    if dispatch::tier() == dispatch::Tier::Lanes8 {
        return simd::select_add_word(word, wgt, m, out);
    }
    select_add_word_scalar(word, wgt, m, out);
}

/// Scalar twin of the lane-parallel select-add (see [`select_add_word`]
/// for the semantics). Exposed for the SIMD twin property suite and the
/// scalar-vs-simd bench section.
#[inline]
pub fn select_add_word_scalar(word: u64, wgt: f32, m: &[f32], out: &mut [f32]) {
    let lanes = m.len().min(out.len()).min(64);
    debug_assert!(
        lanes >= 64 || word >> lanes == 0,
        "plane word has set bits at or beyond lane {lanes}: the weaved tail contract \
         (bits beyond the live columns are zero) is violated"
    );
    let m = &m[..lanes];
    let out = &mut out[..lanes];
    let mut w = word;
    let mut oc = out.chunks_exact_mut(8);
    let mut mc = m.chunks_exact(8);
    for (o8, m8) in (&mut oc).zip(&mut mc) {
        for (j, (o, &mv)) in o8.iter_mut().zip(m8).enumerate() {
            let keep = 0u32.wrapping_sub(((w >> j) & 1) as u32);
            *o += f32::from_bits((wgt * mv).to_bits() & keep);
        }
        w >>= 8;
    }
    for (j, (o, &mv)) in oc.into_remainder().iter_mut().zip(mc.remainder()).enumerate() {
        let keep = 0u32.wrapping_sub(((w >> j) & 1) as u32);
        *o += f32::from_bits((wgt * mv).to_bits() & keep);
    }
}

/// Per-SGD-step context for the fused kernels: `g = m ⊙ x` and its sum,
/// valid until the model `x` changes (refresh once per step — the same
/// amortization the ISSUE's identity assumes).
#[derive(Clone, Debug)]
pub struct StepKernel {
    g: Vec<f32>,
    sum_g: f32,
}

impl StepKernel {
    pub fn new(cols: usize) -> Self {
        StepKernel { g: vec![0.0; cols], sum_g: 0.0 }
    }

    /// Recompute `g[c] = m[c]·x[c]` and `Σ g` for the current model.
    pub fn refresh(&mut self, m: &[f32], x: &[f32]) {
        debug_assert_eq!(m.len(), self.g.len());
        debug_assert_eq!(x.len(), self.g.len());
        let mut acc = 0.0f64;
        for ((g, &mc), &xc) in self.g.iter_mut().zip(m).zip(x) {
            *g = mc * xc;
            acc += *g as f64;
        }
        self.sum_g = acc as f32;
    }

    pub fn g(&self) -> &[f32] {
        &self.g
    }

    pub fn sum_g(&self) -> f32 {
        self.sum_g
    }
}

/// Shared core of [`dot_row`] and [`dot_rows_block`]: the fused dot over
/// one row's plane slice. Plane-major, then word, lane order inside
/// `masked_sum`; per-plane sums carried in f64.
#[inline]
fn dot_planes(planes: &[u64], wpp: usize, p: u32, k: &StepKernel) -> f32 {
    let inv_s2 = 2.0 / ((1u32 << p) - 1) as f32;
    let mut acc = 0.0f64;
    for t in 0..p as usize {
        let weight = (1u64 << (p as usize - 1 - t)) as f64;
        let mut psum = 0.0f64;
        for (wi, &word) in planes[t * wpp..(t + 1) * wpp].iter().enumerate() {
            if word != 0 {
                psum += masked_sum(word, &k.g[wi * 64..]) as f64;
            }
        }
        acc += weight * psum;
    }
    (inv_s2 as f64 * acc - k.sum_g as f64) as f32
}

/// Rank-indexed variant of [`dot_planes`]: identical masked-sum
/// accumulation sequence, but all-zero 8-word runs are skipped via the
/// per-plane occupancy bytes instead of loaded — one byte test replaces
/// one cache line of plane-word loads (DESIGN.md §12). Only truncating
/// readers may take this path: DS readers must visit every residual
/// word, because a zero word still consumes threshold draws.
#[inline]
fn dot_planes_indexed(
    planes: &[u64],
    occ: &[u8],
    rpp: usize,
    wpp: usize,
    p: u32,
    k: &StepKernel,
) -> f32 {
    let inv_s2 = 2.0 / ((1u32 << p) - 1) as f32;
    let mut acc = 0.0f64;
    for t in 0..p as usize {
        let weight = (1u64 << (p as usize - 1 - t)) as f64;
        let mut psum = 0.0f64;
        let pw = &planes[t * wpp..(t + 1) * wpp];
        // ascending run, then ascending bit: the exact nonzero-word order
        // the dense loop visits, so the f64 accumulation is bit-identical
        for (run, &ob) in occ[t * rpp..(t + 1) * rpp].iter().enumerate() {
            let mut ob = ob;
            while ob != 0 {
                let wi = run * 8 + ob.trailing_zeros() as usize;
                psum += masked_sum(pw[wi], &k.g[wi * 64..]) as f64;
                ob &= ob - 1;
            }
        }
        acc += weight * psum;
    }
    (inv_s2 as f64 * acc - k.sum_g as f64) as f32
}

/// Plane words one precision-`p` row visit touches: `p` bit planes of
/// `words_per_plane` u64s each. This is the unit the telemetry
/// `plane_words` counter ([`crate::telemetry::Metrics`]) accumulates —
/// always exactly `bytes_per_row(p) / 8`, since every weaved read moves
/// whole u64 plane spans (the unit-test contract below pins the two
/// accountings together).
pub fn plane_words_per_row(w: &WeavedMatrix, p: u32) -> u64 {
    p as u64 * w.words_per_plane() as u64
}

/// Fused weaved-domain dot product: `dot(dequant_p(row r), x)` where `k`
/// was refreshed with (`scale.m`, `x`). Touches only the p requested bit
/// planes; never materializes indices or an f32 row.
pub fn dot_row(w: &WeavedMatrix, r: usize, p: u32, k: &StepKernel) -> f32 {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    assert_eq!(k.g.len(), w.cols, "StepKernel built for {} cols, store has {}", k.g.len(), w.cols);
    let wpp = w.words_per_plane();
    match w.row_plane_occ(r) {
        Some(occ) => dot_planes_indexed(w.row_planes(r), occ, w.runs_per_plane(), wpp, p, k),
        None => dot_planes(w.row_planes(r), wpp, p, k),
    }
}

/// Blocked fused dots: `out[i] = dot(dequant_p(rows[i]), x)` for a block
/// of rows of ONE shard, against a single resident [`StepKernel`] —
/// plane-pointer setup and `g` residency are amortized across the block.
/// Bit-for-bit equal to calling [`dot_row`] per row in order (the inner
/// core is shared).
pub fn dot_rows_block(w: &WeavedMatrix, rows: &[usize], p: u32, k: &StepKernel, out: &mut [f32]) {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    assert_eq!(k.g.len(), w.cols, "StepKernel built for {} cols, store has {}", k.g.len(), w.cols);
    assert_eq!(out.len(), rows.len(), "one dot output per row");
    let wpp = w.words_per_plane();
    let rpp = w.runs_per_plane();
    for (o, &r) in out.iter_mut().zip(rows) {
        *o = match w.row_plane_occ(r) {
            Some(occ) => dot_planes_indexed(w.row_planes(r), occ, rpp, wpp, p, k),
            None => dot_planes(w.row_planes(r), wpp, p, k),
        };
    }
}

/// Source of uniform `u64` carry thresholds for [`carry_mask_word`]. The
/// direct impl on [`Rng`] draws per call (call sites outside the hot DS
/// row loops keep their exact pre-buffering behavior); the DS row
/// kernels wrap their stream in a [`BufferedThresholds`]. Both serve the
/// *same stream values in the same order* — served threshold k is raw
/// draw k — so every sampled carry is identical regardless of which
/// source wraps the stream.
pub trait ThresholdSource {
    fn next_threshold(&mut self) -> u64;
}

impl ThresholdSource for Rng {
    #[inline]
    fn next_threshold(&mut self) -> u64 {
        self.next_u64()
    }
}

/// Refill granularity of [`BufferedThresholds`]: eight `u64` draws — one
/// cache line — per refill, amortizing the xoshiro state round-trip
/// across up to eight residual-word compares.
const THRESHOLD_BUF: usize = 8;

/// A block-refilled FIFO over an [`Rng`] stream, created once per DS
/// *row call* (DESIGN.md §12). Stream contract:
///
/// * served value k equals raw draw k, so all sampled carries are
///   bit-identical to drawing straight from the stream;
/// * the refill is lazy — a row that needs no thresholds (p = bits)
///   consumes no randomness at all;
/// * leftover buffered draws are discarded when the row call ends, so a
///   row call consumes `ceil(served / 8) · 8` raw draws — the same for
///   the per-row and blocked DS paths, which is what keeps the
///   identical-draws end-state pins green.
pub struct BufferedThresholds<'a> {
    rng: &'a mut Rng,
    buf: [u64; THRESHOLD_BUF],
    next: usize,
    filled: usize,
}

impl<'a> BufferedThresholds<'a> {
    #[inline]
    pub fn new(rng: &'a mut Rng) -> Self {
        BufferedThresholds { rng, buf: [0; THRESHOLD_BUF], next: 0, filled: 0 }
    }
}

impl ThresholdSource for BufferedThresholds<'_> {
    #[inline]
    fn next_threshold(&mut self) -> u64 {
        if self.next == self.filled {
            for slot in &mut self.buf {
                *slot = self.rng.next_u64();
            }
            self.next = 0;
            self.filled = THRESHOLD_BUF;
        }
        let v = self.buf[self.next];
        self.next += 1;
        v
    }
}

/// Draw the stochastic-carry mask for word-column `wi` of a row's planes:
/// bit j of the result is 1 with probability r_j / 2^(bits−p), where r_j
/// is the residual of column wi·64+j — the integer spelled by its low
/// bits−p planes. Exact Bernoulli via a bit-sliced comparison of the
/// residual against fresh uniform threshold bits, MSB first: 64 columns
/// decide in ≤ bits−p bitwise steps, one threshold word each, stopping
/// early once every lane's comparison is settled. At p == bits the mask
/// is zero and no randomness is consumed. Tail bits beyond the live
/// columns stay 0 (their residual planes store 0).
///
/// This compare is already SIMD-within-a-register — 64 column lanes per
/// u64 bit-op — and has no `std::simd` twin *by design*: the early stop
/// makes the threshold count data-dependent, so batching words or planes
/// would reorder the pinned RNG stream (DESIGN.md §12).
#[inline]
pub fn carry_mask_word<T: ThresholdSource>(
    planes: &[u64],
    wpp: usize,
    bits: u32,
    p: u32,
    wi: usize,
    thresholds: &mut T,
) -> u64 {
    debug_assert!(p >= 1 && p <= bits);
    let mut gt = 0u64;
    let mut eq = !0u64;
    for t in p as usize..bits as usize {
        let r = planes[t * wpp + wi];
        let thresh = thresholds.next_threshold();
        // bitwise r > thresh: r & !thresh == r & (r ^ thresh), so one XOR
        // feeds both the greater-than and the still-equal updates
        let d = r ^ thresh;
        gt |= eq & r & d;
        eq &= !d;
        if eq == 0 {
            break;
        }
    }
    gt
}

/// Shared core of [`dot_row_ds`] and [`dot_rows_block_ds`]: one unbiased
/// p-plane draw of the row, dotted with `x` straight from the planes.
/// Word-major so the carry randomness order matches every other DS reader.
#[inline]
fn dot_planes_ds(
    planes: &[u64],
    wpp: usize,
    bits: u32,
    s: u32,
    p: u32,
    k: &StepKernel,
    rng: &mut Rng,
) -> f32 {
    let bits_us = bits as usize;
    let inv_s2 = 2.0 / s as f32;
    let carry_w = (1u64 << (bits_us - p as usize)) as f64;
    let mut acc = 0.0f64;
    // one buffer per row call: thresholds amortize 8 draws per refill
    // while serving the exact raw stream values in order
    let mut thresholds = BufferedThresholds::new(rng);
    for wi in 0..wpp {
        let g = &k.g[wi * 64..];
        for t in 0..p as usize {
            let word = planes[t * wpp + wi];
            if word != 0 {
                acc += (1u64 << (bits_us - 1 - t)) as f64 * masked_sum(word, g) as f64;
            }
        }
        let carry = carry_mask_word(planes, wpp, bits, p, wi, &mut thresholds);
        if carry != 0 {
            acc += carry_w * masked_sum(carry, g) as f64;
        }
    }
    (inv_s2 as f64 * acc - k.sum_g as f64) as f32
}

/// Fused stochastic (double-sampling) dot product: one unbiased p-plane
/// draw of row `r`, dotted with `x` straight from the bit planes. The
/// draw's fine-grid index is `Σ_{t<p} 2^(b−1−t)·bit_t + 2^(b−p)·C`, so
/// plane weights are the *fine-grid* ones and the carry mask enters as one
/// extra plane; the affine term reuses `k.sum_g`. Each call consumes fresh
/// carry randomness — two successive calls are the two independent draws
/// of a §2.2 double-sampled gradient.
pub fn dot_row_ds(w: &WeavedMatrix, r: usize, p: u32, k: &StepKernel, rng: &mut Rng) -> f32 {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    assert_eq!(k.g.len(), w.cols, "StepKernel built for {} cols, store has {}", k.g.len(), w.cols);
    dot_planes_ds(w.row_planes(r), w.words_per_plane(), w.bits, w.s, p, k, rng)
}

/// Blocked stochastic dots: `out[i]` gets one unbiased p-plane draw of
/// `rows[i]` dotted with `x`. Rows are drawn in block order, each with the
/// standard word-major carry order — the RNG consumption is *identical* to
/// calling [`dot_row_ds`] per row in sequence on the same stream
/// (property-tested), so blocked and per-row DS paths draw the same
/// samples from equal states.
pub fn dot_rows_block_ds(
    w: &WeavedMatrix,
    rows: &[usize],
    p: u32,
    k: &StepKernel,
    rng: &mut Rng,
    out: &mut [f32],
) {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    assert_eq!(k.g.len(), w.cols, "StepKernel built for {} cols, store has {}", k.g.len(), w.cols);
    assert_eq!(out.len(), rows.len(), "one dot output per row");
    let wpp = w.words_per_plane();
    for (o, &r) in out.iter_mut().zip(rows) {
        *o = dot_planes_ds(w.row_planes(r), wpp, w.bits, w.s, p, k, rng);
    }
}

/// Plane + carry part of the stochastic axpy: draw one unbiased p-plane
/// sample of row `r` and add `coef · dequant_ds(row)[c]` into `out`,
/// *without* the shared affine term — callers batching rows defer
/// `−(Σ coef)·m` to one [`axpy_affine`] pass, exactly like
/// [`axpy_row_planes`]. Consumes carry randomness in the shared DS order.
pub fn axpy_row_planes_ds(
    w: &WeavedMatrix,
    r: usize,
    p: u32,
    coef: f32,
    rng: &mut Rng,
    out: &mut [f32],
) {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    debug_assert_eq!(out.len(), w.cols);
    let planes = w.row_planes(r);
    let wpp = w.words_per_plane();
    let bits = w.bits as usize;
    let m = &w.scale.m;
    let inv_s2 = 2.0 / w.s as f32;
    let carry_wgt = coef * inv_s2 * (1u64 << (bits - p as usize)) as f32;
    let mut thresholds = BufferedThresholds::new(rng);
    for wi in 0..wpp {
        let c0 = wi * 64;
        for t in 0..p as usize {
            let wgt = coef * inv_s2 * (1u64 << (bits - 1 - t)) as f32;
            let mut word = planes[t * wpp + wi];
            while word != 0 {
                let j = c0 + word.trailing_zeros() as usize;
                out[j] += wgt * m[j];
                word &= word - 1;
            }
        }
        let mut carry = carry_mask_word(planes, wpp, w.bits, p, wi, &mut thresholds);
        while carry != 0 {
            let j = c0 + carry.trailing_zeros() as usize;
            out[j] += carry_wgt * m[j];
            carry &= carry - 1;
        }
    }
}

/// Lane-parallel single-row core of [`axpy_rows_block_ds`]: identical
/// per-column additions and identical carry-randomness order to
/// [`axpy_row_planes_ds`], with the bit-walk replaced by select-adds.
#[inline]
fn axpy_row_planes_ds_lanes(
    w: &WeavedMatrix,
    r: usize,
    p: u32,
    coef: f32,
    rng: &mut Rng,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), w.cols);
    let planes = w.row_planes(r);
    let wpp = w.words_per_plane();
    let bits = w.bits as usize;
    let m = &w.scale.m;
    let inv_s2 = 2.0 / w.s as f32;
    let carry_wgt = coef * inv_s2 * (1u64 << (bits - p as usize)) as f32;
    let mut thresholds = BufferedThresholds::new(rng);
    for wi in 0..wpp {
        let c0 = wi * 64;
        for t in 0..p as usize {
            let wgt = coef * inv_s2 * (1u64 << (bits - 1 - t)) as f32;
            let word = planes[t * wpp + wi];
            if word != 0 {
                select_add_word(word, wgt, &m[c0..], &mut out[c0..]);
            }
        }
        let carry = carry_mask_word(planes, wpp, w.bits, p, wi, &mut thresholds);
        if carry != 0 {
            select_add_word(carry, carry_wgt, &m[c0..], &mut out[c0..]);
        }
    }
}

/// Blocked stochastic axpys: for each row i (in block order), draw one
/// unbiased p-plane sample and add `coefs[i] · dequant_ds(rows[i])[c]`
/// into `out` — plane part only, affine term deferred as in
/// [`axpy_row_planes_ds`]. Bit-for-bit equal to, and RNG-identical with,
/// calling [`axpy_row_planes_ds`] per row in order on the same stream.
pub fn axpy_rows_block_ds(
    w: &WeavedMatrix,
    rows: &[usize],
    p: u32,
    coefs: &[f32],
    rng: &mut Rng,
    out: &mut [f32],
) {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    assert_eq!(rows.len(), coefs.len(), "one coefficient per row");
    debug_assert_eq!(out.len(), w.cols);
    for (&r, &coef) in rows.iter().zip(coefs) {
        axpy_row_planes_ds_lanes(w, r, p, coef, rng, out);
    }
}

/// Plane part of the fused axpy: for every set bit of the p planes of row
/// `r`, add `coef · 2^(p−1−t) · (2/s_p) · m[c]` into `sink(c, delta)`.
#[inline]
fn plane_walk(w: &WeavedMatrix, r: usize, p: u32, coef: f32, mut sink: impl FnMut(usize, f32)) {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    let planes = w.row_planes(r);
    let wpp = w.words_per_plane();
    let m = &w.scale.m;
    let inv_s2 = 2.0 / ((1u32 << p) - 1) as f32;
    for t in 0..p as usize {
        let wgt = coef * inv_s2 * (1u64 << (p as usize - 1 - t)) as f32;
        for (wi, &word) in planes[t * wpp..(t + 1) * wpp].iter().enumerate() {
            let c0 = wi * 64;
            let mut bits = word;
            while bits != 0 {
                let j = c0 + bits.trailing_zeros() as usize;
                sink(j, wgt * m[j]);
                bits &= bits - 1;
            }
        }
    }
}

/// Plane part of `out[c] += coef · dequant_p(row)[c]`; callers batching
/// many rows defer the shared affine term to one [`axpy_affine`] call.
pub fn axpy_row_planes(w: &WeavedMatrix, r: usize, p: u32, coef: f32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), w.cols);
    plane_walk(w, r, p, coef, |c, d| out[c] += d);
}

/// Blocked fused axpys: for each row i (in block order), add
/// `coefs[i] · dequant_p(rows[i])[c]` into `out` — plane part only, the
/// shared affine term is deferred to one [`axpy_affine`] pass. The write
/// side is lane-parallel ([`select_add_word`]), and the result is
/// bit-for-bit equal to calling [`axpy_row_planes`] per row in order (same
/// per-column addition sequence; unset lanes add a masked `+0.0`).
pub fn axpy_rows_block(w: &WeavedMatrix, rows: &[usize], p: u32, coefs: &[f32], out: &mut [f32]) {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    assert_eq!(rows.len(), coefs.len(), "one coefficient per row");
    debug_assert_eq!(out.len(), w.cols);
    let wpp = w.words_per_plane();
    let rpp = w.runs_per_plane();
    let m = &w.scale.m;
    let inv_s2 = 2.0 / ((1u32 << p) - 1) as f32;
    for (&r, &coef) in rows.iter().zip(coefs) {
        let planes = w.row_planes(r);
        match w.row_plane_occ(r) {
            Some(occ) => {
                for t in 0..p as usize {
                    let wgt = coef * inv_s2 * (1u64 << (p as usize - 1 - t)) as f32;
                    let pw = &planes[t * wpp..(t + 1) * wpp];
                    // ascending run then bit = the dense loop's nonzero
                    // visit order, so the addition sequence is identical
                    for (run, &ob) in occ[t * rpp..(t + 1) * rpp].iter().enumerate() {
                        let mut ob = ob;
                        while ob != 0 {
                            let wi = run * 8 + ob.trailing_zeros() as usize;
                            select_add_word(pw[wi], wgt, &m[wi * 64..], &mut out[wi * 64..]);
                            ob &= ob - 1;
                        }
                    }
                }
            }
            None => {
                for t in 0..p as usize {
                    let wgt = coef * inv_s2 * (1u64 << (p as usize - 1 - t)) as f32;
                    for (wi, &word) in planes[t * wpp..(t + 1) * wpp].iter().enumerate() {
                        if word != 0 {
                            select_add_word(word, wgt, &m[wi * 64..], &mut out[wi * 64..]);
                        }
                    }
                }
            }
        }
    }
}

/// The affine term of the dequant identity: `out[c] -= coef_sum · m[c]`.
/// For a batch, `coef_sum` is the sum of the per-row axpy coefficients.
pub fn axpy_affine(coef_sum: f32, m: &[f32], out: &mut [f32]) {
    for (o, &mc) in out.iter_mut().zip(m) {
        *o -= coef_sum * mc;
    }
}

/// Full fused axpy for one row: `out[c] += coef · dequant_p(row)[c]`,
/// computed from bit planes (plane part + affine part), no f32 row.
pub fn axpy_row(w: &WeavedMatrix, r: usize, p: u32, coef: f32, out: &mut [f32]) {
    axpy_row_planes(w, r, p, coef, out);
    axpy_affine(coef, &w.scale.m, out);
}

/// Per-step context for the **popcount fast path**: one stochastic
/// sign/magnitude rounding of `g = m⊙x` onto a q-bit magnitude grid,
/// stored as bit planes so `maskedsum(plane, ĝ)` collapses to AND+POPCNT
/// ([`QuantStepKernel::masked_count`], used by [`dot_row_q`]).
///
/// The grid: `ĝ[c] = ±k_c·step` with `step = max|g| / (2^q − 1)` and
/// `k_c ∈ 0..=2^q−1` drawn by stochastic rounding of `|g[c]|/step`
/// (floor plus a Bernoulli on the fraction), so `E[ĝ[c]] = g[c]` exactly
/// and E of every popcount dot is the exact fused dot (DESIGN.md §8).
/// One refresh consumes exactly `cols` RNG draws, so popcount runs replay
/// deterministically from their seed.
#[derive(Clone, Debug)]
pub struct QuantStepKernel {
    q: u32,
    cols: usize,
    wpp: usize,
    /// Magnitude grid step `max|g| / (2^q − 1)`; 0 when `g == 0`.
    step: f32,
    /// Sign mask per word-column: bit c set ⇔ ĝ[c] < 0.
    sign: Vec<u64>,
    /// q × wpp magnitude planes, MSB first: plane u holds bit q−1−u of k.
    mag: Vec<u64>,
    /// Σ_c ĝ[c], computed exactly from the integer k's.
    sum_g: f32,
}

impl QuantStepKernel {
    pub fn new(cols: usize, q: u32) -> Self {
        assert!((1..=16).contains(&q), "step bits must be 1..=16, got {q}");
        let wpp = cols.div_ceil(64);
        QuantStepKernel {
            q,
            cols,
            wpp,
            step: 0.0,
            sign: vec![0; wpp],
            mag: vec![0; q as usize * wpp],
            sum_g: 0.0,
        }
    }

    pub fn q(&self) -> u32 {
        self.q
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn sum_g(&self) -> f32 {
        self.sum_g
    }

    /// Re-draw the q-bit rounding of `g = m⊙x` for the current model.
    /// Unbiased: `E[ĝ] = g` componentwise (the CLT harness in
    /// tests/ds_statistics.rs pins it). Consumes exactly `m.len()` draws.
    pub fn refresh(&mut self, m: &[f32], x: &[f32], rng: &mut Rng) {
        assert_eq!(m.len(), self.cols, "kernel built for {} cols, got {}", self.cols, m.len());
        assert_eq!(x.len(), self.cols, "kernel built for {} cols, got {}", self.cols, x.len());
        self.sign.fill(0);
        self.mag.fill(0);
        let mut gmax = 0.0f32;
        for (&mc, &xc) in m.iter().zip(x) {
            gmax = gmax.max((mc * xc).abs());
        }
        if gmax == 0.0 {
            // all-zero g (e.g. the x = 0 first step): exact, no RNG needed
            // beyond the per-column draws we still consume for replayability
            self.step = 0.0;
            self.sum_g = 0.0;
            for _ in 0..self.cols {
                rng.f32();
            }
            return;
        }
        let smax = (1u32 << self.q) - 1;
        let step = gmax / smax as f32;
        self.step = step;
        let q = self.q as usize;
        let mut sum_k = 0i64;
        for (c, (&mc, &xc)) in m.iter().zip(x).enumerate() {
            let g = mc * xc;
            let u = g.abs() / step;
            let fl = u.floor();
            let draw = rng.f32();
            let k = ((fl as u32) + u32::from(draw < u - fl)).min(smax);
            if k == 0 {
                continue;
            }
            let (wi, j) = (c / 64, c % 64);
            if g < 0.0 {
                self.sign[wi] |= 1u64 << j;
                sum_k -= k as i64;
            } else {
                sum_k += k as i64;
            }
            for (u_t, plane) in self.mag.chunks_mut(self.wpp).enumerate() {
                if (k >> (q - 1 - u_t)) & 1 != 0 {
                    plane[wi] |= 1u64 << j;
                }
            }
        }
        self.sum_g = (sum_k as f64 * step as f64) as f32;
    }

    /// `Σ_{c ∈ word} ĝ[c]` in integer form — the popcount identity:
    /// `Σ_u 2^(q−1−u)·[pc(word ∧ mag_u) − 2·pc(word ∧ mag_u ∧ sign)]`,
    /// to be rescaled by `step` once per dot. Pure AND+POPCNT+shift.
    /// Tail bits are structurally inert: the magnitude planes store 0
    /// beyond the live columns.
    #[inline]
    fn masked_count(&self, word: u64, wi: usize) -> i64 {
        let s = self.sign[wi];
        let mut acc = 0i64;
        for (u, plane) in self.mag.chunks(self.wpp).enumerate() {
            let mw = word & plane[wi];
            let signed = mw.count_ones() as i64 - 2 * (mw & s).count_ones() as i64;
            acc += signed << (self.q as usize - 1 - u);
        }
        acc
    }
}

/// Popcount-path fused dot: `dot(dequant_p(row r), ĝ-model)` with the
/// q-bit rounded step kernel — the inner loop is integer AND+POPCNT only
/// (p plane words × q magnitude planes per word); floats appear once, in
/// the final rescale. Unbiased for [`dot_row`] over the rounding draw:
/// `E[dot_row_q] = dot_row` with the exact `g`.
pub fn dot_row_q(w: &WeavedMatrix, r: usize, p: u32, qk: &QuantStepKernel) -> f32 {
    assert!(p >= 1 && p <= w.bits, "precision {p} outside 1..={}", w.bits);
    assert_eq!(qk.cols, w.cols, "QuantStepKernel built for {} cols, store has {}", qk.cols, w.cols);
    let planes = w.row_planes(r);
    let wpp = w.words_per_plane();
    debug_assert_eq!(wpp, qk.wpp);
    let inv_s2 = 2.0 / ((1u32 << p) - 1) as f64;
    let mut acc = 0i64;
    for t in 0..p as usize {
        let mut psum = 0i64;
        for (wi, &word) in planes[t * wpp..(t + 1) * wpp].iter().enumerate() {
            if word != 0 {
                psum += qk.masked_count(word, wi);
            }
        }
        acc += psum << (p as usize - 1 - t);
    }
    (inv_s2 * acc as f64 * qk.step as f64 - qk.sum_g as f64) as f32
}

/// Blocked popcount dots: `out[i] = dot_row_q(rows[i])` for a block of
/// rows of one shard against a single resident [`QuantStepKernel`].
/// Bit-for-bit equal to calling [`dot_row_q`] per row in order.
pub fn dot_rows_block_q(
    w: &WeavedMatrix,
    rows: &[usize],
    p: u32,
    qk: &QuantStepKernel,
    out: &mut [f32],
) {
    assert_eq!(out.len(), rows.len(), "one dot output per row");
    for (o, &r) in out.iter_mut().zip(rows) {
        *o = dot_row_q(w, r, p, qk);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scaling::ColumnScale;
    use crate::rng::Rng;
    use crate::tensor::{dot, Matrix};

    fn mk(rows: usize, cols: usize, bits: u32, seed: u64) -> (Matrix, WeavedMatrix) {
        let mut rng = Rng::new(seed);
        let mut data: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        if cols > 2 {
            // plant a zero-scale column
            for r in 0..rows {
                data[r * cols + 1] = 0.0;
            }
        }
        let a = Matrix::from_vec(rows, cols, data);
        let sc = ColumnScale::from_data(&a);
        let w = WeavedMatrix::quantize(&a, &sc, bits, &mut rng);
        (a, w)
    }

    fn rel_err(got: f64, want: f64, scale: f64) -> f64 {
        (got - want).abs() / (1.0 + want.abs() + scale)
    }

    /// Plane-word accounting is bytes/8, exactly, across ragged column
    /// counts — the kernel-level tie between the telemetry `plane_words`
    /// counter and the store's exact byte accounting.
    #[test]
    fn plane_words_per_row_is_bytes_over_eight() {
        for &cols in &[63usize, 64, 65, 130] {
            for bits in [1u32, 5, 16] {
                let (_, w) = mk(4, cols, bits, 3 + bits as u64);
                for p in 1..=bits {
                    assert_eq!(
                        plane_words_per_row(&w, p) * 8,
                        w.bytes_per_row(p) as u64,
                        "cols={cols} bits={bits} p={p}"
                    );
                }
            }
        }
    }

    /// Fused dot == dequantize-then-dot (≤1e-4 relative) for bits 1..=16,
    /// the ragged column counts the ISSUE names, and zero-scale columns.
    #[test]
    fn fused_dot_matches_dequant_oracle() {
        for &cols in &[63usize, 64, 65, 130] {
            for bits in [1u32, 2, 5, 8, 12, 16] {
                let (_, w) = mk(6, cols, bits, 11 + bits as u64);
                let mut rng = Rng::new(99 + cols as u64);
                let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
                let mut k = StepKernel::new(cols);
                k.refresh(&w.scale.m, &x);
                let mut row = vec![0.0f32; cols];
                for p in 1..=bits {
                    for r in 0..6 {
                        w.dequantize_row_at(r, p, &mut row);
                        let want = dot(&row, &x) as f64;
                        let got = dot_row(&w, r, p, &k) as f64;
                        let scale: f64 =
                            row.iter().zip(&x).map(|(&a, &b)| (a as f64 * b as f64).abs()).sum();
                        assert!(
                            rel_err(got, want, scale) < 1e-4,
                            "cols={cols} bits={bits} p={p} r={r}: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    /// Fused axpy (plane + affine) == dequantize-then-`tensor::axpy`.
    #[test]
    fn fused_axpy_matches_dequant_oracle() {
        for &cols in &[63usize, 64, 65, 130] {
            for bits in [1u32, 4, 9, 16] {
                let (_, w) = mk(5, cols, bits, 7 + bits as u64);
                let mut rng = Rng::new(3);
                let mut row = vec![0.0f32; cols];
                for p in [1, bits] {
                    let mut gf = vec![0.0f32; cols];
                    let mut gr = vec![0.0f64; cols];
                    let mut mag = vec![0.0f64; cols];
                    for r in 0..5 {
                        let coef = rng.normal();
                        axpy_row(&w, r, p, coef, &mut gf);
                        w.dequantize_row_at(r, p, &mut row);
                        for ((o, g), &v) in gr.iter_mut().zip(mag.iter_mut()).zip(&row) {
                            *o += coef as f64 * v as f64;
                            *g += (coef as f64 * v as f64).abs();
                        }
                    }
                    for c in 0..cols {
                        assert!(
                            rel_err(gf[c] as f64, gr[c], mag[c]) < 1e-4,
                            "cols={cols} bits={bits} p={p} c={c}: {} vs {}",
                            gf[c],
                            gr[c]
                        );
                    }
                }
            }
        }
    }

    /// Tentpole pin: the blocked batch kernels are BIT-FOR-BIT equal to
    /// the per-row kernels called in the same order, across the ragged
    /// shapes the ISSUE names and every width 1..=16.
    #[test]
    fn blocked_kernels_bit_identical_to_per_row() {
        for &cols in &[63usize, 64, 65, 130] {
            for bits in 1..=16u32 {
                let (_, w) = mk(7, cols, bits, 41 + bits as u64);
                let mut rng = Rng::new(5 + cols as u64);
                let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
                let mut k = StepKernel::new(cols);
                k.refresh(&w.scale.m, &x);
                let rows: Vec<usize> = vec![6, 0, 3, 3, 5, 1];
                let coefs: Vec<f32> = (0..rows.len()).map(|_| rng.normal()).collect();
                for p in [1, bits / 2 + 1, bits] {
                    // dots
                    let mut blocked = vec![0.0f32; rows.len()];
                    dot_rows_block(&w, &rows, p, &k, &mut blocked);
                    for (i, &r) in rows.iter().enumerate() {
                        assert_eq!(
                            blocked[i].to_bits(),
                            dot_row(&w, r, p, &k).to_bits(),
                            "dot cols={cols} bits={bits} p={p} i={i}"
                        );
                    }
                    // axpys (plane part)
                    let mut gb = vec![0.0f32; cols];
                    let mut gp = vec![0.0f32; cols];
                    axpy_rows_block(&w, &rows, p, &coefs, &mut gb);
                    for (&r, &coef) in rows.iter().zip(&coefs) {
                        axpy_row_planes(&w, r, p, coef, &mut gp);
                    }
                    for c in 0..cols {
                        assert_eq!(
                            gb[c].to_bits(),
                            gp[c].to_bits(),
                            "axpy cols={cols} bits={bits} p={p} c={c}: {} vs {}",
                            gb[c],
                            gp[c]
                        );
                    }
                }
            }
        }
    }

    /// DS tentpole pin: the blocked DS kernels consume carry randomness
    /// exactly like the per-row kernels called in sequence — equal RNG
    /// states draw identical samples, results are bit-for-bit equal, and
    /// the streams end in the same state.
    #[test]
    fn blocked_ds_kernels_draw_identical_samples() {
        for &cols in &[63usize, 65, 130] {
            for bits in [2u32, 5, 8, 16] {
                let (_, w) = mk(6, cols, bits, 17 + bits as u64);
                let mut rng = Rng::new(23 + cols as u64);
                let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
                let mut k = StepKernel::new(cols);
                k.refresh(&w.scale.m, &x);
                let rows: Vec<usize> = vec![5, 2, 2, 0, 4];
                let coefs: Vec<f32> = (0..rows.len()).map(|_| rng.normal()).collect();
                for p in [1, bits] {
                    let seed = 900 + (p as u64) * 7 + cols as u64;
                    // dots: blocked vs sequential per-row on twin streams
                    let mut ra = Rng::new(seed);
                    let mut rb = Rng::new(seed);
                    let mut blocked = vec![0.0f32; rows.len()];
                    dot_rows_block_ds(&w, &rows, p, &k, &mut ra, &mut blocked);
                    for (i, &r) in rows.iter().enumerate() {
                        assert_eq!(
                            blocked[i].to_bits(),
                            dot_row_ds(&w, r, p, &k, &mut rb).to_bits(),
                            "ds dot cols={cols} bits={bits} p={p} i={i}"
                        );
                    }
                    assert_eq!(ra.next_u64(), rb.next_u64(), "dot streams diverged");
                    // axpys: same contract
                    let mut ra = Rng::new(seed ^ 1);
                    let mut rb = Rng::new(seed ^ 1);
                    let mut gb = vec![0.0f32; cols];
                    let mut gp = vec![0.0f32; cols];
                    axpy_rows_block_ds(&w, &rows, p, &coefs, &mut ra, &mut gb);
                    for (&r, &coef) in rows.iter().zip(&coefs) {
                        axpy_row_planes_ds(&w, r, p, coef, &mut rb, &mut gp);
                    }
                    for c in 0..cols {
                        assert_eq!(
                            gb[c].to_bits(),
                            gp[c].to_bits(),
                            "ds axpy cols={cols} bits={bits} p={p} c={c}"
                        );
                    }
                    assert_eq!(ra.next_u64(), rb.next_u64(), "axpy streams diverged");
                }
            }
        }
    }

    /// Zero-scale columns: dot ignores them, axpy leaves them untouched.
    #[test]
    fn zero_scale_columns_are_inert() {
        let (_, w) = mk(4, 10, 8, 21);
        assert_eq!(w.scale.m[1], 0.0);
        let x = vec![1.0f32; 10];
        let mut k = StepKernel::new(10);
        k.refresh(&w.scale.m, &x);
        assert_eq!(k.g()[1], 0.0);
        let mut grad = vec![0.0f32; 10];
        for r in 0..4 {
            let _ = dot_row(&w, r, 8, &k);
            axpy_row(&w, r, 8, 1.5, &mut grad);
        }
        assert_eq!(grad[1], 0.0);
        // the blocked write side too: masked +0.0 pads must not leak
        let mut gb = vec![0.0f32; 10];
        axpy_rows_block(&w, &[0, 1, 2, 3], 8, &[1.5, -0.5, 2.0, -1.0], &mut gb);
        assert_eq!(gb[1], 0.0);
    }

    /// spread_word: LUT (dense) and trailing_zeros (sparse) paths agree
    /// with the reference bit extraction, including short tail outputs.
    #[test]
    fn spread_word_paths_match_reference() {
        let mut rng = Rng::new(17);
        for lim in [64usize, 63, 17, 8, 3, 1] {
            for _ in 0..50 {
                let dense = rng.next_u64();
                let sparse = dense & rng.next_u64() & rng.next_u64() & rng.next_u64();
                for word in [dense, sparse, 0, u64::MAX] {
                    let masked = if lim == 64 { word } else { word & ((1u64 << lim) - 1) };
                    let mut out = vec![0u16; lim];
                    spread_word(masked, 3, &mut out);
                    for (j, &o) in out.iter().enumerate() {
                        assert_eq!(o, (((masked >> j) & 1) as u16) << 3, "lim={lim} j={j}");
                    }
                }
            }
        }
    }

    /// masked_sum: the sparse walk and the lane-parallel dense path agree
    /// with a scalar f64 reference within rounding, for full and ragged
    /// lane counts, across the popcount range.
    #[test]
    fn masked_sum_paths_match_reference() {
        let mut rng = Rng::new(29);
        for lanes in [64usize, 63, 17, 9, 8, 3, 1] {
            let g: Vec<f32> = (0..lanes).map(|_| rng.normal()).collect();
            for _ in 0..40 {
                let dense = rng.next_u64();
                let sparse = dense & rng.next_u64() & rng.next_u64() & rng.next_u64();
                for word in [dense, sparse, 0, u64::MAX] {
                    let masked =
                        if lanes == 64 { word } else { word & ((1u64 << lanes) - 1) };
                    let want: f64 = (0..lanes)
                        .filter(|&j| (masked >> j) & 1 == 1)
                        .map(|j| g[j] as f64)
                        .sum();
                    let mag: f64 = (0..lanes)
                        .filter(|&j| (masked >> j) & 1 == 1)
                        .map(|j| g[j].abs() as f64)
                        .sum();
                    for got in [masked_sum_sparse(masked, &g), masked_sum_dense(masked, &g)] {
                        assert!(
                            (got as f64 - want).abs() <= 1e-5 * (1.0 + mag),
                            "lanes={lanes} word={masked:#x}: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    /// Satellite regression: a deliberately dirty tail word (set bits at
    /// or beyond the live columns) trips the masked_sum tail guard in
    /// debug builds instead of silently corrupting the dot.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "tail contract")]
    fn dirty_tail_word_trips_masked_sum_guard() {
        let (_, mut w) = mk(2, 65, 4, 31);
        w.poison_tail_bit_for_test(0);
        let x = vec![1.0f32; 65];
        let mut k = StepKernel::new(65);
        k.refresh(&w.scale.m, &x);
        let _ = dot_row(&w, 0, 4, &k);
    }

    /// Same guard on the lane-parallel axpy write side.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "tail contract")]
    fn dirty_tail_word_trips_select_add_guard() {
        let (_, mut w) = mk(2, 65, 4, 31);
        w.poison_tail_bit_for_test(0);
        let mut out = vec![0.0f32; 65];
        axpy_rows_block(&w, &[0], 4, &[1.0], &mut out);
    }

    /// The carry mask is exactly Bernoulli(residual / 2^(b−p)): degenerate
    /// residuals are deterministic, generic ones match their probability
    /// statistically, and p == bits consumes no randomness.
    #[test]
    fn carry_mask_distribution() {
        let (bits, cols) = (8u32, 64usize);
        // residual of column j is j itself at p = 2 (residual width 6)
        let idx: Vec<u16> = (0..cols as u16).collect();
        let w = WeavedMatrix::from_indices(
            1,
            cols,
            bits,
            255,
            ColumnScale { m: vec![1.0; cols] },
            &idx,
        );
        let planes = w.row_planes(0);
        let p = 2u32;
        let q = 1u64 << (bits - p); // 64
        let trials = 40_000;
        let mut counts = [0u32; 64];
        let mut rng = Rng::new(5);
        for _ in 0..trials {
            let mask = carry_mask_word(planes, w.words_per_plane(), bits, p, 0, &mut rng);
            for (j, c) in counts.iter_mut().enumerate() {
                *c += ((mask >> j) & 1) as u32;
            }
        }
        // residual 0 never carries; residual j carries w.p. j/64
        assert_eq!(counts[0], 0);
        for (j, &c) in counts.iter().enumerate() {
            let want = j as f64 / q as f64;
            let got = c as f64 / trials as f64;
            let tol = 5.0 * (want * (1.0 - want) / trials as f64).sqrt() + 1e-9;
            assert!((got - want).abs() <= tol, "col {j}: p̂ {got} vs {want} (tol {tol})");
        }
        // p == bits: no residual planes, mask identically zero, rng intact
        let mut a = Rng::new(9);
        let before = a.clone().next_u64();
        assert_eq!(carry_mask_word(planes, w.words_per_plane(), bits, bits, 0, &mut a), 0);
        assert_eq!(a.next_u64(), before, "full-width mask consumed randomness");
    }

    /// Fused DS kernels and the materializing DS oracle consume carry
    /// randomness in the same order: equal RNG states draw the same
    /// sample, so fused dot/axpy match dequantize_row_ds within tolerance.
    #[test]
    fn fused_ds_matches_dequant_ds_oracle_same_seed() {
        for &cols in &[63usize, 64, 65, 130] {
            for bits in [2u32, 5, 8, 12, 16] {
                let (_, w) = mk(5, cols, bits, 77 + bits as u64);
                let mut rng = Rng::new(3 + cols as u64);
                let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
                let mut k = StepKernel::new(cols);
                k.refresh(&w.scale.m, &x);
                let mut row = vec![0.0f32; cols];
                for p in [1u32, bits / 2 + 1, bits] {
                    for r in 0..5 {
                        let seed = 1000 + (p as u64) * 31 + r as u64;
                        let got = dot_row_ds(&w, r, p, &k, &mut Rng::new(seed)) as f64;
                        w.dequantize_row_ds(r, p, &mut Rng::new(seed), &mut row);
                        let want = dot(&row, &x) as f64;
                        let scale: f64 =
                            row.iter().zip(&x).map(|(&a, &b)| (a as f64 * b as f64).abs()).sum();
                        assert!(
                            rel_err(got, want, scale) < 1e-4,
                            "dot cols={cols} bits={bits} p={p} r={r}: {got} vs {want}"
                        );
                        // axpy against the same draw
                        let mut gf = vec![0.0f32; cols];
                        axpy_row_planes_ds(&w, r, p, 0.7, &mut Rng::new(seed), &mut gf);
                        axpy_affine(0.7, &w.scale.m, &mut gf);
                        for c in 0..cols {
                            let want = 0.7 * row[c];
                            assert!(
                                rel_err(gf[c] as f64, want as f64, want.abs() as f64) < 1e-4,
                                "axpy cols={cols} bits={bits} p={p} r={r} c={c}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// At p = stored width the DS draw is carry-free: dot_row_ds equals
    /// the truncating dot_row (same sample, different summation order).
    #[test]
    fn ds_dot_degenerates_to_truncation_at_full_width() {
        let (_, w) = mk(6, 100, 9, 13);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..100).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(100);
        k.refresh(&w.scale.m, &x);
        for r in 0..6 {
            let ds = dot_row_ds(&w, r, 9, &k, &mut rng) as f64;
            let tr = dot_row(&w, r, 9, &k) as f64;
            assert!(rel_err(ds, tr, tr.abs()) < 1e-4, "r={r}: {ds} vs {tr}");
        }
    }

    /// Zero-scale columns stay inert through the stochastic kernels too.
    #[test]
    fn ds_kernels_zero_scale_inert() {
        let (_, w) = mk(4, 10, 8, 21);
        assert_eq!(w.scale.m[1], 0.0);
        let x = vec![1.0f32; 10];
        let mut k = StepKernel::new(10);
        k.refresh(&w.scale.m, &x);
        let mut rng = Rng::new(6);
        let mut grad = vec![0.0f32; 10];
        for r in 0..4 {
            let _ = dot_row_ds(&w, r, 3, &k, &mut rng);
            axpy_row_planes_ds(&w, r, 3, 1.5, &mut rng, &mut grad);
            axpy_affine(1.5, &w.scale.m, &mut grad);
        }
        assert_eq!(grad[1], 0.0);
    }

    /// Deterministic: identical inputs give bit-identical fused results.
    #[test]
    fn fused_kernels_deterministic() {
        let (_, w) = mk(8, 130, 8, 31);
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..130).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(130);
        k.refresh(&w.scale.m, &x);
        for r in 0..8 {
            assert_eq!(dot_row(&w, r, 5, &k).to_bits(), dot_row(&w, r, 5, &k).to_bits());
        }
    }

    /// Popcount path at high q: the rounding noise is ≤ step per column,
    /// so dot_row_q tracks the exact fused dot tightly; zero-scale columns
    /// and the ragged shapes stay correct. (Unbiasedness at low q is the
    /// CLT harness in tests/ds_statistics.rs.)
    #[test]
    fn popcount_dot_tracks_exact_dot_at_high_q() {
        for &cols in &[63usize, 64, 65, 130] {
            for bits in [1u32, 5, 8, 16] {
                let (_, w) = mk(5, cols, bits, 53 + bits as u64);
                let mut rng = Rng::new(7 + cols as u64);
                let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
                let mut k = StepKernel::new(cols);
                k.refresh(&w.scale.m, &x);
                let mut qk = QuantStepKernel::new(cols, 16);
                qk.refresh(&w.scale.m, &x, &mut rng);
                // the rounded step sum is within cols·step of the exact one
                let gmax = k.g().iter().fold(0.0f32, |a, &g| a.max(g.abs()));
                let step = gmax / 65535.0;
                assert!(
                    (qk.sum_g() - k.sum_g()).abs() <= cols as f32 * step + 1e-6,
                    "cols={cols} bits={bits}: Σĝ {} vs Σg {}",
                    qk.sum_g(),
                    k.sum_g()
                );
                for p in [1, bits] {
                    for r in 0..5 {
                        let exact = dot_row(&w, r, p, &k) as f64;
                        let got = dot_row_q(&w, r, p, &qk) as f64;
                        // per-column rounding error ≤ step, dotted against
                        // dequant values in [−m, m]: budget Σ_c m_c · step
                        let budget: f64 =
                            w.scale.m.iter().map(|&mc| (mc * step) as f64).sum::<f64>() + 1e-5;
                        assert!(
                            (got - exact).abs() <= 4.0 * budget + 1e-4 * exact.abs(),
                            "cols={cols} bits={bits} p={p} r={r}: {got} vs {exact}"
                        );
                    }
                }
            }
        }
    }

    /// Popcount path degenerate cases: the all-zero model (first SGD step)
    /// is exact, the blocked form is bit-identical to the per-row form,
    /// and refreshes replay bit-for-bit from equal RNG states.
    #[test]
    fn popcount_kernel_degenerate_and_blocked() {
        let (_, w) = mk(6, 100, 8, 61);
        // x = 0 → g = 0 → every dot is exactly 0 (no NaN from step = 0)
        let mut qk = QuantStepKernel::new(100, 4);
        qk.refresh(&w.scale.m, &[0.0f32; 100], &mut Rng::new(3));
        for r in 0..6 {
            assert_eq!(dot_row_q(&w, r, 4, &qk), 0.0, "r={r}");
        }
        // blocked == per-row, and replay from equal states is bit-exact
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..100).map(|_| rng.normal()).collect();
        let mut qa = QuantStepKernel::new(100, 4);
        let mut qb = QuantStepKernel::new(100, 4);
        qa.refresh(&w.scale.m, &x, &mut Rng::new(17));
        qb.refresh(&w.scale.m, &x, &mut Rng::new(17));
        let rows: Vec<usize> = vec![5, 1, 1, 0, 3];
        let mut blocked = vec![0.0f32; rows.len()];
        dot_rows_block_q(&w, &rows, 6, &qa, &mut blocked);
        for (i, &r) in rows.iter().enumerate() {
            assert_eq!(blocked[i].to_bits(), dot_row_q(&w, r, 6, &qb).to_bits(), "i={i}");
        }
        // a refresh consumes exactly cols draws: twin streams stay aligned
        let mut ra = Rng::new(23);
        let mut rb = Rng::new(23);
        qa.refresh(&w.scale.m, &x, &mut ra);
        for _ in 0..100 {
            rb.f32();
        }
        assert_eq!(ra.next_u64(), rb.next_u64(), "refresh RNG budget drifted");
    }

    /// Rank-index pin: with the occupancy index built, the truncating
    /// dot/axpy kernels are BIT-FOR-BIT what they were without it —
    /// including a genuinely sparse store (mostly-zero plane words, the
    /// regime the index exists for) and the ragged shapes.
    #[test]
    fn indexed_kernels_bit_identical_to_dense() {
        // dense random store + a sparse one: rows where only a few
        // scattered columns are nonzero, so most plane words are zero
        for sparse in [false, true] {
            for &cols in &[63usize, 130, 1000] {
                let bits = 6u32;
                let mut w = if sparse {
                    let rows = 5usize;
                    let mut idx = vec![0u16; rows * cols];
                    for r in 0..rows {
                        for j in 0..4usize {
                            idx[r * cols + (r * 211 + j * 97) % cols] = (17 + r + j) as u16;
                        }
                    }
                    WeavedMatrix::from_indices(
                        rows,
                        cols,
                        bits,
                        63,
                        ColumnScale { m: vec![1.0; cols] },
                        &idx,
                    )
                } else {
                    mk(5, cols, bits, 67).1
                };
                let mut rng = Rng::new(11 + cols as u64);
                let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
                let mut k = StepKernel::new(cols);
                k.refresh(&w.scale.m, &x);
                let rows: Vec<usize> = vec![4, 0, 2, 2, 1];
                let coefs: Vec<f32> = (0..rows.len()).map(|_| rng.normal()).collect();
                for p in [1u32, 3, bits] {
                    let mut dots_dense = vec![0.0f32; rows.len()];
                    let mut axpy_dense = vec![0.0f32; cols];
                    dot_rows_block(&w, &rows, p, &k, &mut dots_dense);
                    axpy_rows_block(&w, &rows, p, &coefs, &mut axpy_dense);

                    w.build_plane_index();
                    let mut dots_ix = vec![0.0f32; rows.len()];
                    let mut axpy_ix = vec![0.0f32; cols];
                    dot_rows_block(&w, &rows, p, &k, &mut dots_ix);
                    axpy_rows_block(&w, &rows, p, &coefs, &mut axpy_ix);
                    for i in 0..rows.len() {
                        assert_eq!(
                            dots_dense[i].to_bits(),
                            dots_ix[i].to_bits(),
                            "dot sparse={sparse} cols={cols} p={p} i={i}"
                        );
                        // the per-row entry point routes through the index too
                        assert_eq!(
                            dot_row(&w, rows[i], p, &k).to_bits(),
                            dots_ix[i].to_bits(),
                            "dot_row sparse={sparse} cols={cols} p={p} i={i}"
                        );
                    }
                    for c in 0..cols {
                        assert_eq!(
                            axpy_dense[c].to_bits(),
                            axpy_ix[c].to_bits(),
                            "axpy sparse={sparse} cols={cols} p={p} c={c}"
                        );
                    }
                }
            }
        }
    }

    /// BufferedThresholds stream contract: served value k IS raw draw k,
    /// the refill is lazy (an unused buffer consumes nothing), and a
    /// finished row call has consumed ceil(served/8)·8 raw draws.
    #[test]
    fn buffered_thresholds_serve_the_raw_stream() {
        // served values == the raw stream, across refill boundaries
        let mut raw = Rng::new(41);
        let want: Vec<u64> = (0..21).map(|_| raw.next_u64()).collect();
        let mut rng = Rng::new(41);
        let mut buf = BufferedThresholds::new(&mut rng);
        for (k, &w) in want.iter().enumerate() {
            assert_eq!(buf.next_threshold(), w, "served draw {k} differs from raw draw {k}");
        }
        drop(buf);
        // 21 served → 3 refills → 24 raw draws consumed
        let mut raw = Rng::new(41);
        for _ in 0..24 {
            raw.next_u64();
        }
        assert_eq!(rng.next_u64(), raw.next_u64(), "refill granularity drifted");
        // lazy: an unused buffer leaves the stream untouched
        let mut rng = Rng::new(43);
        let before = rng.clone().next_u64();
        drop(BufferedThresholds::new(&mut rng));
        assert_eq!(rng.next_u64(), before, "constructing the buffer drew randomness");
    }
}
