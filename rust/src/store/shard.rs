//! Sharded any-precision sample store: the serving-grade data plane over
//! [`WeavedMatrix`].
//!
//! Rows are split into fixed-size shards, each an independently allocated
//! weaved block. Shard row counts are rounded to multiples of 8 so every
//! shard payload is a whole number of 64-byte cache lines (row plane spans
//! are multiples of 8 bytes) — parallel ingestion writers and concurrent
//! readers never share a line across shards.
//!
//! * **Ingestion** realizes the paper's "quantize during the first epoch":
//!   each shard quantizes its row slice with an independent, seed-derived
//!   RNG stream, so the result is bit-identical regardless of how many
//!   threads ingest.
//! * **Reads** route a global row to its shard and add the exact bytes
//!   touched to that shard's cache-line-padded relaxed counter — the
//!   accounting the FPGA bandwidth model consumes
//!   ([`crate::fpga::pipeline`]) and the telemetry layer mirrors
//!   ([`crate::telemetry::Metrics`], attached per store). Per-shard
//!   cells replaced the former single global atomic, which ping-ponged
//!   its line between hogwild workers on every row visit.
//! * **[`MinibatchIter`]** hands out deterministic shuffled minibatches;
//!   the strided form partitions one epoch's batches across N workers
//!   without coordination (used by the Hogwild! shard readers).

use crate::sync::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::quant::packing::PackedMatrix;
use crate::quant::scaling::ColumnScale;
use crate::rng::Rng;
use crate::telemetry::Metrics;
use crate::tensor::Matrix;

use super::kernel::{self, QuantStepKernel, StepKernel};
use super::weave::WeavedMatrix;

/// Rows per shard are rounded up to this so shard payloads are whole
/// cache lines (8 rows × ≥8 B/row-plane = ≥64 B).
const SHARD_ROW_ALIGN: usize = 8;

/// Largest block the batch kernels hand to one [`kernel::dot_rows_block`]
/// / [`kernel::axpy_rows_block`] call: shard runs longer than this are
/// emitted in `BLOCK_ROWS` chunks, so every batch entry point works out of
/// fixed stack scratch — the hot loop allocates nothing at any batch size.
/// Chunking preserves row order, so results stay bit-identical.
const BLOCK_ROWS: usize = 256;

/// One cache-line-padded relaxed byte counter — one per shard, so
/// concurrent readers accounting against different shards never share a
/// line (and telemetry gets per-shard byte attribution for free).
// No derive(Default): loom's AtomicU64 has no Default impl, and the
// explicit zero keeps the std and loom builds identical.
#[repr(align(64))]
#[derive(Debug)]
struct PaddedBytes(AtomicU64);

impl Default for PaddedBytes {
    fn default() -> Self {
        PaddedBytes(AtomicU64::new(0))
    }
}

/// A row-sharded, bit-weaved, any-precision sample store.
#[derive(Debug)]
pub struct ShardedStore {
    rows: usize,
    cols: usize,
    bits: u32,
    shard_rows: usize,
    shards: Vec<WeavedMatrix>,
    /// Exact bytes touched by reads since the last reset, attributed to
    /// the shard that served them. Ordering contract on
    /// [`ShardedStore::bytes_read`].
    shard_bytes: Vec<PaddedBytes>,
    /// Telemetry registry mirrored by every accounting site; defaults to
    /// [`Metrics::shared_disabled`] (mask-gated no-op recorders).
    metrics: Arc<Metrics>,
}

impl ShardedStore {
    /// Quantize `a` into `num_shards` shards, `threads` at a time
    /// (0 = available parallelism). Deterministic in `seed` regardless of
    /// thread count.
    pub fn ingest(
        a: &Matrix,
        scale: &ColumnScale,
        bits: u32,
        seed: u64,
        num_shards: usize,
        threads: usize,
    ) -> Self {
        assert!(a.rows > 0, "cannot ingest an empty matrix");
        let num_shards = num_shards.clamp(1, a.rows);
        let shard_rows = shard_rows_for(a.rows, num_shards);
        let ns = a.rows.div_ceil(shard_rows);
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(ns)
        } else {
            threads.min(ns)
        };
        let cols = a.cols;
        let build = |si: usize| -> WeavedMatrix {
            let r0 = si * shard_rows;
            let r1 = (r0 + shard_rows).min(a.rows);
            // per-shard RNG stream: identical under any thread schedule,
            // derived through the one blessed splitter so shard streams
            // and worker streams can never collide by construction
            let mut rng = Rng::new_stream(seed, si as u64);
            WeavedMatrix::quantize_rows(
                &a.data[r0 * cols..r1 * cols],
                r1 - r0,
                cols,
                scale,
                bits,
                &mut rng,
            )
        };
        let shards: Vec<WeavedMatrix> = if threads <= 1 {
            (0..ns).map(build).collect()
        } else {
            std::thread::scope(|scope| {
                let build = &build;
                let handles: Vec<_> = (0..threads)
                    .map(|t| {
                        scope.spawn(move || {
                            let mut done = Vec::new();
                            let mut si = t;
                            while si < ns {
                                done.push((si, build(si)));
                                si += threads;
                            }
                            done
                        })
                    })
                    .collect();
                let mut slots: Vec<Option<WeavedMatrix>> = (0..ns).map(|_| None).collect();
                for h in handles {
                    for (si, w) in h.join().expect("shard ingestion thread panicked") {
                        slots[si] = Some(w);
                    }
                }
                slots.into_iter().map(|s| s.expect("missing shard")).collect()
            })
        };
        ShardedStore {
            rows: a.rows,
            cols,
            bits,
            shard_rows,
            shards,
            shard_bytes: (0..ns).map(|_| PaddedBytes::default()).collect(),
            metrics: Metrics::shared_disabled(),
        }
    }

    /// Re-shard an existing packed store without re-drawing randomness —
    /// reads reproduce `PackedMatrix` values exactly (equivalence tests).
    pub fn from_packed(p: &PackedMatrix, num_shards: usize) -> Self {
        assert!(p.rows > 0);
        let num_shards = num_shards.clamp(1, p.rows);
        let shard_rows = shard_rows_for(p.rows, num_shards);
        let ns = p.rows.div_ceil(shard_rows);
        let mut shards = Vec::with_capacity(ns);
        let mut idx_buf = Vec::new();
        for si in 0..ns {
            let r0 = si * shard_rows;
            let r1 = (r0 + shard_rows).min(p.rows);
            idx_buf.clear();
            idx_buf.resize((r1 - r0) * p.cols, 0u16);
            for r in r0..r1 {
                for (c, o) in idx_buf[(r - r0) * p.cols..(r - r0 + 1) * p.cols]
                    .iter_mut()
                    .enumerate()
                {
                    *o = p.index(r, c);
                }
            }
            shards.push(WeavedMatrix::from_indices(
                r1 - r0,
                p.cols,
                p.bits,
                p.s,
                p.scale.clone(),
                &idx_buf,
            ));
        }
        ShardedStore {
            rows: p.rows,
            cols: p.cols,
            bits: p.bits,
            shard_rows,
            shards,
            shard_bytes: (0..ns).map(|_| PaddedBytes::default()).collect(),
            metrics: Metrics::shared_disabled(),
        }
    }

    #[inline]
    fn locate(&self, r: usize) -> (&WeavedMatrix, usize) {
        debug_assert!(r < self.rows);
        (&self.shards[r / self.shard_rows], r % self.shard_rows)
    }

    /// Account `rows` row visits moving `bytes` served by shard `si` at
    /// read precision `p`: the shard's padded byte cell always counts;
    /// the attached [`Metrics`] mirrors bytes / visits / plane words
    /// (mask-gated no-op when disabled). `lane` spreads concurrent
    /// telemetry writers (shard id or worker id).
    #[inline]
    fn account(&self, si: usize, lane: usize, p: u32, rows: u64, bytes: u64) {
        // ordering: relaxed — exact-once add, no happens-before with the
        // data read it accounts (`bytes_read` ordering contract)
        self.shard_bytes[si].0.fetch_add(bytes, Ordering::Relaxed);
        self.metrics.add_read(lane, p, rows, bytes);
    }

    /// Read the level indices of global row `r` at precision `p`; counts
    /// the exact bytes touched. Returns those bytes.
    pub fn read_row(&self, r: usize, p: u32, out: &mut [u16]) -> usize {
        let (shard, local) = self.locate(r);
        let bytes = shard.read_row(local, p, out);
        let si = r / self.shard_rows;
        self.account(si, si, p, 1, bytes as u64);
        bytes
    }

    /// Dequantize global row `r` at precision `p`; counts bytes touched.
    pub fn dequantize_row(&self, r: usize, p: u32, out: &mut [f32]) -> usize {
        let (shard, local) = self.locate(r);
        let bytes = shard.dequantize_row_at(local, p, out);
        let si = r / self.shard_rows;
        self.account(si, si, p, 1, bytes as u64);
        bytes
    }

    /// Dequantize one stochastic (unbiased) p-plane draw of global row `r`
    /// ([`WeavedMatrix::dequantize_row_ds`]); counts the draw's wire bytes
    /// — the same p plane spans a truncating read moves, see DESIGN.md §5.
    pub fn dequantize_row_ds(&self, r: usize, p: u32, rng: &mut Rng, out: &mut [f32]) -> usize {
        let (shard, local) = self.locate(r);
        let bytes = shard.dequantize_row_ds(local, p, rng, out);
        let si = r / self.shard_rows;
        self.account(si, si, p, 1, bytes as u64);
        self.metrics.add_rng_draws(si, 1);
        bytes
    }

    /// Route global row `r` to `(shard, local row)` for direct fused-kernel
    /// access ([`super::kernel`]). Does NOT count bytes — compose with
    /// [`ShardedStore::note_row_visit`] so each row visit is accounted
    /// exactly once however many kernel passes reuse the cached planes.
    pub fn locate_row(&self, r: usize) -> (&WeavedMatrix, usize) {
        self.locate(r)
    }

    /// Account one fused-kernel visit of global row `r` at precision `p`,
    /// `reads` plane fetches deep (1 = truncating/popcount, 2 =
    /// double-sampled). `lane` is the telemetry lane hint — hogwild
    /// workers pass their worker id so concurrent tallies land on
    /// disjoint cache lines. Returns the bytes counted. This is the
    /// accounting half of [`ShardedStore::locate_row`].
    pub fn note_row_visit(&self, r: usize, p: u32, reads: u32, lane: usize) -> usize {
        let bytes = reads as usize * self.bytes_per_row(p);
        self.account(r / self.shard_rows, lane, p, 1, bytes as u64);
        bytes
    }

    /// Fused weaved-domain dot product of global row `r` at precision `p`;
    /// counts the same bytes a `read_row`/`dequantize_row` of that row
    /// would. No f32 row is materialized.
    pub fn dot_row_fused(&self, r: usize, p: u32, k: &StepKernel) -> f32 {
        let (shard, local) = self.locate(r);
        self.note_row_visit(r, p, 1, r / self.shard_rows);
        kernel::dot_row(shard, local, p, k)
    }

    /// Visit `rows` as shard-grouped **blocks**: shards in ascending id,
    /// and within a shard the rows in their original batch order (a stable
    /// partition — the order is *specified*, so per-row reference
    /// implementations can reproduce it exactly). Runs longer than
    /// [`BLOCK_ROWS`] are emitted in chunks. `f` receives
    /// `(shard, local rows, positions into rows)` — the local mapping is
    /// done here once, so the batch entry points below are just kernel
    /// calls. Minibatch-sized inputs (≤ [`BLOCK_ROWS`]) group alloc-free
    /// with fixed stack scratch; larger inputs take one heap-allocated
    /// stable sort (same specified order, no per-distinct-shard rescans).
    ///
    /// `visit_bytes` (wire bytes per row visit, 2× for double-sampled
    /// batches) is attributed to each serving shard's byte cell here —
    /// one relaxed add per emitted run, not per row — so per-shard
    /// accounting costs the batch paths O(distinct shards), not O(rows).
    fn for_shard_runs(
        &self,
        rows: &[usize],
        visit_bytes: usize,
        mut f: impl FnMut(&WeavedMatrix, &[usize], &[u32]),
    ) {
        let mut locals = [0usize; BLOCK_ROWS];
        if rows.len() > BLOCK_ROWS {
            // large batch: stable sort of positions by shard id — identical
            // visit order to the scan path, O(N log N) instead of O(S·N)
            let mut order: Vec<u32> = (0..rows.len() as u32).collect();
            order.sort_by_key(|&i| rows[i as usize] / self.shard_rows);
            let mut a = 0usize;
            while a < order.len() {
                let s = rows[order[a] as usize] / self.shard_rows;
                let mut b = a + 1;
                while b < order.len() && rows[order[b] as usize] / self.shard_rows == s {
                    b += 1;
                }
                // exact-once batch add, same contract as `account` /
                // `bytes_read` — ordering: relaxed
                self.shard_bytes[s]
                    .0
                    .fetch_add(((b - a) * visit_bytes) as u64, Ordering::Relaxed);
                for chunk in order[a..b].chunks(BLOCK_ROWS) {
                    for (l, &i) in locals.iter_mut().zip(chunk) {
                        *l = rows[i as usize] % self.shard_rows;
                    }
                    f(&self.shards[s], &locals[..chunk.len()], chunk);
                }
                a = b;
            }
            return;
        }
        let mut run = [0u32; BLOCK_ROWS];
        let mut done = 0usize;
        let mut next_shard = 0usize;
        while done < rows.len() {
            // smallest shard id not yet visited
            let mut s = usize::MAX;
            for &r in rows {
                let si = r / self.shard_rows;
                if si >= next_shard && si < s {
                    s = si;
                }
            }
            let mut n = 0usize;
            for (i, &r) in rows.iter().enumerate() {
                if r / self.shard_rows == s {
                    run[n] = i as u32;
                    locals[n] = r % self.shard_rows;
                    n += 1;
                    done += 1;
                }
            }
            // ordering: relaxed — exact-once batch add, same contract as
            // `account` / `bytes_read`
            self.shard_bytes[s].0.fetch_add((n * visit_bytes) as u64, Ordering::Relaxed);
            f(&self.shards[s], &locals[..n], &run[..n]);
            next_shard = s + 1;
        }
    }

    /// One fused minibatch gradient pass on the **blocked batch kernels**
    /// ([`kernel::dot_rows_block`] / [`kernel::axpy_rows_block`]),
    /// generalized over the GLM step multiplier: rows are visited in
    /// shard-grouped blocks (`for_shard_runs`), each block computed
    /// against the single resident [`StepKernel`] — `g` loads and
    /// plane-pointer setup are amortized across the block. For each row
    ///
    /// ```text
    /// coef_i = step(dot(dequant_p(row_i), x), targets[i])
    /// grad  += coef_i · dequant_p(row_i)
    /// ```
    ///
    /// straight from the bit planes (`k` must hold `g = m⊙x` for the
    /// current model), with the shared affine term −(Σ coef_i)·m applied
    /// once per batch. `step` is the loss derivative ℓ′(aᵀx; b) —
    /// `|d, t| d - t` recovers the least-squares residual and makes this
    /// bit-for-bit the classic fused linreg batch
    /// ([`ShardedStore::fused_grad_batch`]); any other
    /// [`crate::sgd::GlmLoss`] multiplier extends the same plane-domain
    /// pass to its GLM. Byte accounting is identical to the row-read path
    /// — p plane spans per row, counted once per row visit; the axpy pass
    /// reuses the planes the dot pass just fetched (cache-resident, not a
    /// second DRAM crossing). Returns the bytes counted.
    pub fn fused_grad_batch_glm<F: Fn(f32, f32) -> f32>(
        &self,
        rows: &[usize],
        p: u32,
        k: &StepKernel,
        targets: &[f32],
        step: F,
        grad: &mut [f32],
    ) -> usize {
        assert_eq!(rows.len(), targets.len(), "one target per row");
        let mut errs = [0.0f32; BLOCK_ROWS];
        let mut coef_sum = 0.0f32;
        let visit_bytes = self.bytes_per_row(p);
        self.for_shard_runs(rows, visit_bytes, |shard, locals, pos| {
            let nb = pos.len();
            kernel::dot_rows_block(shard, locals, p, k, &mut errs[..nb]);
            for (e, &i) in errs[..nb].iter_mut().zip(pos) {
                *e = step(*e, targets[i as usize]);
            }
            kernel::axpy_rows_block(shard, locals, p, &errs[..nb], grad);
            for &e in &errs[..nb] {
                coef_sum += e;
            }
        });
        kernel::axpy_affine(coef_sum, &self.scale().m, grad);
        let bytes = rows.len() * visit_bytes;
        self.metrics.add_read(0, p, rows.len() as u64, bytes as u64);
        bytes
    }

    /// [`ShardedStore::fused_grad_batch_glm`] with the least-squares
    /// residual `coef_i = dot_i − targets[i]` — the classic fused linreg
    /// minibatch gradient (the property-tested bit-for-bit contract with
    /// the per-row kernels lives here). Returns the bytes counted.
    pub fn fused_grad_batch(
        &self,
        rows: &[usize],
        p: u32,
        k: &StepKernel,
        targets: &[f32],
        grad: &mut [f32],
    ) -> usize {
        self.fused_grad_batch_glm(rows, p, k, targets, |d, t| d - t, grad)
    }

    /// One *double-sampled* fused minibatch gradient pass (§2.2) on the
    /// blocked DS kernels: rows are visited in shard-grouped blocks; per
    /// block, draw one of every row feeds the residual
    ///
    /// ```text
    /// err_i = dot(draw1_i, x) − targets[i]
    /// grad += err_i · draw2_i
    /// ```
    ///
    /// and draw two the accumulation, so E[grad] is the gradient on the
    /// stored full-width values at *any* read precision — the unbiased
    /// estimator naive truncation is not. Generalized over the GLM step
    /// multiplier like [`ShardedStore::fused_grad_batch_glm`]:
    /// `coef_i = step(dot(draw1_i, x), targets[i])` scales draw two's
    /// accumulation (for non-linear `step` the two independent draws
    /// still factorize the expectation — the residual bias lives in the
    /// multiplier alone and is bounded by the §4 smoothness argument, see
    /// DESIGN.md §9). Carry randomness is consumed in a fixed, specified
    /// order: per block, the dot draws of all rows (row-major), then the
    /// axpy draws of all rows — identical to calling the per-row DS
    /// kernels in that sequence on the same stream. The shared affine
    /// term −(Σ coef_i)·m is applied once per batch. Byte accounting:
    /// both fetches count, 2·p plane spans per row visit — exactly 2× the
    /// truncating path (DESIGN.md §5). Deterministic in (rng state, store
    /// contents, batch order). Returns the bytes counted.
    pub fn ds_grad_batch_glm<F: Fn(f32, f32) -> f32>(
        &self,
        rows: &[usize],
        p: u32,
        k: &StepKernel,
        targets: &[f32],
        step: F,
        rng: &mut Rng,
        grad: &mut [f32],
    ) -> usize {
        assert_eq!(rows.len(), targets.len(), "one target per row");
        let mut errs = [0.0f32; BLOCK_ROWS];
        let mut coef_sum = 0.0f32;
        let visit_bytes = 2 * self.bytes_per_row(p);
        self.for_shard_runs(rows, visit_bytes, |shard, locals, pos| {
            let nb = pos.len();
            kernel::dot_rows_block_ds(shard, locals, p, k, rng, &mut errs[..nb]);
            for (e, &i) in errs[..nb].iter_mut().zip(pos) {
                *e = step(*e, targets[i as usize]);
            }
            kernel::axpy_rows_block_ds(shard, locals, p, &errs[..nb], rng, grad);
            for &e in &errs[..nb] {
                coef_sum += e;
            }
        });
        kernel::axpy_affine(coef_sum, &self.scale().m, grad);
        let bytes = rows.len() * visit_bytes;
        self.metrics.add_read(0, p, rows.len() as u64, bytes as u64);
        self.metrics.add_rng_draws(0, 2 * rows.len() as u64);
        bytes
    }

    /// [`ShardedStore::ds_grad_batch_glm`] with the least-squares residual
    /// — the §2.2 double-sampled linreg batch. Returns the bytes counted.
    pub fn ds_grad_batch(
        &self,
        rows: &[usize],
        p: u32,
        k: &StepKernel,
        targets: &[f32],
        rng: &mut Rng,
        grad: &mut [f32],
    ) -> usize {
        self.ds_grad_batch_glm(rows, p, k, targets, |d, t| d - t, rng, grad)
    }

    /// [`ShardedStore::fused_grad_batch_glm`] on the **popcount fast
    /// path**: the per-row dots come from [`kernel::dot_rows_block_q`] —
    /// an integer AND+POPCNT inner loop against the q-bit rounded step
    /// kernel (`qk` must hold this step's rounding of `g = m⊙x`) — before
    /// `step` maps each to its GLM multiplier, while the axpy side is the
    /// exact blocked kernel on the true `m`. With the least-squares
    /// residual the estimator is unbiased over the rounding draw:
    /// E[grad] equals the exact fused batch gradient (non-linear
    /// multipliers add the same bounded approximation bias as the DS
    /// path, DESIGN.md §9). Byte accounting is identical to the
    /// truncating path (the ĝ planes are model-side state, not sample
    /// traffic). Returns the bytes counted.
    pub fn fused_grad_batch_q_glm<F: Fn(f32, f32) -> f32>(
        &self,
        rows: &[usize],
        p: u32,
        qk: &QuantStepKernel,
        targets: &[f32],
        step: F,
        grad: &mut [f32],
    ) -> usize {
        assert_eq!(rows.len(), targets.len(), "one target per row");
        let mut errs = [0.0f32; BLOCK_ROWS];
        let mut coef_sum = 0.0f32;
        let visit_bytes = self.bytes_per_row(p);
        self.for_shard_runs(rows, visit_bytes, |shard, locals, pos| {
            let nb = pos.len();
            kernel::dot_rows_block_q(shard, locals, p, qk, &mut errs[..nb]);
            for (e, &i) in errs[..nb].iter_mut().zip(pos) {
                *e = step(*e, targets[i as usize]);
            }
            kernel::axpy_rows_block(shard, locals, p, &errs[..nb], grad);
            for &e in &errs[..nb] {
                coef_sum += e;
            }
        });
        kernel::axpy_affine(coef_sum, &self.scale().m, grad);
        let bytes = rows.len() * visit_bytes;
        self.metrics.add_read(0, p, rows.len() as u64, bytes as u64);
        bytes
    }

    /// [`ShardedStore::fused_grad_batch_q_glm`] with the least-squares
    /// residual — the popcount linreg batch. Returns the bytes counted.
    pub fn fused_grad_batch_q(
        &self,
        rows: &[usize],
        p: u32,
        qk: &QuantStepKernel,
        targets: &[f32],
        grad: &mut [f32],
    ) -> usize {
        self.fused_grad_batch_q_glm(rows, p, qk, targets, |d, t| d - t, grad)
    }

    /// Blocked fused dots over global rows: `out[i] = dot(dequant_p(rows[i]),
    /// x)`, computed in shard-grouped blocks against the resident kernel —
    /// the batch form of [`ShardedStore::dot_row_fused`], bit-for-bit equal
    /// to it per row. Counts the same bytes the row-read path would (one
    /// visit per row). Returns the bytes counted.
    pub fn dot_rows_fused(
        &self,
        rows: &[usize],
        p: u32,
        k: &StepKernel,
        out: &mut [f32],
    ) -> usize {
        assert_eq!(rows.len(), out.len(), "one dot output per row");
        let mut dots = [0.0f32; BLOCK_ROWS];
        let visit_bytes = self.bytes_per_row(p);
        self.for_shard_runs(rows, visit_bytes, |shard, locals, pos| {
            let nb = pos.len();
            kernel::dot_rows_block(shard, locals, p, k, &mut dots[..nb]);
            for (&d, &i) in dots[..nb].iter().zip(pos) {
                out[i as usize] = d;
            }
        });
        let bytes = rows.len() * visit_bytes;
        self.metrics.add_read(0, p, rows.len() as u64, bytes as u64);
        bytes
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored (maximum readable) precision.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard_rows(&self) -> usize {
        self.shard_rows
    }

    pub fn scale(&self) -> &ColumnScale {
        &self.shards[0].scale
    }

    /// Bytes one precision-`p` row read touches (uniform across shards).
    pub fn bytes_per_row(&self, p: u32) -> usize {
        self.shards[0].bytes_per_row(p)
    }

    /// Bytes touched by one full pass over all rows at precision `p` —
    /// the store-derived quantity the FPGA model consumes.
    pub fn epoch_bytes(&self, p: u32) -> f64 {
        self.rows as f64 * self.bytes_per_row(p) as f64
    }

    /// Total stored payload across shards (one copy, every precision).
    pub fn stored_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.bytes()).sum()
    }

    /// Build the per-plane occupancy index on every shard
    /// ([`WeavedMatrix::build_plane_index`]): truncating batch kernels
    /// then skip all-zero 8-word plane runs in O(1) per run. Results are
    /// bit-identical with or without the index (the sparse walk visits
    /// nonzero words in the dense order); only the loads change. The
    /// index is derived metadata — wire-byte accounting is untouched and
    /// its own footprint is reported by [`ShardedStore::index_bytes`].
    pub fn build_plane_index(&mut self) {
        for s in &mut self.shards {
            s.build_plane_index();
        }
    }

    /// Whether the occupancy index is resident (host trace metadata).
    pub fn has_plane_index(&self) -> bool {
        self.shards.iter().all(|s| s.has_plane_index())
    }

    /// Occupancy-index bytes across shards — derived metadata, reported
    /// separately from [`ShardedStore::stored_bytes`] and never part of
    /// any per-read wire figure (DESIGN.md §12).
    pub fn index_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.index_bytes()).sum()
    }

    /// Exact bytes touched by reads since construction / last reset: the
    /// relaxed sum over the per-shard padded cells.
    ///
    /// **Ordering contract:** every read path adds to its serving shard's
    /// cell with `Relaxed` ordering — the adds carry no happens-before
    /// edge with the data reads they account. The sum is *exact* (every
    /// byte is added exactly once) but only once writers have quiesced:
    /// read concurrently with in-flight readers it is a valid, possibly
    /// stale, partial snapshot. All in-repo consumers read it after a
    /// `thread::scope` join or from the owning thread, where it is the
    /// exact total.
    pub fn bytes_read(&self) -> u64 {
        // ordering: relaxed — exact after quiescence, valid partial
        // snapshot while readers race (contract in the doc above)
        self.shard_bytes.iter().map(|c| c.0.load(Ordering::Relaxed)).sum()
    }

    /// Bytes attributed to shard `si` since the last reset (same
    /// ordering contract as [`ShardedStore::bytes_read`]).
    pub fn shard_bytes_read(&self, si: usize) -> u64 {
        // ordering: relaxed — same snapshot contract as `bytes_read`
        self.shard_bytes[si].0.load(Ordering::Relaxed)
    }

    /// Zero every per-shard byte cell (relaxed stores; callers reset
    /// only from quiescent points, per the ordering contract).
    pub fn reset_bytes_read(&self) {
        for c in &self.shard_bytes {
            // ordering: relaxed — callers reset only from quiescent
            // points, never racing readers (ordering contract above)
            c.0.store(0, Ordering::Relaxed);
        }
    }

    /// Attach a telemetry registry: every subsequent read mirrors its
    /// exact byte accounting (plus row visits, plane words, RNG draws)
    /// into `m`. Stores start on [`Metrics::shared_disabled`], whose
    /// mask-gated recorders add 0 through the same instruction stream —
    /// attaching an enabled registry changes no control flow anywhere.
    pub fn attach_metrics(&mut self, m: Arc<Metrics>) {
        self.metrics = m;
    }

    /// The attached telemetry registry (the shared disabled one unless
    /// [`ShardedStore::attach_metrics`] was called).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

fn shard_rows_for(rows: usize, num_shards: usize) -> usize {
    let raw = rows.div_ceil(num_shards);
    raw.div_ceil(SHARD_ROW_ALIGN) * SHARD_ROW_ALIGN
}

/// Deterministic shuffled minibatch iterator over a store's rows.
///
/// All workers sharing (rows, batch, seed) see the same shuffled order;
/// [`MinibatchIter::strided`] gives worker w batches w, w+W, w+2W, … so W
/// workers partition the epoch exactly, without coordination. The tail
/// partial batch is dropped — full batches keep the worker partition
/// coordination-free; the single-threaded SGD drivers visit the ragged
/// tail themselves (see the `sgd::host` sequential epoch skeleton).
pub struct MinibatchIter {
    order: Vec<u32>,
    batch: usize,
    next_batch: usize,
    stride: usize,
    num_batches: usize,
}

impl MinibatchIter {
    pub fn new(rows: usize, batch: usize, seed: u64) -> Self {
        Self::strided(rows, batch, seed, 0, 1)
    }

    pub fn strided(
        rows: usize,
        batch: usize,
        seed: u64,
        worker: usize,
        num_workers: usize,
    ) -> Self {
        assert!(batch >= 1);
        assert!(num_workers >= 1 && worker < num_workers, "worker {worker} of {num_workers}");
        let mut order: Vec<u32> = (0..rows as u32).collect();
        Rng::new(seed).shuffle(&mut order);
        MinibatchIter {
            order,
            batch,
            next_batch: worker,
            stride: num_workers,
            num_batches: rows / batch,
        }
    }

    /// Next batch of row indices for this worker, or `None` at epoch end.
    pub fn next_batch(&mut self) -> Option<&[u32]> {
        if self.next_batch >= self.num_batches {
            return None;
        }
        let b = self.next_batch;
        self.next_batch += self.stride;
        Some(&self.order[b * self.batch..(b + 1) * self.batch])
    }

    /// Total batches in the epoch (across all workers).
    pub fn num_batches(&self) -> usize {
        self.num_batches
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(rows: usize, cols: usize, seed: u64) -> (Matrix, ColumnScale) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let a = Matrix::from_vec(rows, cols, data);
        let s = ColumnScale::from_data(&a);
        (a, s)
    }

    #[test]
    fn ingest_deterministic_across_thread_counts() {
        let (a, sc) = mk(100, 17, 1);
        let s1 = ShardedStore::ingest(&a, &sc, 6, 42, 7, 1);
        let s4 = ShardedStore::ingest(&a, &sc, 6, 42, 7, 4);
        assert_eq!(s1.num_shards(), s4.num_shards());
        let (mut i1, mut i4) = (vec![0u16; 17], vec![0u16; 17]);
        for r in 0..100 {
            s1.read_row(r, 6, &mut i1);
            s4.read_row(r, 6, &mut i4);
            assert_eq!(i1, i4, "row {r}");
        }
    }

    #[test]
    fn from_packed_routes_rows_exactly() {
        let (a, sc) = mk(50, 40, 2);
        let mut rng = Rng::new(3);
        let packed = PackedMatrix::quantize(&a, &sc, 8, &mut rng);
        for num_shards in [1usize, 3, 7, 50] {
            let store = ShardedStore::from_packed(&packed, num_shards);
            let (mut dq, mut dp) = (vec![0.0f32; 40], vec![0.0f32; 40]);
            for r in 0..50 {
                store.dequantize_row(r, 8, &mut dq);
                packed.dequantize_row(r, &mut dp);
                assert_eq!(dq, dp, "shards={num_shards} row {r}");
            }
        }
    }

    #[test]
    fn shard_payloads_are_cache_line_multiples() {
        let (a, sc) = mk(1000, 100, 4);
        let store = ShardedStore::ingest(&a, &sc, 8, 7, 13, 1);
        assert_eq!(store.shard_rows() % SHARD_ROW_ALIGN, 0);
        // every full shard's payload is a whole number of 64 B lines
        assert_eq!(store.shard_rows() * store.bits() as usize * 8 * 2 % 64, 0);
    }

    #[test]
    fn bytes_accounting_is_exact() {
        let (a, sc) = mk(64, 100, 5);
        let store = ShardedStore::ingest(&a, &sc, 8, 9, 4, 1);
        let mut out = vec![0.0f32; 100];
        store.reset_bytes_read();
        for r in 0..64 {
            store.dequantize_row(r, 4, &mut out);
        }
        // 100 cols → 2 words/plane → 4 planes × 16 B × 64 rows
        assert_eq!(store.bytes_read(), 64 * 4 * 2 * 8);
        assert_eq!(store.bytes_read(), store.epoch_bytes(4) as u64);
        // monotone in precision, below one f32 epoch
        let fp_bytes = 64.0 * 100.0 * 4.0;
        let mut prev = 0.0;
        for p in [1u32, 2, 4, 8] {
            let b = store.epoch_bytes(p);
            assert!(b > prev);
            assert!(b < fp_bytes, "Q{p} {b} !< f32 {fp_bytes}");
            prev = b;
        }
    }

    /// Fused per-shard batch gradient equals the dequantize-row reference
    /// within tolerance, and accounts exactly the bytes the row-read path
    /// would for the same rows.
    #[test]
    fn fused_grad_batch_matches_dequant_and_accounting() {
        let (a, sc) = mk(96, 70, 6);
        let store = ShardedStore::ingest(&a, &sc, 8, 13, 5, 1);
        let mut rng = crate::rng::Rng::new(9);
        let x: Vec<f32> = (0..70).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(70);
        k.refresh(&sc.m, &x);
        // a shard-crossing minibatch in shuffled order
        let rows: Vec<usize> = vec![95, 3, 40, 41, 0, 77, 12, 63];
        let targets: Vec<f32> = rows.iter().map(|&r| r as f32 * 0.1).collect();
        for p in [2u32, 8] {
            store.reset_bytes_read();
            let mut grad = vec![0.0f32; 70];
            let bytes = store.fused_grad_batch(&rows, p, &k, &targets, &mut grad);
            assert_eq!(bytes, rows.len() * store.bytes_per_row(p));
            assert_eq!(store.bytes_read(), bytes as u64);

            // reference: dequantize each row, dot, axpy (the oracle path)
            store.reset_bytes_read();
            let mut want = vec![0.0f64; 70];
            let mut mag = vec![0.0f64; 70];
            let mut row = vec![0.0f32; 70];
            for (&r, &t) in rows.iter().zip(&targets) {
                store.dequantize_row(r, p, &mut row);
                let err = crate::tensor::dot(&row, &x) - t;
                for ((o, g), &v) in want.iter_mut().zip(mag.iter_mut()).zip(&row) {
                    *o += err as f64 * v as f64;
                    *g += (err as f64 * v as f64).abs();
                }
            }
            // identical byte accounting across the two paths
            assert_eq!(store.bytes_read(), bytes as u64);
            for c in 0..70 {
                let w = want[c];
                assert!(
                    (grad[c] as f64 - w).abs() <= 1e-4 * (1.0 + mag[c]),
                    "p={p} c={c}: {} vs {w}",
                    grad[c]
                );
            }
        }
    }

    /// ds_grad_batch: counts exactly 2× the truncating batch's bytes, is
    /// deterministic in the rng state, and at p = stored width reproduces
    /// the truncating fused batch (carry-free draws) within tolerance.
    #[test]
    fn ds_grad_batch_accounting_and_full_width_degeneration() {
        let (a, sc) = mk(96, 70, 26);
        let store = ShardedStore::ingest(&a, &sc, 8, 13, 5, 1);
        let mut rng = crate::rng::Rng::new(9);
        let x: Vec<f32> = (0..70).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(70);
        k.refresh(&sc.m, &x);
        let rows: Vec<usize> = vec![95, 3, 40, 41, 0, 77, 12, 63];
        let targets: Vec<f32> = rows.iter().map(|&r| r as f32 * 0.1).collect();
        for p in [2u32, 8] {
            store.reset_bytes_read();
            let mut g1 = vec![0.0f32; 70];
            let bytes =
                store.ds_grad_batch(&rows, p, &k, &targets, &mut crate::rng::Rng::new(4), &mut g1);
            assert_eq!(bytes, 2 * rows.len() * store.bytes_per_row(p), "both draws count");
            assert_eq!(store.bytes_read(), bytes as u64);
            // deterministic: same rng state, bit-identical gradient
            let mut g2 = vec![0.0f32; 70];
            store.ds_grad_batch(&rows, p, &k, &targets, &mut crate::rng::Rng::new(4), &mut g2);
            assert_eq!(g1, g2);
            // different stream, different draws below full width
            let mut g3 = vec![0.0f32; 70];
            store.ds_grad_batch(&rows, p, &k, &targets, &mut crate::rng::Rng::new(5), &mut g3);
            if p < 8 {
                assert_ne!(g1, g3, "p={p}: carry draws ignored the rng");
            }
        }
        // full width: equals the truncating fused batch within tolerance
        let mut gds = vec![0.0f32; 70];
        let mut gtr = vec![0.0f32; 70];
        store.ds_grad_batch(&rows, 8, &k, &targets, &mut crate::rng::Rng::new(4), &mut gds);
        store.fused_grad_batch(&rows, 8, &k, &targets, &mut gtr);
        for c in 0..70 {
            assert!(
                (gds[c] - gtr[c]).abs() <= 1e-3 * (1.0 + gtr[c].abs()),
                "c={c}: ds {} vs trunc {}",
                gds[c],
                gtr[c]
            );
        }
    }

    /// dot_row_fused counts bytes like read_row and matches the oracle.
    #[test]
    fn dot_row_fused_accounts_and_matches() {
        let (a, sc) = mk(40, 33, 8);
        let store = ShardedStore::ingest(&a, &sc, 6, 17, 4, 1);
        let mut rng = crate::rng::Rng::new(2);
        let x: Vec<f32> = (0..33).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(33);
        k.refresh(&sc.m, &x);
        let mut row = vec![0.0f32; 33];
        store.reset_bytes_read();
        for r in 0..40 {
            let d = store.dot_row_fused(r, 3, &k);
            store.dequantize_row(r, 3, &mut row);
            let want = crate::tensor::dot(&row, &x);
            assert!((d - want).abs() <= 1e-4 * (1.0 + want.abs()), "row {r}: {d} vs {want}");
        }
        // both paths counted: 2 passes × 40 rows × bytes_per_row(3)
        assert_eq!(store.bytes_read(), (2 * 40 * store.bytes_per_row(3)) as u64);
    }

    /// The blocked batch gradient is BIT-FOR-BIT equal to the per-row
    /// kernels run over the specified shard-grouped order (ascending
    /// shard id, batch order within a shard) — the tentpole's exactness
    /// contract at the store level, including duplicate rows.
    #[test]
    fn fused_grad_batch_bit_identical_to_per_row_reference() {
        let (a, sc) = mk(96, 70, 36);
        let store = ShardedStore::ingest(&a, &sc, 8, 13, 5, 1);
        let mut rng = crate::rng::Rng::new(9);
        let x: Vec<f32> = (0..70).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(70);
        k.refresh(&sc.m, &x);
        let rows: Vec<usize> = vec![95, 3, 40, 3, 0, 77, 12, 63, 40];
        let targets: Vec<f32> = rows.iter().map(|&r| r as f32 * 0.1).collect();
        for p in [1u32, 3, 8] {
            let mut blocked = vec![0.0f32; 70];
            store.fused_grad_batch(&rows, p, &k, &targets, &mut blocked);

            // per-row reference over the same specified visit order
            let mut order: Vec<usize> = (0..rows.len()).collect();
            order.sort_by_key(|&i| rows[i] / store.shard_rows()); // stable
            let mut want = vec![0.0f32; 70];
            let mut err_sum = 0.0f32;
            for &i in &order {
                let (shard, local) = store.locate_row(rows[i]);
                let err = kernel::dot_row(shard, local, p, &k) - targets[i];
                kernel::axpy_row_planes(shard, local, p, err, &mut want);
                err_sum += err;
            }
            kernel::axpy_affine(err_sum, &sc.m, &mut want);
            for c in 0..70 {
                assert_eq!(
                    blocked[c].to_bits(),
                    want[c].to_bits(),
                    "p={p} c={c}: blocked {} vs per-row {}",
                    blocked[c],
                    want[c]
                );
            }
        }
    }

    /// Popcount batch gradient: tracks the exact fused batch at high q,
    /// replays bit for bit from its rounding seed, and accounts exactly
    /// the truncating path's bytes (ĝ planes are not sample traffic).
    #[test]
    fn fused_grad_batch_q_tracks_exact_and_accounts() {
        let (a, sc) = mk(96, 70, 46);
        let store = ShardedStore::ingest(&a, &sc, 8, 13, 5, 1);
        let mut rng = crate::rng::Rng::new(9);
        let x: Vec<f32> = (0..70).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(70);
        k.refresh(&sc.m, &x);
        let mut qk = kernel::QuantStepKernel::new(70, 16);
        qk.refresh(&sc.m, &x, &mut crate::rng::Rng::new(4));
        let rows: Vec<usize> = vec![95, 3, 40, 41, 0, 77, 12, 63];
        let targets: Vec<f32> = rows.iter().map(|&r| r as f32 * 0.1).collect();
        for p in [2u32, 8] {
            store.reset_bytes_read();
            let mut gq = vec![0.0f32; 70];
            let bytes = store.fused_grad_batch_q(&rows, p, &qk, &targets, &mut gq);
            assert_eq!(bytes, rows.len() * store.bytes_per_row(p), "same bytes as truncating");
            assert_eq!(store.bytes_read(), bytes as u64);
            // replay: same rounding draw, bit-identical gradient
            let mut qk2 = kernel::QuantStepKernel::new(70, 16);
            qk2.refresh(&sc.m, &x, &mut crate::rng::Rng::new(4));
            let mut gq2 = vec![0.0f32; 70];
            store.fused_grad_batch_q(&rows, p, &qk2, &targets, &mut gq2);
            assert_eq!(gq, gq2, "p={p}: popcount batch not deterministic");
            // at q = 16 the rounding noise is far below the test tolerance
            let mut gx = vec![0.0f32; 70];
            store.fused_grad_batch(&rows, p, &k, &targets, &mut gx);
            for c in 0..70 {
                assert!(
                    (gq[c] - gx[c]).abs() <= 1e-2 * (1.0 + gx[c].abs()),
                    "p={p} c={c}: popcount {} vs exact {}",
                    gq[c],
                    gx[c]
                );
            }
        }
    }

    /// dot_rows_fused: bit-identical to dot_row_fused per row, counted
    /// once per row like the row-read path.
    #[test]
    fn dot_rows_fused_matches_per_row_and_accounts() {
        let (a, sc) = mk(40, 33, 8);
        let store = ShardedStore::ingest(&a, &sc, 6, 17, 4, 1);
        let mut rng = crate::rng::Rng::new(2);
        let x: Vec<f32> = (0..33).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(33);
        k.refresh(&sc.m, &x);
        let rows: Vec<usize> = vec![39, 0, 17, 17, 8, 25];
        let mut out = vec![0.0f32; rows.len()];
        store.reset_bytes_read();
        let bytes = store.dot_rows_fused(&rows, 3, &k, &mut out);
        assert_eq!(bytes, rows.len() * store.bytes_per_row(3));
        let counted = store.bytes_read();
        for (i, &r) in rows.iter().enumerate() {
            assert_eq!(out[i].to_bits(), store.dot_row_fused(r, 3, &k).to_bits(), "row {r}");
        }
        assert_eq!(store.bytes_read(), counted + bytes as u64, "per-row pass counts the same");
    }

    /// Per-shard byte cells: sum to exactly the old global total, and an
    /// attached enabled registry mirrors the store's accounting
    /// bit-for-bit (the tentpole's first hard contract, store level).
    #[test]
    fn per_shard_attribution_and_metrics_mirror_store_accounting() {
        let (a, sc) = mk(100, 17, 11);
        let mut store = ShardedStore::ingest(&a, &sc, 6, 42, 7, 1);
        let m = Arc::new(Metrics::enabled());
        store.attach_metrics(m.clone());
        assert!(store.metrics().is_enabled());
        let mut out = vec![0u16; 17];
        for r in 0..100 {
            store.read_row(r, 4, &mut out);
        }
        let per_shard: u64 = (0..store.num_shards()).map(|s| store.shard_bytes_read(s)).sum();
        assert_eq!(per_shard, store.bytes_read());
        assert_eq!(store.bytes_read(), store.epoch_bytes(4) as u64);
        assert_eq!(m.bytes_read_total(), store.bytes_read());
        assert_eq!(m.bytes_read_at(4), store.bytes_read());
        assert_eq!(m.row_visits(), 100);
        assert_eq!(m.plane_words(), store.bytes_read() / 8);

        // fused + DS batches: shard cells, metrics buckets, and RNG-draw
        // tallies all stay in lockstep with the returned byte counts
        store.reset_bytes_read();
        m.reset();
        let mut rng = crate::rng::Rng::new(9);
        let x: Vec<f32> = (0..17).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(17);
        k.refresh(&sc.m, &x);
        let rows: Vec<usize> = vec![99, 3, 40, 41, 0, 77, 12, 63];
        let targets: Vec<f32> = rows.iter().map(|&r| r as f32 * 0.1).collect();
        let mut grad = vec![0.0f32; 17];
        let b1 = store.fused_grad_batch(&rows, 3, &k, &targets, &mut grad);
        let b2 =
            store.ds_grad_batch(&rows, 3, &k, &targets, &mut crate::rng::Rng::new(4), &mut grad);
        assert_eq!(b2, 2 * b1, "DS costs exactly 2x the truncating batch");
        assert_eq!(store.bytes_read(), (b1 + b2) as u64);
        assert_eq!(m.bytes_read_total(), store.bytes_read());
        assert_eq!(m.bytes_read_at(3), store.bytes_read());
        assert_eq!(m.row_visits(), 2 * rows.len() as u64);
        assert_eq!(m.rng_draws(), 2 * rows.len() as u64, "2 draws per DS row visit");
        let per_shard: u64 = (0..store.num_shards()).map(|s| store.shard_bytes_read(s)).sum();
        assert_eq!(per_shard, store.bytes_read());

        // note_row_visit: the fused per-row accounting half
        store.reset_bytes_read();
        let nb = store.note_row_visit(99, 5, 2, 1);
        assert_eq!(nb, 2 * store.bytes_per_row(5));
        assert_eq!(store.bytes_read(), nb as u64);
        assert_eq!(store.shard_bytes_read(99 / store.shard_rows()), nb as u64);
    }

    /// The plane-index fast path is invisible to results and accounting:
    /// building the index changes no fused-batch bit, no wire byte, and
    /// its own footprint is reported separately.
    #[test]
    fn plane_index_preserves_results_and_wire_accounting() {
        let (a, sc) = mk(96, 70, 56);
        let mut store = ShardedStore::ingest(&a, &sc, 8, 13, 5, 1);
        let mut rng = crate::rng::Rng::new(9);
        let x: Vec<f32> = (0..70).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(70);
        k.refresh(&sc.m, &x);
        let rows: Vec<usize> = vec![95, 3, 40, 3, 0, 77, 12, 63, 40];
        let targets: Vec<f32> = rows.iter().map(|&r| r as f32 * 0.1).collect();
        let mut dense = vec![0.0f32; 70];
        store.reset_bytes_read();
        let bytes_dense = store.fused_grad_batch(&rows, 3, &k, &targets, &mut dense);
        let counted_dense = store.bytes_read();

        assert!(!store.has_plane_index());
        store.build_plane_index();
        assert!(store.has_plane_index());
        assert!(store.index_bytes() > 0);
        // 70 cols → 2 words/plane → 1 occ byte per plane, 8 bits × shard rows
        let expect: usize = (0..store.num_shards())
            .map(|si| {
                let r0 = si * store.shard_rows();
                (store.shard_rows().min(store.rows() - r0)) * store.bits() as usize
            })
            .sum();
        assert_eq!(store.index_bytes(), expect);

        let mut indexed = vec![0.0f32; 70];
        store.reset_bytes_read();
        let bytes_indexed = store.fused_grad_batch(&rows, 3, &k, &targets, &mut indexed);
        for c in 0..70 {
            assert_eq!(
                dense[c].to_bits(),
                indexed[c].to_bits(),
                "c={c}: dense {} vs indexed {}",
                dense[c],
                indexed[c]
            );
        }
        // wire accounting is byte-identical: the index never crosses it
        assert_eq!(bytes_indexed, bytes_dense);
        assert_eq!(store.bytes_read(), counted_dense);
    }

    #[test]
    fn minibatch_iter_is_partition() {
        let rows = 103usize;
        let batch = 10usize;
        let mut seen = vec![0u32; rows];
        let workers = 3usize;
        let mut total_batches = 0;
        for w in 0..workers {
            let mut it = MinibatchIter::strided(rows, batch, 77, w, workers);
            while let Some(b) = it.next_batch() {
                total_batches += 1;
                assert_eq!(b.len(), batch);
                for &r in b {
                    seen[r as usize] += 1;
                }
            }
        }
        assert_eq!(total_batches, rows / batch);
        // every row appears at most once; exactly batch*num_batches rows once
        assert!(seen.iter().all(|&c| c <= 1));
        assert_eq!(seen.iter().sum::<u32>() as usize, batch * (rows / batch));
        // deterministic: same seed, same first batch
        let mut a = MinibatchIter::new(rows, batch, 77);
        let mut b = MinibatchIter::new(rows, batch, 77);
        assert_eq!(a.next_batch(), b.next_batch());
    }
}
