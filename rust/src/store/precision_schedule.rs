//! Per-epoch precision schedules for store-backed training.
//!
//! One weaved copy serves every precision (see [`super::weave`]), so the
//! *reader* chooses how many bit planes to fetch each epoch. Three
//! policies, in the spirit of HALP-style precision scheduling:
//!
//! * [`PrecisionSchedule::Fixed`] — constant p (the classic single-width
//!   run, now without a per-width copy).
//! * [`PrecisionSchedule::StepUp`] — start coarse, double p every `every`
//!   epochs: early epochs are bandwidth-cheap while gradients are large,
//!   late epochs refine near the optimum.
//! * [`PrecisionSchedule::RefetchTriggered`] — double p whenever the
//!   relative loss improvement stalls below `min_rel_improve`: the
//!   quantization noise floor has been reached, so refetch more planes
//!   (the store-level analogue of §G's per-sample refetching).
//!
//! All schedules are clamped to `[1, store.bits()]`.

/// Which per-epoch precision policy to run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PrecisionSchedule {
    /// Constant precision.
    Fixed(u32),
    /// Start at `start` bits, double every `every` epochs, cap at `max`.
    StepUp { start: u32, every: usize, max: u32 },
    /// Start at `start`; double (up to `max`) whenever the last epoch's
    /// relative loss improvement drops below `min_rel_improve`.
    RefetchTriggered { start: u32, max: u32, min_rel_improve: f64 },
}

impl PrecisionSchedule {
    pub fn label(&self) -> String {
        match *self {
            PrecisionSchedule::Fixed(p) => format!("p{p}"),
            PrecisionSchedule::StepUp { start, every, max } => {
                format!("step{start}-{max}every{every}")
            }
            PrecisionSchedule::RefetchTriggered { start, max, .. } => {
                format!("refetch{start}-{max}")
            }
        }
    }
}

/// Stateful schedule evaluator (the trigger policy is monotone in p).
#[derive(Clone, Debug)]
pub struct ScheduleState {
    schedule: PrecisionSchedule,
    store_bits: u32,
    current: u32,
}

impl ScheduleState {
    pub fn new(schedule: PrecisionSchedule, store_bits: u32) -> Self {
        assert!(store_bits >= 1);
        let start = match schedule {
            PrecisionSchedule::Fixed(p) => p,
            PrecisionSchedule::StepUp { start, .. }
            | PrecisionSchedule::RefetchTriggered { start, .. } => start,
        };
        ScheduleState { schedule, store_bits, current: start.clamp(1, store_bits) }
    }

    /// Precision to read this epoch. `loss_history` holds per-epoch losses
    /// so far, `loss_history[0]` being the pre-training loss.
    pub fn precision_for_epoch(&mut self, epoch: usize, loss_history: &[f64]) -> u32 {
        let p = match self.schedule {
            PrecisionSchedule::Fixed(p) => p,
            PrecisionSchedule::StepUp { start, every, max } => {
                let doublings = if every == 0 { 0 } else { (epoch / every).min(16) as u32 };
                start.saturating_mul(1u32 << doublings).min(max)
            }
            PrecisionSchedule::RefetchTriggered { max, min_rel_improve, .. } => {
                if loss_history.len() >= 2 {
                    let prev = loss_history[loss_history.len() - 2];
                    let last = loss_history[loss_history.len() - 1];
                    let rel = (prev - last) / prev.abs().max(1e-12);
                    if rel < min_rel_improve {
                        // never step down, even if max < start
                        self.current =
                            self.current.saturating_mul(2).min(max).max(self.current);
                    }
                }
                self.current
            }
        };
        self.current = p.clamp(1, self.store_bits);
        self.current
    }

    /// Precision most recently returned by `precision_for_epoch`; before
    /// the first epoch this is the schedule's start value, clamped to
    /// `[1, store_bits]`.
    pub fn current(&self) -> u32 {
        self.current
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_is_fixed_and_clamped() {
        let mut s = ScheduleState::new(PrecisionSchedule::Fixed(12), 8);
        for e in 0..5 {
            assert_eq!(s.precision_for_epoch(e, &[]), 8);
        }
        let mut s = ScheduleState::new(PrecisionSchedule::Fixed(3), 8);
        assert_eq!(s.precision_for_epoch(0, &[]), 3);
    }

    #[test]
    fn step_up_doubles_and_caps() {
        let mut s =
            ScheduleState::new(PrecisionSchedule::StepUp { start: 1, every: 2, max: 8 }, 8);
        let ps: Vec<u32> = (0..8).map(|e| s.precision_for_epoch(e, &[])).collect();
        assert_eq!(ps, vec![1, 1, 2, 2, 4, 4, 8, 8]);
        // stays capped far beyond the last doubling
        assert_eq!(s.precision_for_epoch(40, &[]), 8);
    }

    #[test]
    fn refetch_trigger_fires_on_plateau_only() {
        let sched =
            PrecisionSchedule::RefetchTriggered { start: 2, max: 8, min_rel_improve: 0.05 };
        let mut s = ScheduleState::new(sched, 8);
        // strong improvement: stay at 2
        assert_eq!(s.precision_for_epoch(0, &[1.0]), 2);
        assert_eq!(s.precision_for_epoch(1, &[1.0, 0.5]), 2);
        // plateau: double
        assert_eq!(s.precision_for_epoch(2, &[1.0, 0.5, 0.499]), 4);
        // plateau again: double to the cap
        assert_eq!(s.precision_for_epoch(3, &[1.0, 0.5, 0.499, 0.498]), 8);
        assert_eq!(s.precision_for_epoch(4, &[1.0, 0.5, 0.499, 0.498, 0.4979]), 8);
    }

    #[test]
    fn monotone_and_bounded_always() {
        let mut s = ScheduleState::new(
            PrecisionSchedule::RefetchTriggered { start: 1, max: 16, min_rel_improve: 1.0 },
            6, // store narrower than max
        );
        let mut prev = 0;
        let mut hist = vec![1.0f64];
        for e in 0..10 {
            let p = s.precision_for_epoch(e, &hist);
            assert!((1..=6).contains(&p));
            assert!(p >= prev);
            prev = p;
            hist.push(hist.last().unwrap() * 0.999); // always a plateau
        }
        assert_eq!(prev, 6);
    }

    #[test]
    fn labels_distinct() {
        let labels = [
            PrecisionSchedule::Fixed(4).label(),
            PrecisionSchedule::StepUp { start: 1, every: 2, max: 8 }.label(),
            PrecisionSchedule::RefetchTriggered { start: 2, max: 8, min_rel_improve: 0.01 }
                .label(),
        ];
        assert_ne!(labels[0], labels[1]);
        assert_ne!(labels[1], labels[2]);
    }
}
