//! Low-randomness ±1 Johnson-Lindenstrauss sketches (§G.3.1, Theorem 5).
//!
//! Used by ℓ2-refetching: transmitter and receiver share a seed, both
//! materialize the same r×n ±1 matrix row stream, and estimate
//! aᵀx = (‖M a − M x‖² − ‖M a‖² − ‖M x‖²)/(−2) from sketches alone —
//! detecting potential hinge-gradient sign flips with sublinear
//! communication.

use crate::rng::Rng;

/// A seeded ±1/√r sketching matrix, materialized on demand.
#[derive(Clone, Debug)]
pub struct JlSketch {
    pub r: usize,
    pub n: usize,
    seed: u64,
}

impl JlSketch {
    pub fn new(r: usize, n: usize, seed: u64) -> Self {
        JlSketch { r, n, seed }
    }

    /// Sketch s = M v, with M_ij ∈ {±1/√r} derived from the shared seed.
    pub fn sketch(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.n);
        let inv_sqrt_r = 1.0 / (self.r as f32).sqrt();
        let mut out = vec![0.0f32; self.r];
        // One RNG per sketch row keeps rows independent and allows the
        // receiver to regenerate any row without storing the matrix.
        for (i, o) in out.iter_mut().enumerate() {
            let mut rng = Rng::new(self.seed ^ ((i as u64 + 1) * 0x9E3779B97F4A7C15));
            let mut acc = 0.0f32;
            // draw 64 signs per u64
            let mut j = 0;
            while j < self.n {
                let mut bits = rng.next_u64();
                let lim = (self.n - j).min(64);
                for _ in 0..lim {
                    let sign = if bits & 1 == 0 { 1.0f32 } else { -1.0f32 };
                    acc += sign * v[j];
                    bits >>= 1;
                    j += 1;
                }
            }
            *o = acc * inv_sqrt_r;
        }
        out
    }

    /// Estimate ⟨a, x⟩ from the two sketches (Corollary 4's identity).
    pub fn est_dot(sa: &[f32], sx: &[f32]) -> f32 {
        crate::tensor::dot(sa, sx)
    }

    /// Communication cost of one sketched sample in bytes (r floats at
    /// `bits_per_coord` precision).
    pub fn sketch_bytes(&self, bits_per_coord: u32) -> usize {
        (self.r * bits_per_coord as usize).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{dot, norm2};

    #[test]
    fn norm_preserved_within_factor() {
        let mut rng = Rng::new(1);
        let n = 512;
        let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let jl = JlSketch::new(256, n, 42);
        let s = jl.sketch(&v);
        let ratio = norm2(&s) / norm2(&v);
        assert!((0.8..1.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn dot_estimated() {
        let mut rng = Rng::new(2);
        let n = 256;
        let a: Vec<f32> = (0..n).map(|_| rng.normal() / (n as f32).sqrt()).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.normal() / (n as f32).sqrt()).collect();
        let jl = JlSketch::new(512, n, 7);
        let (sa, sx) = (jl.sketch(&a), jl.sketch(&x));
        let est = JlSketch::est_dot(&sa, &sx);
        let exact = dot(&a, &x);
        assert!((est - exact).abs() < 0.25, "est {est} exact {exact}");
    }

    #[test]
    fn deterministic_given_seed() {
        let v: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let a = JlSketch::new(16, 64, 5).sketch(&v);
        let b = JlSketch::new(16, 64, 5).sketch(&v);
        assert_eq!(a, b);
        let c = JlSketch::new(16, 64, 6).sketch(&v);
        assert_ne!(a, c);
    }

    #[test]
    fn sketch_is_linear() {
        let mut rng = Rng::new(3);
        let n = 128;
        let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let diff: Vec<f32> = a.iter().zip(&x).map(|(p, q)| p - q).collect();
        let jl = JlSketch::new(64, n, 11);
        let (sa, sx, sd) = (jl.sketch(&a), jl.sketch(&x), jl.sketch(&diff));
        for i in 0..64 {
            assert!((sd[i] - (sa[i] - sx[i])).abs() < 1e-3);
        }
    }
}
