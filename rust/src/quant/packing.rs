//! Bit-packed storage for quantized samples.
//!
//! [`PackedMatrix`] stores one level index per value at an arbitrary bit
//! width (1..=16) in a contiguous little-endian bit stream — the
//! "SampleStore" of the paper's computation model (Fig 2), and the unit of
//! the bandwidth accounting used by the Fig 5 / bandwidth experiments.
//!
//! [`DoubleSampleBlock`] implements §2.2 "Overhead of Storing Samples":
//! the k independent stochastic quantizations of a value all land on the
//! two endpoints of the *same* grid interval, so we store the lower index
//! once (b bits) plus one up/down bit per extra sample — and because the
//! samples are used symmetrically, transmitting only the *count* of lows
//! costs ⌈log₂(k+1)⌉ bits (`extra_bits_symmetric`).

use crate::quant::scaling::ColumnScale;
use crate::rng::Rng;

/// Append-only little-endian bit writer over a `Vec<u8>`.
#[derive(Clone, Debug, Default)]
pub struct BitVec {
    pub data: Vec<u8>,
    len_bits: usize,
}

impl BitVec {
    pub fn with_capacity_bits(bits: usize) -> Self {
        BitVec { data: Vec::with_capacity(bits.div_ceil(8)), len_bits: 0 }
    }

    #[inline]
    pub fn push(&mut self, value: u32, width: u32) {
        debug_assert!(width <= 32 && (width == 32 || value < (1u32 << width)));
        let mut v = value as u64;
        let mut w = width as usize;
        while w > 0 {
            let byte = self.len_bits / 8;
            let off = self.len_bits % 8;
            if byte == self.data.len() {
                self.data.push(0);
            }
            let take = (8 - off).min(w);
            self.data[byte] |= ((v & ((1u64 << take) - 1)) as u8) << off;
            v >>= take;
            w -= take;
            self.len_bits += take;
        }
    }

    #[inline]
    pub fn get(&self, bit_off: usize, width: u32) -> u32 {
        let mut out = 0u64;
        let mut got = 0usize;
        let mut pos = bit_off;
        while got < width as usize {
            let byte = pos / 8;
            let off = pos % 8;
            let take = (8 - off).min(width as usize - got);
            let bits = (self.data[byte] as u64 >> off) & ((1u64 << take) - 1);
            out |= bits << got;
            got += take;
            pos += take;
        }
        out as u32
    }

    pub fn len_bits(&self) -> usize {
        self.len_bits
    }

    pub fn len_bytes(&self) -> usize {
        self.data.len()
    }
}

/// Word-at-a-time packer: ~10x the throughput of per-bit BitVec pushes
/// (EXPERIMENTS.md §Perf L3-2). Little-endian bit order, compatible with
/// `BitVec::get`.
fn pack_indices(idx: &[u16], bits: u32) -> Vec<u8> {
    let total_bits = idx.len() * bits as usize;
    let mut data = vec![0u8; total_bits.div_ceil(8)];
    let mut acc: u64 = 0;
    let mut nbits: usize = 0;
    let mut pos = 0usize;
    for &i in idx {
        acc |= (i as u64) << nbits;
        nbits += bits as usize;
        while nbits >= 8 {
            data[pos] = acc as u8;
            acc >>= 8;
            nbits -= 8;
            pos += 1;
        }
    }
    if nbits > 0 {
        data[pos] = acc as u8;
    }
    data
}

/// Word-at-a-time unpack of `count` values starting at `bit_off`; calls
/// `out(i, idx)` for i in 0..count. Same bit order as `pack_indices`.
#[inline]
fn unpack_span(data: &[u8], bit_off: usize, bits: u32, count: usize, mut out: impl FnMut(usize, u16)) {
    let w = bits as usize;
    let mask = (1u64 << w) - 1;
    let mut byte = bit_off / 8;
    let mut acc: u64 = 0;
    let mut nbits = 0usize;
    let skip = bit_off % 8;
    if skip > 0 {
        acc = (data[byte] >> skip) as u64;
        nbits = 8 - skip;
        byte += 1;
    }
    for i in 0..count {
        while nbits < w {
            if byte < data.len() {
                acc |= (data[byte] as u64) << nbits;
                byte += 1;
            }
            nbits += 8;
        }
        out(i, (acc & mask) as u16);
        acc >>= w;
        nbits -= w;
    }
}

/// A (rows × cols) matrix of level indices packed at `bits` per value.
#[derive(Clone, Debug)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    /// Interval count s (levels are 0..=s on the symmetric grid).
    pub s: u32,
    pub scale: ColumnScale,
    data: Vec<u8>,
}

impl PackedMatrix {
    /// Quantize a dense matrix into packed indices (one stochastic draw).
    pub fn quantize(
        a: &crate::tensor::Matrix,
        scale: &ColumnScale,
        bits: u32,
        rng: &mut Rng,
    ) -> Self {
        let s = crate::quant::intervals_for_bits(bits);
        let mut idx = vec![0u16; a.rows * a.cols];
        crate::quant::stochastic::quantize_indices(&a.data, a.cols, &scale.m, s, rng, &mut idx);
        PackedMatrix {
            rows: a.rows,
            cols: a.cols,
            bits,
            s,
            scale: scale.clone(),
            data: pack_indices(&idx, bits),
        }
    }

    #[inline]
    pub fn index(&self, r: usize, c: usize) -> u16 {
        let mut v = 0u16;
        unpack_span(&self.data, (r * self.cols + c) * self.bits as usize, self.bits, 1, |_, x| v = x);
        v
    }

    /// Dequantize row `r` into `out` (len == cols).
    pub fn dequantize_row(&self, r: usize, out: &mut [f32]) {
        let base = r * self.cols * self.bits as usize;
        // hoist the per-column dequant constants (§Perf L3-2)
        let inv_s2 = 2.0 / self.s as f32;
        let m = &self.scale.m;
        unpack_span(&self.data, base, self.bits, self.cols, |c, idx| {
            out[c] = (idx as f32 * inv_s2 - 1.0) * m[c];
        });
    }

    /// Raw u8 level indices for row `r` (bits ≤ 8) — feeds the u8 artifacts.
    pub fn indices_row_u8(&self, r: usize, out: &mut [u8]) {
        assert!(self.bits <= 8);
        let base = r * self.cols * self.bits as usize;
        unpack_span(&self.data, base, self.bits, self.cols, |c, idx| {
            out[c] = idx as u8;
        });
    }

    /// Stored payload size — the "memory traffic per epoch" unit.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// §2.2: k double-sampled quantizations of a sample batch, stored as base
/// indices + one offset bit per (value, sample).
#[derive(Clone, Debug)]
pub struct DoubleSampleBlock {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub s: u32,
    pub k: usize,
    pub scale: ColumnScale,
    base: Vec<u8>,
    /// rows*cols*k bits, sample-major per value
    offsets: Vec<u8>,
}

impl DoubleSampleBlock {
    /// Quantize `a` with `k` independent draws sharing the base interval.
    ///
    /// Randomness is drawn as 24-bit integer lanes (two per `next_u64`) and
    /// compared against a 24-bit threshold — exact to f32-uniform precision
    /// at half the RNG cost (§Perf L3-3).
    pub fn quantize(
        a: &crate::tensor::Matrix,
        scale: &ColumnScale,
        bits: u32,
        k: usize,
        rng: &mut Rng,
    ) -> Self {
        let s = crate::quant::intervals_for_bits(bits);
        let sf = s as f32;
        let nvals = a.rows * a.cols;
        let cols = a.cols;
        let inv_m: Vec<f32> = scale
            .m
            .iter()
            .map(|&mc| if mc > 0.0 { 0.5 * sf / mc } else { 0.0 })
            .collect();
        let mut base_idx = vec![0u16; nvals];
        let mut offsets = vec![0u8; (nvals * k).div_ceil(8)];
        let mid = (s / 2) as u16;
        let mut bit_pos = 0usize;
        let mut vi = 0usize;
        for vrow in a.data.chunks(cols) {
            for (&x, &im) in vrow.iter().zip(&inv_m) {
                let (lo, thr) = if im == 0.0 {
                    (mid, 0u64)
                } else {
                    let t = (x * im + 0.5 * sf).clamp(0.0, sf);
                    let lo = t.floor().min(sf - 1.0);
                    // 24-bit threshold: P[lane < thr] == frac(t) exactly
                    ((lo as u16), ((t - lo) as f64 * (1u64 << 24) as f64) as u64)
                };
                base_idx[vi] = lo;
                vi += 1;
                let mut j = 0usize;
                while j < k {
                    let r = rng.next_u64();
                    let take = (k - j).min(2);
                    for lane in 0..take {
                        let bits24 = (r >> (24 * lane)) & 0xFF_FFFF;
                        if bits24 < thr {
                            offsets[bit_pos / 8] |= 1 << (bit_pos % 8);
                        }
                        bit_pos += 1;
                    }
                    j += take;
                }
            }
        }
        DoubleSampleBlock {
            rows: a.rows,
            cols: a.cols,
            bits,
            s,
            k,
            scale: scale.clone(),
            base: pack_indices(&base_idx, bits),
            offsets,
        }
    }

    #[inline]
    fn offset_bit(&self, value_idx: usize, j: usize) -> u16 {
        let bit = value_idx * self.k + j;
        ((self.offsets[bit / 8] >> (bit % 8)) & 1) as u16
    }

    /// Dequantize sample `j` (0..k) of row `r`.
    pub fn dequantize_row(&self, r: usize, j: usize, out: &mut [f32]) {
        assert!(j < self.k);
        let row_base = r * self.cols;
        let inv_s2 = 2.0 / self.s as f32;
        let m = &self.scale.m;
        unpack_span(&self.base, row_base * self.bits as usize, self.bits, self.cols, |c, lo| {
            let idx = lo + self.offset_bit(row_base + c, j);
            out[c] = (idx as f32 * inv_s2 - 1.0) * m[c];
        });
    }

    /// Raw u8 level indices of sample `j` for row `r` (bits ≤ 8) — the
    /// operands of the `*_ds_u8_step` artifacts (dequantize-in-kernel path).
    pub fn indices_row_u8(&self, r: usize, j: usize, out: &mut [u8]) {
        assert!(self.bits <= 8 && j < self.k);
        let row_base = r * self.cols;
        unpack_span(&self.base, row_base * self.bits as usize, self.bits, self.cols, |c, lo| {
            out[c] = (lo + self.offset_bit(row_base + c, j)) as u8;
        });
    }

    /// Payload bytes actually stored (base + per-sample offset bits).
    pub fn bytes(&self) -> usize {
        self.base.len() + self.offsets.len()
    }

    /// Bits per value on the wire with the symmetric-count encoding:
    /// b + ⌈log₂(k+1)⌉ (§2.2, "sending k samples only requires log₂k more").
    pub fn wire_bits_per_value(bits: u32, k: usize) -> u32 {
        bits + extra_bits_symmetric(k)
    }
}

/// ⌈log₂(k+1)⌉ — bits to transmit the count of "low" choices among k draws.
pub fn extra_bits_symmetric(k: usize) -> u32 {
    (usize::BITS - k.leading_zeros()) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    fn mk(rows: usize, cols: usize, seed: u64) -> (Matrix, ColumnScale) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let a = Matrix::from_vec(rows, cols, data);
        let s = ColumnScale::from_data(&a);
        (a, s)
    }

    #[test]
    fn bitvec_roundtrip_mixed_widths() {
        let mut bv = BitVec::default();
        let vals = [(5u32, 3u32), (0, 1), (1, 1), (255, 8), (1023, 10), (7, 5)];
        for &(v, w) in &vals {
            bv.push(v, w);
        }
        let mut off = 0;
        for &(v, w) in &vals {
            assert_eq!(bv.get(off, w), v);
            off += w as usize;
        }
    }

    #[test]
    fn packed_roundtrip_on_grid() {
        let (a, sc) = mk(16, 10, 1);
        let mut rng = Rng::new(2);
        for bits in [1u32, 2, 3, 4, 5, 8] {
            let p = PackedMatrix::quantize(&a, &sc, bits, &mut rng);
            let mut row = vec![0.0f32; 10];
            for r in 0..16 {
                p.dequantize_row(r, &mut row);
                for (c, &q) in row.iter().enumerate() {
                    // value must be on the grid and within one interval of v
                    let m = sc.m[c];
                    let width = 2.0 * m / p.s as f32;
                    assert!((q - a.get(r, c)).abs() <= width + 1e-5,
                        "bits={bits} q={q} v={}", a.get(r, c));
                }
            }
        }
    }

    #[test]
    fn packed_size_matches_bits() {
        let (a, sc) = mk(32, 100, 3);
        let mut rng = Rng::new(4);
        let p4 = PackedMatrix::quantize(&a, &sc, 4, &mut rng);
        let p8 = PackedMatrix::quantize(&a, &sc, 8, &mut rng);
        assert_eq!(p4.bytes(), 32 * 100 * 4 / 8);
        assert_eq!(p8.bytes(), 32 * 100);
        // the headline saving: 8x fewer bytes than f32 at 4 bits
        assert_eq!(32 * 100 * 4 / p4.bytes(), 8);
    }

    #[test]
    fn u8_indices_match_dequant() {
        let (a, sc) = mk(8, 12, 5);
        let mut rng = Rng::new(6);
        let p = PackedMatrix::quantize(&a, &sc, 4, &mut rng);
        let mut idx = vec![0u8; 12];
        let mut val = vec![0.0f32; 12];
        for r in 0..8 {
            p.indices_row_u8(r, &mut idx);
            p.dequantize_row(r, &mut val);
            for c in 0..12 {
                let deq = crate::quant::stochastic::dequantize_index(idx[c] as u16, sc.m[c], p.s);
                assert!((deq - val[c]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn double_sample_shares_interval() {
        let (a, sc) = mk(8, 6, 7);
        let mut rng = Rng::new(8);
        let ds = DoubleSampleBlock::quantize(&a, &sc, 3, 2, &mut rng);
        let (mut s0, mut s1) = (vec![0.0f32; 6], vec![0.0f32; 6]);
        for r in 0..8 {
            ds.dequantize_row(r, 0, &mut s0);
            ds.dequantize_row(r, 1, &mut s1);
            for c in 0..6 {
                let width = 2.0 * sc.m[c] / ds.s as f32;
                assert!((s0[c] - s1[c]).abs() <= width + 1e-5); // differ ≤ 1 level
            }
        }
    }

    #[test]
    fn double_sample_unbiased() {
        let a = Matrix::from_vec(1, 1, vec![0.37]);
        let sc = ColumnScale { m: vec![1.0] };
        let mut acc = 0.0f64;
        let trials = 30_000;
        let mut rng = Rng::new(9);
        let mut buf = [0.0f32; 1];
        for _ in 0..trials {
            let ds = DoubleSampleBlock::quantize(&a, &sc, 2, 2, &mut rng);
            for j in 0..2 {
                ds.dequantize_row(0, j, &mut buf);
                acc += buf[0] as f64;
            }
        }
        assert!((acc / (2.0 * trials as f64) - 0.37).abs() < 0.01);
    }

    #[test]
    fn wire_bits_accounting() {
        assert_eq!(extra_bits_symmetric(1), 1);
        assert_eq!(extra_bits_symmetric(2), 2); // ⌈log2(3)⌉
        assert_eq!(extra_bits_symmetric(3), 2);
        assert_eq!(extra_bits_symmetric(15), 4);
        assert_eq!(DoubleSampleBlock::wire_bits_per_value(4, 2), 6);
    }

    #[test]
    fn double_sample_storage_smaller_than_two_copies() {
        let (a, sc) = mk(64, 100, 10);
        let mut rng = Rng::new(11);
        let ds = DoubleSampleBlock::quantize(&a, &sc, 4, 2, &mut rng);
        let two_packed = 2 * PackedMatrix::quantize(&a, &sc, 4, &mut rng).bytes();
        assert!(ds.bytes() < two_packed, "{} !< {}", ds.bytes(), two_packed);
    }
}
