//! Scaling functions M(v) (§A.3).
//!
//! * **Column scaling** — samples: M_i = max(|min_i|, |max_i|) per feature,
//!   computed once over the dataset; constant during training, cache-
//!   resident, shared by every sample.
//! * **Row scaling** — gradients/models: M = ‖v‖₂ per vector (dynamic
//!   range changes every step).

use crate::tensor::Matrix;

/// Per-feature symmetric scale for sample quantization.
#[derive(Clone, Debug)]
pub struct ColumnScale {
    /// m[i] = max(|min_i|, |max_i|) ≥ 0.
    pub m: Vec<f32>,
}

impl ColumnScale {
    /// Compute the paper's column scaling over a dataset (K × n).
    pub fn from_data(a: &Matrix) -> Self {
        let (lo, hi) = a.col_min_max();
        let m = lo
            .iter()
            .zip(&hi)
            .map(|(&l, &h)| l.abs().max(h.abs()))
            .collect();
        ColumnScale { m }
    }

    pub fn dim(&self) -> usize {
        self.m.len()
    }

    /// Verify v/M ∈ [-1, 1] for every row of `a`.
    pub fn covers(&self, a: &Matrix) -> bool {
        for r in 0..a.rows {
            for (c, &v) in a.row(r).iter().enumerate() {
                let m = self.m[c];
                if m == 0.0 {
                    if v != 0.0 {
                        return false;
                    }
                } else if v.abs() > m * (1.0 + 1e-6) {
                    return false;
                }
            }
        }
        true
    }
}

/// Row scaling M(v) = ‖v‖₂ (gradients / model vectors).
pub fn row_scale(v: &[f32]) -> f32 {
    crate::tensor::norm2(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_scale_covers_data() {
        let a = Matrix::from_vec(3, 2, vec![1.0, -2.0, 0.5, 4.0, -3.0, 0.0]);
        let s = ColumnScale::from_data(&a);
        assert_eq!(s.m, vec![3.0, 4.0]);
        assert!(s.covers(&a));
    }

    #[test]
    fn zero_column_is_zero_scale() {
        let a = Matrix::from_vec(2, 2, vec![0.0, 1.0, 0.0, -1.0]);
        let s = ColumnScale::from_data(&a);
        assert_eq!(s.m[0], 0.0);
        assert!(s.covers(&a));
    }

    #[test]
    fn covers_detects_violation() {
        let a = Matrix::from_vec(1, 1, vec![1.0]);
        let s = ColumnScale { m: vec![0.5] };
        assert!(!s.covers(&a));
    }

    #[test]
    fn row_scale_is_l2() {
        assert!((row_scale(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}
