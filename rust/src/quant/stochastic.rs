//! The unbiased stochastic quantizer Q(v, s) (§2.1, §A.3 Eq. 10).
//!
//! For value v with scale m, u = clip(v/m, -1, 1) lands in interval
//! [ℓ/s, (ℓ+1)/s) of [-1, 1] (after the affine shift) and is rounded up
//! with probability equal to its relative position — so E[Q(v)] = v as long
//! as |v| ≤ m. Index-space form (`quantize_indices`) is what the bit-packed
//! store holds; value-space form (`quantize_values`) feeds the f32 artifacts.

use crate::rng::Rng;

/// Quantize `v` (row-major, `cols` wide) to level indices in 0..=s.
///
/// `m[c]` is the per-column scale; a zero scale maps to the midpoint index
/// (which dequantizes to 0 when m = 0).
pub fn quantize_indices(v: &[f32], cols: usize, m: &[f32], s: u32, rng: &mut Rng, out: &mut [u16]) {
    debug_assert_eq!(v.len(), out.len());
    debug_assert_eq!(m.len(), cols);
    let sf = s as f32;
    let mid = (s / 2) as u16;
    // Hot path: row-chunked with precomputed reciprocal scales — no modulo,
    // no division in the inner loop (EXPERIMENTS.md §Perf L3-1).
    let inv_m: Vec<f32> = m.iter().map(|&mc| if mc > 0.0 { 0.5 * sf / mc } else { 0.0 }).collect();
    for (vrow, orow) in v.chunks(cols).zip(out.chunks_mut(cols)) {
        for ((&x, o), &im) in vrow.iter().zip(orow.iter_mut()).zip(&inv_m) {
            if im == 0.0 {
                *o = mid;
                continue;
            }
            let t = (x * im + 0.5 * sf).clamp(0.0, sf);
            let lo = t.floor().min(sf - 1.0);
            let idx = lo as u32 + u32::from(rng.f32() < t - lo);
            *o = idx as u16;
        }
    }
}

/// Dequantize one index on the symmetric uniform grid.
#[inline]
pub fn dequantize_index(idx: u16, m: f32, s: u32) -> f32 {
    (idx as f32 / s as f32 * 2.0 - 1.0) * m
}

/// One-shot value-space quantization: out[i] = dequant(quant(v[i])).
pub fn quantize_values(v: &[f32], cols: usize, m: &[f32], s: u32, rng: &mut Rng, out: &mut [f32]) {
    debug_assert_eq!(v.len(), out.len());
    let sf = s as f32;
    let inv_s2 = 2.0 / sf;
    // precompute per-column forward/backward scales (§Perf L3-1)
    let inv_m: Vec<f32> = m.iter().map(|&mc| if mc > 0.0 { 0.5 * sf / mc } else { 0.0 }).collect();
    for (vrow, orow) in v.chunks(cols).zip(out.chunks_mut(cols)) {
        for (c, (&x, o)) in vrow.iter().zip(orow.iter_mut()).enumerate() {
            let im = inv_m[c];
            if im == 0.0 {
                *o = 0.0;
                continue;
            }
            let t = (x * im + 0.5 * sf).clamp(0.0, sf);
            let lo = t.floor().min(sf - 1.0);
            let idx = lo + f32::from(rng.f32() < t - lo);
            *o = (idx * inv_s2 - 1.0) * m[c];
        }
    }
}

/// Row-scaled (M = ‖v‖₂) quantization of a single vector, value space.
pub fn quantize_vector_row_scaled(v: &[f32], s: u32, rng: &mut Rng) -> Vec<f32> {
    let m = crate::tensor::norm2(v);
    let mut out = vec![0.0f32; v.len()];
    let scales = vec![m; 1];
    // row scaling = every "column" shares one scale; reuse the column path
    // with cols = 1 by treating the vector as one long column.
    quantize_values(v, 1, &scales, s, rng, &mut out);
    out
}

/// Stochastic rounding onto an arbitrary sorted level grid (value space).
/// Used for the variance-optimal grids of §3; E[out] = clip(v, grid range).
pub fn quantize_to_levels(v: &[f32], levels: &[f32], rng: &mut Rng, out: &mut [f32]) {
    debug_assert!(levels.len() >= 2);
    for (&x, o) in v.iter().zip(out.iter_mut()) {
        *o = quantize_one_to_levels(x, levels, rng);
    }
}

/// Index-space stochastic rounding onto a sorted grid.
pub fn quantize_to_level_indices(v: &[f32], levels: &[f32], rng: &mut Rng, out: &mut [u16]) {
    for (&x, o) in v.iter().zip(out.iter_mut()) {
        *o = level_index(x, levels, rng);
    }
}

#[inline]
pub fn quantize_one_to_levels(x: f32, levels: &[f32], rng: &mut Rng) -> f32 {
    levels[level_index(x, levels, rng) as usize]
}

/// Public single-value index-space rounding (OptimalDs store build).
#[inline]
pub fn quantize_one_to_level_index(x: f32, levels: &[f32], rng: &mut Rng) -> u16 {
    level_index(x, levels, rng)
}

#[inline]
fn level_index(x: f32, levels: &[f32], rng: &mut Rng) -> u16 {
    let n = levels.len();
    // Route non-finite samples deterministically: NaN ↦ 0.0 (then clamped
    // into the grid like any out-of-range value); ±inf clamp to the grid
    // ends. Without this, OptimalDs ingestion of a single non-finite
    // sample panicked via `partial_cmp().unwrap()`.
    let x = if x.is_nan() { 0.0 } else { x };
    let xc = x.clamp(levels[0], levels[n - 1]);
    // binary search for the bracketing interval (total_cmp: never panics)
    let hi_idx = match levels.binary_search_by(|l| l.total_cmp(&xc)) {
        Ok(i) => return i as u16, // exactly on a level
        Err(i) => i.min(n - 1).max(1),
    };
    let lo = levels[hi_idx - 1];
    let hi = levels[hi_idx];
    let width = hi - lo;
    let p = if width > 0.0 { (xc - lo) / width } else { 0.0 };
    if rng.f32() < p {
        hi_idx as u16
    } else {
        (hi_idx - 1) as u16
    }
}

/// The uniform level grid over [-m, m] with s intervals, as explicit points.
pub fn uniform_levels(m: f32, s: u32) -> Vec<f32> {
    (0..=s).map(|i| (i as f32 / s as f32 * 2.0 - 1.0) * m).collect()
}

/// Empirical quantization variance TV(v) = E‖Q(v) − v‖² (Lemma 1 quantity),
/// estimated over `trials` draws. Test/diagnostic helper.
pub fn empirical_tv(
    v: &[f32],
    cols: usize,
    m: &[f32],
    s: u32,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let mut buf = vec![0.0f32; v.len()];
    let mut acc = 0.0f64;
    for _ in 0..trials {
        quantize_values(v, cols, m, s, rng, &mut buf);
        let mut e = 0.0f64;
        for (&q, &x) in buf.iter().zip(v) {
            e += ((q - x) as f64).powi(2);
        }
        acc += e;
    }
    acc / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_lands_on_grid() {
        let mut rng = Rng::new(1);
        let v = [0.3f32, -0.7, 0.99, -1.0, 0.0];
        let m = [1.0f32];
        let mut out = [0.0f32; 5];
        quantize_values(&v, 1, &m, 4, &mut rng, &mut out);
        let grid = uniform_levels(1.0, 4);
        for &q in &out {
            assert!(grid.iter().any(|&g| (g - q).abs() < 1e-6), "{q} not on grid");
        }
    }

    #[test]
    fn unbiased_statistically() {
        let mut rng = Rng::new(2);
        let v = [0.37f32, -0.61, 0.05];
        let m = [1.0f32];
        let trials = 60_000;
        let mut acc = [0.0f64; 3];
        let mut out = [0.0f32; 3];
        for _ in 0..trials {
            quantize_values(&v, 1, &m, 3, &mut rng, &mut out);
            for (a, &q) in acc.iter_mut().zip(&out) {
                *a += q as f64;
            }
        }
        for (a, &x) in acc.iter().zip(&v) {
            let mean = *a / trials as f64;
            assert!((mean - x as f64).abs() < 0.005, "mean {mean} vs {x}");
        }
    }

    #[test]
    fn indices_and_values_agree() {
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let v: Vec<f32> = (0..64).map(|i| (i as f32 / 32.0) - 1.0).collect();
        let m = vec![1.0f32; 8];
        let mut idx = vec![0u16; 64];
        let mut val = vec![0.0f32; 64];
        quantize_indices(&v, 8, &m, 15, &mut r1, &mut idx);
        quantize_values(&v, 8, &m, 15, &mut r2, &mut val);
        for (i, (&ix, &vv)) in idx.iter().zip(&val).enumerate() {
            assert!((dequantize_index(ix, m[i % 8], 15) - vv).abs() < 1e-6);
        }
    }

    #[test]
    fn level_grid_rounding_unbiased() {
        let mut rng = Rng::new(4);
        let levels = [-1.0f32, -0.2, 0.1, 0.9];
        let x = 0.4f32; // between 0.1 and 0.9
        let trials = 60_000;
        let mut acc = 0.0f64;
        for _ in 0..trials {
            acc += quantize_one_to_levels(x, &levels, &mut rng) as f64;
        }
        assert!((acc / trials as f64 - 0.4).abs() < 0.01);
    }

    #[test]
    fn clamps_out_of_range() {
        let mut rng = Rng::new(5);
        let levels = [0.0f32, 1.0];
        assert_eq!(quantize_one_to_levels(5.0, &levels, &mut rng), 1.0);
        assert_eq!(quantize_one_to_levels(-5.0, &levels, &mut rng), 0.0);
    }

    #[test]
    fn tv_decreases_with_levels() {
        // Lemma 2: TV ∝ 1/s² — quadrupling s should cut TV ~16x.
        let mut rng = Rng::new(6);
        let v: Vec<f32> = (0..256).map(|_| rng.normal().clamp(-1.0, 1.0)).collect();
        let m = vec![1.0f32];
        let tv1 = empirical_tv(&v, 1, &m, 3, 300, &mut rng);
        let tv2 = empirical_tv(&v, 1, &m, 12, 300, &mut rng);
        let ratio = tv1 / tv2;
        assert!(ratio > 8.0 && ratio < 32.0, "ratio {ratio}");
    }

    /// Non-finite samples route deterministically instead of panicking
    /// (the OptimalDs-ingestion crash): ±inf clamp to the grid ends, NaN
    /// behaves like 0.0 and stays inside its bracketing interval.
    #[test]
    fn non_finite_samples_route_deterministically() {
        let mut rng = Rng::new(8);
        let levels = [-1.0f32, -0.25, 0.5, 2.0];
        assert_eq!(quantize_one_to_level_index(f32::INFINITY, &levels, &mut rng), 3);
        assert_eq!(quantize_one_to_level_index(f32::NEG_INFINITY, &levels, &mut rng), 0);
        for _ in 0..100 {
            // NaN ↦ 0.0 ∈ (-0.25, 0.5): stochastic between indices 1 and 2
            let i = quantize_one_to_level_index(f32::NAN, &levels, &mut rng);
            assert!(i == 1 || i == 2, "NaN routed to index {i}");
        }
        // value-space path lands on a real grid level, never NaN
        let q = quantize_one_to_levels(f32::NAN, &levels, &mut rng);
        assert!(q == -0.25 || q == 0.5, "NaN dequantized to {q}");
        // grid containing 0.0 exactly: NaN maps to it deterministically
        let levels0 = [-1.0f32, 0.0, 1.0];
        assert_eq!(quantize_one_to_level_index(f32::NAN, &levels0, &mut rng), 1);
    }

    #[test]
    fn zero_scale_maps_to_zero() {
        let mut rng = Rng::new(7);
        let v = [0.0f32, 0.0];
        let m = [0.0f32, 0.0];
        let mut out = [9.0f32; 2];
        quantize_values(&v, 2, &m, 7, &mut rng, &mut out);
        assert_eq!(out, [0.0, 0.0]);
    }
}
