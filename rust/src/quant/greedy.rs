//! ADAQUANT (supplementary §I, Algorithm 1): a near-linear-time greedy
//! merge producing ≤ 2(1+γ)k + δ intervals whose total quantization error
//! is at most (1 + 1/γ)·OPT_k (Theorem 9). Its endpoints then serve as DP
//! candidates for a true k-level 2-approximation in O(N log N + k³).

use super::optimal::quantization_variance;

/// One contiguous run of sorted points, quantized to its own endpoints.
#[derive(Clone, Copy, Debug)]
struct Interval {
    /// start index into the sorted point array (inclusive)
    i0: usize,
    /// end index (inclusive)
    i1: usize,
}

/// err(Ω, I) with I spanning sorted points [i0, i1]: endpoints at the
/// extreme points of the run.
fn run_err(s1: &[f64], s2: &[f64], xs: &[f64], iv: Interval) -> f64 {
    let (a, b) = (xs[iv.i0], xs[iv.i1]);
    let cnt = (iv.i1 - iv.i0 + 1) as f64;
    let p1 = s1[iv.i1 + 1] - s1[iv.i0];
    let p2 = s2[iv.i1 + 1] - s2[iv.i0];
    ((a + b) * p1 - p2 - a * b * cnt).max(0.0)
}

/// Run ADAQUANT: returns the *endpoints* (candidate levels) of the final
/// partition, sorted ascending. `gamma` trades approximation for output
/// size; `delta` is the loop slack (Algorithm 1's 2(1+γ)k + δ bound).
pub fn adaquant(points: &[f32], k: usize, gamma: f64, delta: usize) -> Vec<f32> {
    assert!(k >= 1);
    let mut xs: Vec<f64> = points.iter().map(|&x| x as f64).collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs.dedup();
    let n = xs.len();
    if n <= 2 * k + 2 {
        return xs.iter().map(|&x| x as f32).collect();
    }
    let mut s1 = vec![0.0f64];
    let mut s2 = vec![0.0f64];
    for &x in &xs {
        s1.push(s1.last().unwrap() + x);
        s2.push(s2.last().unwrap() + x * x);
    }

    let keep = ((1.0 + gamma) * k as f64).ceil() as usize;
    let target = 2 * keep + delta;
    let mut ivs: Vec<Interval> = (0..n).map(|i| Interval { i0: i, i1: i }).collect();

    while ivs.len() > target {
        // Pair up consecutive intervals; the `keep` merged pairs with the
        // largest error get split back (kept un-merged), the rest merge.
        let mut merged: Vec<(f64, usize)> = Vec::with_capacity(ivs.len() / 2);
        for pi in 0..ivs.len() / 2 {
            let a = ivs[2 * pi];
            let b = ivs[2 * pi + 1];
            let m = Interval { i0: a.i0, i1: b.i1 };
            merged.push((run_err(&s1, &s2, &xs, m), pi));
        }
        // indices of pairs to keep split (largest error)
        let mut order: Vec<usize> = (0..merged.len()).collect();
        order.sort_by(|&a, &b| merged[b].0.partial_cmp(&merged[a].0).unwrap());
        let mut split = vec![false; merged.len()];
        for &pi in order.iter().take(keep) {
            split[pi] = true;
        }
        let mut next: Vec<Interval> = Vec::with_capacity(keep * 2 + merged.len());
        for pi in 0..merged.len() {
            if split[pi] {
                next.push(ivs[2 * pi]);
                next.push(ivs[2 * pi + 1]);
            } else {
                next.push(Interval { i0: ivs[2 * pi].i0, i1: ivs[2 * pi + 1].i1 });
            }
        }
        if ivs.len() % 2 == 1 {
            next.push(*ivs.last().unwrap());
        }
        if next.len() >= ivs.len() {
            break; // cannot shrink further (all pairs kept)
        }
        ivs = next;
    }

    // endpoints of the partition = candidate quantization levels
    let mut endpoints: Vec<f64> = Vec::with_capacity(ivs.len() + 1);
    for iv in &ivs {
        endpoints.push(xs[iv.i0]);
        endpoints.push(xs[iv.i1]);
    }
    endpoints.sort_by(|a, b| a.partial_cmp(b).unwrap());
    endpoints.dedup();
    endpoints.iter().map(|&x| x as f32).collect()
}

/// Full pipeline: ADAQUANT candidates → DP restricted to them → k levels.
/// O(N log N + k³)-style 2-approximation (§3.2 "2-Approximation in
/// Almost-Linear Time").
pub fn adaquant_levels(points: &[f32], nlevels: usize) -> Vec<f32> {
    let cands = adaquant(points, nlevels, 1.0, 2);
    if cands.len() <= nlevels {
        let mut lv = cands;
        while lv.len() < nlevels {
            lv.push(*lv.last().unwrap_or(&0.0));
        }
        return lv;
    }
    // Reuse the DP over the candidate set: emulate by calling the
    // discretized DP with candidates = exact candidate values. The optimal
    // module's DP wants a uniform grid, so we run its internal path by
    // passing candidates through `optimal_levels` on a weighted proxy:
    // simplest correct approach — DP over candidate values directly.
    super::optimal::dp_on_candidates_public(points, &cands, nlevels)
}

/// Theorem-9-style quality check helper: total err of partitioning `points`
/// onto the ADAQUANT endpoint grid.
pub fn adaquant_quality(points: &[f32], k: usize, gamma: f64) -> (usize, f64) {
    let cands = adaquant(points, k, gamma, 2);
    (cands.len(), quantization_variance(points, &cands))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::optimal::{optimal_levels, quantization_variance};
    use crate::rng::Rng;

    #[test]
    fn output_size_bounded() {
        let mut rng = Rng::new(1);
        let pts: Vec<f32> = (0..5000).map(|_| rng.f32()).collect();
        for k in [2usize, 4, 8] {
            let cands = adaquant(&pts, k, 1.0, 2);
            // ≤ 2(1+γ)k + δ intervals, each contributing ≤ 2 endpoints
            let bound = 2 * (2 * (2 * k) + 2);
            assert!(cands.len() <= bound, "k={k}: {} > {}", cands.len(), bound);
            assert!(cands.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn candidates_cover_range() {
        let mut rng = Rng::new(2);
        let pts: Vec<f32> = (0..1000).map(|_| rng.normal()).collect();
        let cands = adaquant(&pts, 4, 1.0, 2);
        let lo = pts.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = pts.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!((cands[0] - lo).abs() < 1e-6);
        assert!((cands.last().unwrap() - hi).abs() < 1e-6);
    }

    #[test]
    fn approximation_vs_exact_dp() {
        // the (1 + 1/γ) guarantee with γ=1 ⇒ ≤ 2·OPT on the 4k-interval
        // output; after the DP restriction we stay within a modest factor.
        let mut rng = Rng::new(3);
        let pts: Vec<f32> = (0..800)
            .map(|_| if rng.f32() < 0.7 { rng.normal() * 0.1 } else { rng.normal() + 3.0 })
            .collect();
        for k in [4usize, 8] {
            let exact = quantization_variance(&pts, &optimal_levels(&pts, k));
            let greedy = quantization_variance(&pts, &adaquant_levels(&pts, k));
            assert!(greedy <= 2.0 * exact + 1e-9, "k={k} greedy {greedy} exact {exact}");
        }
    }

    #[test]
    fn tiny_input_passthrough() {
        let pts = [0.1f32, 0.5, 0.9];
        let cands = adaquant(&pts, 4, 1.0, 2);
        assert_eq!(cands, vec![0.1, 0.5, 0.9]);
    }
}
