//! Variance-optimal quantization points (§3, supplementary §H).
//!
//! Given points Ω = {x₁ ≤ … ≤ x_N} and a budget of L levels, choose levels
//! minimizing MV = (1/N) Σᵢ err(xᵢ, Iᵢ) with err(x, [a,b]) = (b−x)(x−a),
//! the variance of the unique two-point distribution on {a, b} with mean x.
//!
//! * [`optimal_levels`] — the exact O(L·N²) dynamic program (Lemma 3: some
//!   optimum places levels at input points, so the search is combinatorial).
//! * [`discretized_optimal_levels`] — the §3.2 heuristic: one O(N) pass
//!   builds prefix statistics at M grid candidates, then the same DP runs
//!   over candidates in O(L·M²) (Theorem 2 bounds the excess by
//!   a²bk/4M³ + a²bc²/Mk).
//! * [`quantization_variance`] — evaluate MV(levels) on a point set.

/// Prefix statistics enabling O(1) interval-variance queries.
///
/// err(Ω, [a,b]) = Σ_{x∈(a,b)} (a+b)x − x² − ab
///              = (a+b)·S1 − S2 − ab·cnt over the in-range points.
struct Prefix {
    /// sorted points
    xs: Vec<f64>,
    s1: Vec<f64>,
    s2: Vec<f64>,
}

impl Prefix {
    fn new(points: &[f32]) -> Self {
        let mut xs: Vec<f64> = points.iter().map(|&x| x as f64).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut s1 = Vec::with_capacity(xs.len() + 1);
        let mut s2 = Vec::with_capacity(xs.len() + 1);
        s1.push(0.0);
        s2.push(0.0);
        for &x in &xs {
            s1.push(s1.last().unwrap() + x);
            s2.push(s2.last().unwrap() + x * x);
        }
        Prefix { xs, s1, s2 }
    }

    /// Total variance of points with index in [i, j] quantized to [a, b].
    #[inline]
    fn err_range(&self, i: usize, j: usize, a: f64, b: f64) -> f64 {
        if i > j {
            return 0.0;
        }
        let cnt = (j - i + 1) as f64;
        let s1 = self.s1[j + 1] - self.s1[i];
        let s2 = self.s2[j + 1] - self.s2[i];
        ((a + b) * s1 - s2 - a * b * cnt).max(0.0)
    }

    /// First index with xs[idx] >= v.
    #[inline]
    fn lower_bound(&self, v: f64) -> usize {
        self.xs.partition_point(|&x| x < v)
    }
}

/// Exact variance-optimal levels via the §3.1 dynamic program.
///
/// Returns `levels.len() == nlevels` sorted ascending, with the first/last
/// at the data min/max (required for the quantizer to cover the range).
/// Complexity O(nlevels · N²) time, O(nlevels · N) memory (V is computed
/// on the fly from prefix sums instead of materializing the N² matrix).
pub fn optimal_levels(points: &[f32], nlevels: usize) -> Vec<f32> {
    assert!(nlevels >= 2, "need at least 2 levels");
    let p = Prefix::new(points);
    let xs = &p.xs;
    let n = xs.len();
    if n == 0 {
        return vec![0.0; nlevels];
    }
    // Collapse duplicates: DP over distinct values, weighted ranges handled
    // by prefix sums over the full multiset.
    let mut uniq: Vec<f64> = Vec::with_capacity(n);
    for &x in xs.iter() {
        if uniq.last().map_or(true, |&u| x > u) {
            uniq.push(x);
        }
    }
    let u = uniq.len();
    if u <= nlevels {
        // Every distinct value gets its own level: zero variance.
        let mut levels: Vec<f32> = uniq.iter().map(|&x| x as f32).collect();
        while levels.len() < nlevels {
            levels.push(*levels.last().unwrap());
        }
        return levels;
    }
    dp_over_candidates(&p, &uniq, nlevels)
}

/// §3.2 heuristic: restrict candidates to an M-point uniform grid over the
/// data range (plus min/max), computable with a single pass over the data.
pub fn discretized_optimal_levels(points: &[f32], nlevels: usize, m_candidates: usize) -> Vec<f32> {
    assert!(nlevels >= 2);
    assert!(m_candidates >= nlevels);
    let p = Prefix::new(points);
    if p.xs.is_empty() {
        return vec![0.0; nlevels];
    }
    let lo = p.xs[0];
    let hi = *p.xs.last().unwrap();
    if hi <= lo {
        return vec![lo as f32; nlevels];
    }
    let mut cands: Vec<f64> = (0..=m_candidates)
        .map(|i| lo + (hi - lo) * i as f64 / m_candidates as f64)
        .collect();
    cands.dedup();
    dp_over_candidates(&p, &cands, nlevels)
}

/// Shared DP: choose `nlevels` of `cands` (first and last forced) to
/// minimize total variance of `p`'s points.
fn dp_over_candidates(p: &Prefix, cands: &[f64], nlevels: usize) -> Vec<f32> {
    let m = cands.len();
    if m <= nlevels {
        let mut levels: Vec<f32> = cands.iter().map(|&x| x as f32).collect();
        while levels.len() < nlevels {
            levels.push(*levels.last().unwrap());
        }
        return levels;
    }
    // idx[c] = first point index ≥ cands[c]
    let idx: Vec<usize> = cands.iter().map(|&c| p.lower_bound(c)).collect();
    let inf = f64::INFINITY;
    // cost[j][c]: min variance covering points ≤ cands[c] using j+1 levels,
    // last level at cands[c].
    let mut prev = vec![inf; m];
    let mut parent = vec![vec![usize::MAX; m]; nlevels];
    prev[0] = 0.0; // one level at cands[0] (= data min): no interval yet
    for j in 1..nlevels {
        let mut cur = vec![inf; m];
        // last level of a j+1-level solution can sit anywhere after j
        for c in j..m {
            let b = cands[c];
            let hi_pt = if c + 1 == m { p.xs.len() } else { idx[c + 1].max(idx[c]) };
            let _ = hi_pt;
            let mut best = inf;
            let mut best_prev = usize::MAX;
            for pc in (j - 1)..c {
                if prev[pc] == inf {
                    continue;
                }
                let a = cands[pc];
                // points in (a, b): indices [idx[pc], idx[c]) — points equal
                // to an endpoint contribute zero error either way.
                let i0 = idx[pc];
                let i1 = idx[c];
                let v = p.err_range(i0, i1.saturating_sub(1).min(p.xs.len().saturating_sub(1)), a, b);
                let v = if i0 >= i1 { 0.0 } else { v };
                let tot = prev[pc] + v;
                if tot < best {
                    best = tot;
                    best_prev = pc;
                }
            }
            cur[c] = best;
            parent[j][c] = best_prev;
        }
        prev = cur;
    }
    // The last level must cover the max point: force it at cands[m-1].
    let mut levels_idx = Vec::with_capacity(nlevels);
    let mut c = m - 1;
    levels_idx.push(c);
    for j in (1..nlevels).rev() {
        c = parent[j][c];
        debug_assert!(c != usize::MAX);
        levels_idx.push(c);
    }
    levels_idx.reverse();
    levels_idx.iter().map(|&i| cands[i] as f32).collect()
}

/// DP restricted to an arbitrary sorted candidate set (ADAQUANT pipeline).
/// The data min/max are appended to the candidates so the grid covers Ω.
pub fn dp_on_candidates_public(points: &[f32], candidates: &[f32], nlevels: usize) -> Vec<f32> {
    let p = Prefix::new(points);
    if p.xs.is_empty() {
        return vec![0.0; nlevels];
    }
    let mut cands: Vec<f64> = candidates.iter().map(|&x| x as f64).collect();
    cands.push(p.xs[0]);
    cands.push(*p.xs.last().unwrap());
    cands.sort_by(|a, b| a.partial_cmp(b).unwrap());
    cands.dedup();
    // Clip candidates outside the data range (useless levels).
    cands.retain(|&c| c >= p.xs[0] && c <= *p.xs.last().unwrap());
    dp_over_candidates(&p, &cands, nlevels)
}

/// Mean variance MV(levels) of stochastically quantizing `points` onto the
/// grid — the §3 objective, also used to compare uniform vs optimal (Fig 7).
pub fn quantization_variance(points: &[f32], levels: &[f32]) -> f64 {
    assert!(levels.len() >= 2);
    let mut lv: Vec<f64> = levels.iter().map(|&x| x as f64).collect();
    lv.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut total = 0.0f64;
    for &xf in points {
        let x = (xf as f64).clamp(lv[0], *lv.last().unwrap());
        let hi = lv.partition_point(|&l| l < x).min(lv.len() - 1).max(1);
        let (a, b) = (lv[hi - 1], lv[hi]);
        total += ((b - x) * (x - a)).max(0.0);
    }
    total / points.len() as f64
}

/// Brute-force optimum for tiny inputs — test oracle only.
pub fn brute_force_optimal(points: &[f32], nlevels: usize) -> (Vec<f32>, f64) {
    let mut xs: Vec<f32> = points.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs.dedup();
    let n = xs.len();
    assert!(n >= 2 && nlevels >= 2 && n <= 18, "oracle limits");
    let mut best = (Vec::new(), f64::INFINITY);
    // choose nlevels−2 interior levels among xs[1..n−1]
    let interior: Vec<usize> = (1..n - 1).collect();
    let mut combo = vec![0usize; nlevels.saturating_sub(2)];
    fn rec(
        interior: &[usize],
        combo: &mut Vec<usize>,
        pos: usize,
        start: usize,
        xs: &[f32],
        points: &[f32],
        best: &mut (Vec<f32>, f64),
    ) {
        if pos == combo.len() {
            let mut levels = vec![xs[0]];
            levels.extend(combo.iter().map(|&i| xs[i]));
            levels.push(*xs.last().unwrap());
            let mv = quantization_variance(points, &levels);
            if mv < best.1 {
                *best = (levels, mv);
            }
            return;
        }
        for i in start..interior.len() {
            combo[pos] = interior[i];
            rec(interior, combo, pos + 1, i + 1, xs, points, best);
        }
    }
    if nlevels - 2 > interior.len() {
        let mv = quantization_variance(points, &xs);
        return (xs, mv);
    }
    rec(&interior, &mut combo, 0, 0, &xs, points, &mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn exact_matches_brute_force_small() {
        let mut rng = Rng::new(1);
        for trial in 0..20 {
            let n = 6 + (trial % 8);
            let pts: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            for nlevels in 2..=4usize {
                let dp = optimal_levels(&pts, nlevels);
                let (_, bf_mv) = brute_force_optimal(&pts, nlevels);
                let dp_mv = quantization_variance(&pts, &dp);
                assert!(
                    dp_mv <= bf_mv + 1e-9,
                    "trial {trial} L={nlevels}: dp {dp_mv} > brute {bf_mv}"
                );
            }
        }
    }

    #[test]
    fn optimal_beats_uniform_on_skewed_data() {
        // Fig 3/7 story: clustered data → optimal ≪ uniform at equal levels.
        let mut rng = Rng::new(2);
        let mut pts: Vec<f32> = (0..500).map(|_| rng.normal() * 0.05 + 0.1).collect();
        pts.extend((0..20).map(|_| 0.9 + rng.f32() * 0.1));
        let pts: Vec<f32> = pts.iter().map(|&x| x.clamp(0.0, 1.0)).collect();
        let nlevels = 8;
        let lo = pts.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = pts.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let uniform: Vec<f32> = (0..nlevels)
            .map(|i| lo + (hi - lo) * i as f32 / (nlevels - 1) as f32)
            .collect();
        let opt = optimal_levels(&pts, nlevels);
        let mv_u = quantization_variance(&pts, &uniform);
        let mv_o = quantization_variance(&pts, &opt);
        assert!(mv_o < 0.5 * mv_u, "optimal {mv_o} vs uniform {mv_u}");
    }

    #[test]
    fn discretized_converges_to_exact() {
        let mut rng = Rng::new(3);
        let pts: Vec<f32> = (0..400).map(|_| rng.f32().powi(2)).collect();
        let exact = quantization_variance(&pts, &optimal_levels(&pts, 6));
        let coarse = quantization_variance(&pts, &discretized_optimal_levels(&pts, 6, 16));
        let fine = quantization_variance(&pts, &discretized_optimal_levels(&pts, 6, 256));
        assert!(fine <= coarse + 1e-12);
        assert!(fine <= exact * 1.25 + 1e-9, "fine {fine} exact {exact}");
    }

    #[test]
    fn levels_cover_range_and_sorted() {
        let mut rng = Rng::new(4);
        let pts: Vec<f32> = (0..200).map(|_| rng.normal()).collect();
        let lv = optimal_levels(&pts, 5);
        assert_eq!(lv.len(), 5);
        let lo = pts.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = pts.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!((lv[0] - lo).abs() < 1e-5);
        assert!((lv[4] - hi).abs() < 1e-5);
        assert!(lv.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn few_distinct_points_zero_variance() {
        let pts = vec![0.25f32; 50].into_iter().chain(vec![0.75f32; 50]).collect::<Vec<_>>();
        let lv = optimal_levels(&pts, 4);
        assert!(quantization_variance(&pts, &lv) < 1e-12);
    }

    #[test]
    fn variance_is_zero_on_levels() {
        let levels = [0.0f32, 0.5, 1.0];
        assert_eq!(quantization_variance(&[0.0, 0.5, 1.0], &levels), 0.0);
        let mv = quantization_variance(&[0.25], &levels);
        assert!((mv - 0.0625).abs() < 1e-9); // (0.5-0.25)(0.25-0)
    }
}
