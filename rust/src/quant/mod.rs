//! The ZipML numeric-format library: stochastic quantization, scaling,
//! bit-packed storage, and variance-optimal level placement.
//!
//! Layout:
//! * [`scaling`]    — row / column scaling functions M(v) (§A.3)
//! * [`stochastic`] — unbiased stochastic quantizer Q(v, s) (§2.1)
//! * [`packing`]    — bit-packed sample store + the log₂k double-sample
//!   encoding (§2.2 "Overhead of Storing Samples")
//! * [`optimal`]    — exact & discretized dynamic programs for variance-
//!   optimal quantization points (§3.1–3.2)
//! * [`greedy`]     — ADAQUANT, the near-linear 2-approximation (§I)
//! * [`jl`]         — low-randomness ±1 Johnson-Lindenstrauss sketches used
//!   by ℓ2-refetching (§G.3)

pub mod greedy;
pub mod jl;
pub mod optimal;
pub mod packing;
pub mod scaling;
pub mod stochastic;

pub use greedy::adaquant;
pub use optimal::{discretized_optimal_levels, optimal_levels, quantization_variance};
pub use packing::{DoubleSampleBlock, PackedMatrix};
pub use scaling::ColumnScale;
pub use stochastic::{dequantize_index, quantize_indices, quantize_values, uniform_levels};

/// How quantization levels are placed within the scaled range.
#[derive(Clone, Debug, PartialEq)]
pub enum LevelPlacement {
    /// `s` uniform intervals over [-1, 1] (scaled) — the baseline every
    /// low-precision system uses for >1 bit (§3.3 "State-of-the-art").
    Uniform { intervals: u32 },
    /// Explicit level grid (variance-optimal DP / ADAQUANT output),
    /// in *absolute* (unscaled) coordinates.
    Explicit(Vec<f32>),
}

impl LevelPlacement {
    /// Number of distinct representable points (drives bits-per-value).
    pub fn num_levels(&self) -> usize {
        match self {
            LevelPlacement::Uniform { intervals } => *intervals as usize + 1,
            LevelPlacement::Explicit(l) => l.len(),
        }
    }

    /// Bits needed to index a level.
    pub fn bits(&self) -> u32 {
        let n = self.num_levels().max(2);
        (usize::BITS - (n - 1).leading_zeros()) as u32
    }
}

/// Bits → number of uniform intervals s = 2^b − 1 (so all codes are used).
pub fn intervals_for_bits(bits: u32) -> u32 {
    (1u32 << bits) - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_roundtrip() {
        assert_eq!(intervals_for_bits(1), 1);
        assert_eq!(intervals_for_bits(4), 15);
        assert_eq!(intervals_for_bits(8), 255);
        assert_eq!(LevelPlacement::Uniform { intervals: 15 }.bits(), 4);
        assert_eq!(LevelPlacement::Uniform { intervals: 255 }.bits(), 8);
        assert_eq!(LevelPlacement::Explicit(vec![0.0, 0.5, 1.0]).bits(), 2);
    }

    #[test]
    fn num_levels() {
        assert_eq!(LevelPlacement::Uniform { intervals: 3 }.num_levels(), 4);
        assert_eq!(LevelPlacement::Explicit(vec![0.1, 0.9]).num_levels(), 2);
    }
}
