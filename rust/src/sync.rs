//! Concurrency façade: every atomic the crate uses comes from here, so
//! the whole memory-model surface can be swapped for [loom]'s
//! permutation-checked shims with `RUSTFLAGS="--cfg loom"` (DESIGN.md
//! §11). Three real protocols ride on these primitives, and each has a
//! loom model in `rust/tests/loom_models.rs`:
//!
//! 1. [`crate::telemetry::ShardedU64`] — relaxed striped counters
//!    (record / sum / reset);
//! 2. the per-shard byte cells behind
//!    [`crate::store::ShardedStore::bytes_read`] — exact-once relaxed
//!    accounting adds vs. concurrent relaxed sum snapshots;
//! 3. the Hogwild! publish: [`RacyF32Cell`], the one *deliberately*
//!    racy primitive in the repo.
//!
//! Everything here is `Relaxed`-only by design: no protocol in this
//! crate relies on a happens-before edge from an atomic — quiescence
//! always comes from `thread::scope` joins. zipml-lint's
//! `ordering-contract` rule enforces that every `Ordering::*` use in
//! the tree carries an `// ordering:` contract comment.
//!
//! [loom]: https://docs.rs/loom

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicU32, AtomicU64, Ordering};
#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// A deliberately racy shared `f32`: the Hogwild! model-publish
/// primitive (De Sa et al., 2015 — unsynchronized SGD updates still
/// converge).
///
/// Contract (the *hogwild publish contract*, DESIGN.md §11):
///
/// * **Lossy by design.** [`RacyF32Cell::add`] is a relaxed load
///   followed by a relaxed store — NOT a CAS loop. Two racing adds may
///   lose one delta; Hogwild!'s convergence argument absorbs that.
/// * **Never torn.** The payload is a single `AtomicU32` holding the
///   f32's bits, so every load observes some value that was actually
///   stored — mixed-bit-pattern reads are impossible. This is the
///   property the loom model checks exhaustively.
/// * **No ordering.** All accesses are `Relaxed`; readers take racy
///   snapshots and that is fine — the epoch loss is evaluated only
///   after a `thread::scope` join, where every store is visible.
///
/// Keeping the race inside one named type means the ThreadSanitizer
/// suppression (`rust/tsan.supp`) and zipml-lint both reference
/// `RacyF32Cell`, not a blanket file or module.
#[derive(Debug)]
pub struct RacyF32Cell(AtomicU32);

impl RacyF32Cell {
    pub fn new(v: f32) -> Self {
        RacyF32Cell(AtomicU32::new(v.to_bits()))
    }

    /// Racy snapshot of the current value.
    #[inline]
    pub fn load(&self) -> f32 {
        // ordering: relaxed — racy snapshot per the hogwild publish
        // contract; joins, not atomics, provide quiescence
        f32::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Overwrite the value (used from quiescent points only).
    #[inline]
    pub fn store(&self, v: f32) {
        // ordering: relaxed — single-writer or quiescent call sites
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Racy read-modify-write add — deliberately NOT a CAS loop:
    /// Hogwild!'s whole point is that unsynchronized (lossy) updates
    /// still converge. Concurrent adds may drop a delta but can never
    /// produce a torn bit pattern.
    #[inline]
    pub fn add(&self, delta: f32) {
        // ordering: relaxed — lossy-by-design publish (see type docs);
        // the loom model pins "lossy but never torn"
        let cur = f32::from_bits(self.0.load(Ordering::Relaxed));
        self.0.store((cur + delta).to_bits(), Ordering::Relaxed);
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn racy_cell_round_trips_values() {
        let c = RacyF32Cell::new(1.5);
        assert_eq!(c.load(), 1.5);
        c.add(0.25);
        assert_eq!(c.load(), 1.75);
        c.store(-0.0);
        assert_eq!(c.load().to_bits(), (-0.0f32).to_bits(), "bit-exact store");
    }

    #[test]
    fn sequential_adds_are_exact() {
        // single-threaded, the racy add IS a plain add: bit-for-bit the
        // f32 sum in call order (the hogwild threads=1 determinism story)
        let c = RacyF32Cell::new(0.0);
        let mut want = 0.0f32;
        for i in 0..100 {
            let d = (i as f32) * 0.125 - 3.0;
            c.add(d);
            want += d;
        }
        assert_eq!(c.load().to_bits(), want.to_bits());
    }

    #[test]
    fn concurrent_adds_never_tear() {
        // non-exhaustive sibling of the loom model: every observed value
        // must be a genuine f32 sum of a subset of published deltas — with
        // deltas 1.0 and 2.0 from zero, the reachable set is tiny
        let c = std::sync::Arc::new(RacyF32Cell::new(0.0));
        std::thread::scope(|s| {
            let c1 = &c;
            s.spawn(move || c1.add(1.0));
            let c2 = &c;
            s.spawn(move || c2.add(2.0));
        });
        let got = c.load();
        assert!(got == 1.0 || got == 2.0 || got == 3.0, "torn or impossible value {got}");
    }
}
