//! In-repo micro-benchmark harness (criterion is not in the offline crate
//! set). Used by every target under `rust/benches/` with `harness = false`.
//!
//! Methodology: warmup until ≥ `WARMUP` elapsed, then time batches sized so
//! each batch takes ≳ 10 ms, collect ≥ `MIN_SAMPLES` batch means, report
//! mean / median / p95 / stddev. `--quick` (or env `ZIPML_BENCH_QUICK=1`)
//! shrinks budgets ~10× for CI smoke runs.

use std::time::{Duration, Instant};

pub struct BenchOpts {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    /// True when running with shrunken smoke budgets (`--quick` /
    /// `ZIPML_BENCH_QUICK=1`) — benches gate their perf-ratio acceptance
    /// asserts on this so noisy CI smoke runs warn instead of failing.
    pub quick: bool,
}

impl BenchOpts {
    pub fn from_env_and_args() -> Self {
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("ZIPML_BENCH_QUICK").is_ok_and(|v| v == "1");
        if quick {
            BenchOpts {
                warmup: Duration::from_millis(30),
                measure: Duration::from_millis(200),
                min_samples: 5,
                quick,
            }
        } else {
            BenchOpts {
                warmup: Duration::from_millis(300),
                measure: Duration::from_secs(2),
                min_samples: 20,
                quick,
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
    pub samples: usize,
}

impl BenchResult {
    pub fn throughput_line(&self, unit: &str, per_iter: f64) -> String {
        let per_sec = per_iter / (self.mean_ns * 1e-9);
        format!("{:44} {:>12} mean  {:>12} p95   {:>14.3e} {unit}/s",
            self.name, fmt_ns(self.mean_ns), fmt_ns(self.p95_ns), per_sec)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` per the harness methodology; prints and returns the result.
// The measurement loop is a sanctioned wall-clock consumer (like
// telemetry::Stopwatch): bench.rs is outside the determinism contract.
#[allow(clippy::disallowed_methods)]
pub fn bench<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> BenchResult {
    // Warmup + estimate per-call cost.
    let wstart = Instant::now();
    let mut calls = 0u64;
    while wstart.elapsed() < opts.warmup || calls < 3 {
        f();
        calls += 1;
    }
    let per_call = wstart.elapsed().as_secs_f64() / calls as f64;
    let batch = ((0.01 / per_call).ceil() as u64).max(1);

    let mut samples: Vec<f64> = Vec::new();
    let mstart = Instant::now();
    while mstart.elapsed() < opts.measure || samples.len() < opts.min_samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_secs_f64() * 1e9 / batch as f64);
        if samples.len() >= 5000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let median = samples[n / 2];
    let p95 = samples[(n as f64 * 0.95) as usize % n];
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        median_ns: median,
        p95_ns: p95,
        stddev_ns: var.sqrt(),
        samples: n,
    };
    println!(
        "{:44} {:>12} mean  {:>12} med  {:>12} p95  ±{:>10}  ({} samples)",
        r.name, fmt_ns(r.mean_ns), fmt_ns(r.median_ns), fmt_ns(r.p95_ns),
        fmt_ns(r.stddev_ns), r.samples
    );
    r
}

/// One-line speedup summary for an A/B comparison (shared by the fused-dot
/// benches so the two call sites can't drift in how they report ratios).
pub fn speedup_line(name: &str, baseline: &BenchResult, fast: &BenchResult) -> String {
    let speedup = baseline.mean_ns / fast.mean_ns;
    format!(
        "{name}: {speedup:.2}x ({} -> {})",
        fmt_ns(baseline.mean_ns),
        fmt_ns(fast.mean_ns)
    )
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ---------------------------------------------------------------------------
// Machine-readable bench trajectory (serde is not in the offline crate set,
// so this is a deliberately tiny JSON emitter). `benches/fused_dot.rs`
// assembles a `BenchJson` and writes `BENCH_kernels.json` at the repo root
// (override with env `ZIPML_BENCH_JSON`); `ci.sh` invokes the bench so the
// file regenerates on every gate run, and CI uploads it as an artifact —
// the repo's persistent perf trajectory.
// ---------------------------------------------------------------------------

/// One JSON scalar. Non-finite numbers serialize as `null`. Counter
/// totals go through [`JsonVal::UInt`], which emits the integer text
/// directly — `Num` routes through f64 and would silently round values
/// above 2^53, breaking the telemetry bit-for-bit byte contract.
#[derive(Clone, Debug)]
pub enum JsonVal {
    Num(f64),
    UInt(u64),
    Str(String),
    Bool(bool),
}

impl From<f64> for JsonVal {
    fn from(v: f64) -> Self {
        JsonVal::Num(v)
    }
}

impl From<u64> for JsonVal {
    fn from(v: u64) -> Self {
        JsonVal::UInt(v)
    }
}

impl From<usize> for JsonVal {
    fn from(v: usize) -> Self {
        JsonVal::Num(v as f64)
    }
}

impl From<u32> for JsonVal {
    fn from(v: u32) -> Self {
        JsonVal::Num(v as f64)
    }
}

impl From<&str> for JsonVal {
    fn from(v: &str) -> Self {
        JsonVal::Str(v.to_string())
    }
}

impl From<bool> for JsonVal {
    fn from(v: bool) -> Self {
        JsonVal::Bool(v)
    }
}

/// Escape `s` as a JSON string (quotes, backslashes, control chars) and
/// append it, quoted, to `out`. Private on purpose: every writer in the
/// repo ([`BenchJson`], [`JsonObj`] — which `telemetry::trace` builds
/// on) funnels through this one escaper, and zipml-lint's `json-emitter`
/// rule keeps second emitters from growing elsewhere. The trace
/// round-trip tests pin the escaping against the matching parser.
fn json_escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn json_val(v: &JsonVal, out: &mut String) {
    match v {
        JsonVal::Num(n) if n.is_finite() => out.push_str(&format!("{n}")),
        JsonVal::Num(_) => out.push_str("null"),
        JsonVal::UInt(v) => out.push_str(&v.to_string()),
        JsonVal::Str(s) => json_escape(s, out),
        JsonVal::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
    }
}

/// A compact flat JSON object under construction: `{"k":v,...}` with no
/// whitespace, fields in call order. This is THE writer for single-line
/// JSON in the repo — [`crate::telemetry::trace::TraceSink`] emits every
/// trace event through it and `stable_view` re-renders through it, so
/// the escaping and number formatting of traces and bench trajectories
/// can never drift apart (zipml-lint's `json-emitter` rule enforces
/// that no other module grows its own emitter).
pub struct JsonObj {
    buf: String,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::with_capacity(96)
    }

    /// Pre-size the line buffer (hot emitters pass their typical size).
    pub fn with_capacity(cap: usize) -> Self {
        let mut buf = String::with_capacity(cap.max(2));
        buf.push('{');
        JsonObj { buf }
    }

    fn key(&mut self, k: &str) {
        if self.buf.len() > 1 {
            self.buf.push(',');
        }
        json_escape(k, &mut self.buf);
        self.buf.push(':');
    }

    /// Append one `"k":v` field.
    pub fn field(&mut self, k: &str, v: &JsonVal) -> &mut Self {
        self.key(k);
        json_val(v, &mut self.buf);
        self
    }

    /// Append one `"k":"v"` string field without routing the value
    /// through an owned [`JsonVal::Str`] (the hot emit path uses this).
    pub fn field_str(&mut self, k: &str, v: &str) -> &mut Self {
        self.key(k);
        json_escape(v, &mut self.buf);
        self
    }

    /// Close the object and hand back the rendered line (no newline).
    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

impl Default for JsonObj {
    fn default() -> Self {
        Self::new()
    }
}

/// Accumulates `{meta: {...}, sections: {name: [entry, ...]}}` and writes
/// it as JSON. Insertion order is preserved for both sections and entries,
/// so the file diffs stably run over run.
pub struct BenchJson {
    meta: Vec<(String, JsonVal)>,
    sections: Vec<(String, Vec<Vec<(String, JsonVal)>>)>,
}

impl BenchJson {
    pub fn new(bench: &str, quick: bool) -> Self {
        BenchJson {
            meta: vec![
                ("bench".into(), bench.into()),
                ("schema".into(), 1.0.into()),
                ("quick".into(), quick.into()),
            ],
            sections: Vec::new(),
        }
    }

    /// Add a top-level metadata field (workload shape, tuned constants, …).
    pub fn meta(&mut self, key: &str, v: impl Into<JsonVal>) {
        self.meta.push((key.to_string(), v.into()));
    }

    /// Append one entry (an object of fields) to `section`.
    pub fn push(&mut self, section: &str, fields: Vec<(&str, JsonVal)>) {
        let entry: Vec<(String, JsonVal)> =
            fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        match self.sections.iter_mut().find(|(name, _)| name == section) {
            Some((_, entries)) => entries.push(entry),
            None => self.sections.push((section.to_string(), vec![entry])),
        }
    }

    fn render(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("{\n  \"meta\": {");
        for (i, (k, v)) in self.meta.iter().enumerate() {
            out.push_str(if i == 0 { "\n    " } else { ",\n    " });
            json_escape(k, &mut out);
            out.push_str(": ");
            json_val(v, &mut out);
        }
        out.push_str("\n  },\n  \"sections\": {");
        for (si, (name, entries)) in self.sections.iter().enumerate() {
            out.push_str(if si == 0 { "\n    " } else { ",\n    " });
            json_escape(name, &mut out);
            out.push_str(": [");
            for (ei, entry) in entries.iter().enumerate() {
                out.push_str(if ei == 0 { "\n      {" } else { ",\n      {" });
                for (fi, (k, v)) in entry.iter().enumerate() {
                    if fi > 0 {
                        out.push_str(", ");
                    }
                    json_escape(k, &mut out);
                    out.push_str(": ");
                    json_val(v, &mut out);
                }
                out.push('}');
            }
            out.push_str("\n    ]");
        }
        out.push_str("\n  }\n}\n");
        out
    }

    /// Write the trajectory file; returns the path written. The default
    /// resolves against the WORKSPACE ROOT (the parent of this crate's
    /// manifest dir) — deliberately not the process cwd, which cargo sets
    /// to the package dir (`rust/`) for bench binaries, while CI uploads
    /// `BENCH_kernels.json` from the repo root. Override with env
    /// `ZIPML_BENCH_JSON`.
    pub fn write(&self, default_name: &str) -> std::io::Result<std::path::PathBuf> {
        let path = match std::env::var_os("ZIPML_BENCH_JSON") {
            Some(p) => std::path::PathBuf::from(p),
            None => {
                let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
                manifest.parent().unwrap_or(manifest).join(default_name)
            }
        };
        std::fs::write(&path, self.render())?;
        Ok(path)
    }
}

pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_obj_renders_compact_lines() {
        let mut o = JsonObj::new();
        o.field_str("kind", "epoch").field("epoch", &1u64.into()).field("loss", &0.5.into());
        assert_eq!(o.finish(), r#"{"kind":"epoch","epoch":1,"loss":0.5}"#);
        assert_eq!(JsonObj::new().finish(), "{}");
        let mut o = JsonObj::with_capacity(8);
        o.field_str("a\"b", "c\\d").field("nan", &JsonVal::Num(f64::NAN));
        o.field("big", &u64::MAX.into());
        assert_eq!(
            o.finish(),
            format!(r#"{{"a\"b":"c\\d","nan":null,"big":{}}}"#, u64::MAX)
        );
    }

    #[test]
    fn bench_json_renders_valid_shape() {
        let mut js = BenchJson::new("unit", true);
        js.meta("rows", 100usize);
        js.meta("note", "a\"b");
        js.push("sec", vec![("p", 8u32.into()), ("ratio", 2.5f64.into())]);
        js.push("sec", vec![("bad", JsonVal::Num(f64::NAN))]);
        js.push("other", vec![("ok", true.into()), ("big", u64::MAX.into())]);
        let s = js.render();
        assert!(s.contains("\"bench\": \"unit\""), "{s}");
        assert!(s.contains("\"quick\": true"), "{s}");
        assert!(s.contains("\"a\\\"b\""), "escaping broke: {s}");
        assert!(s.contains("\"ratio\": 2.5"), "{s}");
        assert!(s.contains("\"bad\": null"), "non-finite must be null: {s}");
        assert!(
            s.contains(&format!("\"big\": {}", u64::MAX)),
            "u64 must not round through f64: {s}"
        );
        // structural sanity: balanced braces/brackets (none inside strings)
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn bench_reports_sane_numbers() {
        let opts = BenchOpts {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_samples: 3,
            quick: true,
        };
        let mut acc = 0u64;
        let r = bench("noop-ish", &opts, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0 && r.mean_ns < 1e7);
        assert!(r.samples >= 3);
    }
}
