//! In-repo micro-benchmark harness (criterion is not in the offline crate
//! set). Used by every target under `rust/benches/` with `harness = false`.
//!
//! Methodology: warmup until ≥ `WARMUP` elapsed, then time batches sized so
//! each batch takes ≳ 10 ms, collect ≥ `MIN_SAMPLES` batch means, report
//! mean / median / p95 / stddev. `--quick` (or env `ZIPML_BENCH_QUICK=1`)
//! shrinks budgets ~10× for CI smoke runs.

use std::time::{Duration, Instant};

pub struct BenchOpts {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
}

impl BenchOpts {
    pub fn from_env_and_args() -> Self {
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("ZIPML_BENCH_QUICK").is_ok_and(|v| v == "1");
        if quick {
            BenchOpts { warmup: Duration::from_millis(30), measure: Duration::from_millis(200), min_samples: 5 }
        } else {
            BenchOpts { warmup: Duration::from_millis(300), measure: Duration::from_secs(2), min_samples: 20 }
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
    pub samples: usize,
}

impl BenchResult {
    pub fn throughput_line(&self, unit: &str, per_iter: f64) -> String {
        let per_sec = per_iter / (self.mean_ns * 1e-9);
        format!("{:44} {:>12} mean  {:>12} p95   {:>14.3e} {unit}/s",
            self.name, fmt_ns(self.mean_ns), fmt_ns(self.p95_ns), per_sec)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` per the harness methodology; prints and returns the result.
pub fn bench<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> BenchResult {
    // Warmup + estimate per-call cost.
    let wstart = Instant::now();
    let mut calls = 0u64;
    while wstart.elapsed() < opts.warmup || calls < 3 {
        f();
        calls += 1;
    }
    let per_call = wstart.elapsed().as_secs_f64() / calls as f64;
    let batch = ((0.01 / per_call).ceil() as u64).max(1);

    let mut samples: Vec<f64> = Vec::new();
    let mstart = Instant::now();
    while mstart.elapsed() < opts.measure || samples.len() < opts.min_samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_secs_f64() * 1e9 / batch as f64);
        if samples.len() >= 5000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let median = samples[n / 2];
    let p95 = samples[(n as f64 * 0.95) as usize % n];
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        median_ns: median,
        p95_ns: p95,
        stddev_ns: var.sqrt(),
        samples: n,
    };
    println!(
        "{:44} {:>12} mean  {:>12} med  {:>12} p95  ±{:>10}  ({} samples)",
        r.name, fmt_ns(r.mean_ns), fmt_ns(r.median_ns), fmt_ns(r.p95_ns),
        fmt_ns(r.stddev_ns), r.samples
    );
    r
}

/// One-line speedup summary for an A/B comparison (shared by the fused-dot
/// benches so the two call sites can't drift in how they report ratios).
pub fn speedup_line(name: &str, baseline: &BenchResult, fast: &BenchResult) -> String {
    let speedup = baseline.mean_ns / fast.mean_ns;
    format!(
        "{name}: {speedup:.2}x ({} -> {})",
        fmt_ns(baseline.mean_ns),
        fmt_ns(fast.mean_ns)
    )
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let opts = BenchOpts { warmup: Duration::from_millis(5), measure: Duration::from_millis(20), min_samples: 3 };
        let mut acc = 0u64;
        let r = bench("noop-ish", &opts, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0 && r.mean_ns < 1e7);
        assert!(r.samples >= 3);
    }
}
