//! Fig 5 bench: simulated FPGA epoch throughput per precision + the real
//! Hogwild! baseline wallclock. Run: cargo bench --bench fig5_fpga [-- --quick]

use zipml::bench::{bench, black_box, section, BenchOpts};
use zipml::data::synthetic::make_regression;
use zipml::fpga::{epoch_seconds, Precision};
use zipml::sgd::{Execution, HostSession};

fn main() {
    let opts = BenchOpts::from_env_and_args();

    section("simulated FPGA epoch time (paper Fig 5/13/14 shape)");
    let (k, n) = (50_000usize, 90usize);
    let base = epoch_seconds(Precision::Float, k, n);
    println!("  {:8} {:>14} {:>10}", "prec", "epoch_time", "speedup");
    for p in [Precision::Float, Precision::Q(8), Precision::Q(4), Precision::Q(2), Precision::Q(1)] {
        let t = epoch_seconds(p, k, n);
        println!("  {:8} {:>12.4e} s {:>9.2}x", p.label(), t, base / t);
    }
    println!("  (paper: FPGA quantized 6-7x over FPGA float / 10-core Hogwild)");

    section("real Hogwild! epoch wallclock on this machine");
    let ds = make_regression("bench", 20_000, 256, 100, 7);
    for threads in [1usize, 2, 4, 8] {
        let session = HostSession::dense(&ds)
            .execution(Execution::Hogwild { threads })
            .epochs(1)
            .lr0(0.02)
            .seed(1);
        bench(&format!("hogwild epoch, {threads} threads"), &opts, || {
            black_box(session.run().expect("dense hogwild session"));
        });
    }

    section("pipeline model evaluation cost (pure fn)");
    bench("epoch_seconds x1000", &opts, || {
        let mut acc = 0.0;
        for i in 0..1000 {
            acc += epoch_seconds(Precision::Q(4), 10_000 + i, 100);
        }
        black_box(acc);
    });
}
