//! Quantization-throughput benchmarks: the L3 hot path that feeds every
//! training step (stochastic quantize, pack/unpack, double-sample encode).
//! Run: cargo bench --bench quantize [-- --quick]

use zipml::bench::{bench, black_box, section, BenchOpts};
use zipml::quant::packing::{DoubleSampleBlock, PackedMatrix};
use zipml::quant::{quantize_values, ColumnScale};
use zipml::rng::Rng;
use zipml::tensor::Matrix;

fn main() {
    let opts = BenchOpts::from_env_and_args();
    let mut rng = Rng::new(1);
    let (rows, cols) = (64usize, 1000usize);
    let nvals = rows * cols;
    let a = Matrix::from_vec(rows, cols, (0..nvals).map(|_| rng.normal()).collect());
    let scale = ColumnScale::from_data(&a);

    section("stochastic quantization (64x1000 batch)");
    let mut out = vec![0.0f32; nvals];
    for s in [3u32, 15, 255] {
        let r = bench(&format!("quantize_values s={s}"), &opts, || {
            quantize_values(&a.data, cols, &scale.m, s, &mut rng, &mut out);
            black_box(&out);
        });
        println!("   {}", r.throughput_line("values", nvals as f64));
    }

    section("bit-packed store");
    for bits in [2u32, 4, 8] {
        bench(&format!("PackedMatrix::quantize {bits}-bit"), &opts, || {
            black_box(PackedMatrix::quantize(&a, &scale, bits, &mut rng));
        });
    }
    let p4 = PackedMatrix::quantize(&a, &scale, 4, &mut rng);
    let mut row = vec![0.0f32; cols];
    let r = bench("dequantize_row 4-bit (x64 rows)", &opts, || {
        for i in 0..rows {
            p4.dequantize_row(i, &mut row);
        }
        black_box(&row);
    });
    println!("   {}", r.throughput_line("values", nvals as f64));

    section("double-sample encode/decode");
    for k in [2usize, 16] {
        bench(&format!("DoubleSampleBlock::quantize k={k} 4-bit"), &opts, || {
            black_box(DoubleSampleBlock::quantize(&a, &scale, 4, k, &mut rng));
        });
    }
    let ds = DoubleSampleBlock::quantize(&a, &scale, 4, 2, &mut rng);
    let r = bench("ds dequantize both samples (x64 rows)", &opts, || {
        for i in 0..rows {
            ds.dequantize_row(i, 0, &mut row);
            ds.dequantize_row(i, 1, &mut row);
        }
        black_box(&row);
    });
    println!("   {}", r.throughput_line("values", 2.0 * nvals as f64));

    section("rng fill (randomness supply for artifacts)");
    let mut buf = vec![0.0f32; nvals];
    let r = bench("fill_uniform 64k", &opts, || {
        rng.fill_uniform(&mut buf);
        black_box(&buf);
    });
    println!("   {}", r.throughput_line("values", nvals as f64));
}
