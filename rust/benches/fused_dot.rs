//! Acceptance bench for the weaved-domain fused kernels: on a 64-dim,
//! 100k-row, 8-bit store, fused `dot_row` must beat dequantize-row-then-dot
//! at p ≤ 8, with byte accounting identical to the row-read path.
//! Run: cargo bench --bench fused_dot [-- --quick]

use zipml::bench::{bench, black_box, section, BenchOpts};
use zipml::quant::ColumnScale;
use zipml::rng::Rng;
use zipml::store::{kernel, ShardedStore, StepKernel};
use zipml::tensor::{dot, Matrix};

fn main() {
    let opts = BenchOpts::from_env_and_args();
    let mut rng = Rng::new(7);
    let (rows, cols) = (100_000usize, 64usize);
    let a = Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal()).collect());
    let scale = ColumnScale::from_data(&a);
    let store = ShardedStore::ingest(&a, &scale, 8, 42, 64, 0);
    let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
    let mut k = StepKernel::new(cols);
    k.refresh(&scale.m, &x);

    section("dot: fused weaved-domain vs dequantize-row-then-dot (100k x 64, 8-bit store)");
    let mut row = vec![0.0f32; cols];
    let mut r = 0usize;
    let mut acc = 0.0f32;
    for p in [1u32, 2, 4, 8] {
        let deq = bench(&format!("dequantize+dot p={p}"), &opts, || {
            r = (r + 1) % rows;
            store.dequantize_row(r, p, &mut row);
            acc += dot(&row, &x);
            black_box(acc);
        });
        let fus = bench(&format!("fused dot_row   p={p}"), &opts, || {
            r = (r + 1) % rows;
            acc += store.dot_row_fused(r, p, &k);
            black_box(acc);
        });
        let verdict = if deq.mean_ns / fus.mean_ns >= 2.0 { "PASS (>= 2x)" } else { "below 2x" };
        println!(
            "   {} — {verdict}",
            zipml::bench::speedup_line(&format!("fused dot p={p}"), &deq, &fus)
        );
    }

    section("full fused SGD gradient batch vs dequantize path (batch 64)");
    let b = 64usize;
    let batch: Vec<usize> = (0..b).map(|i| (i * 1543) % rows).collect();
    let targets: Vec<f32> = (0..b).map(|i| i as f32 * 0.01).collect();
    let mut grad = vec![0.0f32; cols];
    for p in [2u32, 8] {
        bench(&format!("dequant grad batch p={p}"), &opts, || {
            grad.fill(0.0);
            for (&ri, &t) in batch.iter().zip(&targets) {
                store.dequantize_row(ri, p, &mut row);
                let err = dot(&row, &x) - t;
                zipml::tensor::axpy(err, &row, &mut grad);
            }
            black_box(&grad);
        });
        bench(&format!("fused  grad batch p={p}"), &opts, || {
            grad.fill(0.0);
            store.fused_grad_batch(&batch, p, &k, &targets, &mut grad);
            black_box(&grad);
        });
    }

    section("byte accounting: fused == row-read path, per epoch");
    for p in [2u32, 8] {
        store.reset_bytes_read();
        for ri in 0..rows {
            store.dequantize_row(ri, p, &mut row);
        }
        let dequant_bytes = store.bytes_read();
        store.reset_bytes_read();
        for ri in 0..rows {
            black_box(store.dot_row_fused(ri, p, &k));
        }
        let fused_bytes = store.bytes_read();
        println!(
            "  p={p}: dequant epoch {dequant_bytes} B, fused epoch {fused_bytes} B — {}",
            if dequant_bytes == fused_bytes { "identical" } else { "MISMATCH" }
        );
        assert_eq!(dequant_bytes, fused_bytes, "accounting must not drift");
    }

    // keep the kernel module reachable for per-row axpy shape too
    let (shard, local) = store.locate_row(0);
    bench("fused axpy_row p=8", &opts, || {
        kernel::axpy_row(shard, local, 8, 0.01, &mut grad);
        black_box(&grad);
    });

    section("double sampling: stochastic draws vs truncating reads");
    let mut ds_rng = Rng::new(11);
    for p in [2u32, 4] {
        bench(&format!("fused dot_row    p={p} (trunc)"), &opts, || {
            r = (r + 1) % rows;
            acc += store.dot_row_fused(r, p, &k);
            black_box(acc);
        });
        bench(&format!("fused dot_row_ds p={p} (1 draw)"), &opts, || {
            r = (r + 1) % rows;
            let (shard, local) = store.locate_row(r);
            acc += kernel::dot_row_ds(shard, local, p, &k, &mut ds_rng);
            black_box(acc);
        });
        bench(&format!("ds grad batch    p={p} (2 draws/row)"), &opts, || {
            grad.fill(0.0);
            store.ds_grad_batch(&batch, p, &k, &targets, &mut ds_rng, &mut grad);
            black_box(&grad);
        });
    }

    section("byte accounting: DS epoch == exactly 2x the truncation epoch");
    let epoch_rows: Vec<usize> = (0..rows).collect();
    let epoch_targets = vec![0.0f32; rows];
    for p in [2u32, 8] {
        store.reset_bytes_read();
        for chunk in epoch_rows.chunks(64) {
            store.fused_grad_batch(chunk, p, &k, &epoch_targets[..chunk.len()], &mut grad);
        }
        let trunc_bytes = store.bytes_read();
        store.reset_bytes_read();
        for chunk in epoch_rows.chunks(64) {
            store.ds_grad_batch(
                chunk,
                p,
                &k,
                &epoch_targets[..chunk.len()],
                &mut ds_rng,
                &mut grad,
            );
        }
        let ds_bytes = store.bytes_read();
        println!(
            "  p={p}: truncation epoch {trunc_bytes} B, double-sampled epoch {ds_bytes} B — {}",
            if ds_bytes == 2 * trunc_bytes { "exactly 2x" } else { "MISMATCH" }
        );
        assert_eq!(
            ds_bytes,
            2 * trunc_bytes,
            "the DS path must account exactly 2x the truncation path per epoch"
        );
    }
}
