//! Acceptance + trajectory bench for the weaved-domain fused kernels on
//! the 100k-row × 64-col workload (stored at 16 bits so the p sweep spans
//! 1..=16). Sections:
//!
//!   * dot: dequantize-row oracle vs per-row fused vs blocked fused
//!   * grad batch: per-row fused vs blocked (ASSERT: blocked ≥ 2× per-row
//!     at p = 8 — at full budgets; --quick warns instead of failing)
//!   * popcount fast path: dot_row_q vs the f32 masked-sum dot (ASSERT:
//!     popcount wins at q ≤ 4 — full budgets; --quick warns)
//!   * simd tier: scalar vs `std::simd` twin tiers on the fused grad
//!     batch through the real dispatch sites (ASSERT with `--features
//!     simd`: simd8 >= 2x scalar at p = 8 — full budgets; --quick warns;
//!     without the feature the section records the scalar tier alone)
//!   * sparse/dense crossover: per-popcount timings of both masked_sum
//!     and spread_word paths — the data behind SPARSE_BITS /
//!     MASKED_SUM_SPARSE_BITS, plus the measured crossover popcounts
//!     (`masked_sum_crossover_pc`, `spread_crossover_pc`)
//!   * rank-indexed density sweep: indexed vs dense blocked dots across
//!     plane-WORD densities on block-sparse rows (ASSERT: indexed wins
//!     below 5% density — full budgets; --quick warns)
//!   * byte accounting: blocked == per-row == row-read path; DS == 2×
//!   * telemetry overhead: fused grad batch with an enabled counter
//!     registry attached vs the disabled default (ASSERT: enabled ≥
//!     0.95× disabled throughput at p = 8 — full budgets; --quick warns)
//!
//! Every section is also recorded machine-readably in
//! `BENCH_kernels.json` (repo root; env `ZIPML_BENCH_JSON` overrides) —
//! the repo's persistent perf trajectory, uploaded as a CI artifact.
//! Run: cargo bench --bench fused_dot [-- --quick]

use zipml::bench::{bench, black_box, section, BenchJson, BenchOpts};
use zipml::quant::ColumnScale;
use zipml::rng::Rng;
use zipml::sgd::{GlmLoss, ModelKind};
use zipml::store::{kernel, QuantStepKernel, ShardedStore, StepKernel, WeavedMatrix};
use zipml::tensor::{dot, Matrix};

/// The pre-blocking per-row fused gradient batch (dot_row + bit-walk
/// axpy_row_planes per row over the shard-grouped order, one affine pass)
/// — the baseline the blocked path must beat 2×. `order` is precomputed
/// OUTSIDE the timed loop, while the blocked contender re-groups and
/// counts bytes inside `fused_grad_batch` on every call — the measured
/// ratio therefore under-reports the blocked path's kernel-level win,
/// making the ≥ 2× acceptance assert conservative.
fn per_row_grad_batch(
    store: &ShardedStore,
    order: &[usize],
    rows: &[usize],
    p: u32,
    k: &StepKernel,
    targets: &[f32],
    grad: &mut [f32],
) {
    let mut err_sum = 0.0f32;
    for &i in order {
        let (shard, local) = store.locate_row(rows[i]);
        let err = kernel::dot_row(shard, local, p, k) - targets[i];
        kernel::axpy_row_planes(shard, local, p, err, grad);
        err_sum += err;
    }
    kernel::axpy_affine(err_sum, &store.scale().m, grad);
}

fn main() {
    let opts = BenchOpts::from_env_and_args();
    let quick = opts.quick;
    let mut js = BenchJson::new("fused_dot", quick);

    let mut rng = Rng::new(7);
    let (rows, cols, store_bits) = (100_000usize, 64usize, 16u32);
    let a = Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal()).collect());
    let scale = ColumnScale::from_data(&a);
    let mut store = ShardedStore::ingest(&a, &scale, store_bits, 42, 64, 0);
    let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
    let mut k = StepKernel::new(cols);
    k.refresh(&scale.m, &x);
    js.meta("rows", rows);
    js.meta("cols", cols);
    js.meta("store_bits", store_bits);
    js.meta("shards", store.num_shards());
    js.meta("masked_sum_sparse_bits", kernel::MASKED_SUM_SPARSE_BITS);
    js.meta("spread_word_sparse_bits", kernel::SPARSE_BITS);

    // a shard-crossing 64-row minibatch, fixed across all batch benches
    let b = 64usize;
    let batch: Vec<usize> = (0..b).map(|i| (i * 1543) % rows).collect();
    let targets: Vec<f32> = (0..b).map(|i| i as f32 * 0.01).collect();
    let mut order: Vec<usize> = (0..b).collect();
    order.sort_by_key(|&i| batch[i] / store.shard_rows());
    let mut grad = vec![0.0f32; cols];
    let mut dots = vec![0.0f32; b];

    // the oracle and per-row contenders run kernel-level after the same
    // locate_row (no per-call byte-counter atomic on either); the blocked
    // contender is the full store entry point, so its ns/row INCLUDES the
    // per-batch grouping and one per-batch counter add — real overhead it
    // pays in production, amortized over the 64-row block
    section("dot: dequantize oracle vs per-row fused vs blocked (100k x 64, 16-bit store)");
    let mut row = vec![0.0f32; cols];
    let mut r = 0usize;
    let mut acc = 0.0f32;
    for p in [1u32, 2, 4, 8, 16] {
        let deq = bench(&format!("dequantize+dot    p={p}"), &opts, || {
            r = (r + 1) % rows;
            let (shard, local) = store.locate_row(r);
            shard.dequantize_row_at(local, p, &mut row);
            acc += dot(&row, &x);
            black_box(acc);
        });
        let fus = bench(&format!("fused dot_row     p={p}"), &opts, || {
            r = (r + 1) % rows;
            let (shard, local) = store.locate_row(r);
            acc += kernel::dot_row(shard, local, p, &k);
            black_box(acc);
        });
        let blk = bench(&format!("blocked dots (64) p={p}"), &opts, || {
            store.dot_rows_fused(&batch, p, &k, &mut dots);
            black_box(&dots);
        });
        let blk_per_row = blk.mean_ns / b as f64;
        let verdict = if deq.mean_ns / fus.mean_ns >= 2.0 { "PASS (>= 2x)" } else { "below 2x" };
        println!(
            "   {} — {verdict}; blocked {:.1} ns/row",
            zipml::bench::speedup_line(&format!("fused dot p={p}"), &deq, &fus),
            blk_per_row
        );
        js.push(
            "dot",
            vec![
                ("p", p.into()),
                ("oracle_ns", deq.mean_ns.into()),
                ("per_row_ns", fus.mean_ns.into()),
                ("blocked_ns_per_row", blk_per_row.into()),
                ("rows_per_sec_blocked", (1e9 / blk_per_row).into()),
                ("bytes_per_row", store.bytes_per_row(p).into()),
                ("speedup_per_row_vs_oracle", (deq.mean_ns / fus.mean_ns).into()),
                ("speedup_blocked_vs_per_row", (fus.mean_ns / blk_per_row).into()),
            ],
        );
    }

    section("grad batch: per-row fused vs blocked batch kernels (batch 64)");
    for p in [2u32, 8] {
        let deq = bench(&format!("dequant grad batch p={p}"), &opts, || {
            grad.fill(0.0);
            for (&ri, &t) in batch.iter().zip(&targets) {
                store.dequantize_row(ri, p, &mut row);
                let err = dot(&row, &x) - t;
                zipml::tensor::axpy(err, &row, &mut grad);
            }
            black_box(&grad);
        });
        let per_row = bench(&format!("per-row grad batch p={p}"), &opts, || {
            grad.fill(0.0);
            per_row_grad_batch(&store, &order, &batch, p, &k, &targets, &mut grad);
            black_box(&grad);
        });
        let blocked = bench(&format!("blocked grad batch p={p}"), &opts, || {
            grad.fill(0.0);
            store.fused_grad_batch(&batch, p, &k, &targets, &mut grad);
            black_box(&grad);
        });
        let speedup = per_row.mean_ns / blocked.mean_ns;
        println!(
            "   {}",
            zipml::bench::speedup_line(&format!("blocked grad p={p}"), &per_row, &blocked)
        );
        js.push(
            "grad_batch",
            vec![
                ("p", p.into()),
                ("batch", b.into()),
                ("oracle_ns", deq.mean_ns.into()),
                ("per_row_ns", per_row.mean_ns.into()),
                ("blocked_ns", blocked.mean_ns.into()),
                ("rows_per_sec_blocked", (b as f64 * 1e9 / blocked.mean_ns).into()),
                ("bytes_per_row", store.bytes_per_row(p).into()),
                ("speedup_blocked_vs_per_row", speedup.into()),
                ("speedup_blocked_vs_oracle", (deq.mean_ns / blocked.mean_ns).into()),
            ],
        );
        if p == 8 {
            // perf-ratio acceptance: enforced at full measurement budgets
            // only — quick-mode smoke runs (200 ms budgets on shared CI
            // runners) are too noisy to gate on and warn instead
            if quick {
                if speedup < 2.0 {
                    println!("   WARNING: blocked < 2x per-row ({speedup:.2}x) in quick mode");
                }
            } else {
                assert!(
                    speedup >= 2.0,
                    "ACCEPTANCE: blocked grad batch must be >= 2x the per-row fused path \
                     at p=8 (got {speedup:.2}x)"
                );
            }
        }
    }

    section("simd tier: scalar vs std::simd twins on the fused grad batch (p=8)");
    // A/B through the real dispatch sites on the same workload; the twins
    // are bit-identical (tests/simd_twins.rs), so this is pure throughput.
    #[cfg(feature = "simd")]
    {
        use zipml::store::kernel::dispatch::{force_tier, tier, Tier};
        let probed = tier();
        force_tier(Tier::Scalar);
        let scalar = bench("grad batch scalar tier p=8", &opts, || {
            grad.fill(0.0);
            store.fused_grad_batch(&batch, 8, &k, &targets, &mut grad);
            black_box(&grad);
        });
        force_tier(Tier::Lanes8);
        let simd8 = bench("grad batch simd8 tier  p=8", &opts, || {
            grad.fill(0.0);
            store.fused_grad_batch(&batch, 8, &k, &targets, &mut grad);
            black_box(&grad);
        });
        force_tier(probed);
        let speedup = scalar.mean_ns / simd8.mean_ns;
        println!("   {}", zipml::bench::speedup_line("simd8 vs scalar p=8", &scalar, &simd8));
        js.push(
            "simd",
            vec![
                ("p", 8u32.into()),
                ("batch", b.into()),
                ("probed_tier", zipml::store::kernel::dispatch::tier_label().into()),
                ("scalar_ns", scalar.mean_ns.into()),
                ("simd8_ns", simd8.mean_ns.into()),
                ("rows_per_sec_simd8", (b as f64 * 1e9 / simd8.mean_ns).into()),
                ("speedup_simd8_vs_scalar", speedup.into()),
            ],
        );
        if quick {
            if speedup < 2.0 {
                println!("   WARNING: simd8 < 2x scalar ({speedup:.2}x) in quick mode");
            }
        } else {
            assert!(
                speedup >= 2.0,
                "ACCEPTANCE: the simd8 tier must be >= 2x the scalar tier on the fused \
                 grad batch at p=8 (got {speedup:.2}x)"
            );
        }
    }
    #[cfg(not(feature = "simd"))]
    {
        // stable default: one tier only; the section still exists so the
        // trajectory file keeps a stable shape across feature builds
        let scalar = bench("grad batch scalar tier p=8", &opts, || {
            grad.fill(0.0);
            store.fused_grad_batch(&batch, 8, &k, &targets, &mut grad);
            black_box(&grad);
        });
        println!(
            "   simd feature off: scalar tier only ({:.1} rows/s)",
            b as f64 * 1e9 / scalar.mean_ns
        );
        js.push(
            "simd",
            vec![
                ("p", 8u32.into()),
                ("batch", b.into()),
                ("probed_tier", zipml::store::kernel::dispatch::tier_label().into()),
                ("scalar_ns", scalar.mean_ns.into()),
                ("rows_per_sec_scalar", (b as f64 * 1e9 / scalar.mean_ns).into()),
            ],
        );
    }

    section("per-model fused grad batch: any GLM through one engine (p=8, batch 64)");
    // the widened scenario space of the HostSession redesign: the same
    // blocked plane-domain batch, with each GlmLoss's step multiplier
    // applied between the fused dot and the fused axpy — rows/sec per
    // model, relative to the linreg residual (the historical hot path)
    let glms: [(&str, ModelKind); 4] = [
        ("linreg", ModelKind::Linreg),
        ("lssvm", ModelKind::Lssvm { c: 1e-4 }),
        ("logistic", ModelKind::Logistic),
        ("svm", ModelKind::Svm),
    ];
    let pm_targets: Vec<f32> = (0..b).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let mut linreg_ns = 0.0f64;
    for (name, model) in &glms {
        let br = bench(&format!("glm grad batch {name:8} p=8"), &opts, || {
            grad.fill(0.0);
            store.fused_grad_batch_glm(
                &batch,
                8,
                &k,
                &pm_targets,
                |d, t| model.multiplier(d, t),
                &mut grad,
            );
            black_box(&grad);
        });
        if *name == "linreg" {
            linreg_ns = br.mean_ns;
        }
        let rel = br.mean_ns / linreg_ns;
        println!("   {name:8}: {:.1} rows/s ({rel:.3}x linreg time)", b as f64 * 1e9 / br.mean_ns);
        js.push(
            "per_model",
            vec![
                ("model", (*name).into()),
                ("p", 8u32.into()),
                ("batch", b.into()),
                ("ns", br.mean_ns.into()),
                ("rows_per_sec", (b as f64 * 1e9 / br.mean_ns).into()),
                ("rel_time_vs_linreg", rel.into()),
            ],
        );
    }

    section("popcount fast path: integer AND+POPCNT dot vs f32 masked-sum dot (p=8)");
    // baseline and candidate are symmetric: both locate the row and run
    // the bare kernel, neither touches the byte-counter atomic
    let p_q = 8u32;
    let f32_dot = bench("fused dot_row f32  p=8", &opts, || {
        r = (r + 1) % rows;
        let (shard, local) = store.locate_row(r);
        acc += kernel::dot_row(shard, local, p_q, &k);
        black_box(acc);
    });
    let mut q_rng = Rng::new(29);
    for q in [1u32, 2, 4, 8] {
        let mut qk = QuantStepKernel::new(cols, q);
        qk.refresh(&scale.m, &x, &mut q_rng);
        let qb = bench(&format!("popcount dot_row_q q={q}"), &opts, || {
            r = (r + 1) % rows;
            let (shard, local) = store.locate_row(r);
            acc += kernel::dot_row_q(shard, local, p_q, &qk);
            black_box(acc);
        });
        let speedup = f32_dot.mean_ns / qb.mean_ns;
        println!(
            "   {}",
            zipml::bench::speedup_line(&format!("popcount q={q}"), &f32_dot, &qb)
        );
        js.push(
            "popcount",
            vec![
                ("q", q.into()),
                ("p", p_q.into()),
                ("dot_f32_ns", f32_dot.mean_ns.into()),
                ("dot_q_ns", qb.mean_ns.into()),
                ("speedup", speedup.into()),
            ],
        );
        if q <= 4 {
            if quick {
                if speedup <= 1.0 {
                    println!("   WARNING: popcount q={q} not ahead ({speedup:.2}x) in quick mode");
                }
            } else {
                assert!(
                    speedup > 1.0,
                    "ACCEPTANCE: the popcount path must beat the f32 masked-sum path \
                     at q={q} (got {speedup:.2}x)"
                );
            }
        }
    }

    section("sparse/dense crossover: per-popcount path timings (64-word cycles)");
    let g64: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
    let mut out16 = vec![0u16; 64];
    let mut lanes: Vec<u32> = (0..64).collect();
    let mut pc_rows: Vec<(u64, f64, f64, f64, f64)> = Vec::new();
    for pc in [1usize, 2, 4, 6, 8, 12, 16, 24, 32, 48] {
        // 256 words with exactly pc set bits each
        let words: Vec<u64> = (0..256)
            .map(|_| {
                rng.shuffle(&mut lanes);
                lanes[..pc].iter().fold(0u64, |w, &j| w | (1u64 << j))
            })
            .collect();
        let mut wi = 0usize;
        let ms_walk = bench(&format!("masked_sum walk  pc={pc:2}"), &opts, || {
            wi = (wi + 1) & 255;
            acc += kernel::masked_sum_sparse(words[wi], &g64);
            black_box(acc);
        });
        let ms_lane = bench(&format!("masked_sum lanes pc={pc:2}"), &opts, || {
            wi = (wi + 1) & 255;
            acc += kernel::masked_sum_dense(words[wi], &g64);
            black_box(acc);
        });
        let sp_walk = bench(&format!("spread walk      pc={pc:2}"), &opts, || {
            wi = (wi + 1) & 255;
            kernel::spread_word_sparse(words[wi], 3, &mut out16);
            black_box(&out16);
        });
        let sp_lut = bench(&format!("spread LUT       pc={pc:2}"), &opts, || {
            wi = (wi + 1) & 255;
            kernel::spread_word_dense(words[wi], 3, &mut out16);
            black_box(&out16);
        });
        js.push(
            "sparse_crossover",
            vec![
                ("popcount", pc.into()),
                ("masked_sum_walk_ns", ms_walk.mean_ns.into()),
                ("masked_sum_lanes_ns", ms_lane.mean_ns.into()),
                ("spread_walk_ns", sp_walk.mean_ns.into()),
                ("spread_lut_ns", sp_lut.mean_ns.into()),
            ],
        );
        println!(
            "   pc={pc:2}: masked_sum walk/lanes {:.2} — spread walk/LUT {:.2}",
            ms_walk.mean_ns / ms_lane.mean_ns,
            sp_walk.mean_ns / sp_lut.mean_ns
        );
        pc_rows.push((pc as u64, ms_walk.mean_ns, ms_lane.mean_ns, sp_walk.mean_ns, sp_lut.mean_ns));
    }
    // the measured crossovers pin SPARSE_BITS / MASKED_SUM_SPARSE_BITS to
    // data: the smallest swept popcount where the lane/LUT path beats the
    // walk (64 = the walk won everywhere in this sweep)
    let ms_xover = pc_rows.iter().find(|r| r.2 < r.1).map_or(64, |r| r.0);
    let sp_xover = pc_rows.iter().find(|r| r.4 < r.3).map_or(64, |r| r.0);
    println!(
        "   crossovers: masked_sum lanes win from pc={ms_xover}, spread LUT wins from pc={sp_xover}"
    );
    js.push(
        "crossover",
        vec![
            ("masked_sum_crossover_pc", ms_xover.into()),
            ("spread_crossover_pc", sp_xover.into()),
            ("masked_sum_sparse_bits_const", kernel::MASKED_SUM_SPARSE_BITS.into()),
            ("spread_word_sparse_bits_const", kernel::SPARSE_BITS.into()),
        ],
    );

    section("rank-indexed sparse planes: indexed vs dense blocked dots by plane-word density");
    // density = fraction of NONZERO plane words (DESIGN.md §12): the rank
    // index skips all-zero 8-word runs, so zeros are planted at word
    // granularity (block-sparse rows) — uniform value sparsity barely
    // produces zero words at 64 values per word
    let (srows, scols, sbits) = (512usize, 4096usize, 8u32);
    let swpp = scols.div_ceil(64);
    let sx: Vec<f32> = (0..scols).map(|_| rng.normal()).collect();
    let ones = vec![1.0f32; scols];
    let mut sk = StepKernel::new(scols);
    sk.refresh(&ones, &sx);
    let sbatch: Vec<usize> = (0..64).map(|i| (i * 37) % srows).collect();
    let mut sdots = vec![0.0f32; 64];
    let mut indexed_wins_up_to = 0u64;
    for density_pc in [1u64, 2, 5, 10, 25, 100] {
        let nzw = (density_pc as usize * swpp).div_ceil(100).max(1);
        let mut idx = vec![0u16; srows * scols];
        for r in 0..srows {
            for j in 0..nzw {
                // evenly spaced nonzero words; the all-ones index value
                // makes every plane's word occupancy equal the value one
                let wj = j * swpp / nzw;
                for c in wj * 64..(wj + 1) * 64 {
                    idx[r * scols + c] = (1u16 << sbits) - 1;
                }
            }
        }
        let dense_w = WeavedMatrix::from_indices(
            srows,
            scols,
            sbits,
            (1u32 << sbits) - 1,
            ColumnScale { m: ones.clone() },
            &idx,
        );
        let mut indexed_w = dense_w.clone();
        indexed_w.build_plane_index();
        let dn = bench(&format!("dense blocked dots   d={density_pc:3}%"), &opts, || {
            kernel::dot_rows_block(&dense_w, &sbatch, sbits, &sk, &mut sdots);
            black_box(&sdots);
        });
        let ix = bench(&format!("indexed blocked dots d={density_pc:3}%"), &opts, || {
            kernel::dot_rows_block(&indexed_w, &sbatch, sbits, &sk, &mut sdots);
            black_box(&sdots);
        });
        let speedup = dn.mean_ns / ix.mean_ns;
        if speedup > 1.0 {
            indexed_wins_up_to = density_pc;
        }
        println!(
            "   d={density_pc:3}% ({nzw}/{swpp} words): indexed {speedup:.2}x dense, index {} B",
            indexed_w.index_bytes()
        );
        js.push(
            "density_sweep",
            vec![
                ("density_pc", density_pc.into()),
                ("nonzero_words_per_plane", nzw.into()),
                ("words_per_plane", swpp.into()),
                ("dense_ns", dn.mean_ns.into()),
                ("indexed_ns", ix.mean_ns.into()),
                ("speedup_indexed_vs_dense", speedup.into()),
                ("index_bytes", indexed_w.index_bytes().into()),
            ],
        );
        if density_pc <= 5 {
            if quick {
                if speedup <= 1.0 {
                    println!(
                        "   WARNING: indexed not ahead at {density_pc}% density \
                         ({speedup:.2}x) in quick mode"
                    );
                }
            } else {
                assert!(
                    speedup > 1.0,
                    "ACCEPTANCE: the rank-indexed path must beat the dense walk at and \
                     below 5% plane-word density (got {speedup:.2}x at {density_pc}%)"
                );
            }
        }
    }
    js.push(
        "density_sweep_summary",
        vec![("indexed_wins_up_to_density_pc", indexed_wins_up_to.into())],
    );

    section("byte accounting: blocked == per-row == row-read path, per epoch");
    for p in [2u32, 8] {
        store.reset_bytes_read();
        for ri in 0..rows {
            store.dequantize_row(ri, p, &mut row);
        }
        let dequant_bytes = store.bytes_read();
        store.reset_bytes_read();
        for ri in 0..rows {
            black_box(store.dot_row_fused(ri, p, &k));
        }
        let fused_bytes = store.bytes_read();
        store.reset_bytes_read();
        let epoch_rows: Vec<usize> = (0..rows).collect();
        let epoch_targets = vec![0.0f32; b];
        for chunk in epoch_rows.chunks(b) {
            grad.fill(0.0);
            store.fused_grad_batch(chunk, p, &k, &epoch_targets[..chunk.len()], &mut grad);
        }
        let blocked_bytes = store.bytes_read();
        println!(
            "  p={p}: dequant {dequant_bytes} B, per-row fused {fused_bytes} B, \
             blocked {blocked_bytes} B — {}",
            if dequant_bytes == fused_bytes && fused_bytes == blocked_bytes {
                "identical"
            } else {
                "MISMATCH"
            }
        );
        assert_eq!(dequant_bytes, fused_bytes, "accounting must not drift");
        assert_eq!(
            fused_bytes, blocked_bytes,
            "ACCEPTANCE: blocked and per-row byte accounting must be equal"
        );
        js.push(
            "accounting",
            vec![
                ("p", p.into()),
                ("dequant_epoch_bytes", (dequant_bytes as f64).into()),
                ("per_row_epoch_bytes", (fused_bytes as f64).into()),
                ("blocked_epoch_bytes", (blocked_bytes as f64).into()),
            ],
        );
    }

    // keep the per-row axpy shape reachable too
    let (shard, local) = store.locate_row(0);
    bench("fused axpy_row p=8", &opts, || {
        kernel::axpy_row(shard, local, 8, 0.01, &mut grad);
        black_box(&grad);
    });

    section("double sampling: stochastic draws vs truncating reads");
    let mut ds_rng = Rng::new(11);
    for p in [2u32, 4] {
        let tr = bench(&format!("fused dot_row    p={p} (trunc)"), &opts, || {
            r = (r + 1) % rows;
            let (shard, local) = store.locate_row(r);
            acc += kernel::dot_row(shard, local, p, &k);
            black_box(acc);
        });
        let one = bench(&format!("fused dot_row_ds p={p} (1 draw)"), &opts, || {
            r = (r + 1) % rows;
            let (shard, local) = store.locate_row(r);
            acc += kernel::dot_row_ds(shard, local, p, &k, &mut ds_rng);
            black_box(acc);
        });
        let dsb = bench(&format!("ds grad batch    p={p} (2 draws/row)"), &opts, || {
            grad.fill(0.0);
            store.ds_grad_batch(&batch, p, &k, &targets, &mut ds_rng, &mut grad);
            black_box(&grad);
        });
        js.push(
            "double_sampling",
            vec![
                ("p", p.into()),
                ("trunc_dot_ns", tr.mean_ns.into()),
                ("ds_dot_ns", one.mean_ns.into()),
                ("ds_grad_batch_ns", dsb.mean_ns.into()),
                ("rows_per_sec_ds_batch", (b as f64 * 1e9 / dsb.mean_ns).into()),
            ],
        );
    }

    section("byte accounting: DS epoch == exactly 2x the truncation epoch");
    let epoch_rows: Vec<usize> = (0..rows).collect();
    let epoch_targets = vec![0.0f32; rows];
    for p in [2u32, 8] {
        store.reset_bytes_read();
        for chunk in epoch_rows.chunks(64) {
            store.fused_grad_batch(chunk, p, &k, &epoch_targets[..chunk.len()], &mut grad);
        }
        let trunc_bytes = store.bytes_read();
        store.reset_bytes_read();
        for chunk in epoch_rows.chunks(64) {
            store.ds_grad_batch(
                chunk,
                p,
                &k,
                &epoch_targets[..chunk.len()],
                &mut ds_rng,
                &mut grad,
            );
        }
        let ds_bytes = store.bytes_read();
        println!(
            "  p={p}: truncation epoch {trunc_bytes} B, double-sampled epoch {ds_bytes} B — {}",
            if ds_bytes == 2 * trunc_bytes { "exactly 2x" } else { "MISMATCH" }
        );
        assert_eq!(
            ds_bytes,
            2 * trunc_bytes,
            "the DS path must account exactly 2x the truncation path per epoch"
        );
        js.push(
            "accounting_ds",
            vec![
                ("p", p.into()),
                ("trunc_epoch_bytes", (trunc_bytes as f64).into()),
                ("ds_epoch_bytes", (ds_bytes as f64).into()),
            ],
        );
    }

    section("telemetry overhead: enabled vs disabled counter registry (grad batch, p=8)");
    // the branch-free contract (DESIGN.md §10): the disabled default does
    // the same mask-gated relaxed adds with mask 0, so attaching an
    // enabled registry must cost ~nothing on the fused hot path. Disabled
    // is measured first, on the store's shared disabled registry.
    let disabled = bench("grad batch, telemetry off p=8", &opts, || {
        grad.fill(0.0);
        store.fused_grad_batch(&batch, 8, &k, &targets, &mut grad);
        black_box(&grad);
    });
    let reg = std::sync::Arc::new(zipml::telemetry::Metrics::enabled());
    store.attach_metrics(std::sync::Arc::clone(&reg));
    let enabled = bench("grad batch, telemetry on  p=8", &opts, || {
        grad.fill(0.0);
        store.fused_grad_batch(&batch, 8, &k, &targets, &mut grad);
        black_box(&grad);
    });
    assert!(reg.bytes_read_total() > 0, "the enabled registry saw no bytes");
    let ratio = disabled.mean_ns / enabled.mean_ns;
    println!("   telemetry on/off throughput ratio: {ratio:.3} (acceptance: >= 0.95)");
    js.push(
        "telemetry_overhead",
        vec![
            ("p", 8u32.into()),
            ("batch", b.into()),
            ("disabled_ns", disabled.mean_ns.into()),
            ("enabled_ns", enabled.mean_ns.into()),
            ("throughput_ratio", ratio.into()),
        ],
    );
    if quick {
        if ratio < 0.95 {
            println!("   WARNING: telemetry overhead above 5% ({ratio:.3}x) in quick mode");
        }
    } else {
        assert!(
            ratio >= 0.95,
            "ACCEPTANCE: the enabled-telemetry fused grad batch must keep >= 0.95x the \
             disabled throughput at p=8 (got {ratio:.3}x)"
        );
    }

    match js.write("BENCH_kernels.json") {
        Ok(path) => println!("\nwrote bench trajectory to {}", path.display()),
        Err(e) => eprintln!("\nWARNING: could not write bench trajectory: {e}"),
    }
}
