//! SampleStore benchmarks: weaved any-precision read throughput at
//! p ∈ {1, 2, 4, 8} vs the full-width `PackedMatrix` accessors, plus
//! sharded (parallel) vs single-shard ingestion.
//! Run: cargo bench --bench store [-- --quick]

use zipml::bench::{bench, black_box, section, BenchOpts};
use zipml::quant::packing::PackedMatrix;
use zipml::quant::ColumnScale;
use zipml::rng::Rng;
use zipml::store::{kernel, ShardedStore, StepKernel, WeavedMatrix};
use zipml::tensor::Matrix;

fn main() {
    let opts = BenchOpts::from_env_and_args();
    let mut rng = Rng::new(5);
    let (rows, cols) = (2048usize, 512usize);
    let a = Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal()).collect());
    let scale = ColumnScale::from_data(&a);
    let packed = PackedMatrix::quantize(&a, &scale, 8, &mut rng);
    let weaved = WeavedMatrix::from_packed(&packed);

    section("any-precision row reads (2048x512 store, 8-bit planes)");
    let mut out = vec![0.0f32; cols];
    let mut r = 0usize;
    for p in [1u32, 2, 4, 8] {
        let bytes = weaved.bytes_per_row(p) as f64;
        let res = bench(&format!("weaved dequantize_row p={p} ({bytes} B/row)"), &opts, || {
            r = (r + 1) % rows;
            black_box(weaved.dequantize_row_at(r, p, &mut out));
        });
        println!("   {}", res.throughput_line("B", bytes));
    }
    let res = bench("packed dequantize_row (full width)", &opts, || {
        r = (r + 1) % rows;
        packed.dequantize_row(r, &mut out);
        black_box(&out);
    });
    println!("   {}", res.throughput_line("B", packed.bytes() as f64 / rows as f64));
    let mut acc = 0u32;
    bench("packed PackedMatrix::index, one row", &opts, || {
        r = (r + 1) % rows;
        for c in 0..cols {
            acc = acc.wrapping_add(packed.index(r, c) as u32);
        }
        black_box(acc);
    });

    // (benches/fused_dot.rs is the 100k x 64 acceptance bench on the
    // ShardedStore accounting path; this section exercises the raw
    // WeavedMatrix kernel on a wide 512-col store.)
    section("fused weaved-domain dot vs dequantize-then-dot (2048x512)");
    let mut rngx = Rng::new(9);
    let x: Vec<f32> = (0..cols).map(|_| rngx.normal()).collect();
    let mut k = StepKernel::new(cols);
    k.refresh(&scale.m, &x);
    let mut acc = 0.0f32;
    for p in [1u32, 2, 4, 8] {
        let deq = bench(&format!("dequantize+dot p={p}"), &opts, || {
            r = (r + 1) % rows;
            weaved.dequantize_row_at(r, p, &mut out);
            acc += zipml::tensor::dot(&out, &x);
            black_box(acc);
        });
        let fus = bench(&format!("fused dot_row   p={p}"), &opts, || {
            r = (r + 1) % rows;
            acc += kernel::dot_row(&weaved, r, p, &k);
            black_box(acc);
        });
        println!("   {}", zipml::bench::speedup_line(&format!("fused dot p={p}"), &deq, &fus));
    }

    section("ingestion: quantize + weave + shard (2048x512, 8-bit)");
    for (shards, threads, label) in
        [(1usize, 1usize, "single shard, 1 thread"), (16, 0, "16 shards, auto threads")]
    {
        bench(&format!("ingest {label}"), &opts, || {
            black_box(ShardedStore::ingest(&a, &scale, 8, 42, shards, threads));
        });
    }

    section("stored footprint");
    let store = ShardedStore::ingest(&a, &scale, 8, 42, 16, 0);
    println!(
        "  one weaved copy: {} B  (f32: {} B; per-width packed copies at 1/2/4/8 bits: {} B)",
        store.stored_bytes(),
        rows * cols * 4,
        (rows * cols) / 8 + (rows * cols) / 4 + (rows * cols) / 2 + rows * cols,
    );
    for p in [1u32, 2, 4, 8] {
        println!("  epoch bytes @p={p}: {:.3e}", store.epoch_bytes(p));
    }
}
