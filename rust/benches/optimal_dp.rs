//! Level-placement algorithm benchmarks (§3.1/§3.2/§I; Theorem 8's
//! near-linear claim): exact DP vs discretized DP vs ADAQUANT runtime, and
//! the resulting variance quality.
//! Run: cargo bench --bench optimal_dp [-- --quick]

use zipml::bench::{bench, black_box, section, BenchOpts};
use zipml::quant::{
    discretized_optimal_levels, greedy::adaquant_levels, optimal_levels, quantization_variance,
};
use zipml::rng::Rng;

fn main() {
    let opts = BenchOpts::from_env_and_args();
    let mut rng = Rng::new(2);
    let levels = 8;

    section("runtime scaling in N (k=8 levels)");
    for n in [500usize, 2000, 8000] {
        let pts: Vec<f32> = (0..n).map(|_| rng.f32().powi(2)).collect();
        if n <= 2000 {
            bench(&format!("exact_dp      N={n}"), &opts, || {
                black_box(optimal_levels(&pts, levels));
            });
        }
        bench(&format!("discretized   N={n} M=128"), &opts, || {
            black_box(discretized_optimal_levels(&pts, levels, 128));
        });
        bench(&format!("adaquant      N={n}"), &opts, || {
            black_box(adaquant_levels(&pts, levels));
        });
    }

    section("quality at N=4000 (mean variance, lower is better)");
    let pts: Vec<f32> = (0..4000)
        .map(|_| if rng.f32() < 0.75 { rng.normal() * 0.1 } else { rng.normal() * 0.5 + 2.0 })
        .collect();
    let lo = pts.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = pts.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let uniform: Vec<f32> = (0..levels).map(|i| lo + (hi - lo) * i as f32 / (levels - 1) as f32).collect();
    println!("  uniform      MV = {:.4e}", quantization_variance(&pts, &uniform));
    println!("  exact DP     MV = {:.4e}", quantization_variance(&pts, &optimal_levels(&pts, levels)));
    println!("  discretized  MV = {:.4e}", quantization_variance(&pts, &discretized_optimal_levels(&pts, levels, 128)));
    println!("  adaquant     MV = {:.4e}", quantization_variance(&pts, &adaquant_levels(&pts, levels)));
}
