//! Chebyshev machinery benchmarks (§4): fit/eval cost vs degree, and the
//! degree-accuracy tradeoff table behind the precision-variance discussion.
//! Run: cargo bench --bench cheby [-- --quick]

use zipml::bench::{bench, black_box, section, BenchOpts};
use zipml::cheby::{cheb_eval, cheb_fit, cheb_to_monomial, degree_for_eps_logistic, logistic_lprime};

fn main() {
    let opts = BenchOpts::from_env_and_args();

    section("fit + monomial conversion cost vs degree");
    for deg in [7usize, 15, 31] {
        bench(&format!("cheb_fit logistic deg={deg}"), &opts, || {
            black_box(cheb_fit(logistic_lprime, 8.0, deg));
        });
        let coefs = cheb_fit(logistic_lprime, 8.0, deg);
        bench(&format!("cheb_to_monomial deg={deg}"), &opts, || {
            black_box(cheb_to_monomial(&coefs, 8.0));
        });
    }

    section("Clenshaw evaluation throughput (deg 15)");
    let coefs = cheb_fit(logistic_lprime, 8.0, 15);
    let zs: Vec<f64> = (0..4096).map(|i| -8.0 + 16.0 * i as f64 / 4095.0).collect();
    let r = bench("cheb_eval x4096", &opts, || {
        let mut acc = 0.0;
        for &z in &zs {
            acc += cheb_eval(&coefs, 8.0, z);
        }
        black_box(acc);
    });
    println!("   {}", r.throughput_line("evals", 4096.0));

    section("degree needed for eps (Lemma 5's D(eps, l) empirically)");
    for eps in [1e-1f64, 1e-2, 1e-3, 1e-4] {
        match degree_for_eps_logistic(8.0, eps, 64) {
            Some(d) => println!("  eps={eps:.0e}  degree {d}"),
            None => println!("  eps={eps:.0e}  > 64"),
        }
    }
}
