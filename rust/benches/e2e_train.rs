//! End-to-end training epoch wallclock per mode — the whole-stack numbers
//! behind EXPERIMENTS.md §Perf. Requires `make artifacts`.
//! Run: cargo bench --bench e2e_train [-- --quick]

use zipml::bench::{bench, black_box, section, BenchOpts};
use zipml::data::synthetic::make_regression;
use zipml::runtime::Runtime;
use zipml::sgd::{self, Mode, ModelKind, TrainConfig};

fn main() {
    let opts = BenchOpts::from_env_and_args();
    let rt = Runtime::open_default().expect("run `make artifacts`");
    let ds = make_regression("bench100", 4096, 256, 100, 11);

    let mk = |mode: Mode| {
        let mut c = TrainConfig::new(ModelKind::Linreg, mode);
        c.epochs = 1;
        c.lr0 = 0.05;
        c.eval_batches = 1;
        c
    };

    section("one epoch (4096 samples, n=100, batch 64) per mode");
    for mode in [
        Mode::Full,
        Mode::Naive { bits: 4 },
        Mode::DoubleSample { bits: 4 },
        Mode::DoubleSampleU8 { bits: 4 },
        Mode::EndToEnd { bits_s: 5, bits_m: 8, bits_g: 8 },
        Mode::OptimalDs { levels: 16 },
    ] {
        let cfg = mk(mode);
        // warm compile cache
        let _ = sgd::train(&rt, &ds, &cfg).unwrap();
        bench(&format!("epoch {}", cfg.mode.label()), &opts, || {
            black_box(sgd::train(&rt, &ds, &cfg).unwrap());
        });
    }

    let st = rt.stats();
    println!(
        "\nruntime totals: {} executions, mean exec {:.1} µs",
        st.executions,
        st.exec_nanos as f64 / 1e3 / st.executions.max(1) as f64
    );
}
