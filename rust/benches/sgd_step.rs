//! PJRT step-dispatch latency per artifact kind — the L3↔runtime boundary
//! that dominates training wallclock (EXPERIMENTS.md §Perf).
//! Requires `make artifacts`. Run: cargo bench --bench sgd_step [-- --quick]

use zipml::bench::{bench, black_box, section, BenchOpts};
use zipml::rng::Rng;
use zipml::runtime::{lit_f32, lit_scalar11, lit_u8, Runtime};

fn main() {
    let opts = BenchOpts::from_env_and_args();
    let rt = Runtime::open_default().expect("run `make artifacts`");
    let mut rng = Rng::new(3);
    let b = 64usize;

    section("per-step execute latency (batch 64)");
    for n in [10usize, 100, 1000] {
        let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let a1: Vec<f32> = (0..b * n).map(|_| rng.normal()).collect();
        let a2: Vec<f32> = (0..b * n).map(|_| rng.normal()).collect();
        let bv: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let name = format!("linreg_ds_step_n{n}");
        // warm the compile cache outside the timer
        rt.load(&name).unwrap();
        bench(&format!("exec {name}"), &opts, || {
            let out = rt
                .exec1_f32(
                    &name,
                    &[
                        lit_f32(&[n, 1], &x).unwrap(),
                        lit_f32(&[b, n], &a1).unwrap(),
                        lit_f32(&[b, n], &a2).unwrap(),
                        lit_f32(&[b, 1], &bv).unwrap(),
                        lit_scalar11(0.05).unwrap(),
                    ],
                )
                .unwrap();
            black_box(out);
        });
    }

    section("u8 vs f32 operand upload (n=1000)");
    let n = 1000;
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let bv: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
    let a1: Vec<f32> = (0..b * n).map(|_| rng.normal()).collect();
    let a2 = a1.clone();
    let i1: Vec<u8> = (0..b * n).map(|_| rng.below(16) as u8).collect();
    let i2 = i1.clone();
    let m: Vec<f32> = (0..n).map(|_| 1.0).collect();
    rt.load("linreg_ds_step_n1000").unwrap();
    rt.load("linreg_ds_u8_step_n1000").unwrap();
    bench("f32 operands (256 KiB/step)", &opts, || {
        black_box(
            rt.exec1_f32(
                "linreg_ds_step_n1000",
                &[
                    lit_f32(&[n, 1], &x).unwrap(),
                    lit_f32(&[b, n], &a1).unwrap(),
                    lit_f32(&[b, n], &a2).unwrap(),
                    lit_f32(&[b, 1], &bv).unwrap(),
                    lit_scalar11(0.05).unwrap(),
                ],
            )
            .unwrap(),
        );
    });
    bench("u8 operands (64 KiB/step, dequant in-kernel)", &opts, || {
        black_box(
            rt.exec1_f32(
                "linreg_ds_u8_step_n1000",
                &[
                    lit_f32(&[n, 1], &x).unwrap(),
                    lit_u8(&[b, n], &i1).unwrap(),
                    lit_u8(&[b, n], &i2).unwrap(),
                    lit_f32(&[1, n], &m).unwrap(),
                    lit_scalar11(15.0).unwrap(),
                    lit_f32(&[b, 1], &bv).unwrap(),
                    lit_scalar11(0.05).unwrap(),
                ],
            )
            .unwrap(),
        );
    });

    section("per-step vs epoch-fused dispatch (n=100, 64 batches)");
    let n = 100;
    let nb = 64usize;
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let a_all: Vec<f32> = (0..nb * b * n).map(|_| rng.normal()).collect();
    let b_all: Vec<f32> = (0..nb * b).map(|_| rng.normal()).collect();
    rt.load("linreg_ds_step_n100").unwrap();
    rt.load("linreg_ds_epoch_n100").unwrap();
    bench("64 x linreg_ds_step_n100", &opts, || {
        let mut xc = x.clone();
        for i in 0..nb {
            let sl = &a_all[i * b * n..(i + 1) * b * n];
            let bl = &b_all[i * b..(i + 1) * b];
            xc = rt
                .exec1_f32(
                    "linreg_ds_step_n100",
                    &[
                        lit_f32(&[n, 1], &xc).unwrap(),
                        lit_f32(&[b, n], sl).unwrap(),
                        lit_f32(&[b, n], sl).unwrap(),
                        lit_f32(&[b, 1], bl).unwrap(),
                        lit_scalar11(0.05).unwrap(),
                    ],
                )
                .unwrap();
        }
        black_box(xc);
    });
    bench("1 x linreg_ds_epoch_n100 (scan-fused)", &opts, || {
        black_box(
            rt.exec1_f32(
                "linreg_ds_epoch_n100",
                &[
                    lit_f32(&[n, 1], &x).unwrap(),
                    lit_f32(&[nb, b, n], &a_all).unwrap(),
                    lit_f32(&[nb, b, n], &a_all).unwrap(),
                    lit_f32(&[nb, b, 1], &b_all).unwrap(),
                    lit_scalar11(0.05).unwrap(),
                ],
            )
            .unwrap(),
        );
    });
}
