//! Offline stand-in for the `xla` PJRT bindings (xla-rs API subset).
//!
//! The real crate wraps the XLA C++ client; it is not part of the offline
//! crate set, so this stub keeps the workspace building everywhere:
//!
//! * [`Literal`] is a fully functional host-side typed buffer — literal
//!   construction, extraction, and the tuple decomposition used by the
//!   runtime all work (and are unit-tested upstream).
//! * Device paths ([`PjRtClient::compile`], [`PjRtLoadedExecutable`]) fail
//!   with a descriptive [`Error`] — callers degrade gracefully exactly as
//!   they do when `make artifacts` has not been run (DESIGN.md §2).
//!
//! Swapping in the real bindings is a one-line Cargo change; no call site
//! needs to move.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring xla-rs: a message, convertible into `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes used by the ZipML artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U8,
}

impl ElementType {
    pub fn size_bytes(&self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::U8 => 1,
        }
    }
}

/// Host types that can live inside a [`Literal`].
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn read_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn read_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn read_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for u8 {
    const ELEMENT_TYPE: ElementType = ElementType::U8;
    fn read_le(bytes: &[u8]) -> Self {
        bytes[0]
    }
}

#[derive(Clone, Debug)]
enum Repr {
    Array { ty: ElementType, dims: Vec<usize>, data: Vec<u8> },
    Tuple(Vec<Literal>),
}

/// A typed host buffer (array literal) or a tuple of literals.
#[derive(Clone, Debug)]
pub struct Literal {
    repr: Repr,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Self> {
        let expected: usize = dims.iter().product::<usize>() * ty.size_bytes();
        if data.len() != expected {
            return Err(Error(format!(
                "literal shape {dims:?} of {ty:?} wants {expected} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal { repr: Repr::Array { ty, dims: dims.to_vec(), data: data.to_vec() } })
    }

    /// Build a tuple literal (what executable roots decompose from).
    pub fn tuple(elements: Vec<Literal>) -> Self {
        Literal { repr: Repr::Tuple(elements) }
    }

    pub fn element_type(&self) -> Result<ElementType> {
        match &self.repr {
            Repr::Array { ty, .. } => Ok(*ty),
            Repr::Tuple(_) => Err(Error("tuple literal has no element type".into())),
        }
    }

    pub fn shape(&self) -> Result<Vec<usize>> {
        match &self.repr {
            Repr::Array { dims, .. } => Ok(dims.clone()),
            Repr::Tuple(_) => Err(Error("tuple literal has no array shape".into())),
        }
    }

    /// Extract the elements as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match &self.repr {
            Repr::Array { ty, data, .. } => {
                if *ty != T::ELEMENT_TYPE {
                    return Err(Error(format!(
                        "literal holds {ty:?}, asked for {:?}",
                        T::ELEMENT_TYPE
                    )));
                }
                Ok(data.chunks_exact(ty.size_bytes()).map(T::read_le).collect())
            }
            Repr::Tuple(_) => Err(Error("cannot extract elements from a tuple literal".into())),
        }
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.repr {
            Repr::Tuple(elements) => Ok(elements),
            Repr::Array { .. } => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module text (held opaquely by the stub).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("reading HLO text {:?}: {e}", path.as_ref())))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _priv: () }
    }
}

/// The PJRT client. The stub constructs fine (cheap host object) but
/// refuses to compile: device execution needs the real bindings.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(
            "PJRT compilation unavailable: built against the offline stub `xla` crate \
             (swap rust/vendor/xla for the real xla-rs bindings to execute artifacts)"
                .into(),
        ))
    }
}

/// A compiled executable. Unconstructible through the stub (compile always
/// errors), but the full call surface typechecks.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error("PJRT execution unavailable in the offline stub".into()))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error("PJRT buffers unavailable in the offline stub".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes)
            .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data.to_vec());
        assert_eq!(lit.shape().unwrap(), vec![3]);
    }

    #[test]
    fn literal_rejects_bad_sizes_and_types() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4])
            .is_err());
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::U8, &[2], &[1u8, 2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<u8>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn tuple_decomposes() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::U8, &[1], &[7]).unwrap();
        let t = Literal::tuple(vec![a]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].to_vec::<u8>().unwrap(), vec![7]);
    }

    #[test]
    fn device_paths_error_descriptively() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto { text: String::new() });
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
