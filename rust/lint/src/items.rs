//! The item tree: a brace-matched, per-file model of the code the rules
//! reason about across files.
//!
//! Built over the scrubbed code lines (never comments or literals), the
//! model extracts:
//!
//! * fn items — name, body line span, visibility, enclosing impl
//!   type/trait, `#[deprecated]`, and whether the fn sits in test scope
//!   (`#[cfg(test)] mod` or a `#[test]`/`#[cfg(test)]` attribute);
//! * impl blocks — type name and optional trait name, generics stripped;
//! * inline `mod` scopes (with `#[cfg(test)]` detection) and `mod name;`
//!   declarations, mirroring the crate's module graph;
//! * call sites — `ident(` edges attributed to the innermost enclosing
//!   fn (name-based: the cross-file call graph joins edges by callee
//!   name, deliberately over-approximating — see DESIGN.md §13);
//! * match blocks with their top-level arm pattern texts.
//!
//! The parser is recovery-oriented: any construct it cannot interpret is
//! simply not an item. It never fails on weird-but-valid Rust; it only
//! has to be *consistent*, because every flow rule is fixture-pinned
//! against it.

use crate::scrub::{scrub, ScrubbedLine};

/// Keywords that look like `ident(` but are never call sites.
const KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "fn", "let", "else", "move", "in", "as",
    "ref", "mut", "box", "await", "yield", "unsafe",
];

/// One `fn` item with a resolved body span.
#[derive(Debug)]
pub struct FnItem {
    pub name: String,
    /// 0-based line of the `fn` keyword.
    pub line: usize,
    /// Body line span (open-brace line ..= close-brace line), 0-based.
    pub body: (usize, usize),
    /// Any `pub` / `pub(crate)` / `pub(super)` visibility.
    pub is_pub: bool,
    /// Enclosing `impl TYPE` type name, if any.
    pub impl_type: Option<String>,
    /// Enclosing `impl TRAIT for TYPE` trait name, if any.
    pub impl_trait: Option<String>,
    pub deprecated: bool,
    /// In a `#[cfg(test)] mod` or carrying `#[test]`/`#[cfg(test)]`.
    pub in_test: bool,
}

/// One `impl` block (inherent or trait) with its body span.
#[derive(Debug)]
pub struct ImplItem {
    pub type_name: Option<String>,
    pub trait_name: Option<String>,
    pub line: usize,
    pub body: (usize, usize),
}

/// One inline `mod name { … }` scope.
#[derive(Debug)]
pub struct ModScope {
    pub name: String,
    pub line: usize,
    pub is_test: bool,
    pub body: (usize, usize),
}

/// One `match` expression with its top-level arms.
#[derive(Debug)]
pub struct MatchBlock {
    /// 0-based line of the `match` keyword.
    pub line: usize,
    pub body: (usize, usize),
    /// Brace depth of the body's interior (arm level).
    pub depth: usize,
    /// (0-based line, pattern text before `=>`) per top-level arm.
    pub arms: Vec<(usize, String)>,
}

/// One `ident(` call site.
#[derive(Debug)]
pub struct CallSite {
    /// Index into [`FileModel::fns`] of the innermost enclosing fn.
    pub caller: Option<usize>,
    pub callee: String,
    /// 0-based line.
    pub line: usize,
}

#[derive(Debug, PartialEq, Eq, Clone)]
enum Tok {
    Ident(String),
    Num,
    Punct(char),
}

fn tokens(code: &str) -> Vec<Tok> {
    let b: Vec<char> = code.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let ch = b[i];
        if ch.is_alphabetic() || ch == '_' {
            let start = i;
            while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            out.push(Tok::Ident(b[start..i].iter().collect()));
        } else if ch.is_ascii_digit() {
            while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            out.push(Tok::Num);
        } else if ch == ' ' || ch == '\t' {
            i += 1;
        } else {
            out.push(Tok::Punct(ch));
            i += 1;
        }
    }
    out
}

/// Remove `<…>` spans from an impl-header token list (no shift operators
/// appear in impl headers, so plain depth counting is safe).
fn strip_generics(toks: &[Tok]) -> Vec<Tok> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    for t in toks {
        match t {
            Tok::Punct('<') => depth += 1,
            Tok::Punct('>') if depth > 0 => depth -= 1,
            _ if depth == 0 => out.push(t.clone()),
            _ => {}
        }
    }
    out
}

/// Last ident token's text (so `kernel::Foo` -> `Foo`), or None.
fn last_path_ident(toks: &[Tok]) -> Option<String> {
    toks.iter().rev().find_map(|t| match t {
        Tok::Ident(s) => Some(s.clone()),
        _ => None,
    })
}

fn is_ident(t: Option<&Tok>, name: &str) -> bool {
    matches!(t, Some(Tok::Ident(s)) if s == name)
}

fn is_punct(t: Option<&Tok>, c: char) -> bool {
    matches!(t, Some(Tok::Punct(p)) if *p == c)
}

/// Everything the flow rules need to know about one source file.
pub struct FileModel {
    pub rel_path: String,
    pub lines: Vec<ScrubbedLine>,
    pub fns: Vec<FnItem>,
    pub impls: Vec<ImplItem>,
    pub mods: Vec<ModScope>,
    /// (0-based line, name) per `mod name;` declaration.
    pub mod_decls: Vec<(usize, String)>,
    pub matches: Vec<MatchBlock>,
    pub calls: Vec<CallSite>,
}

enum OpenObj {
    Fn { f: FnItem },
    Impl { im: ImplItem },
    Mod { m: ModScope },
    Match { mb: MatchBlock },
    Brace,
}

struct Open {
    obj: OpenObj,
    open_depth: usize,
    open_line: usize,
}

impl FileModel {
    /// Parse one file into its item tree.
    pub fn build(rel_path: &str, src: &str) -> FileModel {
        let mut m = FileModel {
            rel_path: rel_path.to_string(),
            lines: scrub(src),
            fns: Vec::new(),
            impls: Vec::new(),
            mods: Vec::new(),
            mod_decls: Vec::new(),
            matches: Vec::new(),
            calls: Vec::new(),
        };
        m.parse();
        m
    }

    /// Index of the innermost fn whose body span contains `line`.
    pub fn fn_at(&self, line: usize) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (idx, f) in self.fns.iter().enumerate() {
            if f.body.0 <= line && line <= f.body.1 {
                let better = match best {
                    None => true,
                    Some(b) => f.body.0 > self.fns[b].body.0,
                };
                if better {
                    best = Some(idx);
                }
            }
        }
        best
    }

    /// Index of the innermost impl block containing `line`.
    pub fn impl_at(&self, line: usize) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (idx, im) in self.impls.iter().enumerate() {
            if im.body.0 <= line && line <= im.body.1 {
                let better = match best {
                    None => true,
                    Some(b) => im.body.0 > self.impls[b].body.0,
                };
                if better {
                    best = Some(idx);
                }
            }
        }
        best
    }

    /// Whether `line` sits inside test scope: a `#[cfg(test)] mod`, or a
    /// fn carrying `#[test]` / `#[cfg(test)]`.
    pub fn in_test_scope(&self, line: usize) -> bool {
        if self.mods.iter().any(|m| m.is_test && m.body.0 <= line && line <= m.body.1) {
            return true;
        }
        self.fn_at(line).is_some_and(|i| self.fns[i].in_test)
    }

    /// The scrubbed code of fn `idx`'s body, joined with newlines.
    pub fn body_code(&self, idx: usize) -> String {
        let (b0, b1) = self.fns[idx].body;
        self.lines[b0..=b1].iter().map(|l| l.code.as_str()).collect::<Vec<_>>().join("\n")
    }

    fn parse(&mut self) {
        let mut stack: Vec<Open> = Vec::new();
        let mut pend_matches: Vec<MatchBlock> = Vec::new();
        let mut pending: Option<OpenObj> = None;
        let mut pending_attrs: Vec<String> = Vec::new();
        let mut depth = 0usize;
        let mut paren = 0usize;

        let lines = std::mem::take(&mut self.lines);
        for (ln, sl) in lines.iter().enumerate() {
            let toks = tokens(&sl.code);
            let mut k = 0usize;
            while k < toks.len() {
                // attribute: `# [ … ]` — consume the bracket group
                if is_punct(toks.get(k), '#') && is_punct(toks.get(k + 1), '[') {
                    let mut bdepth = 0usize;
                    let mut j = k + 1;
                    let mut attr = String::new();
                    while j < toks.len() {
                        match &toks[j] {
                            Tok::Punct('[') => {
                                bdepth += 1;
                                if bdepth > 1 {
                                    attr.push('[');
                                }
                            }
                            Tok::Punct(']') => {
                                bdepth -= 1;
                                if bdepth == 0 {
                                    break;
                                }
                                attr.push(']');
                            }
                            Tok::Ident(s) => attr.push_str(s),
                            Tok::Num => attr.push('0'),
                            Tok::Punct(p) => attr.push(*p),
                        }
                        j += 1;
                    }
                    pending_attrs.push(attr);
                    k = j + 1;
                    continue;
                }

                match &toks[k] {
                    Tok::Ident(text) if text == "fn" && pending.is_none() => {
                        if let Some(Tok::Ident(name)) = toks.get(k + 1) {
                            let is_pub = toks[..k]
                                .iter()
                                .any(|t| matches!(t, Tok::Ident(s) if s == "pub"));
                            let deprecated =
                                pending_attrs.iter().any(|a| a.starts_with("deprecated"));
                            let in_test_attr = pending_attrs
                                .iter()
                                .any(|a| a == "test" || a.replace(' ', "").starts_with("cfg(test"));
                            pending = Some(OpenObj::Fn {
                                f: FnItem {
                                    name: name.clone(),
                                    line: ln,
                                    body: (0, 0),
                                    is_pub,
                                    impl_type: None,
                                    impl_trait: None,
                                    deprecated,
                                    in_test: in_test_attr,
                                },
                            });
                            pending_attrs.clear();
                        }
                        k += 2;
                    }
                    Tok::Ident(text) if text == "impl" && pending.is_none() && paren == 0 => {
                        let mut j = k + 1;
                        let mut header = Vec::new();
                        while j < toks.len()
                            && !is_punct(toks.get(j), '{')
                            && !is_punct(toks.get(j), ';')
                        {
                            header.push(toks[j].clone());
                            j += 1;
                        }
                        let ht = strip_generics(&header);
                        let fi = ht.iter().position(|t| matches!(t, Tok::Ident(s) if s == "for"));
                        let (trait_name, type_name) = match fi {
                            Some(fi) => (last_path_ident(&ht[..fi]), last_path_ident(&ht[fi + 1..])),
                            None => (None, last_path_ident(&ht)),
                        };
                        pending = Some(OpenObj::Impl {
                            im: ImplItem { type_name, trait_name, line: ln, body: (0, 0) },
                        });
                        pending_attrs.clear();
                        k = j;
                    }
                    Tok::Ident(text) if text == "mod" && pending.is_none() => {
                        if let Some(Tok::Ident(name)) = toks.get(k + 1) {
                            if is_punct(toks.get(k + 2), ';') {
                                self.mod_decls.push((ln, name.clone()));
                            } else {
                                let is_test = pending_attrs
                                    .iter()
                                    .any(|a| a.replace(' ', "").starts_with("cfg(test"));
                                pending = Some(OpenObj::Mod {
                                    m: ModScope {
                                        name: name.clone(),
                                        line: ln,
                                        is_test,
                                        body: (0, 0),
                                    },
                                });
                            }
                        }
                        pending_attrs.clear();
                        k += 2;
                    }
                    Tok::Ident(text) if text == "match" => {
                        pend_matches.push(MatchBlock {
                            line: ln,
                            body: (0, 0),
                            depth: 0,
                            arms: Vec::new(),
                        });
                        k += 1;
                    }
                    Tok::Ident(text) => {
                        // call site: ident followed by `(`, not a keyword,
                        // not a fn definition (macros never reach here:
                        // a macro ident is followed by `!`, not `(`).
                        // Caller attribution is a post-pass.
                        if !KEYWORDS.contains(&text.as_str())
                            && is_punct(toks.get(k + 1), '(')
                            && !(k > 0 && is_ident(toks.get(k - 1), "fn"))
                        {
                            self.calls.push(CallSite {
                                caller: None,
                                callee: text.clone(),
                                line: ln,
                            });
                        }
                        k += 1;
                    }
                    Tok::Punct('(') => {
                        paren += 1;
                        k += 1;
                    }
                    Tok::Punct(')') => {
                        paren = paren.saturating_sub(1);
                        k += 1;
                    }
                    Tok::Punct(';') => {
                        if paren == 0 && matches!(pending, Some(OpenObj::Fn { .. })) {
                            pending = None; // bodyless trait-method signature
                        }
                        if paren == 0 && pending.is_none() {
                            pending_attrs.clear();
                        }
                        k += 1;
                    }
                    Tok::Punct('{') => {
                        depth += 1;
                        if pending.is_some() && paren == 0 {
                            let obj = pending.take().expect("pending checked");
                            stack.push(Open { obj, open_depth: depth, open_line: ln });
                        } else if let Some(mut mb) = pend_matches.pop() {
                            mb.depth = depth;
                            stack.push(Open {
                                obj: OpenObj::Match { mb },
                                open_depth: depth,
                                open_line: ln,
                            });
                        } else {
                            stack.push(Open {
                                obj: OpenObj::Brace,
                                open_depth: depth,
                                open_line: ln,
                            });
                        }
                        k += 1;
                    }
                    Tok::Punct('}') => {
                        if stack.last().is_some_and(|e| e.open_depth == depth) {
                            let e = stack.pop().expect("non-empty checked");
                            let span = (e.open_line, ln);
                            match e.obj {
                                OpenObj::Fn { mut f } => {
                                    f.body = span;
                                    // in_test holds the attr flag here; the
                                    // mod-scope half is resolved post-pass
                                    self.fns.push(f);
                                }
                                OpenObj::Impl { mut im } => {
                                    im.body = span;
                                    self.impls.push(im);
                                }
                                OpenObj::Mod { mut m } => {
                                    m.body = span;
                                    self.mods.push(m);
                                }
                                OpenObj::Match { mut mb } => {
                                    mb.body = span;
                                    self.matches.push(mb);
                                }
                                OpenObj::Brace => {}
                            }
                        }
                        depth = depth.saturating_sub(1);
                        k += 1;
                    }
                    _ => k += 1,
                }
            }
        }
        self.lines = lines;

        // post-pass: impl attribution + test-scope resolution + callers
        for i in 0..self.fns.len() {
            if let Some(im) = self.impl_at(self.fns[i].line) {
                self.fns[i].impl_type = self.impls[im].type_name.clone();
                self.fns[i].impl_trait = self.impls[im].trait_name.clone();
            }
        }
        for i in 0..self.fns.len() {
            let line = self.fns[i].line;
            let in_test_mod = self
                .mods
                .iter()
                .any(|m| m.is_test && m.body.0 <= line && line <= m.body.1);
            self.fns[i].in_test = self.fns[i].in_test || in_test_mod;
        }
        for i in 0..self.calls.len() {
            self.calls[i].caller = self.fn_at(self.calls[i].line);
        }
        self.collect_arms();
    }

    fn collect_arms(&mut self) {
        // per-line start depth over the scrubbed code chars (the same
        // brace stream the parser counted)
        let mut depth = 0usize;
        let mut line_depth = Vec::with_capacity(self.lines.len());
        for sl in &self.lines {
            line_depth.push(depth);
            for ch in sl.code.chars() {
                match ch {
                    '{' => depth += 1,
                    '}' => depth = depth.saturating_sub(1),
                    _ => {}
                }
            }
        }
        for mb in &mut self.matches {
            let (b0, b1) = mb.body;
            let interior = mb.depth;
            for ln in b0..=b1 {
                let code: Vec<char> = self.lines[ln].code.chars().collect();
                if !self.lines[ln].code.contains("=>") {
                    continue;
                }
                let mut d = line_depth[ln];
                let mut seg_start = 0usize;
                let mut seen_arrow = false;
                let mut i = 0usize;
                while i < code.len() {
                    match code[i] {
                        '{' => d += 1,
                        '}' => {
                            d = d.saturating_sub(1);
                            // a `}` closing back to arm level ends a braced
                            // arm body — but only after its `=>` (a `}` in
                            // a struct PATTERN precedes the arrow and must
                            // not reset the segment)
                            if seen_arrow && d <= interior {
                                seg_start = i + 1;
                                seen_arrow = false;
                            }
                        }
                        ',' if d == interior && seen_arrow => {
                            seg_start = i + 1;
                            seen_arrow = false;
                        }
                        '=' if i + 1 < code.len() && code[i + 1] == '>' => {
                            if d == interior && !seen_arrow {
                                let mut pat: String =
                                    code[seg_start..i].iter().collect::<String>().trim().to_string();
                                if ln == b0 {
                                    // strip the `match EXPR {` head
                                    if let Some(brace) = pat.rfind('{') {
                                        pat = pat[brace + 1..].trim().to_string();
                                    }
                                }
                                mb.arms.push((ln, pat));
                                seen_arrow = true;
                            }
                            i += 2;
                            continue;
                        }
                        _ => {}
                    }
                    i += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fn_items_get_bodies_visibility_and_impl_scope() {
        let src = "\
impl ShardedStore {
    pub fn read_row(&self) -> u64 {
        self.inner()
    }
    fn inner(&self) -> u64 { 7 }
}
impl ThresholdSource for Rng {
    fn draw(&mut self) -> u64 { self.next_u64() }
}
";
        let m = FileModel::build("store/x.rs", src);
        assert_eq!(m.fns.len(), 3);
        let read = m.fns.iter().find(|f| f.name == "read_row").unwrap();
        assert!(read.is_pub);
        assert_eq!(read.impl_type.as_deref(), Some("ShardedStore"));
        assert_eq!(read.impl_trait, None);
        assert_eq!(read.body, (1, 3));
        let draw = m.fns.iter().find(|f| f.name == "draw").unwrap();
        assert_eq!(draw.impl_trait.as_deref(), Some("ThresholdSource"));
        assert_eq!(draw.impl_type.as_deref(), Some("Rng"));
    }

    #[test]
    fn impl_headers_strip_generics_and_paths() {
        let src = "impl<'a> ThresholdSource for BufferedThresholds<'_> {\n}\n\
                   impl kernel::StepKernel {\n}\n";
        let m = FileModel::build("x.rs", src);
        assert_eq!(m.impls[0].trait_name.as_deref(), Some("ThresholdSource"));
        assert_eq!(m.impls[0].type_name.as_deref(), Some("BufferedThresholds"));
        assert_eq!(m.impls[1].type_name.as_deref(), Some("StepKernel"));
        assert_eq!(m.impls[1].trait_name, None);
    }

    #[test]
    fn cfg_test_mod_and_test_attr_mark_test_scope() {
        let src = "\
fn prod() { helper() }
#[cfg(test)]
mod tests {
    fn in_mod() { helper() }
}
#[test]
fn unit() { helper() }
";
        let m = FileModel::build("x.rs", src);
        assert!(!m.fns.iter().find(|f| f.name == "prod").unwrap().in_test);
        assert!(m.fns.iter().find(|f| f.name == "in_mod").unwrap().in_test);
        assert!(m.fns.iter().find(|f| f.name == "unit").unwrap().in_test);
        assert!(m.mods[0].is_test);
    }

    #[test]
    fn deprecated_attr_is_detected() {
        let src = "#[deprecated(note = \"use run\")]\npub fn old_run() { run() }\n";
        let m = FileModel::build("x.rs", src);
        assert!(m.fns[0].deprecated);
    }

    #[test]
    fn calls_attach_to_innermost_fn_and_skip_macros() {
        let src = "\
fn outer() {
    helper(1);
    assert!(x);
    vec![helper2()];
}
";
        let m = FileModel::build("x.rs", src);
        let callees: Vec<&str> = m.calls.iter().map(|c| c.callee.as_str()).collect();
        assert!(callees.contains(&"helper"));
        assert!(callees.contains(&"helper2"));
        assert!(!callees.contains(&"assert"), "macro calls are not edges");
        for c in &m.calls {
            assert_eq!(c.caller, Some(0), "{}", c.callee);
        }
    }

    #[test]
    fn mod_decls_are_recorded() {
        let m = FileModel::build("lib.rs", "pub mod store;\nmod bench;\n");
        let names: Vec<&str> = m.mod_decls.iter().map(|(_, n)| n.as_str()).collect();
        assert_eq!(names, vec!["store", "bench"]);
    }

    #[test]
    fn match_arms_split_on_top_level_patterns() {
        let src = "\
fn f(m: ModelKind) -> f32 {
    match m {
        ModelKind::Lssvm { c } => *c,
        ModelKind::Linreg | ModelKind::Svm => 0.0,
        _ => 1.0,
    }
}
";
        let m = FileModel::build("x.rs", src);
        assert_eq!(m.matches.len(), 1);
        let pats: Vec<&str> = m.matches[0].arms.iter().map(|(_, p)| p.as_str()).collect();
        assert_eq!(pats, vec!["ModelKind::Lssvm { c }", "ModelKind::Linreg | ModelKind::Svm", "_"]);
    }

    #[test]
    fn single_line_match_keeps_struct_patterns_intact() {
        let src = "fn f() -> u32 { match k { ReadStrategy::Popcount { q } => q, _ => 1 } }\n";
        let m = FileModel::build("x.rs", src);
        let pats: Vec<&str> = m.matches[0].arms.iter().map(|(_, p)| p.as_str()).collect();
        assert_eq!(pats, vec!["ReadStrategy::Popcount { q }", "_"]);
    }

    #[test]
    fn nested_matches_do_not_leak_arms() {
        let src = "\
fn f(a: u32, b: u32) -> u32 {
    match a {
        0 => match b {
            1 => 10,
            _ => 20,
        },
        _ => 30,
    }
}
";
        let m = FileModel::build("x.rs", src);
        assert_eq!(m.matches.len(), 2);
        let outer = m.matches.iter().find(|mb| mb.line == 1).unwrap();
        // the outer match's arms are its own two, not the inner's
        assert_eq!(outer.arms.len(), 2);
    }

    #[test]
    fn matches_macro_is_not_a_match_block() {
        let src = "fn f(x: u32) -> bool { matches!(x, 1 | 2) }\n";
        let m = FileModel::build("x.rs", src);
        assert!(m.matches.is_empty());
    }
}
