//! The scrubber: split each source line into code text and comment text.
//!
//! Comments, string/char literals, and raw strings are blanked out of the
//! code channel so rule tokens inside them never match; comment text is
//! kept in its own channel because several rules *read* comments
//! (`// ordering:` contracts, `// twin:` contracts, `// lint: allow(…)`
//! suppressions, `DESIGN.md §N` references).

/// One source line after scrubbing: `code` with all comment bodies and
/// string/char-literal contents blanked, `comment` holding the line's
/// comment text (line comments and any block-comment content).
#[derive(Debug, Default, Clone)]
pub struct ScrubbedLine {
    pub code: String,
    pub comment: String,
}

#[derive(Clone, Copy)]
enum State {
    Code,
    /// Inside `/* */`, tracking nesting depth.
    Block(u32),
    /// Inside a `"…"` (or `b"…"`) string literal.
    Str,
    /// Inside a raw string; payload is the `#` count that closes it.
    RawStr(u32),
}

/// Scrub `src` into per-line code/comment records. Handles line and
/// nested block comments, string/byte-string literals, raw strings with
/// any hash count (`r"…"`, `r#"…"#`, `r##"…"##`, …), char literals, and
/// the char-vs-lifetime ambiguity.
pub fn scrub(src: &str) -> Vec<ScrubbedLine> {
    let c: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = ScrubbedLine::default();
    let mut state = State::Code;
    let mut i = 0;
    while i < c.len() {
        let ch = c[i];
        if ch == '\n' {
            lines.push(std::mem::take(&mut cur));
            // line comments end at the newline; block/string states span
            if !matches!(state, State::Block(_) | State::Str | State::RawStr(_)) {
                state = State::Code;
            }
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if ch == '/' && c.get(i + 1) == Some(&'/') {
                    // line comment: capture to end of line
                    i += 2;
                    while i < c.len() && c[i] != '\n' {
                        cur.comment.push(c[i]);
                        i += 1;
                    }
                } else if ch == '/' && c.get(i + 1) == Some(&'*') {
                    state = State::Block(1);
                    i += 2;
                } else if ch == '"' {
                    cur.code.push(' ');
                    state = State::Str;
                    i += 1;
                } else if (ch == 'r' || ch == 'b') && !prev_is_ident(&c, i) {
                    // r"…" / r#"…"# / b"…" / br#"…"# raw & byte strings
                    let mut j = i + 1;
                    if ch == 'b' && c.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    while c.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    let raw = j > i + 1 || (ch == 'r' && hashes == 0);
                    if c.get(j) == Some(&'"') && (raw || ch == 'b') {
                        cur.code.push(' ');
                        state = if ch == 'b' && hashes == 0 && j == i + 1 {
                            State::Str
                        } else {
                            State::RawStr(hashes)
                        };
                        i = j + 1;
                    } else {
                        cur.code.push(ch);
                        i += 1;
                    }
                } else if ch == '\'' {
                    // char literal vs lifetime: a backslash or a closing
                    // quote two chars on means char literal
                    if c.get(i + 1) == Some(&'\\') {
                        i += 2; // skip the escape head
                        while i < c.len() && c[i] != '\'' && c[i] != '\n' {
                            i += 1;
                        }
                        cur.code.push(' ');
                        i += 1; // past the closing quote
                    } else if c.get(i + 2) == Some(&'\'') {
                        cur.code.push(' ');
                        i += 3;
                    } else {
                        // lifetime: keep the tick so `'a` stays one token
                        cur.code.push('\'');
                        i += 1;
                    }
                } else {
                    cur.code.push(ch);
                    i += 1;
                }
            }
            State::Block(depth) => {
                if ch == '/' && c.get(i + 1) == Some(&'*') {
                    state = State::Block(depth + 1);
                    i += 2;
                } else if ch == '*' && c.get(i + 1) == Some(&'/') {
                    state = if depth == 1 { State::Code } else { State::Block(depth - 1) };
                    i += 2;
                } else {
                    cur.comment.push(ch);
                    i += 1;
                }
            }
            State::Str => {
                // an escape consumes the next char — except a newline
                // (the `\`-continuation), which must still count a line
                if ch == '\\' && c.get(i + 1).is_some_and(|&n| n != '\n') {
                    i += 2;
                } else if ch == '"' {
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if ch == '"' {
                    let close = (0..hashes as usize).all(|k| c.get(i + 1 + k) == Some(&'#'));
                    if close {
                        state = State::Code;
                        i += 1 + hashes as usize;
                        continue;
                    }
                }
                i += 1;
            }
        }
    }
    lines.push(cur);
    lines
}

fn prev_is_ident(c: &[char], i: usize) -> bool {
    i > 0 && (c[i - 1].is_alphanumeric() || c[i - 1] == '_')
}

/// Whether `tok` appears in `s` as a whole word (identifier boundaries
/// on both sides) — so `unsafe_code` never matches the token `unsafe`.
pub fn has_token(s: &str, tok: &str) -> bool {
    let sb = s.as_bytes();
    let mut from = 0;
    while let Some(pos) = s[from..].find(tok) {
        let start = from + pos;
        let end = start + tok.len();
        let ok_before =
            start == 0 || !(sb[start - 1].is_ascii_alphanumeric() || sb[start - 1] == b'_');
        let ok_after = end >= sb.len() || !(sb[end].is_ascii_alphanumeric() || sb[end] == b'_');
        if ok_before && ok_after {
            return true;
        }
        from = end;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_code_and_comments() {
        let s = scrub("let a = 1; // trailing note\n/* block\nstill block */ code()\n");
        assert_eq!(s[0].code.trim(), "let a = 1;");
        assert!(s[0].comment.contains("trailing note"));
        assert!(s[1].comment.contains("block"));
        assert!(s[1].code.trim().is_empty());
        assert_eq!(s[2].code.trim(), "code()");
    }

    #[test]
    fn blanks_strings_and_chars() {
        let s = scrub("let x = \"unsafe Instant\"; let c = 'u'; let l: &'a str = y;\n");
        assert!(!s[0].code.contains("unsafe"));
        assert!(!s[0].code.contains("Instant"));
        assert!(s[0].code.contains("&'a str"), "lifetimes survive: {}", s[0].code);
    }

    #[test]
    fn handles_raw_and_byte_strings() {
        let s = scrub("let r = r#\"Ordering:: \"quoted\" unsafe\"#; after()\nb\"bytes unsafe\";\n");
        assert!(!s[0].code.contains("unsafe"), "{:?}", s[0].code);
        assert!(s[0].code.contains("after()"));
        assert!(!s[1].code.contains("unsafe"), "{:?}", s[1].code);
    }

    #[test]
    fn handles_multi_hash_raw_strings() {
        // ≥2 hashes: the embedded `"#` must NOT close the literal; only
        // `"` followed by the full hash count does.
        let s = scrub("let r = r##\"unsafe Instant \"# still\"##; after()\n");
        assert!(!s[0].code.contains("unsafe"), "{:?}", s[0].code);
        assert!(!s[0].code.contains("still"), "{:?}", s[0].code);
        assert!(s[0].code.contains("after()"), "{:?}", s[0].code);
        let s = scrub("let r = r###\"x\"# y\"## z\"###; tail()\n");
        assert!(!s[0].code.contains('y'), "{:?}", s[0].code);
        assert!(!s[0].code.contains('z'), "{:?}", s[0].code);
        assert!(s[0].code.contains("tail()"), "{:?}", s[0].code);
    }

    #[test]
    fn multi_hash_raw_strings_span_lines() {
        let s = scrub("br##\"line1\nline2 unsafe \"# not yet\nend\"## code()\n");
        assert!(!s[1].code.contains("unsafe"), "{:?}", s[1].code);
        assert!(!s[1].code.contains("not yet"), "{:?}", s[1].code);
        assert_eq!(s[2].code.trim(), "code()");
    }

    #[test]
    fn escaped_quote_char_literal_does_not_derail() {
        let s = scrub("let q = '\\''; let x = \"unsafe\"; after()\n");
        assert!(!s[0].code.contains("unsafe"), "{:?}", s[0].code);
        assert!(s[0].code.contains("after()"), "{:?}", s[0].code);
    }

    #[test]
    fn token_matching_respects_word_boundaries() {
        assert!(has_token("unsafe {", "unsafe"));
        assert!(!has_token("#![forbid(unsafe_code)]", "unsafe"));
        assert!(!has_token("an_unsafe_name", "unsafe"));
        assert!(has_token("x(unsafe)", "unsafe"));
    }

    #[test]
    fn handles_nested_block_comments() {
        let s = scrub("/* a /* nested */ still comment */ let ok = 1;\n");
        assert_eq!(s[0].code.trim(), "let ok = 1;");
        assert!(s[0].comment.contains("nested"));
    }
}
