//! `zipml-lint` CLI: lint the crate's source tree against the ZipML
//! invariant rules (see the library docs / DESIGN.md §11).
//!
//! Usage: `zipml-lint [SRC_DIR [ALLOWLIST]]`
//!
//! With no arguments it lints the in-repo `rust/src/` with the in-repo
//! `rust/lint/allowlist_unsafe.txt`, so `cargo run -p zipml-lint` from
//! anywhere in the workspace is the whole invocation. Exit status is 1
//! if any diagnostic fires, 2 on I/O or usage errors, 0 on a clean tree.

#![forbid(unsafe_code)]

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "-h" || a == "--help") || args.len() > 2 {
        eprintln!("usage: zipml-lint [SRC_DIR [ALLOWLIST]]");
        eprintln!("  defaults: SRC_DIR = rust/src, ALLOWLIST = rust/lint/allowlist_unsafe.txt");
        return ExitCode::from(2);
    }
    // CARGO_MANIFEST_DIR is baked in at compile time, so the default
    // paths resolve no matter the invocation cwd.
    let manifest: PathBuf = env!("CARGO_MANIFEST_DIR").into();
    let src_root = args.first().map(PathBuf::from).unwrap_or_else(|| manifest.join("../src"));
    let allow_path = args
        .get(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| manifest.join("allowlist_unsafe.txt"));

    let allow_text = match std::fs::read_to_string(&allow_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("zipml-lint: cannot read allowlist {}: {e}", allow_path.display());
            return ExitCode::from(2);
        }
    };
    let allowlist = zipml_lint::parse_allowlist(&allow_text);

    match zipml_lint::lint_tree(&src_root, &allowlist) {
        Ok((files, diags)) if diags.is_empty() => {
            println!(
                "zipml-lint OK: {files} files, {} rules, 0 findings",
                zipml_lint::RULE_NAMES.len()
            );
            ExitCode::SUCCESS
        }
        Ok((_, diags)) => {
            for d in &diags {
                println!("{d}");
            }
            eprintln!("zipml-lint: {} finding(s)", diags.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("zipml-lint: cannot scan {}: {e}", src_root.display());
            ExitCode::from(2)
        }
    }
}
