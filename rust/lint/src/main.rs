//! `zipml-lint` CLI: lint the crate's source tree against the ZipML
//! invariant rules (see the library docs / DESIGN.md §11, §13).
//!
//! Usage: `zipml-lint [SRC_DIR [ALLOWLIST]] [FLAGS]`
//!
//! With no positional arguments it lints the in-repo `rust/src/` with
//! the in-repo `rust/lint/allowlist_unsafe.txt` AND the full cross-tree
//! config (repo `DESIGN.md`, `rust/tests/`), so
//! `cargo run -p zipml-lint` from anywhere in the workspace is the
//! whole twelve-rule invocation. An explicit SRC_DIR runs config-free
//! (fixture trees bring their own config via `--design`/`--tests`).
//!
//! Flags:
//!  - `--json`            print findings as JSONL to stdout (no prose)
//!  - `--json=FILE`       also write findings as JSONL to FILE
//!  - `--baseline=FILE`   diff mode: fail only on findings not in FILE
//!  - `--write-baseline=FILE`  write current findings to FILE, exit 0
//!  - `--design=FILE`     DESIGN.md to resolve `design-ref` against
//!  - `--tests=DIR`       tests root for `twin-contract-v2` existence
//!
//! Exit status: 1 if any (new, under `--baseline`) finding fires, 2 on
//! I/O or usage errors, 0 on a clean tree.

#![forbid(unsafe_code)]

use std::path::PathBuf;
use std::process::ExitCode;

use zipml_lint::{json, lint_tree_with, parse_allowlist, read_tree, LintConfig, RULE_NAMES};

struct Cli {
    src_root: PathBuf,
    allow_path: PathBuf,
    json_stdout: bool,
    json_file: Option<PathBuf>,
    baseline: Option<PathBuf>,
    write_baseline: Option<PathBuf>,
    design: Option<PathBuf>,
    tests: Option<PathBuf>,
}

fn usage() -> ExitCode {
    eprintln!(
        "usage: zipml-lint [SRC_DIR [ALLOWLIST]] [--json[=FILE]] [--baseline=FILE]\n\
         \x20                 [--write-baseline=FILE] [--design=FILE] [--tests=DIR]\n\
         \x20 defaults: SRC_DIR = rust/src, ALLOWLIST = rust/lint/allowlist_unsafe.txt;\n\
         \x20 with default SRC_DIR, --design/--tests default to the repo DESIGN.md and rust/tests"
    );
    ExitCode::from(2)
}

fn parse_cli(args: &[String]) -> Result<Cli, ()> {
    let mut pos: Vec<&String> = Vec::new();
    let mut cli = Cli {
        src_root: PathBuf::new(),
        allow_path: PathBuf::new(),
        json_stdout: false,
        json_file: None,
        baseline: None,
        write_baseline: None,
        design: None,
        tests: None,
    };
    for a in args {
        if a == "-h" || a == "--help" {
            return Err(());
        } else if a == "--json" {
            cli.json_stdout = true;
        } else if let Some(v) = a.strip_prefix("--json=") {
            cli.json_file = Some(PathBuf::from(v));
        } else if let Some(v) = a.strip_prefix("--baseline=") {
            cli.baseline = Some(PathBuf::from(v));
        } else if let Some(v) = a.strip_prefix("--write-baseline=") {
            cli.write_baseline = Some(PathBuf::from(v));
        } else if let Some(v) = a.strip_prefix("--design=") {
            cli.design = Some(PathBuf::from(v));
        } else if let Some(v) = a.strip_prefix("--tests=") {
            cli.tests = Some(PathBuf::from(v));
        } else if a.starts_with("--") {
            eprintln!("zipml-lint: unknown flag {a}");
            return Err(());
        } else {
            pos.push(a);
        }
    }
    if pos.len() > 2 {
        return Err(());
    }
    // CARGO_MANIFEST_DIR is baked in at compile time, so the default
    // paths resolve no matter the invocation cwd.
    let manifest: PathBuf = env!("CARGO_MANIFEST_DIR").into();
    let default_src = pos.is_empty();
    cli.src_root = pos.first().map(PathBuf::from).unwrap_or_else(|| manifest.join("../src"));
    cli.allow_path =
        pos.get(1).map(PathBuf::from).unwrap_or_else(|| manifest.join("allowlist_unsafe.txt"));
    if default_src {
        // the in-repo run gets the full cross-tree config by default
        if cli.design.is_none() {
            cli.design = Some(manifest.join("../../DESIGN.md"));
        }
        if cli.tests.is_none() {
            cli.tests = Some(manifest.join("../tests"));
        }
    }
    Ok(cli)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Ok(cli) = parse_cli(&args) else {
        return usage();
    };

    let allow_text = match std::fs::read_to_string(&cli.allow_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("zipml-lint: cannot read allowlist {}: {e}", cli.allow_path.display());
            return ExitCode::from(2);
        }
    };
    let allowlist = parse_allowlist(&allow_text);

    let design_text = match &cli.design {
        None => None,
        Some(p) => match std::fs::read_to_string(p) {
            Ok(t) => Some(t),
            Err(e) => {
                eprintln!("zipml-lint: cannot read design doc {}: {e}", p.display());
                return ExitCode::from(2);
            }
        },
    };
    let test_texts: Option<Vec<String>> = match &cli.tests {
        None => None,
        Some(p) => match read_tree(p) {
            Ok(files) => Some(files.into_iter().map(|(_rel, src)| src).collect()),
            Err(e) => {
                eprintln!("zipml-lint: cannot scan tests root {}: {e}", p.display());
                return ExitCode::from(2);
            }
        },
    };
    let cfg = LintConfig { design_text: design_text.as_deref(), test_texts: test_texts.as_deref() };

    let (files, diags) = match lint_tree_with(&cli.src_root, &allowlist, &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("zipml-lint: cannot scan {}: {e}", cli.src_root.display());
            return ExitCode::from(2);
        }
    };

    let rendered = json::render_findings(&diags);
    if let Some(p) = &cli.json_file {
        if let Err(e) = std::fs::write(p, &rendered) {
            eprintln!("zipml-lint: cannot write {}: {e}", p.display());
            return ExitCode::from(2);
        }
    }
    if let Some(p) = &cli.write_baseline {
        if let Err(e) = std::fs::write(p, &rendered) {
            eprintln!("zipml-lint: cannot write baseline {}: {e}", p.display());
            return ExitCode::from(2);
        }
        eprintln!(
            "zipml-lint: wrote baseline {} ({} finding(s))",
            p.display(),
            diags.len()
        );
        return ExitCode::SUCCESS;
    }
    if cli.json_stdout {
        print!("{rendered}");
    }

    // diff mode: only findings absent from the baseline fail the run
    if let Some(p) = &cli.baseline {
        let base_text = match std::fs::read_to_string(p) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("zipml-lint: cannot read baseline {}: {e}", p.display());
                return ExitCode::from(2);
            }
        };
        let baseline = match json::parse_findings(&base_text) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("zipml-lint: {e}");
                return ExitCode::from(2);
            }
        };
        let new = json::new_findings(&diags, &baseline);
        let stale = json::stale_entries(&diags, &baseline);
        if !cli.json_stdout {
            for d in &new {
                println!("{d}");
            }
        }
        for (path, line, rule) in &stale {
            eprintln!("zipml-lint: baseline entry burned down (tighten it): {path}:{line} [{rule}]");
        }
        return if new.is_empty() {
            if !cli.json_stdout {
                println!(
                    "zipml-lint OK: {files} files, {} rules, {} finding(s), 0 new vs baseline",
                    RULE_NAMES.len(),
                    diags.len()
                );
            }
            ExitCode::SUCCESS
        } else {
            eprintln!("zipml-lint: {} new finding(s) vs baseline", new.len());
            ExitCode::FAILURE
        };
    }

    if diags.is_empty() {
        if !cli.json_stdout {
            println!("zipml-lint OK: {files} files, {} rules, 0 findings", RULE_NAMES.len());
        }
        ExitCode::SUCCESS
    } else {
        if !cli.json_stdout {
            for d in &diags {
                println!("{d}");
            }
        }
        eprintln!("zipml-lint: {} finding(s)", diags.len());
        ExitCode::FAILURE
    }
}
