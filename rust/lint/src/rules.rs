//! The rules: six per-line rules (ported unchanged from v1) and six
//! cross-file flow rules over the [`crate::items::FileModel`] tree.
//!
//! Line rules see one scrubbed file at a time; flow rules see every
//! file's item tree at once plus optional cross-tree context (the
//! DESIGN.md section list, the test-fn name set under `rust/tests/`).
//! Each rule is individually fixture-pinned; every finding can be waived
//! in place with `// lint: allow(rule)` on the same or preceding line.

use std::collections::{BTreeMap, BTreeSet};

use crate::items::FileModel;
use crate::scrub::{has_token, ScrubbedLine};
use crate::Diagnostic;

/// Narrowing targets of the `byte-truncating-cast` rule: a byte total
/// cast to any of these can silently truncate or round (`u64`, `usize`
/// and `f64`→ reporting casts stay legal).
const NARROW_CASTS: &[&str] = &["u8", "u16", "u32", "i8", "i16", "i32", "f32"];

pub(crate) fn cast_to_narrow(code: &str) -> Option<&'static str> {
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(" as ") {
        let mut j = from + pos + 4;
        while j < b.len() && b[j] == b' ' {
            j += 1;
        }
        let start = j;
        while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
            j += 1;
        }
        let ty = &code[start..j];
        if let Some(&n) = NARROW_CASTS.iter().find(|&&n| n == ty) {
            return Some(n);
        }
        from += pos + 4;
    }
    None
}

/// Whether the scrubbed code mentions a byte-accounting identifier (any
/// identifier containing `bytes`, case-insensitive).
fn mentions_bytes_ident(code: &str) -> bool {
    code.to_ascii_lowercase().contains("bytes")
}

pub(crate) fn suppressed(lines: &[ScrubbedLine], i: usize, rule: &str) -> bool {
    let needle = format!("lint: allow({rule})");
    lines[i].comment.contains(&needle)
        || (i > 0 && lines[i - 1].comment.contains(&needle))
}

/// How many lines above an `Ordering::` use its `// ordering:` contract
/// comment may sit (inclusive; same-line comments always count).
const ORDERING_COMMENT_REACH: usize = 3;

fn has_ordering_contract(lines: &[ScrubbedLine], i: usize) -> bool {
    let lo = i.saturating_sub(ORDERING_COMMENT_REACH);
    lines[lo..=i].iter().any(|l| l.comment.contains("ordering:"))
}

/// How many lines above a `dispatch::tier` site its `// twin:` contract
/// comment may sit (same reach as the ordering rule).
const TWIN_COMMENT_REACH: usize = 3;

/// A complete twin contract names the scalar equivalent and, in parens,
/// the bit-equality test: `twin: scalar_name (test_name)`. Returns the
/// two halves; either empty means the contract is not actually stated.
pub(crate) fn twin_contract_parts(comment: &str) -> Option<(String, String)> {
    let rest = comment.split("twin:").nth(1)?;
    let open = rest.find('(')?;
    let close = rest[open + 1..].find(')')?;
    let scalar = rest[..open].trim();
    let test = rest[open + 1..open + 1 + close].trim();
    if scalar.is_empty() || test.is_empty() {
        return None;
    }
    Some((scalar.to_string(), test.to_string()))
}

fn has_twin_contract(lines: &[ScrubbedLine], i: usize) -> bool {
    let lo = i.saturating_sub(TWIN_COMMENT_REACH);
    lines[lo..=i].iter().any(|l| twin_contract_parts(&l.comment).is_some())
}

const MSG_UNSAFE: &str =
    "`unsafe` outside the allowlist (rust/lint/allowlist_unsafe.txt); the crate forbids unsafe";
const MSG_ORDERING: &str =
    "`Ordering::*` without an `// ordering:` comment on this line or the 3 above (DESIGN.md \u{a7}11)";
const MSG_WALL_CLOCK: &str =
    "wall-clock read outside telemetry//bench.rs; use telemetry::Stopwatch (determinism contract)";
const MSG_BYTE_CAST: &str =
    "byte-accounting expression narrowed with `as` can truncate; byte totals stay u64 end to end";
const MSG_HASH: &str =
    "HashMap/HashSet in a deterministic path (store/, sgd/, fpga/); use Vec or BTreeMap";
const MSG_JSON: &str =
    "second JSON emitter outside bench.rs; write through bench::JsonObj so escaping never drifts";
const MSG_TWIN_SITE: &str =
    "`dispatch::tier` site without a `// twin: scalar_name (bit_equality_test)` comment on this \
     line or the 3 above (DESIGN.md \u{a7}12)";
const MSG_ACCT: &str =
    "public store entry point reaches plane words without reaching a byte-accounting sink \
     (`note_row_visit` / shard byte cells); every read path tallies exactly once (DESIGN.md \u{a7}5/\u{a7}8)";
const MSG_RNG_SPAWN: &str =
    "`Rng::new` inside a thread-spawning fn; per-thread randomness derives through \
     `Rng::new_stream` so streams can never collide (DESIGN.md \u{a7}10)";
const MSG_RNG_THRESH: &str =
    "raw `.next_u64()` threshold draw in store/ outside an `impl ThresholdSource` block; \
     DS threshold randomness flows only through `ThresholdSource` (DESIGN.md \u{a7}5)";
const MSG_STRATEGY: &str =
    "wildcard `_` arm in a ReadStrategy/Execution/ModelKind match; enumerate the variants so a \
     new strategy can never silently fall back (error-never-fall-back contract)";

/// Lint one file's source text with the six line rules plus the
/// dispatch-site half of `twin-contract-v2`. `rel_path` is the
/// `/`-separated path relative to the scanned source root — the
/// path-scoped rules key off it. `unsafe_allowlist` holds rel paths
/// where `unsafe` is permitted.
pub fn line_rules(rel_path: &str, lines: &[ScrubbedLine], unsafe_allowlist: &[String]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let in_store = rel_path.starts_with("store/");
    let det_path = in_store || rel_path.starts_with("sgd/") || rel_path.starts_with("fpga/");
    let wall_exempt = rel_path.starts_with("telemetry/") || rel_path == "bench.rs";
    let json_exempt = rel_path == "bench.rs";
    let unsafe_allowed = unsafe_allowlist.iter().any(|p| p == rel_path);
    let mut diag = |i: usize, rule: &'static str, msg: &str| {
        out.push(Diagnostic {
            path: rel_path.to_string(),
            line: i + 1,
            rule,
            message: msg.to_string(),
        });
    };
    for (i, l) in lines.iter().enumerate() {
        let code = l.code.as_str();
        if !unsafe_allowed && has_token(code, "unsafe") && !suppressed(lines, i, "unsafe-code") {
            diag(i, "unsafe-code", MSG_UNSAFE);
        }
        if code.contains("Ordering::")
            && !has_ordering_contract(lines, i)
            && !suppressed(lines, i, "ordering-contract")
        {
            diag(i, "ordering-contract", MSG_ORDERING);
        }
        if !wall_exempt
            && (has_token(code, "Instant") || has_token(code, "SystemTime"))
            && !suppressed(lines, i, "wall-clock")
        {
            diag(i, "wall-clock", MSG_WALL_CLOCK);
        }
        if in_store && mentions_bytes_ident(code) {
            if let Some(ty) = cast_to_narrow(code) {
                if !suppressed(lines, i, "byte-truncating-cast") {
                    diag(i, "byte-truncating-cast", &format!("{MSG_BYTE_CAST} (`as {ty}`)"));
                }
            }
        }
        if det_path
            && (has_token(code, "HashMap") || has_token(code, "HashSet"))
            && !suppressed(lines, i, "hash-in-deterministic-path")
        {
            diag(i, "hash-in-deterministic-path", MSG_HASH);
        }
        if has_token(code, "dispatch::tier")
            && !has_twin_contract(lines, i)
            && !suppressed(lines, i, "twin-contract-v2")
        {
            diag(i, "twin-contract-v2", MSG_TWIN_SITE);
        }
        let json_def = code.contains("fn json_");
        if !json_exempt
            && (json_def || has_token(code, "json_escape") || has_token(code, "json_val"))
            && !suppressed(lines, i, "json-emitter")
        {
            diag(i, "json-emitter", MSG_JSON);
        }
    }
    out
}

/// Cross-tree context the flow rules may consult. Either half absent
/// means the rules needing it are skipped (fixture trees and plain
/// `zipml-lint SOME_DIR` runs stay self-contained).
#[derive(Default)]
pub struct FlowContext {
    /// `§N` numbers of real `## §N` sections in DESIGN.md, when known.
    pub design_sections: Option<BTreeSet<u32>>,
    /// Names of `fn`s found under the tests root, when known.
    pub test_fns: Option<BTreeSet<String>>,
}

/// Base fact for the accounting closure: the fn's body reads bit-plane
/// words directly.
fn touches_planes_base(m: &FileModel, idx: usize) -> bool {
    let code = m.body_code(idx);
    ["row_planes", "gather_word", "carry_mask_word", "row_plane_occ"]
        .iter()
        .any(|t| has_token(&code, t))
}

/// Base fact for the accounting closure: the fn's body accounts bytes —
/// it adds to the shard byte cells directly or calls an accounting sink.
fn accounts_base(m: &FileModel, idx: usize) -> bool {
    let code = m.body_code(idx);
    if has_token(&code, "shard_bytes") && has_token(&code, "fetch_add") {
        return true;
    }
    m.calls
        .iter()
        .any(|c| c.caller == Some(idx) && (c.callee == "note_row_visit" || c.callee == "account"))
}

/// Run the six flow rules over the whole file set.
pub fn flow_rules(models: &[FileModel], ctx: &FlowContext) -> Vec<Diagnostic> {
    let mut out = Vec::new();

    // ---- crate-wide fn table + name-based call edges ----
    // global fn id = (model idx, fn idx)
    let mut by_name: BTreeMap<&str, Vec<(usize, usize)>> = BTreeMap::new();
    for (mi, m) in models.iter().enumerate() {
        for (fi, f) in m.fns.iter().enumerate() {
            by_name.entry(f.name.as_str()).or_default().push((mi, fi));
        }
    }
    // reachability closure: flag(fn) = base(fn) || flag(any callee)
    let closure = |base: &dyn Fn(&FileModel, usize) -> bool| -> Vec<Vec<bool>> {
        let mut flag: Vec<Vec<bool>> = models
            .iter()
            .map(|m| (0..m.fns.len()).map(|fi| base(m, fi)).collect())
            .collect();
        let mut changed = true;
        while changed {
            changed = false;
            for (mi, m) in models.iter().enumerate() {
                for c in &m.calls {
                    let Some(fi) = c.caller else { continue };
                    if flag[mi][fi] {
                        continue;
                    }
                    let hit = by_name
                        .get(c.callee.as_str())
                        .is_some_and(|tgts| tgts.iter().any(|&(tm, tf)| flag[tm][tf]));
                    if hit {
                        flag[mi][fi] = true;
                        changed = true;
                    }
                }
            }
        }
        flag
    };
    let touches = closure(&touches_planes_base);
    let accounts = closure(&accounts_base);

    // accounting-flow: pub fns on *Store impls in store/ that reach
    // plane words must also reach an accounting sink
    for (mi, m) in models.iter().enumerate() {
        if !m.rel_path.starts_with("store/") {
            continue;
        }
        for (fi, f) in m.fns.iter().enumerate() {
            if !f.is_pub || f.in_test {
                continue;
            }
            if !f.impl_type.as_deref().is_some_and(|t| t.ends_with("Store")) {
                continue;
            }
            if touches[mi][fi] && !accounts[mi][fi] && !suppressed(&m.lines, f.line, "accounting-flow")
            {
                out.push(Diagnostic {
                    path: m.rel_path.clone(),
                    line: f.line + 1,
                    rule: "accounting-flow",
                    message: MSG_ACCT.to_string(),
                });
            }
        }
    }

    // rng-stream-discipline
    for m in models {
        for f in &m.fns {
            if f.in_test {
                continue;
            }
            let (b0, b1) = f.body;
            let code: String =
                m.lines[b0..=b1].iter().map(|l| l.code.as_str()).collect::<Vec<_>>().join("\n");
            if !has_token(&code, "spawn") {
                continue;
            }
            for i in b0..=b1 {
                let flat: String = m.lines[i].code.chars().filter(|c| *c != ' ').collect();
                if flat.contains("Rng::new(")
                    && !m.in_test_scope(i)
                    && !suppressed(&m.lines, i, "rng-stream-discipline")
                {
                    out.push(Diagnostic {
                        path: m.rel_path.clone(),
                        line: i + 1,
                        rule: "rng-stream-discipline",
                        message: MSG_RNG_SPAWN.to_string(),
                    });
                }
            }
        }
        if m.rel_path.starts_with("store/") {
            for (i, l) in m.lines.iter().enumerate() {
                let flat: String = l.code.chars().filter(|c| *c != ' ').collect();
                if !flat.contains(".next_u64(") || m.in_test_scope(i) {
                    continue;
                }
                let in_threshold_impl = m
                    .impl_at(i)
                    .is_some_and(|im| m.impls[im].trait_name.as_deref() == Some("ThresholdSource"));
                if !in_threshold_impl && !suppressed(&m.lines, i, "rng-stream-discipline") {
                    out.push(Diagnostic {
                        path: m.rel_path.clone(),
                        line: i + 1,
                        rule: "rng-stream-discipline",
                        message: MSG_RNG_THRESH.to_string(),
                    });
                }
            }
        }
    }

    // strategy-matrix-exhaustiveness
    for m in models {
        for mb in &m.matches {
            if m.in_test_scope(mb.line) {
                continue;
            }
            let strategic = mb.arms.iter().any(|(_, pat)| {
                ["ReadStrategy::", "Execution::", "ModelKind::"].iter().any(|e| pat.contains(e))
            });
            if !strategic {
                continue;
            }
            for (ln, pat) in &mb.arms {
                if pat == "_" || pat.starts_with("_ if") || pat.starts_with("_if") {
                    if !suppressed(&m.lines, *ln, "strategy-matrix-exhaustiveness") {
                        out.push(Diagnostic {
                            path: m.rel_path.clone(),
                            line: ln + 1,
                            rule: "strategy-matrix-exhaustiveness",
                            message: MSG_STRATEGY.to_string(),
                        });
                    }
                }
            }
        }
    }

    // design-ref: every `DESIGN.md §N` in a comment resolves to a real
    // `## §N` section (skipped when no DESIGN.md was configured)
    if let Some(sections) = &ctx.design_sections {
        for m in models {
            for (i, l) in m.lines.iter().enumerate() {
                if !l.comment.contains("DESIGN.md") {
                    continue;
                }
                for n in section_refs(&l.comment) {
                    if !sections.contains(&n) && !suppressed(&m.lines, i, "design-ref") {
                        out.push(Diagnostic {
                            path: m.rel_path.clone(),
                            line: i + 1,
                            rule: "design-ref",
                            message: format!(
                                "comment references DESIGN.md \u{a7}{n}, but DESIGN.md has no \
                                 `## \u{a7}{n}` section (stale after a renumbering?)"
                            ),
                        });
                    }
                }
            }
        }
    }

    // twin-contract-v2 (cross-file half): the test named by the twin
    // comment attached to each dispatch site must exist under the tests
    // root. Only comments in a site's reach window bind — stray doc
    // examples elsewhere are not contracts.
    if let Some(test_fns) = &ctx.test_fns {
        for m in models {
            for (i, l) in m.lines.iter().enumerate() {
                if !has_token(&l.code, "dispatch::tier") {
                    continue;
                }
                let lo = i.saturating_sub(TWIN_COMMENT_REACH);
                for j in lo..=i {
                    let Some((_, test)) = twin_contract_parts(&m.lines[j].comment) else {
                        continue;
                    };
                    if !test_fns.contains(&test) && !suppressed(&m.lines, j, "twin-contract-v2") {
                        out.push(Diagnostic {
                            path: m.rel_path.clone(),
                            line: j + 1,
                            rule: "twin-contract-v2",
                            message: format!(
                                "twin contract names test `{test}`, which does not exist under \
                                 the tests root (rust/tests/)"
                            ),
                        });
                    }
                }
            }
        }
    }

    // deprecated-no-internal-callers
    let deprecated: BTreeSet<&str> = models
        .iter()
        .flat_map(|m| m.fns.iter().filter(|f| f.deprecated).map(|f| f.name.as_str()))
        .collect();
    for m in models {
        for c in &m.calls {
            if !deprecated.contains(c.callee.as_str()) || m.in_test_scope(c.line) {
                continue;
            }
            if c.caller.is_some_and(|fi| m.fns[fi].deprecated) {
                continue;
            }
            if !suppressed(&m.lines, c.line, "deprecated-no-internal-callers") {
                out.push(Diagnostic {
                    path: m.rel_path.clone(),
                    line: c.line + 1,
                    rule: "deprecated-no-internal-callers",
                    message: format!(
                        "internal caller of `#[deprecated]` `{}`; deprecated entry points keep \
                         exactly zero in-crate callers so they can be dropped on schedule",
                        c.callee
                    ),
                });
            }
        }
    }
    out
}

/// All `§N` numbers in a comment (design-ref scans comments that mention
/// `DESIGN.md`; every section number on such a line must resolve).
fn section_refs(comment: &str) -> Vec<u32> {
    let mut out = Vec::new();
    let chars: Vec<char> = comment.chars().collect();
    let mut i = 0;
    while i < chars.len() {
        if chars[i] == '\u{a7}' {
            let mut j = i + 1;
            let mut n = 0u32;
            let mut any = false;
            while j < chars.len() && chars[j].is_ascii_digit() {
                n = n.saturating_mul(10) + (chars[j] as u32 - '0' as u32);
                any = true;
                j += 1;
            }
            if any {
                out.push(n);
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

/// Parse DESIGN.md text into its `## §N` section-number set. The digits
/// must end at a word boundary (`## §5x` is not section 5).
pub fn design_sections(text: &str) -> BTreeSet<u32> {
    let mut out = BTreeSet::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("## \u{a7}") {
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            let boundary = match rest[digits.len()..].chars().next() {
                None => true,
                Some(c) => !(c.is_alphanumeric() || c == '_'),
            };
            if !digits.is_empty() && boundary {
                if let Ok(n) = digits.parse() {
                    out.insert(n);
                }
            }
        }
    }
    out
}

/// Collect every `fn NAME` in the given file texts (scrubbed first, so
/// strings and comments never contribute names).
pub fn test_fn_names(texts: &[String]) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for t in texts {
        for sl in crate::scrub::scrub(t) {
            let b = sl.code.as_bytes();
            let mut from = 0;
            while let Some(pos) = sl.code[from..].find("fn") {
                let start = from + pos;
                from = start + 2;
                let ok_before = start == 0
                    || !(b[start - 1].is_ascii_alphanumeric() || b[start - 1] == b'_');
                if !ok_before {
                    continue;
                }
                // at least one whitespace char, then the name
                let mut j = start + 2;
                let ws_start = j;
                while j < b.len() && (b[j] == b' ' || b[j] == b'\t') {
                    j += 1;
                }
                if j == ws_start {
                    continue;
                }
                let name_start = j;
                while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                    j += 1;
                }
                if j > name_start && !b[name_start].is_ascii_digit() {
                    out.insert(sl.code[name_start..j].to_string());
                }
            }
        }
    }
    out
}
