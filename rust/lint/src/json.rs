//! JSON findings: render and baseline-diff.
//!
//! The linter is a JSON *consumer* of the main crate, not a second
//! emitter: every line it writes goes through [`zipml::bench::JsonObj`]
//! (the repo's single JSON writer — the very invariant the
//! `json-emitter` rule guards) and every baseline line it reads goes
//! through [`zipml::telemetry::trace::parse_line`]. Findings render as
//! JSONL, one flat object per finding:
//!
//! ```text
//! {"path":"store/shard.rs","line":106,"rule":"rng-stream-discipline","message":"..."}
//! ```
//!
//! A committed baseline (`LINT_baseline.json`) plus `--baseline` diff
//! mode lets CI fail only on findings *not* present in the baseline, so
//! a new rule can land before the last legacy finding is burned down.

use std::collections::BTreeSet;

use zipml::bench::{JsonObj, JsonVal};
use zipml::telemetry::trace::{field, parse_line};

use crate::Diagnostic;

/// Identity of a finding for baseline matching: (path, line, rule).
/// Messages stay out of the key so rewording a message never churns
/// the baseline.
pub type FindingKey = (String, u64, String);

/// Render one finding as a single JSON line (no trailing newline).
pub fn finding_line(d: &Diagnostic) -> String {
    let mut o = JsonObj::with_capacity(96);
    o.field_str("path", &d.path);
    // UInt, not Num: line numbers must render as integers, byte for byte
    o.field("line", &JsonVal::UInt(d.line as u64));
    o.field_str("rule", d.rule);
    o.field_str("message", &d.message);
    o.finish()
}

/// Render the full findings list as JSONL (one finding per line, with a
/// trailing newline when non-empty; the empty list renders as the empty
/// string so an all-clean `LINT_findings.json` is a zero-byte artifact).
pub fn render_findings(diags: &[Diagnostic]) -> String {
    let mut out = String::new();
    for d in diags {
        out.push_str(&finding_line(d));
        out.push('\n');
    }
    out
}

/// Parse a findings/baseline JSONL file back into finding keys. Blank
/// lines are skipped; any malformed line is a hard error (a corrupt
/// baseline must never silently waive findings).
pub fn parse_findings(text: &str) -> Result<BTreeSet<FindingKey>, String> {
    let mut out = BTreeSet::new();
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let obj = parse_line(line).map_err(|e| format!("baseline line {}: {e}", ln + 1))?;
        let path = field(&obj, "path")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("baseline line {}: missing `path`", ln + 1))?;
        let lno = field(&obj, "line")
            .and_then(|v| v.as_num())
            .ok_or_else(|| format!("baseline line {}: missing `line`", ln + 1))?;
        let rule = field(&obj, "rule")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("baseline line {}: missing `rule`", ln + 1))?;
        out.insert((path.to_string(), lno as u64, rule.to_string()));
    }
    Ok(out)
}

/// Findings not covered by the baseline — the only ones diff mode fails
/// on. Baseline entries with no matching finding are fine (burned-down
/// debt); CI prints them as a hint to re-tighten the baseline.
pub fn new_findings<'a>(
    diags: &'a [Diagnostic],
    baseline: &BTreeSet<FindingKey>,
) -> Vec<&'a Diagnostic> {
    diags
        .iter()
        .filter(|d| {
            !baseline.contains(&(d.path.clone(), d.line as u64, d.rule.to_string()))
        })
        .collect()
}

/// Baseline keys whose finding no longer fires (stale debt entries).
pub fn stale_entries(diags: &[Diagnostic], baseline: &BTreeSet<FindingKey>) -> Vec<FindingKey> {
    let current: BTreeSet<FindingKey> = diags
        .iter()
        .map(|d| (d.path.clone(), d.line as u64, d.rule.to_string()))
        .collect();
    baseline.iter().filter(|k| !current.contains(*k)).cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag(path: &str, line: usize, rule: &'static str, msg: &str) -> Diagnostic {
        Diagnostic { path: path.to_string(), line, rule, message: msg.to_string() }
    }

    #[test]
    fn finding_renders_exact_bytes() {
        let d = diag("store/shard.rs", 106, "rng-stream-discipline", "raw \"draw\"");
        assert_eq!(
            finding_line(&d),
            "{\"path\":\"store/shard.rs\",\"line\":106,\"rule\":\"rng-stream-discipline\",\
             \"message\":\"raw \\\"draw\\\"\"}"
        );
    }

    #[test]
    fn empty_findings_render_empty() {
        assert_eq!(render_findings(&[]), "");
    }

    #[test]
    fn findings_round_trip_through_the_trace_parser() {
        let diags = vec![
            diag("a.rs", 3, "unsafe-code", "m1"),
            diag("b.rs", 9, "wall-clock", "m2 \\ \"q\""),
        ];
        let keys = parse_findings(&render_findings(&diags)).unwrap();
        assert_eq!(keys.len(), 2);
        assert!(keys.contains(&("a.rs".to_string(), 3, "unsafe-code".to_string())));
        assert!(keys.contains(&("b.rs".to_string(), 9, "wall-clock".to_string())));
    }

    #[test]
    fn diff_fails_only_on_new_findings() {
        let old = diag("a.rs", 3, "unsafe-code", "msg wording may change");
        let baseline = parse_findings(&render_findings(&[old])).unwrap();
        let now = vec![
            diag("a.rs", 3, "unsafe-code", "reworded message, same finding"),
            diag("c.rs", 7, "design-ref", "new"),
        ];
        let new = new_findings(&now, &baseline);
        assert_eq!(new.len(), 1);
        assert_eq!(new[0].path, "c.rs");
        assert!(stale_entries(&now, &baseline).is_empty());
    }

    #[test]
    fn burned_down_entries_surface_as_stale() {
        let baseline =
            parse_findings(&render_findings(&[diag("gone.rs", 1, "wall-clock", "x")])).unwrap();
        let stale = stale_entries(&[], &baseline);
        assert_eq!(stale, vec![("gone.rs".to_string(), 1, "wall-clock".to_string())]);
    }

    #[test]
    fn malformed_baseline_is_a_hard_error() {
        assert!(parse_findings("{\"path\":\"a.rs\"}\n").is_err());
        assert!(parse_findings("not json\n").is_err());
    }
}
