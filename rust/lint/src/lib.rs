//! `zipml-lint` — repo-native static analysis for the ZipML invariants
//! (DESIGN.md §11).
//!
//! The crate's correctness story leans on contracts that rustc cannot
//! see: the exact-byte accounting (DESIGN.md §5/§8), the fixed-seed
//! determinism contract (§10), and the relaxed-ordering protocols the
//! loom models check. This linter machine-checks the *textual* side of
//! those contracts as named, individually-testable rules over
//! `rust/src/`:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `unsafe-code` | no `unsafe` outside `allowlist_unsafe.txt` |
//! | `ordering-contract` | every `Ordering::*` use carries an `// ordering:` comment (same line or ≤ 3 lines above) |
//! | `wall-clock` | no `Instant`/`SystemTime` outside `telemetry/` and `bench.rs` |
//! | `byte-truncating-cast` | in `store/`: no `as`-narrowing casts on byte-accounting expressions |
//! | `hash-in-deterministic-path` | no `HashMap`/`HashSet` in `store/`, `sgd/`, `fpga/` |
//! | `json-emitter` | no JSON writer outside `bench.rs` (`json_escape`/`json_val` calls, `fn json_*` definitions) |
//! | `simd-twin-contract` | every `dispatch::tier` dispatch site carries a `// twin: scalar_name (bit_equality_test)` comment |
//!
//! The scanner is line/token-level (like the repo's serde-free JSON
//! code, deliberately not a full parser): comments, string/char
//! literals, and raw strings are scrubbed first so tokens inside them
//! never match. A finding can be waived in place with
//! `// lint: allow(rule-name)` on the same or the preceding line —
//! greppable, narrow, and reviewed like any other diff line.

#![forbid(unsafe_code)]

use std::fmt;
use std::path::Path;

/// Every rule this linter knows, in diagnostic order.
pub const RULE_NAMES: &[&str] = &[
    "unsafe-code",
    "ordering-contract",
    "wall-clock",
    "byte-truncating-cast",
    "hash-in-deterministic-path",
    "json-emitter",
    "simd-twin-contract",
];

/// One finding: `path:line: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Path relative to the scanned source root, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// The rule name (one of [`RULE_NAMES`]).
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

// ---------------------------------------------------------------------------
// The scrubber: split each source line into code text and comment text
// ---------------------------------------------------------------------------

/// One source line after scrubbing: `code` with all comment bodies and
/// string/char-literal contents blanked, `comment` holding the line's
/// comment text (line comments and any block-comment content).
#[derive(Debug, Default, Clone)]
pub struct ScrubbedLine {
    pub code: String,
    pub comment: String,
}

#[derive(Clone, Copy)]
enum State {
    Code,
    /// Inside `/* */`, tracking nesting depth.
    Block(u32),
    /// Inside a `"…"` (or `b"…"`) string literal.
    Str,
    /// Inside a raw string; payload is the `#` count that closes it.
    RawStr(u32),
}

/// Scrub `src` into per-line code/comment records. Handles line and
/// nested block comments, string/byte-string literals, raw strings
/// (`r#"…"#`), char literals, and the char-vs-lifetime ambiguity.
pub fn scrub(src: &str) -> Vec<ScrubbedLine> {
    let c: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = ScrubbedLine::default();
    let mut state = State::Code;
    let mut i = 0;
    while i < c.len() {
        let ch = c[i];
        if ch == '\n' {
            lines.push(std::mem::take(&mut cur));
            // line comments end at the newline; block/string states span
            if !matches!(state, State::Block(_) | State::Str | State::RawStr(_)) {
                state = State::Code;
            }
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if ch == '/' && c.get(i + 1) == Some(&'/') {
                    // line comment: capture to end of line
                    i += 2;
                    while i < c.len() && c[i] != '\n' {
                        cur.comment.push(c[i]);
                        i += 1;
                    }
                } else if ch == '/' && c.get(i + 1) == Some(&'*') {
                    state = State::Block(1);
                    i += 2;
                } else if ch == '"' {
                    cur.code.push(' ');
                    state = State::Str;
                    i += 1;
                } else if (ch == 'r' || ch == 'b') && !prev_is_ident(&c, i) {
                    // r"…" / r#"…"# / b"…" / br#"…"# raw & byte strings
                    let mut j = i + 1;
                    if ch == 'b' && c.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    while c.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    let raw = j > i + 1 || (ch == 'r' && hashes == 0);
                    if c.get(j) == Some(&'"') && (raw || ch == 'b') {
                        cur.code.push(' ');
                        state = if ch == 'b' && hashes == 0 && j == i + 1 {
                            State::Str
                        } else {
                            State::RawStr(hashes)
                        };
                        i = j + 1;
                    } else {
                        cur.code.push(ch);
                        i += 1;
                    }
                } else if ch == '\'' {
                    // char literal vs lifetime: a backslash or a closing
                    // quote two chars on means char literal
                    if c.get(i + 1) == Some(&'\\') {
                        i += 2; // skip the escape head
                        while i < c.len() && c[i] != '\'' && c[i] != '\n' {
                            i += 1;
                        }
                        cur.code.push(' ');
                        i += 1; // past the closing quote
                    } else if c.get(i + 2) == Some(&'\'') {
                        cur.code.push(' ');
                        i += 3;
                    } else {
                        // lifetime: keep the tick so `'a` stays one token
                        cur.code.push('\'');
                        i += 1;
                    }
                } else {
                    cur.code.push(ch);
                    i += 1;
                }
            }
            State::Block(depth) => {
                if ch == '/' && c.get(i + 1) == Some(&'*') {
                    state = State::Block(depth + 1);
                    i += 2;
                } else if ch == '*' && c.get(i + 1) == Some(&'/') {
                    state = if depth == 1 { State::Code } else { State::Block(depth - 1) };
                    i += 2;
                } else {
                    cur.comment.push(ch);
                    i += 1;
                }
            }
            State::Str => {
                // an escape consumes the next char — except a newline
                // (the `\`-continuation), which must still count a line
                if ch == '\\' && c.get(i + 1).is_some_and(|&n| n != '\n') {
                    i += 2;
                } else if ch == '"' {
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if ch == '"' {
                    let close = (0..hashes as usize).all(|k| c.get(i + 1 + k) == Some(&'#'));
                    if close {
                        state = State::Code;
                        i += 1 + hashes as usize;
                        continue;
                    }
                }
                i += 1;
            }
        }
    }
    lines.push(cur);
    lines
}

fn prev_is_ident(c: &[char], i: usize) -> bool {
    i > 0 && (c[i - 1].is_alphanumeric() || c[i - 1] == '_')
}

/// Whether `tok` appears in `s` as a whole word (identifier boundaries
/// on both sides) — so `unsafe_code` never matches the token `unsafe`.
pub fn has_token(s: &str, tok: &str) -> bool {
    let sb = s.as_bytes();
    let mut from = 0;
    while let Some(pos) = s[from..].find(tok) {
        let start = from + pos;
        let end = start + tok.len();
        let ok_before =
            start == 0 || !(sb[start - 1].is_ascii_alphanumeric() || sb[start - 1] == b'_');
        let ok_after = end >= sb.len() || !(sb[end].is_ascii_alphanumeric() || sb[end] == b'_');
        if ok_before && ok_after {
            return true;
        }
        from = end;
    }
    false
}

// ---------------------------------------------------------------------------
// The rules
// ---------------------------------------------------------------------------

/// Narrowing targets of the `byte-truncating-cast` rule: a byte total
/// cast to any of these can silently truncate or round (`u64`, `usize`
/// and `f64`→ reporting casts stay legal).
const NARROW_CASTS: &[&str] = &["u8", "u16", "u32", "i8", "i16", "i32", "f32"];

fn cast_to_narrow(code: &str) -> Option<&'static str> {
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(" as ") {
        let mut j = from + pos + 4;
        while j < b.len() && b[j] == b' ' {
            j += 1;
        }
        let start = j;
        while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
            j += 1;
        }
        let ty = &code[start..j];
        if let Some(&n) = NARROW_CASTS.iter().find(|&&n| n == ty) {
            return Some(n);
        }
        from += pos + 4;
    }
    None
}

/// Whether the scrubbed code mentions a byte-accounting identifier (any
/// identifier containing `bytes`, case-insensitive).
fn mentions_bytes_ident(code: &str) -> bool {
    code.to_ascii_lowercase().contains("bytes")
}

fn suppressed(lines: &[ScrubbedLine], i: usize, rule: &str) -> bool {
    let needle = format!("lint: allow({rule})");
    lines[i].comment.contains(&needle)
        || (i > 0 && lines[i - 1].comment.contains(&needle))
}

/// How many lines above an `Ordering::` use its `// ordering:` contract
/// comment may sit (inclusive; same-line comments always count).
const ORDERING_COMMENT_REACH: usize = 3;

fn has_ordering_contract(lines: &[ScrubbedLine], i: usize) -> bool {
    let lo = i.saturating_sub(ORDERING_COMMENT_REACH);
    lines[lo..=i].iter().any(|l| l.comment.contains("ordering:"))
}

/// How many lines above a `dispatch::tier` site its `// twin:` contract
/// comment may sit (same reach as the ordering rule).
const SIMD_TWIN_COMMENT_REACH: usize = 3;

/// A complete twin contract names the scalar equivalent and, in parens,
/// the bit-equality test: `twin: scalar_name (test_name)`. Either half
/// empty means the contract is not actually stated.
fn twin_contract_complete(comment: &str) -> bool {
    let Some(rest) = comment.split("twin:").nth(1) else {
        return false;
    };
    let Some(open) = rest.find('(') else {
        return false;
    };
    let Some(close) = rest[open + 1..].find(')') else {
        return false;
    };
    let scalar = rest[..open].trim();
    let test = rest[open + 1..open + 1 + close].trim();
    !scalar.is_empty() && !test.is_empty()
}

fn has_twin_contract(lines: &[ScrubbedLine], i: usize) -> bool {
    let lo = i.saturating_sub(SIMD_TWIN_COMMENT_REACH);
    lines[lo..=i].iter().any(|l| twin_contract_complete(&l.comment))
}

const MSG_UNSAFE: &str =
    "`unsafe` outside the allowlist (rust/lint/allowlist_unsafe.txt); the crate forbids unsafe";
const MSG_ORDERING: &str =
    "`Ordering::*` without an `// ordering:` comment on this line or the 3 above (DESIGN.md \u{a7}11)";
const MSG_WALL_CLOCK: &str =
    "wall-clock read outside telemetry//bench.rs; use telemetry::Stopwatch (determinism contract)";
const MSG_BYTE_CAST: &str =
    "byte-accounting expression narrowed with `as` can truncate; byte totals stay u64 end to end";
const MSG_HASH: &str =
    "HashMap/HashSet in a deterministic path (store/, sgd/, fpga/); use Vec or BTreeMap";
const MSG_JSON: &str =
    "second JSON emitter outside bench.rs; write through bench::JsonObj so escaping never drifts";
const MSG_SIMD_TWIN: &str =
    "`dispatch::tier` site without a `// twin: scalar_name (bit_equality_test)` comment on this \
     line or the 3 above (DESIGN.md \u{a7}12)";

/// Lint one file's source text. `rel_path` is the `/`-separated path
/// relative to the scanned source root — the path-scoped rules key off
/// it. `unsafe_allowlist` holds rel paths where `unsafe` is permitted.
pub fn lint_source(rel_path: &str, src: &str, unsafe_allowlist: &[String]) -> Vec<Diagnostic> {
    let lines = scrub(src);
    let mut out = Vec::new();
    let in_store = rel_path.starts_with("store/");
    let det_path = in_store || rel_path.starts_with("sgd/") || rel_path.starts_with("fpga/");
    let wall_exempt = rel_path.starts_with("telemetry/") || rel_path == "bench.rs";
    let json_exempt = rel_path == "bench.rs";
    let unsafe_allowed = unsafe_allowlist.iter().any(|p| p == rel_path);
    let mut diag = |i: usize, rule: &'static str, msg: &str| {
        out.push(Diagnostic {
            path: rel_path.to_string(),
            line: i + 1,
            rule,
            message: msg.to_string(),
        });
    };
    for (i, l) in lines.iter().enumerate() {
        let code = l.code.as_str();
        if !unsafe_allowed && has_token(code, "unsafe") && !suppressed(&lines, i, "unsafe-code") {
            diag(i, "unsafe-code", MSG_UNSAFE);
        }
        if code.contains("Ordering::")
            && !has_ordering_contract(&lines, i)
            && !suppressed(&lines, i, "ordering-contract")
        {
            diag(i, "ordering-contract", MSG_ORDERING);
        }
        if !wall_exempt
            && (has_token(code, "Instant") || has_token(code, "SystemTime"))
            && !suppressed(&lines, i, "wall-clock")
        {
            diag(i, "wall-clock", MSG_WALL_CLOCK);
        }
        if in_store && mentions_bytes_ident(code) {
            if let Some(ty) = cast_to_narrow(code) {
                if !suppressed(&lines, i, "byte-truncating-cast") {
                    diag(i, "byte-truncating-cast", &format!("{MSG_BYTE_CAST} (`as {ty}`)"));
                }
            }
        }
        if det_path
            && (has_token(code, "HashMap") || has_token(code, "HashSet"))
            && !suppressed(&lines, i, "hash-in-deterministic-path")
        {
            diag(i, "hash-in-deterministic-path", MSG_HASH);
        }
        if has_token(code, "dispatch::tier")
            && !has_twin_contract(&lines, i)
            && !suppressed(&lines, i, "simd-twin-contract")
        {
            diag(i, "simd-twin-contract", MSG_SIMD_TWIN);
        }
        let json_def = code.contains("fn json_");
        if !json_exempt
            && (json_def || has_token(code, "json_escape") || has_token(code, "json_val"))
            && !suppressed(&lines, i, "json-emitter")
        {
            diag(i, "json-emitter", MSG_JSON);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Tree walking
// ---------------------------------------------------------------------------

/// Parse `allowlist_unsafe.txt` content: one rel path per line, `#`
/// comments and blank lines ignored.
pub fn parse_allowlist(text: &str) -> Vec<String> {
    text.lines()
        .map(|l| l.split('#').next().unwrap_or("").trim())
        .filter(|l| !l.is_empty())
        .map(|l| l.to_string())
        .collect()
}

fn walk(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let p = entry?.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `src_root`, in sorted path order (so
/// diagnostics are deterministic). Returns (files scanned, findings).
pub fn lint_tree(
    src_root: &Path,
    unsafe_allowlist: &[String],
) -> std::io::Result<(usize, Vec<Diagnostic>)> {
    let mut files = Vec::new();
    walk(src_root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in &files {
        let rel = f
            .strip_prefix(src_root)
            .expect("walked under root")
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(f)?;
        out.extend(lint_source(&rel, &src, unsafe_allowlist));
    }
    Ok((files.len(), out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(rel: &str, src: &str) -> Vec<(&'static str, usize)> {
        lint_source(rel, src, &[]).into_iter().map(|d| (d.rule, d.line)).collect()
    }

    #[test]
    fn scrubber_separates_code_and_comments() {
        let s = scrub("let a = 1; // trailing note\n/* block\nstill block */ code()\n");
        assert_eq!(s[0].code.trim(), "let a = 1;");
        assert!(s[0].comment.contains("trailing note"));
        assert!(s[1].comment.contains("block"));
        assert!(s[1].code.trim().is_empty());
        assert_eq!(s[2].code.trim(), "code()");
    }

    #[test]
    fn scrubber_blanks_strings_and_chars() {
        let s = scrub("let x = \"unsafe Instant\"; let c = 'u'; let l: &'a str = y;\n");
        assert!(!s[0].code.contains("unsafe"));
        assert!(!s[0].code.contains("Instant"));
        assert!(s[0].code.contains("&'a str"), "lifetimes survive: {}", s[0].code);
    }

    #[test]
    fn scrubber_handles_raw_and_byte_strings() {
        let s = scrub("let r = r#\"Ordering:: \"quoted\" unsafe\"#; after()\nb\"bytes unsafe\";\n");
        assert!(!s[0].code.contains("unsafe"), "{:?}", s[0].code);
        assert!(s[0].code.contains("after()"));
        assert!(!s[1].code.contains("unsafe"), "{:?}", s[1].code);
    }

    #[test]
    fn scrubber_handles_nested_block_comments() {
        let s = scrub("/* a /* nested */ still comment */ let ok = 1;\n");
        assert_eq!(s[0].code.trim(), "let ok = 1;");
        assert!(s[0].comment.contains("nested"));
    }

    #[test]
    fn token_matching_respects_word_boundaries() {
        assert!(has_token("unsafe {", "unsafe"));
        assert!(!has_token("#![forbid(unsafe_code)]", "unsafe"));
        assert!(!has_token("an_unsafe_name", "unsafe"));
        assert!(has_token("x(unsafe)", "unsafe"));
    }

    #[test]
    fn rule_unsafe_code_fires_and_respects_allowlist() {
        let src = "fn f() { unsafe { g() } }\n";
        assert_eq!(rules_hit("a.rs", src), vec![("unsafe-code", 1)]);
        let allow = vec!["a.rs".to_string()];
        assert!(lint_source("a.rs", src, &allow).is_empty());
    }

    #[test]
    fn rule_ordering_contract_checks_comment_reach() {
        let bad = "a.load(Ordering::Relaxed);\n";
        assert_eq!(rules_hit("a.rs", bad), vec![("ordering-contract", 1)]);
        let same_line = "a.load(Ordering::Relaxed); // ordering: relaxed — test\n";
        assert!(rules_hit("a.rs", same_line).is_empty());
        let above = "// ordering: relaxed — contract\n\n\na.load(Ordering::Relaxed);\n";
        assert!(rules_hit("a.rs", above).is_empty(), "3 lines above is in reach");
        let too_far = "// ordering: relaxed\n\n\n\na.load(Ordering::Relaxed);\n";
        assert_eq!(rules_hit("a.rs", too_far), vec![("ordering-contract", 5)]);
    }

    #[test]
    fn rule_wall_clock_exempts_telemetry_and_bench() {
        let src = "let t = Instant::now();\n";
        assert_eq!(rules_hit("sgd/host.rs", src), vec![("wall-clock", 1)]);
        assert_eq!(rules_hit("x.rs", "SystemTime::now();\n"), vec![("wall-clock", 1)]);
        assert!(rules_hit("telemetry/clock.rs", src).is_empty());
        assert!(rules_hit("bench.rs", src).is_empty());
    }

    #[test]
    fn rule_byte_cast_only_narrowing_only_store() {
        let bad = "let b = total_bytes as u32;\n";
        assert_eq!(rules_hit("store/shard.rs", bad), vec![("byte-truncating-cast", 1)]);
        assert!(rules_hit("sgd/host.rs", bad).is_empty(), "scoped to store/");
        assert!(rules_hit("store/shard.rs", "let b = n_bytes as u64;\n").is_empty());
        assert!(rules_hit("store/shard.rs", "let r = rows as u32;\n").is_empty());
    }

    #[test]
    fn rule_hash_scoped_to_deterministic_paths() {
        let src = "use std::collections::HashMap;\n";
        for p in ["store/a.rs", "sgd/a.rs", "fpga/a.rs"] {
            assert_eq!(rules_hit(p, src), vec![("hash-in-deterministic-path", 1)], "{p}");
        }
        assert!(rules_hit("runtime/mod.rs", src).is_empty());
        assert_eq!(
            rules_hit("sgd/a.rs", "let s: HashSet<u32> = x;\n"),
            vec![("hash-in-deterministic-path", 1)]
        );
    }

    #[test]
    fn rule_json_emitter_fires_on_calls_and_defs() {
        assert_eq!(rules_hit("a.rs", "json_escape(s, &mut out);\n"), vec![("json-emitter", 1)]);
        assert_eq!(rules_hit("a.rs", "fn json_write(x: &str) {}\n"), vec![("json-emitter", 1)]);
        assert!(rules_hit("bench.rs", "json_val(v, &mut out);\n").is_empty());
        assert!(rules_hit("a.rs", "let json_value = parse();\n").is_empty(), "other idents ok");
    }

    #[test]
    fn rule_simd_twin_contract_requires_named_twin_and_test() {
        let bad = "if dispatch::tier() == dispatch::Tier::Lanes8 { return simd::f(x); }\n";
        assert_eq!(rules_hit("store/kernel.rs", bad), vec![("simd-twin-contract", 1)]);
        let good = "// twin: f_scalar (simd_f_bit_identical_to_scalar)\n\
                    if dispatch::tier() == dispatch::Tier::Lanes8 { return simd::f(x); }\n";
        assert!(rules_hit("store/kernel.rs", good).is_empty());
        let same_line =
            "if dispatch::tier() == t { f() } // twin: f_scalar (simd_f_bit_identical_to_scalar)\n";
        assert!(rules_hit("a.rs", same_line).is_empty());
        let empty_scalar = "// twin: (some_test) — scalar half missing\n\
                           if dispatch::tier() == t { f() }\n";
        assert_eq!(rules_hit("a.rs", empty_scalar), vec![("simd-twin-contract", 2)]);
        let no_test = "// twin: f_scalar\nif dispatch::tier() == t { f() }\n";
        assert_eq!(rules_hit("a.rs", no_test), vec![("simd-twin-contract", 2)]);
        assert!(
            rules_hit("a.rs", "let l = dispatch::tier_label();\n").is_empty(),
            "label reads are not dispatch sites"
        );
        assert!(rules_hit("a.rs", "let t = dispatch::Tier::Scalar;\n").is_empty());
    }

    #[test]
    fn inline_suppression_waives_same_and_next_line() {
        let same = "let t = Instant::now(); // lint: allow(wall-clock) — fixture\n";
        assert!(rules_hit("a.rs", same).is_empty());
        let above = "// lint: allow(wall-clock) timing demo\nlet t = Instant::now();\n";
        assert!(rules_hit("a.rs", above).is_empty());
        let wrong_rule = "// lint: allow(unsafe-code)\nlet t = Instant::now();\n";
        assert_eq!(rules_hit("a.rs", wrong_rule), vec![("wall-clock", 2)]);
    }

    #[test]
    fn tokens_inside_literals_never_fire() {
        let src = "let m = \"contains unsafe and Instant and HashMap\";\n";
        assert!(rules_hit("sgd/a.rs", src).is_empty());
        let doc = "/// docs may say unsafe, Instant, HashMap, json_escape\nlet ok = 1;\n";
        assert!(rules_hit("sgd/a.rs", doc).is_empty());
    }

    #[test]
    fn allowlist_parser_strips_comments() {
        let txt = "# header\n\nruntime/literal.rs  # historical\n";
        assert_eq!(parse_allowlist(txt), vec!["runtime/literal.rs".to_string()]);
        assert!(parse_allowlist("# only comments\n").is_empty());
    }

    #[test]
    fn diagnostic_renders_file_line_rule() {
        let d = Diagnostic {
            path: "store/shard.rs".into(),
            line: 7,
            rule: "byte-truncating-cast",
            message: "m".into(),
        };
        assert_eq!(d.to_string(), "store/shard.rs:7: [byte-truncating-cast] m");
    }
}
