//! `zipml-lint` — repo-native static analysis for the ZipML invariants
//! (DESIGN.md §11, §13).
//!
//! The crate's correctness story leans on contracts that rustc cannot
//! see: the exact-byte accounting (DESIGN.md §5/§8), the fixed-seed
//! determinism contract (§10), and the relaxed-ordering protocols the
//! loom models check. v1 of this linter machine-checked the *textual*
//! side of those contracts with per-line rules; v2 adds a symbol layer
//! ([`items::FileModel`]: fn items, impl blocks, mod scopes, match
//! arms, call-site edges) so rules can follow a contract *across*
//! functions and files. Two rule families:
//!
//! **Line rules** (one scrubbed file at a time):
//!
//! | rule | invariant |
//! |------|-----------|
//! | `unsafe-code` | no `unsafe` outside `allowlist_unsafe.txt` |
//! | `ordering-contract` | every `Ordering::*` use carries an `// ordering:` comment (same line or ≤ 3 lines above) |
//! | `wall-clock` | no `Instant`/`SystemTime` outside `telemetry/` and `bench.rs` |
//! | `byte-truncating-cast` | in `store/`: no `as`-narrowing casts on byte-accounting expressions |
//! | `hash-in-deterministic-path` | no `HashMap`/`HashSet` in `store/`, `sgd/`, `fpga/` |
//! | `json-emitter` | no JSON writer outside `bench.rs` (`json_escape`/`json_val` calls, `fn json_*` definitions) |
//!
//! **Flow rules** (the whole crate model at once; see DESIGN.md §13):
//!
//! | rule | invariant |
//! |------|-----------|
//! | `twin-contract-v2` | every `dispatch::tier` site carries a `// twin: scalar_name (bit_equality_test)` comment, and the named test exists under the tests root |
//! | `accounting-flow` | every public `*Store` entry point in `store/` that reaches bit-plane words also reaches a byte-accounting sink (call-graph reachability) |
//! | `rng-stream-discipline` | no `Rng::new` inside thread-spawning fns (streams derive via `new_stream`); store DS threshold draws only inside `impl ThresholdSource` |
//! | `strategy-matrix-exhaustiveness` | no `_` arm in matches over `ReadStrategy`/`Execution`/`ModelKind` |
//! | `design-ref` | every `DESIGN.md §N` comment reference resolves to a real `## §N` section |
//! | `deprecated-no-internal-callers` | `#[deprecated]` fns keep zero non-test in-crate callers |
//!
//! The scanner stays deliberately lexical (no rustc, no syn): the
//! scrubber blanks comments/strings so tokens inside them never match,
//! and the item tree is brace-matched and recovery-oriented — anything
//! it cannot interpret is simply not an item. A finding can be waived
//! in place with `// lint: allow(rule-name)` on the same or the
//! preceding line — greppable, narrow, and reviewed like any other
//! diff line. Findings render as JSONL through the main crate's
//! [`zipml::bench::JsonObj`] (see [`json`]) and diff against a
//! committed baseline so CI fails only on *new* findings.

#![forbid(unsafe_code)]

pub mod items;
pub mod json;
pub mod rules;
pub mod scrub;

pub use scrub::{has_token, scrub, ScrubbedLine};

use std::fmt;
use std::path::Path;

use items::FileModel;
use rules::FlowContext;

/// Every rule this linter knows, in diagnostic order.
pub const RULE_NAMES: &[&str] = &[
    "unsafe-code",
    "ordering-contract",
    "wall-clock",
    "byte-truncating-cast",
    "hash-in-deterministic-path",
    "json-emitter",
    "twin-contract-v2",
    "accounting-flow",
    "rng-stream-discipline",
    "strategy-matrix-exhaustiveness",
    "design-ref",
    "deprecated-no-internal-callers",
];

/// One finding: `path:line: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Path relative to the scanned source root, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// The rule name (one of [`RULE_NAMES`]).
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// Cross-tree inputs for the config-gated flow rules. `design_text`
/// absent skips `design-ref`; `test_texts` absent skips the cross-file
/// (test-existence) half of `twin-contract-v2`. The other flow rules
/// always run — they need nothing beyond the source tree itself.
#[derive(Default)]
pub struct LintConfig<'a> {
    /// Full DESIGN.md text (its `## §N` headers define the section set).
    pub design_text: Option<&'a str>,
    /// Contents of every file under the tests root (`rust/tests/`).
    pub test_texts: Option<&'a [String]>,
}

/// Lint one file's source text with the line rules only. `rel_path` is
/// the `/`-separated path relative to the scanned source root — the
/// path-scoped rules key off it. `unsafe_allowlist` holds rel paths
/// where `unsafe` is permitted. (Flow rules need the whole tree; use
/// [`lint_files`] or [`lint_tree`].)
pub fn lint_source(rel_path: &str, src: &str, unsafe_allowlist: &[String]) -> Vec<Diagnostic> {
    let lines = scrub(src);
    rules::line_rules(rel_path, &lines, unsafe_allowlist)
}

/// Lint a set of in-memory files — the core engine under [`lint_tree`].
/// `files` holds (rel_path, source) pairs; they are modeled in sorted
/// path order and checked with every line rule plus every flow rule the
/// config enables. Diagnostics come back sorted by (path, line, rule).
pub fn lint_files(
    files: &[(String, String)],
    unsafe_allowlist: &[String],
    cfg: &LintConfig,
) -> Vec<Diagnostic> {
    let mut sorted: Vec<&(String, String)> = files.iter().collect();
    sorted.sort_by(|a, b| a.0.cmp(&b.0));
    let models: Vec<FileModel> =
        sorted.iter().map(|(rel, src)| FileModel::build(rel, src)).collect();
    let mut out = Vec::new();
    for m in &models {
        out.extend(rules::line_rules(&m.rel_path, &m.lines, unsafe_allowlist));
    }
    let ctx = FlowContext {
        design_sections: cfg.design_text.map(rules::design_sections),
        test_fns: cfg.test_texts.map(rules::test_fn_names),
    };
    out.extend(rules::flow_rules(&models, &ctx));
    out.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    out
}

// ---------------------------------------------------------------------------
// Tree walking
// ---------------------------------------------------------------------------

/// Parse `allowlist_unsafe.txt` content: one rel path per line, `#`
/// comments and blank lines ignored.
pub fn parse_allowlist(text: &str) -> Vec<String> {
    text.lines()
        .map(|l| l.split('#').next().unwrap_or("").trim())
        .filter(|l| !l.is_empty())
        .map(|l| l.to_string())
        .collect()
}

fn walk(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let p = entry?.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Read every `.rs` file under `root` into (rel_path, source) pairs,
/// sorted by rel path.
pub fn read_tree(root: &Path) -> std::io::Result<Vec<(String, String)>> {
    let mut files = Vec::new();
    walk(root, &mut files)?;
    files.sort();
    let mut out = Vec::with_capacity(files.len());
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .expect("walked under root")
            .to_string_lossy()
            .replace('\\', "/");
        out.push((rel, std::fs::read_to_string(f)?));
    }
    Ok(out)
}

/// Lint every `.rs` file under `src_root` with the line rules and the
/// config-free flow rules (deterministic sorted order). Returns
/// (files scanned, findings). For `design-ref` and the test-existence
/// half of `twin-contract-v2`, use [`lint_tree_with`].
pub fn lint_tree(
    src_root: &Path,
    unsafe_allowlist: &[String],
) -> std::io::Result<(usize, Vec<Diagnostic>)> {
    lint_tree_with(src_root, unsafe_allowlist, &LintConfig::default())
}

/// [`lint_tree`] plus cross-tree config (DESIGN.md text, tests-root
/// file contents) enabling all twelve rules.
pub fn lint_tree_with(
    src_root: &Path,
    unsafe_allowlist: &[String],
    cfg: &LintConfig,
) -> std::io::Result<(usize, Vec<Diagnostic>)> {
    let files = read_tree(src_root)?;
    let out = lint_files(&files, unsafe_allowlist, cfg);
    Ok((files.len(), out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(rel: &str, src: &str) -> Vec<(&'static str, usize)> {
        lint_source(rel, src, &[]).into_iter().map(|d| (d.rule, d.line)).collect()
    }

    fn flow_hit(files: &[(&str, &str)], cfg: &LintConfig) -> Vec<(String, usize, &'static str)> {
        let owned: Vec<(String, String)> =
            files.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect();
        lint_files(&owned, &[], cfg)
            .into_iter()
            .map(|d| (d.path, d.line, d.rule))
            .collect()
    }

    // ---- line rules (ported v1 suite; twin rule renamed to v2) ----

    #[test]
    fn rule_unsafe_code_fires_and_respects_allowlist() {
        let src = "fn f() { unsafe { g() } }\n";
        assert_eq!(rules_hit("a.rs", src), vec![("unsafe-code", 1)]);
        let allow = vec!["a.rs".to_string()];
        assert!(lint_source("a.rs", src, &allow).is_empty());
    }

    #[test]
    fn rule_ordering_contract_checks_comment_reach() {
        let bad = "a.load(Ordering::Relaxed);\n";
        assert_eq!(rules_hit("a.rs", bad), vec![("ordering-contract", 1)]);
        let same_line = "a.load(Ordering::Relaxed); // ordering: relaxed — test\n";
        assert!(rules_hit("a.rs", same_line).is_empty());
        let above = "// ordering: relaxed — contract\n\n\na.load(Ordering::Relaxed);\n";
        assert!(rules_hit("a.rs", above).is_empty(), "3 lines above is in reach");
        let too_far = "// ordering: relaxed\n\n\n\na.load(Ordering::Relaxed);\n";
        assert_eq!(rules_hit("a.rs", too_far), vec![("ordering-contract", 5)]);
    }

    #[test]
    fn rule_wall_clock_exempts_telemetry_and_bench() {
        let src = "let t = Instant::now();\n";
        assert_eq!(rules_hit("sgd/host.rs", src), vec![("wall-clock", 1)]);
        assert_eq!(rules_hit("x.rs", "SystemTime::now();\n"), vec![("wall-clock", 1)]);
        assert!(rules_hit("telemetry/clock.rs", src).is_empty());
        assert!(rules_hit("bench.rs", src).is_empty());
    }

    #[test]
    fn rule_byte_cast_only_narrowing_only_store() {
        let bad = "let b = total_bytes as u32;\n";
        assert_eq!(rules_hit("store/shard.rs", bad), vec![("byte-truncating-cast", 1)]);
        assert!(rules_hit("sgd/host.rs", bad).is_empty(), "scoped to store/");
        assert!(rules_hit("store/shard.rs", "let b = n_bytes as u64;\n").is_empty());
        assert!(rules_hit("store/shard.rs", "let r = rows as u32;\n").is_empty());
    }

    #[test]
    fn rule_hash_scoped_to_deterministic_paths() {
        let src = "use std::collections::HashMap;\n";
        for p in ["store/a.rs", "sgd/a.rs", "fpga/a.rs"] {
            assert_eq!(rules_hit(p, src), vec![("hash-in-deterministic-path", 1)], "{p}");
        }
        assert!(rules_hit("runtime/mod.rs", src).is_empty());
        assert_eq!(
            rules_hit("sgd/a.rs", "let s: HashSet<u32> = x;\n"),
            vec![("hash-in-deterministic-path", 1)]
        );
    }

    #[test]
    fn rule_json_emitter_fires_on_calls_and_defs() {
        assert_eq!(rules_hit("a.rs", "json_escape(s, &mut out);\n"), vec![("json-emitter", 1)]);
        assert_eq!(rules_hit("a.rs", "fn json_write(x: &str) {}\n"), vec![("json-emitter", 1)]);
        assert!(rules_hit("bench.rs", "json_val(v, &mut out);\n").is_empty());
        assert!(rules_hit("a.rs", "let json_value = parse();\n").is_empty(), "other idents ok");
    }

    #[test]
    fn rule_twin_contract_requires_named_twin_and_test() {
        let bad = "if dispatch::tier() == dispatch::Tier::Lanes8 { return simd::f(x); }\n";
        assert_eq!(rules_hit("store/kernel.rs", bad), vec![("twin-contract-v2", 1)]);
        let good = "// twin: f_scalar (simd_f_bit_identical_to_scalar)\n\
                    if dispatch::tier() == dispatch::Tier::Lanes8 { return simd::f(x); }\n";
        assert!(rules_hit("store/kernel.rs", good).is_empty());
        let same_line =
            "if dispatch::tier() == t { f() } // twin: f_scalar (simd_f_bit_identical_to_scalar)\n";
        assert!(rules_hit("a.rs", same_line).is_empty());
        let empty_scalar = "// twin: (some_test) — scalar half missing\n\
                           if dispatch::tier() == t { f() }\n";
        assert_eq!(rules_hit("a.rs", empty_scalar), vec![("twin-contract-v2", 2)]);
        let no_test = "// twin: f_scalar\nif dispatch::tier() == t { f() }\n";
        assert_eq!(rules_hit("a.rs", no_test), vec![("twin-contract-v2", 2)]);
        assert!(
            rules_hit("a.rs", "let l = dispatch::tier_label();\n").is_empty(),
            "label reads are not dispatch sites"
        );
        assert!(rules_hit("a.rs", "let t = dispatch::Tier::Scalar;\n").is_empty());
    }

    #[test]
    fn inline_suppression_waives_same_and_next_line() {
        let same = "let t = Instant::now(); // lint: allow(wall-clock) — fixture\n";
        assert!(rules_hit("a.rs", same).is_empty());
        let above = "// lint: allow(wall-clock) timing demo\nlet t = Instant::now();\n";
        assert!(rules_hit("a.rs", above).is_empty());
        let wrong_rule = "// lint: allow(unsafe-code)\nlet t = Instant::now();\n";
        assert_eq!(rules_hit("a.rs", wrong_rule), vec![("wall-clock", 2)]);
    }

    #[test]
    fn tokens_inside_literals_never_fire() {
        let src = "let m = \"contains unsafe and Instant and HashMap\";\n";
        assert!(rules_hit("sgd/a.rs", src).is_empty());
        let doc = "/// docs may say unsafe, Instant, HashMap, json_escape\nlet ok = 1;\n";
        assert!(rules_hit("sgd/a.rs", doc).is_empty());
    }

    #[test]
    fn allowlist_parser_strips_comments() {
        let txt = "# header\n\nruntime/literal.rs  # historical\n";
        assert_eq!(parse_allowlist(txt), vec!["runtime/literal.rs".to_string()]);
        assert!(parse_allowlist("# only comments\n").is_empty());
    }

    #[test]
    fn diagnostic_renders_file_line_rule() {
        let d = Diagnostic {
            path: "store/shard.rs".into(),
            line: 7,
            rule: "byte-truncating-cast",
            message: "m".into(),
        };
        assert_eq!(d.to_string(), "store/shard.rs:7: [byte-truncating-cast] m");
    }

    // ---- flow rules ----

    #[test]
    fn accounting_flow_flags_unaccounted_store_entry_points() {
        let src = "\
pub struct WeavedStore;\n\
impl WeavedStore {\n\
    pub fn leaky(&self) -> u64 {\n\
        self.row_planes(0)\n\
    }\n\
    pub fn tallied(&self) -> u64 {\n\
        self.note_row_visit(0);\n\
        self.row_planes(0)\n\
    }\n\
    fn row_planes(&self, _r: usize) -> u64 { 0 }\n\
    fn note_row_visit(&self, _r: usize) {}\n\
}\n";
        let hits = flow_hit(&[("store/weaved.rs", src)], &LintConfig::default());
        assert_eq!(hits, vec![("store/weaved.rs".to_string(), 3, "accounting-flow")]);
    }

    #[test]
    fn accounting_flow_follows_the_call_graph_across_files() {
        let a = "\
pub struct PlaneStore;\n\
impl PlaneStore {\n\
    pub fn entry(&self) -> u64 {\n\
        helper_read()\n\
    }\n\
}\n";
        let b = "\
pub fn helper_read() -> u64 {\n\
    gather_word(3)\n\
}\n\
fn gather_word(_w: usize) -> u64 { 0 }\n";
        let hits =
            flow_hit(&[("store/front.rs", a), ("store/inner.rs", b)], &LintConfig::default());
        assert_eq!(hits, vec![("store/front.rs".to_string(), 3, "accounting-flow")]);
        // accounting in the helper clears the entry point transitively
        let b_ok = "\
pub fn helper_read() -> u64 {\n\
    account(1);\n\
    gather_word(3)\n\
}\n\
fn gather_word(_w: usize) -> u64 { 0 }\n\
fn account(_n: u64) {}\n";
        let hits =
            flow_hit(&[("store/front.rs", a), ("store/inner.rs", b_ok)], &LintConfig::default());
        assert!(hits.is_empty(), "{hits:?}");
    }

    #[test]
    fn accounting_flow_skips_non_store_and_non_pub_fns() {
        let src = "\
pub struct XStore;\n\
impl XStore {\n\
    fn private_probe(&self) -> u64 { self.row_planes(0) }\n\
    fn row_planes(&self, _r: usize) -> u64 { 0 }\n\
}\n";
        assert!(flow_hit(&[("store/x.rs", src)], &LintConfig::default()).is_empty());
        let outside = "\
pub struct YStore;\n\
impl YStore {\n\
    pub fn read(&self) -> u64 { self.row_planes(0) }\n\
    fn row_planes(&self, _r: usize) -> u64 { 0 }\n\
}\n";
        assert!(
            flow_hit(&[("sgd/y.rs", outside)], &LintConfig::default()).is_empty(),
            "accounting-flow is scoped to store/"
        );
    }

    #[test]
    fn rng_stream_discipline_flags_rng_new_in_spawning_fns() {
        let bad = "\
fn run(threads: usize) {\n\
    for t in 0..threads {\n\
        std::thread::spawn(move || {\n\
            let mut rng = Rng::new(seed ^ t as u64);\n\
        });\n\
    }\n\
}\n";
        let hits = flow_hit(&[("sgd/host.rs", bad)], &LintConfig::default());
        assert_eq!(hits, vec![("sgd/host.rs".to_string(), 4, "rng-stream-discipline")]);
        let good = bad.replace("Rng::new(seed ^ t as u64)", "Rng::new_stream(seed, t as u64)");
        assert!(flow_hit(&[("sgd/host.rs", good.as_str())], &LintConfig::default()).is_empty());
        // no spawn in the fn: Rng::new is the blessed root-stream form
        let root = "fn seed_root() { let mut rng = Rng::new(0xC0FFEE); }\n";
        assert!(flow_hit(&[("sgd/host.rs", root)], &LintConfig::default()).is_empty());
    }

    #[test]
    fn rng_stream_discipline_gates_threshold_draws_in_store() {
        let bad = "\
pub fn draw(rng: &mut Rng) -> u64 {\n\
    rng.next_u64()\n\
}\n";
        let hits = flow_hit(&[("store/ds.rs", bad)], &LintConfig::default());
        assert_eq!(hits, vec![("store/ds.rs".to_string(), 2, "rng-stream-discipline")]);
        let good = "\
pub struct PcgSource;\n\
impl ThresholdSource for PcgSource {\n\
    fn draw(&mut self) -> u64 {\n\
        self.rng.next_u64()\n\
    }\n\
}\n";
        assert!(flow_hit(&[("store/ds.rs", good)], &LintConfig::default()).is_empty());
        assert!(
            flow_hit(&[("sgd/ds.rs", bad)], &LintConfig::default()).is_empty(),
            "threshold half is scoped to store/"
        );
    }

    #[test]
    fn strategy_matrix_rejects_wildcard_arms() {
        let bad = "\
fn pick(s: ReadStrategy) -> u32 {\n\
    match s {\n\
        ReadStrategy::Dense => 1,\n\
        _ => 0,\n\
    }\n\
}\n";
        let hits = flow_hit(&[("sgd/modes.rs", bad)], &LintConfig::default());
        assert_eq!(hits, vec![("sgd/modes.rs".to_string(), 4, "strategy-matrix-exhaustiveness")]);
        let exhaustive = "\
fn pick(s: ReadStrategy) -> u32 {\n\
    match s {\n\
        ReadStrategy::Dense => 1,\n\
        ReadStrategy::Truncate | ReadStrategy::DoubleSample => 0,\n\
        ReadStrategy::Popcount { q } => q,\n\
    }\n\
}\n";
        assert!(flow_hit(&[("sgd/modes.rs", exhaustive)], &LintConfig::default()).is_empty());
        // non-strategy matches may use wildcards freely
        let plain = "fn f(x: u32) -> u32 { match x { 0 => 1, _ => 0 } }\n";
        assert!(flow_hit(&[("sgd/modes.rs", plain)], &LintConfig::default()).is_empty());
        // test-scope matches are exempt
        let in_test = "\
#[cfg(test)]\n\
mod tests {\n\
    fn pick(s: ReadStrategy) -> u32 {\n\
        match s { ReadStrategy::Dense => 1, _ => 0 }\n\
    }\n\
}\n";
        assert!(flow_hit(&[("sgd/modes.rs", in_test)], &LintConfig::default()).is_empty());
    }

    #[test]
    fn design_ref_checks_section_numbers_when_configured() {
        let src = "let x = 1; // the plane walk (DESIGN.md \u{a7}99)\n";
        let cfg = LintConfig { design_text: Some("## \u{a7}5 Planes\n"), test_texts: None };
        let hits = flow_hit(&[("store/a.rs", src)], &cfg);
        assert_eq!(hits, vec![("store/a.rs".to_string(), 1, "design-ref")]);
        let ok = "let x = 1; // the plane walk (DESIGN.md \u{a7}5)\n";
        assert!(flow_hit(&[("store/a.rs", ok)], &cfg).is_empty());
        // without a DESIGN.md config the rule is off
        assert!(flow_hit(&[("store/a.rs", src)], &LintConfig::default()).is_empty());
    }

    #[test]
    fn twin_v2_checks_test_existence_at_dispatch_sites_only() {
        let src = "\
// twin: gather_scalar (simd_gather_matches_scalar)\n\
if dispatch::tier() == t { simd::gather(x) } else { gather_scalar(x) }\n";
        let tests_missing: Vec<String> = vec!["fn unrelated_test() {}\n".to_string()];
        let cfg = LintConfig { design_text: None, test_texts: Some(&tests_missing) };
        let hits = flow_hit(&[("store/kernel.rs", src)], &cfg);
        assert_eq!(hits, vec![("store/kernel.rs".to_string(), 1, "twin-contract-v2")]);
        let tests_present: Vec<String> =
            vec!["#[test]\nfn simd_gather_matches_scalar() {}\n".to_string()];
        let cfg = LintConfig { design_text: None, test_texts: Some(&tests_present) };
        assert!(flow_hit(&[("store/kernel.rs", src)], &cfg).is_empty());
        // a stray twin-shaped comment away from any dispatch site is doc,
        // not contract — the doc-template in dispatch.rs must stay legal
        let doc_only = "// twin: <scalar_fn> (<bit_equality_test>)\nlet x = 1;\n";
        let cfg = LintConfig { design_text: None, test_texts: Some(&tests_missing) };
        assert!(flow_hit(&[("store/dispatch.rs", doc_only)], &cfg).is_empty());
    }

    #[test]
    fn deprecated_fns_keep_zero_internal_callers() {
        let a = "\
#[deprecated(note = \"use new_api\")]\n\
pub fn old_api(x: u32) -> u32 { new_api(x) }\n\
pub fn new_api(x: u32) -> u32 { x }\n";
        let b = "pub fn caller() -> u32 { old_api(7) }\n";
        let hits = flow_hit(&[("api.rs", a), ("user.rs", b)], &LintConfig::default());
        assert_eq!(hits, vec![("user.rs".to_string(), 1, "deprecated-no-internal-callers")]);
        // test-scope callers are fine (shim coverage tests)
        let b_test = "\
#[cfg(test)]\n\
mod tests {\n\
    #[test]\n\
    fn shim_still_forwards() { assert_eq!(old_api(7), 7); }\n\
}\n";
        assert!(flow_hit(&[("api.rs", a), ("user.rs", b_test)], &LintConfig::default())
            .is_empty());
        // a deprecated fn may call another deprecated fn (shim chains)
        let chain = "\
#[deprecated]\n\
pub fn old2(x: u32) -> u32 { x }\n\
#[deprecated]\n\
pub fn old1(x: u32) -> u32 { old2(x) }\n";
        assert!(flow_hit(&[("api.rs", chain)], &LintConfig::default()).is_empty());
    }

    #[test]
    fn flow_findings_respect_inline_suppressions() {
        let src = "\
fn run() {\n\
    std::thread::spawn(move || {\n\
        // lint: allow(rng-stream-discipline) — fixture exercises the raw form\n\
        let mut rng = Rng::new(9);\n\
    });\n\
}\n";
        assert!(flow_hit(&[("sgd/host.rs", src)], &LintConfig::default()).is_empty());
    }

    #[test]
    fn lint_files_sorts_findings_by_path_line_rule() {
        let files = vec![
            ("z.rs", "let t = Instant::now();\n"),
            ("a.rs", "fn f() { unsafe { g() } }\n"),
        ];
        let hits = flow_hit(&files, &LintConfig::default());
        assert_eq!(
            hits,
            vec![
                ("a.rs".to_string(), 1, "unsafe-code"),
                ("z.rs".to_string(), 1, "wall-clock"),
            ]
        );
    }
}
