// Fixture for the `byte-truncating-cast` rule (scoped to store/):
// byte-accounting expressions must not be narrowed with `as`.

fn widening_is_fine(shard_bytes: u32) -> u64 {
    shard_bytes as u64 // widening a byte count is allowed
}

fn non_byte_narrowing_is_fine(rows: u64) -> u32 {
    rows as u32 // narrowing, but not a byte-accounting identifier
}

fn bad_narrow(total_bytes: u64) -> u32 {
    total_bytes as u32 // LINT-EXPECT[byte-truncating-cast]
}

fn bad_float(bytes_read: u64) -> f32 {
    bytes_read as f32 // LINT-EXPECT[byte-truncating-cast]
}
