// Fixture for the `twin-contract-v2` rule (site half): every
// `dispatch::tier` dispatch site must carry a
// `// twin: scalar_name (bit_equality_test)` comment on the same line
// or within the 3 lines above.

fn documented_site(word: u64, g: &[f32]) -> f32 {
    // twin: masked_sum_dense (simd_masked_sum_bit_identical_to_scalar)
    if dispatch::tier() == dispatch::Tier::Lanes8 {
        return simd::masked_sum_dense(word, g);
    }
    masked_sum_dense(word, g)
}

fn bare_site(word: u64, g: &[f32]) -> f32 {
    if dispatch::tier() == dispatch::Tier::Lanes8 { // LINT-EXPECT[twin-contract-v2]
        return simd::masked_sum_dense(word, g);
    }
    masked_sum_dense(word, g)
}

fn half_named_site(word: u64) -> u64 {
    // twin: (simd_select_add_bit_identical_to_scalar) — scalar name missing
    if dispatch::tier() == dispatch::Tier::Lanes8 { // LINT-EXPECT[twin-contract-v2]
        return word;
    }
    word
}

fn label_read_is_not_a_dispatch_site() -> &'static str {
    dispatch::tier_label()
}
