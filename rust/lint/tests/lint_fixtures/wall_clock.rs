// Fixture for the `wall-clock` rule: Instant/SystemTime reads outside
// telemetry/ and bench.rs break the determinism contract.

fn stringy() {
    let _msg = "Instant and SystemTime in strings are fine";
}

fn bad_instant() {
    let _t0 = std::time::Instant::now(); // LINT-EXPECT[wall-clock]
}

fn bad_system_time() {
    let _now = SystemTime::now(); // LINT-EXPECT[wall-clock]
}
