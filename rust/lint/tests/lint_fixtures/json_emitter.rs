// Fixture for the `json-emitter` rule: JSON writing outside bench.rs —
// either calling bench's private escapers or defining a new `fn json_*`.

fn ok_ident() {
    let json_payload = parse(); // other json_* identifiers are fine
    drop(json_payload);
}

fn bad_call(out: &mut String) {
    json_escape("k", out); // LINT-EXPECT[json-emitter]
}

fn json_emit(v: f64) -> String { // LINT-EXPECT[json-emitter]
    format!("{v}")
}
