// Fixture for the `hash-in-deterministic-path` rule (scoped to store/,
// sgd/, fpga/): hash iteration order is nondeterministic, which would
// break the fixed-seed determinism contract.

fn btree_is_fine() {
    let _m: std::collections::BTreeMap<u32, f32> = Default::default();
}

fn bad_map() {
    let _m: HashMap<u32, f32> = HashMap::new(); // LINT-EXPECT[hash-in-deterministic-path]
}

fn bad_set() {
    use std::collections::HashSet; // LINT-EXPECT[hash-in-deterministic-path]
}
