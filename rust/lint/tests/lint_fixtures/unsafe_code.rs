// Fixture for the `unsafe-code` rule. Files under tests/ subdirectories
// are never compiled by cargo; zipml-lint scans them as text.
// Comments and strings mentioning unsafe must NOT fire; real code must.

fn safe_mention() {
    let _doc = "this string says unsafe and is fine";
}

fn bad() {
    unsafe { core::hint::unreachable_unchecked() } // LINT-EXPECT[unsafe-code]
}
