// Fixture for inline suppression: every would-be finding below carries
// a `lint: allow(rule)` waiver, so this file must lint CLEAN (zero
// diagnostics, zero LINT-EXPECT markers).

fn waived_same_line() {
    let _t0 = Instant::now(); // lint: allow(wall-clock) — fixture waiver
}

fn waived_line_above(a: &AtomicU64) {
    // lint: allow(ordering-contract) — fixture waiver
    a.load(Ordering::Relaxed);
}

fn waived_unsafe() {
    // lint: allow(unsafe-code) — fixture waiver
    unsafe { touch() }
}

fn waived_flow_rule(seed: u64) {
    std::thread::spawn(move || {
        // lint: allow(rng-stream-discipline) — fixture waiver
        let _rng = Rng::new(seed);
    });
}
