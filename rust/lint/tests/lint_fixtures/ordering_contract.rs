// Fixture for the `ordering-contract` rule: every Ordering::* use needs
// an `ordering:` comment on the same line or within the 3 lines above.

fn documented_same_line(a: &AtomicU64) {
    a.load(Ordering::Relaxed); // ordering: relaxed — fixture contract
}

fn documented_above(a: &AtomicU64) {
    // ordering: relaxed — the contract sits two lines up
    let _x = 0;
    a.store(1, Ordering::Relaxed);
}

fn undocumented(a: &AtomicU64) {
    a.fetch_add(1, Ordering::Relaxed); // LINT-EXPECT[ordering-contract]
}
