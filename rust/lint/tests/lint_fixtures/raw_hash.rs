// Scrubber regression fixture: multi-hash raw strings (`r##"…"##`,
// `br##"…"##`) must be blanked exactly — an embedded `"#` must NOT
// close a `##` literal early, and scanning must resume cleanly after
// the real terminator (zero spurious findings from the literal bodies,
// one real finding after them).

fn multi_hash_raw() -> &'static str {
    r##"unsafe Instant HashMap "# still inside the literal"##
}

fn multi_hash_spans_lines() -> &'static [u8] {
    br##"first line
unsafe SystemTime Ordering::Relaxed "# not the end yet
"##
}

fn scanning_resumes_after_raw() {
    let _t = Instant::now(); // LINT-EXPECT[wall-clock]
}
