//! Fixture-driven tests for the v2 flow rules, plus the golden-bytes
//! pin on the JSON findings stream.
//!
//! `tests/flow_fixtures/` is a miniature repo: `src/` (the tree under
//! lint), `DESIGN.md` (two sections, §1/§2), and `tests/` (one real
//! twin test). Scanned with that config, all twelve rules run, and the
//! findings must match the `LINT-EXPECT[rule]` markers exactly.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use zipml_lint::{json, lint_files, lint_tree_with, read_tree, Diagnostic, LintConfig};

fn flow_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/flow_fixtures")
}

fn flow_found() -> Vec<Diagnostic> {
    let root = flow_root();
    let design = std::fs::read_to_string(root.join("DESIGN.md")).expect("flow DESIGN.md");
    let tests: Vec<String> = read_tree(&root.join("tests"))
        .expect("flow tests root")
        .into_iter()
        .map(|(_rel, src)| src)
        .collect();
    let cfg = LintConfig { design_text: Some(&design), test_texts: Some(&tests) };
    let (files, diags) = lint_tree_with(&root.join("src"), &[], &cfg).expect("scan flow fixtures");
    assert!(files >= 8, "flow fixture tree went missing? scanned only {files} files");
    diags
}

fn expected_markers() -> BTreeSet<(String, usize, String)> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
        for entry in std::fs::read_dir(dir).expect("fixture dir") {
            let p = entry.expect("fixture entry").path();
            if p.is_dir() {
                walk(&p, out);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    let root = flow_root().join("src");
    let mut files = Vec::new();
    walk(&root, &mut files);
    let mut set = BTreeSet::new();
    for f in &files {
        let rel = f.strip_prefix(&root).unwrap().to_string_lossy().replace('\\', "/");
        let text = std::fs::read_to_string(f).expect("fixture read");
        for (i, line) in text.lines().enumerate() {
            if let Some(pos) = line.find("LINT-EXPECT[") {
                let rest = &line[pos + "LINT-EXPECT[".len()..];
                let rule = rest.split(']').next().expect("closed marker");
                set.insert((rel.clone(), i + 1, rule.to_string()));
            }
        }
    }
    set
}

#[test]
fn flow_findings_match_expect_markers_exactly() {
    let expected = expected_markers();
    assert!(!expected.is_empty(), "no LINT-EXPECT markers found");
    let got: BTreeSet<(String, usize, String)> = flow_found()
        .into_iter()
        .map(|d| (d.path, d.line, d.rule.to_string()))
        .collect();
    let missed: Vec<_> = expected.difference(&got).collect();
    let spurious: Vec<_> = got.difference(&expected).collect();
    assert!(missed.is_empty(), "marked violations not reported: {missed:?}");
    assert!(spurious.is_empty(), "unmarked findings reported: {spurious:?}");
}

fn hits_in(file: &str, rule: &str) -> Vec<usize> {
    flow_found()
        .into_iter()
        .filter(|d| d.path == file && d.rule == rule)
        .map(|d| d.line)
        .collect()
}

#[test]
fn accounting_flow_fires_at_the_leaky_entry_point_only() {
    assert_eq!(hits_in("store/accounting.rs", "accounting-flow"), vec![9]);
    assert!(hits_in("store/planes.rs", "accounting-flow").is_empty());
}

#[test]
fn rng_discipline_fires_on_both_halves() {
    assert_eq!(hits_in("sgd/spawn_rng.rs", "rng-stream-discipline"), vec![8]);
    assert_eq!(hits_in("store/rng_threshold.rs", "rng-stream-discipline"), vec![15]);
}

#[test]
fn strategy_matrix_fires_at_the_wildcard_arm_only() {
    assert_eq!(hits_in("sgd/strategy.rs", "strategy-matrix-exhaustiveness"), vec![9]);
}

#[test]
fn design_ref_fires_on_the_stale_section_only() {
    assert_eq!(hits_in("design_ref.rs", "design-ref"), vec![11]);
}

#[test]
fn twin_v2_fires_on_the_phantom_test_only() {
    assert_eq!(hits_in("store/twin_site.rs", "twin-contract-v2"), vec![15]);
}

#[test]
fn deprecated_rule_fires_at_the_lingering_caller_only() {
    assert_eq!(hits_in("deprecated.rs", "deprecated-no-internal-callers"), vec![16]);
}

/// Golden bytes: the exact JSONL the CLI's `--json` mode emits for a
/// known two-finding tree — path, line, rule, message, field order,
/// escaping, and sort order all pinned. Rendering goes through
/// `zipml::bench::JsonObj`, so this also pins that the linter stays a
/// consumer of the repo's single JSON writer.
#[test]
fn json_findings_stream_is_golden_bytes() {
    let files = vec![
        (
            "store/cast.rs".to_string(),
            "fn f(n_bytes: u64) -> u32 {\n    n_bytes as u32\n}\n".to_string(),
        ),
        (
            "clock.rs".to_string(),
            "fn now_ms() -> u64 {\n    clock().elapsed(Instant::now())\n}\n".to_string(),
        ),
    ];
    let diags = lint_files(&files, &[], &LintConfig::default());
    let got = json::render_findings(&diags);
    let want = concat!(
        "{\"path\":\"clock.rs\",\"line\":2,\"rule\":\"wall-clock\",\"message\":\"wall-clock ",
        "read outside telemetry//bench.rs; use telemetry::Stopwatch (determinism contract)\"}\n",
        "{\"path\":\"store/cast.rs\",\"line\":2,\"rule\":\"byte-truncating-cast\",",
        "\"message\":\"byte-accounting expression narrowed with `as` can truncate; byte totals ",
        "stay u64 end to end (`as u32`)\"}\n",
    );
    assert_eq!(got, want);
}

/// Round trip: the stream `--json` writes is exactly what `--baseline`
/// reads back, and a baseline equal to the current findings means zero
/// new findings (the CI gate's steady state).
#[test]
fn findings_stream_round_trips_as_a_baseline() {
    let diags = flow_found();
    assert!(!diags.is_empty());
    let baseline = json::parse_findings(&json::render_findings(&diags)).expect("round trip");
    assert!(json::new_findings(&diags, &baseline).is_empty());
    assert!(json::stale_entries(&diags, &baseline).is_empty());
}
