//! Fixture-driven tests for zipml-lint, plus the clean-tree self-run.
//!
//! `tests/lint_fixtures/` holds deliberately-bad (non-compiling — cargo
//! never builds files in tests/ subdirectories) snippets, one file per
//! line rule, with each seeded violation marked `// LINT-EXPECT[rule-name]`
//! on its line. The contract checked here is exact: the linter must
//! report *precisely* the marked (path, line, rule) set — nothing
//! missed, nothing spurious. (`tests/flow_fixtures/` holds the
//! cross-file flow-rule fixtures — see flow_fixtures.rs.)

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use zipml_lint::{lint_tree, lint_tree_with, parse_allowlist, read_tree, Diagnostic, LintConfig};

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures")
}

/// Scan a fixture tree's raw text for `LINT-EXPECT[rule]` markers.
fn expected_markers_under(root: &Path) -> BTreeSet<(String, usize, String)> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
        for entry in std::fs::read_dir(dir).expect("fixture dir") {
            let p = entry.expect("fixture entry").path();
            if p.is_dir() {
                walk(&p, out);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    let mut files = Vec::new();
    walk(root, &mut files);
    let mut set = BTreeSet::new();
    for f in &files {
        let rel = f.strip_prefix(root).unwrap().to_string_lossy().replace('\\', "/");
        let text = std::fs::read_to_string(f).expect("fixture read");
        for (i, line) in text.lines().enumerate() {
            if let Some(pos) = line.find("LINT-EXPECT[") {
                let rest = &line[pos + "LINT-EXPECT[".len()..];
                let rule = rest.split(']').next().expect("closed marker");
                set.insert((rel.clone(), i + 1, rule.to_string()));
            }
        }
    }
    set
}

fn expected_markers() -> BTreeSet<(String, usize, String)> {
    expected_markers_under(&fixture_root())
}

fn found() -> Vec<Diagnostic> {
    // Empty allowlist: the fixtures exercise unsafe-code for real.
    let (files, diags) = lint_tree(&fixture_root(), &[]).expect("scan fixtures");
    assert!(files >= 7, "fixture tree went missing? scanned only {files} files");
    diags
}

/// The flow-fixture tree, scanned with its own DESIGN.md and tests root
/// so all twelve rules run (flow_fixtures.rs pins its exact markers;
/// here it only feeds the every-rule-fires check).
fn flow_found() -> Vec<Diagnostic> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/flow_fixtures");
    let design = std::fs::read_to_string(root.join("DESIGN.md")).expect("flow DESIGN.md");
    let tests: Vec<String> = read_tree(&root.join("tests"))
        .expect("flow tests root")
        .into_iter()
        .map(|(_rel, src)| src)
        .collect();
    let cfg = LintConfig { design_text: Some(&design), test_texts: Some(&tests) };
    let (files, diags) = lint_tree_with(&root.join("src"), &[], &cfg).expect("scan flow fixtures");
    assert!(files >= 8, "flow fixture tree went missing? scanned only {files} files");
    diags
}

#[test]
fn fixture_findings_match_expect_markers_exactly() {
    let expected = expected_markers();
    assert!(!expected.is_empty(), "no LINT-EXPECT markers found");
    let got: BTreeSet<(String, usize, String)> = found()
        .into_iter()
        .map(|d| (d.path, d.line, d.rule.to_string()))
        .collect();
    let missed: Vec<_> = expected.difference(&got).collect();
    let spurious: Vec<_> = got.difference(&expected).collect();
    assert!(missed.is_empty(), "marked violations not reported: {missed:?}");
    assert!(spurious.is_empty(), "unmarked findings reported: {spurious:?}");
}

/// Every rule must be exercised by at least one fixture marker — so a
/// rule can never silently rot into a no-op. Line rules fire in
/// lint_fixtures/, flow rules in flow_fixtures/.
#[test]
fn every_rule_has_a_firing_fixture() {
    let mut hit: BTreeSet<String> = found().into_iter().map(|d| d.rule.to_string()).collect();
    hit.extend(flow_found().into_iter().map(|d| d.rule.to_string()));
    for rule in zipml_lint::RULE_NAMES {
        assert!(hit.contains(*rule), "rule {rule} never fires in the fixtures");
    }
}

fn hits_in(file: &str, rule: &str) -> Vec<usize> {
    found()
        .into_iter()
        .filter(|d| d.path == file && d.rule == rule)
        .map(|d| d.line)
        .collect()
}

#[test]
fn unsafe_code_fires_at_seeded_line_only() {
    assert_eq!(hits_in("unsafe_code.rs", "unsafe-code"), vec![10]);
}

#[test]
fn ordering_contract_fires_at_seeded_line_only() {
    assert_eq!(hits_in("ordering_contract.rs", "ordering-contract"), vec![15]);
}

#[test]
fn wall_clock_fires_at_seeded_lines_only() {
    assert_eq!(hits_in("wall_clock.rs", "wall-clock"), vec![9, 13]);
}

#[test]
fn json_emitter_fires_at_seeded_lines_only() {
    assert_eq!(hits_in("json_emitter.rs", "json-emitter"), vec![10, 13]);
}

#[test]
fn byte_cast_fires_at_seeded_lines_only() {
    assert_eq!(hits_in("store/byte_cast.rs", "byte-truncating-cast"), vec![13, 17]);
}

#[test]
fn hash_rule_fires_at_seeded_lines_only() {
    assert_eq!(hits_in("sgd/hash_iter.rs", "hash-in-deterministic-path"), vec![10, 14]);
}

#[test]
fn twin_contract_fires_at_seeded_lines_only() {
    assert_eq!(hits_in("store/simd_twin.rs", "twin-contract-v2"), vec![15, 23]);
}

/// Multi-hash raw strings scrub as literals end to end: nothing inside
/// them fires, and the scanner picks up real findings right after.
#[test]
fn raw_hash_fixture_only_fires_after_the_literals() {
    let hits: Vec<_> = found().into_iter().filter(|d| d.path == "raw_hash.rs").collect();
    assert_eq!(hits.len(), 1, "{hits:?}");
    assert_eq!((hits[0].line, hits[0].rule), (18, "wall-clock"));
}

#[test]
fn suppressed_fixture_is_fully_waived() {
    let hits: Vec<_> = found().into_iter().filter(|d| d.path == "suppressed.rs").collect();
    assert!(hits.is_empty(), "suppressions ignored: {hits:?}");
}

/// The real tree must lint clean with the real allowlist AND the full
/// cross-tree config (repo DESIGN.md + rust/tests) — all twelve rules.
/// This is the same check `ci.sh --analyze` runs via the CLI, and it
/// runs under plain `cargo test` so tier-1 already enforces every
/// invariant.
#[test]
fn crate_source_tree_lints_clean() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let src_root = manifest.join("../src");
    let allow = parse_allowlist(
        &std::fs::read_to_string(manifest.join("allowlist_unsafe.txt")).expect("allowlist"),
    );
    let design = std::fs::read_to_string(manifest.join("../../DESIGN.md")).expect("DESIGN.md");
    let tests: Vec<String> = read_tree(&manifest.join("../tests"))
        .expect("rust/tests")
        .into_iter()
        .map(|(_rel, src)| src)
        .collect();
    let cfg = LintConfig { design_text: Some(&design), test_texts: Some(&tests) };
    let (files, diags) = lint_tree_with(&src_root, &allow, &cfg).expect("scan rust/src");
    assert!(files >= 20, "rust/src shrank? scanned only {files} files");
    assert!(
        diags.is_empty(),
        "rust/src violates its own invariants:\n{}",
        diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
    );
}
