//! Fixture-driven tests for zipml-lint, plus the clean-tree self-run.
//!
//! `tests/lint_fixtures/` holds deliberately-bad (non-compiling — cargo
//! never builds files in tests/ subdirectories) snippets, one file per
//! rule, with each seeded violation marked `// LINT-EXPECT[rule-name]`
//! on its line. The contract checked here is exact: the linter must
//! report *precisely* the marked (path, line, rule) set — nothing
//! missed, nothing spurious.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use zipml_lint::{lint_tree, parse_allowlist, Diagnostic};

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures")
}

/// Scan the fixture tree's raw text for `LINT-EXPECT[rule]` markers.
fn expected_markers() -> BTreeSet<(String, usize, String)> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
        for entry in std::fs::read_dir(dir).expect("fixture dir") {
            let p = entry.expect("fixture entry").path();
            if p.is_dir() {
                walk(&p, out);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    let root = fixture_root();
    let mut files = Vec::new();
    walk(&root, &mut files);
    let mut set = BTreeSet::new();
    for f in &files {
        let rel = f.strip_prefix(&root).unwrap().to_string_lossy().replace('\\', "/");
        let text = std::fs::read_to_string(f).expect("fixture read");
        for (i, line) in text.lines().enumerate() {
            if let Some(pos) = line.find("LINT-EXPECT[") {
                let rest = &line[pos + "LINT-EXPECT[".len()..];
                let rule = rest.split(']').next().expect("closed marker");
                set.insert((rel.clone(), i + 1, rule.to_string()));
            }
        }
    }
    set
}

fn found() -> Vec<Diagnostic> {
    // Empty allowlist: the fixtures exercise unsafe-code for real.
    let (files, diags) = lint_tree(&fixture_root(), &[]).expect("scan fixtures");
    assert!(files >= 7, "fixture tree went missing? scanned only {files} files");
    diags
}

#[test]
fn fixture_findings_match_expect_markers_exactly() {
    let expected = expected_markers();
    assert!(!expected.is_empty(), "no LINT-EXPECT markers found");
    let got: BTreeSet<(String, usize, String)> = found()
        .into_iter()
        .map(|d| (d.path, d.line, d.rule.to_string()))
        .collect();
    let missed: Vec<_> = expected.difference(&got).collect();
    let spurious: Vec<_> = got.difference(&expected).collect();
    assert!(missed.is_empty(), "marked violations not reported: {missed:?}");
    assert!(spurious.is_empty(), "unmarked findings reported: {spurious:?}");
}

/// Every rule must be exercised by at least one fixture marker — so a
/// rule can never silently rot into a no-op.
#[test]
fn every_rule_has_a_firing_fixture() {
    let hit: BTreeSet<String> = found().into_iter().map(|d| d.rule.to_string()).collect();
    for rule in zipml_lint::RULE_NAMES {
        assert!(hit.contains(*rule), "rule {rule} never fires in the fixtures");
    }
}

fn hits_in(file: &str, rule: &str) -> Vec<usize> {
    found()
        .into_iter()
        .filter(|d| d.path == file && d.rule == rule)
        .map(|d| d.line)
        .collect()
}

#[test]
fn unsafe_code_fires_at_seeded_line_only() {
    assert_eq!(hits_in("unsafe_code.rs", "unsafe-code"), vec![10]);
}

#[test]
fn ordering_contract_fires_at_seeded_line_only() {
    assert_eq!(hits_in("ordering_contract.rs", "ordering-contract"), vec![15]);
}

#[test]
fn wall_clock_fires_at_seeded_lines_only() {
    assert_eq!(hits_in("wall_clock.rs", "wall-clock"), vec![9, 13]);
}

#[test]
fn json_emitter_fires_at_seeded_lines_only() {
    assert_eq!(hits_in("json_emitter.rs", "json-emitter"), vec![10, 13]);
}

#[test]
fn byte_cast_fires_at_seeded_lines_only() {
    assert_eq!(hits_in("store/byte_cast.rs", "byte-truncating-cast"), vec![13, 17]);
}

#[test]
fn hash_rule_fires_at_seeded_lines_only() {
    assert_eq!(hits_in("sgd/hash_iter.rs", "hash-in-deterministic-path"), vec![10, 14]);
}

#[test]
fn simd_twin_fires_at_seeded_lines_only() {
    assert_eq!(hits_in("store/simd_twin.rs", "simd-twin-contract"), vec![14, 22]);
}

#[test]
fn suppressed_fixture_is_fully_waived() {
    let hits: Vec<_> = found().into_iter().filter(|d| d.path == "suppressed.rs").collect();
    assert!(hits.is_empty(), "suppressions ignored: {hits:?}");
}

/// The real tree must lint clean with the real allowlist — this is the
/// same check `ci.sh --analyze` runs via the CLI, and it runs under
/// plain `cargo test` so tier-1 already enforces every invariant.
#[test]
fn crate_source_tree_lints_clean() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let src_root = manifest.join("../src");
    let allow = parse_allowlist(
        &std::fs::read_to_string(manifest.join("allowlist_unsafe.txt")).expect("allowlist"),
    );
    let (files, diags) = lint_tree(&src_root, &allow).expect("scan rust/src");
    assert!(files >= 20, "rust/src shrank? scanned only {files} files");
    assert!(
        diags.is_empty(),
        "rust/src violates its own invariants:\n{}",
        diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
    );
}
