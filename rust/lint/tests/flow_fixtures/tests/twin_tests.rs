// The bit-equality twin test referenced by src/store/twin_site.rs —
// its *name* is what the twin-contract-v2 cross-file half checks.

#[test]
fn gather_twin_bits_match() {
    assert!(true);
}
