// Fixture for `deprecated-no-internal-callers`: a `#[deprecated]` fn
// keeps zero non-test in-crate callers, so the shim can be dropped on
// schedule. Deprecated-to-deprecated forwarding and test-mod callers
// (shim coverage) stay legal.

#[deprecated(note = "use read_rows_at")]
pub fn read_rows(lo: usize, hi: usize) -> u64 {
    read_rows_at(lo, hi - lo)
}

pub fn read_rows_at(lo: usize, n: usize) -> u64 {
    (lo + n) as u64
}

pub fn lingering_caller() -> u64 {
    read_rows(0, 4) // LINT-EXPECT[deprecated-no-internal-callers]
}

#[cfg(test)]
mod tests {
    #[test]
    fn shim_still_forwards() {
        assert_eq!(read_rows(0, 4), read_rows_at(0, 4));
    }
}
