// Fixture for `strategy-matrix-exhaustiveness`: matches over the
// strategy/model enums enumerate every variant — no `_` fallback, so
// a new variant is a compile error at every decision point instead of
// a silent default.

pub fn wildcard_arm(kind: ModelKind) -> f32 {
    match kind {
        ModelKind::Linreg => 0.0,
        _ => 1.0, // LINT-EXPECT[strategy-matrix-exhaustiveness]
    }
}

pub fn exhaustive(kind: ModelKind) -> f32 {
    match kind {
        ModelKind::Linreg => 0.0,
        ModelKind::Logistic | ModelKind::Svm => 1.0,
        ModelKind::Lssvm { c } => c,
    }
}

pub fn plain_wildcards_are_fine(x: u32) -> u32 {
    match x {
        0 => 1,
        _ => 0,
    }
}
