// Fixture for `rng-stream-discipline` (spawn half): a fn that spawns
// threads must not construct `Rng::new` — per-thread streams derive
// through `Rng::new_stream`, the one blessed splitter.

pub fn hogwild_run(seed: u64, threads: usize) {
    for t in 0..threads {
        std::thread::spawn(move || {
            let mut rng = Rng::new(seed ^ t as u64); // LINT-EXPECT[rng-stream-discipline]
            step(&mut rng);
        });
    }
}

pub fn disciplined_run(seed: u64, threads: usize) {
    for t in 0..threads {
        std::thread::spawn(move || {
            let mut rng = Rng::new_stream(seed, t as u64);
            step(&mut rng);
        });
    }
}

pub fn root_seed(seed: u64) -> Rng {
    Rng::new(seed)
}
