// Fixture for `accounting-flow`: public `*Store` entry points that
// reach plane words must also reach a byte-accounting sink — checked
// by call-graph reachability, including across files (the plane walk
// lives in planes.rs).

pub struct FixtureStore;

impl FixtureStore {
    pub fn leaky_read(&self) -> u64 { // LINT-EXPECT[accounting-flow]
        plane_helper(3)
    }

    pub fn tallied_read(&self) -> u64 {
        self.note_row_visit(3);
        plane_helper(3)
    }

    fn note_row_visit(&self, _row: usize) {}
}
