// Fixture for `twin-contract-v2` (cross-file half): the bit-equality
// test named by each dispatch site's twin comment must exist under
// the configured tests root (this tree's tests/ defines exactly one:
// `gather_twin_bits_match`).

fn verified_site(x: u64) -> u64 {
    // twin: gather_scalar (gather_twin_bits_match)
    if dispatch::tier() == dispatch::Tier::Lanes8 {
        return simd_gather(x);
    }
    gather_scalar(x)
}

fn phantom_test_site(x: u64) -> u64 {
    // twin: select_scalar (select_twin_bits_match) // LINT-EXPECT[twin-contract-v2]
    if dispatch::tier() == dispatch::Tier::Lanes8 {
        return simd_select(x);
    }
    select_scalar(x)
}
