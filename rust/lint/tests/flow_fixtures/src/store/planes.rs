// The plane walk: `gather_word` is one of the plane-touch tokens the
// accounting closure seeds from, so every caller chain that reaches
// `plane_helper` counts as touching plane words.

pub fn plane_helper(w: usize) -> u64 {
    gather_word(w)
}

fn gather_word(_w: usize) -> u64 {
    0
}
