// Fixture for `rng-stream-discipline` (threshold half): DS threshold
// draws in store/ happen only inside an `impl ThresholdSource` block.

pub struct FixtureSource {
    state: u64,
}

impl ThresholdSource for FixtureSource {
    fn draw(&mut self) -> u64 {
        self.state.next_u64()
    }
}

pub fn raw_threshold_draw(rng: &mut Pcg) -> u64 {
    rng.next_u64() // LINT-EXPECT[rng-stream-discipline]
}
