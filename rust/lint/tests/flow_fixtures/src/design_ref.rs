// Fixture for `design-ref`: every `DESIGN.md §N`-style comment
// reference must resolve to a real section of the configured design
// doc (this tree's DESIGN.md has §1 and §2 only — see DESIGN.md §1).

pub fn plane_walk() -> u64 {
    // the walk order is pinned (DESIGN.md §1, DESIGN.md §2)
    0
}

pub fn stale_reference() -> u64 {
    // tallied exactly once per visit (DESIGN.md §9) // LINT-EXPECT[design-ref]
    0
}
