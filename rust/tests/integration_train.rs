//! Integration: full training loops through the PJRT runtime per mode —
//! the paper's headline claims at smoke scale. Requires `make artifacts`.

use zipml::data::synthetic::{make_classification, make_regression};
use zipml::quant::packing::PackedMatrix;
use zipml::quant::ColumnScale;
use zipml::rng::Rng;
use zipml::runtime::Runtime;
use zipml::sgd::modes::RefetchStrategy;
use zipml::sgd::{self, deep, Mode, ModelKind, StoreBackend, TrainConfig};
use zipml::store::{PrecisionSchedule, ShardedStore};

/// `None` ⇒ artifacts are not built in this checkout (e.g. the offline
/// stub `xla` backend): tests no-op rather than fail, mirroring
/// `real_manifest_loads_if_present`. Run `make artifacts` for full
/// coverage.
fn runtime() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping (artifacts unavailable): {e:#}");
            None
        }
    }
}

fn cfg(model: ModelKind, mode: Mode, epochs: usize, lr: f32) -> TrainConfig {
    let mut c = TrainConfig::new(model, mode);
    c.epochs = epochs;
    c.lr0 = lr;
    c.eval_batches = 4;
    c
}

/// Double-sampled 5-bit converges to ~the FP32 solution (Fig 4 claim).
#[test]
fn ds5_matches_fp32_linreg() {
    let Some(rt) = runtime() else { return };
    let ds = make_regression("it100", 2048, 256, 100, 7);
    let fp = sgd::train(&rt, &ds, &cfg(ModelKind::Linreg, Mode::Full, 10, 0.05)).unwrap();
    let q5 = sgd::train(&rt, &ds, &cfg(ModelKind::Linreg, Mode::DoubleSample { bits: 5 }, 10, 0.05))
        .unwrap();
    assert!(!fp.diverged && !q5.diverged);
    assert!(fp.final_loss < 0.2 * fp.loss_curve[0], "fp did not converge");
    // comparable convergence: within 2.5x of fp final (smoke tolerance)
    assert!(
        q5.final_loss < (2.5 * fp.final_loss).max(0.05 * q5.loss_curve[0]),
        "ds5 {} vs fp {}",
        q5.final_loss,
        fp.final_loss
    );
    // and the bandwidth win is real
    assert!(fp.sample_bytes_per_epoch / q5.sample_bytes_per_epoch > 4.0);
}

/// Naive quantization at low bits is measurably worse than double sampling
/// on a large-minimizer instance (§B.1).
#[test]
fn naive_is_biased_ds_is_not() {
    let Some(rt) = runtime() else { return };
    // large x*: shift labels so minimizer is far from origin
    let mut ds = make_regression("bias_it", 2048, 256, 10, 9);
    let boost: Vec<f32> = ds.train_a.matvec(&vec![2.0; 10]);
    for (b, add) in ds.train_b.iter_mut().zip(&boost) {
        *b += add;
    }
    let boost_t: Vec<f32> = ds.test_a.matvec(&vec![2.0; 10]);
    for (b, add) in ds.test_b.iter_mut().zip(&boost_t) {
        *b += add;
    }
    let naive = sgd::train(&rt, &ds, &cfg(ModelKind::Linreg, Mode::Naive { bits: 2 }, 25, 0.1))
        .unwrap();
    let dsq = sgd::train(&rt, &ds, &cfg(ModelKind::Linreg, Mode::DoubleSample { bits: 2 }, 25, 0.1))
        .unwrap();
    assert!(
        naive.final_loss > 2.0 * dsq.final_loss,
        "bias not visible: naive {} vs ds {}",
        naive.final_loss,
        dsq.final_loss
    );
}

/// u8-index path trains equivalently to the f32 DS path.
#[test]
fn ds_u8_path_trains() {
    let Some(rt) = runtime() else { return };
    let ds = make_regression("u8run", 1024, 128, 100, 11);
    let r = sgd::train(&rt, &ds, &cfg(ModelKind::Linreg, Mode::DoubleSampleU8 { bits: 4 }, 8, 0.05))
        .unwrap();
    assert!(!r.diverged);
    assert!(r.final_loss < 0.3 * r.loss_curve[0], "{:?}", r.loss_curve);
}

/// End-to-end quantization (samples+model+gradient) still converges (§E).
#[test]
fn end_to_end_converges() {
    let Some(rt) = runtime() else { return };
    let ds = make_regression("e2e", 2048, 128, 100, 13);
    let r = sgd::train(
        &rt,
        &ds,
        &cfg(ModelKind::Linreg, Mode::EndToEnd { bits_s: 6, bits_m: 8, bits_g: 8 }, 10, 0.05),
    )
    .unwrap();
    assert!(!r.diverged);
    assert!(r.final_loss < 0.3 * r.loss_curve[0], "{:?}", r.loss_curve);
}

/// §C: quantizing only the model (8-bit) is unbiased and converges.
#[test]
fn model_only_quant_converges() {
    let Some(rt) = runtime() else { return };
    let ds = make_regression("mq", 2048, 128, 100, 47);
    let r = sgd::train(&rt, &ds, &cfg(ModelKind::Linreg, Mode::ModelQuant { bits: 8 }, 10, 0.05))
        .unwrap();
    assert!(!r.diverged);
    assert!(r.final_loss < 0.3 * r.loss_curve[0], "{:?}", r.loss_curve);
}

/// §D: quantizing only the gradient (QSGD-style, 8-bit) converges.
#[test]
fn grad_only_quant_converges() {
    let Some(rt) = runtime() else { return };
    let ds = make_regression("gq", 2048, 128, 100, 53);
    let r = sgd::train(&rt, &ds, &cfg(ModelKind::Linreg, Mode::GradQuant { bits: 8 }, 10, 0.05))
        .unwrap();
    assert!(!r.diverged);
    assert!(r.final_loss < 0.3 * r.loss_curve[0], "{:?}", r.loss_curve);
}

/// Variance-optimal levels converge at least as well as uniform at equal
/// level count (Fig 7a/8 claim, smoke scale).
#[test]
fn optimal_levels_at_least_as_good() {
    let Some(rt) = runtime() else { return };
    let ds = make_regression("yearprediction", 2048, 128, 90, 17);
    let uni =
        sgd::train(&rt, &ds, &cfg(ModelKind::Linreg, Mode::DoubleSample { bits: 3 }, 10, 0.05))
            .unwrap();
    let opt = sgd::train(&rt, &ds, &cfg(ModelKind::Linreg, Mode::OptimalDs { levels: 8 }, 10, 0.05))
        .unwrap();
    assert!(!opt.diverged);
    assert!(
        opt.final_loss < 1.5 * uni.final_loss,
        "optimal {} vs uniform {}",
        opt.final_loss,
        uni.final_loss
    );
}

/// LS-SVM with double sampling trains on classification data (§F.1).
#[test]
fn lssvm_ds_trains() {
    let Some(rt) = runtime() else { return };
    let ds = make_classification("lssvm", 2048, 512, 100, 19);
    let r = sgd::train(
        &rt,
        &ds,
        &cfg(ModelKind::Lssvm { c: 1e-4 }, Mode::DoubleSample { bits: 5 }, 10, 0.5),
    )
    .unwrap();
    assert!(!r.diverged);
    assert!(r.final_loss < r.loss_curve[0]);
    // labels carry ~15% boundary noise by construction; 0.62 ≫ chance
    assert!(ds.test_accuracy(&r.final_model) > 0.62, "acc {}", ds.test_accuracy(&r.final_model));
}

/// Logistic via Chebyshev approximation converges; naive rounding matches
/// (the §5.4 negative result).
#[test]
fn cheby_and_rounding_both_work() {
    let Some(rt) = runtime() else { return };
    let ds = make_classification("cheb", 2048, 512, 100, 23);
    let fp = sgd::train(&rt, &ds, &cfg(ModelKind::Logistic, Mode::Full, 10, 0.5)).unwrap();
    let ch = sgd::train(&rt, &ds, &cfg(ModelKind::Logistic, Mode::Cheby { bits: 4 }, 10, 0.5))
        .unwrap();
    let rd =
        sgd::train(&rt, &ds, &cfg(ModelKind::Logistic, Mode::NearestRound { bits: 8 }, 10, 0.5))
            .unwrap();
    assert!(!ch.diverged && !rd.diverged);
    let l0 = fp.loss_curve[0];
    assert!(fp.final_loss < 0.9 * l0);
    assert!(ch.final_loss < 0.95 * l0, "cheby didn't descend: {:?}", ch.loss_curve);
    assert!(rd.final_loss < 0.95 * l0, "rounding didn't descend");
    // negative result: rounding is no worse than chebyshev (tolerance 25%)
    assert!(rd.final_loss < 1.25 * ch.final_loss.max(1e-6));
}

/// Unbiased polynomial (multi-sample) estimator descends (§4.1).
#[test]
fn poly_ds_descends() {
    let Some(rt) = runtime() else { return };
    let ds = make_classification("poly", 1024, 256, 100, 29);
    let r = sgd::train(&rt, &ds, &cfg(ModelKind::Logistic, Mode::PolyDs { bits: 4 }, 8, 0.2))
        .unwrap();
    assert!(!r.diverged);
    assert!(r.final_loss < 0.98 * r.loss_curve[0], "{:?}", r.loss_curve);
}

/// SVM refetching: converges and refetches a small fraction at 8 bits (§G).
#[test]
fn svm_refetch_small_fraction() {
    let Some(rt) = runtime() else { return };
    let ds = make_classification("refetch", 2048, 512, 100, 31);
    let r = sgd::train(
        &rt,
        &ds,
        &cfg(ModelKind::Svm, Mode::Refetch { bits: 8, strategy: RefetchStrategy::L1 }, 8, 0.2),
    )
    .unwrap();
    assert!(!r.diverged);
    assert!(r.final_loss < r.loss_curve[0]);
    assert!(r.refetch_fraction < 0.35, "refetch fraction {}", r.refetch_fraction);
    // fewer bits → more refetches
    let r4 = sgd::train(
        &rt,
        &ds,
        &cfg(ModelKind::Svm, Mode::Refetch { bits: 4, strategy: RefetchStrategy::L1 }, 4, 0.2),
    )
    .unwrap();
    assert!(
        r4.refetch_fraction > r.refetch_fraction,
        "{} !> {}",
        r4.refetch_fraction,
        r.refetch_fraction
    );
}

/// JL-sketch refetch path runs end to end.
#[test]
fn svm_refetch_jl_runs() {
    let Some(rt) = runtime() else { return };
    let ds = make_classification("refetchjl", 1024, 128, 100, 37);
    let r = sgd::train(
        &rt,
        &ds,
        &cfg(
            ModelKind::Svm,
            Mode::Refetch {
                bits: 8,
                strategy: RefetchStrategy::L2Jl { sketch_dim: 64, delta: 0.05 },
            },
            5,
            0.2,
        ),
    )
    .unwrap();
    assert!(!r.diverged);
}

/// Quantized-model MLP training descends and evaluates (Fig 7b smoke).
#[test]
fn mlp_quantized_model_trains() {
    let Some(rt) = runtime() else { return };
    let data = deep::make_deep_dataset(512, 256, 41);
    let fp = deep::train_mlp(&rt, &data, deep::WeightQuant::FullPrecision, 3, 0.1, 41).unwrap();
    let opt = deep::train_mlp(&rt, &data, deep::WeightQuant::Optimal { levels: 5 }, 3, 0.1, 41)
        .unwrap();
    assert!(fp.train_loss_curve.last().unwrap() < &fp.train_loss_curve[0]);
    assert!(opt.train_loss_curve.last().unwrap() < &opt.train_loss_curve[0]);
    assert!(opt.final_test_acc > 0.15, "acc {}", opt.final_test_acc);
}

/// Store-backed driver path (weaved, any-precision) matches the legacy
/// `PackedMatrix` path at p=8 within tolerance, with store-accounted
/// bandwidth below the packed wire bytes (acceptance criterion).
#[test]
fn weaved_store_backend_matches_packed_path() {
    let Some(rt) = runtime() else { return };
    let ds = make_regression("weaved_it", 2048, 256, 100, 59);
    let legacy = sgd::train(&rt, &ds, &cfg(ModelKind::Linreg, Mode::Naive { bits: 8 }, 10, 0.05))
        .unwrap();
    let mut wcfg = cfg(ModelKind::Linreg, Mode::Naive { bits: 8 }, 10, 0.05);
    wcfg.store = StoreBackend::Weaved { shards: 8, schedule: PrecisionSchedule::Fixed(8) };
    let weaved = sgd::train(&rt, &ds, &wcfg).unwrap();
    assert!(!legacy.diverged && !weaved.diverged);
    let ratio = weaved.final_loss / legacy.final_loss.max(1e-12);
    assert!((0.5..2.0).contains(&ratio), "loss ratio {ratio}");
    // exact store accounting stays in the same regime as the wire estimate
    assert!(weaved.sample_bytes_per_epoch > 0.0);
    assert!(weaved.sample_bytes_per_epoch < 2048.0 * 100.0 * 4.0, "not below f32 bytes");
}

/// The weaved host paths (no artifacts needed) run in every checkout: the
/// session's dequantize oracle reproduces the legacy packed host path bit
/// for bit at full width, and the fused weaved-domain session tracks the
/// oracle with identical byte accounting.
#[test]
#[allow(deprecated)] // train_packed_host: the legacy baseline under test
fn weaved_host_path_matches_packed_exactly() {
    let ds = make_regression("weaved_host_it", 1024, 128, 48, 61);
    let scale = ColumnScale::from_data(&ds.train_a);
    let mut rng = Rng::new(5);
    let packed = PackedMatrix::quantize(&ds.train_a, &scale, 8, &mut rng);
    let store = ShardedStore::from_packed(&packed, 16);
    let session = sgd::HostSession::over(&ds, &store).epochs(8).batch(64).lr0(0.05).seed(9);
    let a = sgd::train_packed_host(&ds, &packed, 8, 64, 0.05, 9);
    let b = session.schedule(PrecisionSchedule::Fixed(8)).dequant_oracle().run().unwrap();
    assert_eq!(a.loss_curve, b.loss_curve);
    assert!(b.loss_curve.last().unwrap() < &(0.5 * b.loss_curve[0]), "no convergence");
    // the fused session (no f32 row materialization) tracks the oracle
    // and accounts exactly the same bytes
    let f = session.schedule(PrecisionSchedule::Fixed(8)).run().unwrap();
    assert_eq!(f.sample_bytes_per_epoch, b.sample_bytes_per_epoch);
    for (x, y) in b.loss_curve.iter().zip(&f.loss_curve) {
        assert!((x - y).abs() <= 2e-2 * (1.0 + x.abs()), "oracle {x} vs fused {y}");
    }
    // one stored copy at 8 bits serves a 2-bit reader at a quarter of the
    // row bytes (Fig 5's bandwidth knob, post-ingestion)
    let c = session.schedule(PrecisionSchedule::Fixed(2)).run().unwrap();
    assert!(c.sample_bytes_per_epoch * 3.9 < b.sample_bytes_per_epoch * 1.01);
}

/// Determinism: same seed → bit-identical loss curves.
#[test]
fn training_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let ds = make_regression("det", 1024, 128, 10, 43);
    let c = cfg(ModelKind::Linreg, Mode::DoubleSample { bits: 4 }, 4, 0.05);
    let a = sgd::train(&rt, &ds, &c).unwrap();
    let b = sgd::train(&rt, &ds, &c).unwrap();
    assert_eq!(a.loss_curve, b.loss_curve);
}
