//! SIMD twin property suite (DESIGN.md §12).
//!
//! Three layers, each asserting **bit** equality, never approximate:
//!
//! 1. **Primitive twins** — `masked_sum_dense` and `select_add_word_scalar`
//!    against an independent in-test re-statement of their documented
//!    schedules (eight lane accumulators, masked `+0.0` adds, the fixed
//!    `((a0+a1)+(a2+a3))+((a4+a5)+(a6+a7))` reduction). These run with the
//!    `simd` feature OFF too, so the suite is never vacuously green: the
//!    scalar oracle pins the scalar implementation to the contract the SIMD
//!    twin is then held to. With the feature ON, `kernel::simd::*` is
//!    additionally compared lane-for-lane.
//! 2. **Composition** — the dispatched blocked kernels (`dot_rows_block`,
//!    `axpy_rows_block`, and their DS variants) against a scalar oracle
//!    rebuilt from *public* scalar primitives over planes reconstructed via
//!    `read_row`, across shapes 63/64/65/130 × bits 1..=16 × all four
//!    [`GlmLoss`] multipliers, dense and rank-indexed. Whatever tier the
//!    probe picked, the result must equal the scalar composition.
//! 3. **Forced tiers** (`simd` feature only) — the one test allowed to call
//!    `dispatch::force_tier`, running the composition suite under both
//!    tiers explicitly.
//!
//! Plus `should_panic` twins for the poisoned-tail debug guard, and the
//! threshold-source equivalence (buffered vs direct carry draws).

use zipml::quant::ColumnScale;
use zipml::rng::Rng;
use zipml::sgd::{GlmLoss, ModelKind};
use zipml::store::kernel::{self, StepKernel, MASKED_SUM_SPARSE_BITS};
use zipml::store::WeavedMatrix;
use zipml::tensor::Matrix;

/// Column counts straddling the word boundary: one short word, exactly one
/// word, one word + 1 lane, and two words + 2 lanes.
const SHAPES: [usize; 4] = [63, 64, 65, 130];

fn models() -> [ModelKind; 4] {
    [ModelKind::Linreg, ModelKind::Lssvm { c: 1e-4 }, ModelKind::Logistic, ModelKind::Svm]
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: lane {i}: {x} vs {y}");
    }
}

/// Values with planted `+0.0` / `-0.0` lanes — the sign-of-zero cases the
/// masked-add contract (§8/§12) is about.
fn gen_values(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut g: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
    for i in (0..len).step_by(7) {
        g[i] = 0.0;
    }
    for i in (3..len).step_by(11) {
        g[i] = -0.0;
    }
    g
}

/// Dense, sparse, and boundary words (callers mask to the live lanes).
fn test_words(rng: &mut Rng) -> Vec<u64> {
    let mut ws = vec![0u64, !0u64, 1, 1 << 63, 0x8000_0001_0000_0001];
    for _ in 0..24 {
        ws.push(rng.next_u64());
    }
    for _ in 0..12 {
        ws.push(rng.next_u64() & rng.next_u64() & rng.next_u64());
    }
    ws
}

/// Independent re-statement of the documented `masked_sum_dense` schedule:
/// lane j accumulates g[8c+j]; unset lanes add an explicit `+0.0`; fixed
/// pairwise reduction. Deliberately NOT a copy of the implementation (no
/// bit masking tricks) — it encodes the contract, not the code.
fn masked_sum_oracle(word: u64, g: &[f32]) -> f32 {
    let g = &g[..g.len().min(64)];
    let mut acc = [0.0f32; 8];
    for (c, chunk) in g.chunks(8).enumerate() {
        for (j, &gv) in chunk.iter().enumerate() {
            if (word >> (8 * c + j)) & 1 == 1 {
                acc[j] += gv;
            } else {
                acc[j] += 0.0;
            }
        }
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Independent re-statement of the `select_add_word` contract: set lanes
/// add exactly `wgt·m[j]`, unset lanes add `+0.0` (so a `-0.0` already in
/// `out` is normalized to `+0.0` by both sides identically).
fn select_add_oracle(word: u64, wgt: f32, m: &[f32], out: &mut [f32]) {
    let lanes = m.len().min(out.len()).min(64);
    for j in 0..lanes {
        if (word >> j) & 1 == 1 {
            out[j] += wgt * m[j];
        } else {
            out[j] += 0.0;
        }
    }
}

/// Named by the `// twin:` contract comment at the `masked_sum` dispatch
/// site (lint rule `twin-contract-v2`).
#[test]
fn simd_masked_sum_bit_identical_to_scalar() {
    let mut rng = Rng::new(0x51D0);
    for &len in &SHAPES {
        let live = len.min(64);
        let mask = if live == 64 { !0u64 } else { (1u64 << live) - 1 };
        for trial in 0..40 {
            let g = gen_values(&mut rng, len);
            for word in test_words(&mut rng) {
                let word = word & mask;
                let want = masked_sum_oracle(word, &g);
                let scalar = kernel::masked_sum_dense(word, &g);
                assert_eq!(
                    scalar.to_bits(),
                    want.to_bits(),
                    "scalar schedule drifted from contract: len={len} trial={trial} word={word:#x}"
                );
                #[cfg(feature = "simd")]
                {
                    let simd = kernel::simd::masked_sum_dense(word, &g);
                    assert_eq!(
                        simd.to_bits(),
                        scalar.to_bits(),
                        "simd twin diverged: len={len} trial={trial} word={word:#x}"
                    );
                }
            }
        }
    }
}

/// Named by the `// twin:` contract comment at the `select_add_word`
/// dispatch site. Weights come from all four GLM step multipliers so the
/// exact zeros the hinge emits and the saturated `-0.0` the logistic
/// multiplier emits both cross the select masks.
#[test]
fn simd_select_add_bit_identical_to_scalar() {
    let mut rng = Rng::new(0x5E1E);
    for &len in &SHAPES {
        let live = len.min(64);
        let mask = if live == 64 { !0u64 } else { (1u64 << live) - 1 };
        for model in models() {
            for trial in 0..12 {
                let m = gen_values(&mut rng, len);
                let dot = 4.0 * rng.normal();
                let target = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
                let wgt = model.multiplier(dot, target);
                let mut seed_out: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
                for i in (0..len).step_by(5) {
                    seed_out[i] = -0.0;
                }
                for word in test_words(&mut rng) {
                    let word = word & mask;
                    let mut want = seed_out.clone();
                    select_add_oracle(word, wgt, &m, &mut want);
                    let mut scalar = seed_out.clone();
                    kernel::select_add_word_scalar(word, wgt, &m, &mut scalar);
                    let what =
                        format!("select_add {} len={len} trial={trial} word={word:#x}", model.label());
                    assert_bits_eq(&scalar, &want, &what);
                    #[cfg(feature = "simd")]
                    {
                        let mut simd = seed_out.clone();
                        kernel::simd::select_add_word(word, wgt, &m, &mut simd);
                        assert_bits_eq(&simd, &scalar, &format!("simd twin: {what}"));
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Composition layer: dispatched kernels vs a scalar oracle rebuilt from
// public scalar primitives over planes reconstructed through `read_row`.
// ---------------------------------------------------------------------------

fn mk_store(cols: usize, bits: u32, seed: u64) -> WeavedMatrix {
    const ROWS: usize = 5;
    let mut rng = Rng::new(seed);
    let mut data: Vec<f32> = (0..ROWS * cols).map(|_| rng.normal()).collect();
    // a sparse stretch produces zero plane words (rank-index skip targets)
    for v in data.iter_mut().skip(cols / 3).step_by(3) {
        *v = 0.0;
    }
    let a = Matrix::from_vec(ROWS, cols, data);
    let mut scale = ColumnScale::from_data(&a);
    if cols > 2 {
        scale.m[1] = 0.0; // zero-scale columns stay inert through every path
    }
    WeavedMatrix::quantize(&a, &scale, bits, &mut rng)
}

/// Reconstruct the p-truncated bit planes of row `r` from the public
/// `read_row`: bit (p−1−t) of the truncated index IS plane t.
fn planes_of(w: &WeavedMatrix, r: usize, p: u32) -> (Vec<u64>, usize) {
    let wpp = w.words_per_plane();
    let mut idx = vec![0u16; w.cols];
    w.read_row(r, p, &mut idx);
    let mut planes = vec![0u64; p as usize * wpp];
    for (c, &v) in idx.iter().enumerate() {
        for t in 0..p as usize {
            if (v >> (p as usize - 1 - t)) & 1 == 1 {
                planes[t * wpp + c / 64] |= 1u64 << (c % 64);
            }
        }
    }
    (planes, wpp)
}

/// The scalar `masked_sum` dispatch rule: popcount picks sparse vs dense.
fn masked_sum_scalar(word: u64, g: &[f32]) -> f32 {
    if word.count_ones() <= MASKED_SUM_SPARSE_BITS {
        kernel::masked_sum_sparse(word, g)
    } else {
        kernel::masked_sum_dense(word, g)
    }
}

/// Scalar oracle for the fused truncating dot (dot_planes' documented
/// plane-major order, per-plane f64 partial sums).
fn dot_oracle(w: &WeavedMatrix, r: usize, p: u32, k: &StepKernel) -> f32 {
    let (planes, wpp) = planes_of(w, r, p);
    let inv_s2 = 2.0 / ((1u32 << p) - 1) as f32;
    let mut acc = 0.0f64;
    for t in 0..p as usize {
        let weight = (1u64 << (p as usize - 1 - t)) as f64;
        let mut psum = 0.0f64;
        for (wi, &word) in planes[t * wpp..(t + 1) * wpp].iter().enumerate() {
            if word != 0 {
                psum += masked_sum_scalar(word, &k.g()[wi * 64..]) as f64;
            }
        }
        acc += weight * psum;
    }
    (inv_s2 as f64 * acc - k.sum_g() as f64) as f32
}

/// Scalar oracle for the blocked truncating axpy (plane part only): per
/// row, per plane MSB-first, per word ascending — the dense visit order
/// the rank-indexed path also reproduces.
fn axpy_oracle(w: &WeavedMatrix, rows: &[usize], p: u32, coefs: &[f32], out: &mut [f32]) {
    let inv_s2 = 2.0 / ((1u32 << p) - 1) as f32;
    for (&r, &coef) in rows.iter().zip(coefs) {
        let (planes, wpp) = planes_of(w, r, p);
        for t in 0..p as usize {
            let wgt = coef * inv_s2 * (1u64 << (p as usize - 1 - t)) as f32;
            for (wi, &word) in planes[t * wpp..(t + 1) * wpp].iter().enumerate() {
                if word != 0 {
                    kernel::select_add_word_scalar(
                        word,
                        wgt,
                        &w.scale.m[wi * 64..],
                        &mut out[wi * 64..],
                    );
                }
            }
        }
    }
}

/// Scalar oracle for the stochastic dot: full-width planes, word-major,
/// fine-grid plane weights, carry via the public `carry_mask_word` +
/// `BufferedThresholds` — the exact documented DS order.
fn dot_ds_oracle(w: &WeavedMatrix, r: usize, p: u32, k: &StepKernel, rng: &mut Rng) -> f32 {
    let (planes, wpp) = planes_of(w, r, w.bits);
    let bits = w.bits as usize;
    let inv_s2 = 2.0 / w.s as f32;
    let carry_w = (1u64 << (bits - p as usize)) as f64;
    let mut acc = 0.0f64;
    let mut thresholds = kernel::BufferedThresholds::new(rng);
    for wi in 0..wpp {
        let g = &k.g()[wi * 64..];
        for t in 0..p as usize {
            let word = planes[t * wpp + wi];
            if word != 0 {
                acc += (1u64 << (bits - 1 - t)) as f64 * masked_sum_scalar(word, g) as f64;
            }
        }
        let carry = kernel::carry_mask_word(&planes, wpp, w.bits, p, wi, &mut thresholds);
        if carry != 0 {
            acc += carry_w * masked_sum_scalar(carry, g) as f64;
        }
    }
    (inv_s2 as f64 * acc - k.sum_g() as f64) as f32
}

/// Scalar oracle for one row of the stochastic axpy (plane part only),
/// mirroring the lane-parallel core's word-major order and per-row-call
/// threshold buffer.
fn axpy_ds_oracle(w: &WeavedMatrix, r: usize, p: u32, coef: f32, rng: &mut Rng, out: &mut [f32]) {
    let (planes, wpp) = planes_of(w, r, w.bits);
    let bits = w.bits as usize;
    let m = &w.scale.m;
    let inv_s2 = 2.0 / w.s as f32;
    let carry_wgt = coef * inv_s2 * (1u64 << (bits - p as usize)) as f32;
    let mut thresholds = kernel::BufferedThresholds::new(rng);
    for wi in 0..wpp {
        let c0 = wi * 64;
        for t in 0..p as usize {
            let wgt = coef * inv_s2 * (1u64 << (bits - 1 - t)) as f32;
            let word = planes[t * wpp + wi];
            if word != 0 {
                kernel::select_add_word_scalar(word, wgt, &m[c0..], &mut out[c0..]);
            }
        }
        let carry = kernel::carry_mask_word(&planes, wpp, w.bits, p, wi, &mut thresholds);
        if carry != 0 {
            kernel::select_add_word_scalar(carry, carry_wgt, &m[c0..], &mut out[c0..]);
        }
    }
}

/// The composition property: every dispatched kernel equals its scalar
/// oracle bit-for-bit, dense and rank-indexed, all four GLM multipliers,
/// DS streams consumed identically.
fn run_composition_suite(shapes: &[usize], bit_widths: &[u32]) {
    let rows = [4usize, 0, 2, 2, 1];
    for &cols in shapes {
        for &bits in bit_widths {
            let mut w = mk_store(cols, bits, 0xC0DE + cols as u64 * 31 + bits as u64);
            let mut rng = Rng::new(0x11 * cols as u64 + bits as u64);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
            let targets: Vec<f32> = rows
                .iter()
                .map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
                .collect();
            let mut k = StepKernel::new(cols);
            k.refresh(&w.scale.m, &x);
            for indexed in [false, true] {
                if indexed {
                    w.build_plane_index();
                }
                for p in [1, bits.div_ceil(2), bits] {
                    let tag = format!("cols={cols} bits={bits} p={p} indexed={indexed}");

                    let mut dots = vec![0.0f32; rows.len()];
                    kernel::dot_rows_block(&w, &rows, p, &k, &mut dots);
                    for (i, &r) in rows.iter().enumerate() {
                        let want = dot_oracle(&w, r, p, &k);
                        assert_eq!(dots[i].to_bits(), want.to_bits(), "blocked dot row {r}: {tag}");
                        let single = kernel::dot_row(&w, r, p, &k);
                        assert_eq!(single.to_bits(), want.to_bits(), "dot_row {r}: {tag}");
                    }

                    for model in models() {
                        let coefs: Vec<f32> = dots
                            .iter()
                            .zip(&targets)
                            .map(|(&d, &t)| model.multiplier(d, t))
                            .collect();
                        let coef_sum = coefs.iter().sum::<f32>();
                        let mut got = vec![0.0f32; cols];
                        kernel::axpy_rows_block(&w, &rows, p, &coefs, &mut got);
                        kernel::axpy_affine(coef_sum, &w.scale.m, &mut got);
                        let mut want = vec![0.0f32; cols];
                        axpy_oracle(&w, &rows, p, &coefs, &mut want);
                        kernel::axpy_affine(coef_sum, &w.scale.m, &mut want);
                        assert_bits_eq(&got, &want, &format!("axpy {}: {tag}", model.label()));
                    }

                    // DS twins on twin streams; end states must agree too,
                    // so the buffered path provably consumed the same draws.
                    let seed = 0xD5_0000 ^ ((cols as u64) << 8) ^ ((bits as u64) << 4) ^ p as u64;
                    let mut ra = Rng::new(seed);
                    let mut rb = Rng::new(seed);
                    let mut ds = vec![0.0f32; rows.len()];
                    kernel::dot_rows_block_ds(&w, &rows, p, &k, &mut ra, &mut ds);
                    for (i, &r) in rows.iter().enumerate() {
                        let want = dot_ds_oracle(&w, r, p, &k, &mut rb);
                        assert_eq!(ds[i].to_bits(), want.to_bits(), "DS dot row {r}: {tag}");
                    }
                    let coefs: Vec<f32> =
                        ds.iter().zip(&targets).map(|(&d, &t)| d - t).collect();
                    let mut got = vec![0.0f32; cols];
                    kernel::axpy_rows_block_ds(&w, &rows, p, &coefs, &mut ra, &mut got);
                    let mut want = vec![0.0f32; cols];
                    for (&r, &coef) in rows.iter().zip(&coefs) {
                        axpy_ds_oracle(&w, r, p, coef, &mut rb, &mut want);
                    }
                    assert_bits_eq(&got, &want, &format!("DS axpy: {tag}"));
                    assert_eq!(ra.next_u64(), rb.next_u64(), "DS stream end state: {tag}");
                }
            }
        }
    }
}

#[test]
fn fused_glm_composition_matches_scalar_oracle_bitwise() {
    run_composition_suite(&SHAPES, &(1..=16).collect::<Vec<u32>>());
}

/// The ONE test allowed to force the process-global dispatch tier.
/// Concurrent tests in this binary keep passing during the flips precisely
/// because the twins are bit-identical — tier choice is unobservable.
#[cfg(feature = "simd")]
#[test]
fn forced_tiers_agree_bitwise_end_to_end() {
    use zipml::store::kernel::dispatch::{force_tier, tier, Tier};
    let probed = tier();
    for t in [Tier::Scalar, Tier::Lanes8] {
        force_tier(t);
        run_composition_suite(&[65, 130], &[3, 8, 16]);
    }
    force_tier(probed);
}

/// Buffered and direct threshold sources must sample identical carries:
/// served threshold k is raw draw k regardless of the wrapper.
#[test]
fn buffered_and_direct_threshold_sources_sample_identical_carries() {
    let bits = 6u32;
    let wpp = 2usize;
    let mut plane_rng = Rng::new(0xCA881);
    let planes: Vec<u64> = (0..bits as usize * wpp).map(|_| plane_rng.next_u64()).collect();
    for p in 1..=bits {
        let mut direct = Rng::new(0x7117 + p as u64);
        let mut raw = Rng::new(0x7117 + p as u64);
        let mut buffered = kernel::BufferedThresholds::new(&mut raw);
        for wi in 0..wpp {
            let a = kernel::carry_mask_word(&planes, wpp, bits, p, wi, &mut direct);
            let b = kernel::carry_mask_word(&planes, wpp, bits, p, wi, &mut buffered);
            assert_eq!(a, b, "carry mask diverged: p={p} wi={wi}");
        }
    }
}

/// Poisoned-tail `should_panic` twins: the debug guard must hold the SIMD
/// twin to the same weaved tail contract as the scalar path.
#[cfg(debug_assertions)]
mod poisoned_tail {
    #[test]
    #[should_panic(expected = "tail contract")]
    fn scalar_select_add_rejects_poisoned_tail() {
        let m = vec![1.0f32; 10];
        let mut out = vec![0.0f32; 10];
        zipml::store::kernel::select_add_word_scalar(1u64 << 10, 1.0, &m, &mut out);
    }

    #[cfg(feature = "simd")]
    #[test]
    #[should_panic(expected = "tail contract")]
    fn simd_select_add_rejects_poisoned_tail() {
        let m = vec![1.0f32; 10];
        let mut out = vec![0.0f32; 10];
        zipml::store::kernel::simd::select_add_word(1u64 << 10, 1.0, &m, &mut out);
    }
}
