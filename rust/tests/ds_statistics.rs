//! Statistical correctness harness for host-native double sampling in the
//! weaved domain (DESIGN.md §5) — the paper's §2.2/Fig 1/Fig 3 claims as
//! tests, artifact-free, deterministic under fixed seeds, no `#[ignore]`.
//!
//! * **Unbiasedness of the stochastic read**: over many seeded draws the
//!   mean plane-rounded dequantize matches the stored value within a
//!   CLT-derived tolerance, while deterministic truncation is measurably
//!   biased (Fig 1's "naive quantization is biased" claim).
//! * **Unbiasedness of the fused DS gradient**: the mean double-sampled
//!   minibatch gradient matches the full-precision gradient of the stored
//!   data within a self-calibrated 5σ tolerance; the truncation gradient
//!   does not.
//! * **End-to-end (Fig 3's positive/negative pair)**: low-precision
//!   double-sampled weaved training reaches the fp32 SGD loss on the
//!   synthetic and tomography workloads while naive truncation plateaus
//!   measurably above it — with the DS path's byte accounting exactly 2×
//!   the truncating path's.
//!
//! Tolerances were calibrated against a bit-exact simulation of the carry
//! kernels (margins ≥ 3× everywhere; e2e ratios observed: synthetic
//! trunc@2 ≥ 9× fp vs asserted 3×, tomography trunc@1 ≥ 3.3× fp vs
//! asserted 2×, DS within 1.05× fp vs asserted 1.25×).

use zipml::data::synthetic::make_regression;
use zipml::data::{tomo, Dataset};
use zipml::quant::ColumnScale;
use zipml::rng::Rng;
use zipml::sgd::{lr_at_epoch, HostSession, ReadStrategy, SessionResult};
use zipml::store::{PrecisionSchedule, QuantStepKernel, ShardedStore, StepKernel};
use zipml::tensor::{axpy, dot};

/// Truncating host session at fixed read precision p — the weaved-domain
/// fused path the statistics below measure.
fn host_trunc(
    ds: &Dataset,
    store: &ShardedStore,
    p: u32,
    epochs: usize,
    batch: usize,
    lr0: f32,
    seed: u64,
) -> SessionResult {
    HostSession::over(ds, store)
        .schedule(PrecisionSchedule::Fixed(p))
        .epochs(epochs)
        .batch(batch)
        .lr0(lr0)
        .seed(seed)
        .run()
        .expect("truncating session")
}

/// Double-sampled host session at fixed read precision p (§2.2).
fn host_ds(
    ds: &Dataset,
    store: &ShardedStore,
    p: u32,
    epochs: usize,
    batch: usize,
    lr0: f32,
    seed: u64,
) -> SessionResult {
    HostSession::over(ds, store)
        .schedule(PrecisionSchedule::Fixed(p))
        .read(ReadStrategy::DoubleSample)
        .epochs(epochs)
        .batch(batch)
        .lr0(lr0)
        .seed(seed)
        .run()
        .expect("double-sampled session")
}

/// Full-precision dense minibatch SGD with the host skeleton's semantics
/// (per-epoch shuffle, lr0/(e+1), short final batch) — the fp32 reference
/// the quantized paths are measured against.
fn dense_sgd(ds: &Dataset, epochs: usize, batch: usize, lr0: f32, seed: u64) -> f64 {
    let n = ds.n();
    let k = ds.k_train();
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; n];
    let mut order: Vec<usize> = (0..k).collect();
    let mut grad = vec![0.0f32; n];
    for epoch in 0..epochs {
        let lr = lr_at_epoch(lr0, epoch);
        rng.shuffle(&mut order);
        for bi in 0..k.div_ceil(batch) {
            let rows = &order[bi * batch..((bi + 1) * batch).min(k)];
            grad.fill(0.0);
            for &r in rows {
                let row = ds.train_a.row(r);
                let err = dot(row, &x) - ds.train_b[r];
                axpy(err, row, &mut grad);
            }
            axpy(-lr / rows.len() as f32, &grad, &mut x);
        }
    }
    ds.train_mse(&x)
}

/// Mean stochastic plane-rounded dequantize → stored value (CLT budget);
/// deterministic truncation → measurably outside the same budget. Three
/// distinct fixed seeds.
#[test]
fn stochastic_read_unbiased_truncation_biased() {
    for seed in [101u64, 202, 303] {
        let (rows, cols, bits, p) = (8usize, 40usize, 8u32, 3u32);
        let ds = make_regression("ds_stat", rows, 4, cols, seed);
        let sc = ColumnScale::from_data(&ds.train_a);
        let store = ShardedStore::ingest(&ds.train_a, &sc, bits, seed ^ 7, 3, 1);
        let q = (1u32 << (bits - p)) as f64;
        let s = ((1u32 << bits) - 1) as f64;
        let draws = 3000usize;
        let mut rng = Rng::new_stream(seed, 1);
        let mut val = vec![0.0f32; cols];
        let mut stored = vec![0.0f32; cols];
        let mut trunc = vec![0.0f32; cols];
        for r in 0..rows {
            let mut acc = vec![0.0f64; cols];
            for _ in 0..draws {
                store.dequantize_row_ds(r, p, &mut rng, &mut val);
                for (a, &v) in acc.iter_mut().zip(&val) {
                    *a += v as f64;
                }
            }
            store.dequantize_row(r, bits, &mut stored);
            store.dequantize_row(r, p, &mut trunc);
            let mut biased = 0usize;
            for c in 0..cols {
                let mean = acc[c] / draws as f64;
                // one draw spans at most one coarse interval → std ≤ step/2
                let step = q * 2.0 * sc.m[c] as f64 / s;
                let tol = 5.0 * (step / 2.0) / (draws as f64).sqrt() + 1e-6;
                assert!(
                    (mean - stored[c] as f64).abs() <= tol,
                    "seed {seed} r={r} c={c}: mean {mean} vs stored {} (tol {tol})",
                    stored[c]
                );
                if (trunc[c] as f64 - stored[c] as f64).abs() > 3.0 * tol {
                    biased += 1;
                }
            }
            assert!(
                biased * 3 >= cols,
                "seed {seed} r={r}: truncation biased on only {biased}/{cols} columns"
            );
        }
    }
}

/// The mean fused double-sampled minibatch gradient matches the
/// full-precision gradient of the stored data within a self-calibrated
/// 5σ/√N tolerance; the truncation gradient at the same read precision is
/// far outside it (Fig 1's claim, as a test). Three distinct fixed seeds.
#[test]
fn ds_gradient_unbiased_truncation_gradient_biased() {
    for seed in [11u64, 22, 33] {
        let (rows, cols, bits, p) = (16usize, 24usize, 8u32, 2u32);
        let ds = make_regression("ds_grad_stat", rows, 4, cols, seed);
        let sc = ColumnScale::from_data(&ds.train_a);
        let store = ShardedStore::ingest(&ds.train_a, &sc, bits, seed ^ 13, 2, 1);
        let mut rng = Rng::new_stream(seed, 2);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(cols);
        k.refresh(&sc.m, &x);
        let batch: Vec<usize> = (0..rows).collect();
        let targets: Vec<f32> = batch.iter().map(|&r| ds.train_b[r]).collect();

        // reference: full-precision gradient of the stored (b-bit) values
        let mut row = vec![0.0f32; cols];
        let mut g_ref = vec![0.0f64; cols];
        for (&r, &t) in batch.iter().zip(&targets) {
            store.dequantize_row(r, bits, &mut row);
            let err = dot(&row, &x) - t;
            for (g, &v) in g_ref.iter_mut().zip(&row) {
                *g += err as f64 * v as f64;
            }
        }

        // mean + variance of the double-sampled gradient, 5σ budget
        let draws = 3000usize;
        let mut sum = vec![0.0f64; cols];
        let mut sumsq = vec![0.0f64; cols];
        let mut grad = vec![0.0f32; cols];
        for _ in 0..draws {
            grad.fill(0.0);
            store.ds_grad_batch(&batch, p, &k, &targets, &mut rng, &mut grad);
            for ((s1, s2), &g) in sum.iter_mut().zip(sumsq.iter_mut()).zip(&grad) {
                *s1 += g as f64;
                *s2 += (g as f64) * (g as f64);
            }
        }

        // truncation gradient at the same read precision
        let mut g_tr = vec![0.0f32; cols];
        store.fused_grad_batch(&batch, p, &k, &targets, &mut g_tr);

        let mut tr_outside = 0usize;
        let mut norm_ref = 0.0f64;
        let mut norm_tr_err = 0.0f64;
        for c in 0..cols {
            let mean = sum[c] / draws as f64;
            let var = (sumsq[c] / draws as f64 - mean * mean).max(0.0);
            let tol = 5.0 * (var / draws as f64).sqrt() + 1e-4;
            assert!(
                (mean - g_ref[c]).abs() <= tol,
                "seed {seed} c={c}: mean DS grad {mean} vs fp {} (tol {tol})",
                g_ref[c]
            );
            if (g_tr[c] as f64 - g_ref[c]).abs() > 5.0 * tol {
                tr_outside += 1;
            }
            norm_ref += g_ref[c] * g_ref[c];
            norm_tr_err += (g_tr[c] as f64 - g_ref[c]).powi(2);
        }
        assert!(
            tr_outside * 4 >= cols,
            "seed {seed}: truncation gradient outside 5× budget on only {tr_outside}/{cols}"
        );
        assert!(
            norm_tr_err.sqrt() > 0.2 * norm_ref.sqrt(),
            "seed {seed}: truncation gradient bias too small: {} vs ‖g‖ {}",
            norm_tr_err.sqrt(),
            norm_ref.sqrt()
        );
    }
}

/// The popcount fast path's per-step rounding is unbiased for the f32
/// path (ISSUE 4 satellite (c)): the mean popcount minibatch gradient
/// over many rounding draws matches the exact fused gradient within a
/// self-calibrated 5σ/√N budget — at q as low as 2, where a single draw
/// is visibly noisy. Three distinct fixed seeds, CLT scaffolding shared
/// with the DS gradient harness above.
#[test]
fn popcount_gradient_unbiased_for_f32_path() {
    for seed in [41u64, 42, 43] {
        let (rows, cols, bits, p, q) = (16usize, 24usize, 8u32, 3u32, 2u32);
        let ds = make_regression("q_stat", rows, 4, cols, seed);
        let sc = ColumnScale::from_data(&ds.train_a);
        let store = ShardedStore::ingest(&ds.train_a, &sc, bits, seed ^ 3, 2, 1);
        let mut rng = Rng::new_stream(seed, 7);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(cols);
        k.refresh(&sc.m, &x);
        let batch: Vec<usize> = (0..rows).collect();
        let targets: Vec<f32> = batch.iter().map(|&r| ds.train_b[r]).collect();

        // reference: the exact fused gradient (f32 masked-sum path)
        let mut g_ref = vec![0.0f32; cols];
        store.fused_grad_batch(&batch, p, &k, &targets, &mut g_ref);

        // mean + variance of the popcount gradient over rounding draws
        let draws = 3000usize;
        let mut qk = QuantStepKernel::new(cols, q);
        let mut sum = vec![0.0f64; cols];
        let mut sumsq = vec![0.0f64; cols];
        let mut grad = vec![0.0f32; cols];
        let mut single_noisy = 0usize;
        for d in 0..draws {
            qk.refresh(&sc.m, &x, &mut rng);
            grad.fill(0.0);
            store.fused_grad_batch_q(&batch, p, &qk, &targets, &mut grad);
            if d == 0 {
                // a single q=2 draw is measurably off the exact gradient —
                // the unbiasedness below is doing real averaging work
                for c in 0..cols {
                    if (grad[c] - g_ref[c]).abs() > 1e-3 * (1.0 + g_ref[c].abs()) {
                        single_noisy += 1;
                    }
                }
            }
            for ((s1, s2), &g) in sum.iter_mut().zip(sumsq.iter_mut()).zip(&grad) {
                *s1 += g as f64;
                *s2 += (g as f64) * (g as f64);
            }
        }
        assert!(
            single_noisy * 3 >= cols,
            "seed {seed}: a single q=2 draw was noisy on only {single_noisy}/{cols} columns"
        );
        for c in 0..cols {
            let mean = sum[c] / draws as f64;
            let var = (sumsq[c] / draws as f64 - mean * mean).max(0.0);
            let tol = 5.0 * (var / draws as f64).sqrt() + 1e-4;
            assert!(
                (mean - g_ref[c] as f64).abs() <= tol,
                "seed {seed} c={c}: mean popcount grad {mean} vs exact {} (tol {tol})",
                g_ref[c]
            );
        }
    }
}

/// Fig 3's positive/negative pair on the synthetic workload: 4-bit (and
/// even 2-bit) double-sampled weaved training tracks the fp32 SGD loss;
/// 2-bit naive truncation plateaus measurably above it. DS byte accounting
/// is exactly 2× the truncating path's, and the DS run replays bit for
/// bit from its seed. Three distinct fixed seeds.
#[test]
fn e2e_synthetic_ds_converges_truncation_plateaus() {
    for seed in [7u64, 8, 9] {
        let ds = make_regression("ds_e2e", 512, 64, 32, seed);
        let sc = ColumnScale::from_data(&ds.train_a);
        let store = ShardedStore::ingest(&ds.train_a, &sc, 8, seed ^ 21, 4, 1);
        let (epochs, batch, lr0) = (60usize, 32usize, 0.1f32);

        let fp = dense_sgd(&ds, epochs, batch, lr0, seed);
        let ds4 = host_ds(&ds, &store, 4, epochs, batch, lr0, seed);
        let ds2 = host_ds(&ds, &store, 2, epochs, batch, lr0, seed);
        let tr2 = host_trunc(&ds, &store, 2, epochs, batch, lr0, seed);

        let l_ds4 = *ds4.loss_curve.last().unwrap();
        let l_ds2 = *ds2.loss_curve.last().unwrap();
        let l_tr2 = *tr2.loss_curve.last().unwrap();
        assert!(l_ds4 <= 1.25 * fp, "seed {seed}: ds@4 {l_ds4} not at fp optimum {fp}");
        assert!(l_ds2 <= 1.6 * fp, "seed {seed}: ds@2 {l_ds2} not near fp optimum {fp}");
        assert!(l_tr2 >= 3.0 * fp, "seed {seed}: trunc@2 {l_tr2} did not plateau above fp {fp}");
        assert!(l_tr2 >= 2.0 * l_ds2, "seed {seed}: trunc@2 {l_tr2} vs ds@2 {l_ds2}");

        // exact byte accounting: both DS fetches counted, 2× truncation
        assert_eq!(ds2.sample_bytes_per_epoch, 2.0 * tr2.sample_bytes_per_epoch, "seed {seed}");
        assert_eq!(
            tr2.sample_bytes_per_epoch,
            (512 * store.bytes_per_row(2)) as f64,
            "seed {seed}: truncation bytes not rows × plane spans"
        );

        // deterministic: the DS run replays bit for bit
        let again = host_ds(&ds, &store, 4, epochs, batch, lr0, seed);
        assert_eq!(ds4.loss_curve, again.loss_curve, "seed {seed}");
        assert_eq!(ds4.final_model, again.final_model, "seed {seed}");
    }
}

/// The same pair on the tomography workload (paper §1's motivating app):
/// double-sampled reads — even 1-bit draws — track the fp32 SGD loss on
/// the ray system, while 1-bit truncation plateaus far above it.
#[test]
fn e2e_tomography_ds_converges_truncation_plateaus() {
    let (ds, _img) = tomo::make_tomography(8, 24, 1);
    let sc = ColumnScale::from_data(&ds.train_a);
    let store = ShardedStore::ingest(&ds.train_a, &sc, 8, 5, 4, 1);
    let (epochs, batch, lr0) = (150usize, 32usize, 1.0f32);
    for seed in [7u64, 8] {
        let fp = dense_sgd(&ds, epochs, batch, lr0, seed);
        let ds4 = host_ds(&ds, &store, 4, epochs, batch, lr0, seed);
        let ds1 = host_ds(&ds, &store, 1, epochs, batch, lr0, seed);
        let tr1 = host_trunc(&ds, &store, 1, epochs, batch, lr0, seed);
        let l_ds4 = *ds4.loss_curve.last().unwrap();
        let l_ds1 = *ds1.loss_curve.last().unwrap();
        let l_tr1 = *tr1.loss_curve.last().unwrap();
        assert!(l_ds4 <= 1.25 * fp, "seed {seed}: tomo ds@4 {l_ds4} vs fp {fp}");
        assert!(l_ds1 <= 1.35 * fp, "seed {seed}: tomo ds@1 {l_ds1} vs fp {fp}");
        assert!(l_tr1 >= 2.0 * fp, "seed {seed}: tomo trunc@1 {l_tr1} did not plateau (fp {fp})");
        assert!(l_tr1 >= 1.8 * l_ds1, "seed {seed}: tomo trunc@1 {l_tr1} vs ds@1 {l_ds1}");
        // both fetches of every row visit are in the accounting, exactly
        assert_eq!(ds1.sample_bytes_per_epoch, 2.0 * tr1.sample_bytes_per_epoch);
    }
}
