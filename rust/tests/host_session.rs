//! The [`HostSession`] matrix: every GLM loss × read strategy × execution
//! composes through one engine — smoke convergence, exact byte
//! accounting, fixed-seed determinism, invalid-combination errors, and
//! the bit-for-bit shim contract of the nine legacy entry points.
//! Artifact-free: runs in every checkout.

use zipml::data::synthetic::{make_classification, make_regression};
use zipml::data::Dataset;
use zipml::fpga::hogwild::HogwildConfig;
use zipml::quant::ColumnScale;
use zipml::sgd::{self, Execution, GlmLoss, HostSession, ModelKind, ReadStrategy};
use zipml::store::{PrecisionSchedule, ShardedStore};

const MODELS: [ModelKind; 4] = [
    ModelKind::Linreg,
    ModelKind::Lssvm { c: 1e-3 },
    ModelKind::Logistic,
    ModelKind::Svm,
];

/// Per-model workload: regression data for the squared losses, ±1-label
/// classification data (row-normalized) for logistic and hinge, with a
/// learning rate stable for each task's gradient scale.
fn workload(model: ModelKind, seed: u64) -> (Dataset, f32) {
    if model.is_classification() {
        (make_classification("session_cls", 520, 64, 24, seed), 0.5)
    } else {
        (make_regression("session_reg", 520, 64, 24, seed), 0.05)
    }
}

fn store_for(ds: &Dataset, bits: u32, seed: u64) -> ShardedStore {
    let scale = ColumnScale::from_data(&ds.train_a);
    ShardedStore::ingest(&ds.train_a, &scale, bits, seed, 5, 1)
}

fn final_loss(curve: &[f64]) -> f64 {
    *curve.last().unwrap()
}

/// The full store-backed matrix: 4 GLMs × {Truncate, DoubleSample,
/// Popcount} × {Sequential, Hogwild}. Every combination runs, descends
/// from the initial loss, and accounts exactly rows × bytes_per_row(p)
/// per epoch (2× for the two DS fetches) — k % batch != 0, so the ragged
/// tail is in the accounting too.
#[test]
fn matrix_store_reads_converge_and_account_exactly() {
    let reads = [
        ReadStrategy::Truncate,
        ReadStrategy::DoubleSample,
        ReadStrategy::Popcount { q: 8 },
    ];
    let execs = [Execution::Sequential, Execution::Hogwild { threads: 2 }];
    for model in MODELS {
        let (ds, lr) = workload(model, 31);
        let store = store_for(&ds, 8, 77);
        // DS reads draw live carries below the stored width; the
        // deterministic reads run at a precision with real truncation too
        let p = 6u32;
        for read in reads {
            for exec in execs {
                let r = HostSession::over(&ds, &store)
                    .loss(&model)
                    .read(read)
                    .execution(exec)
                    .schedule(PrecisionSchedule::Fixed(p))
                    .epochs(10)
                    .batch(48)
                    .lr0(lr)
                    .seed(9)
                    .run()
                    .unwrap_or_else(|e| panic!("{model:?} × {read:?} × {exec:?}: {e:#}"));
                let tag = format!("{model:?} × {read:?} × {exec:?}");
                let (l0, lf) = (r.loss_curve[0], final_loss(&r.loss_curve));
                assert!(lf.is_finite(), "{tag}: non-finite loss");
                assert!(lf < 0.97 * l0, "{tag}: no descent ({l0} -> {lf})");
                assert_eq!(r.precisions, vec![p; 10], "{tag}");
                let per_fetch = (ds.k_train() * store.bytes_per_row(p)) as f64;
                let want = match read {
                    ReadStrategy::DoubleSample => 2.0 * per_fetch,
                    _ => per_fetch,
                };
                assert_eq!(r.sample_bytes_per_epoch, want, "{tag}: byte accounting");
                // hogwild applies one racy update per (epoch × row);
                // sequential applies one per batch
                let want_updates = match exec {
                    Execution::Sequential => 10 * ds.k_train().div_ceil(48),
                    Execution::Hogwild { .. } => 10 * ds.k_train(),
                };
                assert_eq!(r.updates, want_updates, "{tag}: update count");
            }
        }
    }
}

/// The dense (storeless, fp32) read serves the same 4 GLMs under both
/// executions — the baseline column of the matrix.
#[test]
fn matrix_dense_read_converges_all_models() {
    for model in MODELS {
        let (ds, lr) = workload(model, 33);
        for exec in [Execution::Sequential, Execution::Hogwild { threads: 2 }] {
            let r = HostSession::dense(&ds)
                .loss(&model)
                .execution(exec)
                .epochs(10)
                .batch(48)
                .lr0(lr)
                .seed(5)
                .run()
                .unwrap_or_else(|e| panic!("{model:?} dense × {exec:?}: {e:#}"));
            let tag = format!("{model:?} × dense × {exec:?}");
            assert!(
                final_loss(&r.loss_curve) < 0.97 * r.loss_curve[0],
                "{tag}: no descent ({} -> {})",
                r.loss_curve[0],
                final_loss(&r.loss_curve)
            );
            assert_eq!(r.sample_bytes_per_epoch, (ds.k_train() * ds.n() * 4) as f64, "{tag}");
            assert_eq!(r.precisions, vec![32; 10], "{tag}");
        }
    }
}

/// Fixed-seed determinism: every sequential (model × read) combination
/// replays bit for bit — loss curves and final models.
#[test]
fn sequential_sessions_are_deterministic() {
    for model in MODELS {
        let (ds, lr) = workload(model, 41);
        let store = store_for(&ds, 8, 13);
        let reads = [
            ReadStrategy::Truncate,
            ReadStrategy::DoubleSample,
            ReadStrategy::Popcount { q: 6 },
        ];
        for read in reads {
            let base = HostSession::over(&ds, &store)
                .loss(&model)
                .read(read)
                .schedule(PrecisionSchedule::Fixed(5))
                .epochs(4)
                .batch(32)
                .lr0(lr)
                .seed(3);
            let a = base.run().unwrap();
            let b = base.run().unwrap();
            assert_eq!(a.loss_curve, b.loss_curve, "{model:?} × {read:?}");
            assert_eq!(a.final_model, b.final_model, "{model:?} × {read:?}");
        }
        let dense = HostSession::dense(&ds).loss(&model).epochs(4).batch(32).lr0(lr).seed(3);
        let a = dense.run().unwrap();
        let b = dense.run().unwrap();
        assert_eq!(a.loss_curve, b.loss_curve, "{model:?} × dense");
        assert_eq!(a.final_model, b.final_model, "{model:?} × dense");
    }
}

/// Invalid axis combinations must error, not silently fall back.
#[test]
fn invalid_combinations_error() {
    let ds = make_regression("session_bad", 96, 16, 12, 3);
    let store = store_for(&ds, 8, 5);
    // dense read over a store: the store would be silently ignored
    assert!(HostSession::over(&ds, &store).read(ReadStrategy::Dense).run().is_err());
    // store-backed reads without a store
    for read in [
        ReadStrategy::Truncate,
        ReadStrategy::DoubleSample,
        ReadStrategy::Popcount { q: 4 },
    ] {
        assert!(HostSession::dense(&ds).read(read).run().is_err(), "{read:?} without store");
    }
    // popcount rounding width out of range
    assert!(HostSession::over(&ds, &store).read(ReadStrategy::Popcount { q: 0 }).run().is_err());
    assert!(HostSession::over(&ds, &store).read(ReadStrategy::Popcount { q: 17 }).run().is_err());
    // the dequantize oracle is sequential + truncating only
    assert!(HostSession::over(&ds, &store)
        .dequant_oracle()
        .read(ReadStrategy::DoubleSample)
        .run()
        .is_err());
    assert!(HostSession::over(&ds, &store)
        .dequant_oracle()
        .read(ReadStrategy::Popcount { q: 4 })
        .run()
        .is_err());
    assert!(HostSession::over(&ds, &store)
        .dequant_oracle()
        .execution(Execution::Hogwild { threads: 2 })
        .run()
        .is_err());
    // degenerate knobs
    assert!(HostSession::over(&ds, &store)
        .execution(Execution::Hogwild { threads: 0 })
        .run()
        .is_err());
    assert!(HostSession::over(&ds, &store).batch(0).run().is_err());
    // store/dataset shape mismatch
    let other = make_regression("session_bad2", 80, 16, 12, 4);
    assert!(HostSession::over(&other, &store).run().is_err());
}

/// The nine legacy entry points are shims over the session: for linreg
/// they produce bit-for-bit the session's results (hogwild compared at
/// one thread, where the racy engine is deterministic).
#[test]
#[allow(deprecated)] // the shims are the subject under test
fn legacy_shims_are_bit_for_bit_the_session() {
    let ds = make_regression("session_shim", 260, 32, 16, 21);
    let scale = ColumnScale::from_data(&ds.train_a);
    let mut rng = zipml::rng::Rng::new(2);
    let packed = zipml::quant::packing::PackedMatrix::quantize(&ds.train_a, &scale, 8, &mut rng);
    let store = ShardedStore::from_packed(&packed, 4);
    let sched = PrecisionSchedule::Fixed(5);
    let base =
        HostSession::over(&ds, &store).schedule(sched).epochs(4).batch(32).lr0(0.05).seed(7);

    let a = sgd::train_store_host(&ds, &store, sched, 4, 32, 0.05, 7);
    let b = base.run().unwrap();
    assert_eq!(a.loss_curve, b.loss_curve);
    assert_eq!(a.final_model, b.final_model);
    assert_eq!(a.sample_bytes_per_epoch, b.sample_bytes_per_epoch);
    assert_eq!(a.precisions, b.precisions);

    let a = sgd::train_store_host_ds(&ds, &store, sched, 4, 32, 0.05, 7);
    let b = base.read(ReadStrategy::DoubleSample).run().unwrap();
    assert_eq!(a.loss_curve, b.loss_curve);
    assert_eq!(a.final_model, b.final_model);
    assert_eq!(a.sample_bytes_per_epoch, b.sample_bytes_per_epoch);

    let a = sgd::train_store_host_q(&ds, &store, sched, 6, 4, 32, 0.05, 7);
    let b = base.read(ReadStrategy::Popcount { q: 6 }).run().unwrap();
    assert_eq!(a.loss_curve, b.loss_curve);
    assert_eq!(a.final_model, b.final_model);

    let a = sgd::train_store_host_dequant(&ds, &store, sched, 4, 32, 0.05, 7);
    let b = base.dequant_oracle().run().unwrap();
    assert_eq!(a.loss_curve, b.loss_curve);
    assert_eq!(a.final_model, b.final_model);

    // packed twin: same math through ShardedStore::from_packed(_, 1),
    // legacy wire-bytes figure preserved
    let a = sgd::train_packed_host(&ds, &packed, 4, 32, 0.05, 7);
    let store1 = ShardedStore::from_packed(&packed, 1);
    let b = HostSession::over(&ds, &store1)
        .schedule(PrecisionSchedule::Fixed(8))
        .dequant_oracle()
        .epochs(4)
        .batch(32)
        .lr0(0.05)
        .seed(7)
        .run()
        .unwrap();
    assert_eq!(a.loss_curve, b.loss_curve);
    assert_eq!(a.final_model, b.final_model);
    assert_eq!(
        a.sample_bytes_per_epoch,
        packed.rows as f64 * (packed.bytes() as f64 / packed.rows as f64)
    );

    // hogwild shims at one thread (deterministic: no races, strided
    // partition and streams are seed-derived)
    let cfg = HogwildConfig { threads: 1, epochs: 3, lr0: 0.02, seed: 11 };
    let hw_base = HostSession::over(&ds, &store)
        .execution(Execution::Hogwild { threads: 1 })
        .epochs(3)
        .lr0(0.02)
        .seed(11);

    let a = zipml::fpga::hogwild::hogwild_train(&ds, &cfg);
    let b = HostSession::dense(&ds)
        .execution(Execution::Hogwild { threads: 1 })
        .epochs(3)
        .lr0(0.02)
        .seed(11)
        .run()
        .unwrap();
    assert_eq!(a.loss_curve, b.loss_curve);
    assert_eq!(a.final_model, b.final_model);
    assert_eq!(a.updates, b.updates);

    let a = zipml::fpga::hogwild::hogwild_train_store(&ds, &store, 5, &cfg);
    let b = hw_base.schedule(sched).run().unwrap();
    assert_eq!(a.loss_curve, b.loss_curve);
    assert_eq!(a.final_model, b.final_model);

    let a = zipml::fpga::hogwild::hogwild_train_store_ds(&ds, &store, 5, &cfg);
    let b = hw_base.schedule(sched).read(ReadStrategy::DoubleSample).run().unwrap();
    assert_eq!(a.loss_curve, b.loss_curve);
    assert_eq!(a.final_model, b.final_model);

    let a = zipml::fpga::hogwild::hogwild_train_store_q(&ds, &store, 5, 6, &cfg);
    let b = hw_base.schedule(sched).read(ReadStrategy::Popcount { q: 6 }).run().unwrap();
    assert_eq!(a.loss_curve, b.loss_curve);
    assert_eq!(a.final_model, b.final_model);
}

/// The generalized fused-vs-dequant oracle contract at session level: for
/// every smooth GlmLoss the fused truncating session tracks its
/// dequantize-oracle twin epoch for epoch (the hinge's kink makes
/// curve-level comparison ill-posed for SVM — its fused path is pinned by
/// the gradient-level property in tests/properties.rs instead).
#[test]
fn session_fused_tracks_dequant_oracle_per_smooth_model() {
    for model in [ModelKind::Linreg, ModelKind::Lssvm { c: 1e-3 }, ModelKind::Logistic] {
        let (ds, lr) = workload(model, 57);
        let store = store_for(&ds, 8, 29);
        let base = HostSession::over(&ds, &store)
            .loss(&model)
            .schedule(PrecisionSchedule::Fixed(6))
            .epochs(5)
            .batch(32)
            .lr0(lr)
            .seed(7);
        let fused = base.run().unwrap();
        let oracle = base.dequant_oracle().run().unwrap();
        assert_eq!(fused.precisions, oracle.precisions, "{model:?}");
        assert_eq!(fused.sample_bytes_per_epoch, oracle.sample_bytes_per_epoch, "{model:?}");
        for (e, (a, b)) in oracle.loss_curve.iter().zip(&fused.loss_curve).enumerate() {
            assert!(
                (a - b).abs() <= 2e-2 * (1.0 + a.abs()),
                "{model:?} epoch {e}: oracle {a} vs fused {b}"
            );
        }
    }
}

/// Zero epochs is a degenerate but well-defined session: the curve holds
/// only the initial loss and no update is applied (the CLI refuses it
/// before getting here — tested in main.rs).
#[test]
fn zero_epochs_returns_initial_loss_only() {
    let ds = make_regression("session_e0", 96, 16, 12, 3);
    let store = store_for(&ds, 8, 5);
    let r = HostSession::over(&ds, &store).epochs(0).run().unwrap();
    assert_eq!(r.loss_curve.len(), 1);
    assert_eq!(r.updates, 0);
    assert!(r.precisions.is_empty());
    assert!(r.final_model.iter().all(|&v| v == 0.0));
}

/// New capability from the axis product: precision schedules compose with
/// hogwild execution (the legacy hogwild paths were fixed-p only). The
/// step-up schedule reads coarse planes early and pays fewer bytes than
/// fixed full width, under racing workers.
#[test]
fn schedules_compose_with_hogwild() {
    let ds = make_regression("session_hw_sched", 400, 32, 20, 13);
    let store = store_for(&ds, 8, 17);
    let base = HostSession::over(&ds, &store)
        .execution(Execution::Hogwild { threads: 3 })
        .epochs(6)
        .lr0(0.02)
        .seed(5);
    let full = base.schedule(PrecisionSchedule::Fixed(8)).run().unwrap();
    let step = base
        .schedule(PrecisionSchedule::StepUp { start: 2, every: 2, max: 8 })
        .run()
        .unwrap();
    assert_eq!(step.precisions, vec![2, 2, 4, 4, 8, 8]);
    assert!(step.sample_bytes_per_epoch < full.sample_bytes_per_epoch);
    assert!(final_loss(&step.loss_curve).is_finite());
    assert_eq!(step.updates, full.updates);
}

/// A custom GlmLoss implementation (not a ModelKind) drives the session:
/// the trait is the extension point, not the enum.
#[test]
fn custom_glm_loss_composes() {
    /// Huber-flavored loss: quadratic inside |r| <= 1, linear outside.
    struct Huber;
    impl GlmLoss for Huber {
        fn label(&self) -> &'static str {
            "huber"
        }
        fn multiplier(&self, dot: f32, target: f32) -> f32 {
            (dot - target).clamp(-1.0, 1.0)
        }
        fn loss(&self, dot: f32, target: f32) -> f64 {
            let r = (dot - target) as f64;
            if r.abs() <= 1.0 {
                0.5 * r * r
            } else {
                r.abs() - 0.5
            }
        }
    }
    let ds = make_regression("session_huber", 260, 32, 16, 19);
    let store = store_for(&ds, 8, 23);
    let r = HostSession::over(&ds, &store)
        .loss(&Huber)
        .epochs(8)
        .batch(32)
        .lr0(0.1)
        .seed(3)
        .run()
        .unwrap();
    assert!(r.label.starts_with("huber"), "label: {}", r.label);
    assert!(final_loss(&r.loss_curve) < 0.8 * r.loss_curve[0], "{:?}", r.loss_curve);
}
