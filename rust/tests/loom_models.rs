//! Loom model checks for the crate's three real concurrency protocols
//! (DESIGN.md §11): `ShardedU64` record/sum/reset, the per-shard
//! `PaddedBytes` byte accounting behind `ShardedStore::bytes_read`, and
//! the Hogwild racy f32 publish (`RacyF32Cell`).
//!
//! This whole file compiles ONLY under `RUSTFLAGS="--cfg loom"` (run by
//! `ci.sh --analyze` as `cargo test --release --test loom_models`); a
//! normal `cargo test` sees an empty test binary. Each model keeps the
//! schedule space tiny — 2 threads, a handful of atomic ops — because
//! loom explores every interleaving; the matching full-size dynamic
//! tests live with the types themselves.
#![cfg(loom)]

use loom::sync::Arc;
use loom::thread;

use zipml::quant::ColumnScale;
use zipml::store::ShardedStore;
use zipml::sync::RacyF32Cell;
use zipml::telemetry::ShardedU64;
use zipml::tensor::Matrix;

/// Preemption-bounded model runner for the models that touch more than
/// a couple of atomics (the store's accounting fans out into telemetry
/// lanes). Bound 2 is loom's recommended setting: it catches almost all
/// real bugs while keeping the schedule count tractable.
fn model_bounded<F: Fn() + Sync + Send + 'static>(f: F) {
    let mut b = loom::model::Builder::new();
    b.preemption_bound = Some(2);
    b.check(f);
}

// -- protocol 1: ShardedU64 record / sum / reset ----------------------------

#[test]
fn sharded_u64_concurrent_adds_sum_exactly() {
    loom::model(|| {
        let c = Arc::new(ShardedU64::default());
        let c1 = Arc::clone(&c);
        let c2 = Arc::clone(&c);
        let t1 = thread::spawn(move || c1.add(0, 3));
        let t2 = thread::spawn(move || c2.add(1, 5));
        t1.join().unwrap();
        t2.join().unwrap();
        // every add lands exactly once: relaxed fetch_adds never drop
        assert_eq!(c.sum(), 8);
        assert_eq!(c.lane_values()[0], 3);
        assert_eq!(c.lane_values()[1], 5);
    });
}

#[test]
fn sharded_u64_racing_snapshot_is_a_valid_partial_sum() {
    loom::model(|| {
        let c = Arc::new(ShardedU64::default());
        let w = Arc::clone(&c);
        let r = Arc::clone(&c);
        let writer = thread::spawn(move || {
            w.add(0, 1);
            w.add(0, 1);
        });
        // ordering contract: a sum taken while a writer races is a valid
        // (possibly stale) partial snapshot — never torn, never over
        let snap = thread::spawn(move || r.sum()).join().unwrap();
        writer.join().unwrap();
        assert!(snap <= 2, "snapshot {snap} exceeds total");
        assert_eq!(c.sum(), 2, "post-join sum must be exact");
    });
}

#[test]
fn sharded_u64_reset_from_quiescence_is_clean() {
    loom::model(|| {
        let c = Arc::new(ShardedU64::default());
        let c1 = Arc::clone(&c);
        thread::spawn(move || c1.add(2, 7)).join().unwrap();
        c.reset();
        assert_eq!(c.sum(), 0);
        let c2 = Arc::clone(&c);
        thread::spawn(move || c2.add(2, 9)).join().unwrap();
        assert_eq!(c.sum(), 9, "adds after a quiescent reset are exact");
    });
}

// -- protocol 2: per-shard byte accounting vs bytes_read() ------------------

/// Tiny 2-shard store: 16 rows × 2 cols at 2 bits (8 rows/shard — the
/// shard row alignment floor), ingested sequentially (threads = 1) so
/// construction adds no schedules.
fn tiny_store() -> ShardedStore {
    let rows = 16;
    let cols = 2;
    let data: Vec<f32> = (0..rows * cols).map(|i| (i % 7) as f32 * 0.125).collect();
    let a = Matrix::from_vec(rows, cols, data);
    let scale = ColumnScale::from_data(&a);
    ShardedStore::ingest(&a, &scale, 2, 42, 2, 1)
}

#[test]
fn store_accounting_is_exact_after_concurrent_reads() {
    model_bounded(|| {
        let store = Arc::new(tiny_store());
        let s1 = Arc::clone(&store);
        let s2 = Arc::clone(&store);
        // one read per thread, different shards (rows 0 and 8): accounting
        // adds race only on the telemetry side, byte cells are per-shard
        let t1 = thread::spawn(move || {
            let mut out = [0u16; 2];
            s1.read_row(0, 2, &mut out)
        });
        let t2 = thread::spawn(move || {
            let mut out = [0u16; 2];
            s2.read_row(8, 2, &mut out)
        });
        let b1 = t1.join().unwrap();
        let b2 = t2.join().unwrap();
        // post-join the relaxed cells are exact: every byte counted once
        assert_eq!(store.bytes_read(), (b1 + b2) as u64);
        assert_eq!(store.shard_bytes_read(0), b1 as u64);
        assert_eq!(store.shard_bytes_read(1), b2 as u64);
    });
}

#[test]
fn store_accounting_same_shard_adds_never_drop() {
    model_bounded(|| {
        let store = Arc::new(tiny_store());
        let s1 = Arc::clone(&store);
        let s2 = Arc::clone(&store);
        // both threads hit shard 0: the two fetch_adds on one padded cell
        // must both land (the exact-byte contract under contention)
        let t1 = thread::spawn(move || {
            let mut out = [0u16; 2];
            s1.read_row(0, 2, &mut out)
        });
        let t2 = thread::spawn(move || {
            let mut out = [0u16; 2];
            s2.read_row(1, 2, &mut out)
        });
        let b1 = t1.join().unwrap();
        let b2 = t2.join().unwrap();
        assert_eq!(store.shard_bytes_read(0), (b1 + b2) as u64);
        assert_eq!(store.shard_bytes_read(1), 0);
        assert_eq!(store.bytes_read(), (b1 + b2) as u64);
    });
}

// -- protocol 3: the Hogwild racy f32 publish -------------------------------

#[test]
fn racy_cell_concurrent_adds_lossy_but_never_torn() {
    loom::model(|| {
        let c = Arc::new(RacyF32Cell::new(0.0));
        let c1 = Arc::clone(&c);
        let c2 = Arc::clone(&c);
        let t1 = thread::spawn(move || c1.add(1.0));
        let t2 = thread::spawn(move || c2.add(2.0));
        t1.join().unwrap();
        t2.join().unwrap();
        let got = c.load();
        // the hogwild publish contract: a racing add may be lost (1.0 or
        // 2.0), both may land (3.0) — but no interleaving tears the bits
        assert!(got == 1.0 || got == 2.0 || got == 3.0, "torn/impossible value {got}");
    });
}

#[test]
fn racy_reader_sees_only_published_values() {
    loom::model(|| {
        let c = Arc::new(RacyF32Cell::new(0.5));
        let w = Arc::clone(&c);
        let r = Arc::clone(&c);
        let writer = thread::spawn(move || w.store(1.5));
        // racy snapshot mid-flight: must be one of the two values ever
        // stored — the epoch-skeleton readers rely on exactly this
        let seen = thread::spawn(move || r.load()).join().unwrap();
        writer.join().unwrap();
        assert!(seen == 0.5 || seen == 1.5, "unpublished value {seen}");
        assert_eq!(c.load(), 1.5, "post-join the store is visible");
    });
}

#[test]
fn hogwild_publish_skeleton_counts_exactly_and_never_tears() {
    // the epoch skeleton in miniature: 2 model columns + a ShardedU64
    // publish tally, one publisher racing one reader (as in sgd/host.rs,
    // where workers snapshot the model while others publish)
    model_bounded(|| {
        let x = Arc::new([RacyF32Cell::new(0.0), RacyF32Cell::new(0.0)]);
        let pubs = Arc::new(ShardedU64::default());
        let xw = Arc::clone(&x);
        let pw = Arc::clone(&pubs);
        let writer = thread::spawn(move || {
            xw[0].add(1.0);
            xw[1].add(2.0);
            pw.add(0, 2);
        });
        let xr = Arc::clone(&x);
        let reader = thread::spawn(move || (xr[0].load(), xr[1].load()));
        let (a, b) = reader.join().unwrap();
        writer.join().unwrap();
        // reads observe only values some publish actually produced
        assert!(a == 0.0 || a == 1.0, "column 0 tore: {a}");
        assert!(b == 0.0 || b == 2.0, "column 1 tore: {b}");
        // post-join: every publish landed and was tallied exactly once
        assert_eq!(x[0].load(), 1.0);
        assert_eq!(x[1].load(), 2.0);
        assert_eq!(pubs.sum(), 2);
    });
}
