//! Integration: every artifact class loads, compiles, executes, and agrees
//! with Rust-side reference math. Requires `make artifacts`.

use zipml::rng::Rng;
use zipml::runtime::{lit_f32, lit_scalar11, lit_u8, Runtime};
use zipml::tensor::{dot, Matrix};

/// `None` ⇒ artifacts are not built in this checkout (e.g. the offline
/// stub `xla` backend): tests no-op rather than fail, mirroring
/// `real_manifest_loads_if_present`. Run `make artifacts` for full
/// coverage.
fn runtime() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping (artifacts unavailable): {e:#}");
            None
        }
    }
}

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal()).collect())
}

/// linreg_fp_step == x − lr·Aᵀ(Ax−b)/B computed host-side.
#[test]
fn linreg_fp_step_matches_reference() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    let (b, n) = (64usize, 10usize);
    let a = rand_mat(&mut rng, b, n);
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let bv: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
    let lr = 0.05f32;
    let out = rt
        .exec1_f32(
            "linreg_fp_step_n10",
            &[
                lit_f32(&[n, 1], &x).unwrap(),
                lit_f32(&[b, n], &a.data).unwrap(),
                lit_f32(&[b, 1], &bv).unwrap(),
                lit_scalar11(lr).unwrap(),
            ],
        )
        .unwrap();
    let mut r = a.matvec(&x);
    for (ri, &bi) in r.iter_mut().zip(&bv) {
        *ri -= bi;
    }
    let g = a.tmatvec(&r);
    for (i, &o) in out.iter().enumerate() {
        let expect = x[i] - lr * g[i] / b as f32;
        assert!((o - expect).abs() < 1e-4, "coord {i}: {o} vs {expect}");
    }
}

/// The DS artifact with a1 == a2 == A equals the fp step.
#[test]
fn ds_step_reduces_to_fp_when_unquantized() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(2);
    let (b, n) = (64usize, 100usize);
    let a = rand_mat(&mut rng, b, n);
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let bv: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
    let args_fp = [
        lit_f32(&[n, 1], &x).unwrap(),
        lit_f32(&[b, n], &a.data).unwrap(),
        lit_f32(&[b, 1], &bv).unwrap(),
        lit_scalar11(0.1).unwrap(),
    ];
    let fp = rt.exec1_f32("linreg_fp_step_n100", &args_fp).unwrap();
    let args_ds = [
        lit_f32(&[n, 1], &x).unwrap(),
        lit_f32(&[b, n], &a.data).unwrap(),
        lit_f32(&[b, n], &a.data).unwrap(),
        lit_f32(&[b, 1], &bv).unwrap(),
        lit_scalar11(0.1).unwrap(),
    ];
    let ds = rt.exec1_f32("linreg_ds_step_n100", &args_ds).unwrap();
    for (f, d) in fp.iter().zip(&ds) {
        assert!((f - d).abs() < 1e-4, "{f} vs {d}");
    }
}

/// u8 path: dequantize-in-kernel equals host-side dequantize + DS step.
#[test]
fn u8_step_matches_f32_ds_step() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(3);
    let (b, n, s) = (64usize, 100usize, 15u32);
    let idx1: Vec<u8> = (0..b * n).map(|_| (rng.below(s as usize + 1)) as u8).collect();
    let idx2: Vec<u8> = (0..b * n).map(|_| (rng.below(s as usize + 1)) as u8).collect();
    let m: Vec<f32> = (0..n).map(|_| 0.5 + rng.f32()).collect();
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let bv: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
    let out_u8 = rt
        .exec1_f32(
            "linreg_ds_u8_step_n100",
            &[
                lit_f32(&[n, 1], &x).unwrap(),
                lit_u8(&[b, n], &idx1).unwrap(),
                lit_u8(&[b, n], &idx2).unwrap(),
                lit_f32(&[1, n], &m).unwrap(),
                lit_scalar11(s as f32).unwrap(),
                lit_f32(&[b, 1], &bv).unwrap(),
                lit_scalar11(0.05).unwrap(),
            ],
        )
        .unwrap();
    let deq = |idx: &[u8]| -> Vec<f32> {
        idx.iter()
            .enumerate()
            .map(|(i, &v)| (v as f32 / s as f32 * 2.0 - 1.0) * m[i % n])
            .collect()
    };
    let a1 = deq(&idx1);
    let a2 = deq(&idx2);
    let out_f32 = rt
        .exec1_f32(
            "linreg_ds_step_n100",
            &[
                lit_f32(&[n, 1], &x).unwrap(),
                lit_f32(&[b, n], &a1).unwrap(),
                lit_f32(&[b, n], &a2).unwrap(),
                lit_f32(&[b, 1], &bv).unwrap(),
                lit_scalar11(0.05).unwrap(),
            ],
        )
        .unwrap();
    for (u, f) in out_u8.iter().zip(&out_f32) {
        assert!((u - f).abs() < 1e-4, "{u} vs {f}");
    }
}

/// quantize_v artifact is unbiased and lands on the grid.
#[test]
fn quantize_artifact_unbiased() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(4);
    let n = 100;
    let v: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let m = vec![1.0f32; n];
    let s = 7.0f32;
    let trials = 400;
    let mut acc = vec![0.0f64; n];
    for _ in 0..trials {
        let r: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let q = rt
            .exec1_f32(
                "quantize_v_n100",
                &[
                    lit_f32(&[1, n], &v).unwrap(),
                    lit_f32(&[1, n], &r).unwrap(),
                    lit_f32(&[1, n], &m).unwrap(),
                    lit_scalar11(s).unwrap(),
                ],
            )
            .unwrap();
        for (a, &qi) in acc.iter_mut().zip(&q) {
            *a += qi as f64;
            let t = (qi + 1.0) / 2.0 * s;
            assert!((t - t.round()).abs() < 1e-3, "{qi} off-grid");
        }
    }
    let worst = acc
        .iter()
        .zip(&v)
        .map(|(a, &x)| (a / trials as f64 - x as f64).abs())
        .fold(0.0f64, f64::max);
    assert!(worst < 0.05, "bias {worst}");
}

/// Loss artifacts agree with host math.
#[test]
fn loss_artifacts_match_host() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(5);
    let (b, n) = (64usize, 10usize);
    let a = rand_mat(&mut rng, b, n);
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let bv: Vec<f32> = (0..b).map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 }).collect();
    let args = [
        lit_f32(&[n, 1], &x).unwrap(),
        lit_f32(&[b, n], &a.data).unwrap(),
        lit_f32(&[b, 1], &bv).unwrap(),
    ];
    let mse = rt.exec1_scalar("linreg_loss_n10", &args).unwrap();
    let host_mse: f32 = (0..b)
        .map(|i| (dot(a.row(i), &x) - bv[i]).powi(2))
        .sum::<f32>()
        / b as f32;
    assert!((mse - host_mse).abs() < 1e-3 * host_mse.max(1.0));

    let a8 = rand_mat(&mut rng, b, 8);
    let x8: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
    let hinge = rt
        .exec1_scalar(
            "hinge_loss_n8",
            &[
                lit_f32(&[8, 1], &x8).unwrap(),
                lit_f32(&[b, 8], &a8.data).unwrap(),
                lit_f32(&[b, 1], &bv).unwrap(),
            ],
        )
        .unwrap();
    let host_hinge: f32 = (0..b)
        .map(|i| (1.0 - bv[i] * dot(a8.row(i), &x8)).max(0.0))
        .sum::<f32>()
        / b as f32;
    assert!((hinge - host_hinge).abs() < 1e-3 * host_hinge.max(1.0));
}

/// margins artifact returns b ⊙ (A x).
#[test]
fn margins_artifact_matches_host() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(6);
    let (b, n) = (64usize, 8usize);
    let a = rand_mat(&mut rng, b, n);
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let bv: Vec<f32> = (0..b).map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 }).collect();
    let out = rt
        .exec1_f32(
            "margins_n8",
            &[
                lit_f32(&[n, 1], &x).unwrap(),
                lit_f32(&[b, n], &a.data).unwrap(),
                lit_f32(&[b, 1], &bv).unwrap(),
            ],
        )
        .unwrap();
    for i in 0..b {
        let host = bv[i] * dot(a.row(i), &x);
        assert!((out[i] - host).abs() < 1e-4);
    }
}

/// Executable cache: second load is free; stats track compiles.
#[test]
fn runtime_caches_executables() {
    let Some(rt) = runtime() else { return };
    let _ = rt.load("linreg_loss_n10").unwrap();
    let c1 = rt.stats().compile_count;
    let _ = rt.load("linreg_loss_n10").unwrap();
    assert_eq!(rt.stats().compile_count, c1);
    assert_eq!(rt.cached(), 1);
}

/// Manifest covers the artifact families the driver expects.
#[test]
fn manifest_families_complete() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    for n in [8usize, 10, 12, 90, 100, 500, 1000, 4096] {
        assert!(m.find_kind_n("linreg_fp_step", n).is_ok(), "linreg fp n={n}");
        assert!(m.find_kind_n("linreg_ds_step", n).is_ok(), "linreg ds n={n}");
        assert!(m.find_kind_n("linreg_loss", n).is_ok(), "linreg loss n={n}");
        assert!(m.find_kind_n("lssvm_ds_step", n).is_ok(), "lssvm ds n={n}");
    }
    for n in [8usize, 100, 500] {
        assert!(m.find_kind_n("logistic_fp_step", n).is_ok());
        assert!(m.find_kind_n("svm_fp_step", n).is_ok());
        assert!(m.find_kind_n("cheby_step", n).is_ok());
        assert!(m.find_kind_n("poly_ds_step", n).is_ok());
        assert!(m.find_kind_n("margins", n).is_ok());
    }
    assert!(m.get("mlp_fp_step").is_ok());
    assert!(m.get("mlp_q_step").is_ok());
    assert!(m.get("linreg_ds_epoch_n100").is_ok());
}
