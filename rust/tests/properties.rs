//! Property-based invariants over the quantization library (no artifacts
//! needed — pure L3 math). Uses the in-repo `proptest` helper.

use zipml::proptest::{small_size, sorted_floats, Prop};
use zipml::quant::packing::{BitVec, DoubleSampleBlock, PackedMatrix};
use zipml::quant::{
    self, discretized_optimal_levels, optimal_levels, quantization_variance, ColumnScale,
};
use zipml::rng::Rng;
use zipml::sgd::{GlmLoss, ModelKind};
use zipml::store::{
    kernel, MinibatchIter, PrecisionSchedule, ScheduleState, ShardedStore, StepKernel,
    WeavedMatrix,
};
use zipml::tensor::Matrix;

fn rand_matrix(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> Matrix {
    Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal() * scale).collect())
}

/// BitVec: any sequence of (value, width) pushes reads back exactly.
#[test]
fn prop_bitvec_roundtrip() {
    Prop::new(128).check("bitvec-roundtrip", |rng| {
        let n = small_size(rng, 200);
        let items: Vec<(u32, u32)> = (0..n)
            .map(|_| {
                let w = 1 + rng.below(16) as u32;
                let v = (rng.next_u64() as u32) & ((1u32 << w) - 1);
                (v, w)
            })
            .collect();
        let mut bv = BitVec::default();
        for &(v, w) in &items {
            bv.push(v, w);
        }
        let mut off = 0usize;
        for &(v, w) in &items {
            let got = bv.get(off, w);
            if got != v {
                return Err(format!("at bit {off}: {got} != {v} (width {w})"));
            }
            off += w as usize;
        }
        Ok(())
    });
}

/// PackedMatrix: every dequantized value is on the grid and within one
/// interval of its source value.
#[test]
fn prop_packed_matrix_on_grid() {
    Prop::new(48).check("packed-on-grid", |rng| {
        let rows = small_size(rng, 24);
        let cols = small_size(rng, 40);
        let bits = 1 + rng.below(8) as u32;
        let sc_f = 1.0 + rng.f32() * 3.0;
        let a = rand_matrix(rng, rows, cols, sc_f);
        let sc = ColumnScale::from_data(&a);
        let p = PackedMatrix::quantize(&a, &sc, bits, rng);
        let s = p.s as f32;
        let mut row = vec![0.0f32; cols];
        for r in 0..rows {
            p.dequantize_row(r, &mut row);
            for (c, &q) in row.iter().enumerate() {
                let m = sc.m[c];
                if m == 0.0 {
                    if q != 0.0 {
                        return Err(format!("zero-scale col produced {q}"));
                    }
                    continue;
                }
                let width = 2.0 * m / s;
                let v = a.get(r, c);
                if (q - v).abs() > width + 1e-4 {
                    return Err(format!("bits={bits} q={q} v={v} width={width}"));
                }
                let t = (q / m + 1.0) / 2.0 * s;
                if (t - t.round()).abs() > 1e-2 {
                    return Err(format!("off grid: q={q} t={t}"));
                }
            }
        }
        Ok(())
    });
}

/// DoubleSampleBlock: all k samples share the base interval (≤ 1 level
/// apart) and average ≈ source for large k.
#[test]
fn prop_double_sample_interval_sharing() {
    Prop::new(32).check("ds-shared-interval", |rng| {
        let rows = small_size(rng, 10);
        let cols = small_size(rng, 12);
        let bits = 1 + rng.below(6) as u32;
        let k = 2 + rng.below(14);
        let a = rand_matrix(rng, rows, cols, 2.0);
        let sc = ColumnScale::from_data(&a);
        let ds = DoubleSampleBlock::quantize(&a, &sc, bits, k, rng);
        let mut bufs: Vec<Vec<f32>> = vec![vec![0.0; cols]; k];
        for r in 0..rows {
            for (j, buf) in bufs.iter_mut().enumerate() {
                ds.dequantize_row(r, j, buf);
            }
            for c in 0..cols {
                let width = 2.0 * sc.m[c] / ds.s as f32;
                let lo = bufs.iter().map(|b| b[c]).fold(f32::INFINITY, f32::min);
                let hi = bufs.iter().map(|b| b[c]).fold(f32::NEG_INFINITY, f32::max);
                if hi - lo > width + 1e-4 {
                    return Err(format!("samples span {} > interval {width}", hi - lo));
                }
            }
        }
        Ok(())
    });
}

/// Exact DP is never worse than the brute-force oracle (tiny instances).
#[test]
fn prop_dp_matches_brute_force() {
    Prop::new(40).check("dp-optimal", |rng| {
        let n = 5 + rng.below(9);
        let pts: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let nlevels = 2 + rng.below(3);
        let dp = optimal_levels(&pts, nlevels);
        let (_, bf) = quant::optimal::brute_force_optimal(&pts, nlevels);
        let dpv = quantization_variance(&pts, &dp);
        if dpv > bf + 1e-8 {
            return Err(format!("dp {dpv} > brute {bf} (n={n}, L={nlevels})"));
        }
        Ok(())
    });
}

/// Discretized DP converges monotonically-ish toward exact as M grows, and
/// never beats the exact optimum.
#[test]
fn prop_discretized_bounded_by_exact() {
    Prop::new(24).check("discretized-bounds", |rng| {
        let n = 30 + rng.below(120);
        let pts: Vec<f32> = (0..n).map(|_| rng.f32().powi(2)).collect();
        let nlevels = 3 + rng.below(4);
        let exact = quantization_variance(&pts, &optimal_levels(&pts, nlevels));
        let coarse =
            quantization_variance(&pts, &discretized_optimal_levels(&pts, nlevels, nlevels + 2));
        let fine = quantization_variance(&pts, &discretized_optimal_levels(&pts, nlevels, 512));
        if exact > coarse + 1e-8 {
            return Err(format!("exact {exact} > coarse {coarse}"));
        }
        if exact > fine + 1e-8 {
            return Err(format!("exact {exact} > fine {fine}"));
        }
        if fine > coarse + 1e-8 {
            return Err(format!("fine {fine} > coarse {coarse} (M monotonicity)"));
        }
        Ok(())
    });
}

/// ADAQUANT's final levels stay within the Theorem-9-style factor of the
/// exact DP (we assert a conservative 2x + eps).
#[test]
fn prop_adaquant_2_approx() {
    Prop::new(16).check("adaquant-2approx", |rng| {
        let n = 100 + rng.below(400);
        let bimodal = rng.f32() < 0.5;
        let pts: Vec<f32> = (0..n)
            .map(|_| {
                if bimodal && rng.f32() < 0.3 {
                    rng.normal() * 0.1 + 2.0
                } else {
                    rng.normal() * 0.5
                }
            })
            .collect();
        let k = 3 + rng.below(6);
        let exact = quantization_variance(&pts, &optimal_levels(&pts, k));
        let greedy = quantization_variance(&pts, &quant::greedy::adaquant_levels(&pts, k));
        if greedy > 2.0 * exact + 1e-7 {
            return Err(format!("greedy {greedy} > 2x exact {exact} (k={k}, n={n})"));
        }
        Ok(())
    });
}

/// Column scaling always covers the data it was computed from.
#[test]
fn prop_column_scale_covers() {
    Prop::new(64).check("scale-covers", |rng| {
        let rows = small_size(rng, 50);
        let cols = small_size(rng, 30);
        let sc_f = 1.0 + rng.f32() * 10.0;
        let a = rand_matrix(rng, rows, cols, sc_f);
        let sc = ColumnScale::from_data(&a);
        if !sc.covers(&a) {
            return Err("scale does not cover its own data".into());
        }
        Ok(())
    });
}

/// Stochastic quantization is empirically unbiased for any (value, scale,
/// s) combination.
#[test]
fn prop_quantizer_unbiased() {
    Prop::new(12).check("quantizer-unbiased", |rng| {
        let s = 1 + rng.below(30) as u32;
        let m = 0.5 + rng.f32() * 3.0;
        let v = (rng.f32() * 2.0 - 1.0) * m;
        let trials = 20_000;
        let mut acc = 0.0f64;
        let vals = [v];
        let scales = [m];
        let mut out = [0.0f32];
        for _ in 0..trials {
            quant::stochastic::quantize_values(&vals, 1, &scales, s, rng, &mut out);
            acc += out[0] as f64;
        }
        let mean = acc / trials as f64;
        // interval width / sqrt(trials) * 5 sigma
        let tol = (2.0 * m as f64 / s as f64) / (trials as f64).sqrt() * 5.0 + 1e-4;
        if (mean - v as f64).abs() > tol {
            return Err(format!("bias: mean {mean} vs {v} (tol {tol})"));
        }
        Ok(())
    });
}

/// Level grids from the DP cover the data range and are sorted — required
/// for the unbiased clamp-free quantization path.
#[test]
fn prop_levels_sorted_and_covering() {
    Prop::new(48).check("levels-sorted", |rng| {
        let n = 20 + rng.below(200);
        let pts = sorted_floats(rng, n, -5.0, 5.0);
        let nlevels = 2 + rng.below(6);
        for lv in [
            optimal_levels(&pts, nlevels),
            discretized_optimal_levels(&pts, nlevels, 64),
        ] {
            if !lv.windows(2).all(|w| w[0] <= w[1]) {
                return Err(format!("unsorted levels {lv:?}"));
            }
            let lo = pts.first().unwrap();
            let hi = pts.last().unwrap();
            if lv[0] > lo + 1e-5 || lv[lv.len() - 1] < hi - 1e-5 {
                return Err(format!("levels {:?} don't cover [{lo}, {hi}]", lv));
            }
        }
        Ok(())
    });
}

/// FPGA model: epoch time is monotone non-increasing in precision and the
/// float/Q4 ratio stays in the paper's regime for bandwidth-bound shapes.
#[test]
fn prop_fpga_monotone() {
    use zipml::fpga::{epoch_seconds, Precision};
    Prop::new(64).check("fpga-monotone", |rng| {
        let k = 1000 + rng.below(100_000);
        let n = 10 + rng.below(2000);
        let t32 = epoch_seconds(Precision::Float, k, n);
        let t8 = epoch_seconds(Precision::Q(8), k, n);
        let t4 = epoch_seconds(Precision::Q(4), k, n);
        let t2 = epoch_seconds(Precision::Q(2), k, n);
        if !(t32 >= t8 && t8 >= t4 && t4 >= t2) {
            return Err(format!("not monotone: {t32} {t8} {t4} {t2}"));
        }
        let ratio = t32 / t4;
        if !(2.0..=9.0).contains(&ratio) {
            return Err(format!("float/Q4 ratio {ratio} outside plausible band"));
        }
        Ok(())
    });
}

/// WeavedMatrix::read_row(p) equals the PackedMatrix values truncated to
/// the top p bit-planes, for widths 1..=16 and random shapes; full-width
/// dequantization is bit-identical to the packed path.
#[test]
fn prop_weaved_read_is_packed_truncation() {
    Prop::new(48).check("weave-truncation", |rng| {
        let rows = small_size(rng, 24);
        let cols = small_size(rng, 80);
        let bits = 1 + rng.below(16) as u32;
        let a = rand_matrix(rng, rows, cols, 1.0 + rng.f32() * 3.0);
        let sc = ColumnScale::from_data(&a);
        let packed = PackedMatrix::quantize(&a, &sc, bits, rng);
        let weaved = WeavedMatrix::from_packed(&packed);
        let mut idx = vec![0u16; cols];
        for p in 1..=bits {
            for r in 0..rows {
                let bytes = weaved.read_row(r, p, &mut idx);
                if bytes != p as usize * cols.div_ceil(64) * 8 {
                    return Err(format!("bytes accounting off: {bytes} (p={p} cols={cols})"));
                }
                for (c, &got) in idx.iter().enumerate() {
                    let expect = packed.index(r, c) >> (bits - p);
                    if got != expect {
                        return Err(format!(
                            "bits={bits} p={p} ({r},{c}): {got} != {expect}"
                        ));
                    }
                }
            }
        }
        // full-width dequantization must match the packed path exactly
        let (mut dw, mut dp) = (vec![0.0f32; cols], vec![0.0f32; cols]);
        for r in 0..rows {
            weaved.dequantize_row_at(r, bits, &mut dw);
            packed.dequantize_row(r, &mut dp);
            if dw != dp {
                return Err(format!("dequant mismatch at row {r} (bits={bits})"));
            }
        }
        Ok(())
    });
}

/// Fused weaved-domain kernels match dequantize-then-dot/axpy within 1e-4
/// relative, for widths 1..=16 (random p per case), ragged column counts
/// biased toward the word boundaries (63/64/65/130), and zero-scale
/// columns — the tentpole's correctness pin.
#[test]
fn prop_fused_kernels_match_dequant_oracle() {
    Prop::new(48).check("fused-vs-dequant", |rng| {
        let rows = 1 + small_size(rng, 12);
        // bias the shape toward word-boundary raggedness
        let cols = match rng.below(6) {
            0 => 63,
            1 => 64,
            2 => 65,
            3 => 130,
            _ => small_size(rng, 150),
        };
        let bits = 1 + rng.below(16) as u32;
        let mut a = rand_matrix(rng, rows, cols, 1.0 + rng.f32() * 3.0);
        if cols > 2 {
            // plant a zero-scale column
            for r in 0..rows {
                a.set(r, 1, 0.0);
            }
        }
        let sc = ColumnScale::from_data(&a);
        let w = WeavedMatrix::quantize(&a, &sc, bits, rng);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(cols);
        k.refresh(&sc.m, &x);
        let p = 1 + rng.below(bits as usize) as u32;
        let mut row = vec![0.0f32; cols];
        let mut grad_f = vec![0.0f32; cols];
        let mut grad_r = vec![0.0f64; cols];
        let mut mag = vec![0.0f64; cols];
        for r in 0..rows {
            w.dequantize_row_at(r, p, &mut row);
            // dot
            let want = zipml::tensor::dot(&row, &x) as f64;
            let got = kernel::dot_row(&w, r, p, &k) as f64;
            let scale: f64 = row.iter().zip(&x).map(|(&u, &v)| (u as f64 * v as f64).abs()).sum();
            if (got - want).abs() > 1e-4 * (1.0 + want.abs() + scale) {
                return Err(format!("dot bits={bits} p={p} r={r}: {got} vs {want}"));
            }
            // axpy
            let coef = rng.normal();
            kernel::axpy_row(&w, r, p, coef, &mut grad_f);
            for ((o, g), &v) in grad_r.iter_mut().zip(mag.iter_mut()).zip(&row) {
                *o += coef as f64 * v as f64;
                *g += (coef as f64 * v as f64).abs();
            }
        }
        for c in 0..cols {
            if (grad_f[c] as f64 - grad_r[c]).abs() > 1e-4 * (1.0 + mag[c]) {
                return Err(format!(
                    "axpy bits={bits} p={p} c={c}: {} vs {}",
                    grad_f[c], grad_r[c]
                ));
            }
        }
        // zero-scale column is inert through both kernels
        if cols > 2 && grad_f[1] != 0.0 {
            return Err(format!("zero-scale column accumulated {}", grad_f[1]));
        }
        Ok(())
    });
}

/// The generalized fused-vs-dequant oracle property (the tentpole's
/// acceptance pin): for EVERY GlmLoss impl — linreg, LS-SVM, logistic,
/// SVM/hinge — and every read precision p in 1..=16 of a 16-bit store,
/// the fused plane-domain GLM batch gradient matches the
/// dequantize-then-multiply oracle within 1e-4 relative. The multiplier
/// is applied to marginally different dots on the two paths (plane-order
/// vs column-order f32 summation), so hinge rows whose fused and oracle
/// dots straddle the margin kink are excluded — the subgradient there is
/// a tie-break, not a numerical disagreement.
#[test]
fn prop_glm_fused_vs_dequant_oracle_every_loss() {
    let models: [(&str, ModelKind); 4] = [
        ("linreg", ModelKind::Linreg),
        ("lssvm", ModelKind::Lssvm { c: 1e-3 }),
        ("logistic", ModelKind::Logistic),
        ("svm", ModelKind::Svm),
    ];
    Prop::new(24).check("glm-fused-vs-dequant", |rng| {
        let rows = 9 + small_size(rng, 40);
        let cols = match rng.below(6) {
            0 => 63,
            1 => 64,
            2 => 65,
            3 => 130,
            _ => small_size(rng, 120),
        };
        let a = rand_matrix(rng, rows, cols, 2.0);
        let sc = ColumnScale::from_data(&a);
        let store = ShardedStore::ingest(&a, &sc, 16, rng.next_u64(), 1 + rng.below(5), 1);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal() * 0.3).collect();
        let mut k = StepKernel::new(cols);
        k.refresh(&sc.m, &x);
        let batch: Vec<usize> = (0..8).map(|_| rng.below(rows)).collect();
        // ±1 targets: meaningful for the margin losses, fine for the rest
        let targets: Vec<f32> =
            (0..8).map(|_| if rng.below(2) == 0 { -1.0 } else { 1.0 }).collect();
        let mut row = vec![0.0f32; cols];
        for p in 1..=16u32 {
            for (name, model) in &models {
                let mut fused = vec![0.0f32; cols];
                store.fused_grad_batch_glm(
                    &batch,
                    p,
                    &k,
                    &targets,
                    |d, t| model.multiplier(d, t),
                    &mut fused,
                );
                // dequantize-row oracle in f64, same multiplier rule
                let mut want = vec![0.0f64; cols];
                let mut mag = vec![0.0f64; cols];
                let mut kink = false;
                for (&r, &t) in batch.iter().zip(&targets) {
                    store.dequantize_row(r, p, &mut row);
                    let d_oracle = zipml::tensor::dot(&row, &x);
                    let (shard, local) = store.locate_row(r);
                    let d_fused = kernel::dot_row(shard, local, p, &k);
                    let coef = model.multiplier(d_oracle, t);
                    if matches!(model, ModelKind::Svm)
                        && coef != model.multiplier(d_fused, t)
                    {
                        kink = true; // hinge tie-break, not a numeric bug
                    }
                    for ((o, g), &v) in want.iter_mut().zip(mag.iter_mut()).zip(&row) {
                        *o += coef as f64 * v as f64;
                        *g += (coef as f64 * v as f64).abs();
                    }
                }
                if kink {
                    continue;
                }
                for c in 0..cols {
                    if (fused[c] as f64 - want[c]).abs() > 1e-4 * (1.0 + mag[c]) {
                        return Err(format!(
                            "{name} p={p} c={c}: fused {} vs oracle {}",
                            fused[c], want[c]
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// The fused per-shard batch gradient agrees with the per-row fused
/// kernels and accounts exactly rows × bytes_per_row(p).
#[test]
fn prop_fused_grad_batch_consistent() {
    Prop::new(24).check("fused-batch", |rng| {
        let rows = 9 + small_size(rng, 80);
        let cols = small_size(rng, 100);
        let bits = 1 + rng.below(8) as u32;
        let a = rand_matrix(rng, rows, cols, 2.0);
        let sc = ColumnScale::from_data(&a);
        let store = ShardedStore::ingest(&a, &sc, bits, rng.next_u64(), 1 + rng.below(6), 1);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(cols);
        k.refresh(&sc.m, &x);
        let p = 1 + rng.below(bits as usize) as u32;
        let batch: Vec<usize> = (0..8).map(|_| rng.below(rows)).collect();
        let targets: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
        store.reset_bytes_read();
        let mut grad = vec![0.0f32; cols];
        let bytes = store.fused_grad_batch(&batch, p, &k, &targets, &mut grad);
        if bytes != batch.len() * store.bytes_per_row(p) {
            return Err(format!("bytes {bytes} != rows × bytes_per_row"));
        }
        if store.bytes_read() != bytes as u64 {
            return Err("counter disagrees with returned bytes".into());
        }
        // per-row fused reference
        let mut want = vec![0.0f32; cols];
        let mut err_sum = 0.0f32;
        for (&r, &t) in batch.iter().zip(&targets) {
            let (shard, local) = store.locate_row(r);
            let err = kernel::dot_row(shard, local, p, &k) - t;
            kernel::axpy_row_planes(shard, local, p, err, &mut want);
            err_sum += err;
        }
        kernel::axpy_affine(err_sum, &sc.m, &mut want);
        for c in 0..cols {
            if (grad[c] - want[c]).abs() > 1e-3 * (1.0 + want[c].abs()) {
                return Err(format!("c={c}: batch {} vs per-row {}", grad[c], want[c]));
            }
        }
        Ok(())
    });
}

/// The blocked batch gradient is BIT-FOR-BIT the per-row kernels run over
/// the specified shard-grouped order (ascending shard id, batch order
/// within a shard) — randomized over widths 1..=16, word-boundary-ragged
/// shapes, shard counts, duplicate rows, and batches long enough to
/// exercise the 256-row block chunking.
#[test]
fn prop_blocked_grad_batch_bit_identical_to_per_row() {
    Prop::new(32).check("blocked-bitexact", |rng| {
        let rows = 9 + small_size(rng, 80);
        let cols = match rng.below(6) {
            0 => 63,
            1 => 64,
            2 => 65,
            3 => 130,
            _ => small_size(rng, 150),
        };
        let bits = 1 + rng.below(16) as u32;
        let a = rand_matrix(rng, rows, cols, 2.0);
        let sc = ColumnScale::from_data(&a);
        let store = ShardedStore::ingest(&a, &sc, bits, rng.next_u64(), 1 + rng.below(6), 1);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(cols);
        k.refresh(&sc.m, &x);
        let p = 1 + rng.below(bits as usize) as u32;
        // occasionally a batch longer than one 256-row block
        let nb = if rng.below(8) == 0 { 300 + rng.below(200) } else { 1 + rng.below(12) };
        let batch: Vec<usize> = (0..nb).map(|_| rng.below(rows)).collect();
        let targets: Vec<f32> = (0..nb).map(|_| rng.normal()).collect();
        let mut blocked = vec![0.0f32; cols];
        store.fused_grad_batch(&batch, p, &k, &targets, &mut blocked);
        // per-row reference over the specified visit order
        let mut order: Vec<usize> = (0..batch.len()).collect();
        order.sort_by_key(|&i| batch[i] / store.shard_rows()); // stable
        let mut want = vec![0.0f32; cols];
        let mut err_sum = 0.0f32;
        for &i in &order {
            let (shard, local) = store.locate_row(batch[i]);
            let err = kernel::dot_row(shard, local, p, &k) - targets[i];
            kernel::axpy_row_planes(shard, local, p, err, &mut want);
            err_sum += err;
        }
        kernel::axpy_affine(err_sum, &sc.m, &mut want);
        for c in 0..cols {
            if blocked[c].to_bits() != want[c].to_bits() {
                return Err(format!(
                    "bits={bits} p={p} nb={nb} c={c}: blocked {} != per-row {}",
                    blocked[c], want[c]
                ));
            }
        }
        Ok(())
    });
}

/// The blocked DS kernels draw identical samples to the per-row DS
/// kernels under shared RNG streams: bit-for-bit equal outputs AND
/// streams left in the same state — so blocked and per-row DS paths are
/// interchangeable draw for draw.
#[test]
fn prop_ds_blocked_draws_match_per_row() {
    Prop::new(32).check("ds-blocked-draws", |rng| {
        let rows = 1 + small_size(rng, 12);
        let cols = match rng.below(6) {
            0 => 63,
            1 => 64,
            2 => 65,
            3 => 130,
            _ => small_size(rng, 150),
        };
        let bits = 1 + rng.below(16) as u32;
        let a = rand_matrix(rng, rows, cols, 2.0);
        let sc = ColumnScale::from_data(&a);
        let w = WeavedMatrix::quantize(&a, &sc, bits, rng);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(cols);
        k.refresh(&sc.m, &x);
        let p = 1 + rng.below(bits as usize) as u32;
        let nb = 1 + rng.below(10);
        let batch: Vec<usize> = (0..nb).map(|_| rng.below(rows)).collect();
        let coefs: Vec<f32> = (0..nb).map(|_| rng.normal()).collect();
        let seed = rng.next_u64();
        // dots on twin streams
        let (mut ra, mut rb) = (Rng::new(seed), Rng::new(seed));
        let mut blocked = vec![0.0f32; nb];
        kernel::dot_rows_block_ds(&w, &batch, p, &k, &mut ra, &mut blocked);
        for (i, &r) in batch.iter().enumerate() {
            let want = kernel::dot_row_ds(&w, r, p, &k, &mut rb);
            if blocked[i].to_bits() != want.to_bits() {
                return Err(format!("ds dot bits={bits} p={p} i={i}: {} vs {want}", blocked[i]));
            }
        }
        if ra.next_u64() != rb.next_u64() {
            return Err("dot streams diverged".into());
        }
        // axpys on twin streams
        let (mut ra, mut rb) = (Rng::new(seed ^ 1), Rng::new(seed ^ 1));
        let mut gb = vec![0.0f32; cols];
        let mut gp = vec![0.0f32; cols];
        kernel::axpy_rows_block_ds(&w, &batch, p, &coefs, &mut ra, &mut gb);
        for (&r, &coef) in batch.iter().zip(&coefs) {
            kernel::axpy_row_planes_ds(&w, r, p, coef, &mut rb, &mut gp);
        }
        for c in 0..cols {
            if gb[c].to_bits() != gp[c].to_bits() {
                return Err(format!("ds axpy bits={bits} p={p} c={c}: {} vs {}", gb[c], gp[c]));
            }
        }
        if ra.next_u64() != rb.next_u64() {
            return Err("axpy streams diverged".into());
        }
        Ok(())
    });
}

/// Stochastic (double-sampling) reads: every draw is the truncation plus
/// an at-most-one-ulp carry on the coarse grid, p = stored width is exact,
/// and the fused DS kernels given the same RNG state reproduce the
/// materializing dequantize_row_ds oracle — the DS tentpole's correctness
/// pin, over random widths and word-boundary-ragged shapes.
#[test]
fn prop_ds_draws_bracket_and_fused_matches_oracle() {
    Prop::new(48).check("ds-draws", |rng| {
        let rows = 1 + small_size(rng, 10);
        let cols = match rng.below(6) {
            0 => 63,
            1 => 64,
            2 => 65,
            3 => 130,
            _ => small_size(rng, 150),
        };
        let bits = 1 + rng.below(16) as u32;
        let a = rand_matrix(rng, rows, cols, 1.0 + rng.f32() * 3.0);
        let sc = ColumnScale::from_data(&a);
        let packed = PackedMatrix::quantize(&a, &sc, bits, rng);
        let w = WeavedMatrix::from_packed(&packed);
        let p = 1 + rng.below(bits as usize) as u32;
        let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(cols);
        k.refresh(&sc.m, &x);
        let mut idx = vec![0u16; cols];
        let mut row = vec![0.0f32; cols];
        for r in 0..rows {
            let seed = rng.next_u64();
            let bytes = w.read_row_ds(r, p, &mut Rng::new(seed), &mut idx);
            if bytes != p as usize * cols.div_ceil(64) * 8 {
                return Err(format!("ds wire bytes {bytes} != p plane spans"));
            }
            for (c, &got) in idx.iter().enumerate() {
                // compare in u32: h + 1 can hit 2^16 at full width
                let h = (packed.index(r, c) >> (bits - p)) as u32;
                if (got as u32) != h && (got as u32) != h + 1 {
                    return Err(format!("bits={bits} p={p} ({r},{c}): draw {got} vs trunc {h}"));
                }
                if p == bits && got as u32 != h {
                    return Err(format!("full-width draw carried at ({r},{c})"));
                }
            }
            // same seed: materializing oracle and fused dot share the draw
            w.dequantize_row_ds(r, p, &mut Rng::new(seed), &mut row);
            for (c, (&v, &i)) in row.iter().zip(&idx).enumerate() {
                let fine = i as f32 * (1u32 << (bits - p)) as f32;
                let want = (fine * 2.0 / w.s as f32 - 1.0) * sc.m[c];
                if (v - want).abs() > 1e-5 * (1.0 + want.abs()) {
                    return Err(format!("read/dequant draw mismatch at ({r},{c})"));
                }
            }
            let got = kernel::dot_row_ds(&w, r, p, &k, &mut Rng::new(seed)) as f64;
            let want = zipml::tensor::dot(&row, &x) as f64;
            let scale: f64 = row.iter().zip(&x).map(|(&u, &v)| (u as f64 * v as f64).abs()).sum();
            if (got - want).abs() > 1e-4 * (1.0 + want.abs() + scale) {
                return Err(format!("fused ds dot bits={bits} p={p} r={r}: {got} vs {want}"));
            }
        }
        Ok(())
    });
}

/// The double-sampled batch gradient accounts exactly 2× rows ×
/// bytes_per_row(p) — both independent fetches — and is deterministic in
/// the RNG state.
#[test]
fn prop_ds_grad_batch_accounting() {
    Prop::new(24).check("ds-batch", |rng| {
        let rows = 9 + small_size(rng, 80);
        let cols = small_size(rng, 100);
        let bits = 1 + rng.below(8) as u32;
        let a = rand_matrix(rng, rows, cols, 2.0);
        let sc = ColumnScale::from_data(&a);
        let store = ShardedStore::ingest(&a, &sc, bits, rng.next_u64(), 1 + rng.below(6), 1);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
        let mut k = StepKernel::new(cols);
        k.refresh(&sc.m, &x);
        let p = 1 + rng.below(bits as usize) as u32;
        let batch: Vec<usize> = (0..8).map(|_| rng.below(rows)).collect();
        let targets: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
        let seed = rng.next_u64();
        store.reset_bytes_read();
        let mut g1 = vec![0.0f32; cols];
        let bytes = store.ds_grad_batch(&batch, p, &k, &targets, &mut Rng::new(seed), &mut g1);
        if bytes != 2 * batch.len() * store.bytes_per_row(p) {
            return Err(format!("bytes {bytes} != 2 × rows × bytes_per_row"));
        }
        if store.bytes_read() != bytes as u64 {
            return Err("counter disagrees with returned bytes".into());
        }
        let mut g2 = vec![0.0f32; cols];
        store.ds_grad_batch(&batch, p, &k, &targets, &mut Rng::new(seed), &mut g2);
        if g1 != g2 {
            return Err("ds_grad_batch not deterministic in the rng state".into());
        }
        Ok(())
    });
}

/// Sharded routing is transparent: any shard count reproduces the
/// unsharded weaved reads, and the byte accounting matches epoch_bytes.
#[test]
fn prop_sharded_store_routes_transparently() {
    Prop::new(32).check("shard-routing", |rng| {
        let rows = 1 + small_size(rng, 60);
        let cols = small_size(rng, 50);
        let bits = 1 + rng.below(8) as u32;
        let a = rand_matrix(rng, rows, cols, 2.0);
        let sc = ColumnScale::from_data(&a);
        let packed = PackedMatrix::quantize(&a, &sc, bits, rng);
        let whole = WeavedMatrix::from_packed(&packed);
        let shards = 1 + rng.below(rows);
        let store = ShardedStore::from_packed(&packed, shards);
        let p = 1 + rng.below(bits as usize) as u32;
        let (mut iw, mut is) = (vec![0u16; cols], vec![0u16; cols]);
        store.reset_bytes_read();
        for r in 0..rows {
            whole.read_row(r, p, &mut iw);
            store.read_row(r, p, &mut is);
            if iw != is {
                return Err(format!("row {r} differs (shards={shards} p={p})"));
            }
        }
        if store.bytes_read() as f64 != store.epoch_bytes(p) {
            return Err(format!(
                "accounting: read {} vs epoch_bytes {}",
                store.bytes_read(),
                store.epoch_bytes(p)
            ));
        }
        Ok(())
    });
}

/// Store bytes/epoch are strictly increasing in precision and below the
/// f32 epoch (the Fig 5 ordering, from the store's own accounting).
#[test]
fn prop_store_bytes_ordering() {
    Prop::new(32).check("store-bytes-ordering", |rng| {
        let rows = 8 + small_size(rng, 100);
        // cols > 16: below that, word-granularity plane padding makes the
        // 8-plane read as large as the f32 row (see weave.rs docs)
        let cols = 17 + small_size(rng, 200);
        let a = rand_matrix(rng, rows, cols, 1.0);
        let sc = ColumnScale::from_data(&a);
        let store = ShardedStore::ingest(&a, &sc, 8, rng.next_u64(), 1 + rng.below(8), 1);
        let f32_bytes = (rows * cols * 4) as f64;
        let mut prev = 0.0;
        for p in [1u32, 2, 4, 8] {
            let b = store.epoch_bytes(p);
            if b <= prev {
                return Err(format!("Q{p} bytes {b} not > {prev}"));
            }
            if b >= f32_bytes {
                return Err(format!("Q{p} bytes {b} not < f32 {f32_bytes} (cols={cols})"));
            }
            prev = b;
        }
        Ok(())
    });
}

/// The strided minibatch iterator partitions an epoch across any worker
/// count: batches are disjoint, cover ⌊rows/batch⌋·batch rows, and the
/// union is independent of the number of workers.
#[test]
fn prop_minibatch_iter_partitions() {
    Prop::new(48).check("minibatch-partition", |rng| {
        let rows = 2 + small_size(rng, 300);
        let batch = 1 + rng.below(rows.min(16));
        let workers = 1 + rng.below(6);
        let seed = rng.next_u64();
        let mut seen = vec![0u32; rows];
        for w in 0..workers {
            let mut it = MinibatchIter::strided(rows, batch, seed, w, workers);
            while let Some(b) = it.next_batch() {
                for &r in b {
                    seen[r as usize] += 1;
                }
            }
        }
        if seen.iter().any(|&c| c > 1) {
            return Err("a row was assigned twice".into());
        }
        let covered: usize = seen.iter().map(|&c| c as usize).sum();
        if covered != (rows / batch) * batch {
            return Err(format!("covered {covered} of {}", (rows / batch) * batch));
        }
        // worker-count independence of the union
        let mut single = vec![0u32; rows];
        let mut it = MinibatchIter::new(rows, batch, seed);
        while let Some(b) = it.next_batch() {
            for &r in b {
                single[r as usize] += 1;
            }
        }
        if single != seen {
            return Err("union differs from single-worker epoch".into());
        }
        Ok(())
    });
}

/// Precision schedules always emit p within [1, store_bits] and are
/// non-decreasing over any loss history.
#[test]
fn prop_schedules_bounded_and_monotone() {
    Prop::new(48).check("schedule-bounds", |rng| {
        let store_bits = 1 + rng.below(16) as u32;
        let start = 1 + rng.below(16) as u32;
        let max = 1 + rng.below(16) as u32;
        let sched = match rng.below(3) {
            0 => PrecisionSchedule::Fixed(start),
            1 => PrecisionSchedule::StepUp { start, every: 1 + rng.below(4), max },
            _ => PrecisionSchedule::RefetchTriggered {
                start,
                max,
                min_rel_improve: rng.f64() * 0.2,
            },
        };
        let mut state = ScheduleState::new(sched, store_bits);
        let mut hist = vec![1.0f64];
        let mut prev = 0u32;
        for e in 0..20 {
            let p = state.precision_for_epoch(e, &hist);
            if !(1..=store_bits).contains(&p) {
                return Err(format!("{sched:?}: p={p} outside 1..={store_bits}"));
            }
            if p < prev {
                return Err(format!("{sched:?}: p decreased {prev} -> {p}"));
            }
            prev = p;
            let last = *hist.last().unwrap();
            hist.push(last * (0.5 + rng.f64() * 0.6)); // noisy descent
        }
        Ok(())
    });
}

/// JL sketches preserve norms within the expected concentration band.
#[test]
fn prop_jl_norm_preservation() {
    use zipml::quant::jl::JlSketch;
    Prop::new(24).check("jl-norms", |rng| {
        let n = 32 + rng.below(256);
        let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let jl = JlSketch::new(512, n, rng.next_u64());
        let s = jl.sketch(&v);
        let ratio = zipml::tensor::norm2(&s) / zipml::tensor::norm2(&v).max(1e-9);
        if !(0.7..1.3).contains(&ratio) {
            return Err(format!("norm ratio {ratio}"));
        }
        Ok(())
    });
}
