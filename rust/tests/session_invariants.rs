//! Cross-cutting [`HostSession`] result invariants over the full read ×
//! execution matrix, plus the DESIGN.md §10 observability contracts:
//! telemetry byte counters equal the store's exact-byte accounting (and
//! the analytic truncation / DS-2× formulas), and trace content is
//! deterministic under a fixed seed once the wall-clock fields are
//! stripped ([`zipml::telemetry::stable_view`]).

use std::sync::Arc;

use zipml::data::synthetic::make_regression;
use zipml::data::Dataset;
use zipml::quant::ColumnScale;
use zipml::sgd::{Execution, HostSession, ModelKind, ReadStrategy};
use zipml::store::{PrecisionSchedule, ShardedStore};
use zipml::telemetry::{stable_view, validate, Metrics, TraceLevel, TraceSink};

/// A small sharded store with an enabled counter registry attached, so
/// the store's exact-byte accounting mirrors into the registry.
fn store_with_metrics(ds: &Dataset, bits: u32) -> (ShardedStore, Arc<Metrics>) {
    let scale = ColumnScale::from_data(&ds.train_a);
    let mut store = ShardedStore::ingest(&ds.train_a, &scale, bits, 9, 4, 0);
    let m = Arc::new(Metrics::enabled());
    store.attach_metrics(Arc::clone(&m));
    (store, m)
}

/// Every read × execution combination upholds the `SessionResult`
/// invariants — curve length, initial loss, precision schedule, update
/// count — and the exact byte contract: store accounting == telemetry
/// counters == the analytic per-epoch formula (`k·p·⌈n/64⌉·8`
/// truncating bytes, exactly doubled by double sampling).
#[test]
fn session_invariants_across_read_and_execution_matrix() {
    let ds = make_regression("inv_matrix", 150, 16, 24, 77);
    let k = ds.k_train();
    let (store, metrics) = store_with_metrics(&ds, 8);
    let (epochs, batch, p) = (3usize, 32usize, 4u32);
    let nb = k.div_ceil(batch);
    // the analytic truncating row cost (DESIGN.md §5): p planes of
    // ⌈n/64⌉ words, 8 bytes each — the store's accounting must agree
    let trunc_row_bytes = p as u64 * ds.n().div_ceil(64) as u64 * 8;
    assert_eq!(store.bytes_per_row(p) as u64, trunc_row_bytes);
    let reads =
        [ReadStrategy::Truncate, ReadStrategy::DoubleSample, ReadStrategy::Popcount { q: 8 }];
    let execs = [Execution::Sequential, Execution::Hogwild { threads: 2 }];
    let mut initial = None;
    for read in reads {
        for exec in execs {
            let r = HostSession::over(&ds, &store)
                .read(read)
                .execution(exec)
                .schedule(PrecisionSchedule::Fixed(p))
                .epochs(epochs)
                .batch(batch)
                .lr0(0.02)
                .seed(5)
                .run()
                .unwrap();
            assert_eq!(r.loss_curve.len(), epochs + 1, "{}", r.label);
            let init = *initial.get_or_insert(r.loss_curve[0]);
            assert_eq!(r.loss_curve[0], init, "loss_curve[0] is the initial loss ({})", r.label);
            assert_eq!(r.precisions, vec![p; epochs], "{}", r.label);
            let expected_updates = match exec {
                Execution::Sequential => epochs * nb,
                Execution::Hogwild { .. } => epochs * k,
            };
            assert_eq!(r.updates, expected_updates, "{}", r.label);
            let per_visit = match read {
                ReadStrategy::DoubleSample => 2 * trunc_row_bytes,
                _ => trunc_row_bytes,
            };
            let total = epochs as u64 * k as u64 * per_visit;
            assert_eq!(store.bytes_read(), total, "store accounting ({})", r.label);
            assert_eq!(metrics.bytes_read_total(), total, "telemetry mirror ({})", r.label);
            assert_eq!(metrics.bytes_read_at(p), total, "per-precision bucket ({})", r.label);
            assert_eq!(metrics.row_visits(), epochs as u64 * k as u64, "{}", r.label);
            assert_eq!(r.sample_bytes_per_epoch, (k as u64 * per_visit) as f64, "{}", r.label);
        }
    }
    // Dense: storeless analytic accounting, precision pinned at 32
    for exec in execs {
        let r = HostSession::dense(&ds)
            .execution(exec)
            .epochs(epochs)
            .batch(batch)
            .seed(5)
            .run()
            .unwrap();
        assert_eq!(r.loss_curve.len(), epochs + 1, "{}", r.label);
        assert_eq!(r.precisions, vec![32; epochs], "{}", r.label);
        assert_eq!(r.sample_bytes_per_epoch, (k * ds.n() * 4) as f64, "{}", r.label);
    }
    // the sequential dequantize oracle upholds the same byte contract
    let r = HostSession::over(&ds, &store)
        .dequant_oracle()
        .schedule(PrecisionSchedule::Fixed(p))
        .epochs(epochs)
        .batch(batch)
        .seed(5)
        .run()
        .unwrap();
    assert_eq!(r.loss_curve.len(), epochs + 1);
    assert_eq!(store.bytes_read(), epochs as u64 * k as u64 * trunc_row_bytes);
    assert_eq!(metrics.bytes_read_total(), store.bytes_read());
}

/// A traced double-sampled run emits a schema-valid trace whose byte
/// totals equal the registry, and two same-seed runs agree byte for byte
/// once [`stable_view`] strips the wall-clock fields.
#[test]
fn trace_is_schema_valid_and_deterministic_under_fixed_seed() {
    let ds = make_regression("inv_trace", 120, 12, 16, 31);
    let (store, metrics) = store_with_metrics(&ds, 6);
    let run = |sink: &TraceSink| {
        HostSession::over(&ds, &store)
            .loss(&ModelKind::Logistic)
            .read(ReadStrategy::DoubleSample)
            .schedule(PrecisionSchedule::Fixed(3))
            .epochs(4)
            .batch(32)
            .seed(11)
            .metrics(&metrics)
            .trace(sink)
            .run()
            .unwrap()
    };
    let s1 = TraceSink::in_memory(TraceLevel::Full);
    let r1 = run(&s1);
    let s2 = TraceSink::in_memory(TraceLevel::Full);
    let r2 = run(&s2);
    assert_eq!(r1.loss_curve, r2.loss_curve, "the session itself must replay from its seed");
    let (t1, t2) = (s1.lines().join("\n"), s2.lines().join("\n"));
    let stats = validate(&t1).expect("schema-valid trace");
    assert_eq!(stats.epochs, 4);
    assert_eq!(stats.total_bytes, metrics.bytes_read_total(), "trace bytes == registry bytes");
    assert_eq!(stats.final_loss, r1.loss_curve.last().copied());
    let stable =
        |t: &str| -> Vec<String> { t.lines().map(|l| stable_view(l).unwrap()).collect() };
    assert_eq!(stable(&t1), stable(&t2), "non-timing trace content must be deterministic");
}

/// The determinism contract extends to single-threaded hogwild: with one
/// worker the racy path is a serial replay, so the stable trace view —
/// including the per-worker `hogwild_epoch` update counts — is identical
/// across same-seed runs.
#[test]
fn hogwild_single_thread_trace_is_deterministic() {
    let ds = make_regression("inv_hog", 90, 10, 16, 13);
    let (store, metrics) = store_with_metrics(&ds, 5);
    let run = |sink: &TraceSink| {
        HostSession::over(&ds, &store)
            .execution(Execution::Hogwild { threads: 1 })
            .schedule(PrecisionSchedule::Fixed(4))
            .epochs(3)
            .seed(23)
            .metrics(&metrics)
            .trace(sink)
            .run()
            .unwrap()
    };
    let s1 = TraceSink::in_memory(TraceLevel::Full);
    run(&s1);
    let s2 = TraceSink::in_memory(TraceLevel::Full);
    run(&s2);
    validate(&s1.lines().join("\n")).expect("schema-valid hogwild trace");
    let stable = |s: &TraceSink| -> Vec<String> {
        s.lines().iter().map(|l| stable_view(l).unwrap()).collect()
    };
    assert_eq!(stable(&s1), stable(&s2));
    assert_eq!(metrics.hogwild_updates(), 3 * 90, "one worker visits every row each epoch");
}
