//! Variance-optimal quantization demo (paper §3, Fig 3 + Fig 7a).
//!
//! Builds a skewed empirical distribution, compares uniform vs exact-DP vs
//! discretized-DP vs ADAQUANT level placement, then shows the effect on
//! actual training (optimal 3-bit ≈ uniform 5-bit).
//!
//!   cargo run --release --example optimal_quantization

use zipml::data::synthetic::make_regression;
use zipml::quant::{
    discretized_optimal_levels, greedy::adaquant_levels, optimal_levels, quantization_variance,
};
use zipml::rng::Rng;
use zipml::runtime::Runtime;
use zipml::sgd::{self, Mode, ModelKind, TrainConfig};

fn main() -> anyhow::Result<()> {
    // --- level placement on a bimodal distribution -------------------------
    let mut rng = Rng::new(7);
    let mut pts: Vec<f32> = (0..6000).map(|_| (rng.normal() * 0.07 + 0.25).clamp(0.0, 1.0)).collect();
    pts.extend((0..1500).map(|_| (rng.normal() * 0.04 + 0.8).clamp(0.0, 1.0)));

    println!("level placement, 8 levels on a bimodal distribution:");
    let uniform: Vec<f32> = (0..8).map(|i| i as f32 / 7.0).collect();
    let t0 = zipml::telemetry::Stopwatch::start();
    let exact = optimal_levels(&pts, 8);
    let t_exact = t0.elapsed_secs();
    let t0 = zipml::telemetry::Stopwatch::start();
    let disc = discretized_optimal_levels(&pts, 8, 128);
    let t_disc = t0.elapsed_secs();
    let t0 = zipml::telemetry::Stopwatch::start();
    let greedy = adaquant_levels(&pts, 8);
    let t_greedy = t0.elapsed_secs();
    for (name, lv, t) in [
        ("uniform", &uniform, 0.0f64),
        ("exact DP  O(kN^2)", &exact, t_exact),
        ("discretized DP", &disc, t_disc),
        ("ADAQUANT 2-approx", &greedy, t_greedy),
    ] {
        println!(
            "  {name:20} MV={:.3e}  ({:.2}ms)  levels={:?}",
            quantization_variance(&pts, lv),
            t * 1e3,
            lv.iter().map(|v| (v * 1000.0).round() / 1000.0).collect::<Vec<_>>()
        );
    }

    // --- effect on convergence (Fig 7a) ------------------------------------
    let rt = Runtime::open_default()?;
    let ds = make_regression("yearprediction", 8192, 1024, 90, 42);
    let mut cfg = TrainConfig::new(ModelKind::Linreg, Mode::DoubleSample { bits: 3 });
    cfg.epochs = 12;
    cfg.lr0 = 0.05;
    let u3 = sgd::train(&rt, &ds, &cfg)?;
    cfg.mode = Mode::DoubleSample { bits: 5 };
    let u5 = sgd::train(&rt, &ds, &cfg)?;
    cfg.mode = Mode::OptimalDs { levels: 8 };
    let o3 = sgd::train(&rt, &ds, &cfg)?;

    println!("\ntraining on YearPrediction-like (n=90):");
    println!("  uniform 3-bit  final loss {:.5}", u3.final_loss);
    println!("  uniform 5-bit  final loss {:.5}", u5.final_loss);
    println!("  optimal 3-bit  final loss {:.5}", o3.final_loss);
    println!("  → optimal 3-bit ≈ uniform 5-bit: {:.2}x bit saving (paper: 1.7x)",
        5.0 / 3.0);
    Ok(())
}
