//! Tomographic reconstruction under quantized projection data (paper §1's
//! motivating application; Table 1 bottom row).
//!
//! A Shepp-Logan phantom is projected by a parallel-beam operator; the
//! 64×64 volume (n = 4096) is reconstructed by SGD from full-precision vs
//! double-sampled quantized rays, reporting reconstruction RMSE and the
//! data-movement saving.
//!
//!   cargo run --release --example tomography

use zipml::data::tomo;
use zipml::runtime::Runtime;
use zipml::sgd::{self, Mode, ModelKind, TrainConfig};

fn ascii_render(img: &[f32], size: usize) {
    let ramp = b" .:-=+*#%@";
    let max = img.iter().cloned().fold(0.0f32, f32::max).max(1e-9);
    for r in (0..size).step_by(2) {
        let mut line = String::new();
        for c in (0..size).step_by(1) {
            let v = (img[r * size + c].max(0.0) / max * 9.0) as usize;
            line.push(ramp[v.min(9)] as char);
        }
        println!("  {line}");
    }
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    let size = 64;
    let (ds, truth) = tomo::make_tomography(size, 96, 42);
    println!(
        "projector: {} rays × {} pixels ({} MB dense)",
        ds.k_train(),
        ds.n(),
        ds.k_train() * ds.n() * 4 / (1 << 20)
    );

    let mut cfg = TrainConfig::new(ModelKind::Linreg, Mode::Full);
    cfg.epochs = 25;
    cfg.lr0 = 0.4;
    cfg.eval_batches = 8;
    let fp = sgd::train(&rt, &ds, &cfg)?;
    cfg.mode = Mode::DoubleSample { bits: 8 };
    let q8 = sgd::train(&rt, &ds, &cfg)?;

    println!("\n{:>8} {:>14} {:>12} {:>10}", "mode", "sinogram MSE", "recon RMSE", "bytes/ep");
    for r in [&fp, &q8] {
        println!(
            "{:>8} {:>14.6} {:>12.4} {:>10.2e}",
            r.mode_label,
            r.final_loss,
            tomo::reconstruction_rmse(&r.final_model, &truth),
            r.sample_bytes_per_epoch
        );
    }
    println!(
        "\ndata movement saved: {:.2}x (paper: 2.7x at negligible quality loss)",
        fp.sample_bytes_per_epoch / q8.sample_bytes_per_epoch
    );

    println!("\nreconstruction (8-bit quantized rays):");
    ascii_render(&q8.final_model, size);
    println!("\nground truth:");
    ascii_render(&truth, size);
    Ok(())
}
