//! One stored copy, any precision: quantize a dataset ONCE into the
//! bit-weaved sharded store, then train at 2, 4, and 8 bits — and with a
//! step-up schedule — by reading only the needed bit planes per epoch.
//! Training runs on the fused weaved-domain kernels (store/kernel.rs):
//! dot products and gradients come straight from the bit planes, no f32
//! row materialization. Artifact-free; runs in every checkout.
//!
//!   cargo run --release --example store_weaving

use zipml::data::synthetic::make_regression;
use zipml::fpga::pipeline::{epoch_bytes, epoch_seconds, store_epoch_seconds, Precision};
use zipml::quant::ColumnScale;
use zipml::sgd::{HostSession, ReadStrategy};
use zipml::store::{PrecisionSchedule, ShardedStore};

fn main() {
    let ds = make_regression("weave_demo", 8192, 1024, 100, 42);
    let scale = ColumnScale::from_data(&ds.train_a);

    // quantize-on-first-epoch, in parallel across shards, ONCE at 8 bits
    let t0 = zipml::telemetry::Stopwatch::start();
    let store = ShardedStore::ingest(&ds.train_a, &scale, 8, 42, 16, 0);
    println!(
        "ingested {}x{} at {} bits into {} shards in {:.1} ms ({} B stored — one copy serves p=1..=8)",
        store.rows(),
        store.cols(),
        store.bits(),
        store.num_shards(),
        t0.elapsed_secs() * 1e3,
        store.stored_bytes(),
    );

    // one HostSession builder serves every (read strategy × schedule)
    // below — the same session API the CLI's `--host` path drives
    let session = HostSession::over(&ds, &store).epochs(12).batch(64).lr0(0.05).seed(7);
    println!("\n{:>12} {:>12} {:>14} {:>16}", "schedule", "final_loss", "bytes/epoch", "epoch_s");
    for p in [2u32, 4, 8] {
        let r = session.schedule(PrecisionSchedule::Fixed(p)).run().expect("truncating session");
        println!(
            "{:>12} {:>12.6} {:>14.3e} {:>16.3e}",
            format!("fixed p={p}"),
            r.loss_curve.last().unwrap(),
            r.sample_bytes_per_epoch,
            store_epoch_seconds(&store, p),
        );
    }
    let step = PrecisionSchedule::StepUp { start: 2, every: 4, max: 8 };
    let r = session.schedule(step).run().expect("step-up session");
    println!(
        "{:>12} {:>12.6} {:>14.3e}   (per-epoch p: {:?})",
        "step 2→8",
        r.loss_curve.last().unwrap(),
        r.sample_bytes_per_epoch,
        r.precisions,
    );

    // double sampling (§2.2) from the SAME stored copy: two unbiased
    // stochastic p-plane draws per row visit — the carry comes from the
    // residual planes — so low-precision reads stay unbiased where the
    // truncating reads above are not; both fetches are in the accounting
    for p in [2u32, 4] {
        let r = session
            .read(ReadStrategy::DoubleSample)
            .schedule(PrecisionSchedule::Fixed(p))
            .run()
            .expect("double-sampled session");
        println!(
            "{:>12} {:>12.6} {:>14.3e}   (2 draws/row: bytes exactly 2x p={p})",
            format!("ds p={p}"),
            r.loss_curve.last().unwrap(),
            r.sample_bytes_per_epoch,
        );
    }

    // the Fig 5 argument, from the store's own accounting
    let (k, n) = (store.rows(), store.cols());
    let t32 = epoch_seconds(Precision::Float, k, n);
    println!("\nsimulated FPGA epoch times (store-derived bytes):");
    for p in [1u32, 2, 4, 8] {
        let t = store_epoch_seconds(&store, p);
        println!("  Q{p}: {t:.3e} s   ({:.2}x vs float {:.3e} s)", t32 / t, t32);
    }
    println!(
        "  f32 epoch moves {:.3e} B; the 8-bit weaved read moves {:.3e} B",
        epoch_bytes(Precision::Float, k, n),
        store.epoch_bytes(8),
    );
}
