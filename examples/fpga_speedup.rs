//! Fig 5 reproduction: loss-vs-time for FPGA float, FPGA quantized, and a
//! real multi-threaded Hogwild! CPU baseline.
//!
//!   cargo run --release --example fpga_speedup

use zipml::data::synthetic::make_regression;
use zipml::fpga::{self, Precision};
use zipml::runtime::Runtime;
use zipml::sgd::{self, Execution, HostSession, Mode, ModelKind, TrainConfig};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    let ds = make_regression("synthetic100", 10_000, 1024, 100, 42);
    let (k, n) = (ds.k_train(), ds.n());
    let epochs = 15;

    let mut cfg = TrainConfig::new(ModelKind::Linreg, Mode::Full);
    cfg.epochs = epochs;
    cfg.lr0 = 0.05;
    let fp = sgd::train(&rt, &ds, &cfg)?;
    cfg.mode = Mode::DoubleSample { bits: 4 };
    let q4 = sgd::train(&rt, &ds, &cfg)?;
    let hw = HostSession::dense(&ds)
        .execution(Execution::Hogwild {
            threads: 10.min(std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)),
        })
        .epochs(epochs)
        .lr0(0.02)
        .seed(42)
        .run()?;

    let t32 = fpga::epoch_seconds(Precision::Float, k, n);
    let tq4 = fpga::epoch_seconds(Precision::Q(4), k, n);
    let thw = fpga::hogwild::hogwild_epoch_seconds(k, n, 10);

    println!("simulated epoch times: FPGA-float {t32:.3e}s  FPGA-Q4 {tq4:.3e}s  Hogwild {thw:.3e}s");
    println!("FPGA quantized speedup: {:.2}x (paper: 6-7x)\n", t32 / tq4);

    println!("{:>10} {:>12} {:>12} {:>12}", "time_ms", "fpga_float", "fpga_q4", "hogwild10");
    for e in 0..=epochs {
        println!(
            "{:>10.3} {:>12.6} {:>12.6} {:>12.6}",
            e as f64 * t32 * 1e3,
            fp.loss_curve.get(e).copied().unwrap_or(f64::NAN),
            // Q4 reaches epoch e at time e*tq4 — print aligned by epoch;
            // the CSV from `zipml figure fig5` has the exact time axis.
            q4.loss_curve.get(e).copied().unwrap_or(f64::NAN),
            hw.loss_curve.get(e).copied().unwrap_or(f64::NAN),
        );
    }
    println!("\nat any loss target, FPGA-Q4 arrives ~{:.1}x earlier than FPGA-float", t32 / tq4);
    println!("(real Hogwild wallclock on this machine: {:.2}s for {} updates)",
        hw.wall_secs, hw.updates);
    Ok(())
}
