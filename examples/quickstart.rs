//! Quickstart: train linear regression at 5-bit end-to-end low precision
//! and compare against FP32 — the paper's core claim in ~40 lines.
//!
//!   make artifacts && cargo run --release --example quickstart

use zipml::data::synthetic::make_regression;
use zipml::runtime::Runtime;
use zipml::sgd::{self, Mode, ModelKind, TrainConfig};

fn main() -> anyhow::Result<()> {
    // 1. open the AOT-compiled artifact store (PJRT CPU client)
    let rt = Runtime::open_default()?;

    // 2. a Synthetic-100-like regression problem (Table 1)
    let ds = make_regression("quickstart", 8192, 1024, 100, 42);

    // 3. train FP32 vs double-sampled 5-bit (Fig 4a)
    let mut cfg = TrainConfig::new(ModelKind::Linreg, Mode::Full);
    cfg.epochs = 12;
    cfg.lr0 = 0.05;
    let fp = sgd::train(&rt, &ds, &cfg)?;

    cfg.mode = Mode::DoubleSample { bits: 5 };
    let q5 = sgd::train(&rt, &ds, &cfg)?;

    println!("epoch   fp32        ds5");
    for (e, (a, b)) in fp.loss_curve.iter().zip(&q5.loss_curve).enumerate() {
        println!("{e:5}   {a:<10.6}  {b:<10.6}");
    }
    println!(
        "\nfinal: fp32 {:.6} vs 5-bit {:.6}  ({:.2}x less sample traffic)",
        fp.final_loss,
        q5.final_loss,
        fp.sample_bytes_per_epoch / q5.sample_bytes_per_epoch
    );
    println!("test MSE: fp32 {:.6} vs 5-bit {:.6}",
        ds.test_mse(&fp.final_model), ds.test_mse(&q5.final_model));
    Ok(())
}
