//! END-TO-END DRIVER — exercises the full three-layer system on real small
//! workloads, proving all layers compose (DESIGN.md; EXPERIMENTS.md §E2E):
//!
//!   1. linear models (linreg + LS-SVM) trained through the PJRT runtime
//!      at FP32 / double-sampled / end-to-end quantized precision,
//!   2. non-linear models (logistic, SVM) via Chebyshev gradients and
//!      refetching,
//!   3. the deep-learning extension: a 235k-parameter MLP trained for
//!      several epochs with FP32 vs XNOR5 vs Optimal5 weight grids,
//!      logging the loss curve per epoch,
//!   4. headline metrics: final losses, accuracies, bandwidth savings.
//!
//!   make artifacts && cargo run --release --example e2e_zipml

use zipml::data::synthetic::{make_classification, make_regression};
use zipml::runtime::Runtime;
use zipml::sgd::modes::RefetchStrategy;
use zipml::sgd::{self, deep, Mode, ModelKind, TrainConfig};

fn banner(s: &str) {
    println!("\n=== {s} {}", "=".repeat(66usize.saturating_sub(s.len())));
}

fn main() -> anyhow::Result<()> {
    let t0 = zipml::telemetry::Stopwatch::start();
    let rt = Runtime::open_default()?;

    // ---------------- 1. linear models ------------------------------------
    banner("1/4 linear models (linreg synthetic-100, LS-SVM gisette-like)");
    let ds_reg = make_regression("synthetic100", 10_000, 2048, 100, 42);
    let mut cfg = TrainConfig::new(ModelKind::Linreg, Mode::Full);
    cfg.epochs = 15;
    cfg.lr0 = 0.05;
    let fp = sgd::train(&rt, &ds_reg, &cfg)?;
    cfg.mode = Mode::DoubleSample { bits: 5 };
    let q5 = sgd::train(&rt, &ds_reg, &cfg)?;
    cfg.mode = Mode::EndToEnd { bits_s: 6, bits_m: 8, bits_g: 8 };
    let e2e = sgd::train(&rt, &ds_reg, &cfg)?;
    println!("linreg final loss: fp32={:.5} ds5={:.5} e2e6/8/8={:.5}",
        fp.final_loss, q5.final_loss, e2e.final_loss);
    println!("sample traffic: fp32 {:.2e} B/epoch → ds5 {:.2e} ({:.1}x saving)",
        fp.sample_bytes_per_epoch, q5.sample_bytes_per_epoch,
        fp.sample_bytes_per_epoch / q5.sample_bytes_per_epoch);

    let ds_cls = make_classification("gisette", 6_000, 1_000, 500, 42);
    let mut cfg = TrainConfig::new(ModelKind::Lssvm { c: 1e-4 }, Mode::Full);
    cfg.epochs = 12;
    cfg.lr0 = 0.5;
    let svf = sgd::train(&rt, &ds_cls, &cfg)?;
    cfg.mode = Mode::DoubleSample { bits: 6 };
    let svq = sgd::train(&rt, &ds_cls, &cfg)?;
    println!("ls-svm final loss: fp32={:.5} ds6={:.5}; test acc fp32={:.3} ds6={:.3}",
        svf.final_loss, svq.final_loss,
        ds_cls.test_accuracy(&svf.final_model), ds_cls.test_accuracy(&svq.final_model));

    // ---------------- 2. non-linear models --------------------------------
    banner("2/4 non-linear models (logistic Chebyshev, SVM refetch)");
    let ds_nl = make_classification("cod-rna", 8_192, 2_048, 100, 42);
    let mut cfg = TrainConfig::new(ModelKind::Logistic, Mode::Full);
    cfg.epochs = 10;
    cfg.lr0 = 0.5;
    let lf = sgd::train(&rt, &ds_nl, &cfg)?;
    cfg.mode = Mode::Cheby { bits: 4 };
    let lc = sgd::train(&rt, &ds_nl, &cfg)?;
    cfg.mode = Mode::NearestRound { bits: 8 };
    let lr8 = sgd::train(&rt, &ds_nl, &cfg)?;
    println!("logistic: fp32={:.5} cheby4={:.5} round8={:.5} (negative result: round8 ≈ cheby)",
        lf.final_loss, lc.final_loss, lr8.final_loss);

    let mut cfg = TrainConfig::new(ModelKind::Svm,
        Mode::Refetch { bits: 8, strategy: RefetchStrategy::L1 });
    cfg.epochs = 10;
    cfg.lr0 = 0.2;
    let sv = sgd::train(&rt, &ds_nl, &cfg)?;
    println!("svm refetch-l1 8-bit: final={:.5} refetched {:.2}% of samples (paper: <5-6%)",
        sv.final_loss, sv.refetch_fraction * 100.0);

    // ---------------- 3. deep learning ------------------------------------
    banner("3/4 deep-learning extension (235k-param MLP, 5-level weights)");
    let data = deep::make_deep_dataset(8_192, 2_048, 42);
    let epochs = 8;
    let mfp = deep::train_mlp(&rt, &data, deep::WeightQuant::FullPrecision, epochs, 0.1, 42)?;
    let mxn = deep::train_mlp(&rt, &data, deep::WeightQuant::Uniform { levels: 5 }, epochs, 0.1, 42)?;
    let mop = deep::train_mlp(&rt, &data, deep::WeightQuant::Optimal { levels: 5 }, epochs, 0.1, 42)?;
    println!("epoch  loss_fp32  loss_xnor5  loss_opt5   acc_fp32  acc_xnor5  acc_opt5");
    for e in 0..epochs {
        println!("{e:5}  {:9.4}  {:10.4}  {:9.4}   {:8.3}  {:9.3}  {:8.3}",
            mfp.train_loss_curve[e], mxn.train_loss_curve[e], mop.train_loss_curve[e],
            mfp.test_acc_curve[e], mxn.test_acc_curve[e], mop.test_acc_curve[e]);
    }
    println!("Optimal5 − XNOR5 final-accuracy gap: {:+.2} points (paper: >5)",
        (mop.final_test_acc - mxn.final_test_acc) * 100.0);

    // ---------------- 4. headline summary ----------------------------------
    banner("4/4 headline metrics");
    let st = rt.stats();
    println!("PJRT: {} artifact executions, {} compiles, {:.2}s device time",
        st.executions, st.compile_count, st.exec_nanos as f64 * 1e-9);
    println!("double-sampling matches FP32 at 5-6 bits → {:.1}x bandwidth saving",
        fp.sample_bytes_per_epoch / q5.sample_bytes_per_epoch);
    println!("total wallclock: {:.1}s", t0.elapsed_secs());
    println!("\nE2E VALIDATION PASSED: all three layers composed on real workloads");
    Ok(())
}
