"""AOT registry and manifest sanity — the compile path contract with Rust."""
import json
from pathlib import Path

import numpy as np
import pytest

from compile import aot

ARTIFACT_DIR = Path(__file__).resolve().parents[2] / "artifacts"


def test_registry_names_well_formed():
    arts = aot.registry()
    assert len(arts) > 80
    for name, (fn, args, nout, meta) in arts.items():
        assert "kind" in meta
        assert nout >= 1
        names = [a for (a, _) in args]
        assert len(names) == len(set(names)), name


def test_lower_one_artifact_produces_parseable_hlo():
    arts = aot.registry()
    fn, args, _, _ = arts["linreg_ds_step_n10"]
    text = aot.to_hlo_text(fn, [s for (_, s) in args])
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True → root is a tuple
    assert "tuple(" in text or "(f32[" in text


@pytest.mark.skipif(not (ARTIFACT_DIR / "manifest.json").exists(),
                    reason="run `make artifacts` first")
def test_manifest_matches_registry_and_files():
    manifest = json.loads((ARTIFACT_DIR / "manifest.json").read_text())
    arts = aot.registry()
    assert set(manifest["artifacts"].keys()) == set(arts.keys())
    for name, entry in manifest["artifacts"].items():
        f = ARTIFACT_DIR / entry["file"]
        assert f.exists() and f.stat().st_size > 0, name
        _, args, nout, _ = arts[name]
        assert entry["num_outputs"] == nout
        assert [tuple(i["shape"]) for i in entry["inputs"]] == [tuple(s.shape) for (_, s) in args]


@pytest.mark.skipif(not (ARTIFACT_DIR / "manifest.json").exists(),
                    reason="run `make artifacts` first")
def test_manifest_dtypes():
    manifest = json.loads((ARTIFACT_DIR / "manifest.json").read_text())
    entry = manifest["artifacts"]["linreg_ds_u8_step_n100"]
    dts = {i["name"]: i["dtype"] for i in entry["inputs"]}
    assert dts["idx1"] == "u8" and dts["x"] == "f32"
    entry = manifest["artifacts"]["mlp_fp_step"]
    dts = {i["name"]: i["dtype"] for i in entry["inputs"]}
    assert dts["y"] == "i32"
